"""Property-based invariants of the sampling framework (hypothesis)."""
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import freqfns as F
from repro.core import samplers as S
from repro.core import vectorized as V
from repro.core.segments import EMPTY


def _stream(draw_keys, n):
    rng = np.random.default_rng(sum(draw_keys) % 2**31)
    return rng.choice(draw_keys, size=n).astype(np.int64)


@given(
    keys=st.lists(st.integers(min_value=0, max_value=10**6), min_size=1, max_size=30, unique=True),
    n=st.integers(min_value=1, max_value=500),
    k=st.integers(min_value=1, max_value=40),
    l=st.sampled_from([0.5, 1.0, 5.0, 100.0]),
)
@settings(max_examples=25, deadline=None)
def test_fixed_k_invariants(keys, n, k, l):
    stream = _stream(keys, n)
    res = V.sample_fixed_k(stream, None, k=k, l=l, salt=1, chunk=64)
    # sample size <= min(k, distinct)
    assert len(res.keys) <= min(k, len(np.unique(stream)))
    # sampled keys are real keys, counts within (0, w_x]
    ukeys, cnts = np.unique(stream, return_counts=True)
    wmap = dict(zip(ukeys.tolist(), cnts.tolist()))
    for x, c in zip(res.keys.tolist(), res.counts.tolist()):
        assert x in wmap
        assert 0 < c <= wmap[x] + 1e-3
    assert int(EMPTY) not in res.keys.tolist()


@given(
    keys=st.lists(st.integers(min_value=0, max_value=10**6), min_size=1, max_size=30, unique=True),
    n=st.integers(min_value=1, max_value=400),
    tau=st.floats(min_value=0.05, max_value=0.9),
    kind=st.sampled_from(["continuous", "discrete", "distinct", "sh"]),
)
@settings(max_examples=25, deadline=None)
def test_fixed_tau_matches_oracle(keys, n, tau, kind):
    """Exact oracle equivalence on random small streams — all schemes."""
    stream = _stream(keys, n)
    l = {"continuous": 3.0, "discrete": 4, "distinct": 1, "sh": 1e9}[kind]
    if kind == "continuous":
        ro = S.alg4_fixed_tau_continuous(stream, None, tau, l=l, salt=2)
    else:
        ol = {"discrete": 4, "distinct": 1, "sh": math.inf}[kind]
        ro = S.alg2_fixed_tau_discrete(stream, tau, l=ol, salt=2, kind=kind)
    rv = V.sample_fixed_tau(stream, None, tau=tau, l=l, kind=kind, salt=2, chunk=64, capacity=1024)
    np.testing.assert_array_equal(ro.keys, rv.keys)
    np.testing.assert_allclose(ro.counts, rv.counts, rtol=1e-3, atol=1e-2)


@given(
    n=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=50),
    chunk=st.sampled_from([16, 64, 256]),
)
@settings(max_examples=20, deadline=None)
def test_two_pass_chunk_invariance(n, k, chunk):
    """The 2-pass result must not depend on the chunking (mergeability)."""
    rng = np.random.default_rng(n * 1000 + k)
    stream = rng.integers(0, 50, size=n).astype(np.int64)
    r1 = V.sample_two_pass(stream, None, k=k, l=5.0, salt=4, chunk=chunk)
    r2 = V.sample_two_pass(stream, None, k=k, l=5.0, salt=4, chunk=512)
    np.testing.assert_array_equal(np.sort(r1.keys), np.sort(r2.keys))
    np.testing.assert_allclose(np.sort(r1.counts), np.sort(r2.counts), rtol=1e-5)


@given(weights=st.lists(st.floats(min_value=0.01, max_value=50.0), min_size=2, max_size=200))
@settings(max_examples=20, deadline=None)
def test_two_pass_weights_exact(weights):
    """Pass 2 recovers exact per-key weights."""
    n = len(weights)
    rng = np.random.default_rng(n)
    stream = rng.integers(0, 10, size=n).astype(np.int64)
    w = np.asarray(weights, dtype=np.float32)
    res = V.sample_two_pass(stream, w, k=100, l=5.0, salt=6, chunk=64)
    ukeys = np.unique(stream)
    expect = {int(x): float(w[stream == x].sum()) for x in ukeys}
    for x, wx in zip(res.keys.tolist(), res.counts.tolist()):
        np.testing.assert_allclose(wx, expect[int(x)], rtol=1e-4)


def test_merge_bottomk_lossless():
    """bottom-k(A ∪ B) == merge(bottom-k(A), bottom-k(B)) (paper §3.1)."""
    import jax.numpy as jnp

    from repro.core.distributed import merge_bottomk

    rng = np.random.default_rng(0)
    for _ in range(10):
        ka = rng.integers(0, 40, size=16)
        kb = rng.integers(0, 40, size=16)
        sa = rng.uniform(size=16).astype(np.float32)
        sb = rng.uniform(size=16).astype(np.float32)
        mk, ms = merge_bottomk(
            jnp.asarray(ka, jnp.int32), jnp.asarray(sa),
            jnp.asarray(kb, jnp.int32), jnp.asarray(sb), 8,
        )
        # reference: min score per key over the union, then bottom-8
        import collections

        best = collections.defaultdict(lambda: np.inf)
        for k_, s_ in zip(ka.tolist() + kb.tolist(), sa.tolist() + sb.tolist()):
            best[k_] = min(best[k_], s_)
        ref = sorted(best.items(), key=lambda kv: kv[1])[:8]
        got = [(int(k_), float(s_)) for k_, s_ in zip(np.asarray(mk), np.asarray(ms)) if k_ != int(EMPTY)]
        assert [k for k, _ in got] == [k for k, _ in ref]
        np.testing.assert_allclose([s for _, s in got], [s for _, s in ref], rtol=1e-6)
