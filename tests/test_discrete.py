"""Discrete SH_l machinery (§4): phi recurrence, psi inversion, Thm 4.1/4.2."""
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import discrete as D
from repro.core import freqfns as F


def test_phi_l1_is_distinct():
    np.testing.assert_allclose(D.phi_vector(1, 0.3), [0.3])


def test_phi_linf_is_geometric():
    tau = 0.2
    phi = D.phi_vector(math.inf, tau)
    i = np.arange(1, len(phi) + 1)
    np.testing.assert_allclose(phi, tau * (1 - tau) ** (i - 1), rtol=1e-12)


def test_phi_is_probability_vector():
    """phi_i >= 0, non-increasing, sum <= 1 and -> 1-(1-tau)^l as w -> inf."""
    for l, tau in [(2, 0.3), (5, 0.1), (20, 0.05), (100, 0.01)]:
        phi = D.phi_vector(l, tau)
        assert np.all(phi >= 0)
        assert np.all(np.diff(phi) <= 1e-15), "phi must be non-increasing (Thm 4.2 proof)"
        total = phi.sum()
        limit = 1 - (1 - tau) ** l  # P[some bucket hashes below tau]
        assert total <= limit + 1e-9
        assert total > limit - 1e-6, f"phi tail not converged: {total} vs {limit}"


def test_phi_monte_carlo():
    """phi matches a direct simulation of eq. (6) first-counted-element law."""
    l, tau, n_elem, reps = 4, 0.25, 12, 40000
    rng = np.random.default_rng(0)
    firsts = np.zeros(n_elem + 1)
    for _ in range(reps):
        bucket_hash = rng.uniform(size=l)
        buckets = rng.integers(0, l, size=n_elem)
        scores = bucket_hash[buckets]
        hit = np.nonzero(scores < tau)[0]
        firsts[hit[0] + 1 if len(hit) else 0] += 1
    phi = D.phi_vector(l, tau)
    emp = firsts[1:] / reps
    np.testing.assert_allclose(emp[: min(len(phi), n_elem)], phi[:n_elem][: len(emp)], atol=0.01)


def test_psi_inverts_phi():
    """Y(psi) Y(phi) = I on the leading block."""
    l, tau, n = 7, 0.15, 40
    phi = D.phi_vector(l, tau)
    psi = D.psi_vector(phi, n)
    phi_full = np.zeros(n)
    phi_full[: min(len(phi), n)] = phi[:n]

    def upper(v):
        m = np.zeros((n, n))
        for i in range(n):
            for j in range(i, n):
                m[i, j] = v[j - i]
        return m

    prod = upper(psi) @ upper(phi_full)
    np.testing.assert_allclose(prod, np.eye(n), atol=1e-8)


def test_psi_special_cases():
    np.testing.assert_allclose(D.psi_vector(D.phi_vector(1, 0.1), 3), [10, 0, 0], atol=1e-10)
    np.testing.assert_allclose(
        D.psi_vector(D.phi_vector(math.inf, 0.1), 4), [10, -9, 0, 0], atol=1e-9
    )


def test_psi_prefix_sums_positive():
    """Claim (9) in the proof of Thm 4.2."""
    for l, tau in [(3, 0.4), (5, 0.1), (50, 0.02)]:
        psi = D.psi_vector(D.phi_vector(l, tau), 60)
        assert np.all(np.cumsum(psi) > 0)


@given(
    l=st.sampled_from([1, 2, 5, 20, 100]),
    tau=st.floats(min_value=0.01, max_value=0.9),
    T=st.sampled_from([1, 2, 5, 20, 1000]),
)
@settings(max_examples=30, deadline=None)
def test_beta_nonnegative_for_monotone_f(l, tau, T):
    """Theorem 4.2: monotone non-decreasing f => beta >= 0."""
    fvals = F.cap(T).table(80)
    beta = D.estimator_coefficients(fvals, l, tau, 80)
    assert beta.min() >= -1e-8 * max(1.0, abs(beta).max())


def test_estimator_coefficients_match_closed_forms():
    tau, n = 0.2, 10
    f = F.total().table(n)
    # distinct (eq. 4)
    np.testing.assert_allclose(
        D.estimator_coefficients(f, 1, tau, n), np.arange(1, n + 1) / tau
    )
    # SH (eq. 5)
    i = np.arange(1, n + 1, dtype=float)
    np.testing.assert_allclose(
        D.estimator_coefficients(f, math.inf, tau, n), (i - (i - 1) * (1 - tau)) / tau
    )


def test_unbiased_via_transform():
    """E[Qhat] = f^T Y(psi) E[o] = f^T m exactly, by construction: verify
    numerically that beta^T Y(phi) = f^T (the transform identity)."""
    l, tau, n = 5, 0.12, 50
    phi = D.phi_vector(l, tau)
    psi = D.psi_vector(phi, n)
    fvals = F.cap(7).table(n)
    beta = D.beta_coefficients(fvals, psi)
    # E[o_i] = sum_{j >= i} phi_{j-i+1} m_j ; E[Qhat] = sum_i beta_i E[o_i]
    # = sum_j m_j sum_{i<=j} beta_i phi_{j-i+1}  must equal sum_j m_j f_j
    phi_full = np.zeros(n + 1)
    phi_full[1 : min(len(phi), n) + 1] = phi[:n]
    for j in [1, 2, 3, 5, 10, 30, 49]:
        contrib = sum(beta[i - 1] * phi_full[j - i + 1] for i in range(1, j + 1))
        np.testing.assert_allclose(contrib, fvals[j], rtol=1e-7)


def test_inclusion_prob_monotone_saturating():
    phi = D.phi_vector(10, 0.05)
    w = np.arange(0, 500)
    p = D.inclusion_prob(w, phi)
    assert p[0] == 0
    assert np.all(np.diff(p) >= -1e-15)
    assert p[-1] <= 1.0
