"""reprolint: fixture-based good/bad pairs per rule, pragma/baseline
mechanics, config parsing, repo cleanliness, and the retrace contract."""
import json
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT))

from tools.reprolint import Config, lint_text  # noqa: E402
from tools.reprolint.config import _read_toml_section  # noqa: E402
from tools.reprolint.engine import LintEngine, lint_paths  # noqa: E402

HOT = "src/repro/core/incremental.py"  # hot-path module in the default config
COLD = "src/repro/stats/service.py"    # library but not hot-path
REGISTRY = "src/repro/core/segments.py"


def codes(src, relpath=HOT):
    return [v.code for v in lint_text(textwrap.dedent(src), relpath)]


# ---------------------------------------------------------------------------
# RPL001 — host-device sync
# ---------------------------------------------------------------------------

def test_rpl001_jit_scope_float_on_traced_bad():
    src = """
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
    """
    assert "RPL001" in codes(src)


def test_rpl001_jit_scope_item_bad():
    src = """
        import jax

        @jax.jit
        def f(x):
            return x.sum().item()
    """
    assert "RPL001" in codes(src)


def test_rpl001_jit_scope_np_on_traced_bad():
    src = """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
    """
    assert "RPL001" in codes(src)


def test_rpl001_jit_scope_shape_and_static_good():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("spec",))
        def f(x, spec):
            n = int(x.shape[0])
            k = float(spec.k)
            return x * n + k
    """
    assert "RPL001" not in codes(src)


def test_rpl001_hot_module_state_pull_bad():
    src = """
        def finalize(state: SamplerState):
            return float(state.l)
    """
    assert "RPL001" in codes(src)


def test_rpl001_hot_module_device_get_good():
    src = """
        import jax

        def finalize(state: SamplerState):
            l = jax.device_get(state.l)
            return float(l)
    """
    assert "RPL001" not in codes(src)


def test_rpl001_jit_call_result_tracked():
    # values returned by a module-level jitted name are device-tainted
    src = """
        import functools
        import jax

        def _impl(state, keys):
            return state

        _update = functools.partial(jax.jit, donate_argnums=(0,))(_impl)

        def run(state: SamplerState, keys):
            st = _update(state, keys)
            return int(st.overflow)
    """
    assert "RPL001" in codes(src)


def test_rpl001_unannotated_param_not_flagged():
    # hostness is conservative: unknown roots never flag
    src = """
        def summarize(result):
            return float(result.estimate)
    """
    assert "RPL001" not in codes(src)


# ---------------------------------------------------------------------------
# RPL002 — selection primitives outside the dual registry
# ---------------------------------------------------------------------------

RPL002_SRC = """
    import jax.numpy as jnp

    def pick(x):
        return jnp.argsort(x)
"""


def test_rpl002_hot_module_bad():
    assert "RPL002" in codes(RPL002_SRC)


def test_rpl002_top_k_bad():
    src = """
        import jax

        def pick(x):
            return jax.lax.top_k(x, 4)
    """
    assert "RPL002" in codes(src)


def test_rpl002_registry_exempt_good():
    assert "RPL002" not in codes(RPL002_SRC, relpath=REGISTRY)


def test_rpl002_cold_module_good():
    assert "RPL002" not in codes(RPL002_SRC, relpath=COLD)


# ---------------------------------------------------------------------------
# RPL003 — state-advancing jit without donation
# ---------------------------------------------------------------------------

def test_rpl003_partial_jit_no_donate_bad():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("spec",))
        def _update(state, keys, spec):
            return state
    """
    assert "RPL003" in codes(src, relpath=COLD)


def test_rpl003_donated_good():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(0,))
        def _update(state, keys, spec):
            return state
    """
    assert "RPL003" not in codes(src, relpath=COLD)


def test_rpl003_lambda_jit_bad_then_donated():
    bad = """
        import jax
        step = jax.jit(lambda cache, tok: (cache, tok))
    """
    good = """
        import jax
        step = jax.jit(lambda cache, tok: (cache, tok), donate_argnums=(0,))
    """
    assert "RPL003" in codes(bad, relpath=COLD)
    assert "RPL003" not in codes(good, relpath=COLD)


def test_rpl003_stateless_params_good():
    src = """
        import jax

        @jax.jit
        def score(keys, weights, salt):
            return keys
    """
    assert "RPL003" not in codes(src, relpath=COLD)


# ---------------------------------------------------------------------------
# RPL004 — f64 literals outside enable_x64
# ---------------------------------------------------------------------------

def test_rpl004_bare_f64_bad():
    src = """
        import jax.numpy as jnp

        def acc():
            return jnp.zeros((4,), jnp.float64)
    """
    assert "RPL004" in codes(src)


def test_rpl004_inside_enable_x64_good():
    src = """
        import jax.numpy as jnp

        def acc():
            with enable_x64():
                return jnp.zeros((4,), jnp.float64)
    """
    assert "RPL004" not in codes(src)


def test_rpl004_out_of_scope_good():
    src = """
        import jax.numpy as jnp

        def acc():
            return jnp.zeros((4,), jnp.float64)
    """
    assert "RPL004" not in codes(src, relpath="tests/test_foo.py")


# ---------------------------------------------------------------------------
# RPL005 — ambient randomness in library scope
# ---------------------------------------------------------------------------

def test_rpl005_np_random_bad():
    src = """
        import numpy as np

        def scores(n):
            return np.random.default_rng(0).uniform(size=n)
    """
    assert "RPL005" in codes(src)


def test_rpl005_jax_prngkey_bad():
    src = """
        import jax

        def scores(n):
            key = jax.random.PRNGKey(0)
            return jax.random.uniform(key, (n,))
    """
    assert codes(src).count("RPL005") == 2


def test_rpl005_from_import_bad():
    src = """
        from numpy.random import default_rng

        def scores(n):
            return default_rng(0).uniform(size=n)
    """
    assert "RPL005" in codes(src)


def test_rpl005_out_of_scope_good():
    src = """
        import numpy as np

        def workload(n):
            return np.random.default_rng(0).integers(0, n, n)
    """
    assert "RPL005" not in codes(src, relpath="benchmarks/gen.py")
    assert "RPL005" not in codes(src, relpath="src/repro/data/synth.py")


# ---------------------------------------------------------------------------
# RPL006 — raw sentinel comparisons
# ---------------------------------------------------------------------------

def test_rpl006_raw_compare_bad():
    src = """
        def live_mask(keys):
            return keys != EMPTY
    """
    assert "RPL006" in codes(src)


def test_rpl006_int_empty_and_literal_bad():
    src = """
        def masks(keys):
            a = keys == int(EMPTY)
            b = keys == 2147483647
            return a, b
    """
    assert codes(src).count("RPL006") == 2


def test_rpl006_helper_good():
    src = """
        from .segments import is_live

        def live_mask(keys):
            return is_live(keys)
    """
    assert "RPL006" not in codes(src)


def test_rpl006_registry_exempt_good():
    src = """
        def is_live(keys):
            return keys != EMPTY
    """
    assert "RPL006" not in codes(src, relpath=REGISTRY)


# ---------------------------------------------------------------------------
# RPL007 — unhashable static defaults
# ---------------------------------------------------------------------------

def test_rpl007_list_default_bad():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("ls",))
        def f(x, ls=[1.0, 2.0]):
            return x
    """
    assert "RPL007" in codes(src, relpath=COLD)


def test_rpl007_tuple_default_good():
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("ls",))
        def f(x, ls=(1.0, 2.0)):
            return x
    """
    assert "RPL007" not in codes(src, relpath=COLD)


def test_rpl007_nonstatic_list_default_good():
    # an unhashable default on a *traced* arg is not a cache-key problem
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("k",))
        def f(x, k=4, pads=[0, 0]):
            return x
    """
    assert "RPL007" not in codes(src, relpath=COLD)


# ---------------------------------------------------------------------------
# Pragmas, baseline, config
# ---------------------------------------------------------------------------

def test_pragma_same_line_suppresses():
    src = """
        import jax.numpy as jnp

        def pick(x):
            return jnp.argsort(x)  # reprolint: disable=RPL002 -- boundary conversion
    """
    assert "RPL002" not in codes(src)


def test_pragma_comment_block_above_suppresses():
    src = """
        import jax.numpy as jnp

        def pick(x):
            # reprolint: disable=RPL002 -- once-per-restore boundary, not
            # on the per-chunk path
            return jnp.argsort(x)
    """
    assert "RPL002" not in codes(src)


def test_pragma_without_justification_does_not_suppress():
    # the bare pragma is assembled at runtime so the textual pragma scanner
    # doesn't flag this fixture when linting the test file itself
    src = """
        import jax.numpy as jnp

        def pick(x):
            return jnp.argsort(x)  # PRAGMA
    """.replace("PRAGMA", "reprolint" + ": disable=RPL002")
    got = codes(src)
    assert "RPL002" in got      # not suppressed
    assert "RPL000" in got      # and the bare pragma itself is reported


def test_file_level_pragma_suppresses():
    src = """
        # reprolint: disable-file=RPL002 -- reference oracle module, sorts allowed
        import jax.numpy as jnp

        def pick(x):
            return jnp.argsort(x)
    """
    assert "RPL002" not in codes(src)


def test_baseline_matches_by_context(tmp_path):
    (tmp_path / "baseline.json").write_text(json.dumps({
        "version": 1,
        "entries": [{"code": "RPL002", "path": HOT, "context": "pick",
                     "reason": "fixture"}],
    }))
    config = Config.from_mapping(tmp_path, {"baseline": "baseline.json"})
    engine = LintEngine(config)
    src = textwrap.dedent(RPL002_SRC)
    result = engine.lint_source(src, HOT)
    assert not any(v.code == "RPL002" for v in result.violations)
    assert result.baselined == 1
    # a different context does not match
    other = src.replace("def pick", "def choose")
    result2 = LintEngine(config).lint_source(other, HOT)
    assert any(v.code == "RPL002" for v in result2.violations)


def test_toml_section_parser():
    text = textwrap.dedent("""
        [tool.other]
        x = 1

        [tool.reprolint]
        baseline = "b.json"  # trailing comment
        hot_path = [
            "src/a.py",  # comment in list
            "src/b/*.py",
        ]
        flag = true
        n = 3

        [tool.after]
        y = 2
    """)
    got = _read_toml_section(text, "tool.reprolint")
    assert got == {
        "baseline": "b.json",
        "hot_path": ["src/a.py", "src/b/*.py"],
        "flag": True,
        "n": 3,
    }


def test_repo_is_clean():
    """The committed tree has zero unsuppressed violations (CI acceptance)."""
    result = lint_paths(root=REPO_ROOT)
    assert result.ok, "\n".join(v.render() for v in result.violations)


def test_pallas_empty_key_matches_sentinel():
    # capscore.py mirrors segments.EMPTY as a kernel-local np scalar (jnp
    # constants don't lower inside the Mosaic kernel); keep them in lockstep.
    from repro.core.segments import EMPTY
    from repro.kernels.capscore.capscore import _EMPTY_KEY

    assert int(_EMPTY_KEY) == int(EMPTY)
    assert _EMPTY_KEY.dtype == np.int32


# ---------------------------------------------------------------------------
# Retrace contract
# ---------------------------------------------------------------------------

def test_incremental_update_compiles_exactly_once():
    """Repeated same-shape chunk batches reuse ONE executable (the donated
    update's steady-state contract; budgeted in reprolint_traces.json)."""
    from repro.core import incremental as inc

    # unique (chunk, k) so compiles from other tests in this process don't
    # collide with the delta measurement
    chunk, k = 320, 48
    before = inc._update_multi_donated._cache_size()
    m = inc.MultiSampler([2.0, 8.0], k=k, chunk=chunk)
    for b in range(3):
        m.observe(np.arange(2 * chunk, dtype=np.int64) + 7 * b)
    after = inc._update_multi_donated._cache_size()
    assert after - before == 1


def test_retrace_budget_file_consistent():
    data = json.loads((REPO_ROOT / "tools/reprolint/reprolint_traces.json").read_text())
    budgets = data["budgets"]
    assert budgets and all(isinstance(v, int) and v >= 0 for v in budgets.values())
    from tools.reprolint import retrace

    # the committed budget must encode the exactly-once steady-state contract
    for key in retrace._EXACTLY_ONCE:
        assert budgets[key] == 1, key
