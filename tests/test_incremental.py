"""Incremental state API (core.incremental) and mergeable fixed-k sketches.

Contracts under test:

* chunk-aligned incremental ingestion == one-shot scan, bit-for-bit
  (fixed-tau: element-exact keys and counts; fixed-k: identical sample,
  threshold and counts when chunk boundaries align);
* the multi-l stacked update advances every lane exactly like |ls|
  independent single-l runs;
* state_dict -> load_state_dict mid-stream resumes bit-for-bit, and its
  payload size is independent of the number of observed elements;
* the multi-l capscore kernel matches the reference scorer lane-for-lane;
* merge_fixed_k: merged per-host sketches estimate like a single-stream run
  for key-partitioned shards.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import distributed as D
from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import incremental as I
from repro.core import vectorized as V


def _stream(n=20000, n_keys=5000, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(1.4, size=n) % n_keys).astype(np.int64)
    w = (rng.exponential(1.0, n) + 0.1).astype(np.float32) if weighted else None
    return keys, w


# ---------------------------------------------------------------------------
# incremental == one-shot
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["continuous", "discrete", "distinct", "sh"])
def test_fixed_tau_incremental_element_exact(kind):
    keys, w = _stream(weighted=(kind == "continuous"))
    l = {"continuous": 5.0, "discrete": 5.0, "distinct": 1.0, "sh": 1e9}[kind]
    one = V.sample_fixed_tau(keys, w, tau=0.02, l=l, kind=kind, salt=7,
                             chunk=1024, capacity=16384)
    s = I.IncrementalSampler(l, tau=0.02, kind=kind, chunk=1024,
                             capacity=16384, salt=7)
    for i in range(0, len(keys), 3000):  # deliberately chunk-unaligned batches
        s.observe(keys[i:i + 3000], None if w is None else w[i:i + 3000])
    inc = s.finalize()
    np.testing.assert_array_equal(one.keys, inc.keys)
    np.testing.assert_allclose(one.counts, inc.counts, rtol=1e-6, atol=1e-5)
    assert inc.tau == pytest.approx(one.tau, rel=1e-6)  # f32 state vs host float


def test_fixed_k_incremental_matches_one_shot():
    keys, w = _stream()
    one = V.sample_fixed_k(keys, w, k=512, l=16.0, salt=3, chunk=1024)
    s = I.IncrementalSampler(16.0, k=512, chunk=1024, salt=3)
    for i in range(0, len(keys), 3000):
        s.observe(keys[i:i + 3000], w[i:i + 3000])
    inc = s.finalize()
    np.testing.assert_array_equal(one.keys, inc.keys)
    np.testing.assert_allclose(one.counts, inc.counts, rtol=1e-6)
    np.testing.assert_allclose(one.tau, inc.tau, rtol=1e-6)


def test_finalize_is_nondestructive_and_repeatable():
    keys, w = _stream(n=6000)
    s = I.IncrementalSampler(8.0, k=128, chunk=512, salt=1)
    s.observe(keys[:3500], w[:3500])
    r1 = s.finalize()
    r2 = s.finalize()
    np.testing.assert_array_equal(r1.keys, r2.keys)
    s.observe(keys[3500:], w[3500:])  # ingestion continues after finalize
    r3 = s.finalize()
    one = V.sample_fixed_k(keys, w, k=128, l=8.0, salt=1, chunk=512)
    np.testing.assert_array_equal(one.keys, r3.keys)


def test_multi_l_lanes_match_single_l_runs():
    keys, w = _stream()
    ls = (1.0, 16.0, 256.0)
    m = I.MultiSampler(ls, k=256, chunk=1024, salt=9)
    for i in range(0, len(keys), 2500):
        m.observe(keys[i:i + 2500], w[i:i + 2500])
    res = m.finalize()
    for l in ls:
        ref = V.sample_fixed_k(keys, w, k=256, l=l, salt=9, chunk=1024)
        np.testing.assert_array_equal(ref.keys, res[l].keys)
        np.testing.assert_allclose(ref.counts, res[l].counts, rtol=1e-6)
        np.testing.assert_allclose(ref.tau, res[l].tau, rtol=1e-6)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def test_state_roundtrip_resumes_bit_for_bit():
    from repro.stats.service import StatsConfig, StreamStatsService

    keys, _ = _stream(n=30000)
    cfg = StatsConfig(k=256, ls=(1.0, 8.0, 64.0), chunk=1024)

    uninterrupted = StreamStatsService(cfg)
    for i in range(0, len(keys), 7000):
        uninterrupted.observe(keys[i:i + 7000])

    first = StreamStatsService(cfg)
    first.observe(keys[:14000])
    blob = first.state_dict()  # mid-stream, with a live sub-chunk remainder
    resumed = StreamStatsService(cfg)
    resumed.load_state_dict(blob)
    for i in range(14000, len(keys), 7000):
        resumed.observe(keys[i:i + 7000])

    for l in cfg.ls:
        a = uninterrupted.sketches()[l]
        b = resumed.sketches()[l]
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_allclose(a.counts, b.counts, rtol=1e-6)
        assert a.tau == b.tau


def test_state_size_independent_of_stream_length():
    from repro.stats.service import StatsConfig, StreamStatsService

    cfg = StatsConfig(k=256, ls=(1.0, 8.0), chunk=1024)

    def total_bytes(n):
        svc = StreamStatsService(cfg)
        keys, _ = _stream(n=n, seed=4)
        svc.observe(keys)
        d = svc.state_dict()
        # equal element counts in the remainder so payloads are comparable
        assert svc.n_observed == n
        return sum(np.asarray(v).nbytes for v in d.values())

    small, large = total_bytes(2048), total_bytes(65536)
    assert small == large, (small, large)


def test_checkpoint_manager_roundtrip(tmp_path):
    from repro.stats.service import StatsConfig, StreamStatsService

    keys, _ = _stream(n=20000)
    cfg = StatsConfig(k=128, ls=(1.0, 16.0), chunk=1024)
    svc = StreamStatsService(cfg)
    svc.observe(keys[:11111])
    svc.save_checkpoint(tmp_path / "ck", step=1)

    svc2 = StreamStatsService(cfg)
    step = svc2.restore_checkpoint(tmp_path / "ck")
    assert step == 1
    svc.observe(keys[11111:])
    svc2.observe(keys[11111:])
    assert svc.campaign_forecast(8) == svc2.campaign_forecast(8)


# ---------------------------------------------------------------------------
# multi-l capscore kernel
# ---------------------------------------------------------------------------


def test_capscore_multi_matches_ref_lane_for_lane():
    from repro.kernels.capscore.ops import capscore_multi
    from repro.kernels.capscore.ref import capscore_ref

    rng = np.random.default_rng(5)
    n = 3000  # non-tile-aligned on purpose
    keys = jnp.asarray(rng.integers(0, 1 << 30, n), jnp.int32)
    eids = jnp.arange(n, dtype=jnp.int32)
    w = jnp.asarray(rng.exponential(2.0, n) + 0.05, jnp.float32)
    ls = jnp.asarray([1.0, 16.0, 256.0, 4096.0], jnp.float32)
    taus = jnp.asarray([0.5, np.inf, 0.01, 2.0], jnp.float32)

    s, d, e, kb = capscore_multi(keys, eids, w, ls, taus, 7, backend="pallas")
    assert s.shape == (4, n)
    for j in range(4):
        s1, d1, e1 = capscore_ref(keys, eids, w, float(ls[j]), float(taus[j]),
                                  jnp.uint32(7))
        np.testing.assert_allclose(np.asarray(s[j]), np.asarray(s1), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d[j]), np.asarray(d1), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(e[j]), np.asarray(e1))


def test_capscore_multi_backends_agree():
    from repro.kernels.capscore.ops import capscore_multi

    rng = np.random.default_rng(6)
    n = 2048
    keys = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    eids = jnp.arange(n, dtype=jnp.int32)
    w = jnp.ones(n, jnp.float32)
    ls = jnp.asarray([2.0, 50.0], jnp.float32)
    taus = jnp.asarray([0.3, 0.7], jnp.float32)
    out_p = capscore_multi(keys, eids, w, ls, taus, 9, backend="pallas")
    out_x = capscore_multi(keys, eids, w, ls, taus, 9, backend="xla")
    for a, b in zip(out_p, out_x):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# mergeable fixed-k sketches
# ---------------------------------------------------------------------------


def test_merge_fixed_k_key_disjoint_unbiased():
    keys, _ = _stream(n=40000, n_keys=8000, seed=2)
    ukeys, cnts = np.unique(keys, return_counts=True)
    k, l = 512, 16.0
    truth = F.exact_statistic(F.cap(10), cnts)
    errs = []
    for salt in range(6):
        sa = I.IncrementalSampler(l, k=k, chunk=1024, salt=salt)
        sb = I.IncrementalSampler(l, k=k, chunk=1024, salt=salt)
        sa.observe(keys[keys % 2 == 0])
        sb.observe(keys[keys % 2 == 1])
        tm = D.merge_fixed_k(sa.flushed_state().table, sb.flushed_state().table,
                             jnp.float32(l), jnp.uint32(salt), k=k)
        res = V._to_result(tm, l=l, kind="continuous", tau=float(tm.tau))
        assert len(res.keys) <= k
        errs.append((E.estimate(res, F.cap(10)) - truth) / truth)
    assert abs(np.mean(errs)) < 0.10, errs


def test_merge_fixed_k_element_split_bounded_bias():
    keys, _ = _stream(n=40000, n_keys=8000, seed=2)
    _, cnts = np.unique(keys, return_counts=True)
    k, l = 512, 16.0
    truth = F.exact_statistic(F.cap(10), cnts)
    errs = []
    for salt in range(4):
        sa = I.IncrementalSampler(l, k=k, chunk=1024, salt=salt)
        sb = I.IncrementalSampler(l, k=k, chunk=1024, salt=salt)
        sa.observe(keys[0::2])
        sb.observe(keys[1::2])
        tm = D.merge_fixed_k(sa.flushed_state().table, sb.flushed_state().table,
                             jnp.float32(l), jnp.uint32(salt), k=k)
        res = V._to_result(tm, l=l, kind="continuous", tau=float(tm.tau))
        errs.append((E.estimate(res, F.cap(10)) - truth) / truth)
    # keys straddling shards make the 1-pass merge approximate (DESIGN.md §5)
    assert abs(np.mean(errs)) < 0.20, errs


def test_merge_fixed_k_states_fold():
    keys, _ = _stream(n=40000, n_keys=8000, seed=2)
    _, cnts = np.unique(keys, return_counts=True)
    k, l = 256, 16.0
    tabs = []
    for i in range(4):
        s = I.IncrementalSampler(l, k=k, chunk=1024, salt=1)
        s.observe(keys[keys % 4 == i])
        tabs.append(s.flushed_state().table)
    tm = D.merge_fixed_k_states(tabs, jnp.float32(l), jnp.uint32(1), k=k)
    res = V._to_result(tm, l=l, kind="continuous", tau=float(tm.tau))
    truth = F.exact_statistic(F.cap(10), cnts)
    assert len(res.keys) <= k
    assert abs(E.estimate(res, F.cap(10)) - truth) / truth < 0.25


def test_service_merge_multi_host():
    from repro.stats.service import StatsConfig, StreamStatsService

    keys, _ = _stream(n=40000, n_keys=8000, seed=3)
    _, cnts = np.unique(keys, return_counts=True)
    sh0, sh1 = keys[keys % 2 == 0], keys[keys % 2 == 1]
    a = StreamStatsService(StatsConfig(k=512, ls=(1.0, 8.0, 64.0), chunk=1024,
                                       host_id=0))
    b = StreamStatsService(StatsConfig(k=512, ls=(1.0, 8.0, 64.0), chunk=1024,
                                       host_id=1))
    a.observe(sh0)
    b.observe(sh1)
    a.merge(b)  # exact mode (default): summaries + 1-pass sketches
    assert a.n_observed == len(keys)
    truth8 = F.exact_statistic(F.cap(8), cnts)
    truth_d = float(len(cnts))
    # before reconcile, queries ride the approximate merged sketches
    assert abs(a.campaign_forecast(8) - truth8) / truth8 < 0.2
    assert abs(a.query_distinct() - truth_d) / truth_d < 0.2
    # after the pass-II re-scan of both shards, queries are exact-weighted
    a.reconcile(sh0)
    a.reconcile(sh1)
    assert abs(a.campaign_forecast(8) - truth8) / truth8 < 0.2
    assert abs(a.query_distinct(exact=True) - truth_d) / truth_d < 0.2


def test_load_pre_summary_blob_disables_exact_mode():
    """Blobs written before the summary buffers existed still load (fresh
    empty summaries), but exact mode stays off — empty summaries don't
    describe the observed stream."""
    import pytest

    from repro.stats.service import StatsConfig, StreamStatsService

    keys, _ = _stream(n=8000, n_keys=2000, seed=7)
    cfg = StatsConfig(k=128, ls=(1.0, 16.0), chunk=1024)
    svc = StreamStatsService(cfg)
    svc.observe(keys)
    blob = svc.state_dict()
    old_blob = {k: v for k, v in blob.items()
                if k not in ("bk_keys", "bk_seeds", "n_real", "exact_ok")}

    restored = StreamStatsService(cfg)
    restored.load_state_dict(old_blob)
    assert restored.n_observed == len(keys)  # n_real fallback: n_seen + rem
    assert restored.campaign_forecast(8) == svc.campaign_forecast(8)
    with pytest.raises(ValueError, match="approx|unavailable"):
        restored.begin_reconcile()


def test_service_summary_buffers_checkpoint_roundtrip():
    """The lossless bottom-(k+1) summaries ride state_dict / checkpoint:
    a restored service reconciles to the identical exact sample."""
    from repro.stats.service import StatsConfig, StreamStatsService

    keys, _ = _stream(n=20000, n_keys=4000, seed=6)
    cfg = StatsConfig(k=128, ls=(1.0, 16.0), chunk=1024, host_id=0)
    svc = StreamStatsService(cfg)
    svc.observe(keys[:13333])  # live sub-chunk remainder in the blob
    blob = svc.state_dict()

    svc2 = StreamStatsService(cfg)
    svc2.load_state_dict(blob)
    svc.observe(keys[13333:])
    svc2.observe(keys[13333:])
    for s in (svc, svc2):
        s.reconcile(keys)
    for l in cfg.ls:
        e1, e2 = svc.exact_sketches()[l], svc2.exact_sketches()[l]
        np.testing.assert_array_equal(e1.keys, e2.keys)
        np.testing.assert_array_equal(e1.counts, e2.counts)
        assert e1.tau == e2.tau
