"""Hashing substrate: numpy/jnp bit-equality + distributional sanity."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hashing as H


@given(st.lists(st.integers(min_value=0, max_value=2**31 - 1), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_mix32_np_jnp_bit_equal(xs):
    xs = np.asarray(xs, dtype=np.uint32)
    a = H.mix32_np(xs)
    b = np.asarray(H.mix32(jnp.asarray(xs)))
    np.testing.assert_array_equal(a, b)


@given(
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), min_size=1, max_size=64),
    st.integers(min_value=0, max_value=255),
)
@settings(max_examples=50, deadline=None)
def test_hash_combine_np_jnp_bit_equal(xs, salt):
    xs = np.asarray(xs, dtype=np.int64)
    a = H.hash_combine_np(xs, np.uint32(salt))
    b = np.asarray(H.hash_combine(jnp.asarray(xs, dtype=jnp.int32), jnp.uint32(salt)))
    np.testing.assert_array_equal(a, b)


def test_uniform01_range_and_mean():
    h = H.hash_combine_np(np.arange(200000), np.uint32(3))
    u = H.uniform01_np(h)
    assert u.min() > 0 and u.max() < 1
    assert abs(u.mean() - 0.5) < 0.005
    # chi-square-ish uniformity over 20 bins
    hist, _ = np.histogram(u, bins=20)
    chi2 = np.sum((hist - 10000.0) ** 2 / 10000.0)
    assert chi2 < 60  # 19 dof, p ~ 1e-5 threshold


def test_exp_from_u_mean():
    h = H.hash_combine_np(np.arange(100000), np.uint32(9))
    u = H.uniform01_np(h)
    e = H.exp_from_u(u, 2.0)
    assert abs(e.mean() - 0.5) < 0.01


def test_per_salt_independence():
    keys = np.arange(10000)
    u1 = H.uniform01_np(H.hash_combine_np(keys, np.uint32(1)))
    u2 = H.uniform01_np(H.hash_combine_np(keys, np.uint32(2)))
    corr = np.corrcoef(u1, u2)[0, 1]
    assert abs(corr) < 0.03
