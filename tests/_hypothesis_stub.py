"""Minimal, dependency-free stand-in for the `hypothesis` API surface the
test-suite uses, installed into ``sys.modules`` by conftest.py only when the
real package is unavailable (the CI/container image may not ship it).

It is NOT a property-based testing engine: no shrinking, no example database,
no coverage-guided generation.  It deterministically draws ``max_examples``
pseudo-random examples per test (seeded from the test name, so failures
reproduce) from the small strategy combinator set the suite uses:

    integers, floats, booleans, sampled_from, lists (min/max_size, unique)

plus the ``@given`` / ``@settings`` decorators in either stacking order.
Boundary values (min/max endpoints, empty-ish lists) are visited first, which
is where most of the suite's historical failures live.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib


class Strategy:
    def draw(self, rnd: random.Random):  # pragma: no cover - interface
        raise NotImplementedError

    def boundary(self) -> list:
        """A few deterministic edge-case values to try before random draws."""
        return []


class _Integers(Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def draw(self, rnd):
        return rnd.randint(self.lo, self.hi)

    def boundary(self):
        return [self.lo, self.hi] if self.lo != self.hi else [self.lo]


class _Floats(Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def draw(self, rnd):
        return rnd.uniform(self.lo, self.hi)

    def boundary(self):
        return [self.lo, self.hi]


class _Booleans(Strategy):
    def draw(self, rnd):
        return rnd.random() < 0.5

    def boundary(self):
        return [False, True]


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rnd):
        return rnd.choice(self.elements)

    def boundary(self):
        return [self.elements[0], self.elements[-1]]


class _Lists(Strategy):
    def __init__(self, elements, min_size=0, max_size=None, unique=False):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size) if max_size is not None else self.min_size + 32
        self.unique = unique

    def draw(self, rnd):
        size = rnd.randint(self.min_size, self.max_size)
        if not self.unique:
            return [self.elements.draw(rnd) for _ in range(size)]
        seen, out = set(), []
        attempts = 0
        while len(out) < size and attempts < size * 50 + 100:
            v = self.elements.draw(rnd)
            attempts += 1
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out

    def boundary(self):
        rnd = random.Random(0)
        small = self.draw_sized(rnd, self.min_size)
        return [small]

    def draw_sized(self, rnd, size):
        saved = self.min_size, self.max_size
        self.min_size = self.max_size = size
        try:
            return self.draw(rnd)
        finally:
            self.min_size, self.max_size = saved


class _Module:
    integers = staticmethod(lambda min_value=0, max_value=2**31 - 1: _Integers(min_value, max_value))
    floats = staticmethod(lambda min_value=0.0, max_value=1.0, **_kw: _Floats(min_value, max_value))
    booleans = staticmethod(lambda: _Booleans())
    sampled_from = staticmethod(lambda elements: _SampledFrom(elements))
    lists = staticmethod(
        lambda elements, min_size=0, max_size=None, unique=False: _Lists(
            elements, min_size, max_size, unique
        )
    )


strategies = _Module()

DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_settings = {"max_examples": max_examples}
        return fn

    return deco


def _boundary_examples(pos, kw):
    """Cartesian-free boundary sweep: vary one strategy's endpoints while the
    others sit at their first boundary value (keeps the count linear)."""
    rnd = random.Random(0)
    base_pos = [s.boundary()[0] if s.boundary() else s.draw(rnd) for s in pos]
    base_kw = {n: (s.boundary()[0] if s.boundary() else s.draw(rnd)) for n, s in kw.items()}
    examples = [(list(base_pos), dict(base_kw))]
    for i, s in enumerate(pos):
        for v in s.boundary()[1:]:
            p = list(base_pos)
            p[i] = v
            examples.append((p, dict(base_kw)))
    for name, s in kw.items():
        for v in s.boundary()[1:]:
            d = dict(base_kw)
            d[name] = v
            examples.append((list(base_pos), d))
    return examples


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        inner_settings = getattr(fn, "_hyp_settings", None)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        bound_names = {p.name for p in params[: len(pos_strategies)]}
        bound_names |= set(kw_strategies)

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            cfg = getattr(runner, "_hyp_settings", None) or inner_settings or {}
            max_examples = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rnd = random.Random(seed)
            examples = _boundary_examples(pos_strategies, kw_strategies)[:max_examples]
            while len(examples) < max_examples:
                examples.append(
                    (
                        [s.draw(rnd) for s in pos_strategies],
                        {n: s.draw(rnd) for n, s in kw_strategies.items()},
                    )
                )
            for ex_pos, ex_kw in examples:
                try:
                    fn(*args, *ex_pos, **kwargs, **ex_kw)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): args={ex_pos} kwargs={ex_kw}"
                    ) from e

        # pytest must not treat strategy-bound params as fixtures
        runner.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in bound_names]
        )
        return runner

    return deco
