"""Distributed sampling correctness (subprocess multi-device runs) and
shard element-id disambiguation."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run_distributed(ndev: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_distributed_runner.py"), str(ndev)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_distributed_two_pass_matches_reference():
    _run_distributed(8)


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [3, 6])
def test_distributed_non_power_of_two_devices(ndev):
    """tree merge must fall back to all_gather for non-pow2 axes — the
    butterfly permutation i ^ stage is not a valid pairing there."""
    _run_distributed(ndev)


def test_shard_eids_never_alias():
    """Regression for the int32 overflow in ``base = shard_no * n``: shard
    pairs whose arithmetic bases alias mod 2^32 must still get disjoint
    hashed element ids."""
    from repro.core.samplers import shard_eids_np

    n = 2**12
    # under the old scheme base = shard_no * n (int32): shard 2^20 wraps to
    # base 0 (2^20 * 2^12 = 2^32 ≡ 0), shard 2^20 + 7 to shard 7's base, ...
    aliasing_pairs = [(0, 2**20), (7, 2**20 + 7), (1, 2**19 + 1), (3, 2**31 // n + 3)]
    idx = np.arange(n)
    for a, b in aliasing_pairs:
        ea = shard_eids_np(a, idx)
        eb = shard_eids_np(b, idx)
        # the old scheme would make these IDENTICAL arrays; hashed ids share
        # no elements at all (collisions are birthday-rare, not systematic)
        assert not np.array_equal(ea, eb)
        assert len(np.intersect1d(ea, eb)) == 0, (a, b)


def test_shard_eids_device_matches_host():
    """The jnp and numpy twins must be bit-identical (uint32 stream)."""
    import jax.numpy as jnp

    from repro.core.samplers import shard_eids_np
    from repro.core.vectorized import shard_eids

    idx = np.arange(4096)
    for shard in (0, 1, 5, 2**20):
        host = shard_eids_np(shard, idx).astype(np.uint32)
        dev = np.asarray(
            shard_eids(jnp.uint32(shard), jnp.asarray(idx, jnp.int32))
        ).astype(np.uint32)
        np.testing.assert_array_equal(host, dev)
