"""Distributed sampling correctness (subprocess with 8 host devices)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_two_pass_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_distributed_runner.py")],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
