"""Layer-level invariants: MoE routing/consistency, attention decode==train."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.attention import (
    AttentionConfig,
    attention_decode,
    attention_prefill,
    attention_train,
    init_attention,
)
from repro.layers.common import rms_norm, softmax_xent
from repro.layers.moe import MoEConfig, init_moe, moe_apply, moe_apply_dense


def test_moe_dense_matches_capacity_when_no_drops():
    """With generous capacity the einsum-dispatch path must equal the
    no-drop dense path (same experts, same gates)."""
    cfg = MoEConfig(d_model=32, d_ff=48, n_experts=4, top_k=2,
                    capacity_factor=8.0, group_size=64)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y1, aux = moe_apply(p, cfg, x)
    y2, _ = moe_apply_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=2e-5)
    assert float(aux["expert_fill"]) < 1.0  # nothing hit capacity


def test_moe_aux_losses_sane():
    cfg = MoEConfig(d_model=16, d_ff=24, n_experts=8, top_k=2, group_size=32)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 16))
    _, aux = moe_apply(p, cfg, x)
    # Switch balance loss >= coef (perfect balance gives exactly coef * 1.0)
    assert float(aux["balance_loss"]) >= cfg.balance_coef * 0.99
    assert float(aux["router_z_loss"]) >= 0
    assert 0 <= float(aux["expert_fill"]) <= 1


def test_moe_grad_flows_to_router_and_experts():
    cfg = MoEConfig(d_model=16, d_ff=24, n_experts=4, top_k=2, group_size=32)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))

    def loss(p_):
        y, aux = moe_apply(p_, cfg, x)
        return jnp.sum(y**2) + aux["balance_loss"] + aux["router_z_loss"]

    g = jax.grad(loss)(p)
    for name in ("router", "w1", "w2", "w3"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0, f"no grad into {name}"


@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_attention_decode_matches_train(n_kv):
    """Decoding token-by-token with a cache reproduces full attention."""
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv=n_kv, d_head=8, qk_norm=True)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.float32), (B, S))
    full = attention_train(p, cfg, x, positions)

    ck = jnp.zeros((B, S + 2, n_kv, 8))
    cv = jnp.zeros((B, S + 2, n_kv, 8))
    outs = []
    for t in range(S):
        o, (ck, cv) = attention_decode(
            p, cfg, x[:, t : t + 1], (ck, cv), jnp.full((B,), t, jnp.int32), None
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-5, rtol=3e-5)


def test_attention_prefill_cache_matches_projections():
    cfg = AttentionConfig(d_model=16, n_heads=2, n_kv=2, d_head=8)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    positions = jnp.broadcast_to(jnp.arange(8, dtype=jnp.float32), (1, 8))
    out, (k, v) = attention_prefill(p, cfg, x, positions)
    assert k.shape == (1, 8, 2, 8) and v.shape == (1, 8, 2, 8)
    out2 = attention_train(p, cfg, x, positions)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_rms_norm_scale_invariance_property():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 100
    g = jnp.ones((16,))
    y = rms_norm(x, g)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y**2, -1)), np.ones(4), rtol=1e-4
    )
    # scaling input does not change the output (up to eps)
    y2 = rms_norm(x * 7.0, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)


def test_softmax_xent_ignores_masked_labels():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
    labels = jnp.array([[1, 2, -100, 3], [0, -100, -100, 5]])
    l1 = softmax_xent(logits, labels)
    # changing logits at masked positions must not change the loss
    logits2 = logits.at[0, 2].add(100.0).at[1, 1].add(-50.0)
    l2 = softmax_xent(logits2, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
