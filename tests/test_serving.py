"""Multi-tenant serving plane: stacked banks, scheduler, overlap, handoff.

The serving contract under test (DESIGN.md §10): the bank/scheduler change
HOW MANY dispatches run, never one bit of any tenant's sample, summary, or
answer — every test here compares against the standalone per-tenant path
with np.array_equal, not allclose.
"""
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.core import freqfns, incremental
from repro.core.segments import HashBucket
from repro.launch.stats_serve import StatsServer
from repro.stats.scheduler import ServeConfig, StatsScheduler, _round_robin
from repro.stats.service import (
    MultiTenantStats, StatsConfig, StreamStatsService, TenantQuery)

LS = (1.0, 8.0, 64.0)
K, CHUNK = 96, 192


def _streams(T, n, seed=0, n_keys=600):
    rng = np.random.default_rng(seed)
    return [(rng.zipf(1.3, size=n) % n_keys).astype(np.int64)
            for _ in range(T)]


def _cfg(**kw):
    return StatsConfig(k=kw.pop("k", K), ls=kw.pop("ls", LS),
                       chunk=kw.pop("chunk", CHUNK), **kw)


# ---------------------------------------------------------------------------
# Stacked-bank bit-identity vs standalone per-tenant samplers
# ---------------------------------------------------------------------------


def test_bank_bit_identity_staggered_ingest():
    """Tables, taus, summaries, and results match standalone MultiSamplers
    even when tenants ingest at wildly different rates (partial chunks,
    inactive tenants passing through masked ticks)."""
    T = 4
    streams = _streams(T, 3000, seed=0)
    refs = [incremental.MultiSampler(LS, k=K, chunk=CHUNK, salt=0x5EED)
            for _ in range(T)]
    for t in range(T):
        refs[t].observe(streams[t])

    bank = incremental.TenantBank(LS, n_tenants=T, k=K, chunk=CHUNK,
                                  salts=0x5EED)
    offs = [0] * T
    sizes = [193, 1024, 77, 3000]  # adversarial stagger incl. sub-chunk
    while any(offs[t] < len(streams[t]) for t in range(T)):
        for t in range(T):
            if offs[t] < len(streams[t]):
                n = min(sizes[t], len(streams[t]) - offs[t])
                bank.observe(t, streams[t][offs[t]: offs[t] + n])
                offs[t] += n
        bank.tick()
    bank.drain()

    # resident state: tables + summaries, bitwise
    bst = bank.flushed_state()
    for t in range(T):
        rst = refs[t].flushed_state()
        for leaf_b, leaf_r in zip(
                [bst.table.keys[t], bst.table.counts[t], bst.table.kb[t],
                 bst.table.seed[t], bst.table.tau[t],
                 bst.bk_keys[t], bst.bk_seeds[t]],
                [rst.table.keys, rst.table.counts, rst.table.kb,
                 rst.table.seed, rst.table.tau,
                 rst.bk_keys, rst.bk_seeds]):
            assert np.array_equal(np.asarray(leaf_b), np.asarray(leaf_r))
        assert bank.n_observed(t) == refs[t].n_observed

    # finalized results
    for t in range(T):
        r_ref, r_bank = refs[t].finalize(), bank.finalize(t)
        for l in LS:
            assert np.array_equal(r_ref[l].keys, r_bank[l].keys)
            assert np.array_equal(r_ref[l].counts, r_bank[l].counts)
            assert r_ref[l].tau == r_bank[l].tau


def test_bank_per_tenant_salts():
    """Distinct per-tenant salts reproduce the per-instance salted sampler."""
    T = 3
    streams = _streams(T, 1000, seed=2)
    salts = [7, 99, 12345]
    bank = incremental.TenantBank(LS, n_tenants=T, k=K, chunk=CHUNK,
                                  salts=salts)
    for t in range(T):
        bank.observe(t, streams[t])
    bank.drain()
    for t in range(T):
        ref = incremental.MultiSampler(LS, k=K, chunk=CHUNK, salt=salts[t])
        ref.observe(streams[t])
        r_ref, r_bank = ref.finalize(), bank.finalize(t)
        for l in LS:
            assert np.array_equal(r_ref[l].keys, r_bank[l].keys)
            assert r_ref[l].tau == r_bank[l].tau


def test_multitenant_query_identity():
    """MultiTenantStats answers (estimates AND diagnostics) == per-tenant
    StreamStatsService, including segment queries, via ONE coalesced
    dispatch across tenants."""
    T = 3
    cfg = _cfg()
    streams = _streams(T, 2000, seed=3)
    mts = MultiTenantStats(cfg, n_tenants=T)
    svcs = [StreamStatsService(cfg) for _ in range(T)]
    for t in range(T):
        mts.observe(t, streams[t])
        svcs[t].observe(streams[t])
    mts.drain()

    seg = HashBucket(4, 1)
    reqs = [TenantQuery(t, fn, s)
            for t in range(T)
            for fn, s in [(freqfns.cap(8.0), None), (freqfns.cap(8.0), seg),
                          (freqfns.distinct(), None), (freqfns.total(), None)]]
    batch = mts.query_batch(reqs)
    per_tenant = [svcs[t].query_batch(
        [(freqfns.cap(8.0), None), (freqfns.cap(8.0), seg),
         (freqfns.distinct(), None), (freqfns.total(), None)])
        for t in range(T)]
    for i, q in enumerate(reqs):
        ref = per_tenant[q.tenant]
        j = i % 4
        assert batch.estimates[i] == ref.estimates[j]
        assert batch.variances[i] == ref.variances[j]
        assert batch.ci_low[i] == ref.ci_low[j]
        assert batch.n_keys[i] == ref.n_keys[j]


def test_async_query_matches_sync():
    """query_batch_async + later result() == query_batch (overlap changes
    scheduling, not answers)."""
    cfg = _cfg()
    mts = MultiTenantStats(cfg, n_tenants=2)
    streams = _streams(2, 1500, seed=4)
    for t in range(2):
        mts.observe(t, streams[t])
    mts.drain()
    reqs = [TenantQuery(t, freqfns.cap(c))
            for t in range(2) for c in (1.0, 8.0, 64.0)]
    pending = mts.query_batch_async(reqs)
    # enqueue more device work before syncing, as the scheduler does
    mts.observe(0, streams[0][:CHUNK])
    mts.tick()
    got = pending.result()
    want = mts.query_batch(reqs, auto_refresh=False)
    assert np.array_equal(got.estimates, want.estimates)


def test_partial_refresh_widens_on_miss():
    """A partial-refresh snapshot transparently widens when a query batch
    touches an uncovered tenant."""
    cfg = _cfg()
    T = 4
    mts = MultiTenantStats(cfg, n_tenants=T)
    streams = _streams(T, 1200, seed=5)
    for t in range(T):
        mts.observe(t, streams[t])
    mts.drain()
    mts.refresh(tenants={0, 1})
    full = [StreamStatsService(cfg) for _ in range(T)]
    for t in range(T):
        full[t].observe(streams[t])
    # tenant 3 is outside the snapshot -> widening refresh, same answers
    batch = mts.query_batch([TenantQuery(3, freqfns.cap(8.0)),
                             TenantQuery(0, freqfns.cap(8.0))],
                            auto_refresh=False)
    assert batch.estimates[0] == full[3].campaign_forecast(8.0)
    assert batch.estimates[1] == full[0].campaign_forecast(8.0)


# ---------------------------------------------------------------------------
# Scheduler: fairness, eviction, drain
# ---------------------------------------------------------------------------


def test_round_robin_fairness_primitive():
    from collections import deque
    queues = {0: deque(range(100)), 1: deque(["a"]), 2: deque(), 3: deque(["b", "c"])}
    out = _round_robin(queues, start=1, n_tenants=4, budget=5)
    # one per non-empty tenant per rotation, starting at 1
    assert out == [(1, "a"), (3, "b"), (0, 0), (3, "c"), (0, 1)]
    assert len(queues[0]) == 98


def test_scheduler_fairness_under_skew():
    """An adversarial tenant flooding the queues cannot starve the others:
    every light tenant's single query completes in the FIRST step."""
    T = 4
    cfg = _cfg(chunk=128)
    mts = MultiTenantStats(cfg, n_tenants=T)
    sched = StatsScheduler(mts, ServeConfig(max_ingest_per_step=4,
                                            max_queries_per_step=4))
    streams = _streams(T, 512, seed=6)
    # adversary (tenant 0) floods: 50 ingest slices + 50 queries
    for _ in range(50):
        sched.submit_ingest(0, streams[0][:128])
    heavy = [sched.submit_query(0, freqfns.cap(8.0)) for _ in range(50)]
    light = []
    for t in range(1, T):
        sched.submit_ingest(t, streams[t][:128])
        light.append(sched.submit_query(t, freqfns.cap(8.0)))
    done = sched.step()
    for rid in light:
        assert rid in done, "light tenant starved by adversarial backlog"
    assert sum(rid in done for rid in heavy) == 1  # one slot per rotation
    # ingest admission is fair too: each light tenant's slice was admitted
    for t in range(1, T):
        assert len(sched._ingest_q[t]) == 0, "light ingest starved"
    assert len(sched._ingest_q[0]) == 50 - 1  # adversary got one slot


def test_scheduler_results_evicted_on_read():
    cfg = _cfg(chunk=128)
    mts = MultiTenantStats(cfg, n_tenants=2)
    sched = StatsScheduler(mts)
    sched.submit_ingest(0, _streams(1, 256, seed=7)[0])
    rid = sched.submit_query(0, freqfns.cap(8.0))
    sched.drain()
    assert sched.buffered_results == 1
    rec = sched.pop_result(rid)
    assert rec is not None and rec.latency_s >= 0.0
    assert sched.buffered_results == 0
    assert sched.pop_result(rid) is None


def test_scheduler_answers_match_direct_service():
    """Answers through the overlapped scheduler == direct MultiTenantStats
    queries on the settled state."""
    T = 3
    cfg = _cfg(chunk=128)
    streams = _streams(T, 1024, seed=8)
    mts = MultiTenantStats(cfg, n_tenants=T)
    sched = StatsScheduler(mts)
    for t in range(T):
        sched.submit_ingest(t, streams[t])
    sched.drain()  # settle ingest first, then query the settled state
    rids = {t: sched.submit_query(t, freqfns.cap(8.0)) for t in range(T)}
    sched.drain()
    ref = MultiTenantStats(cfg, n_tenants=T)
    for t in range(T):
        ref.observe(t, streams[t])
    ref.drain()
    for t in range(T):
        rec = sched.pop_result(rids[t])
        assert rec.estimate == ref.query_cap(t, 8.0)


# ---------------------------------------------------------------------------
# Checkpointing: stacked round-trip + per-tenant slice/splice
# ---------------------------------------------------------------------------


def test_bank_checkpoint_roundtrip_and_slice(tmp_path):
    T = 3
    cfg = _cfg()
    streams = _streams(T, 1100, seed=9)
    mts = MultiTenantStats(cfg, n_tenants=T)
    for t in range(T):
        mts.observe(t, streams[t])
    # deliberately leave a sub-chunk remainder staged (mid-stream ckpt)
    mts.tick()
    mts.save_checkpoint(tmp_path, step=5)

    # full-bank round-trip resumes bit-for-bit
    mts2 = MultiTenantStats(cfg, n_tenants=T)
    assert mts2.restore_checkpoint(tmp_path) == 5
    for t in range(T):
        assert mts2.query_cap(t, 8.0) == mts.query_cap(t, 8.0)

    # per-tenant slice into a standalone service (leave)
    for t in range(T):
        svc = StreamStatsService(cfg)
        ex = svc.state_dict()
        ex.pop("exact_ok")
        blob = ckpt.restore_slice(tmp_path, 5, ex, t)
        blob["exact_ok"] = np.bool_(False)
        svc.load_state_dict(blob)
        assert svc.campaign_forecast(8.0) == mts.query_cap(t, 8.0)

    # splice a standalone service into a bank slot (join)
    lone = StreamStatsService(cfg)
    lone.observe(streams[0])
    blob = lone.state_dict()
    blob.pop("exact_ok")
    mts3 = MultiTenantStats(cfg, n_tenants=T)
    mts3.load_tenant_state_dict(1, blob)
    assert mts3.query_cap(1, 8.0) == lone.campaign_forecast(8.0)


def test_restore_slice_rejects_mismatched_tree(tmp_path):
    cfg = _cfg()
    mts = MultiTenantStats(cfg, n_tenants=2)
    mts.observe(0, _streams(1, 400, seed=10)[0])
    mts.drain()
    mts.save_checkpoint(tmp_path, step=1)
    svc = StreamStatsService(cfg)
    with pytest.raises(ValueError, match="leaf count"):
        ckpt.restore_slice(tmp_path, 1, svc.state_dict(), 0)  # exact_ok extra


# ---------------------------------------------------------------------------
# StatsServer (single-service shell): burst drain + eviction
# ---------------------------------------------------------------------------


def test_stats_server_drains_burst_and_evicts():
    svc = StreamStatsService(_cfg(chunk=128))
    svc.observe(_streams(1, 1024, seed=11)[0])
    server = StatsServer(svc, max_batch=8)
    for rid in range(30):
        server.submit(rid, freqfns.cap(8.0))
    done = server.step()  # drain-to-empty: the whole burst, FIFO slices
    assert sorted(done) == list(range(30))
    assert server.batch_sizes[-4:] == [8, 8, 8, 6]
    assert len(server.results) == 30
    r = server.pop_result(0)
    assert r is not None and "estimate" in r
    assert server.pop_result(0) is None
    assert len(server.results) == 29

    for rid in range(30, 60):
        server.submit(rid, freqfns.cap(8.0))
    assert server.step(drain=False) == list(range(30, 38))  # one slice only
    assert len(server.pending) == 22


# ---------------------------------------------------------------------------
# Scheduler backpressure (QueueFull) + result TTL expiry
# ---------------------------------------------------------------------------


def test_scheduler_backpressure_queue_full():
    """Admission past max_queue_depth raises QueueFull — retriable by
    contract, nothing enqueued — per tenant and per plane."""
    from repro.stats.scheduler import QueueFull

    cfg = _cfg(chunk=128)
    mts = MultiTenantStats(cfg, n_tenants=2)
    sched = StatsScheduler(mts, ServeConfig(max_queue_depth=3))
    keys = _streams(1, 128, seed=12)[0]
    for _ in range(3):
        sched.submit_ingest(0, keys)
    with pytest.raises(QueueFull) as ei:
        sched.submit_ingest(0, keys)
    assert ei.value.retriable and ei.value.plane == "ingest"
    assert ei.value.tenant == 0 and ei.value.depth == 3
    assert sched.pending_ingest == 3  # the rejected slice was NOT enqueued

    # the query plane counts depth separately
    for _ in range(3):
        sched.submit_query(0, freqfns.cap(8.0))
    with pytest.raises(QueueFull) as ei:
        sched.submit_query(0, freqfns.cap(8.0))
    assert ei.value.retriable and ei.value.plane == "query"
    assert sched.pending_queries == 3

    # depth is per tenant: tenant 1 is unaffected by tenant 0's backlog
    sched.submit_ingest(1, keys)
    sched.submit_query(1, freqfns.cap(8.0))

    # draining frees depth — the client's retry is then admitted
    sched.step()
    sched.submit_ingest(0, keys)
    sched.submit_query(0, freqfns.cap(8.0))


def test_scheduler_depth_unbounded_by_default():
    cfg = _cfg(chunk=128)
    mts = MultiTenantStats(cfg, n_tenants=1)
    sched = StatsScheduler(mts)  # max_queue_depth=None: legacy behavior
    keys = _streams(1, 128, seed=13)[0]
    for _ in range(100):
        sched.submit_ingest(0, keys)
    assert sched.pending_ingest == 100


def test_scheduler_result_ttl_expires_abandoned_records():
    """A completed record never popped within result_ttl_steps is evicted
    (abandoned clients must not leak the result buffer); records read
    within the window are unaffected."""
    cfg = _cfg(chunk=128)
    mts = MultiTenantStats(cfg, n_tenants=1)
    sched = StatsScheduler(mts, ServeConfig(result_ttl_steps=2))
    sched.submit_ingest(0, _streams(1, 256, seed=14)[0])
    abandoned = sched.submit_query(0, freqfns.cap(8.0))
    read = sched.submit_query(0, freqfns.cap(8.0))
    sched.drain()
    assert sched.buffered_results == 2
    rec = sched.pop_result(read)  # the live client reads within the TTL
    assert rec is not None and rec.done_step == sched.n_steps

    sched.step()  # age 1 < ttl: the abandoned record survives
    assert sched.buffered_results == 1
    sched.step()  # age 2 >= ttl: evicted at the top of the step
    assert sched.buffered_results == 0
    assert sched.n_results_expired == 1
    assert sched.pop_result(abandoned) is None
