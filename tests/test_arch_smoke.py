"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finiteness.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import graphs as GD
from repro.data import recsys_events as RD
from repro.models import recsys as R
from repro.models import schnet as G
from repro.models import transformer as T
from repro.optim import adamw

LM_ARCHS = [a for a in registry.ARCH_IDS if registry.family(a) == "lm"]
RECSYS_ARCHS = [a for a in registry.ARCH_IDS if registry.family(a) == "recsys"]


def _finite(x):
    return bool(np.isfinite(np.asarray(x)).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    cfg = registry.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 64)), jnp.int32)
    labels = jnp.roll(toks, -1, 1)

    loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, toks, labels)
    assert _finite(loss) and loss > 0
    gn = jax.tree.reduce(lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads, 0.0)
    assert _finite(gn) and gn > 0

    # one optimizer step decreases nothing catastrophically
    opt = adamw.AdamWConfig(lr=1e-3)
    state = adamw.init_state(params)
    params2, state, _ = adamw.update(opt, params, grads, state)
    loss2 = T.loss_fn(params2, cfg, toks, labels)
    assert _finite(loss2)

    # prefill + a couple decode steps
    logits, _ = T.prefill(params, cfg, toks)
    assert logits.shape == (2, cfg.vocab) and _finite(logits)
    cache = T.init_cache(cfg, 2, 96, jnp.float32)
    lg, cache = T.decode_step(params, cfg, toks[:, 0], cache, jnp.zeros((2,), jnp.int32))
    lg, cache = T.decode_step(params, cfg, toks[:, 1], cache, jnp.ones((2,), jnp.int32))
    assert lg.shape == (2, cfg.vocab) and _finite(lg)


def test_schnet_smoke_molecule_and_node():
    cfg = registry.get_config("schnet", smoke=True)
    rng = np.random.default_rng(1)

    # batched molecules (graph task)
    z, es, ed, dist, gid = GD.random_molecules(rng, batch=4, n_atoms=6, n_edges_per=12)
    params = G.init_params(jax.random.PRNGKey(0), cfg)
    batch = dict(
        node_input=jnp.asarray(z), edge_src=jnp.asarray(es), edge_dst=jnp.asarray(ed),
        edge_dist=jnp.asarray(dist), graph_ids=jnp.asarray(gid),
        targets=jnp.asarray(rng.normal(size=4), jnp.float32),
    )
    pred = G.forward(params, cfg, batch, 4)
    assert pred.shape == (4,) and _finite(pred)
    loss, grads = jax.value_and_grad(G.loss_fn)(params, cfg, batch, 4)
    assert _finite(loss)

    # feature graph (node task) via the real neighbor sampler
    import dataclasses

    g = GD.CSRGraph.random(rng, n_nodes=500, n_edges=3000)
    nodes, es2, ed2 = GD.neighbor_sample(g, np.arange(8), fanouts=(5, 3), salt=1)
    cfgf = dataclasses.replace(cfg, d_node_feat=12)
    pf = G.init_params(jax.random.PRNGKey(1), cfgf)
    feats = rng.normal(size=(len(nodes), 12)).astype(np.float32)
    batch2 = dict(
        node_input=jnp.asarray(feats),
        edge_src=jnp.asarray(es2), edge_dst=jnp.asarray(ed2),
        edge_dist=jnp.asarray(np.ones(len(es2), np.float32)),
        graph_ids=jnp.zeros(len(nodes), jnp.int32),
    )
    pred2 = G.forward(pf, cfgf, batch2, None)
    assert pred2.shape == (len(nodes),) and _finite(pred2)


def test_neighbor_sampler_properties():
    rng = np.random.default_rng(3)
    g = GD.CSRGraph.random(rng, n_nodes=1000, n_edges=20000)
    seeds = np.arange(32)
    nodes, es, ed = GD.neighbor_sample(g, seeds, fanouts=(15, 10), salt=7)
    # seeds first, all edges reference local ids, fanout bound respected
    assert np.array_equal(nodes[:32], seeds)
    assert es.max() < len(nodes) and ed.max() < len(nodes)
    deg = np.bincount(ed, minlength=len(nodes))
    assert deg[:32].max() <= 15
    # determinism
    nodes2, es2, ed2 = GD.neighbor_sample(g, seeds, fanouts=(15, 10), salt=7)
    assert np.array_equal(nodes, nodes2) and np.array_equal(es, es2)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    cfg = registry.get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    raw = RD.impression_batch(rng, batch=16, seq_len=cfg.seq_len,
                              n_items=cfg.n_items, n_users=getattr(cfg, "n_users", 100))
    batch = {k: jnp.asarray(v) for k, v in raw.items()}

    init, loss, serve = {
        "din": (R.din_init, R.din_loss, R.din_forward),
        "bst": (R.bst_init, R.bst_loss, R.bst_forward),
        "mind": (R.mind_init, R.mind_loss, R.mind_point_serve),
        "two-tower-retrieval": (R.twotower_init, R.twotower_loss, R.twotower_serve),
    }[arch]
    params = init(jax.random.PRNGKey(0), cfg)
    lv, grads = jax.value_and_grad(loss)(params, cfg, batch)
    assert _finite(lv)
    gn = jax.tree.reduce(lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads, 0.0)
    assert _finite(gn) and gn > 0
    scores = serve(params, cfg, batch)
    assert scores.shape == (16,) and _finite(scores)


def test_retrieval_scoring_paths():
    """retrieval_cand cells: batched dot / capsule-max, not loops."""
    rng = np.random.default_rng(2)
    ncand = 512

    cfg = registry.get_config("two-tower-retrieval", smoke=True)
    params = R.twotower_init(jax.random.PRNGKey(0), cfg)
    batch = {
        "hist": jnp.asarray(rng.integers(0, cfg.n_items, (1, cfg.seq_len)), jnp.int32),
        "user_id": jnp.zeros((1,), jnp.int32),
        "candidates": jnp.asarray(rng.integers(0, cfg.n_items, ncand), jnp.int32),
    }
    vals, idx = R.twotower_retrieve(params, cfg, batch)
    assert vals.shape == (100,) and _finite(vals)
    assert np.all(np.diff(np.asarray(vals)) <= 1e-6)  # sorted top-k

    mcfg = registry.get_config("mind", smoke=True)
    mp = R.mind_init(jax.random.PRNGKey(1), mcfg)
    mb = {
        "hist": jnp.asarray(rng.integers(1, mcfg.n_items, (1, mcfg.seq_len)), jnp.int32),
        "candidates": jnp.asarray(rng.integers(0, mcfg.n_items, ncand), jnp.int32),
    }
    sc = R.mind_serve(mp, mcfg, mb)
    assert sc.shape == (1, ncand) and _finite(sc)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_cells_build_abstractly(arch):
    """Every (arch x shape) cell must at least build its abstract program
    (full configs, no allocation)."""
    for shape in registry.shapes_for(arch):
        cell = registry.build_cell(arch, shape)
        assert cell.model_flops > 0
        leaves = jax.tree.leaves(cell.in_shapes)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_moe_capacity_drops_monotone():
    """Lower capacity factor -> more dropped tokens (expert_fill sanity)."""
    from repro.layers.moe import MoEConfig, init_moe, moe_apply

    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    fills = []
    for cf in (0.5, 2.0):
        cfg = MoEConfig(d_model=32, d_ff=48, n_experts=4, top_k=2,
                        capacity_factor=cf, group_size=64)
        p = init_moe(rng, cfg, jnp.float32)
        y, aux = moe_apply(p, cfg, x)
        assert y.shape == x.shape and _finite(y)
        fills.append(float(aux["expert_fill"]))
    assert fills[0] > fills[1]  # tighter capacity runs fuller
