"""Estimator correctness: gold-standard CV targets, segments, special cases."""
import math

import numpy as np
import pytest

from repro.core import continuous as C
from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import vectorized as V


def test_distinct_sampling_estimates_distinct(zipf_stream, zipf_truth):
    ukeys, cnts = zipf_truth
    ests = [
        E.estimate(
            V.sample_two_pass(zipf_stream, None, k=100, l=1, kind="distinct", salt=r), F.distinct()
        )
        for r in range(50)
    ]
    m = np.mean(ests)
    assert abs(m - len(ukeys)) / len(ukeys) < 0.06


def test_sh_estimates_sum_exactly_relative(zipf_stream, zipf_truth):
    _, cnts = zipf_truth
    truth = cnts.sum()
    ests = [
        E.estimate(
            V.sample_two_pass(zipf_stream, None, k=100, l=1e9, kind="sh", salt=100 + r), F.total()
        )
        for r in range(50)
    ]
    assert abs(np.mean(ests) - truth) / truth < 0.05


def test_segment_queries(zipf_stream, zipf_truth):
    """Q(cap_T, H) for H = keys = 0 mod 3, via predicate segments."""
    ukeys, cnts = zipf_truth
    seg_mask = ukeys % 3 == 0
    truth = F.exact_statistic(F.cap(5), cnts[seg_mask])
    seg = lambda keys: keys % 3 == 0
    ests = [
        E.estimate(V.sample_fixed_k(zipf_stream, None, k=300, l=5.0, salt=200 + r), F.cap(5), seg)
        for r in range(60)
    ]
    m, sd = np.mean(ests), np.std(ests)
    assert abs(m - truth) < 4 * sd / math.sqrt(60) + 0.01 * truth
    # CV sanity: q = truth share; bound ~ (q(k-1))^{-1/2} * 1.6 (Thm 5.4)
    q = truth / F.exact_statistic(F.cap(5), cnts)
    assert sd / truth < 2.0 * C.cv_bound_one_pass(5, 5, q, 300)


def test_cv_meets_gold_standard(zipf_stream, zipf_truth):
    """At l = T the empirical CV should be within the Thm 5.4 bound (and in
    practice near (qk)^-0.5)."""
    _, cnts = zipf_truth
    truth = F.exact_statistic(F.cap(20), cnts)
    ests = [
        E.estimate(V.sample_fixed_k(zipf_stream, None, k=150, l=20.0, salt=300 + r), F.cap(20))
        for r in range(150)
    ]
    cv = np.std(ests) / truth
    assert cv < C.cv_bound_one_pass(20, 20, 1.0, 150)
    assert cv < 2.0 / math.sqrt(149)  # near gold standard


def test_disparity_degrades_gracefully(zipf_stream, zipf_truth):
    """Estimating cap_100 from an l=1 sample must be worse than from l=100."""
    _, cnts = zipf_truth
    truth = F.exact_statistic(F.cap(100), cnts)
    errs = {}
    for l in (1.0, 100.0):
        es = [
            E.estimate(V.sample_fixed_k(zipf_stream, None, k=100, l=l, salt=400 + r), F.cap(100))
            for r in range(80)
        ]
        errs[l] = np.sqrt(np.mean((np.asarray(es) / truth - 1) ** 2))
    assert errs[100.0] < errs[1.0]


def test_nonnegative_estimates(zipf_stream):
    """Monotone f => nonnegative per-key estimates (Thm 4.2 / eq. 13)."""
    for r in range(10):
        res = V.sample_fixed_k(zipf_stream, None, k=50, l=5.0, salt=500 + r)
        vals = E.estimate_per_key(res, F.cap(3))
        assert np.all(vals >= 0)


def test_estimate_empty_segment(zipf_stream):
    res = V.sample_fixed_k(zipf_stream, None, k=50, l=5.0, salt=1)
    assert E.estimate(res, F.cap(5), segment=np.array([10**8])) == 0.0


def test_small_stream_all_keys_sampled():
    """If fewer than k+1 active keys, tau = inf and estimates are exact."""
    keys = np.array([1, 1, 2, 3, 3, 3])
    res = V.sample_fixed_k(keys, None, k=100, l=5.0, salt=0, chunk=8)
    assert math.isinf(res.tau)
    assert E.estimate(res, F.total()) == pytest.approx(6.0)
    assert E.estimate(res, F.distinct()) == pytest.approx(3.0)
