"""Merge-bias regression harness (DESIGN.md §5 merge semantics).

Locks in the accuracy contract of both multi-host merge modes:

* ``mode="approx"`` (1-pass ``merge_fixed_k``): unbiased within CI noise for
  key-partitioned shards; arbitrary element splits stay within the
  documented ~10% envelope (the bias is inherent to 1-pass merging: entry
  events condition on per-host thresholds and cross-shard mass of unsampled
  keys is unrecoverable).
* ``mode="exact"`` + reconcile (lossless bottom-(k+1) min-merge + pass II):
  bias ~ 0 on the *same* element splits, and the pass-1 sample (keys, tau)
  is bit-identical to a single-stream bottom-k over the union of the
  per-shard scored streams.
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import freqfns as F
from repro.core import vectorized as V
from repro.core.samplers import shard_eids_np
from repro.core.segments import EMPTY
from repro.stats.service import StatsConfig, StreamStatsService

EMPTY = int(EMPTY)
K, L, CHUNK, T = 512, 16.0, 1024, 10.0


def _stream(n=40000, n_keys=8000, seed=2):
    rng = np.random.default_rng(seed)
    return (rng.zipf(1.4, size=n) % n_keys).astype(np.int64)


def _two_host_services(salt):
    a = StreamStatsService(StatsConfig(k=K, ls=(L,), chunk=CHUNK, salt=salt, host_id=0))
    b = StreamStatsService(StatsConfig(k=K, ls=(L,), chunk=CHUNK, salt=salt, host_id=1))
    return a, b


def _merged_estimate(keys, split, salt, mode):
    """Observe the two shards on two hosts, merge, estimate Q(cap_T)."""
    sh0, sh1 = split(keys)
    a, b = _two_host_services(salt)
    a.observe(sh0)
    b.observe(sh1)
    a.merge(b, mode=mode)
    if mode == "exact":
        a.reconcile(sh0)
        a.reconcile(sh1)
        return a.query_cap(T, exact=True)
    return a.query_cap(T)


def _element_split(keys):
    """Every key's elements straddle both hosts — the adversarial split."""
    return keys[0::2], keys[1::2]


def _key_split(keys):
    return keys[keys % 2 == 0], keys[keys % 2 == 1]


def test_approx_merge_key_partitioned_unbiased():
    keys = _stream()
    _, cnts = np.unique(keys, return_counts=True)
    truth = F.exact_statistic(F.cap(T), cnts)
    errs = [(_merged_estimate(keys, _key_split, salt, "approx") - truth) / truth
            for salt in range(6)]
    assert abs(np.mean(errs)) < 0.10, errs


def test_approx_merge_element_split_within_envelope():
    keys = _stream()
    _, cnts = np.unique(keys, return_counts=True)
    truth = F.exact_statistic(F.cap(T), cnts)
    errs = [(_merged_estimate(keys, _element_split, salt, "approx") - truth) / truth
            for salt in range(6)]
    # keys straddling shards make the 1-pass merge approximate; the measured
    # envelope is ~10% at k=512 — fail if it ever degrades past 20%
    assert abs(np.mean(errs)) < 0.20, errs


def test_exact_merge_element_split_bias_zero():
    """The headline claim: exact mode kills the element-split merge bias."""
    keys = _stream()
    _, cnts = np.unique(keys, return_counts=True)
    truth = F.exact_statistic(F.cap(T), cnts)
    errs = [(_merged_estimate(keys, _element_split, salt, "exact") - truth) / truth
            for salt in range(6)]
    m, se = np.mean(errs), np.std(errs) / math.sqrt(len(errs))
    # unbiased: mean error within CI noise of zero (and far inside the
    # approximate mode's ~10% envelope)
    assert abs(m) < 3 * se + 0.02, (m, se, errs)


def test_exact_merge_matches_single_stream_reference_bitwise():
    """Merged pass-1 sample == brute-force bottom-k over the union of the
    per-shard scored streams (same hashed eids), and pass-2 weights are the
    exact key frequencies."""
    keys = _stream()
    sh0, sh1 = _element_split(keys)
    salt = 3
    a, b = _two_host_services(salt)
    a.observe(sh0)
    b.observe(sh1)
    a.merge(b, mode="exact")
    a.reconcile(sh0)
    a.reconcile(sh1)
    lane = a.exact_sketches()[L]

    # reference: score each shard with the device scorer under its host's
    # hashed element ids (incl. the flush padding), min per key, bottom-k
    seeds = {}
    for host, shard in ((0, sh0), (1, sh1)):
        n = len(shard)
        pad = (-n) % CHUNK
        kk = np.concatenate([shard.astype(np.int32), np.full(pad, EMPTY, np.int32)])
        ww = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
        eids = shard_eids_np(host, np.arange(len(kk))).astype(np.int32)
        sc = np.asarray(V.element_scores(
            "continuous", jnp.asarray(kk), jnp.asarray(eids), jnp.asarray(ww),
            jnp.float32(L), jnp.uint32(salt)))
        for key_, s_ in zip(kk.tolist(), sc.tolist()):
            if key_ != EMPTY:
                seeds[key_] = min(seeds.get(key_, np.inf), s_)
    ordered = sorted(seeds.items(), key=lambda kv: kv[1])
    ref_keys = np.sort([x for x, _ in ordered[:K]])
    ref_tau = ordered[K][1]

    np.testing.assert_array_equal(lane.keys, ref_keys)
    assert lane.tau == ref_tau
    ref_w = {x: 0.0 for x in ref_keys.tolist()}
    for x in keys.tolist():
        if x in ref_w:
            ref_w[x] += 1.0
    np.testing.assert_array_equal(
        lane.counts, np.array([ref_w[x] for x in ref_keys.tolist()], np.float64))


def test_exact_merge_requires_distinct_host_ids():
    keys = _stream(n=4000)
    a = StreamStatsService(StatsConfig(k=64, ls=(L,), chunk=CHUNK, salt=0, host_id=0))
    b = StreamStatsService(StatsConfig(k=64, ls=(L,), chunk=CHUNK, salt=0, host_id=0))
    a.observe(keys[0::2])
    b.observe(keys[1::2])
    with pytest.raises(ValueError, match="host_id"):
        a.merge(b, mode="exact")
    # approx mode tolerates shared ids (its bias contract already covers it)
    a.merge(b, mode="approx")
    with pytest.raises(ValueError, match="approx"):
        a.begin_reconcile()


def test_exact_merge_rejects_duplicate_absorbed_host_ids():
    """The host_id guard is transitive: a host absorbed earlier claims its
    namespace, so a later merge with the same id must be rejected even
    though the pairwise check against the absorber would pass."""
    keys = _stream(n=6000)

    def svc(host_id, shard):
        s = StreamStatsService(
            StatsConfig(k=64, ls=(L,), chunk=CHUNK, salt=0, host_id=host_id))
        s.observe(shard)
        return s

    a = svc(0, keys[0::3])
    a.merge(svc(1, keys[1::3]), mode="exact")
    with pytest.raises(ValueError, match="host_id"):
        a.merge(svc(1, keys[2::3]), mode="exact")  # reuses absorbed id 1
    a.merge(svc(2, keys[2::3]), mode="exact")  # fresh id is fine


def test_reconcile_invalidated_by_observe_raises():
    """observe()/merge() after a begun reconcile discards the accumulated
    pass-II weights; continuing must fail loudly, not report partial sums
    as exact."""
    keys = _stream(n=12000, n_keys=2000)
    svc = StreamStatsService(StatsConfig(k=128, ls=(L,), chunk=CHUNK, salt=1))
    svc.observe(keys[:8000])
    svc.reconcile(keys[:8000])
    svc.observe(keys[8000:])  # pass-1 sample changes -> accumulators stale
    with pytest.raises(ValueError, match="begin_reconcile"):
        svc.reconcile(keys[8000:])
    # explicit restart over the full stream recovers exactness
    svc.begin_reconcile()
    svc.reconcile(keys)
    lane = svc.exact_sketches()[L]
    freq = dict(zip(*np.unique(keys, return_counts=True)))
    for x, w in zip(lane.keys.tolist(), lane.counts.tolist()):
        assert w == freq[x]


def test_partial_reconcile_never_pollutes_queries():
    """Queries between begin_reconcile and pass-II completion must keep
    answering from the valid 1-pass sketches (never nan / partial sums);
    forcing exact=True mid-pass fails loudly."""
    keys = _stream(n=12000, n_keys=2000)
    _, cnts = np.unique(keys, return_counts=True)
    truth = F.exact_statistic(F.cap(T), cnts)
    svc = StreamStatsService(StatsConfig(k=256, ls=(L,), chunk=CHUNK, salt=1))
    svc.observe(keys)
    svc.begin_reconcile()  # zero-weight accumulators
    est = svc.query_cap(T)  # auto mode: falls back to the sketches
    assert np.isfinite(est) and abs(est - truth) / truth < 0.3
    svc.reconcile(keys[:4000])  # partial pass II
    est = svc.query_cap(T)
    assert np.isfinite(est) and abs(est - truth) / truth < 0.3
    with pytest.raises(ValueError, match="reconcile"):
        svc.query_cap(T, exact=True)
    svc.reconcile(keys[4000:])  # pass II complete -> exact path unlocks
    assert np.isfinite(svc.query_cap(T, exact=True))


def test_exact_single_host_reconcile_matches_two_pass():
    """Degenerate single-host case: reconcile over the own stream yields the
    classic 2-pass sample (sanity anchor for the estimator path)."""
    keys = _stream(n=20000, n_keys=4000, seed=5)
    _, cnts = np.unique(keys, return_counts=True)
    svc = StreamStatsService(StatsConfig(k=256, ls=(L,), chunk=CHUNK, salt=1))
    svc.observe(keys)
    svc.reconcile(keys)
    truth = F.exact_statistic(F.cap(T), cnts)
    est = svc.query_cap(T)  # exact auto-selected after reconcile
    assert abs(est - truth) / truth < 0.15
    # weights are exact frequencies for every sampled key
    lane = svc.exact_sketches()[L]
    freq = dict(zip(*np.unique(keys, return_counts=True)))
    for x, w in zip(lane.keys.tolist(), lane.counts.tolist()):
        assert w == freq[x]
