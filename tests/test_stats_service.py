"""StreamStatsService + hot/cold embedding planning integration tests."""
import numpy as np

from repro.models.embedding_sharding import hot_cold_lookup, plan_hot_cold, split_table
from repro.stats.service import StatsConfig, StreamStatsService


def _service_with_stream(n=60000, alpha=1.4, n_keys=5000, k=1024):
    svc = StreamStatsService(StatsConfig(k=k, ls=(1.0, 8.0, 64.0), chunk=1024))
    rng = np.random.default_rng(0)
    keys = (rng.zipf(alpha, size=n) % n_keys).astype(np.int64)
    for i in range(0, n, 10000):  # batched ingestion like a pipeline
        svc.observe(keys[i : i + 10000])
    return svc, keys


def test_queries_accuracy():
    svc, keys = _service_with_stream()
    ukeys, cnts = np.unique(keys, return_counts=True)
    assert abs(svc.query_distinct() - len(ukeys)) / len(ukeys) < 0.15
    assert abs(svc.query_total() - len(keys)) / len(keys) < 0.15
    truth8 = float(np.minimum(cnts, 8).sum())
    assert abs(svc.campaign_forecast(8) - truth8) / truth8 < 0.15
    # segment query
    seg = lambda k: k % 2 == 0
    truth_seg = float(np.minimum(cnts[ukeys % 2 == 0], 8).sum())
    assert abs(svc.campaign_forecast(8, segment=seg) - truth_seg) / truth_seg < 0.2


def test_pick_l_matches_log_distance():
    svc = StreamStatsService(StatsConfig(ls=(1.0, 8.0, 64.0)))
    assert svc.pick_l(1) == 1.0
    assert svc.pick_l(10) == 8.0
    assert svc.pick_l(500) == 64.0


def test_state_roundtrip():
    svc, keys = _service_with_stream(n=20000)
    q1 = svc.campaign_forecast(8)
    state = svc.state_dict()
    svc2 = StreamStatsService(svc.config)
    svc2.load_state_dict(state)
    assert svc2.campaign_forecast(8) == q1


def test_hot_cold_plan_and_lookup():
    import jax.numpy as jnp

    svc, keys = _service_with_stream(n=40000, alpha=1.6, n_keys=2000)
    plan = plan_hot_cold(svc, n_hot=32)
    assert 0 < plan.est_hot_traffic_frac <= 1.0
    # heavy keys should be overrepresented in the plan
    ukeys, cnts = np.unique(keys, return_counts=True)
    top = set(ukeys[np.argsort(-cnts)[:200]].tolist())
    hits = sum(1 for x in plan.hot_ids_sorted if int(x) in top)
    assert hits >= 16, f"only {hits}/32 hot keys in true top-200"

    table = jnp.asarray(np.random.default_rng(1).normal(size=(2000, 8)), jnp.float32)
    hot_table, hot_ids = split_table(table, plan)
    ids = jnp.asarray([int(plan.hot_ids_sorted[0]), 3, int(plan.hot_ids_sorted[-1]), 7])
    out = hot_cold_lookup(table, hot_table, hot_ids, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table)[np.asarray(ids)], rtol=1e-6)
