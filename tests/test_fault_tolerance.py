"""Checkpoint/restart, elastic resharding, data-cursor continuity,
gradient compression."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.streams import ShardedStream, StreamCursor
from repro.optim.compression import compress_gradients_ef, compress_leaf

ROOT = Path(__file__).resolve().parent.parent


def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": [jnp.ones(5), jnp.zeros((2, 2))]}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, extra={"cursor": {"offset": s}}, keep_last=2)
        assert ckpt.latest_step(d) == 5
        # retention kept only last 2
        steps = sorted(p.name for p in Path(d).iterdir())
        assert steps == ["step_00000004", "step_00000005"]
        out = ckpt.restore(d, 5, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert ckpt.restore_extra(d, 5)["cursor"]["offset"] == 5


def test_checkpoint_atomicity_tmp_ignored():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        # simulate a crashed half-written checkpoint
        (Path(d) / "step_00000002.tmp").mkdir()
        assert ckpt.latest_step(d) == 1


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(d, 1, {"a": jnp.ones((3, 3))})


def test_stream_cursor_resume_exact():
    def mk():
        return ShardedStream(n_total=10000, alpha=1.3, n_keys=100, seed=5,
                             cursor=StreamCursor(shard=0, n_shards=2))

    s1 = mk()
    a = s1.next_batch(64)
    state = s1.state_dict()
    b = s1.next_batch(64)
    s2 = mk()
    s2.load_state_dict(state)
    b2 = s2.next_batch(64)
    np.testing.assert_array_equal(b, b2)
    assert not np.array_equal(a, b)


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    ef = jnp.zeros_like(g)
    # single-step quantization error is bounded by scale/127 per block
    deq, ef2 = compress_leaf(g, ef)
    err = np.abs(np.asarray(deq - g))
    assert err.max() < float(jnp.max(jnp.abs(g))) / 127 + 1e-6
    # error feedback: accumulated compressed sum converges to true sum
    total_true = np.zeros(1000)
    total_comp = np.zeros(1000)
    ef = jnp.zeros_like(g)
    for i in range(30):
        gi = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
        total_true += np.asarray(gi)
        deq, ef = compress_leaf(gi, ef)
        total_comp += np.asarray(deq)
    # residual is bounded by the EF buffer, not growing with steps
    assert np.abs(total_true - total_comp).max() <= np.abs(np.asarray(ef)).max() + 1e-5


def test_compress_gradients_tree():
    grads = {"w": jnp.ones((70,)), "b": jnp.full((3,), 0.5)}
    ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads)
    out, ef2 = compress_gradients_ef(grads, ef)
    assert jax.tree.structure(out) == jax.tree.structure(grads)


@pytest.mark.slow
def test_elastic_restart_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Durability: fsync discipline + crash-interrupted save
# ---------------------------------------------------------------------------


def test_checkpoint_fsync_ordering(monkeypatch):
    """The write/fsync(files)/fsync(tmp dir)/rename/fsync(parent) discipline
    (manager docstring): every byte of the checkpoint reaches stable storage
    BEFORE the rename makes it visible, and the rename itself is made
    durable by the parent-directory fsync AFTER."""
    events = []
    real_file, real_dir = ckpt.fsync_file, ckpt.fsync_dir
    with tempfile.TemporaryDirectory() as d:
        final = Path(d) / "step_00000001"

        def rec_file(path):
            events.append(("file", Path(path).name, final.exists()))
            real_file(path)

        def rec_dir(path):
            events.append(("dir", Path(path).name, final.exists()))
            real_dir(path)

        monkeypatch.setattr(ckpt, "fsync_file", rec_file)
        monkeypatch.setattr(ckpt, "fsync_dir", rec_dir)
        ckpt.save(d, 1, {"a": jnp.arange(4.0)}, extra={"cursor": {"o": 1}})

    files = [e for e in events if e[0] == "file"]
    dirs = [e for e in events if e[0] == "dir"]
    # every checkpoint file fsynced, all pre-commit (final not yet visible)
    assert {n for _, n, _ in files} == {"arrays.npz", "manifest.json",
                                        "extra.json"}
    assert all(not committed for _, _, committed in files)
    # tmp dir fsynced pre-commit; parent dir fsynced post-commit
    assert len(dirs) == 2
    assert dirs[0][1].endswith(".tmp") and not dirs[0][2]
    assert not dirs[1][1].endswith(".tmp") and dirs[1][2]


def test_checkpoint_save_without_fsync_skips_syncs(monkeypatch):
    calls = []
    monkeypatch.setattr(ckpt, "fsync_file", lambda p: calls.append(p))
    monkeypatch.setattr(ckpt, "fsync_dir", lambda p: calls.append(p))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.ones(2)}, fsync=False)
        assert calls == []
        assert ckpt.latest_step(d) == 1


def test_checkpoint_interrupted_save_keeps_previous(monkeypatch):
    """A crash mid-save (simulated: fsync raises before the rename) never
    harms the committed checkpoint: latest_step is unchanged and the old
    step restores bit-for-bit."""
    tree1 = {"a": jnp.arange(3.0)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree1)

        def power_cut(path):
            raise OSError("simulated power cut during fsync")

        monkeypatch.setattr(ckpt, "fsync_file", power_cut)
        with pytest.raises(OSError, match="power cut"):
            ckpt.save(d, 2, {"a": jnp.arange(3.0) * 2})
        monkeypatch.undo()
        # the half-written step 2 is invisible (.tmp); step 1 is intact
        assert ckpt.latest_step(d) == 1
        out = ckpt.restore(d, 1, tree1)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree1["a"]))
        # and a post-restart save of step 2 commits over the debris
        ckpt.save(d, 2, {"a": jnp.arange(3.0) * 2})
        assert ckpt.latest_step(d) == 2


# ---------------------------------------------------------------------------
# Crash mid-handoff: tenant leave/join interrupted between slice and splice
# ---------------------------------------------------------------------------


def test_crash_mid_handoff_recovers_from_committed_checkpoint():
    """Kill the mover between ``restore_slice`` (leave) and
    ``load_tenant_state_dict`` (join): the in-flight blob is memory-only, so
    nothing is torn — the destination bank is untouched, the source
    checkpoint still serves the row, and the retried handoff is
    bit-identical because the slice is a pure read of committed state."""
    from repro.core import hashing
    from repro.stats.service import (
        MultiTenantStats, StatsConfig, StreamStatsService)

    cfg = StatsConfig(k=64, ls=(1.0, 8.0), chunk=64)
    T = 3
    eids = np.arange(1200, dtype=np.int64)
    streams = [
        ((hashing.hash_combine_np(eids, np.int64(t)) % np.uint32(300))
         .astype(np.int64) + 1)
        for t in range(T)
    ]
    bank = MultiTenantStats(cfg, n_tenants=T)
    for t in range(T):
        bank.observe(t, streams[t])
    bank.drain()
    want = bank.query_cap(1, 8.0)

    with tempfile.TemporaryDirectory() as d:
        bank.save_checkpoint(d, step=1)
        example = StreamStatsService(cfg).state_dict()
        example.pop("exact_ok")  # bank rows are 1-pass sketch state

        # attempt 1: the mover slices tenant 1 out of the bank checkpoint…
        blob = ckpt.restore_slice(d, 1, example, index=1)
        # …then dies BEFORE load_tenant_state_dict ran on the destination.
        del blob  # in-flight state gone with the process

        # no torn row: the destination bank never saw the handoff
        dest = MultiTenantStats(cfg, n_tenants=T)
        assert dest.n_observed(1) == 0

        # attempt 2 (restart): the same committed checkpoint replays the
        # handoff — the slice is deterministic, the splice lands intact
        blob_a = ckpt.restore_slice(d, 1, example, index=1)
        blob_b = ckpt.restore_slice(d, 1, example, index=1)
        assert set(blob_a) == set(blob_b)
        for key in blob_a:
            np.testing.assert_array_equal(np.asarray(blob_a[key]),
                                          np.asarray(blob_b[key]))
        dest.load_tenant_state_dict(1, blob_a)
        assert dest.query_cap(1, 8.0) == want
        # the source checkpoint is unchanged — a third reader still slices
        # the identical row (the crash wrote nothing anywhere)
        again = ckpt.restore_slice(d, 1, example, index=1)
        for key in blob_a:
            np.testing.assert_array_equal(np.asarray(again[key]),
                                          np.asarray(blob_a[key]))


# ---------------------------------------------------------------------------
# Chaos suite: seeded fault schedules against the sharded ingestion tier
# ---------------------------------------------------------------------------

# Failing seeds get committed verbatim here as regression schedules
# (FaultSchedule.to_json makes them portable) — see DESIGN.md §13.
CHAOS_SEEDS = (3, 11, 29)


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_tier_exact_bit_identity_after_recovery(seed):
    """Drive the sharded tier through a seeded schedule of crashes, stalls,
    slow calls, and lost replies while ingesting the SAME stream as a
    fault-free oracle tier.  Invariants:

    * mid-run answers are always available — exact when reachable, else a
      flagged degraded answer with a coverage stamp;
    * after the schedule drains and every shard recovers, the exact
      two-pass answer is bit-identical to the oracle's (crash/recover
      history leaves zero trace in the state).
    """
    import dataclasses

    from repro.core import freqfns, hashing
    from repro.launch.faults import FaultInjector, FaultSchedule
    from repro.stats.query import Query
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import ExactUnavailable, ShardTier, TierConfig

    cfg = StatsConfig(k=64, ls=(1.0, 8.0), chunk=32)
    queries = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]
    n_shards = 3
    schedule = FaultSchedule.generate(seed, n_shards=n_shards, n_events=12)
    assert schedule.events, "a chaos seed must actually schedule faults"
    tier_cfg = TierConfig(n_shards=n_shards, checkpoint_every=4,
                          retain_wal=True, auto_recover=True)

    n_batches, batch = 8, 250
    eids = np.arange(n_batches * batch, dtype=np.int64)
    keys = ((hashing.hash_combine_np(eids, np.int64(5)) % np.uint32(400))
            .astype(np.int64) + 1).reshape(n_batches, batch)

    with tempfile.TemporaryDirectory() as d:
        oracle = ShardTier(cfg, dataclasses.replace(tier_cfg),
                           Path(d) / "oracle")
        tier = ShardTier(cfg, dataclasses.replace(tier_cfg),
                         Path(d) / "tier", faults=FaultInjector(schedule))
        for i, b in enumerate(keys):
            oracle.ingest(b)
            tier.ingest(b)
            if i == n_batches // 2:
                # mid-run leg: auto mode must answer NOW, whatever is down
                mid = tier.query_batch(queries, mode="auto")
                assert np.all(np.isfinite(mid.estimates))
                if mid.degraded:
                    assert 0.0 < mid.coverage < 1.0
                    assert mid.staleness_elements > 0
                    assert mid.mode == "approx"
                else:
                    assert mid.coverage == 1.0

        # drain the schedule: events fire once per (site, call_no <= 8), so
        # a bounded number of health/query rounds exhausts every remaining
        # event; exact answers require all shards up + caught up
        got = None
        for _ in range(20):
            try:
                got = tier.query_batch(queries, mode="exact")
                break
            except ExactUnavailable:
                for _ in range(10):
                    if all(st == "up"
                           for st in tier.check_health().values()):
                        break
        assert got is not None, (
            f"seed {seed}: exact answer still unavailable after the "
            f"schedule drained; membership={tier.membership()}")
        assert got.mode == "exact" and not got.degraded
        assert got.coverage == 1.0 and got.staleness_elements == 0

        want = oracle.query_batch(queries, mode="exact")
        np.testing.assert_array_equal(got.estimates, want.estimates)
        # approx answers converge to full coverage too (all shards up)
        approx = tier.query_batch(queries, mode="approx")
        ref = oracle.query_batch(queries, mode="approx")
        assert not approx.degraded
        np.testing.assert_array_equal(approx.estimates, ref.estimates)


def test_chaos_schedule_regression_roundtrip():
    """A failing chaos seed commits as a verbatim JSON schedule; replaying
    the JSON drives the injector through the identical event sequence."""
    from repro.launch.faults import FaultInjector, FaultSchedule

    schedule = FaultSchedule.generate(CHAOS_SEEDS[0], n_shards=3,
                                      n_events=12)
    replayed = FaultSchedule.from_json(schedule.to_json())
    assert replayed.events == schedule.events

    a, b = FaultInjector(schedule), FaultInjector(replayed)
    sites = [e.site for e in schedule.events for _ in range(e.call_no)]
    for inj in (a, b):
        for s in sites:
            try:
                with inj.site(s):
                    pass
            except Exception:  # noqa: BLE001 — any injected fault kind
                pass
    assert a.fired == b.fired and len(a.fired) == len(schedule.events)
