"""Checkpoint/restart, elastic resharding, data-cursor continuity,
gradient compression."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.streams import ShardedStream, StreamCursor
from repro.optim.compression import compress_gradients_ef, compress_leaf

ROOT = Path(__file__).resolve().parent.parent


def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": [jnp.ones(5), jnp.zeros((2, 2))]}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, extra={"cursor": {"offset": s}}, keep_last=2)
        assert ckpt.latest_step(d) == 5
        # retention kept only last 2
        steps = sorted(p.name for p in Path(d).iterdir())
        assert steps == ["step_00000004", "step_00000005"]
        out = ckpt.restore(d, 5, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert ckpt.restore_extra(d, 5)["cursor"]["offset"] == 5


def test_checkpoint_atomicity_tmp_ignored():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        # simulate a crashed half-written checkpoint
        (Path(d) / "step_00000002.tmp").mkdir()
        assert ckpt.latest_step(d) == 1


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(d, 1, {"a": jnp.ones((3, 3))})


def test_stream_cursor_resume_exact():
    def mk():
        return ShardedStream(n_total=10000, alpha=1.3, n_keys=100, seed=5,
                             cursor=StreamCursor(shard=0, n_shards=2))

    s1 = mk()
    a = s1.next_batch(64)
    state = s1.state_dict()
    b = s1.next_batch(64)
    s2 = mk()
    s2.load_state_dict(state)
    b2 = s2.next_batch(64)
    np.testing.assert_array_equal(b, b2)
    assert not np.array_equal(a, b)


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    ef = jnp.zeros_like(g)
    # single-step quantization error is bounded by scale/127 per block
    deq, ef2 = compress_leaf(g, ef)
    err = np.abs(np.asarray(deq - g))
    assert err.max() < float(jnp.max(jnp.abs(g))) / 127 + 1e-6
    # error feedback: accumulated compressed sum converges to true sum
    total_true = np.zeros(1000)
    total_comp = np.zeros(1000)
    ef = jnp.zeros_like(g)
    for i in range(30):
        gi = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
        total_true += np.asarray(gi)
        deq, ef = compress_leaf(gi, ef)
        total_comp += np.asarray(deq)
    # residual is bounded by the EF buffer, not growing with steps
    assert np.abs(total_true - total_comp).max() <= np.abs(np.asarray(ef)).max() + 1e-5


def test_compress_gradients_tree():
    grads = {"w": jnp.ones((70,)), "b": jnp.full((3,), 0.5)}
    ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads)
    out, ef2 = compress_gradients_ef(grads, ef)
    assert jax.tree.structure(out) == jax.tree.structure(grads)


@pytest.mark.slow
def test_elastic_restart_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
