"""Multi-objective samples (§6): coordination, Lemma 6.1/6.2, estimation."""
import math

import numpy as np

from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import multiobjective as M


def test_union_size_lemma61(zipf_stream):
    """E|S_L| <= k ln n for L = (0, inf) (Lemma 6.1)."""
    k = 50
    sizes = []
    for salt in range(8):
        ukeys, hx, y, wx = M.per_key_randomness(zipf_stream, None, salt=salt)
        union = M.union_sample_all_l(ukeys, hx, y, k)
        sizes.append(len(union))
    n = len(np.unique(zipf_stream))
    bound = k * math.log(n)
    assert np.mean(sizes) <= bound, f"{np.mean(sizes)} > {bound}"
    # and the union is much larger than a single sample
    assert np.mean(sizes) > k


def test_coordination_nesting(zipf_stream):
    """Coordinated samples change gradually with l: neighbors in the grid
    share most keys (this is the point of coordination, §6.1)."""
    ukeys, hx, y, _ = M.per_key_randomness(zipf_stream, None, salt=3)
    k = 100
    s1, _ = M.sample_for_l(ukeys, hx, y, k, 8.0)
    s2, _ = M.sample_for_l(ukeys, hx, y, k, 11.0)
    s3, _ = M.sample_for_l(ukeys, hx, y, k, 8000.0)
    j12 = len(np.intersect1d(s1, s2)) / k
    j13 = len(np.intersect1d(s1, s3)) / k
    assert j12 > 0.8
    assert j13 < j12


def test_membership_interval_structure(zipf_stream):
    """x in S_l holds on a contiguous l-interval (corollary of Lemma 6.1)."""
    ukeys, hx, y, _ = M.per_key_randomness(zipf_stream, None, salt=5)
    k = 60
    ls = np.geomspace(0.1, 10000, 25)
    member = np.zeros((len(ukeys), len(ls)), dtype=bool)
    key_idx = {x: i for i, x in enumerate(ukeys.tolist())}
    for j, l in enumerate(ls):
        s, _ = M.sample_for_l(ukeys, hx, y, k, l)
        for x in s.tolist():
            member[key_idx[x], j] = True
    # membership pattern per key must be a contiguous run of True
    for i in range(len(ukeys)):
        row = member[i]
        if row.any():
            nz = np.nonzero(row)[0]
            assert np.all(np.diff(nz) == 1), f"non-contiguous membership for key {ukeys[i]}"


def test_combined_inclusion_prob_monte_carlo():
    """Lemma 6.2 rectangle-union integration vs direct Monte Carlo."""
    taus = {2.0: 0.3, 10.0: 0.08, 100.0: 0.009}
    w = 3.5
    p_exact = M.combined_inclusion_prob(w, taus)
    rng = np.random.default_rng(0)
    y = rng.exponential(1.0 / w, size=400000)
    h = rng.uniform(size=400000)
    hit = np.zeros(400000, dtype=bool)
    for l, tau in taus.items():
        hit |= (y < max(tau, 1.0 / l)) & (h < l * tau)
    p_mc = hit.mean()
    np.testing.assert_allclose(p_exact, p_mc, atol=0.004)


def test_multiobjective_estimator_unbiased(zipf_stream, zipf_truth):
    """Combined-Phi inverse probability estimates across a T range."""
    _, cnts = zipf_truth
    ls = [1.0, 8.0, 64.0, 512.0]
    ests = {T: [] for T in (1, 8, 64)}
    for salt in range(25):
        union_keys, wx, taus_per_key, _ = M.multiobjective_sample(zipf_stream, None, 80, ls, salt=salt)
        for T in ests:
            ests[T].append(M.estimate_multi(F.cap(T), union_keys, wx, taus_per_key))
    for T, es in ests.items():
        truth = F.exact_statistic(F.cap(T), cnts)
        m, se = np.mean(es), np.std(es) / math.sqrt(len(es))
        assert abs(m - truth) < 4 * se + 0.02 * truth, f"T={T}: {m} vs {truth}"


def test_estimate_multi_exact_when_keys_at_most_k():
    """<= k distinct keys: every tau_l^{-x} is inf, Phi == 1, and the
    estimate IS the exact statistic (the sample is the data set)."""
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 40, size=3000)  # 40 distinct keys, k = 64
    _, cnts = np.unique(keys, return_counts=True)
    ls = [1.0, 8.0, 64.0]
    union_keys, wx, taus_per_key, _ = M.multiobjective_sample(keys, None, 64, ls, salt=1)
    assert len(union_keys) == len(cnts)
    assert all(math.isinf(t) for taus in taus_per_key for t in taus.values())
    for T in (1, 4, 16):
        truth = F.exact_statistic(F.cap(T), cnts)
        est = M.estimate_multi(F.cap(T), union_keys, wx, taus_per_key)
        np.testing.assert_allclose(est, truth, rtol=1e-9)


def test_multiobjective_estimator_unbiased_near_k_boundary():
    """Monte-Carlo unbiasedness right at the tau_l^{-x} exclusion edge: the
    number of distinct keys barely exceeds k, so every estimate exercises
    the s_sorted[k-1] / s_sorted[k] k-th-smallest-of-others indexing (the
    off-by-one audited in multiobjective.multiobjective_sample)."""
    rng = np.random.default_rng(8)
    k = 60
    keys = (rng.zipf(1.5, size=8000) % 70).astype(np.int64)  # ~70 distinct
    _, cnts = np.unique(keys, return_counts=True)
    assert k < len(cnts) <= k + 12  # the edge regime under test
    ls = [1.0, 8.0, 64.0]
    ests = {T: [] for T in (1, 8, 64)}
    for salt in range(30):
        union_keys, wx, taus_per_key, _ = M.multiobjective_sample(
            keys, None, k, ls, salt=salt)
        for T in ests:
            ests[T].append(M.estimate_multi(F.cap(T), union_keys, wx, taus_per_key))
    for T, es in ests.items():
        truth = F.exact_statistic(F.cap(T), cnts)
        m, se = np.mean(es), np.std(es) / math.sqrt(len(es))
        assert abs(m - truth) < 4 * se + 0.02 * truth, f"T={T}: {m} vs {truth}"


def test_multi_beats_single_when_off_grid(zipf_stream, zipf_truth):
    """The union estimator's variance is <= the single-sample variance
    (inclusion probability dominates each individual Phi_l)."""
    _, cnts = zipf_truth
    T = 64.0
    truth = F.exact_statistic(F.cap(T), cnts)
    ls = [1.0, 8.0, 64.0, 512.0]
    multi, single = [], []
    from repro.core import vectorized as V

    for salt in range(20):
        union_keys, wx, taus_per_key, _ = M.multiobjective_sample(zipf_stream, None, 60, ls, salt=salt)
        multi.append(M.estimate_multi(F.cap(T), union_keys, wx, taus_per_key))
        r = V.sample_two_pass(zipf_stream, None, k=60, l=64.0, salt=7000 + salt)
        single.append(E.estimate(r, F.cap(T)))
    rmse_m = np.sqrt(np.mean((np.asarray(multi) / truth - 1) ** 2))
    rmse_s = np.sqrt(np.mean((np.asarray(single) / truth - 1) ** 2))
    assert rmse_m < 1.5 * rmse_s  # allow noise; typically rmse_m <= rmse_s
