"""Oracle (Algorithms 1-5) vs TPU-native vectorized samplers.

* fixed-threshold: EXACT equality (same per-element hashes).
* 2-pass: EXACT equality of sampled key set, tau, and weights.
* fixed-k: distributional equality (unbiased estimates, count law, sizes).
"""
import math

import numpy as np
import pytest

from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import samplers as S
from repro.core import vectorized as V


@pytest.mark.parametrize("l,tau", [(5.0, 0.02), (1.0, 0.01), (100.0, 0.005)])
def test_fixed_tau_continuous_exact(zipf_stream, l, tau):
    ro = S.alg4_fixed_tau_continuous(zipf_stream, None, tau, l=l, salt=7)
    rv = V.sample_fixed_tau(zipf_stream, None, tau=tau, l=l, salt=7, capacity=16384)
    np.testing.assert_array_equal(ro.keys, rv.keys)
    np.testing.assert_allclose(ro.counts, rv.counts, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("kind,l", [("discrete", 5), ("distinct", 1), ("sh", math.inf)])
def test_fixed_tau_discrete_family_exact(zipf_stream, kind, l):
    eff_l = 1 if kind == "distinct" else l
    ro = S.alg2_fixed_tau_discrete(zipf_stream, 0.02, l=eff_l, salt=7, kind=kind)
    rv = V.sample_fixed_tau(
        zipf_stream, None, tau=0.02, l=(eff_l if not math.isinf(eff_l) else 1e9),
        kind=kind, salt=7, capacity=16384,
    )
    np.testing.assert_array_equal(ro.keys, rv.keys)
    np.testing.assert_array_equal(ro.counts, rv.counts.astype(np.int64))


@pytest.mark.parametrize("kind", ["continuous", "discrete", "distinct", "sh"])
def test_two_pass_exact(zipf_stream, kind):
    l = {"continuous": 5.0, "discrete": 5, "distinct": 1, "sh": 1e9}[kind]
    okind = kind
    ro = S.alg1_two_pass(zipf_stream, None, 100, l=l, kind=okind, salt=42)
    rv = V.sample_two_pass(zipf_stream, None, k=100, l=l, kind=kind, salt=42)
    np.testing.assert_array_equal(np.sort(ro.keys), np.sort(rv.keys))
    np.testing.assert_allclose(ro.tau, rv.tau, rtol=1e-5)
    np.testing.assert_allclose(
        ro.counts[np.argsort(ro.keys)], rv.counts[np.argsort(rv.keys)], rtol=1e-5
    )


def test_fixed_k_sizes_and_counts_domain(zipf_stream):
    rv = V.sample_fixed_k(zipf_stream, None, k=100, l=5.0, salt=3)
    assert len(rv.keys) == 100
    assert np.all(rv.counts > 0)
    ukeys, cnts = np.unique(zipf_stream, return_counts=True)
    w_map = dict(zip(ukeys.tolist(), cnts.tolist()))
    for x, c in zip(rv.keys.tolist(), rv.counts.tolist()):
        assert c <= w_map[x] + 1e-3, "count exceeds true weight"


def test_fixed_k_unbiased_vectorized(zipf_truth, zipf_stream):
    """The headline distributional test: mean of 200 estimates within 4 sigma."""
    _, cnts = zipf_truth
    truth = F.exact_statistic(F.cap(5), cnts)
    ests = [
        E.estimate(V.sample_fixed_k(zipf_stream, None, k=100, l=5.0, salt=77000 + r), F.cap(5))
        for r in range(200)
    ]
    m, se = np.mean(ests), np.std(ests) / math.sqrt(200)
    assert abs(m - truth) < 4 * se + 0.001 * truth, f"bias {(m-truth)/truth:+.2%} se {se/truth:.2%}"


def test_fixed_k_unbiased_oracle(zipf_truth, zipf_stream):
    """Sequential Algorithm 5 (with reconstruction notes) is unbiased too."""
    _, cnts = zipf_truth
    truth = F.exact_statistic(F.cap(5), cnts)
    ests = [
        E.estimate(S.alg5_fixed_k_continuous(zipf_stream, None, 100, l=5.0, salt=88000 + r), F.cap(5))
        for r in range(25)
    ]
    m, se = np.mean(ests), np.std(ests) / math.sqrt(25)
    assert abs(m - truth) < 4 * se + 0.01 * truth


def _count_law_pit(result, wmap, l, top_keys):
    """Probability-integral-transform of sampled counts under the Thm 5.2 law:
    phi = w - c ~ TruncExp(rate=max(1/l, tau)) on [0, w)  =>  F(phi) ~ U(0,1).
    """
    rate = max(1.0 / l, result.tau)
    us = []
    d = result.asdict()
    for x in top_keys:
        if x in d:
            w = wmap[x]
            phi = w - d[x]
            u = -np.expm1(-rate * phi) / -np.expm1(-rate * w)
            us.append(min(max(u, 0.0), 1.0))
    return us


def _ks_uniform(us):
    us = np.sort(np.asarray(us))
    n = len(us)
    grid = np.arange(1, n + 1) / n
    return max(np.max(np.abs(grid - us)), np.max(np.abs(us - (grid - 1.0 / n))))


def test_fixed_k_count_law_thm52(zipf_stream):
    """Counts of sampled keys follow the Thm 5.2 conditional law, in BOTH the
    sequential oracle and the vectorized sampler (PIT + KS vs uniform)."""
    ukeys, cnts = np.unique(zipf_stream, return_counts=True)
    wmap = dict(zip(ukeys.tolist(), cnts.tolist()))
    top = [int(x) for x in ukeys[np.argsort(-cnts)[:30]]]
    l = 5.0
    pit_o, pit_v = [], []
    for r in range(40):
        ro = S.alg5_fixed_k_continuous(zipf_stream, None, 100, l=l, salt=91000 + r)
        pit_o += _count_law_pit(ro, wmap, l, top)
    for r in range(150):
        rv = V.sample_fixed_k(zipf_stream, None, k=100, l=l, salt=92000 + r)
        pit_v += _count_law_pit(rv, wmap, l, top)
    assert len(pit_o) > 80 and len(pit_v) > 300
    # alpha ~ 1e-3 critical value 1.95/sqrt(n); PITs share tau within a run,
    # so allow some slack on top.
    assert _ks_uniform(pit_o) < 2.2 / math.sqrt(len(pit_o)), f"oracle KS {_ks_uniform(pit_o):.3f} n={len(pit_o)}"
    assert _ks_uniform(pit_v) < 2.2 / math.sqrt(len(pit_v)), f"vec KS {_ks_uniform(pit_v):.3f} n={len(pit_v)}"


def test_weighted_elements_continuous(zipf_stream):
    """Non-uniform weights: vectorized fixed-tau matches oracle exactly."""
    rng = np.random.default_rng(5)
    w = rng.exponential(2.0, size=len(zipf_stream)).astype(np.float32) + 0.1
    ro = S.alg4_fixed_tau_continuous(zipf_stream, w, 0.05, l=3.0, salt=11)
    rv = V.sample_fixed_tau(zipf_stream, w, tau=0.05, l=3.0, salt=11, capacity=16384)
    np.testing.assert_array_equal(ro.keys, rv.keys)
    np.testing.assert_allclose(ro.counts, rv.counts, rtol=1e-3, atol=1e-2)
