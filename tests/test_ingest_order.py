"""Single-sort + score-in-key-order ingest (DESIGN.md §8-§9): bit-identity.

Contracts under test:

* ``chunk_order`` / ``merge_sorted_runs`` / the scatter-form
  ``compact_valid`` reproduce the historical sort-based forms bit-for-bit;
* eviction threshold selection (top_k / rank-select / full sort) is one
  order statistic however it is lowered;
* the restructured chunk steps (shared ChunkOrder + ordered scoring +
  sorted-runs table merge + selected-threshold evict) are bit-identical to
  the pre-restructure reference path across kinds, chunk sizes, lane
  counts, and the tau=inf edge;
* the fused ``capscore_agg`` (score in key order, reduce in the same pass)
  equals score-then-gather-then-reduce: exactly on the XLA path, exactly on
  min/max/entered and to f32-reassociation on sums for the Pallas kernel;
* element scoring is permutation-covariant (the keystone of ordered
  scoring): scoring a permuted chunk with permuted eids == permuting the
  scores;
* the key-sorted bottom-(k+1) summary carry reproduces the seed-sorted
  iterated merge bit-for-bit (tables AND summaries, all L lanes);
* the sorted-table invariant holds after every step;
* ``evict_every > 1`` (amortized lazy eviction) keeps the sample a valid
  fixed-k SH_l sample: size <= k, Thm 5.2 count law (PIT + KS), unbiased
  cap estimates (Monte Carlo);
* the one-shot samplers validate keys through ``normalize_keys``;
* the capscore interpret default derives from the backend with env override;
* the kernel pad helper: padded-vs-aligned outputs slice bit-identically.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import estimators as EST
from repro.core import freqfns as F
from repro.core import incremental as I
from repro.core import vectorized as V
from repro.kernels.capscore.ops import _pad_tile, capscore, capscore_agg, capscore_multi
from repro.core.segments import (
    EMPTY,
    chunk_order,
    compact_valid,
    kth_smallest,
    merge_sorted_runs,
    merge_sorted_runs_gather,
    segment_ids,
    sort_by_key,
)


def _stream(n=16000, n_keys=3000, seed=0):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(1.4, size=n) % n_keys).astype(np.int64)
    w = (rng.exponential(1.0, n) + 0.1).astype(np.float32)
    return keys, w


# ---------------------------------------------------------------------------
# primitives: shared order, sorted-runs merge, sort-free compaction
# ---------------------------------------------------------------------------


def test_chunk_order_matches_sort_by_key():
    rng = np.random.default_rng(1)
    for n, n_keys in [(64, 7), (256, 300), (1024, 50)]:
        keys = rng.integers(0, n_keys, n).astype(np.int32)
        keys[rng.uniform(size=n) < 0.2] = int(EMPTY)  # padding interspersed
        keys = jnp.asarray(keys)
        o = chunk_order(keys)
        ks_ref, (perm_ref,) = sort_by_key(keys, jnp.arange(n))
        seg_ref, _ = segment_ids(ks_ref)
        np.testing.assert_array_equal(np.asarray(o.ks), np.asarray(ks_ref))
        np.testing.assert_array_equal(np.asarray(o.perm), np.asarray(perm_ref))
        np.testing.assert_array_equal(np.asarray(o.seg), np.asarray(seg_ref))
        # ukeys: ascending uniques compacted to the front, EMPTY padded
        uk = np.asarray(o.ukeys)
        expect = np.unique(np.asarray(keys))
        np.testing.assert_array_equal(uk[: len(expect)], expect)
        assert (uk[len(expect):] == int(EMPTY)).all()


def test_merge_sorted_runs_matches_stable_concat_sort():
    rng = np.random.default_rng(2)
    for na, nb in [(16, 16), (128, 32), (5, 200)]:
        a = np.sort(rng.integers(0, 60, na)).astype(np.int32)
        b = np.sort(rng.integers(0, 60, nb)).astype(np.int32)
        a[-na // 4 or -1:] = int(EMPTY)  # EMPTY tails like real tables
        b[-nb // 4 or -1:] = int(EMPTY)
        pos_a, pos_b = merge_sorted_runs(jnp.asarray(a), jnp.asarray(b))
        merged = np.zeros(na + nb, np.int32)
        merged[np.asarray(pos_a)] = a
        merged[np.asarray(pos_b)] = b
        concat = np.concatenate([a, b])
        order = np.argsort(concat, kind="stable")
        np.testing.assert_array_equal(merged, concat[order])
        # positions form a permutation, and ties keep run-a entries first
        assert sorted(np.concatenate([np.asarray(pos_a), np.asarray(pos_b)]).tolist()) \
            == list(range(na + nb))


def test_compact_valid_matches_stable_argsort_reference():
    rng = np.random.default_rng(3)
    for n in (8, 100, 257):
        valid = jnp.asarray(rng.uniform(size=n) < 0.6)
        vals = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
        fvals = jnp.asarray(rng.normal(size=n), jnp.float32)
        got_i, got_f = compact_valid(valid, vals, fvals,
                                     fills=(EMPTY, jnp.float32(jnp.inf)))
        # historical form: stable argsort on ~valid, then fill the tail
        order = np.argsort(~np.asarray(valid), kind="stable")
        v = np.asarray(valid)[order]
        ref_i = np.where(v, np.asarray(vals)[order], int(EMPTY))
        ref_f = np.where(v, np.asarray(fvals)[order], np.inf)
        np.testing.assert_array_equal(np.asarray(got_i), ref_i)
        np.testing.assert_array_equal(np.asarray(got_f), ref_f)


def test_evict_threshold_selection_routes_agree():
    """tau* from lax.top_k == rank-select == the full descending sort, and
    the whole evicted table agrees bitwise (max_evict both bounded and
    None) — the selection is one order statistic however it is lowered."""
    rng = np.random.default_rng(4)
    cap, k = 256, 64
    for trial in range(5):
        n_valid = int(rng.integers(k + 1, cap))
        keys = np.full(cap, int(EMPTY), np.int32)
        keys[:n_valid] = np.sort(rng.choice(10**6, n_valid, replace=False)).astype(np.int32)
        counts = np.where(keys != int(EMPTY),
                          rng.exponential(5.0, cap).astype(np.float32), 0.0)
        kb = np.where(keys != int(EMPTY),
                      rng.uniform(0, 0.3, cap).astype(np.float32), np.inf)
        seed = np.where(keys != int(EMPTY),
                        rng.uniform(0, 1, cap).astype(np.float32), np.inf)
        for tau in (np.inf, 0.5, 0.01):
            args = (jnp.asarray(keys), jnp.asarray(counts, jnp.float32),
                    jnp.asarray(kb, jnp.float32), jnp.asarray(seed, jnp.float32),
                    jnp.float32(tau), k, jnp.float32(8.0), jnp.uint32(9),
                    jnp.int32(trial + 1))
            ref = V._evict_to_k_ref(*args)
            for me in (None, cap - k):
                for select in ("auto", "topk", "rank"):
                    got = V._evict_to_k(*args, max_evict=me, select=select)
                    for g, r in zip(got, ref):
                        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_kth_smallest_matches_sort():
    """Rank selection == np.sort order statistic, incl. infinities, ties and
    a traced rank."""
    rng = np.random.default_rng(44)
    for n, r in [(1, 0), (7, 3), (100, 0), (100, 99), (513, 200), (4096, 2048)]:
        x = rng.normal(size=n).astype(np.float32)
        if n > 8:
            x[rng.integers(0, n, 3)] = np.inf
            x[rng.integers(0, n, 2)] = -np.inf
            x[rng.integers(0, n, 2)] = x[0]  # duplicates
        got = jax.jit(kth_smallest)(jnp.asarray(x), jnp.int32(r))
        assert np.asarray(got) == np.sort(x)[r], (n, r)


# ---------------------------------------------------------------------------
# restructured chunk steps == pre-restructure reference, bit for bit
# ---------------------------------------------------------------------------


def _extract(table):
    """Order-independent table content: (sorted keys, their counts/kb/seed, tau)."""
    keys = np.asarray(table.keys)
    valid = keys != int(EMPTY)
    order = np.argsort(keys[valid], kind="stable")
    return (keys[valid][order], np.asarray(table.counts)[valid][order],
            np.asarray(table.kb)[valid][order],
            np.asarray(table.seed)[valid][order], float(table.tau))


def _assert_tables_equal(a, b):
    ka, ca, kba, sda, ta = _extract(a)
    kb_, cb, kbb, sdb, tb = _extract(b)
    np.testing.assert_array_equal(ka, kb_)
    np.testing.assert_array_equal(ca, cb)   # bitwise: same reductions, same order
    np.testing.assert_array_equal(kba, kbb)
    np.testing.assert_array_equal(sda, sdb)
    assert ta == tb


def _assert_sorted_invariant(table):
    keys = np.asarray(table.keys)
    n_valid = int((keys != int(EMPTY)).sum())
    assert (keys[n_valid:] == int(EMPTY)).all(), "EMPTY not compacted to back"
    assert (np.diff(keys[:n_valid]) > 0).all(), "keys not strictly ascending"


@pytest.mark.parametrize("chunk,k,l", [(64, 16, 0.5), (256, 32, 16.0), (128, 64, 5.0)])
def test_fixed_k_step_bit_identity_vs_reference(chunk, k, l):
    keys, w = _stream(n=chunk * 12, seed=chunk + k)
    new = V.init_table(k + chunk)
    ref = V.init_table(k + chunk)
    for i in range(12):
        ck = jnp.asarray(keys[i * chunk:(i + 1) * chunk], jnp.int32)
        cw = jnp.asarray(w[i * chunk:(i + 1) * chunk])
        eids = jnp.arange(i * chunk, (i + 1) * chunk, dtype=jnp.int32)
        score, delta, entry, kb = jax.tree.map(
            lambda x: x[0],
            capscore_multi(ck, eids, cw, jnp.asarray([l], jnp.float32),
                           ref.tau[None], jnp.uint32(3)))
        new = V.fixed_k_step(new, ck, cw, eids, jnp.float32(l), jnp.uint32(3), k=k)
        ref = V.fixed_k_step_scored_ref(ref, ck, cw, score, delta, entry, kb,
                                        k=k, l=jnp.float32(l), salt=jnp.uint32(3))
        _assert_tables_equal(new, ref)
        _assert_sorted_invariant(new)


def test_fixed_k_step_tau_inf_edge():
    """Stream smaller than k: tau stays inf, nothing ever evicts, and the
    sorted path still matches the reference merge exactly."""
    rng = np.random.default_rng(8)
    chunk, k = 64, 512
    new = V.init_table(k + chunk)
    ref = V.init_table(k + chunk)
    for i in range(6):
        ck = jnp.asarray(rng.integers(0, 40, chunk), jnp.int32)
        cw = jnp.ones(chunk, jnp.float32)
        eids = jnp.arange(i * chunk, (i + 1) * chunk, dtype=jnp.int32)
        agg = V.aggregate_continuous_ref(ck, cw, eids, ref.tau, jnp.float32(4.0),
                                         jnp.uint32(1))
        keys_c, counts_c, kb_c, seed_c, _ = V._merge_table(ref, agg)
        cap = ref.keys.shape[0]
        keys_e, counts_e, kb_e, seed_e, tau_e = V._evict_to_k_ref(
            keys_c[:cap], counts_c[:cap], kb_c[:cap], seed_c[:cap],
            ref.tau, k, jnp.float32(4.0), jnp.uint32(1), ref.step + 1)
        ref = V.TableState(keys_e, counts_e, kb_e, seed_e, tau_e,
                           ref.step + 1, ref.overflow)
        new = V.fixed_k_step(new, ck, cw, eids, jnp.float32(4.0), jnp.uint32(1), k=k)
        assert float(new.tau) == math.inf
        _assert_tables_equal(new, ref)


@pytest.mark.parametrize("kind", ["continuous", "discrete", "distinct", "sh"])
def test_fixed_tau_step_bit_identity_vs_reference(kind):
    keys, w = _stream(n=4096, seed=17)
    l = {"continuous": 5.0, "discrete": 5.0, "distinct": 1.0, "sh": 1e9}[kind]
    chunk, capacity = 256, 4096
    new = V.init_table(capacity, 0.05)
    ref = V.init_table(capacity, 0.05)
    for i in range(16):
        ck = jnp.asarray(keys[i * chunk:(i + 1) * chunk], jnp.int32)
        cw = jnp.asarray(w[i * chunk:(i + 1) * chunk])
        eids = jnp.arange(i * chunk, (i + 1) * chunk, dtype=jnp.int32)
        # reference: verbatim pre-PR aggregate + legacy concat-and-sort merge
        if kind == "continuous":
            agg = V.aggregate_continuous_ref(ck, cw, eids, ref.tau,
                                             jnp.float32(l), jnp.uint32(5))
        else:
            agg = V.aggregate_discrete_ref(ck, cw, eids, ref.tau, kind,
                                           jnp.float32(l), jnp.uint32(5))
        keys_c, counts_c, kb_c, seed_c, n_valid = V._merge_table(ref, agg)
        over = ref.overflow + jnp.maximum(n_valid - capacity, 0)
        ref = V.TableState(keys_c[:capacity], counts_c[:capacity],
                           kb_c[:capacity], seed_c[:capacity],
                           ref.tau, ref.step + 1, over)
        new = V.fixed_tau_step(new, ck, cw, eids, jnp.float32(l), jnp.uint32(5),
                               kind=kind)
        _assert_tables_equal(new, ref)
        _assert_sorted_invariant(new)


def test_merge_sorted_runs_gather_out_len_prefix():
    """Truncated interleave == the first out_len slots of the full merge."""
    rng = np.random.default_rng(21)
    for na, nb, ol in [(16, 16, 8), (128, 32, 128), (5, 200, 60), (64, 64, 128)]:
        a = np.sort(rng.integers(0, 300, na)).astype(np.int32)
        b = np.sort(rng.integers(0, 300, nb)).astype(np.int32)
        concat = np.concatenate([a, b])
        ref = concat[np.argsort(concat, kind="stable")]
        for out_len in (None, ol):
            fb, ia, ib = merge_sorted_runs_gather(jnp.asarray(a), jnp.asarray(b),
                                                  out_len)
            merged = np.where(np.asarray(fb), b[np.asarray(ib)], a[np.asarray(ia)])
            np.testing.assert_array_equal(merged, ref[: len(merged)])


# ---------------------------------------------------------------------------
# score-in-key-order: covariance, the fused aggregate, ordered fixed-tau
# ---------------------------------------------------------------------------


def test_element_scoring_permutation_covariance():
    """The keystone of ordered scoring: element randomness hangs off the
    (key, eid, weight) VALUES, so scoring a permuted chunk with permuted
    eids equals permuting the scores — bitwise, for every lane and output."""
    rng = np.random.default_rng(23)
    C, L = 1024, 5
    keys = jnp.asarray(rng.integers(0, 200, C), jnp.int32)
    eids = jnp.asarray(rng.permutation(C * 7)[:C], jnp.int32)
    w = jnp.asarray(rng.exponential(1.0, C) + 0.1, jnp.float32)
    ls = jnp.asarray(np.geomspace(1.0, 16.0, L), jnp.float32)
    taus = jnp.asarray(rng.uniform(0.05, 2.0, L), jnp.float32)
    perm = jnp.asarray(rng.permutation(C))
    base = capscore_multi(keys, eids, w, ls, taus, jnp.uint32(9))
    permuted = capscore_multi(keys[perm], eids[perm], w[perm], ls, taus,
                              jnp.uint32(9))
    for b, p in zip(base, permuted):
        np.testing.assert_array_equal(np.asarray(b)[:, np.asarray(perm)],
                                      np.asarray(p))


def _agg_via_gather_path(keys, eids, w, ls, taus, salt, order):
    """The score-then-gather-then-reduce chain the fused op replaces."""
    score, delta, entry, kb = capscore_multi(keys, eids, w, ls, taus, salt)
    return jax.vmap(
        lambda s_, d_, e_, b_: V.aggregate_continuous_scored(
            keys, w, s_, d_, e_, b_, order)
    )(score, delta, entry, kb)


@pytest.mark.parametrize("C,n_keys,L", [(300, 40, 3), (1024, 5000, 1),
                                        (2048, 150, 8)])
def test_capscore_agg_xla_bit_identity(C, n_keys, L):
    """Fused score+aggregate == score, gather x4L, segment-reduce — bitwise,
    EMPTY padding and tau=inf lanes included."""
    rng = np.random.default_rng(C + L)
    keys = rng.integers(0, n_keys, C).astype(np.int32)
    keys[rng.uniform(size=C) < 0.2] = int(EMPTY)
    keys = jnp.asarray(keys)
    eids = jnp.asarray(rng.permutation(10 * C)[:C], jnp.int32)
    w = jnp.asarray(rng.exponential(1.0, C) + 0.1, jnp.float32)
    ls = jnp.asarray(np.geomspace(1.0, 2.0 ** (L - 1), L), jnp.float32)
    taus = jnp.asarray(rng.uniform(0.05, 2.0, L), jnp.float32)
    taus = taus.at[0].set(jnp.inf)  # tau=inf lane rides along
    salt = jnp.uint32(7)
    order = chunk_order(keys, eids, w)
    w_total, entered, contrib, kb_min, min_score = capscore_agg(
        order.ks, order.eids, order.ws, order.seg, ls, taus, salt,
        backend="xla")
    ref = _agg_via_gather_path(keys, eids, w, ls, taus, salt, order)
    np.testing.assert_array_equal(np.asarray(order.ukeys), np.asarray(ref.ukeys[0]))
    np.testing.assert_array_equal(np.asarray(w_total), np.asarray(ref.w_total[0]))
    np.testing.assert_array_equal(np.asarray(entered), np.asarray(ref.entered))
    np.testing.assert_array_equal(np.asarray(contrib), np.asarray(ref.contrib))
    np.testing.assert_array_equal(np.asarray(kb_min), np.asarray(ref.kb))
    np.testing.assert_array_equal(np.asarray(min_score), np.asarray(ref.min_score))


def test_capscore_agg_pallas_matches_xla():
    """The Pallas kernel (interpret mode on CPU) agrees with the XLA path:
    exactly on entered/min/max columns, to f32-reassociation on the sums
    (the in-block one-hot matmul reduces in a different order)."""
    rng = np.random.default_rng(31)
    for C, n_keys, n_l in [(300, 40, 3), (1024, 200, 1), (2048, 3000, 4)]:
        keys = rng.integers(0, n_keys, C).astype(np.int32)
        keys[rng.uniform(size=C) < 0.15] = int(EMPTY)
        w = jnp.asarray(rng.exponential(1.0, C) + 0.1, jnp.float32)
        eids = jnp.asarray(np.arange(C), jnp.int32)
        ls = jnp.asarray(np.geomspace(1.0, 8.0, n_l), jnp.float32)
        taus = jnp.asarray(rng.uniform(0.05, 2.0, n_l), jnp.float32)
        o = chunk_order(jnp.asarray(keys), eids, w)
        args = (o.ks, o.eids, o.ws, o.seg, ls, taus, jnp.uint32(7))
        ref = capscore_agg(*args, backend="xla")
        got = capscore_agg(*args, backend="pallas")
        for nm, g, r in zip(("w_total", "entered", "contrib", "kb", "min_score"),
                            got, ref):
            if nm in ("w_total", "contrib"):
                np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                           rtol=2e-6, atol=1e-6, err_msg=nm)
            else:
                np.testing.assert_array_equal(np.asarray(g), np.asarray(r), nm)


@pytest.mark.parametrize("kind", ["continuous", "discrete", "distinct", "sh"])
def test_ordered_discrete_continuous_aggregates_match_ref(kind):
    """aggregate_continuous/_discrete on the pre-gathered view == the
    verbatim pre-ChunkOrder reducers, across kinds and chunk sizes."""
    rng = np.random.default_rng(57)
    l = {"continuous": 5.0, "discrete": 5.0, "distinct": 1.0, "sh": 1e9}[kind]
    for C in (64, 256, 1000):
        keys = rng.integers(0, max(8, C // 8), C).astype(np.int32)
        keys[rng.uniform(size=C) < 0.1] = int(EMPTY)
        keys = jnp.asarray(keys)
        w = jnp.asarray(rng.exponential(1.0, C) + 0.1, jnp.float32)
        eids = jnp.asarray(np.arange(C), jnp.int32)
        for tau in (jnp.float32(jnp.inf), jnp.float32(0.2)):
            order = chunk_order(keys, eids, w)
            if kind == "continuous":
                got = V.aggregate_continuous(keys, w, eids, tau, jnp.float32(l),
                                             jnp.uint32(5), order)
                ref = V.aggregate_continuous_ref(keys, w, eids, tau,
                                                 jnp.float32(l), jnp.uint32(5))
            else:
                got = V.aggregate_discrete(keys, w, eids, tau, kind,
                                           jnp.float32(l), jnp.uint32(5), order)
                ref = V.aggregate_discrete_ref(keys, w, eids, tau, kind,
                                               jnp.float32(l), jnp.uint32(5))
            for g, r in zip(got, ref):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_pass1_fold_keysorted_matches_seed_sorted_merge():
    """The key-sorted summary carry == iterated merge_bottomk_summary after
    conversion, chunk by chunk (the in-scan form of the §3.1 losslessness).

    Scores are coarsely quantized, so seeds TIE at the bottom-cap threshold
    constantly — pinning the fold's tie-break (every seed strictly below the
    threshold survives; the remaining quota goes to tied entries
    smallest-key-first) to ``bottom_k_by``'s exact semantics."""
    rng = np.random.default_rng(71)
    C, cap, rounds = 512, 129, 18
    sk = jnp.full((cap,), EMPTY, jnp.int32)
    ss = jnp.full((cap,), jnp.inf, jnp.float32)
    kk, vv = V.summary_to_keysorted(sk, ss)
    for t in range(rounds):
        keys = jnp.asarray(rng.integers(0, 300 if t % 2 else 2**30, C), jnp.int32)
        scores = jnp.asarray(
            np.round(rng.uniform(0, 1, C), [2, 1, 3][t % 3]).astype(np.float32))
        order = chunk_order(keys)
        live = order.ks != EMPTY
        mins = jax.ops.segment_min(
            jnp.where(live, scores[order.perm], jnp.float32(jnp.inf)),
            order.seg, num_segments=C)
        mins = jnp.where(order.ukeys != EMPTY, mins, jnp.inf)
        sk, ss = V.merge_bottomk_summary(sk, ss, order.ukeys, mins, cap)
        kk, vv = V.pass1_fold_keysorted(kk, vv, order.ukeys, mins, cap)
        got_k, got_s = V.summary_from_keysorted(kk, vv, cap)
        np.testing.assert_array_equal(np.asarray(got_k), np.asarray(sk))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(ss))


@pytest.mark.parametrize("L,chunk", [(1, 1024), (4, 1024), (8, 256)])
def test_update_multi_bit_identity_vs_reference_path(L, chunk):
    keys, w = _stream(n=chunk * 10, seed=100 + L)
    ls = tuple(float(2.0 ** j) for j in range(L))
    st_new, spec = I.init_multi_state(ls, k=128, chunk=chunk, salt=11)
    st_ref, _ = I.init_multi_state(ls, k=128, chunk=chunk, salt=11)
    kk = keys.astype(np.int32)
    st_new = I.update_multi(st_new, kk, w, spec, donate=False)
    st_ref = I.update_multi(st_ref, kk, w, spec, donate=False, reference=True)
    # identical per-lane samples, thresholds, and lossless summaries
    rn = I.finalize_multi(st_new, spec, ls=ls)
    rr = I.finalize_multi(st_ref, spec, ls=ls)
    for l in ls:
        np.testing.assert_array_equal(rn[l].keys, rr[l].keys)
        np.testing.assert_array_equal(rn[l].counts, rr[l].counts)
        assert rn[l].tau == rr[l].tau
    np.testing.assert_array_equal(np.asarray(st_new.bk_keys), np.asarray(st_ref.bk_keys))
    np.testing.assert_array_equal(np.asarray(st_new.bk_seeds), np.asarray(st_ref.bk_seeds))


# ---------------------------------------------------------------------------
# amortized eviction (evict_every = E > 1)
# ---------------------------------------------------------------------------


def test_evict_every_capacity_and_schedule():
    keys, w = _stream(n=8192, seed=31)
    E, k, chunk = 4, 64, 512
    s = I.IncrementalSampler(8.0, k=k, chunk=chunk, salt=2, evict_every=E)
    assert s.state.capacity == k + E * chunk
    per_chunk_valid = []
    for i in range(0, len(keys), chunk):
        s.observe(keys[i:i + chunk], w[i:i + chunk])
        per_chunk_valid.append(int((np.asarray(s.state.table.keys) != int(EMPTY)).sum()))
    # between scheduled evictions the table legitimately exceeds k...
    assert max(per_chunk_valid) > k
    assert max(per_chunk_valid) <= k + E * chunk
    # ...and right after each E-th chunk it is back to <= k
    assert all(v <= k for v in per_chunk_valid[E - 1::E])
    # finalize projects down to a valid fixed-k sample, repeatably
    r1, r2 = s.finalize(), s.finalize()
    assert len(r1.keys) <= k
    np.testing.assert_array_equal(r1.keys, r2.keys)
    np.testing.assert_array_equal(r1.counts, r2.counts)


def test_evict_every_multi_matches_capacity_contract():
    keys, _ = _stream(n=6144, seed=32)
    m = I.MultiSampler((1.0, 16.0), k=64, chunk=512, salt=3, evict_every=3)
    m.observe(keys)
    res = m.finalize()
    for l, r in res.items():
        assert len(r.keys) <= 64, (l, len(r.keys))
    # summaries are eviction-independent: identical to an E=1 run
    m1 = I.MultiSampler((1.0, 16.0), k=64, chunk=512, salt=3, evict_every=1)
    m1.observe(keys)
    bkE, bsE = m.bottomk_summaries()
    bk1, bs1 = m1.bottomk_summaries()
    np.testing.assert_array_equal(bkE, bk1)
    np.testing.assert_array_equal(bsE, bs1)


def test_load_state_dict_rejects_capacity_mismatch():
    """A blob written under a different evict_every (hence table capacity)
    must refuse to load: silently truncated merges / overflowed top_k windows
    would corrupt the sample with no error."""
    keys, _ = _stream(n=2048, seed=33)
    m1 = I.MultiSampler((1.0, 16.0), k=64, chunk=512, salt=4, evict_every=1)
    m1.observe(keys)
    blob = m1.state_dict()
    m4 = I.MultiSampler((1.0, 16.0), k=64, chunk=512, salt=4, evict_every=4)
    with pytest.raises(ValueError, match="capacity"):
        m4.load_state_dict(blob)


def _ks_uniform(us):
    us = np.sort(np.asarray(us))
    n = len(us)
    grid = np.arange(1, n + 1) / n
    return max(np.max(np.abs(grid - us)), np.max(np.abs(us - (grid - 1.0 / n))))


def test_evict_every_unbiased_and_count_law(zipf_stream):
    """E>1 changes the eviction randomness *schedule*, not the sampling law:
    cap estimates stay unbiased (MC over salts) and sampled counts follow the
    Thm 5.2 conditional law (PIT + KS), exactly like the E=1 path."""
    ukeys, cnts = np.unique(zipf_stream, return_counts=True)
    wmap = dict(zip(ukeys.tolist(), cnts.tolist()))
    truth = F.exact_statistic(F.cap(5), cnts)
    top = [int(x) for x in ukeys[np.argsort(-cnts)[:30]]]
    l, k, period = 5.0, 100, 3
    rate_pit, ests = [], []
    for r in range(120):
        s = I.IncrementalSampler(l, k=k, chunk=1024, salt=95000 + r,
                                 evict_every=period)
        s.observe(zipf_stream)
        res = s.finalize()
        assert len(res.keys) <= k
        ests.append(EST.estimate(res, F.cap(5)))
        rate = max(1.0 / l, res.tau)
        d = res.asdict()
        for x in top:
            if x in d:
                w = wmap[x]
                phi = w - d[x]
                u = -np.expm1(-rate * phi) / -np.expm1(-rate * w)
                rate_pit.append(min(max(u, 0.0), 1.0))
    m, se = np.mean(ests), np.std(ests) / math.sqrt(len(ests))
    assert abs(m - truth) < 4 * se + 0.001 * truth, \
        f"bias {(m-truth)/truth:+.2%} se {se/truth:.2%}"
    assert len(rate_pit) > 300
    assert _ks_uniform(rate_pit) < 2.2 / math.sqrt(len(rate_pit)), \
        f"KS {_ks_uniform(rate_pit):.3f} n={len(rate_pit)}"


# ---------------------------------------------------------------------------
# satellites: one-shot key validation, interpret default
# ---------------------------------------------------------------------------


def test_one_shot_samplers_validate_keys():
    for call in (
        lambda ks: V.sample_fixed_k(ks, None, k=8, l=2.0, chunk=64),
        lambda ks: V.sample_fixed_tau(ks, None, tau=0.5, l=2.0, chunk=64),
        lambda ks: V.sample_two_pass(ks, None, k=8, l=2.0, chunk=64),
    ):
        with pytest.raises(TypeError, match="integers"):
            call(np.asarray([1.5, 2.0]))
        with pytest.raises(ValueError, match="int32 range"):
            call(np.asarray([2**40, 3], np.int64))
        with pytest.raises(ValueError, match="EMPTY"):
            call(np.asarray([int(EMPTY)], np.int64))
    # valid int64 ids keep working
    res = V.sample_fixed_k(np.asarray([1, 2, 3, 1], np.int64), None, k=8,
                           l=2.0, chunk=64)
    assert set(res.keys.tolist()) <= {1, 2, 3}


def test_pad_tile_padded_vs_aligned_bit_identical():
    """The shared kernel pad helper: a non-aligned chunk scored through the
    padded kernel slices bit-identically to the aligned prefix computation,
    and aligned inputs pass through without any concatenate."""
    rng = np.random.default_rng(91)
    n = 1000  # not a multiple of the 1024 kernel tile
    keys = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    eids = jnp.asarray(np.arange(n), jnp.int32)
    w = jnp.asarray(rng.exponential(1.0, n) + 0.1, jnp.float32)
    # aligned reference: compute on a 1024-aligned superset, slice to n
    keys_al = jnp.concatenate([keys, jnp.zeros((24,), jnp.int32)])
    eids_al = jnp.concatenate([eids, jnp.zeros((24,), jnp.int32)])
    w_al = jnp.concatenate([w, jnp.ones((24,), jnp.float32)])
    for backend in ("xla", "pallas"):
        got = capscore(keys, eids, w, 4.0, 0.3, 3, backend=backend)
        ref = capscore(keys_al, eids_al, w_al, 4.0, 0.3, 3, backend=backend)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r)[:n])
    # aligned input: helper is a no-op passthrough (same objects, pad=0)
    out = _pad_tile(1024, (keys_al, 0), (w_al, 1.0))
    assert out[-1] == 0 and out[0] is keys_al and out[1] is w_al
    # padded: fills applied, arrays extended to the tile
    k2, w2, pad = _pad_tile(1024, (keys, int(EMPTY)), (w, 0.0))
    assert pad == 24 and k2.shape[0] == 1024
    assert (np.asarray(k2[-24:]) == int(EMPTY)).all()
    assert (np.asarray(w2[-24:]) == 0.0).all()


def test_update_multi_tau_inf_edge():
    """Stream smaller than k: tau stays inf in every lane, nothing evicts,
    and the fused path still matches the reference bit for bit."""
    rng = np.random.default_rng(92)
    ls = (1.0, 8.0)
    st_new, spec = I.init_multi_state(ls, k=512, chunk=256, salt=13)
    st_ref, _ = I.init_multi_state(ls, k=512, chunk=256, salt=13)
    keys = rng.integers(0, 60, 1024).astype(np.int32)
    w = np.ones(1024, np.float32)
    st_new = I.update_multi(st_new, keys, w, spec, donate=False)
    st_ref = I.update_multi(st_ref, keys, w, spec, donate=False, reference=True)
    assert np.isinf(np.asarray(st_new.table.tau)).all()
    rn = I.finalize_multi(st_new, spec, ls=ls)
    rr = I.finalize_multi(st_ref, spec, ls=ls)
    for l in ls:
        np.testing.assert_array_equal(rn[l].keys, rr[l].keys)
        np.testing.assert_array_equal(rn[l].counts, rr[l].counts)
        assert rn[l].tau == rr[l].tau == math.inf
    np.testing.assert_array_equal(np.asarray(st_new.bk_keys), np.asarray(st_ref.bk_keys))
    np.testing.assert_array_equal(np.asarray(st_new.bk_seeds), np.asarray(st_ref.bk_seeds))


def test_default_interpret_backend_and_env(monkeypatch):
    from repro.kernels.capscore import capscore as K

    monkeypatch.delenv(K._INTERPRET_ENV, raising=False)
    # this suite runs on CPU: auto must pick interpret mode
    assert jax.default_backend() != "tpu"
    assert K.default_interpret() is True
    monkeypatch.setenv(K._INTERPRET_ENV, "0")
    assert K.default_interpret() is False
    monkeypatch.setenv(K._INTERPRET_ENV, "1")
    assert K.default_interpret() is True
