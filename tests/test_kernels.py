"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
with hypothesis shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.capscore.ops import capscore
from repro.kernels.capscore.ref import capscore_ref
from repro.kernels.embedding_bag.ops import embedding_bag, segment_sum
from repro.kernels.embedding_bag.ref import embedding_bag_ref, segment_sum_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import xla_chunked_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# capscore
# ---------------------------------------------------------------------------


@given(
    n=st.integers(min_value=1, max_value=4000),
    l=st.floats(min_value=0.2, max_value=1000.0),
    tau=st.floats(min_value=1e-4, max_value=0.99),
    salt=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_capscore_matches_ref(n, l, tau, salt):
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 1 << 30, n), jnp.int32)
    eids = jnp.arange(n, dtype=jnp.int32)
    w = jnp.asarray(rng.exponential(2.0, n) + 0.05, jnp.float32)
    s1, d1, e1 = capscore(keys, eids, w, l, tau, salt, backend="pallas")
    s2, d2, e2 = capscore_ref(keys, eids, w, l, tau, jnp.uint32(salt))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_capscore_matches_sampler_scores():
    """The kernel reproduces core.vectorized element scores bit-for-bit, so
    the sampler can swap it in on TPU with identical samples."""
    from repro.core import vectorized as V

    n = 2048
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    eids = jnp.arange(n, dtype=jnp.int32)
    w = jnp.ones(n, jnp.float32)
    s1, _, _ = capscore(keys, eids, w, 5.0, 0.3, 9, backend="pallas")
    s2 = V.element_scores("continuous", keys, eids, w, jnp.float32(5.0), jnp.uint32(9))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_gqa(hq, hkv, causal):
    rng = np.random.default_rng(0)
    B, S, D = 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, hkv, S, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@given(
    s=st.sampled_from([128, 256, 384]),
    d=st.sampled_from([32, 64, 128]),
    bq=st.sampled_from([64, 128]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_shapes_dtypes(s, d, bq, dtype):
    if s % bq:
        return
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_xla_chunked_matches_naive():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 256, 64)), jnp.float32)
    out = xla_chunked_attention(q, k, v, causal=True, chunk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_xla_chunked_is_differentiable():
    import jax

    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    g = jax.grad(lambda q_: jnp.sum(xla_chunked_attention(q_, k, v, chunk=64) ** 2))(q)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# segment_sum / embedding_bag
# ---------------------------------------------------------------------------


@given(
    n=st.integers(min_value=1, max_value=2000),
    d=st.sampled_from([8, 64, 256]),
    s=st.sampled_from([4, 128, 1024]),
)
@settings(max_examples=12, deadline=None)
def test_segment_sum_matches_ref(n, d, s):
    rng = np.random.default_rng(n + d)
    vals = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    segs = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    out = segment_sum(vals, segs, n_segments=s, backend="pallas")
    ref = segment_sum_ref(vals, segs, n_segments=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_segment_sum_unsorted_and_empty_segments():
    vals = jnp.ones((512, 16), jnp.float32)
    segs = jnp.asarray(np.tile([7, 3, 7, 0], 128), jnp.int32)
    out = np.asarray(segment_sum(vals, segs, n_segments=10, backend="pallas"))
    assert out[7, 0] == 256 and out[3, 0] == 128 and out[0, 0] == 128
    assert np.all(out[[1, 2, 4, 5, 6, 8, 9]] == 0)


@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag(mode):
    rng = np.random.default_rng(9)
    V, D, B, bag = 1000, 32, 64, 5
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    ids = rng.integers(0, V, size=B * bag)
    ids[::7] = -1  # padding entries
    segs = np.repeat(np.arange(B), bag)
    out = embedding_bag(
        table, jnp.asarray(ids, jnp.int32), jnp.asarray(segs, jnp.int32),
        n_bags=B, mode=mode, backend="pallas",
    )
    ref = embedding_bag_ref(table, jnp.asarray(ids), jnp.asarray(segs), n_bags=B, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
