"""Test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must see
exactly 1 CPU device (the 512-device mesh lives only in launch/dryrun.py and
subprocess-based distributed tests)."""
import sys

import numpy as np
import pytest

try:  # pragma: no cover - exercised implicitly at collection
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Containers without hypothesis still run the suite: register the
    # deterministic stub (see tests/_hypothesis_stub.py) before any test
    # module does `from hypothesis import given`.
    import importlib.util
    from pathlib import Path

    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub", Path(__file__).parent / "_hypothesis_stub.py"
    )
    _hypothesis_stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_hypothesis_stub)

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


@pytest.fixture(scope="session")
def zipf_stream():
    """A deterministic Zipf(1.5) stream of 20k elements (paper §7 setup)."""
    rng = np.random.default_rng(1)
    keys = (rng.zipf(1.5, size=20000) % 5000).astype(np.int64)
    return keys


@pytest.fixture(scope="session")
def zipf_truth(zipf_stream):
    ukeys, cnts = np.unique(zipf_stream, return_counts=True)
    return ukeys, cnts
