"""Test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must see
exactly 1 CPU device (the 512-device mesh lives only in launch/dryrun.py and
subprocess-based distributed tests)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def zipf_stream():
    """A deterministic Zipf(1.5) stream of 20k elements (paper §7 setup)."""
    rng = np.random.default_rng(1)
    keys = (rng.zipf(1.5, size=20000) % 5000).astype(np.int64)
    return keys


@pytest.fixture(scope="session")
def zipf_truth(zipf_stream):
    ukeys, cnts = np.unique(zipf_stream, return_counts=True)
    return ukeys, cnts
