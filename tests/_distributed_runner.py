"""Subprocess body for distributed sampler tests.

Run as: python tests/_distributed_runner.py [ndev]
(default 8 host devices; 3 / 6 exercise the non-power-of-two butterfly
fallback).  Prints "OK" on success; assertion errors otherwise.
"""
import os
import sys

NDEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={NDEV}"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed as DD  # noqa: E402
from repro.core import vectorized as V  # noqa: E402
from repro.core.samplers import shard_eids_np  # noqa: E402
from repro.core.segments import EMPTY  # noqa: E402

EMPTY = int(EMPTY)


def _make_mesh():
    try:  # AxisType landed after jax 0.4; default axis types are equivalent
        from jax.sharding import AxisType

        return jax.make_mesh((NDEV,), ("data",), axis_types=(AxisType.Auto,))
    except ImportError:
        return jax.make_mesh((NDEV,), ("data",))


def _reference(keys, w, l, salt):
    """Per-key (min seed, total weight) with the device's shard-hashed eids.

    Scores via the device scorer (V.element_scores, float32) so key sets and
    thresholds are bit-comparable with the shard_map program.
    """
    shard_len = len(keys) // NDEV
    ref_seeds, ref_w = {}, {}
    for s in range(NDEV):
        sk = keys[s * shard_len:(s + 1) * shard_len]
        sw = w[s * shard_len:(s + 1) * shard_len]
        eids = shard_eids_np(s, np.arange(shard_len)).astype(np.int32)
        sc = np.asarray(V.element_scores(
            "continuous", jnp.asarray(sk), jnp.asarray(eids),
            jnp.asarray(sw), jnp.float32(l), jnp.uint32(salt)))
        for key_, s_, w_ in zip(sk.tolist(), sc.tolist(), sw.tolist()):
            ref_seeds[key_] = min(ref_seeds.get(key_, np.inf), s_)
            ref_w[key_] = ref_w.get(key_, 0.0) + w_
    return ref_seeds, ref_w


def _check_lane(skeys, sw, ref_seeds, ref_w, k, label):
    ref_sorted = sorted(ref_seeds.items(), key=lambda kv: kv[1])[: k + 1]
    ref_keys = sorted(k_ for k_, _ in ref_sorted)
    got = sorted(int(x) for x in skeys if x != EMPTY)
    assert got == ref_keys, f"{label}: key sets differ: {got[:5]} vs {ref_keys[:5]}"
    key_order = {int(x): i for i, x in enumerate(skeys.tolist())}
    for key_ in ref_keys:
        np.testing.assert_allclose(sw[key_order[key_]], ref_w[key_], rtol=1e-3)


def main():
    assert len(jax.devices()) == NDEV
    mesh = _make_mesh()

    rng = np.random.default_rng(0)
    n = NDEV * 2048
    keys = (rng.zipf(1.4, size=n) % 3000).astype(np.int32)
    w = np.ones(n, dtype=np.float32)
    k = 64
    salt, l = 9, 5.0

    ref_seeds, ref_w = _reference(keys, w, l, salt)

    # single-l program, both merge topologies (tree falls back to all_gather
    # for non-power-of-two NDEV — same result either way)
    for merge in ("tree", "allgather"):
        fn = DD.make_distributed_two_pass(
            mesh, kind="continuous", l=l, salt=salt, k=k, chunk=512, merge=merge
        )
        skeys, _, sw = (np.asarray(a)[0] for a in fn(keys, w))
        _check_lane(skeys, sw, ref_seeds, ref_w, k, f"single-l merge={merge}")
        print(f"merge={merge} OK")

    # multi-l program: the whole grid in one launch (fused capscore scoring)
    ls = (2.0, 5.0, 64.0)
    fn = DD.make_distributed_two_pass_multi(
        mesh, ls=ls, salt=salt, k=k, chunk=512, merge="tree")
    mkeys, _, mw = (np.asarray(a)[0] for a in fn(keys, w))
    assert mkeys.shape == (len(ls), k + 1), mkeys.shape
    for j, lj in enumerate(ls):
        rs, rw = (ref_seeds, ref_w) if lj == l else _reference(keys, w, lj, salt)
        _check_lane(mkeys[j], mw[j], rs, rw, k, f"multi-l l={lj}")
    # lane scored at the single-l program's l must agree with it exactly
    print("multi-l OK")

    print("OK")


if __name__ == "__main__":
    main()
