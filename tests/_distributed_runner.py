"""Subprocess body for distributed sampler tests (8 host devices).

Run as: python tests/_distributed_runner.py
Prints "OK" on success; assertion errors otherwise.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed as DD  # noqa: E402
from repro.core import vectorized as V  # noqa: E402


def _make_mesh():
    try:  # AxisType landed after jax 0.4; default axis types are equivalent
        from jax.sharding import AxisType

        return jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    except ImportError:
        return jax.make_mesh((8,), ("data",))


def main():
    assert len(jax.devices()) == 8
    mesh = _make_mesh()

    rng = np.random.default_rng(0)
    n = 8 * 4096
    keys = (rng.zipf(1.4, size=n) % 3000).astype(np.int32)
    w = np.ones(n, dtype=np.float32)
    k = 64

    for merge in ("tree", "allgather"):
        fn = DD.make_distributed_two_pass(
            mesh, kind="continuous", l=5.0, salt=9, k=k, chunk=512, merge=merge
        )
        skeys, sseeds, sw = fn(keys, w)
        skeys = np.asarray(skeys)[0]
        sseeds = np.asarray(sseeds)[0]
        sw = np.asarray(sw)[0]
        # all shards agree (merged state is replicated)
        for i in range(1, 8):
            np.testing.assert_array_equal(np.asarray(skeys), np.asarray(jax.device_get(skeys)))

        # reference: single-stream 2-pass with the same sharded element ids
        ref_seeds = {}
        ref_w = {}
        shard_len = n // 8
        for s in range(8):
            shard_keys = keys[s * shard_len : (s + 1) * shard_len]
            shard_w = w[s * shard_len : (s + 1) * shard_len]
            eids = (s * shard_len + np.arange(shard_len)).astype(np.int64)
            from repro.core.samplers import continuous_score_np

            sc = continuous_score_np(shard_keys.astype(np.int64), eids, shard_w, 5.0, 9)
            for key_, s_, w_ in zip(shard_keys.tolist(), sc.tolist(), shard_w.tolist()):
                ref_seeds[key_] = min(ref_seeds.get(key_, np.inf), s_)
                ref_w[key_] = ref_w.get(key_, 0.0) + w_
        ref_sorted = sorted(ref_seeds.items(), key=lambda kv: kv[1])[: k + 1]
        ref_keys = sorted(k_ for k_, _ in ref_sorted)

        got = sorted(int(x) for x in skeys if x != 2**31 - 1)
        assert got == ref_keys, f"{merge}: key sets differ: {got[:5]} vs {ref_keys[:5]}"
        # exact weights
        key_order = {int(x): i for i, x in enumerate(skeys.tolist())}
        for key_ in ref_keys:
            np.testing.assert_allclose(sw[key_order[key_]], ref_w[key_], rtol=1e-3)
        print(f"merge={merge} OK")

    print("OK")


if __name__ == "__main__":
    main()
