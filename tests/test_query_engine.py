"""Batched query plane: bit-identity vs the scalar estimator loop, segment
semantics, key validation, variance/CI calibration, pick_l grid warning."""
import math
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import segments as SEG
from repro.core import vectorized as V
from repro.core.incremental import normalize_keys
from repro.stats.query import Query, QueryEngine
from repro.stats.service import StatsConfig, StreamStatsService

SEGMENTS = [None,
            lambda keys: keys % 3 == 0,
            np.arange(0, 5000, 11),       # id-list
            SEG.HashBucket(8, 3)]
FNS = [F.cap(5), F.cap(20), F.distinct(), F.total(), F.threshold(4.0),
       F.moment(1.5), F.log1p()]


@pytest.fixture(scope="module")
def lanes(zipf_stream):
    """One sketch per estimator path x scheme kind, plus the tau=inf edge."""
    s = zipf_stream
    return {
        # 2-pass (exact_weights) paths, every kind
        2.0: V.sample_two_pass(s, None, k=200, l=2.0, kind="continuous", salt=1),
        3.0: V.sample_two_pass(s, None, k=150, l=3.0, kind="discrete", salt=2),
        1.0: V.sample_two_pass(s, None, k=100, l=1, kind="distinct", salt=3),
        9.0: V.sample_two_pass(s, None, k=100, l=1e9, kind="sh", salt=4),
        # 1-pass paths: continuous coefficient form + discrete-spectrum tables
        5.0: V.sample_fixed_k(s, None, k=300, l=5.0, salt=5),
        7.0: V.sample_fixed_tau(s, None, tau=0.02, l=7, kind="discrete", salt=6),
        8.0: V.sample_fixed_tau(s, None, tau=0.05, l=1, kind="distinct", salt=7),
        6.0: V.sample_fixed_tau(s, None, tau=0.01, l=1e9, kind="sh", salt=8),
        # tau = inf: fewer than k+1 keys ever qualified
        4.0: V.sample_fixed_k(np.array([1, 1, 2, 3, 3, 3]), None, k=100,
                              l=5.0, salt=0, chunk=8),
    }


def test_query_batch_bit_identical_across_kinds(lanes):
    """The core contract: one 252-query mixed batch == the scalar loop,
    bit for bit, across 2-pass/1-pass x all kinds x segments x statistics
    (incl. the transcendental ones) and the tau=inf edge."""
    eng = QueryEngine(lanes)
    qs = [Query(fn, seg, l) for l in lanes for seg in SEGMENTS for fn in FNS]
    res = eng.query_batch(qs)
    for q, est in zip(qs, res.estimates):
        assert float(est) == E.estimate(lanes[q.l], q.fn, q.segment), \
            (q.fn.name, q.l, q.segment)
    # answers are stable across repeated batches (bank/plan caches)
    res2 = eng.query_batch(qs)
    np.testing.assert_array_equal(res.estimates, res2.estimates)


def test_query_batch_matches_singleton_batches(lanes):
    """Batching is pure vectorization: a 64-query batch == 64 one-query
    batches, bit for bit."""
    eng = QueryEngine(lanes)
    qs = [Query(fn, seg, l) for l in lanes for seg in SEGMENTS[:2]
          for fn in FNS[:4]][:64]
    big = eng.query_batch(qs)
    for i, q in enumerate(qs):
        one = eng.query_batch([q])
        assert float(one.estimates[0]) == float(big.estimates[i])


@pytest.fixture(scope="module")
def service(zipf_stream):
    svc = StreamStatsService(StatsConfig(k=512, ls=(1.0, 8.0, 64.0), chunk=1024))
    for i in range(0, len(zipf_stream), 7000):  # unaligned batches
        svc.observe(zipf_stream[i: i + 7000])
    return svc


def test_service_wrappers_bit_compatible(service):
    """query_cap/query_distinct/query_total are thin query_batch wrappers,
    bit-compatible with the scalar estimator on the picked lane."""
    sk = service.sketches()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for T in (1, 4, 8, 64):
            for seg in SEGMENTS:
                assert service.query_cap(T, seg) == E.estimate(
                    sk[service.pick_l(T)], F.cap(T), seg)
        assert service.query_distinct() == E.estimate(
            sk[service.pick_l(1.0)], F.distinct())
        assert service.query_total() == E.estimate(sk[64.0], F.total())


def test_service_exact_path_bit_identical(zipf_stream):
    """Exact (reconciled) query_batch == scalar loop over exact_sketches,
    and the jitted multi-lane pass II == the historical numpy accumulation."""
    svc = StreamStatsService(StatsConfig(k=256, ls=(1.0, 8.0), chunk=1024,
                                         host_id=0))
    svc.observe(zipf_stream)
    svc.reconcile(zipf_stream[:9000])
    svc.reconcile(zipf_stream[9000:])
    ek = svc.exact_sketches()
    qs = [Query(fn, seg) for fn in (F.cap(8), F.distinct(), F.total())
          for seg in SEGMENTS]
    res = svc.query_batch(qs, exact=True)
    for q, est in zip(qs, res.estimates):
        rq = svc._resolve_lane(q)
        assert float(est) == E.estimate(ek[rq.l], q.fn, q.segment)
    # jitted pass-II accumulators == np.searchsorted / np.add.at reference
    w = np.ones(len(zipf_stream), np.float64)
    k32 = zipf_stream.astype(np.int32)
    for lane in ek.values():
        ref = np.zeros(len(lane.keys), np.float64)
        loc = np.clip(np.searchsorted(lane.keys, k32), 0, len(lane.keys) - 1)
        m = lane.keys[loc] == k32
        np.add.at(ref, loc[m], w[m])
        np.testing.assert_array_equal(ref, lane.counts)


@settings(max_examples=12)
@given(T=st.floats(min_value=0.5, max_value=200),
       salt=st.integers(min_value=0, max_value=2**31 - 1),
       seg_mod=st.integers(min_value=1, max_value=7))
def test_property_engine_matches_scalar(zipf_stream, T, salt, seg_mod):
    """Property form of the contract on a fresh 1-pass sketch: arbitrary
    cap_T, salt and predicate segment."""
    res = V.sample_fixed_k(zipf_stream[:8192], None, k=128, l=8.0, salt=salt)
    eng = QueryEngine({8.0: res})
    seg = (lambda keys: keys % seg_mod == 0)
    batch = eng.query_batch([Query(F.cap(T), seg, 8.0),
                             Query(F.threshold(T), seg, 8.0)])
    assert float(batch.estimates[0]) == E.estimate(res, F.cap(T), seg)
    assert float(batch.estimates[1]) == E.estimate(res, F.threshold(T), seg)


def test_variance_ci_monte_carlo(zipf_stream, zipf_truth):
    """The HT plug-in variance must be calibrated: across independent
    sampler randomness the normal 95% CI covers the truth most of the time
    and the stderr tracks the empirical spread within a small factor."""
    _, cnts = zipf_truth
    truth = F.exact_statistic(F.cap(8), cnts)
    ests, covered, stderrs = [], 0, []
    reps = 40
    for r in range(reps):
        res = V.sample_fixed_k(zipf_stream, None, k=200, l=8.0, salt=900 + r)
        b = QueryEngine({8.0: res}).query_batch([Query(F.cap(8), None, 8.0)])
        ests.append(float(b.estimates[0]))
        stderrs.append(float(b.stderr[0]))
        covered += int(b.ci_low[0] <= truth <= b.ci_high[0])
    emp_sd = float(np.std(ests))
    med_se = float(np.median(stderrs))
    assert covered / reps >= 0.6, f"CI95 coverage {covered}/{reps}"
    assert med_se > 0
    assert 1 / 4 < med_se / emp_sd < 4, (med_se, emp_sd)


def test_exact_lane_variance_zero_when_everything_sampled():
    res = V.sample_fixed_k(np.array([1, 1, 2, 3]), None, k=64, l=2.0, chunk=8)
    assert math.isinf(res.tau)
    b = QueryEngine({2.0: res}).query_batch([Query(F.total(), None, 2.0)])
    assert float(b.variances[0]) == 0.0  # p = 1: the sample IS the data


# -- segment semantics (satellite: one Segment abstraction everywhere) -------


def test_segments_unified_across_surfaces(zipf_truth):
    ukeys, cnts = zipf_truth
    mask = ukeys % 5 == 0
    ids = ukeys[mask]
    pred = lambda keys: keys % 5 == 0
    ref = float(np.sum(np.minimum(cnts[mask], 7)))
    # exact_statistic: mask (historical), predicate, id-list, Segment
    assert F.exact_statistic(F.cap(7), cnts, mask) == pytest.approx(ref)
    for seg in (pred, ids, SEG.IdSet(ids), SEG.Predicate(pred)):
        assert F.exact_statistic(F.cap(7), cnts, seg, keys=ukeys) == pytest.approx(ref)
    # key-based segments need keys=
    with pytest.raises(ValueError, match="keys"):
        F.exact_statistic(F.cap(7), cnts, ids)
    # positional masks must match length
    with pytest.raises(ValueError, match="[Mm]ask"):
        SEG.Mask(mask[:10]).mask_np(ukeys)


def test_hash_bucket_segments_partition(lanes):
    """HashBucket segments partition every lane: bucket estimates sum to the
    all-keys estimate (same per-key values, disjoint masks)."""
    eng = QueryEngine(lanes)
    fn = F.cap(5)
    full = eng.query_batch([Query(fn, None, 5.0)]).estimates[0]
    parts = eng.query_batch(
        [Query(fn, SEG.HashBucket(4, b), 5.0) for b in range(4)]).estimates
    assert float(np.sum(parts)) == pytest.approx(float(full), rel=1e-12)


def test_adhoc_lane_key_differs_from_sketch_l(zipf_stream):
    """The dict key addressing a lane is just an address: the Thm 5.3
    coefficients must come from the sketch's own l (regression: d1 was
    computed from the dict key, silently corrupting ad-hoc engines)."""
    res = V.sample_fixed_k(zipf_stream, None, k=200, l=8.0, salt=11)
    eng = QueryEngine({5.0: res})  # address != res.l on purpose
    b = eng.query_batch([Query(F.cap(8), None, 5.0)])
    assert float(b.estimates[0]) == E.estimate(res, F.cap(8))


def test_bank_reset_keeps_answers_bit_identical(zipf_stream):
    """Overflowing the segment bank resets it wholesale; answers before and
    after the reset stay bit-identical to the scalar path."""
    res = V.sample_fixed_k(zipf_stream, None, k=100, l=5.0, salt=12)
    eng = QueryEngine({5.0: res})
    eng._seg_rows_max = 4  # force resets quickly
    ref = {}
    for mod in range(2, 12):
        seg = SEG.Predicate((lambda m: lambda keys: keys % m == 0)(mod),
                            f"mod{mod}")
        got = float(eng.query_batch([Query(F.cap(5), seg, 5.0)]).estimates[0])
        ref[mod] = E.estimate(res, F.cap(5), seg)
        assert got == ref[mod], mod
    # revisit an early (evicted) segment: recompiled mask, same bits
    seg2 = SEG.Predicate(lambda keys: keys % 2 == 0, "mod2b")
    assert float(eng.query_batch([Query(F.cap(5), seg2, 5.0)]).estimates[0]) \
        == ref[2]
    # a batch of NEW segments straddling the cap must reset upfront, never
    # mid-plan (regression: a mid-batch reset stranded earlier rows)
    while len(eng._seg_rows) < eng._seg_rows_max - 1:
        eng._seg_row(0, SEG.HashBucket(64, len(eng._seg_rows)))
    straddle = [Query(F.cap(5), SEG.HashBucket(128, b), 5.0) for b in (17, 18)]
    got = eng.query_batch(straddle)
    for q, e in zip(straddle, got.estimates):
        assert float(e) == E.estimate(res, F.cap(5), q.segment)
    # the cached plan must stay valid on replay
    np.testing.assert_array_equal(
        got.estimates, eng.query_batch(straddle).estimates)


def test_segment_equality_and_caching():
    a, b = SEG.IdSet([3, 1, 2]), SEG.IdSet(np.array([1, 2, 3]))
    assert a == b and hash(a) == hash(b)
    assert SEG.HashBucket(8, 1) == SEG.HashBucket(8, 1)
    assert SEG.HashBucket(8, 1) != SEG.HashBucket(8, 2)
    f = lambda k: k > 0
    assert SEG.Predicate(f) == SEG.Predicate(f)
    assert SEG.as_segment(None) == SEG.AllKeys()


# -- key validation (satellite: no silent int32 wrapping) --------------------


def test_normalize_keys_rejects_bad_inputs():
    with pytest.raises(TypeError, match="integers"):
        normalize_keys(np.array([1.5, 2.5]))
    with pytest.raises(ValueError, match="int32"):
        normalize_keys(np.array([2**40], dtype=np.int64))
    with pytest.raises(ValueError, match="EMPTY"):
        normalize_keys(np.array([2**31 - 1], dtype=np.int64))
    out = normalize_keys(np.array([[1, 2], [3, 4]], dtype=np.int64))
    assert out.dtype == np.int32 and out.tolist() == [1, 2, 3, 4]


def test_service_observe_and_reconcile_validate_keys(zipf_stream):
    svc = StreamStatsService(StatsConfig(k=64, ls=(1.0,), chunk=512, host_id=0))
    with pytest.raises(TypeError, match="integers"):
        svc.observe(np.array([1.0, 2.0]))
    with pytest.raises(ValueError, match="int32"):
        svc.observe(np.array([2**31], dtype=np.int64))
    svc.observe(zipf_stream[:4096])
    with pytest.raises(ValueError, match="int32"):
        svc.reconcile(np.array([-2**35], dtype=np.int64))
    svc.reconcile(zipf_stream[:4096])
    assert svc.query_distinct(exact=True) > 0


# -- pick_l grid warning (satellite) ----------------------------------------


def test_pick_l_warns_once_outside_sqrt2_factor():
    svc = StreamStatsService(StatsConfig(ls=(1.0, 8.0, 64.0)))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # within sqrt(2): silent
        assert svc.pick_l(8.0) == 8.0
        assert svc.pick_l(10.0) == 8.0
    with pytest.warns(RuntimeWarning, match="sqrt"):
        assert svc.pick_l(500.0) == 64.0
    with warnings.catch_warnings():  # second offence: silent (warn once)
        warnings.simplefilter("error")
        assert svc.pick_l(2000.0) == 64.0
