"""Out-of-process shard tier (stats.procshard): REAL subprocess workers.

These tests are the repo's only ones that spawn worker subprocesses (each
pays an interpreter+jax import, ~10-20s), so they are few and each one
covers several contract points at once:

* ``test_sigkill_mid_ingest_recovery_bit_identity`` — the headline
  acceptance criterion: SIGKILL a real worker mid-stream, let the
  supervisor restart+recover it, and pin the exact two-pass answers
  ``np.array_equal`` to a fault-free in-process oracle over the same
  stream.  Also exercises the restart budget (a second kill exhausts
  ``max_restarts=1`` and the tier degrades instead of hanging) and the
  process-mode status plane (pid/restart facts).

* ``test_chaos_schedule_realized_against_processes`` — a seeded
  PROC_KINDS schedule (crash/stall/slow/lost_reply/partition) realized
  physically: kills are SIGKILLs, partitions sever the actual socket (the
  worker reconnects with state intact).  Post-chaos, after health rounds
  converge, exact answers are bit-identical to the oracle.
"""
import numpy as np
import pytest

from repro.core import freqfns, hashing
from repro.launch.faults import (
    PROC_KINDS,
    FaultInjector,
    FaultSchedule,
    WallClock,
)
from repro.stats.procshard import ProcShardTier, SupervisorConfig
from repro.stats.query import Query
from repro.stats.service import StatsConfig
from repro.stats.shardtier import ShardTier, TierConfig

CFG = StatsConfig(k=64, ls=(1.0, 8.0), chunk=32)

QUERIES = [Query(freqfns.cap(8.0)), Query(freqfns.distinct()),
           Query(freqfns.total())]


def _stream(n, lo, hi, stream_id):
    idx = np.arange(n, dtype=np.int64)
    h = hashing.hash_combine_np(idx, np.int64(stream_id), np.int64(77))
    keys = (lo + (h % np.uint32(hi - lo)).astype(np.int64)).astype(np.int32)
    hw = hashing.hash_combine_np(idx, np.int64(stream_id), np.int64(78))
    weights = (1.0 + hashing.uniform01_np(hw) * 3.0).astype(np.float32)
    return keys, weights


def _oracle_exact(batches, root):
    """Fault-free in-process tier over the same stream: the bit-identity
    reference (same shard count/salt => same partition, same host_ids)."""
    tier = ShardTier(CFG, TierConfig(n_shards=2, checkpoint_every=4,
                                     retain_wal=True, fsync=False), root)
    for keys, weights in batches:
        tier.ingest(keys, weights)
    return tier.query_batch(QUERIES, mode="exact")


def _proc_tier(root, *, faults=None, max_restarts=3,
               merge_every_n_batches=None):
    tc = TierConfig(n_shards=2, checkpoint_every=4, retain_wal=True,
                    fsync=False, backoff_base_s=0.02, call_deadline_s=5.0,
                    merge_every_n_batches=merge_every_n_batches)
    sup = SupervisorConfig(max_restarts=max_restarts,
                           restart_backoff_s=0.05)
    return ProcShardTier(CFG, tc, root, faults=faults, supervisor=sup)


def test_sigkill_mid_ingest_recovery_bit_identity(tmp_path):
    batches = [_stream(200, 0, 500, i) for i in range(6)]
    with _proc_tier(tmp_path / "proc", max_restarts=1) as tier:
        for keys, weights in batches[:3]:
            tier.ingest(keys, weights)
        # REAL SIGKILL mid-stream; the next apply discovers the corpse,
        # marks the shard down, and auto-recovery respawns + replays
        tier.kill_shard(1)
        for keys, weights in batches[3:5]:
            tier.ingest(keys, weights)
        tier.check_health()
        for keys, weights in batches[5:]:
            tier.ingest(keys, weights)
        res = tier.query_batch(QUERIES, mode="exact")
        assert res.mode == "exact" and not res.degraded

        st = tier.status()
        s1 = st["shards"][1]
        assert s1["state"] == "up" and s1["alive"]
        assert s1["restarts"] == 1 and isinstance(s1["pid"], int)
        assert s1["applied_seq"] == 6  # caught all the way up
        assert any(e[2] == "recovered" for e in st["events"])

        oracle = _oracle_exact(batches, tmp_path / "oracle")
        assert np.array_equal(res.estimates, oracle.estimates)
        assert np.array_equal(res.variances, oracle.variances)

        # restart budget: max_restarts=1 is spent — a second SIGKILL must
        # leave the slot down and auto-mode queries DEGRADED, not raising
        tier.kill_shard(1)
        tier.check_health()
        assert tier.slots[1] == "down"
        deg = tier.query_batch(QUERIES, mode="auto")
        assert deg.degraded and deg.mode == "approx"
        total = sum(tier._routed)
        assert deg.coverage == pytest.approx(tier._routed[0] / total)
        assert np.all(np.isfinite(deg.estimates))


def test_chaos_schedule_realized_against_processes(tmp_path):
    # Real-process chaos: tiny latencies (wall clock!) and every PROC kind,
    # including partition (socket sever + reconnect) and crash (SIGKILL).
    sched = FaultSchedule.generate(
        29, n_shards=2, n_events=10, kinds=PROC_KINDS,
        max_call_no=6, max_latency_s=0.05)
    assert sched.events, "seed 29 must produce events"
    faults = FaultInjector(sched, clock=WallClock())
    batches = [_stream(150, 0, 400, 100 + i) for i in range(8)]
    with _proc_tier(tmp_path / "proc", faults=faults,
                    max_restarts=8) as tier:
        for i, (keys, weights) in enumerate(batches):
            tier.ingest(keys, weights)
            if i % 2 == 1:
                tier.check_health()
        # converge: bounded health rounds until every shard is back up
        for _ in range(20):
            if all(s == "up" for s in tier.slots):
                break
            tier.check_health()
        assert all(s == "up" for s in tier.slots)
        res = tier.query_batch(QUERIES, mode="exact")
        # the schedule really fired, physically
        fired = {e.kind for e in faults.fired}
        assert fired, "chaos schedule never fired"
    oracle = _oracle_exact(batches, tmp_path / "oracle")
    assert np.array_equal(res.estimates, oracle.estimates)
