"""Sharded ingestion tier: routing, WAL, recovery bit-identity, degraded
queries, bounded retry/backoff, elastic membership (stats/shardtier.py) and
the deterministic fault harness (launch/faults.py)."""
import dataclasses
import tempfile

import numpy as np
import pytest

from repro.core import freqfns, hashing
from repro.launch.faults import (
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    InjectedLostReply,
    VirtualClock,
)
from repro.stats.query import Query
from repro.stats.service import StatsConfig, StreamStatsService
from repro.stats.shardtier import (
    ExactUnavailable,
    ShardTier,
    ShardWAL,
    ShardWorker,
    TierConfig,
    partition_batch,
    route_keys,
)

CFG = StatsConfig(k=64, ls=(1.0, 8.0), chunk=32)
QUERIES = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]


def _stream(n, lo=1, hi=400, stream_id=0):
    """Deterministic skewed key stream from the library's own hashing."""
    eids = np.arange(n, dtype=np.int64)
    h = hashing.hash_combine_np(eids, np.int64(stream_id))
    return (h % np.uint32(hi - lo)).astype(np.int64) + lo


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def test_route_keys_deterministic_and_stable():
    keys = _stream(500)
    a = route_keys(keys, 4)
    b = route_keys(keys, 4)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 4
    # every key maps to ONE shard regardless of batch context
    solo = np.array([int(route_keys(np.array([k]), 4)[0]) for k in keys[:50]])
    np.testing.assert_array_equal(solo, a[:50])


def test_partition_batch_covers_and_preserves_order():
    keys = _stream(300)
    w = np.arange(300, dtype=np.float32)
    parts = partition_batch(keys, w, 3)
    total = sum(len(pk) for pk, _ in parts)
    assert total == 300
    sid = route_keys(keys, 3)
    for s, (pk, pw) in enumerate(parts):
        np.testing.assert_array_equal(pk, keys[sid == s])
        np.testing.assert_array_equal(pw, w[sid == s])  # arrival order kept


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------


def test_wal_roundtrip_truncate_and_gap():
    with tempfile.TemporaryDirectory() as d:
        wal = ShardWAL(d)
        for seq in (1, 2, 3, 4):
            wal.append(seq, np.full(seq, seq, np.int32),
                       np.full(seq, float(seq), np.float32))
        assert wal.last_seq() == 4 and wal.covers_from_origin()
        got = [(s, k.tolist()) for s, k, _ in wal.entries(after=2)]
        assert got == [(3, [3, 3, 3]), (4, [4, 4, 4, 4])]
        wal.truncate_through(2)
        assert wal.seqs() == [3, 4] and not wal.covers_from_origin()
        # replaying from before the truncation point must fail loudly
        with pytest.raises(ValueError, match="WAL gap"):
            list(wal.entries(after=0))
        # no torn segments: a leftover .tmp is invisible
        (wal.dir / "wal_00000009.npz.tmp").write_bytes(b"torn")
        assert wal.seqs() == [3, 4]


# ---------------------------------------------------------------------------
# Fault schedule determinism
# ---------------------------------------------------------------------------


def test_fault_schedule_deterministic_and_replayable():
    a = FaultSchedule.generate(7, n_shards=3, n_events=10)
    b = FaultSchedule.generate(7, n_shards=3, n_events=10)
    assert a == b
    c = FaultSchedule.from_json(a.to_json())
    assert c.events == a.events
    assert a.events  # dedup may shrink but not to zero at these sizes
    assert all(e.kind in ("crash", "stall", "slow", "lost_reply")
               for e in a.events)
    assert FaultSchedule.generate(8, n_shards=3, n_events=10) != a


def test_injector_fires_on_nth_call_and_records():
    sched = FaultSchedule(events=(
        FaultEvent("s.op", 2, "lost_reply"),
        FaultEvent("s.op", 3, "slow", 1.5),
    ))
    inj = FaultInjector(sched, VirtualClock())
    with inj.site("s.op"):
        pass  # call 1: clean
    with pytest.raises(InjectedLostReply):
        with inj.site("s.op"):
            pass  # call 2: body runs, reply lost
    t0 = inj.clock.now()
    with inj.site("s.op"):
        pass  # call 3: slow
    assert inj.clock.now() == t0 + 1.5
    assert [e.call_no for e in inj.fired] == [2, 3]


# ---------------------------------------------------------------------------
# Worker: recovery bit-identity, idempotent apply
# ---------------------------------------------------------------------------


def _feed_worker(worker, batches, start_seq=1):
    for i, b in enumerate(batches):
        worker.wal.append(start_seq + i, b, np.ones(len(b), np.float32))
        worker.apply(start_seq + i, b, np.ones(len(b), np.float32))


def _state_equal(sa: dict, sb: dict) -> bool:
    return (sa.keys() == sb.keys()
            and all(np.array_equal(np.asarray(sa[k]), np.asarray(sb[k]))
                    for k in sa))


def test_worker_crash_recover_bit_identical():
    batches = [_stream(60, stream_id=i) for i in range(7)]
    with tempfile.TemporaryDirectory() as d:
        ref = ShardWorker(0, CFG, d + "/ref", checkpoint_every=3)
        _feed_worker(ref, batches)

        w = ShardWorker(0, CFG, d + "/w", checkpoint_every=3)
        _feed_worker(w, batches[:5])
        w.crash()
        with pytest.raises(Exception):
            w.n_observed  # dead worker refuses service
        w.recover()  # checkpoint restore + WAL tail replay
        _feed_worker(w, batches[5:], start_seq=6)

        assert _state_equal(w.service.state_dict(), ref.service.state_dict())
        # recovery is idempotent: recover() on a LIVE worker is a no-op
        # state-wise (rebuild from durable state reproduces the same bits)
        w.recover()
        assert _state_equal(w.service.state_dict(), ref.service.state_dict())


def test_worker_apply_is_idempotent():
    b = _stream(50)
    with tempfile.TemporaryDirectory() as d:
        w = ShardWorker(0, CFG, d, checkpoint_every=0)
        w.wal.append(1, b, np.ones(len(b), np.float32))
        w.apply(1, b, np.ones(len(b), np.float32))
        n = w.n_observed
        # the retry path after a lost reply: same seq again is an ack no-op
        w.apply(1, b, np.ones(len(b), np.float32))
        assert w.n_observed == n
        with pytest.raises(ValueError, match="gap"):
            w.apply(5, b, np.ones(len(b), np.float32))


# ---------------------------------------------------------------------------
# Tier: ingest equivalence, degraded queries, exact mode
# ---------------------------------------------------------------------------


def _mk_tier(d, **kw):
    tier_kw = dict(n_shards=3, checkpoint_every=4, retain_wal=True)
    tier_kw.update(kw)
    return ShardTier(CFG, TierConfig(**tier_kw), d)


def test_tier_healthy_queries_not_degraded():
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d)
        for i in range(6):
            tier.ingest(_stream(100, stream_id=i))
        res = tier.query_batch(QUERIES)
        assert res.coverage == 1.0 and not res.degraded
        assert res.mode == "approx" and res.staleness_elements == 0
        exact = tier.query_batch(QUERIES, mode="exact")
        assert exact.mode == "exact" and not exact.degraded
        # auto prefers exact when available
        auto = tier.query_batch(QUERIES, mode="auto")
        np.testing.assert_array_equal(auto.estimates, exact.estimates)
        assert auto.mode == "exact"


def test_tier_degraded_flags_and_ht_scaling():
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, auto_recover=False)
        for i in range(6):
            tier.ingest(_stream(100, stream_id=i))
        tier.kill_shard(1)
        tier.check_health()
        assert tier.membership()[1] == "down"
        res = tier.query_batch(QUERIES, mode="auto")
        live_routed = tier._routed[0] + tier._routed[2]
        total = sum(tier._routed)
        assert res.degraded and res.mode == "approx"
        assert res.coverage == pytest.approx(live_routed / total)
        assert res.staleness_elements == tier._routed[1]
        # estimates are the surviving-shard fold scaled by 1/coverage,
        # with widened (not narrowed) uncertainty
        raw = tier._merged_approx()[0].query_batch(QUERIES, exact=False)
        np.testing.assert_allclose(
            res.estimates, raw.estimates / res.coverage)
        assert (res.stderr >= raw.stderr).all()
        # exact mode refuses rather than silently degrade
        with pytest.raises(ExactUnavailable):
            tier.query_batch(QUERIES, mode="exact")
        # recovery restores full coverage
        assert tier.recover_shard(1)
        back = tier.query_batch(QUERIES, mode="auto")
        assert back.coverage == 1.0 and not back.degraded


def test_tier_exact_needs_full_wal():
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, retain_wal=False, checkpoint_every=2)
        for i in range(6):
            tier.ingest(_stream(100, stream_id=i))
        with pytest.raises(ExactUnavailable, match="truncated"):
            tier.query_batch(QUERIES, mode="exact")
        # auto falls back to the one-pass answer instead
        res = tier.query_batch(QUERIES, mode="auto")
        assert res.mode == "approx" and res.coverage == 1.0


def test_tier_down_shard_keeps_data_and_catches_up():
    """Batches routed while a shard is down land in its WAL and are applied
    at recovery — the tier's answers equal a never-crashed tier's."""
    batches = [_stream(100, stream_id=i) for i in range(8)]
    with tempfile.TemporaryDirectory() as d:
        oracle = _mk_tier(d + "/oracle")
        tier = _mk_tier(d + "/tier", auto_recover=False)
        for b in batches[:4]:
            oracle.ingest(b)
            tier.ingest(b)
        tier.kill_shard(2)
        tier.check_health()
        for b in batches[4:]:
            oracle.ingest(b)
            tier.ingest(b)  # shard 2's share goes to WAL only
        assert tier.recover_shard(2)
        got = tier.query_batch(QUERIES, mode="exact")
        want = oracle.query_batch(QUERIES, mode="exact")
        np.testing.assert_array_equal(got.estimates, want.estimates)


# ---------------------------------------------------------------------------
# Bounded retry / backoff / failure detection (virtual clock)
# ---------------------------------------------------------------------------


def test_retry_backoff_on_virtual_clock():
    """Two stalls on one apply site: the bounded retry sleeps the exponential
    backoff on the VIRTUAL clock and the call ultimately succeeds."""
    sched = FaultSchedule(events=(
        FaultEvent("shard0.ingest", 1, "stall", 0.2),
        FaultEvent("shard0.ingest", 2, "stall", 0.2),
    ))
    inj = FaultInjector(sched, VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(CFG, TierConfig(n_shards=1, retain_wal=True),
                         d, faults=inj)
        keys = _stream(80)
        tier.ingest(keys)
        assert tier.membership()[0] == "up"
        # clock advanced by both stall latencies + both backoff sleeps
        base, factor = tier.tier.backoff_base_s, tier.tier.backoff_factor
        assert tier.clock.now() == pytest.approx(
            0.2 + 0.2 + base + base * factor)
        assert tier.workers[0].n_observed == len(keys)


def test_retry_exhaustion_marks_down_then_recovery_catches_up():
    stalls = tuple(FaultEvent("shard0.ingest", n, "stall", 0.01)
                   for n in range(1, 9))
    inj = FaultInjector(FaultSchedule(events=stalls), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            CFG, TierConfig(n_shards=1, retain_wal=True, auto_recover=False),
            d, faults=inj)
        keys = _stream(80)
        tier.ingest(keys)  # every attempt stalls -> budget exhausted
        assert tier.membership()[0] == "down"
        assert any(ev[2] == "down" for ev in tier.events)
        assert tier.recover_shard(0)  # WAL replay catches the shard up
        assert tier.workers[0].n_observed == len(keys)


def test_heartbeat_miss_limit_declares_down():
    stalls = tuple(FaultEvent("shard0.heartbeat", n, "stall", 0.01)
                   for n in range(1, 4))
    inj = FaultInjector(FaultSchedule(events=stalls), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            CFG, TierConfig(n_shards=1, heartbeat_miss_limit=3,
                            auto_recover=False), d, faults=inj)
        tier.ingest(_stream(50))
        tier.check_health()
        tier.check_health()
        assert tier.membership()[0] == "up"  # 2 misses < limit
        tier.check_health()
        assert tier.membership()[0] == "down"  # 3rd miss trips the limit
        tier.check_health()  # clean heartbeat now -> recovered + caught up
        assert tier.membership()[0] == "up"


def test_lost_reply_retry_does_not_double_count():
    inj = FaultInjector(FaultSchedule(events=(
        FaultEvent("shard0.ingest", 1, "lost_reply"),)), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        ref = ShardTier(CFG, TierConfig(n_shards=1, retain_wal=True),
                        d + "/ref")
        tier = ShardTier(CFG, TierConfig(n_shards=1, retain_wal=True),
                         d + "/t", faults=inj)
        keys = _stream(90)
        ref.ingest(keys)
        tier.ingest(keys)  # applied, reply lost, retried -> deduped
        assert tier.workers[0].n_observed == len(keys)
        got = tier.query_batch(QUERIES, mode="exact")
        want = ref.query_batch(QUERIES, mode="exact")
        np.testing.assert_array_equal(got.estimates, want.estimates)


# ---------------------------------------------------------------------------
# merge_many / absorb_many partial-merge surface
# ---------------------------------------------------------------------------


def test_merge_many_matches_sequential_pairwise():
    """merge_many == the sequential pairwise fold, bit for bit (the fixed-k
    fold is a left fold by contract), in both modes."""
    streams = [_stream(150, stream_id=i) for i in range(3)]
    for mode in ("exact", "approx"):
        svcs = [StreamStatsService(dataclasses.replace(CFG, host_id=i))
                for i in range(3)]
        pair = [StreamStatsService(dataclasses.replace(CFG, host_id=i))
                for i in range(3)]
        for i in range(3):
            svcs[i].observe(streams[i])
            pair[i].observe(streams[i])
        many = StreamStatsService(dataclasses.replace(CFG, host_id=9))
        many.merge_many(svcs, mode=mode)
        fold = StreamStatsService(dataclasses.replace(CFG, host_id=9))
        fold.merge(pair[0], mode=mode)
        fold.merge(pair[1], mode=mode)
        fold.merge(pair[2], mode=mode)
        assert _state_equal(many.state_dict(), fold.state_dict())
        r_many = many.query_batch(QUERIES, exact=False)
        r_fold = fold.query_batch(QUERIES, exact=False)
        np.testing.assert_array_equal(r_many.estimates, r_fold.estimates)


def test_merge_many_validates_group_host_ids():
    a = StreamStatsService(dataclasses.replace(CFG, host_id=1))
    b = StreamStatsService(dataclasses.replace(CFG, host_id=1))
    dst = StreamStatsService(dataclasses.replace(CFG, host_id=0))
    a.observe(_stream(40))
    b.observe(_stream(40, stream_id=1))
    with pytest.raises(ValueError, match="distinct host_ids"):
        dst.merge_many([a, b], mode="exact")
    dst.merge_many([], mode="exact")  # empty group is a no-op
    assert dst.n_observed == 0


# ---------------------------------------------------------------------------
# Elastic membership
# ---------------------------------------------------------------------------


def test_leave_then_join_bit_identical():
    batches = [_stream(100, stream_id=i) for i in range(6)]
    with tempfile.TemporaryDirectory() as d:
        oracle = _mk_tier(d + "/oracle")
        tier = _mk_tier(d + "/tier")
        for b in batches[:3]:
            oracle.ingest(b)
            tier.ingest(b)
        tier.leave_shard(0)
        assert tier.membership()[0] == "left"
        with pytest.raises(ValueError):
            tier.recover_shard(0)  # left slots revive via join only
        for b in batches[3:]:
            oracle.ingest(b)
            tier.ingest(b)
        assert tier.query_batch(QUERIES).degraded
        assert tier.join_shard(0)
        got = tier.query_batch(QUERIES, mode="exact")
        want = oracle.query_batch(QUERIES, mode="exact")
        np.testing.assert_array_equal(got.estimates, want.estimates)
        assert not tier.query_batch(QUERIES).degraded


# ---------------------------------------------------------------------------
# WAL integrity: CRC32 trailers + torn-tail tolerance
# ---------------------------------------------------------------------------


def _truncate_half(path):
    """Interposition: simulate a torn write by keeping half the bytes."""
    raw = path.read_bytes()
    path.write_bytes(raw[:len(raw) // 2])


def test_wal_crc_detects_corruption():
    from repro.stats.shardtier import WALCorrupt
    with tempfile.TemporaryDirectory() as d:
        wal = ShardWAL(d)
        wal.append(1, np.arange(8, dtype=np.int32),
                   np.ones(8, np.float32))
        p = wal._path(1)
        raw = bytearray(p.read_bytes())
        raw[10] ^= 0xFF  # flip one payload byte: CRC must catch it
        p.write_bytes(bytes(raw))
        with pytest.raises(WALCorrupt):
            wal.read_segment(1)


def test_wal_torn_tail_repaired_from_wal_first_buffer():
    with tempfile.TemporaryDirectory() as d:
        wal = ShardWAL(d)
        for seq in (1, 2, 3):
            wal.append(seq, np.full(4, seq, np.int32),
                       np.full(4, float(seq), np.float32))
        _truncate_half(wal._path(3))
        # same instance still holds batch 3 in the WAL-first buffer:
        # replay repairs the segment and yields the full log
        got = list(wal.entries())
        assert [s for s, _, _ in got] == [1, 2, 3]
        keys3, _ = wal.read_segment(3)  # rewritten, verifies clean
        np.testing.assert_array_equal(keys3, np.full(4, 3, np.int32))


def test_wal_torn_tail_dropped_without_buffer():
    with tempfile.TemporaryDirectory() as d:
        ShardWAL(d).append(1, np.ones(4, np.int32), np.ones(4, np.float32))
        wal = ShardWAL(d)  # fresh instance: no WAL-first buffer
        wal.append(2, np.full(4, 2, np.int32), np.full(4, 2.0, np.float32))
        wal2 = ShardWAL(d)
        _truncate_half(wal2._path(2))
        assert wal2.check_tail() == 1  # dropped, replay ends one early
        assert [s for s, _, _ in wal2.entries()] == [1]
        assert wal2.seqs() == [1]  # the torn file is gone


def test_wal_interior_corruption_raises():
    from repro.stats.shardtier import WALCorrupt
    with tempfile.TemporaryDirectory() as d:
        wal = ShardWAL(d)
        for seq in (1, 2, 3):
            wal.append(seq, np.full(4, seq, np.int32),
                       np.full(4, float(seq), np.float32))
        _truncate_half(wal._path(2))
        with pytest.raises(WALCorrupt):  # interior loss is NOT tolerable
            list(wal.entries())


def test_torn_tail_recovery_bit_identity():
    """The satellite's interposition contract: a half-written tail segment
    plus a crash must recover bit-identical — the coordinator's WAL-first
    buffer re-ingests the torn batch."""
    batches = [_stream(100, stream_id=i) for i in range(5)]
    with tempfile.TemporaryDirectory() as d:
        oracle = _mk_tier(d + "/oracle")
        tier = _mk_tier(d + "/tier")
        for b in batches:
            oracle.ingest(b)
            tier.ingest(b)
        s = 1
        wal = tier.workers[s].wal
        _truncate_half(wal._path(wal.last_seq()))  # torn mid-write
        tier.kill_shard(s)
        tier.check_health()  # declares down + auto-recovers through the WAL
        assert tier.membership()[s] == "up"
        got = tier.query_batch(QUERIES, mode="exact")
        want = oracle.query_batch(QUERIES, mode="exact")
        np.testing.assert_array_equal(got.estimates, want.estimates)


# ---------------------------------------------------------------------------
# Heartbeat flap: slow-but-alive shards must not be declared dead
# ---------------------------------------------------------------------------


def test_slow_but_alive_shard_never_flapped_dead():
    """Regression (PR 10): under sustained heartbeat stalls, a shard that
    keeps APPLYING successfully proves liveness — any successful call resets
    the miss counter, so misses never accumulate to the limit across health
    rounds separated by working ingest."""
    stalls = tuple(FaultEvent("shard0.heartbeat", n, "stall", 0.01)
                   for n in range(1, 7))
    inj = FaultInjector(FaultSchedule(events=stalls), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            CFG, TierConfig(n_shards=1, heartbeat_miss_limit=3,
                            auto_recover=False), d, faults=inj)
        for i in range(6):
            tier.check_health()   # stalled heartbeat: one miss
            tier.ingest(_stream(50, stream_id=i))  # successful apply: reset
            assert tier.membership()[0] == "up"
        assert not any(e[2] == "down" for e in tier.events)
        # sanity: the schedule really fired all six stalls
        assert len(inj.fired) == 6


def test_slow_heartbeats_reset_miss_counter():
    """A shard that misses miss_limit-1 beats then answers one (even slowly)
    starts over from zero misses."""
    events = (FaultEvent("shard0.heartbeat", 1, "stall", 0.01),
              FaultEvent("shard0.heartbeat", 2, "stall", 0.01),
              FaultEvent("shard0.heartbeat", 3, "slow", 0.5),  # succeeds late
              FaultEvent("shard0.heartbeat", 4, "stall", 0.01),
              FaultEvent("shard0.heartbeat", 5, "stall", 0.01))
    inj = FaultInjector(FaultSchedule(events=events), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            CFG, TierConfig(n_shards=1, heartbeat_miss_limit=3,
                            auto_recover=False), d, faults=inj)
        tier.ingest(_stream(50))
        for _ in range(5):
            tier.check_health()
        # 2 misses, slow success (reset), 2 misses: never reaches 3
        assert tier.membership()[0] == "up"
        assert tier._miss[0] == 2


# ---------------------------------------------------------------------------
# Retry exhaustion: degraded answers, never an exception
# ---------------------------------------------------------------------------


def test_retry_exhaustion_auto_query_degrades_not_raises():
    # stalls long past the call deadline: the ingest call's retry budget
    # expires with shard 1 still unreachable -> marked down, and auto-mode
    # queries must DEGRADE (coverage-stamped, HT-scaled), not raise
    stalls = tuple(FaultEvent("shard1.ingest", n, "stall", 10.0)
                   for n in range(1, 6))
    inj = FaultInjector(FaultSchedule(events=stalls), VirtualClock())
    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            CFG, TierConfig(n_shards=3, retain_wal=True,
                            auto_recover=False), d, faults=inj)
        for i in range(4):
            tier.ingest(_stream(120, stream_id=i))
        assert tier.membership()[1] == "down"
        res = tier.query_batch(QUERIES, mode="auto")
        assert res.degraded and res.mode == "approx"
        live = sum(tier._routed[s] for s in tier.live_shards())
        total = sum(tier._routed)
        assert res.coverage == pytest.approx(live / total)
        assert res.staleness_elements == total - live
        assert np.all(np.isfinite(res.estimates))


@pytest.mark.parametrize("down_set", [(1,), (0, 2), (1, 2, 3)])
def test_degraded_coverage_matches_live_shard_set(down_set):
    """Property: coverage equals the live-shard routed fraction for every
    down-set, and recovery restores coverage 1."""
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, n_shards=4, auto_recover=False)
        for i in range(6):
            tier.ingest(_stream(150, stream_id=i))
        for s in down_set:
            tier.kill_shard(s)
        tier.check_health()
        res = tier.query_batch(QUERIES, mode="auto")
        live = [s for s in range(4) if s not in down_set]
        assert set(tier.live_shards()) == set(live)
        total = sum(tier._routed)
        covered = sum(tier._routed[s] for s in live)
        assert res.degraded and res.coverage == pytest.approx(covered / total)
        assert res.staleness_elements == total - covered
        for s in down_set:
            assert tier.recover_shard(s)
        assert tier.query_batch(QUERIES, mode="auto").coverage == 1.0


# ---------------------------------------------------------------------------
# Background exact-merge cadence + snapshot queries
# ---------------------------------------------------------------------------


def test_merge_cadence_requires_retain_wal():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="retain_wal"):
            ShardTier(CFG, TierConfig(n_shards=2, retain_wal=False,
                                      merge_every_n_batches=4), d)


def test_merge_cadence_builds_and_refreshes_snapshot():
    batches = [_stream(100, stream_id=i) for i in range(4)]
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d + "/t", n_shards=2, merge_every_n_batches=2)
        with pytest.raises(ExactUnavailable):
            tier.query_batch(QUERIES, mode="snapshot")  # nothing merged yet
        tier.ingest(batches[0])
        assert tier._snapshot is None  # 1 < cadence
        tier.ingest(batches[1])
        assert tier._n_merges == 1 and tier.snapshot_staleness() == 0
        snap0 = tier.query_batch(QUERIES, mode="snapshot")
        assert snap0.mode == "snapshot" and not snap0.degraded
        assert snap0.coverage == 1.0 and snap0.staleness_elements == 0
        # the snapshot IS the exact answer as of its watermark: pin against
        # an oracle tier that stopped at the watermark
        oracle = _mk_tier(d + "/o", n_shards=2)
        oracle.ingest(batches[0])
        oracle.ingest(batches[1])
        want = oracle.query_batch(QUERIES, mode="exact")
        np.testing.assert_array_equal(snap0.estimates, want.estimates)
        # a batch past the watermark: served stale (stamped), not rebuilt
        tier.ingest(batches[2])
        snap1 = tier.query_batch(QUERIES, mode="snapshot")
        assert snap1.staleness_elements == len(batches[2])
        np.testing.assert_array_equal(snap1.estimates, snap0.estimates)
        assert tier.snapshot_staleness() == len(batches[2])
        # cadence rolls over: next batch refreshes
        tier.ingest(batches[3])
        assert tier._n_merges == 2
        assert tier.query_batch(QUERIES, mode="snapshot").staleness_elements == 0


def test_merge_every_s_cadence_on_clock():
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, n_shards=2, merge_every_s=1.0)
        tier.ingest(_stream(80, stream_id=0))
        assert tier._n_merges == 0  # no time elapsed on the virtual clock
        tier.clock.sleep(1.5)
        tier.ingest(_stream(80, stream_id=1))
        assert tier._n_merges == 1


def test_merge_skipped_while_shard_down_keeps_serving_stale():
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, n_shards=2, merge_every_n_batches=1,
                        auto_recover=False)
        tier.ingest(_stream(90, stream_id=0))
        assert tier._n_merges == 1
        stale_before = tier.query_batch(QUERIES, mode="snapshot")
        tier.kill_shard(0)
        tier.ingest(_stream(90, stream_id=1))  # cadence due, but shard down
        assert tier._n_merges == 1 and tier._n_merges_skipped >= 1
        assert any(e[2] == "merge_skipped" for e in tier.events)
        # the OLD snapshot keeps answering, stamped stale, not degraded
        res = tier.query_batch(QUERIES, mode="snapshot")
        assert res.staleness_elements > 0 and not res.degraded
        np.testing.assert_array_equal(res.estimates, stale_before.estimates)
        # recovery un-wedges the cadence on the next batch
        assert tier.recover_shard(0)
        tier.ingest(_stream(90, stream_id=2))
        assert tier._n_merges == 2


# ---------------------------------------------------------------------------
# Status plane
# ---------------------------------------------------------------------------


def test_status_plane_accounting_and_serializable():
    import json as _json
    with tempfile.TemporaryDirectory() as d:
        tier = _mk_tier(d, n_shards=3, auto_recover=False)
        for i in range(5):
            tier.ingest(_stream(200, stream_id=i))
        st = tier.status()
        assert st["n_observed"] == tier.n_observed == 1000
        assert sum(s["load"] for s in st["shards"].values()) == 1000
        assert sum(s["share"] for s in st["shards"].values()) == pytest.approx(1.0)
        assert st["coverage"] == 1.0 and st["snapshot"] is None
        for s in range(3):
            w = tier.workers[s]
            assert st["shards"][s]["applied_seq"] == w.applied_seq
            assert st["shards"][s]["wal_depth"] == len(w.wal.seqs())
            assert st["shards"][s]["last_checkpoint_seq"] == w._last_ckpt_seq
        _json.dumps(st)  # the plane is a scrape target: JSON all the way
        # a down shard shows up in coverage, state, and the events feed
        tier.kill_shard(2)
        tier.check_health()
        st2 = tier.status()
        assert st2["shards"][2]["state"] == "down"
        assert not st2["shards"][2]["alive"]
        assert st2["coverage"] == pytest.approx(
            (tier._routed[0] + tier._routed[1]) / 1000)
        assert any(e[2] == "down" for e in st2["events"])
        _json.dumps(st2)
