"""Chunksort kernel contract: the Pallas block-local bitonic + cross-block
two-run merge sort is BIT-IDENTICAL to the stable-argsort dual
(``segments.stable_sort_with_perm``) — not approximately, by construction:
the kernel orders (key, index) pairs lexicographically, and on distinct
pairs that order *is* the stable sort order.

All tests run in interpret mode (CPU CI); the properties pinned here are
exactly what a compiled Mosaic/Triton run must preserve.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import incremental as I
from repro.core.segments import EMPTY, chunk_order, stable_sort_with_perm
from repro.kernels.chunksort import sort_with_perm, sort_with_perm_ref
from repro.kernels.chunksort.chunksort import sort_pairs
from repro.kernels.capscore.tiling import tile_config


def _assert_pairs_equal(a, b):
    assert (np.asarray(a[0]) == np.asarray(b[0])).all()
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()


@pytest.mark.parametrize("n", [1, 5, 64, 256, 257, 777, 1024, 2048])
def test_sort_bit_identical_across_sizes(n):
    """Pallas sort == stable argsort, power-of-two and ragged sizes alike
    (ragged sizes exercise the EMPTY padding + exact [:n] slice)."""
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, max(2, n // 3), n), jnp.int32)
    _assert_pairs_equal(sort_with_perm(keys, backend="pallas"),
                        stable_sort_with_perm(keys))


@pytest.mark.parametrize("n_distinct", [1, 2, 7])
def test_sort_tie_pressure(n_distinct):
    """Duplicate-heavy chunks: stability (= index order within equal keys)
    must survive the bitonic network, where it is carried by the idx lane of
    the lexicographic pairs, not by any property of the network itself."""
    rng = np.random.default_rng(17)
    n = 1000
    keys = jnp.asarray(rng.integers(0, n_distinct, n), jnp.int32)
    got_ks, got_perm = sort_with_perm(keys, backend="pallas")
    ref_ks, ref_perm = stable_sort_with_perm(keys)
    assert (np.asarray(got_perm) == np.asarray(ref_perm)).all()
    assert (np.asarray(got_ks) == np.asarray(ref_ks)).all()


def test_sort_empty_padding_cases():
    """Real EMPTY keys sort to the end but BEFORE the kernel's pad entries
    (pads have idx >= n, losing every tie), so the [:n] slice is exact."""
    rng = np.random.default_rng(5)
    # partially padded: ragged size, ~30% real EMPTYs sprinkled through
    n = 700
    keys = rng.integers(0, 50, n).astype(np.int32)
    keys[rng.random(n) < 0.3] = int(EMPTY)
    k = jnp.asarray(keys)
    got = sort_with_perm(k, backend="pallas")
    ref = stable_sort_with_perm(k)
    _assert_pairs_equal(got, ref)
    assert int(np.asarray(got[1]).max()) < n  # no pad index leaks out

    # all-EMPTY chunk (the padding-chunk shape the samplers feed at flush)
    k = jnp.full((513,), EMPTY, jnp.int32)
    _assert_pairs_equal(sort_with_perm(k, backend="pallas"),
                        stable_sort_with_perm(k))


def test_sort_gpu_flavor_tile_bit_identical():
    """The GPU tile config (different block size -> different network +
    merge depth) produces the same bits as the default flavor."""
    rng = np.random.default_rng(23)
    keys = jnp.asarray(rng.integers(0, 97, 2048), jnp.int32)
    idx = jnp.arange(2048, dtype=jnp.int32)
    a = sort_pairs(keys, idx, cfg=tile_config("chunksort", "interpret"),
                   interpret=True)
    b = sort_pairs(keys, idx, cfg=tile_config("chunksort", "gpu"),
                   interpret=True)
    _assert_pairs_equal(a, b)
    _assert_pairs_equal(a, stable_sort_with_perm(keys))


def test_ref_is_the_registered_dual():
    keys = jnp.asarray([3, 1, 2, 1], jnp.int32)
    _assert_pairs_equal(sort_with_perm_ref(keys), stable_sort_with_perm(keys))
    # xla route of the op == the dual too
    _assert_pairs_equal(sort_with_perm(keys, backend="xla"),
                        stable_sort_with_perm(keys))


@pytest.mark.parametrize("n", [256, 1000])
def test_chunk_order_routes_bit_identical(n):
    """Every field of ChunkOrder (ks/perm/seg/ukeys + pre-gathered eids/ws)
    is bitwise equal between the pallas and xla sort routes."""
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 60, n), jnp.int32)
    eids = jnp.asarray(rng.integers(0, 1 << 30, n), jnp.int32)
    ws = jnp.asarray(rng.random(n), jnp.float32) + 0.1
    a = chunk_order(keys, eids, ws, sort_backend="pallas")
    b = chunk_order(keys, eids, ws, sort_backend="xla")
    c = chunk_order(keys, eids, ws)  # auto == xla on CPU
    for fa, fb, fc in zip(a, b, c):
        assert (np.asarray(fa) == np.asarray(fb)).all()
        assert (np.asarray(fb) == np.asarray(fc)).all()


def test_chunk_order_rejects_unknown_backend():
    with pytest.raises(ValueError, match="sort backend"):
        chunk_order(jnp.zeros((4,), jnp.int32), sort_backend="triton")


def test_update_multi_downstream_unchanged():
    """Swapping only the chunk sort to the Pallas kernel leaves the whole
    multi-lane update — tables, taus, bottom-(k+1) summaries — bitwise
    unchanged, and the update_multi(reference=True) oracle still matches:
    the sort is pure routing, invisible to the sampler's semantics."""
    rng = np.random.default_rng(41)
    keys = rng.integers(0, 300, 4096).astype(np.int32)
    ws = rng.random(4096).astype(np.float32) + 0.1
    ls = [1.0, 8.0, 64.0]
    mk_spec = dict(k=128, chunk=1024, salt=3)

    s_def, spec_def = I.init_multi_state(ls, **mk_spec)
    s_pal, spec_pal = I.init_multi_state(ls, **mk_spec, backend="xla",
                                         sort_backend="pallas")
    out_def = I.update_multi(s_def, keys, ws, spec_def)
    out_pal = I.update_multi(s_pal, keys, ws, spec_pal)
    for a, b in zip(jax.tree.leaves(out_def), jax.tree.leaves(out_pal)):
        assert (np.asarray(a) == np.asarray(b)).all()

    # the reference oracle is untouched by the routing knobs: finalized
    # per-lane samples/thresholds agree (the established fused-vs-reference
    # contract — raw table slot layouts may differ between the pipelines)
    s_ref, spec_ref = I.init_multi_state(ls, **mk_spec)
    out_ref = I.update_multi(s_ref, keys, ws, spec_ref, reference=True)
    rn = I.finalize_multi(out_pal, spec_pal, ls=ls)
    rr = I.finalize_multi(out_ref, spec_ref, ls=ls)
    for l in ls:
        np.testing.assert_array_equal(rn[l].keys, rr[l].keys)
        np.testing.assert_array_equal(rn[l].counts, rr[l].counts)
        assert rn[l].tau == rr[l].tau
