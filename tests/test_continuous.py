"""Continuous SH_l machinery (§5): inclusion, count law, Thm 5.3 estimator."""
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import continuous as C
from repro.core import freqfns as F


def test_inclusion_prob_regimes():
    # tau*l < 1: (1-e^{-w/l}) * tau*l
    w, tau, l = 3.0, 0.05, 4.0
    np.testing.assert_allclose(C.inclusion_prob(w, tau, l), (1 - math.exp(-w / l)) * tau * l)
    # tau*l >= 1: 1-e^{-tau w}
    tau = 0.5
    np.testing.assert_allclose(C.inclusion_prob(w, tau, l), 1 - math.exp(-tau * w))


def test_inclusion_prob_proportional_to_cap():
    """Fig 1/2 property: Phi(w) ~ w for w << l, ~ const for w >> l."""
    tau, l = 0.001, 10.0
    w_small = np.array([0.1, 0.2, 0.4])
    p = C.inclusion_prob(w_small, tau, l)
    ratios = p / w_small
    np.testing.assert_allclose(ratios, ratios[0], rtol=0.03)
    p_big = C.inclusion_prob(np.array([1000.0, 4000.0]), tau, l)
    np.testing.assert_allclose(p_big[0], p_big[1], rtol=1e-6)


def test_count_law_integrates_to_inclusion():
    """integral of count density over (0,w) == Phi(w) (Thm 5.2 + eq. 11)."""
    for tau, l, w in [(0.05, 4.0, 7.0), (0.5, 4.0, 3.0), (0.01, 100.0, 250.0)]:
        ys = np.linspace(1e-6, w - 1e-6, 200001)
        mass = np.trapezoid(C.count_density(ys, w, tau, l), ys)
        np.testing.assert_allclose(mass, C.inclusion_prob(w, tau, l), rtol=1e-4)


def test_conditional_count_matches_density():
    """Inverse-CDF sampler agrees with the Thm 5.2 density (moment check)."""
    tau, l, w = 0.08, 5.0, 12.0
    u = (np.arange(100000) + 0.5) / 100000
    c = C.conditional_count(w, tau, l, u)
    assert np.all((c > 0) & (c <= w))
    ys = np.linspace(1e-9, w - 1e-9, 400001)
    dens = C.count_density(ys, w, tau, l)
    dens /= np.trapezoid(dens, ys)
    np.testing.assert_allclose(c.mean(), np.trapezoid(ys * dens, ys), rtol=1e-3)
    np.testing.assert_allclose((c**2).mean(), np.trapezoid(ys**2 * dens, ys), rtol=1e-3)


@given(
    tau=st.floats(min_value=0.01, max_value=0.9),
    l=st.floats(min_value=0.5, max_value=100.0),
    w=st.floats(min_value=0.1, max_value=300.0),
    T=st.floats(min_value=0.5, max_value=50.0),
)
@settings(max_examples=40, deadline=None)
def test_estimator_unbiased_by_quadrature(tau, l, w, T):
    """Thm 5.3: E[beta(c_x)] = f(w) exactly.  Verified by numerical
    integration of beta against the count law, for f = cap_T."""
    fn = F.cap(T)
    ys = np.linspace(1e-7 * w, w * (1 - 1e-9), 300001)
    dens = C.count_density(ys, w, tau, l)
    vals = C.beta(fn, ys, tau, l)
    est = np.trapezoid(vals * dens, ys)  # zero contribution when c_x = 0
    np.testing.assert_allclose(est, fn.f(np.array([w]))[0], rtol=2e-3)


def test_two_pass_estimator_identity():
    """f(w)/Phi(w) * Phi(w) = f(w): inverse probability is trivially unbiased;
    check the code path end-to-end on arrays."""
    w = np.array([0.5, 2.0, 10.0, 100.0])
    tau, l = 0.07, 8.0
    est = C.estimate_two_pass(F.cap(5), w, tau, l)
    manual = np.sum(np.minimum(w, 5) / C.inclusion_prob(w, tau, l))
    np.testing.assert_allclose(est, manual)


def test_cv_bounds_shape():
    """Thm 5.1/5.4 bounds: minimized near l = T, degrade with disparity."""
    q, k = 0.1, 200
    at_T = C.cv_bound_two_pass(10, 10, q, k)
    off = C.cv_bound_two_pass(10, 100, q, k)
    assert at_T < off
    # l = T constants: 2-pass ~1.26/sqrt(qk), 1-pass ~1.8/sqrt(qk)
    base = 1.0 / math.sqrt(q * (k - 1))
    np.testing.assert_allclose(at_T, math.sqrt(math.e / (math.e - 1)) * base, rtol=1e-9)
    np.testing.assert_allclose(
        C.cv_bound_one_pass(10, 10, q, k), math.sqrt(2 * math.e / (math.e - 1)) * base, rtol=1e-9
    )
