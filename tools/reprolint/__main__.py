"""CLI: ``python -m tools.reprolint [paths ...] [--retrace]``.

Run from the repo root. With positional paths (or none — config default),
runs the AST engine and exits 1 on any unsuppressed violation. With
``--retrace``, runs the runtime retrace auditor against the committed
compile-count budget (requires jax + ``PYTHONPATH=src``); ``--update-budget``
rewrites the budget file from the measured counts instead of diffing.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .config import load_config
from .engine import LintEngine


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="project invariant checker: AST lint + jit retrace audit",
    )
    parser.add_argument("paths", nargs="*", help="files/dirs to lint (default: config paths)")
    parser.add_argument("--root", default=".", help="repo root (default: cwd)")
    parser.add_argument("--retrace", action="store_true", help="run the runtime retrace auditor")
    parser.add_argument(
        "--update-budget",
        action="store_true",
        help="with --retrace: rewrite reprolint_traces.json from measured counts",
    )
    parser.add_argument(
        "--no-baseline", action="store_true", help="ignore the committed baseline file"
    )
    args = parser.parse_args(argv)

    config = load_config(Path(args.root))

    if args.retrace:
        from . import retrace

        return retrace.main(config, update=args.update_budget)

    engine = LintEngine(config, use_baseline=not args.no_baseline)
    result = engine.lint_paths(args.paths or None)
    return engine.report(result)


if __name__ == "__main__":
    sys.exit(main())
