"""Lint driver: file discovery, pragma suppression, baseline, reporting.

Suppression mechanics (both require a justification to count):

* inline pragma, on the flagged line or anywhere in the contiguous
  comment block immediately above it::

      x = jnp.argsort(z)  # reprolint: disable=RPL002 -- once-per-batch boundary

* file-level pragma anywhere in the file::

      # reprolint: disable-file=RPL005 -- synthetic demo driver

* baseline entry in ``tools/reprolint/baseline.json`` matching
  ``(code, path, context)`` where context is the enclosing function/class
  qualname (line-number independent, so refactors don't churn the file).

A pragma without a ``-- reason`` does NOT suppress; it is itself reported
(code RPL000) so the justification contract stays honest. Baseline entries
that match nothing are reported as warnings so the file shrinks over time.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Iterable, Optional

from .config import Config, load_config
from .rules import RULES, FileContext

_PRAGMA_RE = re.compile(
    r"#\s*reprolint:\s*(?P<kind>disable|disable-file)\s*=\s*"
    r"(?P<codes>RPL\d{3}(?:\s*,\s*RPL\d{3})*)"
    r"(?:\s*--\s*(?P<reason>\S.*))?"
)


@dataclasses.dataclass
class Violation:
    path: str
    line: int
    col: int
    code: str
    message: str
    context: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} [{self.context}] {self.message}"


@dataclasses.dataclass
class Pragma:
    line: int
    codes: set[str]
    file_level: bool
    justified: bool


@dataclasses.dataclass
class LintResult:
    violations: list[Violation]
    suppressed: int = 0
    baselined: int = 0
    unused_baseline: list[dict] = dataclasses.field(default_factory=list)
    errors: list[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations and not self.errors


def _scan_pragmas(lines: list[str]) -> list[Pragma]:
    out = []
    for i, line in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        codes = {c.strip() for c in m.group("codes").split(",")}
        out.append(
            Pragma(
                line=i,
                codes=codes,
                file_level=m.group("kind") == "disable-file",
                justified=bool(m.group("reason")),
            )
        )
    return out


class _Baseline:
    def __init__(self, entries: list[dict]):
        self.entries = entries
        self.used = [False] * len(entries)

    def matches(self, v: Violation) -> bool:
        for i, e in enumerate(self.entries):
            if (
                e.get("code") == v.code
                and e.get("path") == v.path
                and e.get("context") == v.context
            ):
                self.used[i] = True
                return True
        return False

    def unused(self) -> list[dict]:
        return [e for e, u in zip(self.entries, self.used) if not u]


def _load_baseline(config: Config) -> _Baseline:
    path = config.root / config.baseline
    if not path.is_file():
        return _Baseline([])
    data = json.loads(path.read_text())
    return _Baseline(list(data.get("entries", [])))


class LintEngine:
    def __init__(self, config: Config, use_baseline: bool = True):
        self.config = config
        self.baseline = _load_baseline(config) if use_baseline else _Baseline([])

    # -- single-file linting -------------------------------------------------

    def lint_source(self, source: str, relpath: str) -> LintResult:
        result = LintResult(violations=[])
        try:
            ctx = FileContext(relpath, source, self.config)
        except SyntaxError as exc:
            result.errors.append(f"{relpath}: syntax error: {exc}")
            return result

        pragmas = _scan_pragmas(ctx.lines)
        file_codes = {c for p in pragmas if p.file_level and p.justified for c in p.codes}
        line_codes: dict[int, set[str]] = {}
        for p in pragmas:
            if p.file_level or not p.justified:
                continue
            line_codes.setdefault(p.line, set()).update(p.codes)
        # Report unjustified pragmas so `-- reason` stays mandatory.
        for p in pragmas:
            if not p.justified:
                result.violations.append(
                    Violation(
                        path=relpath,
                        line=p.line,
                        col=1,
                        code="RPL000",
                        message="reprolint pragma without a `-- justification`; "
                        "suppressions must say why",
                        context=_context_at_line(ctx, p.line),
                    )
                )

        for code, rule in sorted(RULES.items()):
            for finding in rule.check(ctx):
                node = finding.node
                line = getattr(node, "lineno", 1)
                col = getattr(node, "col_offset", 0) + 1
                v = Violation(
                    path=relpath,
                    line=line,
                    col=col,
                    code=code,
                    message=finding.message,
                    context=ctx.context_of(node),
                )
                if code in file_codes:
                    result.suppressed += 1
                    continue
                if code in _codes_covering(ctx.lines, line_codes, line):
                    result.suppressed += 1
                    continue
                if self.baseline.matches(v):
                    result.baselined += 1
                    continue
                result.violations.append(v)
        return result

    # -- tree walking --------------------------------------------------------

    def iter_files(self, paths: Iterable[str]) -> Iterable[Path]:
        seen = set()
        root = self.config.root.resolve()
        for p in paths:
            path = (root / p).resolve()
            if path.is_file() and path.suffix == ".py":
                files = [path]
            elif path.is_dir():
                files = sorted(path.rglob("*.py"))
            else:
                continue
            for f in files:
                if "__pycache__" in f.parts or f in seen:
                    continue
                seen.add(f)
                yield f

    def lint_paths(self, paths: Optional[Iterable[str]] = None) -> LintResult:
        paths = list(paths) if paths else list(self.config.paths)
        total = LintResult(violations=[])
        root = self.config.root.resolve()
        for f in self.iter_files(paths):
            rel = f.relative_to(root).as_posix()
            r = self.lint_source(f.read_text(), rel)
            total.violations.extend(r.violations)
            total.suppressed += r.suppressed
            total.baselined += r.baselined
            total.errors.extend(r.errors)
        total.unused_baseline = self.baseline.unused()
        total.violations.sort(key=lambda v: (v.path, v.line, v.col, v.code))
        return total

    # -- reporting -----------------------------------------------------------

    def report(self, result: LintResult, stream=sys.stdout) -> int:
        for err in result.errors:
            print(f"error: {err}", file=stream)
        for v in result.violations:
            print(v.render(), file=stream)
        for e in result.unused_baseline:
            print(
                f"warning: unused baseline entry {e.get('code')} "
                f"{e.get('path')} [{e.get('context')}] — remove it",
                file=stream,
            )
        n = len(result.violations)
        print(
            f"reprolint: {n} violation(s), {result.suppressed} pragma-suppressed, "
            f"{result.baselined} baselined",
            file=stream,
        )
        return 0 if result.ok else 1


def _codes_covering(lines: list[str], line_codes: dict[int, set[str]], line: int) -> set[str]:
    """Pragma codes applying to ``line``: its own, plus any found in the
    contiguous run of comment-only lines directly above it."""
    codes = set(line_codes.get(line, ()))
    i = line - 1
    while i >= 1 and lines[i - 1].lstrip().startswith("#"):
        codes |= line_codes.get(i, set())
        i -= 1
    return codes


def _context_at_line(ctx: FileContext, line: int) -> str:
    """Qualname of the innermost def/class containing a source line."""
    best = "<module>"
    best_span = None
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        if node.lineno <= line <= end:
            span = end - node.lineno
            if best_span is None or span < best_span:
                best_span = span
                # context_of(def) already includes the def's own name.
                best = ctx.context_of(node)
    return best


# -- convenience API (used by tests) ----------------------------------------


def lint_text(
    source: str,
    relpath: str = "src/repro/core/fixture.py",
    config: Optional[Config] = None,
    use_baseline: bool = False,
) -> list[Violation]:
    config = config or Config.from_mapping(Path("."), {})
    return LintEngine(config, use_baseline=use_baseline).lint_source(source, relpath).violations


def lint_paths(
    paths: Optional[Iterable[str]] = None,
    root: str | Path = ".",
    use_baseline: bool = True,
) -> LintResult:
    config = load_config(root)
    return LintEngine(config, use_baseline=use_baseline).lint_paths(paths)
