"""Lint rules RPL001-RPL007 and the shared AST analyses they sit on.

Every rule is a function ``check(ctx) -> Iterator[Finding]`` registered in
``RULES`` via the :func:`rule` decorator. ``ctx`` is a :class:`FileContext`
with the parsed tree plus precomputed facts: which functions are jit-wrapped
(decorator, module-level ``jax.jit(f)`` / ``partial(jax.jit, ...)``, and
``jax.jit(lambda ...)`` forms), which source lines sit inside an
``enable_x64`` ``with`` block, and the qualified name enclosing every node
(used for baseline matching, which is line-number independent).

Design bias: rules are tuned for *this* codebase and err toward silence.
RPL001's hot-module hostness analysis only taints values it can prove came
off-device (parameters annotated with a device state type, or results of
calling a module-level jit-wrapped name) and only clears them on provable
host conversion (``jax.device_get`` / ``np.*``); anything it cannot trace is
not flagged. The pragma + baseline escape hatches cover the rest.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Iterator, Optional

from .config import Config

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Finding:
    node: ast.AST
    message: str


@dataclasses.dataclass
class Rule:
    code: str
    summary: str
    check: Callable[["FileContext"], Iterator[Finding]]


RULES: dict[str, Rule] = {}


def rule(code: str, summary: str):
    def register(fn: Callable[["FileContext"], Iterator[Finding]]):
        RULES[code] = Rule(code, summary, fn)
        return fn

    return register


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

#: module spellings canonicalized before dotted-name matching
_CANON = (
    ("jax.numpy.", "jnp."),
    ("numpy.random.", "np.random."),
    ("numpy.", "np."),
)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, canonicalized; None otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    name = ".".join(reversed(parts))
    for long, short in _CANON:
        if name.startswith(long):
            name = short + name[len(long):]
            break
    return name


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted(node) in ("jax.jit", "jit")


def _is_partial_jit(call: ast.AST) -> bool:
    """``functools.partial(jax.jit, ...)``"""
    return (
        isinstance(call, ast.Call)
        and dotted(call.func) in ("functools.partial", "partial")
        and bool(call.args)
        and _is_jax_jit(call.args[0])
    )


@dataclasses.dataclass
class JitSite:
    """One place a callable gets wrapped in jax.jit."""

    node: ast.AST  # node to anchor RPL003/RPL007 findings on
    wrapped: Optional[ast.AST]  # FunctionDef / Lambda if resolvable
    donated: bool
    static_names: list[str]
    bound_name: Optional[str]  # module-level name the jitted fn is bound to


def _jit_kwargs(call_kwargs: list[ast.keyword]) -> tuple[bool, list[str]]:
    donated = False
    statics: list[str] = []
    for kw in call_kwargs:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            donated = True
        if kw.arg in ("static_argnames", "static_argnums"):
            statics.extend(_static_names(kw.value))
    return donated, statics


def _static_names(node: ast.AST) -> list[str]:
    """String static_argnames from a literal str/tuple/list; ints ignored
    here (RPL007 resolves static_argnums positionally)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def _static_nums(call_kwargs: list[ast.keyword]) -> list[int]:
    for kw in call_kwargs:
        if kw.arg == "static_argnums":
            node = kw.value
            if isinstance(node, ast.Constant) and isinstance(node.value, int):
                return [node.value]
            if isinstance(node, (ast.Tuple, ast.List)):
                return [
                    e.value
                    for e in node.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)
                ]
    return []


def _params(fn: ast.AST) -> list[ast.arg]:
    assert isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
    a = fn.args
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


# --------------------------------------------------------------------------
# FileContext
# --------------------------------------------------------------------------


class FileContext:
    def __init__(self, relpath: str, source: str, config: Config):
        self.relpath = relpath
        self.source = source
        self.config = config
        self.tree = ast.parse(source)
        self.lines = source.splitlines()

        self.qualname: dict[int, str] = {}  # id(node) -> enclosing symbol
        self.jit_sites: list[JitSite] = []
        self.jit_defs: set[int] = set()  # id() of jit-wrapped FunctionDef/Lambda
        self.jit_names: set[str] = set()  # names whose call returns device values
        self.x64_lines: set[int] = set()
        self._defs_by_name: dict[str, ast.AST] = {}

        self._annotate_qualnames()
        self._collect_defs()
        self._collect_jit_sites()
        self._collect_x64_lines()

    # -- precomputation ------------------------------------------------------

    def _annotate_qualnames(self) -> None:
        def walk(node: ast.AST, stack: list[str]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    self.qualname[id(child)] = ".".join(stack + [child.name]) or "<module>"
                    walk(child, stack + [child.name])
                else:
                    self.qualname[id(child)] = ".".join(stack) or "<module>"
                    walk(child, stack)

        self.qualname[id(self.tree)] = "<module>"
        walk(self.tree, [])

    def context_of(self, node: ast.AST) -> str:
        return self.qualname.get(id(node), "<module>")

    def _collect_defs(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # Last definition wins; adequate for resolving jax.jit(name).
                self._defs_by_name[node.name] = node

    def _collect_jit_sites(self) -> None:
        # Form 1: decorated defs.
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                donated, statics = False, []
                hit = False
                if _is_jax_jit(dec):
                    hit = True
                elif isinstance(dec, ast.Call) and _is_jax_jit(dec.func):
                    hit = True
                    donated, statics = _jit_kwargs(dec.keywords)
                    statics += self._nums_to_names(node, _static_nums(dec.keywords))
                elif _is_partial_jit(dec):
                    hit = True
                    donated, statics = _jit_kwargs(dec.keywords)
                    statics += self._nums_to_names(node, _static_nums(dec.keywords))
                if hit:
                    self._add_site(node, node, donated, statics, node.name)
                    break
        # Form 2/3: call forms anywhere — jax.jit(fn_or_lambda, ...) and
        # functools.partial(jax.jit, ...)(fn_or_lambda).
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            donated, statics, target = False, [], None
            if _is_jax_jit(node.func) and node.args:
                donated, statics = _jit_kwargs(node.keywords)
                target = node.args[0]
                nums = _static_nums(node.keywords)
            elif _is_partial_jit(node.func) and node.args:
                inner = node.func
                assert isinstance(inner, ast.Call)
                donated, statics = _jit_kwargs(inner.keywords)
                target = node.args[0]
                nums = _static_nums(inner.keywords)
            else:
                continue
            wrapped: Optional[ast.AST] = None
            if isinstance(target, ast.Lambda):
                wrapped = target
            elif isinstance(target, ast.Name):
                wrapped = self._defs_by_name.get(target.id)
            if wrapped is not None:
                statics = statics + self._nums_to_names(wrapped, nums)
            self._add_site(node, wrapped, donated, statics, self._bound_name(node))

    def _nums_to_names(self, fn: Optional[ast.AST], nums: list[int]) -> list[str]:
        if fn is None or not nums:
            return []
        params = _params(fn)
        return [params[i].arg for i in nums if 0 <= i < len(params)]

    def _bound_name(self, call: ast.Call) -> Optional[str]:
        """If ``name = jax.jit(...)`` at module/class level, return name."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and node.value is call:
                if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
                    return node.targets[0].id
        return None

    def _add_site(
        self,
        node: ast.AST,
        wrapped: Optional[ast.AST],
        donated: bool,
        statics: list[str],
        name: Optional[str],
    ) -> None:
        self.jit_sites.append(JitSite(node, wrapped, donated, statics, name))
        if wrapped is not None:
            self.jit_defs.add(id(wrapped))
        if name:
            self.jit_names.add(name)

    def _collect_x64_lines(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.With):
                continue
            for item in node.items:
                d = dotted(item.context_expr) or (
                    dotted(item.context_expr.func)
                    if isinstance(item.context_expr, ast.Call)
                    else None
                )
                if d and ("enable_x64" in d or "x64" in d.split(".")[-1]):
                    end = getattr(node, "end_lineno", node.lineno) or node.lineno
                    self.x64_lines.update(range(node.lineno, end + 1))
                    break

    # -- scope shorthands ----------------------------------------------------

    @property
    def is_hot(self) -> bool:
        return self.config.is_hot_path(self.relpath)

    @property
    def is_registry(self) -> bool:
        return self.config.is_dual_registry(self.relpath)


# --------------------------------------------------------------------------
# RPL001 — host-device sync inside jit scope / on device state
# --------------------------------------------------------------------------

# np.* functions that consume array data (forcing a device->host transfer
# when handed a traced value). Dtype constructors (np.int32(…) on a python
# scalar) and constants (np.inf, np.pi) are deliberately absent.
_NP_ARRAY_FNS = {
    "asarray", "array", "ascontiguousarray", "sum", "min", "max", "mean",
    "prod", "std", "var", "sort", "argsort", "argmin", "argmax", "where",
    "concatenate", "stack", "vstack", "hstack", "dot", "matmul", "clip",
    "abs", "any", "all", "isin", "searchsorted", "cumsum", "cumprod",
    "unique", "nonzero", "count_nonzero", "take", "maximum", "minimum",
    "floor", "ceil", "round", "log", "exp", "sqrt", "allclose",
    "array_equal",
}


def _iter_jit_scope_syncs(ctx: FileContext, site: JitSite) -> Iterator[Finding]:
    fn = site.wrapped
    assert fn is not None
    # Traced inputs: the wrapped callable's params minus static_argnames.
    traced = {p.arg for p in _params(fn)} - set(site.static_names)

    def shape_like(node: ast.AST) -> bool:
        """Constants, statics, and metadata pulls that are safe in a trace."""
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.UnaryOp):
            return shape_like(node.operand)
        if isinstance(node, ast.BinOp):
            return shape_like(node.left) and shape_like(node.right)
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id in ("len", "min", "max"):
                return all(shape_like(a) for a in node.args)
            d = dotted(node.func)
            return bool(d) and d.startswith("math.")
        if isinstance(node, ast.Subscript):
            return shape_like(node.value)
        if isinstance(node, ast.Attribute):
            if node.attr in ("shape", "ndim", "size", "dtype", "itemsize", "nbytes"):
                return True
            return shape_like(node.value)
        if isinstance(node, ast.Name):
            # Only the jit callable's traced params are known-traced; locals
            # and closure names stay conservative (not flagged).
            return node.id not in traced
        return False

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        # x.item()
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "item"
            and not node.args
        ):
            yield Finding(node, ".item() forces a host sync inside jit-traced code")
            continue
        # float(x) / int(x) / bool(x) on traced expressions
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in ("float", "int", "bool")
            and len(node.args) == 1
            and not shape_like(node.args[0])
        ):
            yield Finding(
                node,
                f"{node.func.id}() on a traced value forces a host sync inside jit",
            )
            continue
        # np.<array-fn>(traced, ...) inside a trace
        d = dotted(node.func)
        if (
            d
            and d.startswith("np.")
            and d.split(".")[-1] in _NP_ARRAY_FNS
            and any(not shape_like(a) for a in node.args)
        ):
            yield Finding(
                node,
                f"{d}() inside jit-traced code pulls traced operands to host; use jnp",
            )


class _Hostness(ast.NodeVisitor):
    """Order-sensitive host/device taint for one function body.

    ``state[name]`` is ``"device"`` (came off a jit call or a device-typed
    param), ``"host"`` (went through jax.device_get / np.*), or absent
    (unknown — never flagged). Findings are float()/int()/.item() applied to
    a device-tainted root outside any jit trace: each is a silent blocking
    transfer on the host hot path.
    """

    def __init__(self, ctx: FileContext, fn: ast.FunctionDef):
        self.ctx = ctx
        self.fn = fn
        self.state: dict[str, str] = {}
        self.findings: list[Finding] = []
        for p in _params(fn):
            ann = p.annotation
            ann_name = None
            if ann is not None:
                ann_name = dotted(ann)
                if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                    ann_name = ann.value
            if ann_name and ann_name.split(".")[-1] in ctx.config.device_state_types:
                self.state[p.arg] = "device"

    # taint inference ------------------------------------------------------

    def _infer(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d:
                base = d.split(".")[0]
                if d in ("jax.device_get", "jax.block_until_ready") or base in ("np",):
                    return "host"
                if d in self.ctx.jit_names or (
                    "." not in d and d in self.ctx.jit_names
                ):
                    return "device"
            kinds = {self._infer(a) for a in node.args}
            kinds |= {self._infer(k.value) for k in node.keywords}
            kinds.discard(None)
            if kinds == {"host"}:
                return "host"
            if "device" in kinds:
                return "device"
            return None
        if isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
            return self._infer(node.value)
        if isinstance(node, ast.Name):
            return self.state.get(node.id)
        if isinstance(node, (ast.Tuple, ast.List)):
            kinds = {self._infer(e) for e in node.elts} - {None}
            if kinds == {"host"}:
                return "host"
            if "device" in kinds:
                return "device"
            return None
        if isinstance(node, (ast.BinOp,)):
            kinds = {self._infer(node.left), self._infer(node.right)} - {None}
            if "device" in kinds:
                return "device"
            if kinds == {"host"}:
                return "host"
            return None
        if isinstance(node, ast.IfExp):
            kinds = {self._infer(node.body), self._infer(node.orelse)} - {None}
            if "device" in kinds:
                return "device"
            if kinds == {"host"}:
                return "host"
            return None
        return None

    def _bind(self, target: ast.AST, kind: Optional[str]) -> None:
        if isinstance(target, ast.Name):
            if kind is None:
                self.state.pop(target.id, None)
            else:
                self.state[target.id] = kind
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, kind)
        # Attribute/Subscript targets (self.x = …) stay unknown by design.

    # traversal ------------------------------------------------------------

    def _check_expr(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            if (
                isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "item"
                and not sub.args
                and self._infer(sub.func.value) == "device"
            ):
                self.findings.append(
                    Finding(sub, ".item() on device-resident state is a blocking transfer; jax.device_get once, then read")
                )
            elif (
                isinstance(sub.func, ast.Name)
                and sub.func.id in ("float", "int")
                and len(sub.args) == 1
                and self._infer(sub.args[0]) == "device"
            ):
                self.findings.append(
                    Finding(
                        sub,
                        f"{sub.func.id}() on device-resident state is a blocking transfer; jax.device_get once, then read",
                    )
                )

    def run(self) -> list[Finding]:
        self._visit_body(self.fn.body)
        return self.findings

    def _visit_body(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self._visit_stmt(stmt)

    def _visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested scopes get their own pass
        if isinstance(stmt, ast.Assign):
            self._check_expr(stmt.value)
            kind = self._infer(stmt.value)
            for t in stmt.targets:
                self._bind(t, kind)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._check_expr(stmt.value)
            self._bind(stmt.target, self._infer(stmt.value))
            return
        if isinstance(stmt, ast.AugAssign):
            self._check_expr(stmt.value)
            return
        if isinstance(stmt, ast.For):
            self._check_expr(stmt.iter)
            kind = self._infer(stmt.iter)
            self._bind(stmt.target, kind)
            self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.While,)):
            self._check_expr(stmt.test)
            self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
            return
        if isinstance(stmt, ast.If):
            self._check_expr(stmt.test)
            self._visit_body(stmt.body)
            self._visit_body(stmt.orelse)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._check_expr(item.context_expr)
            self._visit_body(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            self._visit_body(stmt.body)
            for h in stmt.handlers:
                self._visit_body(h.body)
            self._visit_body(stmt.orelse)
            self._visit_body(stmt.finalbody)
            return
        # Return / Expr / Raise / Assert / Delete / …
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._check_expr(child)


@rule("RPL001", "host-device sync inside jit scope or on device-resident state")
def check_rpl001(ctx: FileContext) -> Iterator[Finding]:
    # (a) inside jit-traced functions, anywhere.
    for site in ctx.jit_sites:
        if site.wrapped is not None:
            yield from _iter_jit_scope_syncs(ctx, site)
    # (b) hot modules: host functions pulling scalars off device state.
    if not ctx.is_hot:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if id(node) in ctx.jit_defs:
            continue
        yield from _Hostness(ctx, node).run()


# --------------------------------------------------------------------------
# RPL002 — raw selection primitives outside the dual registry
# --------------------------------------------------------------------------

_SELECTION_FNS = {
    "jnp.sort": "sort",
    "jnp.argsort": "argsort",
    "jnp.lexsort": "lexsort",
    "jnp.searchsorted": "searchsorted",
    "jnp.unique": "unique",
    "jnp.partition": "partition",
    "jnp.argpartition": "argpartition",
    "jax.lax.sort": "sort",
    "jax.lax.sort_key_val": "sort",
    "jax.lax.top_k": "top_k",
    "jax.lax.approx_max_k": "top_k",
    "jax.lax.approx_min_k": "top_k",
    "lax.sort": "sort",
    "lax.sort_key_val": "sort",
    "lax.top_k": "top_k",
    "lax.approx_max_k": "top_k",
    "lax.approx_min_k": "top_k",
}

_DUAL_HINTS = {
    "sort": "chunk_order / merge_sorted_runs_gather",
    "argsort": "chunk_order / bottom_k_by",
    "lexsort": "chunk_order",
    "searchsorted": "segments.searchsorted (pinned scan_unrolled)",
    "unique": "sorted-runs boundary masks (segments)",
    "partition": "kth_smallest + compact_valid",
    "argpartition": "kth_smallest + compact_valid",
    "top_k": "kth_smallest + compact_valid / bottom_k_by",
}


@rule("RPL002", "selection primitive in hot-path module bypasses core/segments duals")
def check_rpl002(ctx: FileContext) -> Iterator[Finding]:
    if not ctx.is_hot or ctx.is_registry:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d in _SELECTION_FNS:
            kind = _SELECTION_FNS[d]
            yield Finding(
                node,
                f"{d}() in a hot-path module; route through the registered dual "
                f"({_DUAL_HINTS[kind]}) so XLA:CPU keeps the rank/scan lowering",
            )


# --------------------------------------------------------------------------
# RPL003 — state-advancing jit without donation
# --------------------------------------------------------------------------


@rule("RPL003", "state-advancing jax.jit without donate_argnums")
def check_rpl003(ctx: FileContext) -> Iterator[Finding]:
    for site in ctx.jit_sites:
        if site.donated or site.wrapped is None:
            continue
        state_params = [
            p.arg for p in _params(site.wrapped) if ctx.config.is_state_param(p.arg)
        ]
        if state_params:
            anchor = site.node
            if (
                isinstance(anchor, (ast.FunctionDef, ast.AsyncFunctionDef))
                and anchor.decorator_list
            ):
                # Anchor on the decorator so a pragma above `@jax.jit` covers it.
                anchor = anchor.decorator_list[0]
            yield Finding(
                anchor,
                f"jit over state params {state_params} without donate_argnums: "
                "the old buffers stay live and every tick pays an extra copy",
            )


# --------------------------------------------------------------------------
# RPL004 — f64 dtype literals outside enable_x64 scopes
# --------------------------------------------------------------------------


@rule("RPL004", "f64 dtype literal outside an enable_x64 scope")
def check_rpl004(ctx: FileContext) -> Iterator[Finding]:
    if not ctx.config.in_x64_scope(ctx.relpath):
        return
    for node in ast.walk(ctx.tree):
        hit: Optional[str] = None
        if isinstance(node, ast.Attribute):
            d = dotted(node)
            if d in ("jnp.float64", "jnp.complex128"):
                hit = d
        elif isinstance(node, ast.Call):
            d = dotted(node.func)
            if d and d.startswith("jnp."):
                for kw in node.keywords:
                    if (
                        kw.arg == "dtype"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value in ("float64", "complex128")
                    ):
                        hit = f'dtype="{kw.value.value}"'
        if hit and node.lineno not in ctx.x64_lines:
            yield Finding(
                node,
                f"{hit} outside a `with enable_x64()` block silently truncates "
                "to f32 (or flips global state); keep f64 inside explicit scopes",
            )


# --------------------------------------------------------------------------
# RPL005 — ambient randomness where scoring must be salted-hash derived
# --------------------------------------------------------------------------

_RANDOM_PREFIXES = ("np.random.", "jax.random.", "random.")


@rule("RPL005", "ambient randomness in library scope (must derive from core/hashing salts)")
def check_rpl005(ctx: FileContext) -> Iterator[Finding]:
    if not ctx.config.in_randomness_scope(ctx.relpath):
        return
    # from-import aliases: from numpy.random import default_rng, …
    aliased: set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module in (
            "numpy.random",
            "jax.random",
            "random",
        ):
            for alias in node.names:
                aliased.add(alias.asname or alias.name)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d and any(d.startswith(p) for p in _RANDOM_PREFIXES):
            yield Finding(
                node,
                f"{d}() is ambient randomness; library scoring/merging must "
                "derive from salted (key, eid) hashes in core/hashing.py",
            )
        elif isinstance(node.func, ast.Name) and node.func.id in aliased:
            yield Finding(
                node,
                f"{node.func.id}() (imported from a PRNG module) is ambient "
                "randomness; derive from core/hashing.py salts",
            )


# --------------------------------------------------------------------------
# RPL006 — raw EMPTY-sentinel comparisons bypassing is_empty/is_live
# --------------------------------------------------------------------------

_EMPTY_SENTINEL = 2**31 - 1


def _is_sentinel_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id == "EMPTY" or node.id.startswith("_EMPTY")
    if isinstance(node, ast.Attribute):
        d = dotted(node)
        return bool(d) and (d.endswith(".EMPTY") or ("._EMPTY" in d))
    if isinstance(node, ast.Constant):
        return node.value == _EMPTY_SENTINEL
    if isinstance(node, ast.Call):
        # int(EMPTY) / np.int32(2**31 - 1)
        return any(_is_sentinel_expr(a) for a in node.args)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
        return (
            isinstance(node.left, ast.BinOp)
            and isinstance(node.left.op, ast.Pow)
            and isinstance(node.right, ast.Constant)
            and node.right.value == 1
        )
    return False


@rule("RPL006", "raw == EMPTY sentinel comparison; use segments.is_empty/is_live")
def check_rpl006(ctx: FileContext) -> Iterator[Finding]:
    if not ctx.is_hot or ctx.is_registry:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if any(_is_sentinel_expr(s) for s in sides):
            yield Finding(
                node,
                "raw sentinel comparison; use segments.is_empty/is_live so the "
                "EMPTY encoding stays changeable in one place",
            )


# --------------------------------------------------------------------------
# RPL007 — unhashable static-argnum defaults (retrace storms)
# --------------------------------------------------------------------------


def _is_unhashable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("list", "dict", "set", "bytearray")
    return False


@rule("RPL007", "unhashable static-argnum default forces a retrace per call")
def check_rpl007(ctx: FileContext) -> Iterator[Finding]:
    for site in ctx.jit_sites:
        if site.wrapped is None or not site.static_names:
            continue
        fn = site.wrapped
        if isinstance(fn, ast.Lambda):
            args = fn.args
        else:
            args = fn.args  # type: ignore[union-attr]
        pos = list(args.posonlyargs) + list(args.args)
        defaults = list(args.defaults)
        # defaults align with the tail of positional params
        for param, default in zip(pos[len(pos) - len(defaults):], defaults):
            if param.arg in site.static_names and _is_unhashable_default(default):
                yield Finding(
                    default,
                    f"static arg {param.arg!r} has an unhashable default; every "
                    "call misses the jit cache and retraces — use a tuple or None",
                )
        for param, default in zip(args.kwonlyargs, args.kw_defaults):
            if (
                default is not None
                and param.arg in site.static_names
                and _is_unhashable_default(default)
            ):
                yield Finding(
                    default,
                    f"static arg {param.arg!r} has an unhashable default; every "
                    "call misses the jit cache and retraces — use a tuple or None",
                )
