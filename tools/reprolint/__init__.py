"""reprolint: the project's jaxpr+AST invariant checker and retrace auditor.

Two engines mechanically enforce the hot-path rules PRs 1-6 established by
hand (DESIGN.md §11 lists each rule, the invariant it encodes, and which PR
established it):

* an AST lint engine (stdlib ``ast``, zero dependencies) with rules
  RPL001-RPL007, per-line ``# reprolint: disable=RPLxxx -- reason`` pragmas
  and a committed baseline (``tools/reprolint/baseline.json``);
* a runtime retrace auditor (``tools.reprolint.retrace``) that replays the
  benchmark smoke workloads against the library's jit entry points and diffs
  the observed compile counts against a committed budget
  (``tools/reprolint/reprolint_traces.json``).

CLI::

    python -m tools.reprolint src/ tests/ benchmarks/   # AST engine
    python -m tools.reprolint --retrace                 # retrace auditor

Both exit non-zero on any unsuppressed violation / budget excess, so CI can
gate on them like a test suite.
"""
from __future__ import annotations

__version__ = "1.0.0"

from .config import Config, load_config  # noqa: F401
from .engine import LintEngine, Violation, lint_paths, lint_text  # noqa: F401
