"""Configuration for reprolint.

Loaded from the ``[tool.reprolint]`` table in ``pyproject.toml``. This runs
on Python 3.10 (no ``tomllib``) and must stay dependency-free, so a minimal
TOML-subset reader lives here: it understands exactly the value shapes the
table uses — strings, booleans, integers, and (possibly multiline) lists of
strings. That subset is asserted by tests; anything fancier belongs in a
real TOML parser.

All path globs use :func:`fnmatch.fnmatch` semantics against the
POSIX-style path relative to the repo root — note ``*`` matches across
``/`` in fnmatch, so ``src/repro/kernels/*`` covers nested files too.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from pathlib import Path

SECTION = "tool.reprolint"

# Defaults mirror the committed pyproject table so the engine still works on
# a bare checkout (and in lint_text-based tests that pass no pyproject).
DEFAULTS: dict[str, object] = {
    # Directories the CLI scans when invoked with no positional paths.
    "paths": ["src", "tests", "benchmarks"],
    # RPL002/RPL006 scope: modules on the per-chunk / per-query hot path,
    # where a stray sort-shaped op or raw sentinel compare is a perf or
    # correctness landmine (DESIGN.md §11).
    "hot_path": [
        "src/repro/core/vectorized.py",
        "src/repro/core/incremental.py",
        "src/repro/core/distributed.py",
        "src/repro/core/segments.py",
        "src/repro/kernels/capscore/*.py",
        "src/repro/stats/query.py",
    ],
    # Modules allowed to contain the raw selection primitives (they ARE the
    # registered duals) and raw sentinel compares (they define the helpers).
    "dual_registry": ["src/repro/core/segments.py"],
    # RPL005 scope: library code whose randomness must derive from salted
    # (key, eid) hashing in core/hashing.py. launch/ is included so the
    # demo-driver boundary is an explicit, baselined allowlist rather than a
    # blind spot. data/ and benchmarks/ are synthetic workload generators,
    # deliberately out of scope.
    "randomness_scope": [
        "src/repro/core/*",
        "src/repro/stats/*",
        "src/repro/kernels/*",
        "src/repro/launch/*",
    ],
    # RPL004 scope: f64 literals are policed in library code only; tests
    # build f64 oracles freely.
    "x64_scope": ["src/repro/*"],
    # RPL001(b): pytree container types that live on device. A function
    # parameter annotated with one of these is treated as device-resident.
    "device_state_types": ["SamplerState", "TableState"],
    # RPL003: a jit whose wrapped callable has a parameter matching one of
    # these (exact name, or leading underscore-separated word, e.g.
    # table_a -> table) is considered state-advancing.
    "state_param_names": ["state", "table", "acc", "carry", "cache", "bank", "tab", "st"],
    "baseline": "tools/reprolint/baseline.json",
    "trace_budget": "tools/reprolint/reprolint_traces.json",
}


@dataclasses.dataclass
class Config:
    root: Path
    paths: list[str]
    hot_path: list[str]
    dual_registry: list[str]
    randomness_scope: list[str]
    x64_scope: list[str]
    device_state_types: list[str]
    state_param_names: list[str]
    baseline: str
    trace_budget: str

    @classmethod
    def from_mapping(cls, root: Path, data: dict[str, object]) -> "Config":
        merged = dict(DEFAULTS)
        unknown = set(data) - set(DEFAULTS)
        if unknown:
            raise ValueError(f"[{SECTION}] unknown keys: {sorted(unknown)}")
        merged.update(data)
        return cls(root=Path(root), **merged)  # type: ignore[arg-type]

    # -- scope predicates (all take repo-relative POSIX paths) ---------------

    def _match(self, relpath: str, globs: list[str]) -> bool:
        return any(fnmatch.fnmatch(relpath, g) for g in globs)

    def is_hot_path(self, relpath: str) -> bool:
        return self._match(relpath, self.hot_path)

    def is_dual_registry(self, relpath: str) -> bool:
        return self._match(relpath, self.dual_registry)

    def in_randomness_scope(self, relpath: str) -> bool:
        return self._match(relpath, self.randomness_scope)

    def in_x64_scope(self, relpath: str) -> bool:
        return self._match(relpath, self.x64_scope)

    def is_state_param(self, name: str) -> bool:
        if name in self.state_param_names:
            return True
        head = name.split("_", 1)[0]
        return head in self.state_param_names


def load_config(root: str | Path) -> Config:
    root = Path(root)
    pyproject = root / "pyproject.toml"
    data: dict[str, object] = {}
    if pyproject.is_file():
        data = _read_toml_section(pyproject.read_text(), SECTION)
    return Config.from_mapping(root, data)


# --------------------------------------------------------------------------
# Minimal TOML-subset reader (see module docstring for why it exists).
# --------------------------------------------------------------------------

_SECTION_RE = re.compile(r"^\s*\[(?P<name>[^\]]+)\]\s*(?:#.*)?$")
_KEY_RE = re.compile(r"^\s*(?P<key>[A-Za-z0-9_-]+)\s*=\s*(?P<rest>.*)$")


def _read_toml_section(text: str, section: str) -> dict[str, object]:
    """Extract one ``[section]`` table supporting str/bool/int/list-of-str."""
    out: dict[str, object] = {}
    lines = text.splitlines()
    i = 0
    in_section = False
    while i < len(lines):
        line = lines[i]
        m = _SECTION_RE.match(line)
        if m:
            in_section = m.group("name").strip() == section
            i += 1
            continue
        if not in_section or not line.strip() or line.lstrip().startswith("#"):
            i += 1
            continue
        km = _KEY_RE.match(line)
        if not km:
            raise ValueError(f"[{section}] cannot parse line {i + 1}: {line!r}")
        key = km.group("key").replace("-", "_")
        rest = km.group("rest").strip()
        if rest.startswith("["):
            # Accumulate until the closing bracket (multiline lists).
            buf = _strip_comment(rest)
            while not _balanced(buf):
                i += 1
                if i >= len(lines):
                    raise ValueError(f"[{section}] unterminated list for {key!r}")
                buf += " " + _strip_comment(lines[i].strip())
            out[key] = _parse_list(buf, section, key)
        else:
            out[key] = _parse_scalar(_strip_comment(rest), section, key)
        i += 1
    return out


def _strip_comment(value: str) -> str:
    """Drop a trailing ``# comment`` outside of quoted strings."""
    in_str: str | None = None
    for j, ch in enumerate(value):
        if in_str:
            if ch == in_str:
                in_str = None
        elif ch in "\"'":
            in_str = ch
        elif ch == "#":
            return value[:j].rstrip()
    return value.strip()


def _balanced(buf: str) -> bool:
    depth = 0
    in_str: str | None = None
    for ch in buf:
        if in_str:
            if ch == in_str:
                in_str = None
        elif ch in "\"'":
            in_str = ch
        elif ch == "[":
            depth += 1
        elif ch == "]":
            depth -= 1
            if depth == 0:
                return True
    return depth <= 0


def _parse_scalar(value: str, section: str, key: str) -> object:
    if len(value) >= 2 and value[0] == value[-1] and value[0] in "\"'":
        return value[1:-1]
    if value == "true":
        return True
    if value == "false":
        return False
    try:
        return int(value)
    except ValueError:
        raise ValueError(f"[{section}] {key}: unsupported value {value!r}") from None


def _parse_list(buf: str, section: str, key: str) -> list[object]:
    body = _strip_comment(buf).strip()
    if not (body.startswith("[") and body.endswith("]")):
        raise ValueError(f"[{section}] {key}: malformed list {buf!r}")
    items: list[object] = []
    token = ""
    in_str: str | None = None
    for ch in body[1:-1]:
        if in_str:
            token += ch
            if ch == in_str:
                in_str = None
            continue
        if ch in "\"'":
            in_str = ch
            token += ch
        elif ch == ",":
            if token.strip():
                items.append(_parse_scalar(token.strip(), section, key))
            token = ""
        else:
            token += ch
    if token.strip():
        items.append(_parse_scalar(token.strip(), section, key))
    return items
