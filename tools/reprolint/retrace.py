"""Runtime retrace auditor: compile-count budgets for the jit entry points.

The AST engine cannot see *dynamic* retrace storms — a cache key that starts
varying (an unhashable static, a host scalar folded into a shape, a dtype
flapping between calls) compiles a fresh executable per call and shows up
only at runtime. This auditor replays the benchmark smoke workloads against
the library's jit entry points, reads each function's compile-cache size
(``PjitFunction._cache_size()``), and diffs the counts against the
committed budget in ``tools/reprolint/reprolint_traces.json``:

* measured > budget  -> FAIL (a cache-key regression, treated like a perf bug)
* key missing        -> FAIL (new entry point without a committed budget)
* measured < budget  -> warning (tighten the budget)

Independent of the budget file, the donated per-chunk update paths
(``_update_donated`` / ``_update_multi_donated`` / ``_update_bank_donated``)
must compile **exactly once** across repeated same-shape chunks — that is
the steady-state serving contract; the auditor hard-fails if it doesn't
hold, so ``--update-budget`` cannot silently bake in a storm.

Workloads are deliberately deterministic (arange-derived keys, no PRNG) so
counts are reproducible; run from the repo root with ``PYTHONPATH=src``.
"""
from __future__ import annotations

import json
import sys
from typing import Callable

from .config import Config

_SMOKE = dict(chunk=256, k=128, batches=4, batch=512, remainder=100)

# Entry points whose donated/steady-state path must compile exactly once in
# the smoke workloads regardless of what the budget file says.
_EXACTLY_ONCE = (
    "incremental._update_donated",
    "incremental._update_multi_donated",
    "incremental._update_bank_donated",
    "query._dispatch",
)

# Delta-based entry points that must measure exactly zero regardless of the
# budget file: shard recovery replays the stream through already-compiled
# executables, so ``--update-budget`` must never bake in a recompile storm.
_EXACTLY_ZERO = (
    "shardtier.steady_new_compiles",
    "shardtier.recover_replay_new_compiles",
    "procshard.steady_new_compiles",
    "procshard.recover_new_compiles",
)


def _cache_size(fn) -> int:
    sizer = getattr(fn, "_cache_size", None)
    if sizer is None:
        raise RuntimeError(
            f"{fn!r} has no _cache_size(); jax's PjitFunction interface "
            "changed — update tools/reprolint/retrace.py"
        )
    return int(sizer())


def _keys(n: int, offset: int = 0):
    import numpy as np

    # Deterministic skewed keyspace (no PRNG — RPL005 applies to tools too in
    # spirit): low ids repeat often, high ids are near-distinct.
    i = np.arange(n, dtype=np.int64) + offset
    return ((i * i) % 7919 + (i % 13) * 1000).astype(np.int64)


def _audit_ingest() -> dict[str, int]:
    """Single- and multi-lane samplers over repeated same-shape batches."""
    from repro.core import incremental as inc

    s = _SMOKE
    single = inc.IncrementalSampler(4.0, k=s["k"], chunk=s["chunk"], capacity=4096)
    for b in range(s["batches"]):
        single.observe(_keys(s["batch"], b * s["batch"]))
    single.observe(_keys(s["remainder"]))
    single.finalize()
    single.finalize()  # repeat finalize: flush path must not recompile

    multi = inc.MultiSampler([2.0, 8.0], k=s["k"], chunk=s["chunk"])
    for b in range(s["batches"]):
        multi.observe(_keys(s["batch"], b * s["batch"]))
    multi.observe(_keys(s["remainder"]))
    multi.finalize()
    multi.finalize()

    return {
        "incremental._update_donated": _cache_size(inc._update_donated),
        "incremental._update_fresh": _cache_size(inc._update_fresh),
        "incremental._update_multi_donated": _cache_size(inc._update_multi_donated),
        "incremental._update_multi_fresh": _cache_size(inc._update_multi_fresh),
        "incremental._final_evict": _cache_size(inc._final_evict),
        "incremental._final_evict_multi": _cache_size(inc._final_evict_multi),
    }


def _audit_serve() -> dict[str, int]:
    """TenantBank steady-state ticks: one stacked compile for all tenants."""
    from repro.core import incremental as inc

    s = _SMOKE
    bank = inc.TenantBank([2.0, 8.0], n_tenants=3, k=64, chunk=s["chunk"])
    for rnd in range(3):
        for t in range(3):
            bank.observe(t, _keys(s["chunk"], rnd * 1000 + t))
        bank.drain()
    bank.finalize_all()
    bank.finalize_all()
    return {
        "incremental._update_bank_donated": _cache_size(inc._update_bank_donated),
        "incremental._update_bank_fresh": _cache_size(inc._update_bank_fresh),
        "incremental._final_evict_bank": _cache_size(inc._final_evict_bank),
    }


def _audit_query() -> dict[str, int]:
    """QueryEngine batches: repeated same-sized batches hit one executable."""
    from repro.core import freqfns, incremental as inc
    from repro.stats import query as Q

    s = _SMOKE
    multi = inc.MultiSampler([2.0, 8.0], k=s["k"], chunk=s["chunk"])
    multi.observe(_keys(4 * s["chunk"]))
    engine = Q.QueryEngine(multi.finalize())
    qs = [Q.Query(fn=freqfns.cap(2.0), l=2.0), Q.Query(fn=freqfns.distinct(), l=8.0),
          Q.Query(fn=freqfns.total(), l=2.0), Q.Query(fn=freqfns.cap(8.0), l=8.0)]
    engine.query_batch(qs)
    engine.query_batch(qs)  # same batch size: must reuse the executable
    return {"query._dispatch": _cache_size(Q._dispatch)}


def _audit_shardtier() -> dict[str, int]:
    """Sharded tier (stats/shardtier.py): DELTA-based compile counts.

    The tier rides the same jit entry points as the single-service plane
    (the donated chunk updates, the query dispatch), so its budgets are
    deltas, not absolutes: after a warmup pass, (a) steady-state ingest +
    query must add ZERO cache entries, and (b) kill + recover of a shard —
    checkpoint restore plus WAL replay through the ordinary observe path —
    must also add ZERO.  A nonzero delta means recovery or routing varied a
    cache key (per-shard shapes, a host scalar in the replay loop) and
    every crash would pay a recompile storm exactly when latency matters
    most."""
    import tempfile

    from repro.core import incremental as inc
    from repro.stats import query as Q
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import ShardTier, TierConfig

    s = _SMOKE
    tracked = (inc._update_multi_donated, inc._update_multi_fresh,
               inc._final_evict_multi, Q._dispatch)

    def snap() -> int:
        return sum(_cache_size(f) for f in tracked)

    with tempfile.TemporaryDirectory() as d:
        tier = ShardTier(
            StatsConfig(k=s["k"], ls=(2.0, 8.0), chunk=s["chunk"]),
            TierConfig(n_shards=2, checkpoint_every=2, retain_wal=True,
                       auto_recover=False),
            d)
        for b in range(s["batches"]):
            tier.ingest(_keys(s["batch"], b * s["batch"]))
        tier.query_cap(2.0)
        warm = snap()
        tier.ingest(_keys(s["batch"], 99_000))
        tier.query_cap(2.0)
        steady_delta = snap() - warm

        pre = snap()
        tier.kill_shard(0)
        tier.recover_shard(0)
        tier.query_cap(2.0)
        recover_delta = snap() - pre
    return {
        "shardtier.steady_new_compiles": steady_delta,
        "shardtier.recover_replay_new_compiles": recover_delta,
    }


def _audit_procshard() -> dict[str, int]:
    """Out-of-process tier (stats/procshard.py): COORDINATOR-side deltas.

    The worker subprocesses have their own jit caches (audited implicitly —
    each runs the same ShardWorker the shardtier workload covers); what this
    workload pins is the coordinator: steady-state routed ingest + merged
    queries over REAL subprocess workers must add zero cache entries after
    warmup, and a real SIGKILL + supervised restart + recover RPC —
    the process-mode recovery path — must also add ZERO coordinator-side.
    Recovery is wire + filesystem work (WAL tail check, respawn, one RPC,
    state_dict rebuild); if it starts compiling, every crash pays a
    coordinator recompile storm on top of the worker's cold start."""
    import tempfile

    from repro.core import incremental as inc
    from repro.stats import query as Q
    from repro.stats.procshard import ProcShardTier, SupervisorConfig
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import TierConfig

    s = _SMOKE
    tracked = (inc._update_multi_donated, inc._update_multi_fresh,
               inc._final_evict_multi, Q._dispatch)

    def snap() -> int:
        return sum(_cache_size(f) for f in tracked)

    with tempfile.TemporaryDirectory() as d:
        with ProcShardTier(
                StatsConfig(k=s["k"], ls=(2.0, 8.0), chunk=s["chunk"]),
                TierConfig(n_shards=2, checkpoint_every=2, retain_wal=True,
                           fsync=False, auto_recover=False),
                d, supervisor=SupervisorConfig(restart_backoff_s=0.05)) as tier:
            for b in range(s["batches"]):
                tier.ingest(_keys(s["batch"], b * s["batch"]))
            tier.query_cap(2.0)
            warm = snap()
            tier.ingest(_keys(s["batch"], 99_000))
            tier.query_cap(2.0)
            steady_delta = snap() - warm

            pre = snap()
            tier.kill_shard(0)  # real SIGKILL
            tier.recover_shard(0)  # respawn + recover RPC
            tier.query_cap(2.0)
            recover_delta = snap() - pre
    return {
        "procshard.steady_new_compiles": steady_delta,
        "procshard.recover_new_compiles": recover_delta,
    }


def _audit_chunksort() -> dict[str, int]:
    """Pallas chunk-order sort: one compile per tile config / padded shape.

    Two ragged sizes that pad to the same power-of-two P plus a repeat call
    must share ONE executable — the sort is keyed only on (cfg, interpret, P),
    so a per-call recompile here is a static-arg cache-key regression.  The
    ingest workloads never touch this path on CPU (auto dispatch routes the
    chunk sort to XLA), so the count below is exactly this workload's.
    """
    import numpy as np

    from repro.kernels.chunksort import chunksort, ops

    for n in (200, 256, 256):  # 200 and 256 both pad to P = 256
        ops.sort_with_perm(_keys(n).astype(np.int32), backend="pallas")
    return {"chunksort.sort_pairs": _cache_size(chunksort.sort_pairs)}


WORKLOADS: dict[str, Callable[[], dict[str, int]]] = {
    "ingest": _audit_ingest,
    "serve": _audit_serve,
    "query": _audit_query,
    "shardtier": _audit_shardtier,
    "procshard": _audit_procshard,
    "chunksort": _audit_chunksort,
}


def measure() -> dict[str, int]:
    counts: dict[str, int] = {}
    for name, fn in WORKLOADS.items():
        counts.update(fn())
    return counts


def main(config: Config, *, update: bool = False, stream=sys.stdout) -> int:
    from tools import reprolint as _pkg

    budget_path = config.root / config.trace_budget
    counts = measure()

    failures: list[str] = []
    for key in _EXACTLY_ONCE:
        if counts.get(key) != 1:
            failures.append(
                f"{key}: compiled {counts.get(key)}x under the smoke workload "
                "(steady-state contract is exactly 1 — a cache-key regression)"
            )
    for key in _EXACTLY_ZERO:
        if counts.get(key) != 0:
            failures.append(
                f"{key}: {counts.get(key)} new compile(s) under the smoke "
                "workload (contract is exactly 0 — recovery/steady state "
                "must reuse existing executables)"
            )

    if update:
        if failures:
            for f in failures:
                print(f"FAIL {f}", file=stream)
            print("retrace: refusing to --update-budget over a broken invariant",
                  file=stream)
            return 1
        budget_path.write_text(json.dumps({
            "version": 1,
            "reprolint_version": _pkg.__version__,
            "workload": "smoke-v1 (tools/reprolint/retrace.py)",
            "budgets": counts,
        }, indent=2) + "\n")
        print(f"retrace: wrote {budget_path} ({len(counts)} budgets)", file=stream)
        return 0

    if not budget_path.is_file():
        print(f"retrace: missing budget file {budget_path}; run with "
              "--update-budget to create it", file=stream)
        return 1
    budgets: dict[str, int] = json.loads(budget_path.read_text())["budgets"]

    for key, measured in sorted(counts.items()):
        if key not in budgets:
            failures.append(f"{key}: no committed budget (measured {measured})")
            continue
        if measured > budgets[key]:
            failures.append(
                f"{key}: compiled {measured}x > budget {budgets[key]} — "
                "retrace regression"
            )
        elif measured < budgets[key]:
            print(f"note: {key} compiled {measured}x < budget {budgets[key]}; "
                  "tighten with --update-budget", file=stream)
    for key in sorted(set(budgets) - set(counts)):
        print(f"warning: budget entry {key} not measured by any workload",
              file=stream)

    for f in failures:
        print(f"FAIL {f}", file=stream)
    print(f"retrace: {len(counts)} entry points, {len(failures)} failure(s)",
          file=stream)
    return 1 if failures else 0
