"""Distributed 2-pass sampling across an 8-device mesh: each device samples
its stream shard, states merge via log-depth ppermute butterflies (the
paper's mergeability, §3.1, as jax.lax collectives).

    PYTHONPATH=src python examples/distributed_stats.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import AxisType  # noqa: E402

from repro.core import continuous as C  # noqa: E402
from repro.core import distributed as DD  # noqa: E402
from repro.core import freqfns as F  # noqa: E402

mesh = jax.make_mesh((len(jax.devices()),), ("data",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
n = len(jax.devices()) * 65536
keys = (rng.zipf(1.3, size=n) % 100_000).astype(np.int32)
weights = np.ones(n, np.float32)

k, l = 256, 8.0
fn = DD.make_distributed_two_pass(mesh, kind="continuous", l=l, salt=3, k=k,
                                  chunk=4096, merge="tree")
skeys, sseeds, sw = map(np.asarray, fn(keys, weights))
skeys, sseeds, sw = skeys[0], sseeds[0], sw[0]

valid = skeys != 2**31 - 1
order = np.argsort(sseeds[valid])
tau = sseeds[valid][order[k]] if valid.sum() > k else np.inf
sample_w = sw[valid][order[:k]]

ukeys, cnts = np.unique(keys, return_counts=True)
for T in (1.0, 8.0, 64.0):
    est = float(np.sum(np.minimum(sample_w, T) / C.inclusion_prob(sample_w, tau, l)))
    truth = F.exact_statistic(F.cap(T), cnts)
    print(f"cap_{T:<4g} distributed estimate {est:12.0f}  truth {truth:12.0f}  "
          f"err {abs(est-truth)/truth:6.2%}")
print(f"[example] {len(jax.devices())} devices, {n} elements, k={k}, "
      f"state per device = O(k)")
