"""Distributed 2-pass sampling across an 8-device mesh: each device samples
its stream shard, states merge via log-depth ppermute butterflies (the
paper's mergeability, §3.1, as jax.lax collectives).  The multi-l program
answers every cap_T of a query grid from ONE launch — chunks are scored once
through the fused multi-l capscore kernel and all lanes reuse the hashes.

    PYTHONPATH=src python examples/distributed_stats.py

``--chaos SEED`` instead replays a seeded fault schedule against the
fault-tolerant sharded ingestion tier (stats/shardtier.py): crashes,
stalls, slow calls, and lost replies fire at scheduled call sites while
the tier ingests the same stream as a fault-free oracle; the run GATES on
the recovered tier's exact answers being bit-identical to the oracle's
(exit 1 on any divergence).  This is the CI chaos leg — a failing seed's
schedule JSON is printed so it can be committed verbatim as a regression.

    PYTHONPATH=src python examples/distributed_stats.py --chaos 11
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_mesh_demo():
    import jax
    import numpy as np

    from repro.core import continuous as C
    from repro.core import distributed as DD
    from repro.core import freqfns as F
    from repro.core.segments import EMPTY

    EMPTY_ = int(EMPTY)
    try:  # AxisType landed after jax 0.4; default axis types are equivalent
        from jax.sharding import AxisType

        mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                             axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    rng = np.random.default_rng(0)
    n = len(jax.devices()) * 65536
    keys = (rng.zipf(1.3, size=n) % 100_000).astype(np.int32)
    weights = np.ones(n, np.float32)

    k = 256
    ls = (1.0, 8.0, 64.0)
    fn = DD.make_distributed_two_pass_multi(mesh, ls=ls, salt=3, k=k,
                                            chunk=4096, merge="tree")
    mkeys, mseeds, mw = (np.asarray(a)[0] for a in fn(keys, weights))

    ukeys, cnts = np.unique(keys, return_counts=True)
    for j, (l, T) in enumerate(zip(ls, (1.0, 8.0, 64.0))):
        valid = mkeys[j] != EMPTY_
        order = np.argsort(mseeds[j][valid])
        tau = mseeds[j][valid][order[k]] if valid.sum() > k else np.inf
        sample_w = mw[j][valid][order[:k]]
        est = float(np.sum(np.minimum(sample_w, T)
                           / C.inclusion_prob(sample_w, tau, l)))
        truth = F.exact_statistic(F.cap(T), cnts)
        print(f"cap_{T:<4g} (lane l={l:<4g}) distributed estimate "
              f"{est:12.0f}  truth {truth:12.0f}  "
              f"err {abs(est-truth)/truth:6.2%}")
    print(f"[example] {len(jax.devices())} devices, {n} elements, k={k}, "
          f"|ls|={len(ls)} lanes in one launch, state per device = "
          f"O(k * |ls|)")


def run_chaos_replay(seed, n_shards=3, n_batches=10, batch=300):
    """Seeded chaos replay over the sharded tier, gated on bit-identity.

    Deterministic end to end: the stream comes from the library's
    counter-based hashing, the fault schedule is a pure function of the
    seed, and backoff runs on the injector's virtual clock — a failing
    seed replays identically anywhere.
    """
    import tempfile

    import numpy as np

    from repro.core import freqfns, hashing
    from repro.launch.faults import FaultInjector, FaultSchedule
    from repro.stats.query import Query
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import ExactUnavailable, ShardTier, TierConfig

    cfg = StatsConfig(k=128, ls=(1.0, 8.0), chunk=64)
    tier_cfg = TierConfig(n_shards=n_shards, checkpoint_every=4,
                          retain_wal=True, auto_recover=True)
    schedule = FaultSchedule.generate(seed, n_shards=n_shards, n_events=12)
    queries = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]

    eids = np.arange(n_batches * batch, dtype=np.int64)
    keys = ((hashing.hash_combine_np(eids, np.int64(seed)) % np.uint32(500))
            .astype(np.int64) + 1).reshape(n_batches, batch)

    with tempfile.TemporaryDirectory() as d:
        oracle = ShardTier(cfg, TierConfig(**vars(tier_cfg)), d + "/oracle")
        injector = FaultInjector(schedule)
        tier = ShardTier(cfg, TierConfig(**vars(tier_cfg)), d + "/tier",
                         faults=injector)
        for b in keys:
            oracle.ingest(b)
            tier.ingest(b)

        # drain the (finite) schedule with health rounds, then demand exact
        got = None
        for _ in range(20):
            try:
                got = tier.query_batch(queries, mode="exact")
                break
            except ExactUnavailable:
                for _ in range(10):
                    if all(st == "up" for st in tier.check_health().values()):
                        break
        if got is None:
            print(f"[chaos] seed {seed}: tier never reached exact mode; "
                  f"membership={tier.membership()}", file=sys.stderr)
            print(schedule.to_json(), file=sys.stderr)
            return 1
        want = oracle.query_batch(queries, mode="exact")
        if not np.array_equal(got.estimates, want.estimates):
            print(f"[chaos] seed {seed}: BIT-IDENTITY VIOLATED — recovered "
                  f"tier answers {got.estimates} vs fault-free oracle "
                  f"{want.estimates}.  Regression schedule:",
                  file=sys.stderr)
            print(schedule.to_json(), file=sys.stderr)
            return 1
        n_down = sum(1 for _, _, ev, _ in tier.events if ev == "down")
        print(f"[chaos] seed {seed}: {len(injector.fired)} faults fired "
              f"({n_down} shard-down episodes) across {n_shards} shards / "
              f"{n_batches * batch} elements; exact answers bit-identical "
              f"to the fault-free oracle: {got.estimates}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", type=int, metavar="SEED", default=None,
                    nargs="+",
                    help="replay seeded fault schedule(s) against the "
                         "sharded tier; exits 1 unless the recovered exact "
                         "answers are bit-identical to a fault-free oracle")
    args = ap.parse_args()
    if args.chaos is not None:
        rc = 0
        for seed in args.chaos:
            rc |= run_chaos_replay(seed)
        sys.exit(rc)
    run_mesh_demo()


if __name__ == "__main__":
    main()
