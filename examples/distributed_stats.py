"""Distributed 2-pass sampling across an 8-device mesh: each device samples
its stream shard, states merge via log-depth ppermute butterflies (the
paper's mergeability, §3.1, as jax.lax collectives).  The multi-l program
answers every cap_T of a query grid from ONE launch — chunks are scored once
through the fused multi-l capscore kernel and all lanes reuse the hashes.

    PYTHONPATH=src python examples/distributed_stats.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import continuous as C  # noqa: E402
from repro.core import distributed as DD  # noqa: E402
from repro.core import freqfns as F  # noqa: E402
from repro.core.segments import EMPTY  # noqa: E402

EMPTY = int(EMPTY)

try:  # AxisType landed after jax 0.4; default axis types are equivalent
    from jax.sharding import AxisType

    mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                         axis_types=(AxisType.Auto,))
except ImportError:
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
rng = np.random.default_rng(0)
n = len(jax.devices()) * 65536
keys = (rng.zipf(1.3, size=n) % 100_000).astype(np.int32)
weights = np.ones(n, np.float32)

k = 256
ls = (1.0, 8.0, 64.0)
fn = DD.make_distributed_two_pass_multi(mesh, ls=ls, salt=3, k=k,
                                        chunk=4096, merge="tree")
mkeys, mseeds, mw = (np.asarray(a)[0] for a in fn(keys, weights))

ukeys, cnts = np.unique(keys, return_counts=True)
for j, (l, T) in enumerate(zip(ls, (1.0, 8.0, 64.0))):
    valid = mkeys[j] != EMPTY
    order = np.argsort(mseeds[j][valid])
    tau = mseeds[j][valid][order[k]] if valid.sum() > k else np.inf
    sample_w = mw[j][valid][order[:k]]
    est = float(np.sum(np.minimum(sample_w, T) / C.inclusion_prob(sample_w, tau, l)))
    truth = F.exact_statistic(F.cap(T), cnts)
    print(f"cap_{T:<4g} (lane l={l:<4g}) distributed estimate {est:12.0f}  "
          f"truth {truth:12.0f}  err {abs(est-truth)/truth:6.2%}")
print(f"[example] {len(jax.devices())} devices, {n} elements, k={k}, "
      f"|ls|={len(ls)} lanes in one launch, state per device = O(k * |ls|)")
