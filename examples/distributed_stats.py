"""Distributed 2-pass sampling across an 8-device mesh: each device samples
its stream shard, states merge via log-depth ppermute butterflies (the
paper's mergeability, §3.1, as jax.lax collectives).  The multi-l program
answers every cap_T of a query grid from ONE launch — chunks are scored once
through the fused multi-l capscore kernel and all lanes reuse the hashes.

    PYTHONPATH=src python examples/distributed_stats.py

``--chaos SEED`` instead replays a seeded fault schedule against the
fault-tolerant sharded ingestion tier (stats/shardtier.py): crashes,
stalls, slow calls, and lost replies fire at scheduled call sites while
the tier ingests the same stream as a fault-free oracle; the run GATES on
the recovered tier's exact answers being bit-identical to the oracle's
(exit 1 on any divergence).  This is the CI chaos leg — a failing seed's
schedule JSON is printed so it can be committed verbatim as a regression.

    PYTHONPATH=src python examples/distributed_stats.py --chaos 11

``--soak SEED`` runs the out-of-process tier (stats/procshard.py): 4 REAL
worker subprocesses behind the supervisor, a seeded chaos schedule realized
physically (SIGKILL / socket partitions / stalls) while a million-element
keyed stream ingests WAL-first, the background exact-merge cadence
refreshing snapshots throughout.  The run polls the flexlb-style status
plane (``ShardTier.status()``) on a fixed cadence into a JSON event log
(``--soak-out``) and GATES on post-soak exact answers being bit-identical
to a fault-free in-process oracle over the same stream (exit 1 otherwise,
printing the committable failing schedule).  ``--soak-time-box`` stops
ingesting new batches past the budget — verification still runs over
whatever was ingested, so a time-boxed CI leg gates the same contract.

    PYTHONPATH=src python examples/distributed_stats.py --soak 7 \
        --soak-elements 1000000 --soak-out soak_events.json
"""
import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_mesh_demo():
    import jax
    import numpy as np

    from repro.core import continuous as C
    from repro.core import distributed as DD
    from repro.core import freqfns as F
    from repro.core.segments import EMPTY

    EMPTY_ = int(EMPTY)
    try:  # AxisType landed after jax 0.4; default axis types are equivalent
        from jax.sharding import AxisType

        mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                             axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    rng = np.random.default_rng(0)
    n = len(jax.devices()) * 65536
    keys = (rng.zipf(1.3, size=n) % 100_000).astype(np.int32)
    weights = np.ones(n, np.float32)

    k = 256
    ls = (1.0, 8.0, 64.0)
    fn = DD.make_distributed_two_pass_multi(mesh, ls=ls, salt=3, k=k,
                                            chunk=4096, merge="tree")
    mkeys, mseeds, mw = (np.asarray(a)[0] for a in fn(keys, weights))

    ukeys, cnts = np.unique(keys, return_counts=True)
    for j, (l, T) in enumerate(zip(ls, (1.0, 8.0, 64.0))):
        valid = mkeys[j] != EMPTY_
        order = np.argsort(mseeds[j][valid])
        tau = mseeds[j][valid][order[k]] if valid.sum() > k else np.inf
        sample_w = mw[j][valid][order[:k]]
        est = float(np.sum(np.minimum(sample_w, T)
                           / C.inclusion_prob(sample_w, tau, l)))
        truth = F.exact_statistic(F.cap(T), cnts)
        print(f"cap_{T:<4g} (lane l={l:<4g}) distributed estimate "
              f"{est:12.0f}  truth {truth:12.0f}  "
              f"err {abs(est-truth)/truth:6.2%}")
    print(f"[example] {len(jax.devices())} devices, {n} elements, k={k}, "
          f"|ls|={len(ls)} lanes in one launch, state per device = "
          f"O(k * |ls|)")


def run_chaos_replay(seed, n_shards=3, n_batches=10, batch=300):
    """Seeded chaos replay over the sharded tier, gated on bit-identity.

    Deterministic end to end: the stream comes from the library's
    counter-based hashing, the fault schedule is a pure function of the
    seed, and backoff runs on the injector's virtual clock — a failing
    seed replays identically anywhere.
    """
    import tempfile

    import numpy as np

    from repro.core import freqfns, hashing
    from repro.launch.faults import FaultInjector, FaultSchedule
    from repro.stats.query import Query
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import ExactUnavailable, ShardTier, TierConfig

    cfg = StatsConfig(k=128, ls=(1.0, 8.0), chunk=64)
    tier_cfg = TierConfig(n_shards=n_shards, checkpoint_every=4,
                          retain_wal=True, auto_recover=True)
    schedule = FaultSchedule.generate(seed, n_shards=n_shards, n_events=12)
    queries = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]

    eids = np.arange(n_batches * batch, dtype=np.int64)
    keys = ((hashing.hash_combine_np(eids, np.int64(seed)) % np.uint32(500))
            .astype(np.int64) + 1).reshape(n_batches, batch)

    with tempfile.TemporaryDirectory() as d:
        oracle = ShardTier(cfg, TierConfig(**vars(tier_cfg)), d + "/oracle")
        injector = FaultInjector(schedule)
        tier = ShardTier(cfg, TierConfig(**vars(tier_cfg)), d + "/tier",
                         faults=injector)
        for b in keys:
            oracle.ingest(b)
            tier.ingest(b)

        # drain the (finite) schedule with health rounds, then demand exact
        got = None
        for _ in range(20):
            try:
                got = tier.query_batch(queries, mode="exact")
                break
            except ExactUnavailable:
                for _ in range(10):
                    if all(st == "up" for st in tier.check_health().values()):
                        break
        if got is None:
            print(f"[chaos] seed {seed}: tier never reached exact mode; "
                  f"membership={tier.membership()}", file=sys.stderr)
            print(schedule.to_json(), file=sys.stderr)
            return 1
        want = oracle.query_batch(queries, mode="exact")
        if not np.array_equal(got.estimates, want.estimates):
            print(f"[chaos] seed {seed}: BIT-IDENTITY VIOLATED — recovered "
                  f"tier answers {got.estimates} vs fault-free oracle "
                  f"{want.estimates}.  Regression schedule:",
                  file=sys.stderr)
            print(schedule.to_json(), file=sys.stderr)
            return 1
        n_down = sum(1 for _, _, ev, _ in tier.events if ev == "down")
        print(f"[chaos] seed {seed}: {len(injector.fired)} faults fired "
              f"({n_down} shard-down episodes) across {n_shards} shards / "
              f"{n_batches * batch} elements; exact answers bit-identical "
              f"to the fault-free oracle: {got.estimates}")
    return 0


def run_soak(seed, *, n_shards=4, elements=1_000_000, batch=8192,
             time_box_s=None, out_path=None, n_events=24,
             merge_every_n_batches=24, status_every=8):
    """Seeded multi-process soak over the out-of-process tier, gated on
    post-soak exact bit-identity against a fault-free in-process oracle.

    Everything is derived from ``seed``: the keyed stream (counter-based
    hashing), the chaos schedule (PROC_KINDS — crashes are real SIGKILLs,
    partitions sever real sockets), and therefore the entire run.  The
    status plane is sampled every ``status_every`` batches into a JSON
    event log consumable by dashboards (and uploaded by the CI soak job).
    """
    import tempfile

    import numpy as np

    from repro.core import freqfns, hashing
    from repro.launch.faults import (PROC_KINDS, FaultInjector,
                                     FaultSchedule, WallClock)
    from repro.stats.procshard import ProcShardTier, SupervisorConfig
    from repro.stats.query import Query
    from repro.stats.service import StatsConfig
    from repro.stats.shardtier import ShardTier, TierConfig

    cfg = StatsConfig(k=128, ls=(1.0, 8.0), chunk=1024)
    tier_cfg = TierConfig(n_shards=n_shards, checkpoint_every=8,
                          retain_wal=True, auto_recover=True,
                          backoff_base_s=0.02, call_deadline_s=10.0,
                          merge_every_n_batches=merge_every_n_batches)
    n_batches = (elements + batch - 1) // batch
    # spread events across the whole run: call_no up to ~the apply count a
    # single shard sees, tiny latencies (wall clock — stalls really sleep)
    schedule = FaultSchedule.generate(
        seed, n_shards=n_shards, n_events=n_events, kinds=PROC_KINDS,
        max_call_no=max(8, n_batches // 2), max_latency_s=0.05)
    queries = [Query(freqfns.distinct()), Query(freqfns.cap(8.0)),
               Query(freqfns.total())]

    t0 = time.monotonic()
    log_obj = {
        "schema": 1, "seed": seed, "n_shards": n_shards,
        "elements_requested": elements, "batch": batch,
        "merge_every_n_batches": merge_every_n_batches,
        "schedule": json.loads(schedule.to_json()),
        "status_samples": [], "result": None,
    }

    def stream_batch(i):
        eids = np.arange(i * batch, (i + 1) * batch, dtype=np.int64)
        keys = ((hashing.hash_combine_np(eids, np.int64(seed))
                 % np.uint32(1_000_000)).astype(np.int64) + 1)
        return keys

    def finish(rc, detail, got=None, tier=None):
        log_obj["result"] = {
            "ok": rc == 0, "detail": detail,
            "elapsed_s": round(time.monotonic() - t0, 3),
            "estimates": None if got is None else
                         [float(x) for x in got.estimates],
        }
        if tier is not None:
            log_obj["final_status"] = tier.status(events_tail=256)
        if out_path:
            with open(out_path, "w") as f:
                json.dump(log_obj, f, indent=2)
            print(f"[soak] event log -> {out_path}")
        if rc != 0:
            print(f"[soak] seed {seed}: FAILED — {detail}.  "
                  "Committable regression schedule:", file=sys.stderr)
            print(schedule.to_json(), file=sys.stderr)
        return rc

    with tempfile.TemporaryDirectory() as d:
        injector = FaultInjector(schedule, clock=WallClock())
        tier = ProcShardTier(cfg, tier_cfg, d + "/tier", faults=injector,
                             supervisor=SupervisorConfig(
                                 max_restarts=max(8, n_events),
                                 restart_backoff_s=0.05))
        ingested = []
        try:
            for i in range(n_batches):
                if time_box_s is not None and time.monotonic() - t0 > time_box_s:
                    print(f"[soak] time box {time_box_s}s hit after {i} "
                          f"batches ({i * batch} elements); verifying what "
                          "was ingested")
                    break
                b = stream_batch(i)
                tier.ingest(b)
                ingested.append(b)
                if i % status_every == 0:
                    st = tier.status()
                    st["batch_no"] = i
                    st["elapsed_s"] = round(time.monotonic() - t0, 3)
                    log_obj["status_samples"].append(st)
                if i % 4 == 3:
                    tier.check_health()

            # post-soak: converge membership, then demand exact
            for _ in range(30):
                if all(s == "up" for s in tier.slots):
                    break
                tier.check_health()
            if not all(s == "up" for s in tier.slots):
                return finish(1, f"membership never converged: "
                                 f"{tier.membership()}", tier=tier)
            got = tier.query_batch(queries, mode="exact")
            fired = [f"{e.site}:{e.kind}" for e in injector.fired]
            n_down = sum(1 for _, _, ev, _ in tier.events if ev == "down")
            st = tier.status()
        finally:
            tier.close()

        oracle = ShardTier(
            cfg, TierConfig(n_shards=n_shards, checkpoint_every=8,
                            retain_wal=True, fsync=False), d + "/oracle")
        for b in ingested:
            oracle.ingest(b)
        want = oracle.query_batch(queries, mode="exact")
        if not np.array_equal(got.estimates, want.estimates):
            return finish(
                1, f"POST-SOAK BIT-IDENTITY VIOLATED: {got.estimates} vs "
                   f"oracle {want.estimates}", got=got)
        detail = (f"{len(ingested) * batch} elements over {n_shards} worker "
                  f"processes; {len(fired)} faults realized ({n_down} "
                  f"shard-down episodes, {st['merges']['done']} exact "
                  f"merges, {st['merges']['skipped']} skipped); exact "
                  "answers bit-identical to the fault-free oracle")
        log_obj["fired"] = fired
        log_obj["final_status"] = st
        print(f"[soak] seed {seed}: {detail}: {got.estimates}")
        return finish(0, detail, got=got)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", type=int, metavar="SEED", default=None,
                    nargs="+",
                    help="replay seeded fault schedule(s) against the "
                         "sharded tier; exits 1 unless the recovered exact "
                         "answers are bit-identical to a fault-free oracle")
    ap.add_argument("--soak", type=int, metavar="SEED", default=None,
                    help="multi-process soak: real subprocess workers, "
                         "physical chaos, status-plane event log, gated on "
                         "post-soak exact bit-identity")
    ap.add_argument("--soak-elements", type=int, default=1_000_000)
    ap.add_argument("--soak-shards", type=int, default=4)
    ap.add_argument("--soak-time-box", type=float, default=None,
                    metavar="SECONDS",
                    help="stop ingesting past this budget; verification "
                         "still gates over what was ingested")
    ap.add_argument("--soak-out", default=None, metavar="PATH",
                    help="write the status-plane event log JSON here")
    args = ap.parse_args()
    if args.soak is not None:
        sys.exit(run_soak(args.soak, n_shards=args.soak_shards,
                          elements=args.soak_elements,
                          time_box_s=args.soak_time_box,
                          out_path=args.soak_out))
    if args.chaos is not None:
        rc = 0
        for seed in args.chaos:
            rc |= run_chaos_replay(seed)
        sys.exit(rc)
    run_mesh_demo()


if __name__ == "__main__":
    main()
