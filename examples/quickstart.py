"""Quickstart: frequency-cap statistics over a stream in ten lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import estimators, freqfns, vectorized
from repro.data.streams import zipf_keys

# an unaggregated stream: 200k elements, Zipf-popular keys (users, queries...)
rng = np.random.default_rng(0)
keys = zipf_keys(rng, 200_000, alpha=1.3, n_keys=100_000)

# one pass, O(k) state: fixed-size continuous SH_l sample tuned for cap_10
sample = vectorized.sample_fixed_k(keys, k=512, l=10.0, salt=42)

# estimate any frequency statistic from the same sample
truth_keys, truth_counts = np.unique(keys, return_counts=True)
for fn in (freqfns.distinct(), freqfns.cap(10), freqfns.total()):
    est = estimators.estimate(sample, fn)
    truth = freqfns.exact_statistic(fn, truth_counts)
    print(f"{fn.name:10s} estimate {est:12.0f}   truth {truth:12.0f}   "
          f"err {abs(est-truth)/truth:6.2%}   (from the l=10 sample)")

# the paper's rule: match l to the cap T you care about.  Distinct = cap_1,
# so an l=1 (distinct-sampling) sketch nails it where the l=10 one cannot:
s1 = vectorized.sample_fixed_k(keys, k=512, l=1.0, salt=42)
est = estimators.estimate(s1, freqfns.distinct())
truth = len(truth_keys)
print(f"{'distinct':10s} estimate {est:12.0f}   truth {truth:12.0f}   "
      f"err {abs(est-truth)/truth:6.2%}   (from an l=1 sample)")

# segment query: keys divisible by 7 (an audience segment)
seg = lambda k: k % 7 == 0
est = estimators.estimate(sample, freqfns.cap(10), segment=seg)
truth = freqfns.exact_statistic(freqfns.cap(10), truth_counts[truth_keys % 7 == 0])
print(f"{'cap10|seg':10s} estimate {est:12.0f}   truth {truth:12.0f}   "
      f"err {abs(est-truth)/truth:6.2%}")
