"""Quickstart: frequency-cap statistics over a stream in ten lines.

    PYTHONPATH=src python examples/quickstart.py

The sampler is *incremental*: feed batches as they arrive, keep O(k) state,
finalize whenever you want an answer — no stream buffering anywhere.
"""
import numpy as np

from repro.core import estimators, freqfns
from repro.core.incremental import IncrementalSampler
from repro.data.streams import zipf_keys

# an unaggregated stream: 200k elements, Zipf-popular keys (users, queries...)
rng = np.random.default_rng(0)
keys = zipf_keys(rng, 200_000, alpha=1.3, n_keys=100_000)

# fixed-size continuous SH_l sampler tuned for cap_10: one pass, O(k) state.
# Batches stream through a single jitted, donated-buffer update; the sampler
# never holds more than k + chunk entries no matter how long the stream runs.
sampler = IncrementalSampler(l=10.0, k=512, salt=42)
for i in range(0, len(keys), 8192):          # as an input pipeline would
    sampler.observe(keys[i : i + 8192])
sample = sampler.finalize()                   # non-destructive: keep streaming

# estimate any frequency statistic from the same sample
truth_keys, truth_counts = np.unique(keys, return_counts=True)
for fn in (freqfns.distinct(), freqfns.cap(10), freqfns.total()):
    est = estimators.estimate(sample, fn)
    truth = freqfns.exact_statistic(fn, truth_counts)
    print(f"{fn.name:10s} estimate {est:12.0f}   truth {truth:12.0f}   "
          f"err {abs(est-truth)/truth:6.2%}   (from the l=10 sample)")

# the paper's rule: match l to the cap T you care about.  Distinct = cap_1,
# so an l=1 (distinct-sampling) sketch nails it where the l=10 one cannot:
s1 = IncrementalSampler(l=1.0, k=512, salt=42)
s1.observe(keys)
est = estimators.estimate(s1.finalize(), freqfns.distinct())
truth = len(truth_keys)
print(f"{'distinct':10s} estimate {est:12.0f}   truth {truth:12.0f}   "
      f"err {abs(est-truth)/truth:6.2%}   (from an l=1 sample)")

# segment query: keys divisible by 7 (an audience segment)
seg = lambda k: k % 7 == 0
est = estimators.estimate(sample, freqfns.cap(10), segment=seg)
truth = freqfns.exact_statistic(freqfns.cap(10), truth_counts[truth_keys % 7 == 0])
print(f"{'cap10|seg':10s} estimate {est:12.0f}   truth {truth:12.0f}   "
      f"err {abs(est-truth)/truth:6.2%}")

# need a whole l-grid (any cap T on demand)?  that's the StreamStatsService:
# one observe() advances every sketch in a single device dispatch — see
# examples/ad_campaign_stats.py.
