"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU, with checkpoint/restart and stream statistics — the same launcher that
lowers the production cells at 512 chips.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="lm100m")
    args = ap.parse_args()

    # a ~100M-parameter dense config (registered ad hoc — the assigned archs
    # are multi-billion scale; this one actually trains on this CPU)
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models.transformer import TransformerConfig

    cfg100 = TransformerConfig(
        name="lm100m", n_layers=8, d_model=512, n_heads=8, n_kv=4,
        d_ff=2048, vocab=32000, dtype=jnp.float32, attention_chunk=128,
    )
    registry._MODULES["lm100m"] = type(
        "M", (), {"ARCH_ID": "lm100m", "FAMILY": "lm",
                  "full_config": staticmethod(lambda: cfg100),
                  "smoke_config": staticmethod(lambda: cfg100)},
    )

    with tempfile.TemporaryDirectory() as d:
        losses = run(
            "lm100m", smoke=True, steps=args.steps, batch=8, seq=256,
            ckpt_dir=d, ckpt_every=100, lr=6e-4, log_every=20,
        )
    drop = losses[0] - sum(losses[-10:]) / 10
    print(f"[example] loss drop over {args.steps} steps: {drop:.3f} "
          f"({'LEARNING' if drop > 0.3 else 'check hyperparameters'})")


if __name__ == "__main__":
    main()
