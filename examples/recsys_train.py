"""RecSys training with the paper's technique in the loop:

* SH_l sketches over the impression stream estimate item frequencies;
* the two-tower sampled softmax uses them for logQ correction;
* the sketch's hot keys drive the hot/cold embedding split.

    PYTHONPATH=src python examples/recsys_train.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.core import estimators, freqfns  # noqa: E402
from repro.data.recsys_events import impression_batch  # noqa: E402
from repro.models import recsys as R  # noqa: E402
from repro.models.embedding_sharding import plan_hot_cold  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.stats.service import StatsConfig, StreamStatsService  # noqa: E402

cfg = registry.get_config("two-tower-retrieval", smoke=True)
rng = np.random.default_rng(0)
params = R.twotower_init(jax.random.PRNGKey(0), cfg)
opt_cfg = adamw.AdamWConfig(lr=3e-3, total_steps=200, warmup=10)
opt_state = adamw.init_state(params)

stats = StreamStatsService(StatsConfig(k=512, ls=(1.0, 8.0, 64.0), chunk=512))


@jax.jit
def step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(R.twotower_loss)(params, cfg, batch)
    params, opt_state, _ = adamw.update(opt_cfg, params, grads, opt_state)
    return params, opt_state, loss


losses = []
total_seen = 0
for i in range(150):
    raw = impression_batch(rng, batch=64, seq_len=cfg.seq_len,
                           n_items=cfg.n_items, n_users=cfg.n_users)
    stats.observe(raw["target"])          # item-frequency sketch
    total_seen += len(raw["target"])

    # logQ correction from the sketch: q_j ~ freq_j / total  (the paper's
    # estimator supplies freq_j without aggregating the stream)
    sketch = stats.sketches()[8.0]
    d = sketch.asdict()
    freq = np.array([d.get(int(t), 1.0) for t in raw["target"]])
    logq = np.log(freq / max(total_seen, 1))

    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    batch["logq"] = jnp.asarray(logq, jnp.float32)
    params, opt_state, loss = step(params, opt_state, batch)
    losses.append(float(loss))
    if (i + 1) % 30 == 0:
        print(f"step {i+1:4d} loss {np.mean(losses[-30:]):.4f}")

print(f"[example] loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
      f"({'LEARNING' if losses[0] - np.mean(losses[-10:]) > 0.1 else 'flat'})")

plan = plan_hot_cold(stats, n_hot=64)
print(f"[example] hot/cold plan: {len(plan.hot_ids_sorted)} hot keys, "
      f"estimated hot-traffic share {plan.est_hot_traffic_frac:.1%}")
