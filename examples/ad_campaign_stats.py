"""The paper's motivating application: ad-campaign frequency-cap forecasting.

An advertiser asks: "with a cap of T impressions per user, how many
qualifying impressions does segment H hold?"  The StreamStatsService keeps
one fixed-k SH_l sketch per l of a geometric grid over the live impression
stream and answers interactively for any (T, segment).

The service is fully incremental: each observe() advances *all* sketches in
one jitted device dispatch (fused multi-l scoring + vmapped merge/evict),
resident state is O(k * |ls|) — independent of how many impressions have
flowed through — and the same fixed-size pytree checkpoints and resumes the
stream bit-for-bit.

    PYTHONPATH=src python examples/ad_campaign_stats.py
"""
import numpy as np

from repro.core import freqfns
from repro.data.recsys_events import impression_batch, impression_stream_elements
from repro.stats.service import StatsConfig, StreamStatsService

rng = np.random.default_rng(1)
service = StreamStatsService(StatsConfig(k=2048, ls=(1.0, 4.0, 16.0, 64.0), chunk=2048))

# ingest a day of impressions (batched like the serving path would see them);
# nothing is buffered — each batch updates the resident sketches and is gone
all_users = []
for _ in range(40):
    batch = impression_batch(rng, batch=2048, seq_len=30, n_items=50_000, n_users=200_000)
    users, items = impression_stream_elements(batch)
    service.observe(users)          # keys = users  (frequency = impressions)
    all_users.append(users)

stream = np.concatenate(all_users)  # kept here only to print ground truth
ukeys, cnts = np.unique(stream, return_counts=True)

print(f"observed {service.n_observed:,} impressions; resident service state "
      f"{service.resident_bytes/1e6:.2f} MB (O(k*|ls|), flat in stream length;"
      f" raw stream would be {stream.nbytes/1e6:.1f} MB and growing)")

print("\ncampaign forecasts (qualifying impressions under per-user cap T):")
print(f"{'cap T':>6} {'segment':>22} {'forecast':>12} {'truth':>12} {'err':>8}")
for T in (1, 4, 16):
    for seg_name, seg in (("all users", None), ("user_id % 3 == 0", lambda k: k % 3 == 0)):
        est = service.campaign_forecast(T, segment=seg)
        mask = np.ones(len(ukeys), bool) if seg is None else (ukeys % 3 == 0)
        truth = freqfns.exact_statistic(freqfns.cap(T), cnts[mask])
        print(f"{T:>6} {seg_name:>22} {est:>12.0f} {truth:>12.0f} "
              f"{abs(est-truth)/truth:>8.2%}")

print(f"\nreach (distinct users): {service.query_distinct():.0f} "
      f"(truth {len(ukeys)})")
print(f"total impressions:      {service.query_total():.0f} (truth {len(stream)})")

# the fixed-size state checkpoints with the training state and resumes the
# stream mid-flight (atomic commit via checkpoint.manager):
import tempfile

with tempfile.TemporaryDirectory() as d:
    service.save_checkpoint(d, step=1)
    restored = StreamStatsService(service.config)
    restored.restore_checkpoint(d)
    assert restored.campaign_forecast(4) == service.campaign_forecast(4)
    print("\ncheckpoint roundtrip: OK (payload is the O(k*|ls|) sketch pytree)")

# hot keys drive the embedding-table hot/cold split (models/embedding_sharding)
hot = service.hot_keys(10)
true_hot = ukeys[np.argsort(-cnts)[:50]]
print(f"hot-key precision@10 vs true top-50: "
      f"{np.isin(hot, true_hot).mean():.0%}")
