"""The paper's motivating application: ad-campaign frequency-cap forecasting.

An advertiser asks: "with a cap of T impressions per user, how many
qualifying impressions does segment H hold?" — for MANY (T, H) cells at
once: a forecast grid over cap levels x audience segments.  The
StreamStatsService keeps one fixed-k SH_l sketch per l of a geometric grid
over the live impression stream, and ``query_batch`` answers the whole grid
in ONE jitted device dispatch over the stacked sketches (stats/query.py),
bit-identical to looping the scalar estimators, with a variance-based 95%
CI per cell.

The service is fully incremental: each observe() advances *all* sketches in
one jitted device dispatch (fused multi-l scoring + vmapped merge/evict),
resident state is O(k * |ls|) — independent of how many impressions have
flowed through — and the same fixed-size pytree checkpoints and resumes the
stream bit-for-bit.

    PYTHONPATH=src python examples/ad_campaign_stats.py
"""
import numpy as np

from repro.core import freqfns
from repro.core.segments import HashBucket, Predicate
from repro.data.recsys_events import impression_batch, impression_stream_elements
from repro.stats.query import Query
from repro.stats.service import StatsConfig, StreamStatsService

rng = np.random.default_rng(1)
service = StreamStatsService(StatsConfig(k=2048, ls=(1.0, 4.0, 16.0, 64.0), chunk=2048))

# ingest a day of impressions (batched like the serving path would see them);
# nothing is buffered — each batch updates the resident sketches and is gone
all_users = []
for _ in range(40):
    batch = impression_batch(rng, batch=2048, seq_len=30, n_items=50_000, n_users=200_000)
    users, items = impression_stream_elements(batch)
    service.observe(users)          # keys = users  (frequency = impressions)
    all_users.append(users)

stream = np.concatenate(all_users)  # kept here only to print ground truth
ukeys, cnts = np.unique(stream, return_counts=True)

print(f"observed {service.n_observed:,} impressions; resident service state "
      f"{service.resident_bytes/1e6:.2f} MB (O(k*|ls|), flat in stream length;"
      f" raw stream would be {stream.nbytes/1e6:.1f} MB and growing)")

# -- the many-T many-segment forecast grid, one batched dispatch -------------
caps = (1, 2, 4, 8, 16, 64)
segments = [("all users", None),
            ("user_id % 3 == 0", Predicate(lambda k: k % 3 == 0, "mod3")),
            ("audience bucket 0/4", HashBucket(4, 0)),
            ("audience bucket 1/4", HashBucket(4, 1))]
grid = [Query(freqfns.cap(float(T)), seg) for T in caps for _, seg in segments]
forecast = service.query_batch(grid)   # ONE jitted dispatch for all 24 cells

print(f"\ncampaign forecast grid ({len(grid)} (T x segment) cells in one "
      "batched dispatch):")
print(f"{'cap T':>6} {'segment':>20} {'forecast':>10} {'95% CI':>19} "
      f"{'truth':>10} {'err':>7}")
for i, q in enumerate(grid):
    T = q.fn.param
    name, seg = segments[i % len(segments)]
    mask = (np.ones(len(ukeys), bool) if seg is None
            else np.asarray(seg.mask_np(ukeys)))
    truth = freqfns.exact_statistic(freqfns.cap(T), cnts[mask])
    est, lo, hi = (float(forecast.estimates[i]), float(forecast.ci_low[i]),
                   float(forecast.ci_high[i]))
    print(f"{T:>6g} {name:>20} {est:>10.0f} [{lo:>8.0f},{hi:>8.0f}] "
          f"{truth:>10.0f} {abs(est-truth)/max(truth,1):>7.2%}")

print(f"\nreach (distinct users): {service.query_distinct():.0f} "
      f"(truth {len(ukeys)})")
print(f"total impressions:      {service.query_total():.0f} (truth {len(stream)})")

# the fixed-size state checkpoints with the training state and resumes the
# stream mid-flight (atomic commit via checkpoint.manager):
import tempfile

with tempfile.TemporaryDirectory() as d:
    service.save_checkpoint(d, step=1)
    restored = StreamStatsService(service.config)
    restored.restore_checkpoint(d)
    assert restored.campaign_forecast(4) == service.campaign_forecast(4)
    print("\ncheckpoint roundtrip: OK (payload is the O(k*|ls|) sketch pytree)")

# hot keys drive the embedding-table hot/cold split (models/embedding_sharding)
hot = service.hot_keys(10)
true_hot = ukeys[np.argsort(-cnts)[:50]]
print(f"hot-key precision@10 vs true top-50: "
      f"{np.isin(hot, true_hot).mean():.0%}")
