"""Backend-aware tile/block registry for the chunk-step Pallas kernels.

One ``TileConfig`` per (kernel entry point, backend flavor) pair replaces the
hardcoded ``(8, 128)`` / ``(1, 256)`` block shapes that previously lived in
``capscore.py``: the Mosaic TPU flavor keeps the f32-native ``(8, 128)``
element tile and the sublane-aligned aggregate window, the Triton GPU flavor
trades sublane structure for wide 1-D blocks with a deeper software pipeline
(``num_stages``), and the interpret flavor mirrors the TPU shapes so CPU
correctness runs exercise the exact block decomposition the compiled path
uses.

The config is hashable (frozen dataclass of scalars/tuples) so the kernels
take it as a static jit argument — each distinct tile config is a distinct
compile, which is exactly what the reprolint retrace budgets meter
("compile exactly once per tile config").

This module must stay import-light (jax + stdlib only): ``core/segments.py``
and the chunksort package pull ``resolve_backend``/``tile_config`` from here,
and ``capscore.py`` builds its grids from it, so any heavier import would
cycle.
"""
from __future__ import annotations

import dataclasses
import math

import jax

#: flavors a TileConfig can target.  'interpret' covers every platform
#: without a compiled Pallas route (CPU today); the shapes still matter
#: there because tests pin the block decomposition bit-for-bit.
FLAVORS = ("tpu", "gpu", "interpret")


def detect_flavor() -> str:
    """Map the active jax platform onto a tile-registry flavor."""
    plat = jax.default_backend()
    return plat if plat in ("tpu", "gpu") else "interpret"


def resolve_backend(backend: str | None) -> str:
    """Validate + default the kernel dispatch route ('xla' | 'pallas').

    ``None`` (auto) selects the compiled Pallas route on accelerators with a
    real lowering (Mosaic on TPU, Triton on GPU) and the XLA reference route
    everywhere else.  Raising on unknown strings matters now that the knob is
    user-facing (StatsConfig.ingest_backend / SamplerSpec.backend): a typo
    like 'XLA' must not silently select the interpret-mode Pallas path.
    """
    if backend is None:
        return "pallas" if jax.default_backend() in ("tpu", "gpu") else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(
            f"unknown kernel backend {backend!r}: use None (auto), 'xla' "
            "or 'pallas'")
    return backend


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Per-backend block/tile parameters for one Pallas entry point.

    block:   element block shape per grid step — (rows, lanes) for the
             element-stream kernels, (1, bn) for the sorted-aggregate kernel,
             (b,) for the chunksort block kernel.
    align:   sublane alignment of dynamic output-row windows (the aggregate
             kernel rounds its window start down to a multiple of this; the
             window gets ``align`` slack rows).
    num_stages: software-pipeline depth for the streamed element blocks —
             2 is classic double buffering (block i+1 DMAs while block i
             computes; Mosaic's grid pipeline and Triton's num_stages both
             consume this).
    scalar_prefetch: True routes scalars through Mosaic's SMEM prefetch
             (``PrefetchScalarGridSpec``); False passes them as a plain
             leading operand (the Triton path has no SMEM prefetch).
    """

    kernel: str
    backend: str
    block: tuple[int, ...]
    align: int = 8
    num_stages: int = 2
    scalar_prefetch: bool = True

    def __post_init__(self):
        assert self.backend in FLAVORS, self.backend

    @property
    def elements(self) -> int:
        """Elements consumed per grid step (the padding quantum)."""
        return math.prod(self.block)

    @property
    def compiled(self) -> bool:
        """Whether this flavor has a real (non-interpret) lowering."""
        return self.backend in ("tpu", "gpu")

    def describe(self) -> dict:
        """JSON-safe stamp for BENCH_ingest schema v4 records."""
        return {
            "block": list(self.block),
            "align": self.align,
            "num_stages": self.num_stages,
            "scalar_prefetch": self.scalar_prefetch,
            "flavor": self.backend,
        }


_REGISTRY: dict[tuple[str, str], TileConfig] = {}


def register(cfg: TileConfig) -> TileConfig:
    _REGISTRY[(cfg.kernel, cfg.backend)] = cfg
    return cfg


def tile_config(kernel: str, flavor: str | None = None) -> TileConfig:
    """Look up the tile config for ``kernel`` on ``flavor`` (default: the
    detected platform flavor)."""
    f = flavor or detect_flavor()
    if f not in FLAVORS:
        f = "interpret"
    try:
        return _REGISTRY[(kernel, f)]
    except KeyError:
        raise ValueError(f"no tile config registered for {kernel!r} on {f!r}; "
                         f"known: {sorted(_REGISTRY)}") from None


def registry() -> dict[tuple[str, str], TileConfig]:
    """Read-only view of the full (kernel, flavor) -> TileConfig table."""
    return dict(_REGISTRY)


# --------------------------------------------------------------------------
# The backend matrix.  TPU shapes are the f32-native tiles the kernels were
# built around; interpret mirrors TPU so CPU test runs pin the same block
# decomposition; GPU trades the (8, 128) sublane structure for 1024-wide
# 1-D-ish blocks and a 3-deep Triton pipeline (heuristic — untuned until a
# GPU runner lands, but the plumbing is live, not dead code).
# --------------------------------------------------------------------------

# elementwise scoring stream, viewed (rows, 128)
register(TileConfig("capscore", "tpu", (8, 128)))
register(TileConfig("capscore", "gpu", (8, 128), num_stages=3,
                    scalar_prefetch=False))
register(TileConfig("capscore", "interpret", (8, 128)))

register(TileConfig("capscore_multi", "tpu", (8, 128)))
register(TileConfig("capscore_multi", "gpu", (8, 128), num_stages=3,
                    scalar_prefetch=False))
register(TileConfig("capscore_multi", "interpret", (8, 128)))

# fused score + sorted segment-reduce: (1, bn) element blocks, output row
# window bn + align.  GPU uses a narrower block: the (window x bn) one-hot
# is register/SMEM-resident per CTA and 264x256 f32 overflows it.
register(TileConfig("capscore_agg", "tpu", (1, 256)))
register(TileConfig("capscore_agg", "gpu", (1, 128), num_stages=3,
                    scalar_prefetch=False))
register(TileConfig("capscore_agg", "interpret", (1, 256)))

# chunk-order sort: block-local bitonic networks of this many (key, idx)
# pairs, then cross-block two-run merges.  No scalars -> no prefetch style.
register(TileConfig("chunksort", "tpu", (256,), scalar_prefetch=False))
register(TileConfig("chunksort", "gpu", (512,), num_stages=3,
                    scalar_prefetch=False))
register(TileConfig("chunksort", "interpret", (256,), scalar_prefetch=False))
