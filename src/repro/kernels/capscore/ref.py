"""Pure-jnp oracle for the capscore kernel (mirrors core.vectorized scoring)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import hashing as H
from ...core.samplers import SALT_ELEM, SALT_KEYBASE
from ...core.segments import EMPTY, is_live  # noqa: F401 (EMPTY re-export)

_INF = jnp.float32(jnp.inf)


def capscore_ref(keys, eids, weights, l, tau, salt):
    l = jnp.float32(l)
    tau = jnp.float32(tau)
    u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))
    kb = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt))) / l
    e = -jnp.log1p(-u)
    v = e / weights
    score = jnp.where(v <= 1.0 / l, kb, v)
    rate = jnp.maximum(1.0 / l, tau)
    delta = e / rate
    gate = jnp.where(tau * l > 1.0, True, kb < tau)
    entry = ((delta < weights) & gate).astype(jnp.int32)
    return score, delta, entry


def capscore_multi_ref(keys, eids, weights, ls, taus, salt):
    """Multi-l oracle: lane j = capscore under (ls[j], taus[j]) + KeyBase.

    Element hashes are shared across lanes (the same sharing the fused kernel
    exploits); per-lane outputs are bit-identical to single-l ``capscore_ref``.
    """
    ls = jnp.asarray(ls, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))
    ku = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt)))
    e = -jnp.log1p(-u)
    v = e / weights

    def lane(l, tau):
        inv_l = 1.0 / l
        kb = ku / l  # division, not *inv_l: bit-identical to core.vectorized.keybase
        score = jnp.where(v <= inv_l, kb, v)
        rate = jnp.maximum(inv_l, tau)
        delta = e / rate
        gate = jnp.where(tau * l > 1.0, True, kb < tau)
        entry = ((delta < weights) & gate).astype(jnp.int32)
        return score, delta, entry, kb

    return jax.vmap(lane)(ls, taus)


def capscore_agg_ref(ks, eids, ws, seg, ls, taus, salt):
    """Fused score + per-key segment reduce over a KEY-ORDERED chunk (XLA).

    Inputs are the chunk's (keys, eids, weights) pre-gathered by the shared
    ``ChunkOrder`` permutation (``ks`` ascending, EMPTY last, ``seg`` its
    segment ids).  Because element scoring is elementwise in (key, eid,
    weight) — permutation-covariant — the per-lane scores emerge already
    key-sorted, and the continuous-scheme chunk aggregation reduces them in
    the same pass: the [L, N] score/delta/entry/kb intermediates exist only
    as fusion-local values, never as materialized arrays handed between
    stages.

    Returns the per-unique-key ChunkAgg columns
        (w_total f32 [C], entered bool [L, C], contrib f32 [L, C],
         kb_min f32 [L, C], min_score f32 [L, C])
    with ``w_total`` computed once (it is lane-independent) instead of once
    per lane.  Bit-identical to ``capscore_multi_ref`` +
    ``vectorized.aggregate_continuous_scored`` on the unordered chunk: the
    segment reductions see exactly the values the gather-then-reduce path
    sees, in exactly the same order.
    """
    C = ks.shape[0]
    score, delta, entry, kb = capscore_multi_ref(ks, eids, ws, ls, taus, salt)
    live = is_live(ks)
    idx = jnp.arange(C)
    w_live = jnp.where(live, ws, 0.0)
    w_total = jax.ops.segment_sum(w_live, seg, num_segments=C)

    def lane(sc, dl, en, kbe):
        es = en.astype(bool) & live
        sc = jnp.where(live, sc, _INF)
        entry_idx = jnp.where(es, idx, C)
        first_entry = jax.ops.segment_min(entry_idx, seg, num_segments=C)
        fe = first_entry[seg]
        after = idx > fe
        at = (idx == fe) & es
        contrib_elem = jnp.where(after, ws, 0.0) + jnp.where(at, ws - dl, 0.0)
        contrib = jax.ops.segment_sum(jnp.where(live, contrib_elem, 0.0), seg,
                                      num_segments=C)
        entered = jax.ops.segment_max(es.astype(jnp.int32), seg,
                                      num_segments=C) > 0
        min_score = jax.ops.segment_min(sc, seg, num_segments=C)
        kb_min = jax.ops.segment_min(jnp.where(live, kbe, _INF), seg,
                                     num_segments=C)
        return entered, contrib, kb_min, min_score

    entered, contrib, kb_min, min_score = jax.vmap(lane)(score, delta, entry, kb)
    return w_total, entered, contrib, kb_min, min_score
