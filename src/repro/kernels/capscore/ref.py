"""Pure-jnp oracle for the capscore kernel (mirrors core.vectorized scoring)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import hashing as H
from ...core.samplers import SALT_ELEM, SALT_KEYBASE


def capscore_ref(keys, eids, weights, l, tau, salt):
    l = jnp.float32(l)
    tau = jnp.float32(tau)
    u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))
    kb = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt))) / l
    e = -jnp.log1p(-u)
    v = e / weights
    score = jnp.where(v <= 1.0 / l, kb, v)
    rate = jnp.maximum(1.0 / l, tau)
    delta = e / rate
    gate = jnp.where(tau * l > 1.0, True, kb < tau)
    entry = ((delta < weights) & gate).astype(jnp.int32)
    return score, delta, entry


def capscore_multi_ref(keys, eids, weights, ls, taus, salt):
    """Multi-l oracle: lane j = capscore under (ls[j], taus[j]) + KeyBase.

    Element hashes are shared across lanes (the same sharing the fused kernel
    exploits); per-lane outputs are bit-identical to single-l ``capscore_ref``.
    """
    ls = jnp.asarray(ls, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))
    ku = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt)))
    e = -jnp.log1p(-u)
    v = e / weights

    def lane(l, tau):
        inv_l = 1.0 / l
        kb = ku / l  # division, not *inv_l: bit-identical to core.vectorized.keybase
        score = jnp.where(v <= inv_l, kb, v)
        rate = jnp.maximum(inv_l, tau)
        delta = e / rate
        gate = jnp.where(tau * l > 1.0, True, kb < tau)
        entry = ((delta < weights) & gate).astype(jnp.int32)
        return score, delta, entry, kb

    return jax.vmap(lane)(ls, taus)
