"""Pure-jnp oracle for the capscore kernel (mirrors core.vectorized scoring)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import hashing as H
from ...core.samplers import SALT_ELEM, SALT_KEYBASE


def capscore_ref(keys, eids, weights, l, tau, salt):
    l = jnp.float32(l)
    tau = jnp.float32(tau)
    u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))
    kb = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt))) / l
    e = -jnp.log1p(-u)
    v = e / weights
    score = jnp.where(v <= 1.0 / l, kb, v)
    rate = jnp.maximum(1.0 / l, tau)
    delta = e / rate
    gate = jnp.where(tau * l > 1.0, True, kb < tau)
    entry = ((delta < weights) & gate).astype(jnp.int32)
    return score, delta, entry
