"""Pallas TPU kernel: fused element scoring for continuous SH_l (eq. 10).

The sampler hot loop is a pure elementwise pipeline

    eid --hash--> u --exp--> v ;  key --hash--> KeyBase ;
    score = v <= 1/l ? KeyBase : v ;
    Delta = -log1p(-u)/max(1/l,tau) ;  entry = Delta < w  &  regime-gate

i.e. two integer avalanche hashes + two transcendentals per element, fully
memory-bound.  Fusing it into one VMEM-resident kernel removes five HBM
round-trips (u, v, kb, score, Delta materializations) that the XLA path pays
when it can't fuse across the int->float boundary.

Layout: the element stream is viewed as (rows, 128) with (8, 128)-aligned
blocks (float32 native TPU tile); the grid walks row-blocks.  Scalars
(l, tau, salt) arrive in SMEM.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# salts must match core.samplers
from ...core.samplers import SALT_ELEM, SALT_KEYBASE
from .tiling import TileConfig, tile_config

# legacy aliases: the TPU/interpret-flavor tile shapes now live in the
# tiling registry; these remain for importers that pin the default shapes
BLOCK_ROWS = 8
LANES = 128
AGG_BN = 256
AGG_WINDOW = AGG_BN + 8

# env override for the interpret-mode default (CI / debugging): "1"/"true"
# forces interpret even on a compiled backend, "0"/"false" forces the
# compiled Mosaic/Triton path
_INTERPRET_ENV = "REPRO_CAPSCORE_INTERPRET"


def default_interpret() -> bool:
    """Pallas interpret-mode default, derived from the detected backend.

    False on a real TPU or GPU (the kernels compile through Mosaic resp.
    Triton and actually run fused), True everywhere else (interpret mode is
    the only way the kernels execute on CPU — correctness checking, not
    speed).  ``REPRO_CAPSCORE_INTERPRET=0/1`` overrides either way; the value
    is read at trace time, so set it before the first capscore call.
    """
    env = os.environ.get(_INTERPRET_ENV)
    if env is not None and env.strip():  # empty string == unset
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() not in ("tpu", "gpu")


def _compiler_params(cfg: TileConfig, interpret: bool):
    """Backend compiler params for a compiled run; None in interpret mode.

    TPU: 'arbitrary' grid semantics keep Mosaic's cross-step pipeline legal
    for the carry-accumulating aggregate kernel while still double-buffering
    the streamed element blocks.  GPU: Triton's num_stages is the software
    pipeline depth for the same streamed blocks.
    """
    if interpret or not cfg.compiled:
        return None
    if cfg.backend == "tpu":
        return pltpu.TPUCompilerParams(dimension_semantics=("arbitrary",))
    from jax.experimental.pallas import triton as plgpu
    return plgpu.TritonCompilerParams(num_stages=cfg.num_stages)


def _grid_call(kernel, *, cfg, interpret, grid, in_specs, out_specs,
               out_shape, n_scalars):
    """Build the pallas_call for one entry point under a TileConfig.

    Two grid styles, one kernel body: with ``cfg.scalar_prefetch`` the
    scalars ride Mosaic's SMEM prefetch (``PrefetchScalarGridSpec``);
    without it they arrive as a plain leading operand whose block covers the
    whole scalar vector (the Triton route — index maps use ``(i, *_)`` so
    both arities work).  Either way the kernel sees ``(scalar_ref, *refs)``.
    """
    kw = {}
    params = _compiler_params(cfg, interpret)
    if params is not None:
        kw["compiler_params"] = params
    if cfg.scalar_prefetch:
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid,
                in_specs=in_specs, out_specs=out_specs),
            out_shape=out_shape, interpret=interpret, **kw)
    scalar_spec = pl.BlockSpec((n_scalars,), lambda i, *_: (0,))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[scalar_spec] + list(in_specs), out_specs=out_specs,
        out_shape=out_shape, interpret=interpret, **kw)

import numpy as np

_C1 = np.uint32(0x7FEB352D)
_C2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)
_SEED0 = np.uint32(0x243F6A88)


def _mix32(x):
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 15)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def _combine(h, p):
    return _mix32(h ^ (p + _GOLDEN + (h << 6) + (h >> 2)))


def _u01(h):
    return ((h >> 8).astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 16777216.0)


def _capscore_kernel(scalar_ref, keys_ref, eids_ref, w_ref, score_ref, delta_ref, entry_ref):
    # scalars arrive as int32 bit patterns (exact for both floats and salts)
    l = jax.lax.bitcast_convert_type(scalar_ref[0], jnp.float32)
    tau = jax.lax.bitcast_convert_type(scalar_ref[1], jnp.float32)
    salt = scalar_ref[2].astype(jnp.uint32)

    keys = keys_ref[...].astype(jnp.uint32)
    eids = eids_ref[...].astype(jnp.uint32)
    w = w_ref[...]

    # element uniform: hash(eid, SALT_ELEM, salt)
    h = _combine(jnp.full_like(eids, _SEED0), eids)
    h = _combine(h, np.uint32(SALT_ELEM))
    h = _combine(h, salt)
    u = _u01(h)

    # KeyBase(x) = hash(key, SALT_KEYBASE, salt)/l
    hk = _combine(jnp.full_like(keys, _SEED0), keys)
    hk = _combine(hk, np.uint32(SALT_KEYBASE))
    hk = _combine(hk, salt)
    kb = _u01(hk) / l

    e = -jnp.log1p(-u)
    v = e / w
    inv_l = 1.0 / l
    score = jnp.where(v <= inv_l, kb, v)

    rate = jnp.maximum(inv_l, tau)
    delta = e / rate
    gate = jnp.where(tau * l > 1.0, True, kb < tau)
    entry = ((delta < w) & gate).astype(jnp.int32)

    score_ref[...] = score
    delta_ref[...] = delta
    entry_ref[...] = entry


@functools.partial(jax.jit, static_argnames=("interpret", "cfg"))
def capscore(keys, eids, weights, l, tau, salt, *, interpret: bool | None = None,
             cfg: TileConfig | None = None):
    """Fused scoring over a stream chunk.

    Args:
      keys, eids: int32 [N], N a multiple of the tile (use ops.capscore for
        padding).
      weights: float32 [N].
      l, tau, salt: scalars (traced ok).
      interpret: None (default) resolves via ``default_interpret()`` —
        compiled on TPU/GPU, interpret elsewhere, env-overridable.
      cfg: tile config (static); None selects the platform flavor from the
        tiling registry.
    Returns:
      (score f32[N], delta f32[N], entry int32[N]).
    """
    if interpret is None:
        interpret = default_interpret()
    if cfg is None:
        cfg = tile_config("capscore")
    br, lanes = cfg.block
    n = keys.shape[0]
    assert n % (br * lanes) == 0, n
    rows = n // lanes
    shape2d = (rows, lanes)
    keys2 = keys.reshape(shape2d)
    eids2 = eids.reshape(shape2d)
    w2 = weights.reshape(shape2d)
    scalars = jnp.concatenate(
        [
            jax.lax.bitcast_convert_type(jnp.float32(l), jnp.int32).reshape(1),
            jax.lax.bitcast_convert_type(jnp.float32(tau), jnp.int32).reshape(1),
            jnp.asarray(salt, jnp.uint32).astype(jnp.int32).reshape(1),
        ]
    )

    grid = (rows // br,)
    blk = lambda: pl.BlockSpec((br, lanes), lambda i, *_: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct(shape2d, jnp.float32),
        jax.ShapeDtypeStruct(shape2d, jnp.float32),
        jax.ShapeDtypeStruct(shape2d, jnp.int32),
    ]
    score, delta, entry = _grid_call(
        _capscore_kernel, cfg=cfg, interpret=interpret, grid=grid,
        in_specs=[blk(), blk(), blk()], out_specs=[blk(), blk(), blk()],
        out_shape=out_shape, n_scalars=3,
    )(scalars, keys2, eids2, w2)
    return score.reshape(n), delta.reshape(n), entry.reshape(n)


# ---------------------------------------------------------------------------
# Multi-l variant: score every l lane of the sketch grid in one VMEM pass
# ---------------------------------------------------------------------------


def _make_capscore_multi_kernel(n_l: int):
    """Kernel closure over the (static) number of l lanes.

    The element hashes (eid avalanche -> u, e = -log1p(-u); key avalanche ->
    Hash(x)) are computed ONCE per element block and kept VMEM-resident while
    all ``n_l`` (l, tau) lanes are scored — the per-lane work is 4 cheap
    vector ops, so the whole l-grid costs barely more than one lane.
    """

    def kernel(scalar_ref, keys_ref, eids_ref, w_ref,
               score_ref, delta_ref, entry_ref, kb_ref):
        keys = keys_ref[...].astype(jnp.uint32)
        eids = eids_ref[...].astype(jnp.uint32)
        w = w_ref[...]
        salt = scalar_ref[2 * n_l].astype(jnp.uint32)

        # shared element randomness (independent of l and tau)
        h = _combine(jnp.full_like(eids, _SEED0), eids)
        h = _combine(h, np.uint32(SALT_ELEM))
        h = _combine(h, salt)
        u = _u01(h)
        e = -jnp.log1p(-u)
        v = e / w

        hk = _combine(jnp.full_like(keys, _SEED0), keys)
        hk = _combine(hk, np.uint32(SALT_KEYBASE))
        hk = _combine(hk, salt)
        ku = _u01(hk)  # Hash(x) in (0,1); KeyBase = ku / l

        for j in range(n_l):
            l = jax.lax.bitcast_convert_type(scalar_ref[j], jnp.float32)
            tau = jax.lax.bitcast_convert_type(scalar_ref[n_l + j], jnp.float32)
            inv_l = 1.0 / l
            kb = ku / l  # division, not *inv_l: bit-identical to the XLA path
            score = jnp.where(v <= inv_l, kb, v)
            rate = jnp.maximum(inv_l, tau)
            delta = e / rate
            gate = jnp.where(tau * l > 1.0, True, kb < tau)
            entry = ((delta < w) & gate).astype(jnp.int32)
            score_ref[j] = score
            delta_ref[j] = delta
            entry_ref[j] = entry
            kb_ref[j] = kb

    return kernel


@functools.partial(jax.jit, static_argnames=("n_l", "interpret", "cfg"))
def capscore_multi(keys, eids, weights, ls, taus, salt, *, n_l: int,
                   interpret: bool | None = None,
                   cfg: TileConfig | None = None):
    """Fused multi-l scoring over a stream chunk.

    Args:
      keys, eids: int32 [N], N a multiple of the tile (use ops.capscore_multi).
      weights: float32 [N].
      ls, taus: float32 [n_l] per-lane cap parameter / current threshold.
      salt: uint32 scalar shared by all lanes.
      interpret: None (default) resolves via ``default_interpret()``.
      cfg: tile config (static); None selects the platform flavor.
    Returns:
      (score f32[n_l, N], delta f32[n_l, N], entry int32[n_l, N],
       kb f32[n_l, N]) — lane j scored under (ls[j], taus[j]).
    """
    if interpret is None:
        interpret = default_interpret()
    if cfg is None:
        cfg = tile_config("capscore_multi")
    br, lanes = cfg.block
    n = keys.shape[0]
    assert n % (br * lanes) == 0, n
    rows = n // lanes
    shape2d = (rows, lanes)
    keys2 = keys.reshape(shape2d)
    eids2 = eids.reshape(shape2d)
    w2 = weights.reshape(shape2d)
    scalars = jnp.concatenate(
        [
            jax.lax.bitcast_convert_type(jnp.asarray(ls, jnp.float32), jnp.int32).reshape(n_l),
            jax.lax.bitcast_convert_type(jnp.asarray(taus, jnp.float32), jnp.int32).reshape(n_l),
            jnp.asarray(salt, jnp.uint32).astype(jnp.int32).reshape(1),
        ]
    )

    grid = (rows // br,)
    in_blk = lambda: pl.BlockSpec((br, lanes), lambda i, *_: (i, 0))
    out_blk = lambda: pl.BlockSpec((n_l, br, lanes), lambda i, *_: (0, i, 0))
    shape3d = (n_l, rows, lanes)
    out_shape = [
        jax.ShapeDtypeStruct(shape3d, jnp.float32),
        jax.ShapeDtypeStruct(shape3d, jnp.float32),
        jax.ShapeDtypeStruct(shape3d, jnp.int32),
        jax.ShapeDtypeStruct(shape3d, jnp.float32),
    ]
    score, delta, entry, kb = _grid_call(
        _make_capscore_multi_kernel(n_l), cfg=cfg, interpret=interpret,
        grid=grid, in_specs=[in_blk(), in_blk(), in_blk()],
        out_specs=[out_blk(), out_blk(), out_blk(), out_blk()],
        out_shape=out_shape, n_scalars=2 * n_l + 1,
    )(scalars, keys2, eids2, w2)
    return (score.reshape(n_l, n), delta.reshape(n_l, n),
            entry.reshape(n_l, n), kb.reshape(n_l, n))


# ---------------------------------------------------------------------------
# Fused score + segment-reduce: the [n_l, N] intermediates never leave VMEM
# ---------------------------------------------------------------------------

# block/window sizes for the fused-aggregate kernel come from the tiling
# registry: the block-local one-hot (window x bn) and the masked reductions
# over it are the per-block working set (~0.5 MB at bn=256), the
# embedding_bag segment-sum idiom; the output row window is bn segments +
# ``align`` slack rows (the dynamic row start is rounded down to a multiple
# of ``align`` so the store stays tile-aligned; a block of bn sorted
# elements spans < bn segments)

_EMPTY_KEY = np.int32(2**31 - 1)  # == core.segments.EMPTY (int32 max)
_NO_ENTRY = np.int32(2**30)       # > any element index: "no entry event"


def _make_capscore_agg_kernel(n_l: int, bn: int, window: int, align: int):
    """Kernel closure for the fused multi-lane score + per-key aggregate.

    Consumes the chunk in KEY-SORTED order (the pre-gathered ``ChunkOrder``
    view): per grid step, one block of ``bn`` elements is scored for all
    ``n_l`` lanes entirely in VMEM, then segment-reduced into the per-key
    output columns through a block-local one-hot — sums ride the MXU
    (``onehot @ vals``, the embedding_bag idiom), mins/maxes ride the VPU as
    masked reductions.  Because ``seg`` is sorted, a block's segments span a
    contiguous id range, so each block touches one ``window``-row slice
    of the (fully VMEM-resident) outputs; the slice is read-modify-written,
    which is the **cross-block carry**: the boundary segment shared with the
    previous block combines via +/min/max, and the entered-before flag
    carried in ``ent`` decides the contrib recurrence
    ``contrib = entered_before ? contrib + block_w : block_contrib``
    (the first-entry-onward count semantics of Algorithm 4, folded left
    block by block).

    Contract vs the XLA path (``ref.capscore_agg_ref``): min/max columns and
    ``entered`` are bit-identical; the float sums (``w_total``, ``contrib``)
    are reassociated by the in-block matmul reduce, so they agree up to
    f32 summation order (tests pin mins exactly and sums to tight rtol).
    """

    def kernel(scalar_ref, keys_ref, eids_ref, w_ref, seg_ref,
               wt_ref, ent_ref, ctr_ref, kbm_ref, msc_ref):
        step = pl.program_id(0)

        @pl.when(step == 0)
        def _init():
            wt_ref[...] = jnp.zeros_like(wt_ref)
            ent_ref[...] = jnp.zeros_like(ent_ref)
            ctr_ref[...] = jnp.zeros_like(ctr_ref)
            kbm_ref[...] = jnp.full_like(kbm_ref, jnp.inf)
            msc_ref[...] = jnp.full_like(msc_ref, jnp.inf)

        keys = keys_ref[...].astype(jnp.uint32)    # (1, BN)
        eids = eids_ref[...].astype(jnp.uint32)
        w = w_ref[...]
        seg = seg_ref[...]                         # (1, BN) int32, sorted
        salt = scalar_ref[2 * n_l].astype(jnp.uint32)

        # shared element randomness (independent of l and tau)
        h = _combine(jnp.full_like(eids, _SEED0), eids)
        h = _combine(h, np.uint32(SALT_ELEM))
        h = _combine(h, salt)
        u = _u01(h)
        e = -jnp.log1p(-u)
        v = e / w

        hk = _combine(jnp.full_like(keys, _SEED0), keys)
        hk = _combine(hk, np.uint32(SALT_KEYBASE))
        hk = _combine(hk, salt)
        ku = _u01(hk)  # Hash(x) in (0,1); KeyBase = ku / l

        # reprolint: disable=RPL006 -- Pallas kernel body: compares against the
        # kernel-local np mirror of segments.EMPTY (jnp helpers don't lower
        # inside the Mosaic kernel); _EMPTY_KEY is asserted == EMPTY in tests
        live = keys_ref[...] != _EMPTY_KEY         # (1, BN)
        w_live = jnp.where(live, w, 0.0)

        # block-local one-hot over the (sublane-aligned) segment window
        s0 = seg_ref[0, 0]
        s0a = (s0 // align) * align
        local = seg - s0a                          # (1, BN) in [0, window)
        oh = (jax.lax.broadcasted_iota(jnp.int32, (window, bn), 0)
              == local)                            # (W, BN) bool
        ohf = oh.astype(jnp.float32)
        rows = pl.ds(s0a, window)

        seg_sum = lambda vals: jax.lax.dot_general(  # (1, BN) -> (W, 1)
            ohf, vals, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        seg_min = lambda vals: jnp.min(jnp.where(oh, vals, jnp.inf), axis=1,
                                       keepdims=True)

        bw = seg_sum(w_live)                       # (W, 1) block weight/segment
        wt_ref[rows, :] += bw

        idx = step * bn + jax.lax.broadcasted_iota(
            jnp.int32, (1, bn), 1)

        for j in range(n_l):
            l = jax.lax.bitcast_convert_type(scalar_ref[j], jnp.float32)
            tau = jax.lax.bitcast_convert_type(scalar_ref[n_l + j], jnp.float32)
            inv_l = 1.0 / l
            kb = ku / l  # division, not *inv_l: bit-identical to the XLA path
            score = jnp.where(v <= inv_l, kb, v)
            rate = jnp.maximum(inv_l, tau)
            delta = e / rate
            gate = jnp.where(tau * l > 1.0, True, kb < tau)
            es = (delta < w) & gate & live

            # first entry event per segment, then back to per-element form
            # via the same one-hot (no data-dependent gathers in VMEM)
            entry_idx = jnp.where(es, idx, _NO_ENTRY)
            fe_loc = jnp.min(jnp.where(oh, entry_idx, _NO_ENTRY), axis=1,
                             keepdims=True)                     # (W, 1)
            fe_elem = jnp.min(jnp.where(oh, fe_loc, _NO_ENTRY), axis=0,
                              keepdims=True)                    # (1, BN)
            at = (idx == fe_elem) & es
            after = (idx > fe_elem) & live
            contrib_elem = (jnp.where(after, w, 0.0)
                            + jnp.where(at, w - delta, 0.0))

            bc = seg_sum(contrib_elem)                          # (W, 1)
            be = jnp.max(jnp.where(oh, es.astype(jnp.int32), 0), axis=1,
                         keepdims=True)
            ms = seg_min(jnp.where(live, score, jnp.inf))
            bkb = seg_min(jnp.where(live, kb, jnp.inf))

            # cross-block carry: read the window BEFORE updating `ent` so the
            # contrib recurrence sees "entered in an earlier block"
            prev_ent = ent_ref[rows, j:j + 1]
            prev_ctr = ctr_ref[rows, j:j + 1]
            ctr_ref[rows, j:j + 1] = jnp.where(prev_ent > 0, prev_ctr + bw, bc)
            ent_ref[rows, j:j + 1] = jnp.maximum(prev_ent, be)
            kbm_ref[rows, j:j + 1] = jnp.minimum(kbm_ref[rows, j:j + 1], bkb)
            msc_ref[rows, j:j + 1] = jnp.minimum(msc_ref[rows, j:j + 1], ms)

    return kernel


@functools.partial(jax.jit, static_argnames=("n_l", "interpret", "cfg"))
def capscore_agg(ks, eids, ws, seg, ls, taus, salt, *, n_l: int,
                 interpret: bool | None = None,
                 cfg: TileConfig | None = None):
    """Fused multi-l scoring + per-key chunk aggregation (Pallas).

    Args:
      ks, eids: int32 [C] in KEY-SORTED order (the ChunkOrder pre-gathered
        view), C a multiple of the block size ``cfg.block[1]`` (use
        ops.capscore_agg for padding); ``ks`` ascending with EMPTY last.
      ws: float32 [C] weights, same order.
      seg: int32 [C] sorted segment ids of ``ks`` (0..n_seg-1).
      ls, taus: float32 [n_l] per-lane cap parameter / current threshold.
      salt: uint32 scalar shared by all lanes.
      cfg: tile config (static); None selects the platform flavor.  The
        element stream is double-buffered across grid steps (Mosaic grid
        pipeline / Triton num_stages) while the output columns stay resident.
    Returns:
      (w_total f32 [C + window, 1],
       entered i32 / contrib f32 / kb_min f32 / min_score f32, each
       [C + window, n_l]) — segment-id-indexed columns; rows past the
      real segment count hold the reduction identities (the wrapper slices
      and transposes).  ``window = cfg.block[1] + cfg.align``.
    """
    if interpret is None:
        interpret = default_interpret()
    if cfg is None:
        cfg = tile_config("capscore_agg")
    bn = cfg.block[-1]
    window = bn + cfg.align
    C = ks.shape[0]
    assert C % bn == 0, C
    scalars = jnp.concatenate(
        [
            jax.lax.bitcast_convert_type(jnp.asarray(ls, jnp.float32), jnp.int32).reshape(n_l),
            jax.lax.bitcast_convert_type(jnp.asarray(taus, jnp.float32), jnp.int32).reshape(n_l),
            jnp.asarray(salt, jnp.uint32).astype(jnp.int32).reshape(1),
        ]
    )
    view = lambda a: a.reshape(1, C)
    rows_out = C + window
    in_blk = lambda: pl.BlockSpec((1, bn), lambda i, *_: (0, i))
    out_blk = lambda cols: pl.BlockSpec((rows_out, cols), lambda i, *_: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((rows_out, 1), jnp.float32),
        jax.ShapeDtypeStruct((rows_out, n_l), jnp.int32),
        jax.ShapeDtypeStruct((rows_out, n_l), jnp.float32),
        jax.ShapeDtypeStruct((rows_out, n_l), jnp.float32),
        jax.ShapeDtypeStruct((rows_out, n_l), jnp.float32),
    ]
    return _grid_call(
        _make_capscore_agg_kernel(n_l, bn, window, cfg.align), cfg=cfg,
        interpret=interpret, grid=(C // bn,),
        in_specs=[in_blk(), in_blk(), in_blk(), in_blk()],
        out_specs=[out_blk(1), out_blk(n_l), out_blk(n_l), out_blk(n_l),
                   out_blk(n_l)],
        out_shape=out_shape, n_scalars=2 * n_l + 1,
    )(scalars, view(ks), view(eids), view(ws), view(seg))
