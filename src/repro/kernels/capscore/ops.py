"""Public op: padding + backend dispatch for the capscore kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .capscore import (
    BLOCK_ROWS,
    LANES,
    capscore as _kernel,
    capscore_multi as _kernel_multi,
    default_interpret,
)
from .ref import capscore_multi_ref, capscore_ref

_TILE = BLOCK_ROWS * LANES


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def capscore(keys, eids, weights, l, tau, salt, *, backend: str | None = None):
    """Fused element scoring.  backend: 'pallas' | 'xla' | None (auto).

    On CPU the Pallas path runs in interpret mode (correctness only); 'xla'
    is the fast CPU path and the differentiation-friendly fallback.
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return capscore_ref(keys, eids, weights, l, tau, salt)
    n = keys.shape[0]
    pad = (-n) % _TILE
    if pad:
        keys = jnp.concatenate([keys, jnp.zeros((pad,), keys.dtype)])
        eids = jnp.concatenate([eids, jnp.zeros((pad,), eids.dtype)])
        weights = jnp.concatenate([weights, jnp.ones((pad,), weights.dtype)])
    s, d, e = _kernel(keys, eids, weights, l, tau, salt,
                      interpret=default_interpret())
    if pad:
        s, d, e = s[:n], d[:n], e[:n]
    return s, d, e


def capscore_multi(keys, eids, weights, ls, taus, salt, *, backend: str | None = None):
    """Fused multi-l element scoring: one pass over the elements scores every
    (ls[j], taus[j]) lane of a sketch grid.  backend: 'pallas' | 'xla' | None.

    Returns (score, delta, entry, kb), each shaped [len(ls), N].
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return capscore_multi_ref(keys, eids, weights, ls, taus, salt)
    n = keys.shape[0]
    n_l = ls.shape[0] if hasattr(ls, "shape") else len(ls)
    pad = (-n) % _TILE
    if pad:
        keys = jnp.concatenate([keys, jnp.zeros((pad,), keys.dtype)])
        eids = jnp.concatenate([eids, jnp.zeros((pad,), eids.dtype)])
        weights = jnp.concatenate([weights, jnp.ones((pad,), weights.dtype)])
    s, d, e, kb = _kernel_multi(keys, eids, weights, ls, taus, salt,
                                n_l=int(n_l), interpret=default_interpret())
    if pad:
        s, d, e, kb = s[:, :n], d[:, :n], e[:, :n], kb[:, :n]
    return s, d, e, kb
