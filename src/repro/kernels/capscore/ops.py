"""Public op: padding + backend dispatch for the capscore kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.segments import EMPTY
from .capscore import (
    AGG_BN,
    AGG_WINDOW,
    BLOCK_ROWS,
    LANES,
    capscore as _kernel,
    capscore_agg as _kernel_agg,
    capscore_multi as _kernel_multi,
    default_interpret,
)
from .ref import capscore_agg_ref, capscore_multi_ref, capscore_ref
from .tiling import resolve_backend as _resolve_backend
from .tiling import tile_config


def _pad_tile(tile, *cols):
    """Pad 1-D arrays to a multiple of ``tile`` with per-array fill values.

    ``cols`` are (array, fill) pairs; returns (padded_arrays..., pad).  The
    no-op case (already tile-aligned — every ``SamplerSpec.chunk`` in
    practice) skips the concatenates entirely, so the aligned hot path traces
    zero extra ops; tests/test_ingest_order.py pins padded-vs-aligned outputs
    slice-bit-identical.
    """
    n = cols[0][0].shape[0]
    pad = (-n) % tile
    if pad == 0:
        return tuple(a for a, _ in cols) + (0,)
    return tuple(
        jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) for a, fill in cols
    ) + (pad,)


def capscore(keys, eids, weights, l, tau, salt, *, backend: str | None = None):
    """Fused element scoring.  backend: 'pallas' | 'xla' | None (auto).

    On CPU the Pallas path runs in interpret mode (correctness only); 'xla'
    is the fast CPU path and the differentiation-friendly fallback.
    """
    backend = _resolve_backend(backend)
    if backend == "xla":
        return capscore_ref(keys, eids, weights, l, tau, salt)
    cfg = tile_config("capscore")
    n = keys.shape[0]
    keys, eids, weights, pad = _pad_tile(
        cfg.elements, (keys, 0), (eids, 0), (weights, 1.0))
    s, d, e = _kernel(keys, eids, weights, l, tau, salt,
                      interpret=default_interpret(), cfg=cfg)
    if pad:
        s, d, e = s[:n], d[:n], e[:n]
    return s, d, e


def capscore_multi(keys, eids, weights, ls, taus, salt, *, backend: str | None = None):
    """Fused multi-l element scoring: one pass over the elements scores every
    (ls[j], taus[j]) lane of a sketch grid.  backend: 'pallas' | 'xla' | None.

    Returns (score, delta, entry, kb), each shaped [len(ls), N].
    """
    backend = _resolve_backend(backend)
    if backend == "xla":
        return capscore_multi_ref(keys, eids, weights, ls, taus, salt)
    cfg = tile_config("capscore_multi")
    n = keys.shape[0]
    n_l = ls.shape[0] if hasattr(ls, "shape") else len(ls)
    keys, eids, weights, pad = _pad_tile(
        cfg.elements, (keys, 0), (eids, 0), (weights, 1.0))
    s, d, e, kb = _kernel_multi(keys, eids, weights, ls, taus, salt,
                                n_l=int(n_l), interpret=default_interpret(),
                                cfg=cfg)
    if pad:
        s, d, e, kb = s[:, :n], d[:, :n], e[:, :n], kb[:, :n]
    return s, d, e, kb


def capscore_agg(ks, eids, ws, seg, ls, taus, salt, *, backend: str | None = None):
    """Fused multi-l scoring + per-key chunk aggregation over a KEY-ORDERED
    chunk (the ChunkOrder pre-gathered view).  backend: 'pallas'|'xla'|None.

    One pass over the elements scores every (ls[j], taus[j]) lane AND reduces
    the scores into the per-unique-key ChunkAgg columns, so the [L, N]
    score/delta/entry/kb intermediates are never materialized between stages.

    Returns (w_total [C], entered bool [L, C], contrib [L, C], kb_min [L, C],
    min_score [L, C]); ``w_total`` is lane-independent and computed once.
    The 'xla' path (CPU/GPU production) is bit-identical to scoring then
    aggregating; the Pallas path reassociates the f32 sums in-block (mins,
    maxes and ``entered`` stay exact) — see the kernel docstring.
    """
    backend = _resolve_backend(backend)
    if backend == "xla":
        return capscore_agg_ref(ks, eids, ws, seg, ls, taus, salt)
    cfg = tile_config("capscore_agg")
    n = ks.shape[0]
    n_l = ls.shape[0] if hasattr(ls, "shape") else len(ls)
    # padding: EMPTY keys are masked to the reduction identities inside the
    # kernel, and segment id ``n`` (one past the last real segment) parks
    # them on output rows the slice below drops
    ks, eids, ws, seg, pad = _pad_tile(
        cfg.elements, (ks, int(EMPTY)), (eids, 0), (ws, 1.0), (seg, n))
    wt, ent, ctr, kbm, msc = _kernel_agg(ks, eids, ws, seg, ls, taus, salt,
                                         n_l=int(n_l),
                                         interpret=default_interpret(),
                                         cfg=cfg)
    lane_cols = lambda a: a[:n].T  # [rows, n_l] -> [n_l, C]
    return (wt[:n, 0], lane_cols(ent) > 0, lane_cols(ctr), lane_cols(kbm),
            lane_cols(msc))
