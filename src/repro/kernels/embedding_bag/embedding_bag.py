"""Pallas TPU kernel: segment-sum / EmbeddingBag reduction.

JAX has no native EmbeddingBag or CSR sparse ops (assignment note) — the
framework implements bag reduction as gather + segment-sum.  The segment-sum
is the hot reduction in both the recsys embedding path and GNN message
passing, so it gets a kernel.

TPU-native design: scatter-add is hostile to the VPU (random row writes), so
we recast the reduction as an MXU matmul with a block-local one-hot matrix:

    out[s, :] += sum_n (seg_ids[n] == s) * vals[n, :]
               = onehot(seg_ids_block).T @ vals_block

The grid walks value blocks (BN rows); the full (S, D) accumulator stays
VMEM-resident as a revisited output block (TPU grids are sequential, so
read-modify-write accumulation across grid steps is well-defined — the
canonical Pallas accumulation pattern).  Constraint: S * D * 4B must fit
VMEM (~2k segments x 512 dims); the wrapper shards larger problems over D
and hierarchically over S.  This mirrors how FBGEMM TBE tiles bags on GPU,
re-thought for explicit VMEM residency instead of L2-cached atomics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256  # value rows per grid step


def _segment_sum_kernel(vals_ref, seg_ref, out_ref, *, n_segments: int, bn: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...].astype(jnp.float32)          # (BN, D)
    segs = seg_ref[...]                               # (BN, 1) int32
    seg_col = segs[:, 0]
    onehot = (
        seg_col[:, None] == jax.lax.broadcasted_iota(jnp.int32, (bn, n_segments), 1)
    ).astype(jnp.float32)                             # (BN, S)
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ()))
    ).astype(out_ref.dtype)                           # (S, D)


@functools.partial(jax.jit, static_argnames=("n_segments", "interpret"))
def segment_sum(vals, seg_ids, *, n_segments: int, interpret: bool = True):
    """out[s] = sum_{n: seg_ids[n]==s} vals[n].

    vals: [N, D] float; seg_ids: [N] int32 in [0, n_segments) (out-of-range
    rows are dropped by pointing them at a padding row). N % 256 == 0
    (ops.segment_sum pads).
    """
    n, d = vals.shape
    assert n % BN == 0, n
    seg2 = seg_ids.reshape(n, 1).astype(jnp.int32)
    # out-of-range -> drop: redirect to segment 0 with zero value
    ok = (seg2 >= 0) & (seg2 < n_segments)
    seg2 = jnp.where(ok, seg2, 0)
    vals = jnp.where(ok, vals, 0)

    return pl.pallas_call(
        functools.partial(_segment_sum_kernel, n_segments=n_segments, bn=BN),
        grid=(n // BN,),
        in_specs=[
            pl.BlockSpec((BN, d), lambda i: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments, d), jnp.float32),
        interpret=interpret,
    )(vals, seg2)
