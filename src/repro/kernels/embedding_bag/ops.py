"""Public ops: segment_sum + embedding_bag with backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .embedding_bag import BN, segment_sum as _pallas_segment_sum
from .ref import embedding_bag_ref, segment_sum_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_sum(vals, seg_ids, *, n_segments: int, backend: str | None = None):
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return segment_sum_ref(vals, seg_ids, n_segments=n_segments)
    n = vals.shape[0]
    pad = (-n) % BN
    if pad:
        vals = jnp.concatenate([vals, jnp.zeros((pad, vals.shape[1]), vals.dtype)])
        seg_ids = jnp.concatenate([seg_ids, jnp.full((pad,), -1, jnp.int32)])
    return _pallas_segment_sum(vals, seg_ids, n_segments=n_segments, interpret=not _on_tpu())


def embedding_bag(table, ids, bag_segments, *, n_bags: int, mode: str = "sum",
                  per_sample_weights=None, backend: str | None = None):
    """Gather + bag-reduce.  ids: [N] (negative = padding); bag_segments: [N]."""
    rows = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if per_sample_weights is not None:
        rows = rows * per_sample_weights[:, None]
    rows = jnp.where((ids >= 0)[:, None], rows, 0)
    out = segment_sum(rows, bag_segments, n_segments=n_bags, backend=backend)
    if mode == "mean":
        cnt = segment_sum(
            jnp.where(ids >= 0, 1.0, 0.0)[:, None], bag_segments, n_segments=n_bags,
            backend=backend,
        )
        out = out / jnp.maximum(cnt, 1.0)
    return out
