"""Pure-jnp oracles for segment_sum / embedding_bag."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(vals, seg_ids, *, n_segments: int):
    ok = (seg_ids >= 0) & (seg_ids < n_segments)
    vals = jnp.where(ok[:, None], vals, 0)
    seg_ids = jnp.where(ok, seg_ids, 0)
    return jax.ops.segment_sum(vals.astype(jnp.float32), seg_ids, num_segments=n_segments)


def embedding_bag_ref(table, ids, offsets_segments, *, n_bags: int, mode: str = "sum",
                      per_sample_weights=None):
    """EmbeddingBag: rows = table[ids]; reduce by bag segment ids."""
    rows = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if per_sample_weights is not None:
        rows = rows * per_sample_weights[:, None]
    valid = ids >= 0
    rows = jnp.where(valid[:, None], rows, 0)
    out = jax.ops.segment_sum(rows.astype(jnp.float32), offsets_segments, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid.astype(jnp.float32), offsets_segments, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out
