"""Chunk-order sort kernel: block-local bitonic + cross-block run merge."""
from .ops import sort_with_perm  # noqa: F401
from .ref import sort_with_perm_ref  # noqa: F401
