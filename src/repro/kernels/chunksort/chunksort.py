"""Pallas kernels: block-local bitonic sort + cross-block two-run merge.

The chunk-order sort (``segments.ChunkOrder``) is the single shared O(C log C)
stage of the ingest path — every lane consumes its permutation.  This module
replaces the XLA ``argsort`` with a two-phase sorting network over
``(key, index)`` pairs:

  phase 1 — block-local sort: the padded chunk is cut into B-element blocks
    (B = tile config, power of two); each grid step runs a full bitonic
    network over its block entirely in VMEM, emitting B-long ascending runs.

  phase 2 — cross-block two-run merge: log2(P/B) further pallas_calls; each
    grid step loads TWO adjacent sorted runs, reverses the second (making the
    concatenation a single bitonic sequence) and collapses it with log2(2m)
    compare-exchange stages, doubling the run length per call until one run
    spans the chunk.

Why pairs: the kernels order ``(key, idx)`` tuples lexicographically.  All
tuples are distinct (``idx`` is a permutation), so the network needs no
stability of its own — the tuple order *is* the stable argsort order, which
makes the result bit-identical to ``jnp.argsort(keys, stable=True)`` by
construction, not by numerical accident.  EMPTY (int32 max) needs no special
casing: it is maximal, so padded tails sort to the end on their own.

Every compare-exchange stage is a vectorized reshape ``(m, 2, s)`` +
``where`` swap — no data-dependent control flow, no gathers; the network
shape is fully static per TileConfig, so each tile config is exactly one
compile (metered by the reprolint retrace budgets).  On lane-narrow stages
(s < 128) Mosaic pads the relayout; that cost is the known compiled-TPU
tuning item and does not affect interpret-mode bit-identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..capscore.capscore import _compiler_params, default_interpret
from ..capscore.tiling import TileConfig, tile_config


def _compare_exchange(keys, idx, stride, size):
    """One butterfly stage on flat [n] pair arrays.

    Partners sit ``stride`` apart inside contiguous 2*stride groups — the
    ``(m, 2, stride)`` reshape puts them on the middle axis.  A group is
    ascending iff bit ``size`` of its first element index is clear (the
    classic bitonic direction rule; ``size == 0`` means all-ascending, the
    merge-cascade case) — derived from an in-kernel iota because Pallas
    kernels cannot close over trace-time arrays.  Pairs are distinct, so the
    strict lexicographic ``>`` decides both directions.
    """
    m = keys.shape[0] // (2 * stride)
    k3 = keys.reshape(m, 2, stride)
    i3 = idx.reshape(m, 2, stride)
    ka, kb = k3[:, 0, :], k3[:, 1, :]
    ia, ib = i3[:, 0, :], i3[:, 1, :]
    a_gt_b = (ka > kb) | ((ka == kb) & (ia > ib))
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    asc_rows = ((rows * (2 * stride)) & size) == 0
    swap = jnp.where(asc_rows, a_gt_b, ~a_gt_b)
    ka2 = jnp.where(swap, kb, ka)
    kb2 = jnp.where(swap, ka, kb)
    ia2 = jnp.where(swap, ib, ia)
    ib2 = jnp.where(swap, ia, ib)
    keys = jnp.stack([ka2, kb2], axis=1).reshape(-1)
    idx = jnp.stack([ia2, ib2], axis=1).reshape(-1)
    return keys, idx


def _bitonic_stages(block: int):
    """Static (stride, size) schedule of the full bitonic sort network.

    Classic form: for size = 2, 4, .., block, merge 2*size-bitonic runs with
    strides size/2 .. 1; group direction is bit ``size`` of the element
    index, constant within each 2*stride-aligned group.
    """
    stages = []
    size = 2
    while size <= block:
        stride = size // 2
        while stride >= 1:
            stages.append((stride, size))
            stride //= 2
        size *= 2
    return stages


def _make_block_sort_kernel(block: int):
    """Kernel: full bitonic sort of one (1, block) pair block in VMEM."""
    stages = _bitonic_stages(block)

    def kernel(k_ref, i_ref, ko_ref, io_ref):
        k = k_ref[0, :]
        i = i_ref[0, :]
        for stride, size in stages:
            k, i = _compare_exchange(k, i, stride, size)
        ko_ref[0, :] = k
        io_ref[0, :] = i

    return kernel


def _make_merge_kernel(merged: int):
    """Kernel: merge two adjacent ascending runs of merged/2 pairs.

    Reversing the second run turns the block into one bitonic sequence; a
    log2(merged)-stage all-ascending butterfly cascade then sorts it — the
    cross-block carry is the run layout itself (each call halves the run
    count), so no state crosses grid steps.
    """
    half = merged // 2
    strides = []
    s = half
    while s >= 1:
        strides.append(s)
        s //= 2

    def kernel(k_ref, i_ref, ko_ref, io_ref):
        k = k_ref[0, :]
        i = i_ref[0, :]
        k = jnp.concatenate([k[:half], k[half:][::-1]])
        i = jnp.concatenate([i[:half], i[half:][::-1]])
        for stride in strides:
            k, i = _compare_exchange(k, i, stride, 0)  # 0: all-ascending
        ko_ref[0, :] = k
        io_ref[0, :] = i

    return kernel


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def sort_pairs(keys, idx, *, cfg: TileConfig | None = None,
               interpret: bool | None = None):
    """Sort int32 ``(keys[j], idx[j])`` pairs lexicographically ascending.

    Args:
      keys: int32 [P], P a power of two and a multiple of the block size
        (use ops.sort_with_perm for padding; EMPTY-maximal padding keeps the
        real prefix exact).
      idx: int32 [P], all distinct (a permutation — normally ``arange(P)``).
      cfg: tile config (static); None selects the platform flavor.
      interpret: None resolves via ``default_interpret()``.
    Returns:
      (keys_sorted, idx_sorted) — bit-identical to the stable argsort dual
      ``segments.stable_sort_with_perm`` when ``idx = arange(P)``.
    """
    if interpret is None:
        interpret = default_interpret()
    if cfg is None:
        cfg = tile_config("chunksort")
    P = keys.shape[0]
    block = min(cfg.block[0], P)
    assert P & (P - 1) == 0 and P % block == 0, (P, block)

    kw = {}
    params = _compiler_params(cfg, interpret)
    if params is not None:
        kw["compiler_params"] = params
    pair_shape = [jax.ShapeDtypeStruct((1, P), jnp.int32)] * 2

    def run(kernel, width, k2, i2):
        blk = lambda: pl.BlockSpec((1, width), lambda i: (0, i))
        return pl.pallas_call(
            kernel, grid=(P // width,),
            in_specs=[blk(), blk()], out_specs=[blk(), blk()],
            out_shape=pair_shape, interpret=interpret, **kw)(k2, i2)

    view = lambda a: a.reshape(1, P)
    k2, i2 = run(_make_block_sort_kernel(block), block, view(keys), view(idx))
    m = block
    while m < P:
        k2, i2 = run(_make_merge_kernel(2 * m), 2 * m, k2, i2)
        m *= 2
    return k2.reshape(P), i2.reshape(P)
