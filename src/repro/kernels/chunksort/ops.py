"""Public op: padding + backend dispatch for the chunk-order sort kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.segments import EMPTY, stable_sort_with_perm
from ..capscore.capscore import default_interpret
from ..capscore.tiling import resolve_backend as _resolve_backend
from ..capscore.tiling import tile_config
from .chunksort import sort_pairs


def sort_with_perm(keys, *, backend: str | None = None):
    """Stable ascending key sort of an int32 chunk: ``(ks, perm)``.

    Bit-identical to the registered dual ``segments.stable_sort_with_perm``
    (``perm = argsort(keys, stable=True); ks = keys[perm]``) on every route.
    backend: 'pallas' runs the block-local bitonic + cross-block two-run
    merge kernels; 'xla' (and None on backends without a compiled sort
    lowering) falls back to the argsort dual.

    Padding: the kernel wants a power-of-two multiple of the tile block, so
    the tail is filled with (EMPTY, idx >= n) pairs.  EMPTY is the maximal
    int32 and the pad indices exceed every real index, so pads sort strictly
    after all real entries — including real EMPTY keys, which win their ties
    by index — and the [:n] slice is exact, not approximate.
    """
    backend = _resolve_backend(backend)
    if backend == "xla":
        return stable_sort_with_perm(keys)
    # normalize host arrays up front: a numpy chunk and a jnp chunk of the
    # same aval must hit the same sort_pairs cache entry (retrace budget = 1)
    keys = jnp.asarray(keys)
    n = keys.shape[0]
    cfg = tile_config("chunksort")
    P = max(cfg.block[0], 1 << max(0, n - 1).bit_length()) if n else cfg.block[0]
    pad = P - n
    kp = (jnp.concatenate([keys, jnp.full((pad,), EMPTY, keys.dtype)])
          if pad else keys)
    idx = jnp.arange(P, dtype=jnp.int32)
    ks, perm = sort_pairs(kp, idx, cfg=cfg, interpret=default_interpret())
    if pad:
        ks, perm = ks[:n], perm[:n]
    return ks, perm
