"""Reference chunk sort: the bit-identity oracle for the Pallas route.

Delegates to the registered sort dual in ``core.segments`` — the Pallas
kernel's contract is bit-identity against exactly that function, so the
reference IS the registry entry, not a private reimplementation.
"""
from __future__ import annotations

from ...core.segments import stable_sort_with_perm


def sort_with_perm_ref(keys):
    return stable_sort_with_perm(keys)
