"""Pallas TPU kernel: blockwise causal GQA attention (FlashAttention-style).

TPU-native design notes (vs the CUDA original):
* blocks are MXU-shaped: q-block (BQ=128) x head_dim, kv chunks BK=128 —
  every matmul is a 128-aligned systolic pass;
* the kv stream for one (batch, kv_head) stays VMEM-resident as a single
  block (S*D*4B*2 = 4 MB at S=4096, D=128 — fits v5e's ~16 MB VMEM) and the
  kernel walks it with `pl.ds` slices, so there is no HBM re-fetch per
  q-block (the CUDA version re-reads K/V from HBM per SM tile and relies on
  L2; on TPU we exploit the explicitly-managed VMEM instead);
* the causal loop bound is dynamic (`fori_loop` upper = ceil((q_start+BQ)/BK))
  — Pallas grids are sequential on TPU so there is no warp-divergence analog;
  skipped chunks cost nothing.
* GQA: the kv-head index map is h // (Hq//Hkv); no KV duplication in memory.

Forward only: the training path uses the differentiable XLA-chunked
implementation (layers/attention.py); this kernel serves prefill/serving.
For S beyond VMEM (long-context), serving falls back to the XLA path — noted
in DESIGN.md (a kv-blocked two-level variant is the natural extension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, scale: float, causal: bool):
    i = pl.program_id(2)
    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (BQ, D)
    seq = k_ref.shape[2]
    d = q.shape[-1]
    n_chunks = seq // bk
    q_start = i * bq

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)  # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)  # (BK, D)
        acc_new = acc * alpha[:, None] + jax.lax.dot(p, v)
        return m_new, l_new, acc_new

    if causal:
        upper = (q_start + bq + bk - 1) // bk
    else:
        upper = n_chunks
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    o_ref[0, 0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """Blockwise attention.

    q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] with Hq % Hkv == 0; S % bq == 0,
    S % bk == 0.  Returns [B, Hq, S, D] in q.dtype.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0 and S % bq == 0 and S % bk == 0, (q.shape, k.shape, bq, bk)
    group = Hq // Hkv
    scale = 1.0 / (D**0.5)

    grid = (B, Hq, S // bq)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
