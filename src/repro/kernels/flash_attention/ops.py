"""Public attention op with backend dispatch.

backends:
  'pallas'      — the TPU kernel (interpret mode on CPU; correctness only)
  'xla_chunked' — lax.map over query chunks: memory-efficient (O(S*BQ) scores),
                  differentiable, and the dry-run/training path
  'naive'       — materializes the S x S scores (small-shape reference)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _pallas
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def xla_chunked_attention(q, k, v, *, causal: bool = True, chunk: int = 512):
    """Memory-efficient attention: compute scores one q-chunk at a time.

    Peak score memory S*chunk instead of S*S; fully differentiable; this is
    what train_step lowers (flash numerics, XLA codegen).
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    chunk = min(chunk, S)
    assert S % chunk == 0
    scale = 1.0 / (D**0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)

    qc = q.reshape(B, Hq, S // chunk, chunk, D)

    def do_chunk(ci, qblk):
        s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32), kk.astype(jnp.float32)) * scale
        if causal:
            qpos = ci * chunk + jnp.arange(chunk)[:, None]
            kpos = jnp.arange(S)[None, :]
            s = jnp.where(qpos >= kpos, s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
        return o / jnp.sum(p, axis=-1, keepdims=True)

    out = jax.lax.map(
        lambda args: do_chunk(args[0], args[1]),
        (jnp.arange(S // chunk), jnp.moveaxis(qc, 2, 0)),
    )
    out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, S, D)
    return out.astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, backend: str | None = None, chunk: int = 512):
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla_chunked"
    if backend == "pallas":
        return _pallas(q, k, v, causal=causal, interpret=not _on_tpu())
    if backend == "xla_chunked":
        return xla_chunked_attention(q, k, v, causal=causal, chunk=chunk)
    if backend == "naive":
        return attention_ref(q, k, v, causal=causal)
    raise ValueError(backend)
