"""Pure-jnp oracle: naive causal GQA attention (f32 softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / (D**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
