"""Continuous-batching scheduler for the multi-tenant serving plane.

Glues three planes together (DESIGN.md §10):

* **Admission** — per-tenant FIFO queues for ingest and query requests,
  drained round-robin with a rotating start pointer so no tenant can
  starve another regardless of submission skew (one request per tenant
  per rotation, repeated until the step budget is spent).
* **Coalescing** — all admitted queries, across every tenant, become ONE
  ``QueryEngine`` dispatch batch (lane keys ``(tenant, l)``); all admitted
  ingest lands in the bank's staging queues and one vmapped ``tick()``
  advances every tenant with a full chunk buffered.
* **Overlap** — within a step the query batch's device dispatch is
  enqueued first (against the refreshed snapshot), then the ingest tick's
  dispatch (donated buffers), and only then does the host block — on the
  query result alone.  JAX async dispatch runs the two back-to-back on
  device with zero host sync between the planes; the next step's
  ``refresh()`` is the single point that waits for ingest.

        step t:   refresh ─┐ (sync prior ticks)
        host      admit ─ enqueue Q(t) ─ enqueue I(t) ─ block on Q(t)
        device    ───────── [ Q(t) ▸▸▸ ][ I(t) ▸▸▸ ]──────▸ (t+1)

Results are buffered per request id and **evicted on read**
(``pop_result``) so a long-running server's memory stays bounded by the
outstanding-request window, not its lifetime.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from ..core import freqfns
from .service import MultiTenantStats, TenantQuery


@dataclasses.dataclass
class ServeConfig:
    """Per-step budgets + cadences for StatsScheduler."""

    max_ingest_per_step: int = 64     # ingest requests admitted per step
    max_queries_per_step: int = 256   # queries coalesced into one dispatch
    # rebuild the query snapshot at most every N steps while ingest is hot
    # (1 = every step => freshest answers, more sync; larger = staler
    # answers, longer uninterrupted overlap runs)
    refresh_every: int = 1
    max_ticks_per_step: int = 1       # stacked ingest dispatches per step
    # backpressure: per-tenant admission queue depth (ingest and query
    # queues separately).  ``submit_*`` past the limit raises QueueFull —
    # a RETRIABLE rejection — instead of letting one unthrottled client
    # grow the backlog without bound.  None = unbounded (legacy behavior).
    max_queue_depth: int | None = None
    # result expiry: a completed QueryRecord never ``pop_result``-ed within
    # this many subsequent steps is evicted (an abandoned client must not
    # leak the result buffer).  None = records live until popped.
    result_ttl_steps: int | None = None


class QueueFull(RuntimeError):
    """Admission rejected: the tenant's queue is at ``max_queue_depth``.

    Retriable by contract (``retriable = True``): the client should back
    off and resubmit — nothing was enqueued, and the server sheds load
    instead of buffering it."""

    retriable = True

    def __init__(self, plane: str, tenant: int, depth: int):
        super().__init__(
            f"{plane} queue for tenant {tenant} is full ({depth} deep) — "
            "retry after the scheduler drains")
        self.plane = plane
        self.tenant = tenant
        self.depth = depth


@dataclasses.dataclass
class QueryRecord:
    """One completed query: the answer + diagnostics + latency."""

    req_id: int
    tenant: int
    estimate: float
    stderr: float
    ci_low: float
    ci_high: float
    lane: float
    latency_s: float
    done_step: int = 0   # scheduler step that completed it (TTL accounting)


def _round_robin(queues: dict[int, deque], start: int, n_tenants: int,
                 budget: int) -> list[tuple[int, object]]:
    """Pop up to ``budget`` items fairly as (tenant, item) pairs: one per
    tenant per rotation, beginning at ``start`` and wrapping, until the
    budget is spent or every queue is empty.  A tenant with a deep backlog
    gets exactly as many slots per rotation as a tenant with one request."""
    out: list[tuple[int, object]] = []
    while budget > 0:
        took = 0
        for i in range(n_tenants):
            t = (start + i) % n_tenants
            q = queues.get(t)
            if q:
                out.append((t, q.popleft()))
                took += 1
                budget -= 1
                if budget == 0:
                    break
        if took == 0:
            break
    return out


class StatsScheduler:
    """Continuous-batching front end over one ``MultiTenantStats`` plane.

    Usage (see launch/stats_serve.py for the full server)::

        svc = MultiTenantStats(StatsConfig(...), n_tenants=64)
        sched = StatsScheduler(svc)
        sched.submit_ingest(tenant=3, keys=arr)
        rid = sched.submit_query(3, freqfns.cap(8.0))
        done = sched.step()          # one overlapped serve iteration
        rec = sched.pop_result(rid)  # evicts the record on read
    """

    def __init__(self, service: MultiTenantStats,
                 config: ServeConfig | None = None, *,
                 clock: Callable[[], float] = time.perf_counter):
        self.service = service
        self.config = config or ServeConfig()
        self._clock = clock
        T = service.n_tenants
        self._ingest_q: dict[int, deque] = {t: deque() for t in range(T)}
        self._query_q: dict[int, deque] = {t: deque() for t in range(T)}
        self._rr_ingest = 0
        self._rr_query = 0
        self._next_id = 0
        self._results: dict[int, QueryRecord] = {}
        self._steps_since_refresh = 0
        # counters (monotone, for throughput reporting)
        self.n_elements_ingested = 0
        self.n_queries_answered = 0
        self.n_results_expired = 0
        self.n_steps = 0

    # -- submission --------------------------------------------------------

    def submit_ingest(self, tenant: int, keys, weights=None) -> None:
        """Queue a stream slice for one tenant (admitted at a later step).
        Raises QueueFull (retriable) at ``ServeConfig.max_queue_depth``."""
        self._check_tenant(tenant)
        self._check_depth("ingest", self._ingest_q, tenant)
        self._ingest_q[tenant].append((np.asarray(keys), weights))

    def submit_query(self, tenant: int, fn: freqfns.FreqFn, segment=None,
                     l: float | None = None) -> int:
        """Queue a statistic request; returns the request id to poll.
        Raises QueueFull (retriable) at ``ServeConfig.max_queue_depth``."""
        self._check_tenant(tenant)
        self._check_depth("query", self._query_q, tenant)
        rid = self._next_id
        self._next_id += 1
        self._query_q[tenant].append(
            (rid, TenantQuery(tenant, fn, segment, l), self._clock()))
        return rid

    def _check_tenant(self, tenant: int) -> None:
        if not (0 <= tenant < self.service.n_tenants):
            raise ValueError(f"tenant {tenant} out of range "
                             f"[0, {self.service.n_tenants})")

    def _check_depth(self, plane: str, queues: dict[int, deque],
                     tenant: int) -> None:
        depth = self.config.max_queue_depth
        if depth is not None and len(queues[tenant]) >= depth:
            raise QueueFull(plane, tenant, depth)

    # -- results -----------------------------------------------------------

    def pop_result(self, req_id: int) -> QueryRecord | None:
        """Take (and EVICT) a completed query's record; None if pending."""
        return self._results.pop(req_id, None)

    @property
    def pending_queries(self) -> int:
        return sum(len(q) for q in self._query_q.values())

    @property
    def pending_ingest(self) -> int:
        return sum(len(q) for q in self._ingest_q.values())

    @property
    def buffered_results(self) -> int:
        return len(self._results)

    # -- the serve loop ----------------------------------------------------

    def step(self) -> list[int]:
        """One overlapped serve iteration; returns completed request ids.

        Order is the overlap contract (module docstring): admit → refresh
        (only when due AND queries are waiting) → enqueue the coalesced
        query dispatch → enqueue the stacked ingest tick(s) → block on the
        query result only.
        """
        cfg = self.config
        self.n_steps += 1
        T = self.service.n_tenants

        # 0) expire abandoned results: records not popped within the TTL
        #    window are evicted so a vanished client cannot leak the buffer.
        if cfg.result_ttl_steps is not None:
            expired = [rid for rid, rec in self._results.items()
                       if self.n_steps - rec.done_step >= cfg.result_ttl_steps]
            for rid in expired:
                del self._results[rid]
            self.n_results_expired += len(expired)

        # 1) admit ingest fairly into the bank's staging queues (host-side
        #    numpy appends — no device work yet).
        admitted = _round_robin(self._ingest_q, self._rr_ingest, T,
                                cfg.max_ingest_per_step)
        self._rr_ingest = (self._rr_ingest + 1) % max(T, 1)
        for tenant, (keys, weights) in admitted:
            self.service.observe(tenant, keys, weights)
            self.n_elements_ingested += int(np.asarray(keys).size)

        # 2) admit queries fairly and coalesce across tenants.
        picked = _round_robin(self._query_q, self._rr_query, T,
                              cfg.max_queries_per_step)
        self._rr_query = (self._rr_query + 1) % max(T, 1)

        # 3) refresh the snapshot only when it pays: queries are waiting
        #    and the snapshot is stale and the cadence is due (or there is
        #    no engine yet).  Only the admitted batch's tenants are
        #    materialized (partial refresh — the dominant snapshot cost is
        #    per-tenant).  This is the one sync point with prior ticks.
        self._steps_since_refresh += 1
        if picked and self.service.stale and (
                self._steps_since_refresh >= cfg.refresh_every
                or not self.service.has_engine):
            self.service.refresh(tenants={t for t, _ in picked})
            self._steps_since_refresh = 0

        # 4) enqueue the ONE coalesced query dispatch (no host sync).
        pending = None
        if picked:
            pending = self.service.query_batch_async(
                [tq for _, (_, tq, _) in picked], auto_refresh=False)

        # 5) enqueue the stacked ingest tick(s): device work for tick t+1
        #    runs while the query batch is still in flight.
        for _ in range(cfg.max_ticks_per_step):
            if self.service.tick() == 0:
                break

        # 6) block — on the query result only.
        done: list[int] = []
        if pending is not None:
            batch = pending.result()
            now = self._clock()
            for j, (tenant, (rid, _tq, t_submit)) in enumerate(picked):
                self._results[rid] = QueryRecord(
                    req_id=rid, tenant=tenant,
                    estimate=float(batch.estimates[j]),
                    stderr=float(batch.stderr[j]),
                    ci_low=float(batch.ci_low[j]),
                    ci_high=float(batch.ci_high[j]),
                    lane=float(batch.lanes[j]),
                    latency_s=now - t_submit,
                    done_step=self.n_steps)
                done.append(rid)
            self.n_queries_answered += len(done)
        return done

    def drain(self, *, max_steps: int = 1_000_000) -> list[int]:
        """Step until every queued request is admitted and answered and the
        bank's backlog is fully ingested (remainders stay staged, as in the
        single-tenant service).  Returns all request ids completed."""
        done: list[int] = []
        for _ in range(max_steps):
            idle = (self.pending_ingest == 0 and self.pending_queries == 0
                    and int(self.service.backlog_chunks().sum()) == 0)
            if idle:
                break
            done.extend(self.step())
        return done
