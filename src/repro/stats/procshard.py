"""Out-of-process shard tier: real worker subprocesses behind the ShardTier
coordinator (DESIGN.md §14).

PR 9's tier (stats.shardtier) proved the recovery contract — WAL-first
ingest, checkpoint + replay recovery bit-identical to the never-crashed run
— against *injected* exceptions.  This module runs the same contract against
real process death: each shard worker is an OS subprocess
(``launch.shard_worker``) speaking a length-prefixed ``.npz`` frame protocol
over an ``AF_UNIX`` socket, and the chaos schedule's events are REALIZED
rather than raised — ``crash`` is an actual ``SIGKILL`` racing an in-flight
apply, ``partition`` severs the actual connection.

Layers:

* **Frame protocol** (``send_frame`` / ``recv_frame``) — 8-byte big-endian
  length prefix + one ``np.savez`` archive (``allow_pickle=False`` both
  ways).  Everything on the wire is numpy arrays: ops and error strings ride
  as 0-d unicode arrays, service state rides as the flat ``state_dict``
  leaves under an ``s_`` prefix.  No third-party serializer, no pickles.

* **ShardProcess** — one worker subprocess + its socket lifecycle: the
  supervisor binds and listens *before* ``Popen`` (the worker connects; a
  severed worker reconnects to the same listener), reads a hello frame on
  accept, and classifies transport failures: timeout/EOF with the process
  alive is :class:`~..launch.faults.Unreachable` (retriable, exactly like a
  stall), with the process dead it is :class:`~.shardtier.ShardDown`.

* **ShardSupervisor** — owns every ShardProcess: spawn (parallel — all
  workers pay the interpreter+jax import concurrently), liveness via
  wall-clock heartbeats (process mode replaces the virtual clock: real
  sleeps, real timeouts), bounded restart-with-backoff (``max_restarts``
  per shard; beyond it the slot stays down), and graceful shutdown.

* **ProcWorkerClient** — the ShardWorker surface (apply / heartbeat /
  checkpoint / recover / service_view) as RPCs, with the fault backend in
  front: ``FaultInjector.poll`` yields the scheduled event and the client
  realizes it against the real process.  An injected ``crash`` SENDS the
  request and then SIGKILLs — a genuine mid-ingest race; recovery is
  bit-identical either way because the WAL segment is durable before the
  call and ``recover`` rebuilds from durable state alone.  The client keeps
  the coordinator-side :class:`~.shardtier.ShardWAL` (shared filesystem with
  the worker), so WAL-first ingest, torn-tail repair (the WAL-first buffer
  lives here), and exact pass II all run coordinator-side without shipping
  segments over the socket.

* **ProcShardTier** — ``ShardTier`` with ``_make_worker`` swapped for
  ProcWorkerClient and a wall clock.  Everything above the worker surface —
  routing, WAL-first ingest, health/miss accounting, degraded/exact/
  snapshot queries, the background exact-merge cadence, the status plane —
  is inherited unchanged: that surface was process-shaped by construction.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import shutil
import socket
import struct
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from ..launch.faults import (
    FaultInjector,
    InjectedLostReply,
    InjectedPartition,
    InjectedStall,
    Unreachable,
    WallClock,
)
from .service import StatsConfig, StreamStatsService
from .shardtier import ShardDown, ShardTier, ShardWAL, TierConfig


# ---------------------------------------------------------------------------
# Frame protocol
# ---------------------------------------------------------------------------

_FRAME_LEN = struct.Struct(">Q")
# npz state for k=4096 x 8 lanes is ~1 MiB; a frame far beyond any real
# payload indicates a desynced/corrupt stream — fail fast, don't allocate.
MAX_FRAME_BYTES = 1 << 30


def send_frame(sock: socket.socket, arrays: dict) -> None:
    """Write one frame: 8-byte big-endian payload length + npz archive.
    Values must be numpy arrays/scalars (strings are passed through
    ``np.asarray`` — 0-d unicode arrays round-trip)."""
    buf = io.BytesIO()
    np.savez(buf, allow_pickle=False,
             **{k: np.asarray(v) for k, v in arrays.items()})
    payload = buf.getvalue()
    sock.sendall(_FRAME_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> dict:
    """Read one frame; raises ConnectionError on EOF, socket.timeout on a
    configured timeout."""
    (n,) = _FRAME_LEN.unpack(_recv_exact(sock, _FRAME_LEN.size))
    if n > MAX_FRAME_BYTES:
        raise ConnectionError(f"frame length {n} exceeds {MAX_FRAME_BYTES} "
                              "— protocol desync")
    payload = _recv_exact(sock, n)
    with np.load(io.BytesIO(payload), allow_pickle=False) as d:
        return {k: d[k] for k in d.files}


def _text(v) -> str:
    """Unwrap a 0-d unicode array back to str."""
    return str(np.asarray(v).item())


# -- request/response helpers (shared with launch.shard_worker) -------------

_STATE_PREFIX = "s_"  # state_dict leaves on the wire (avoids op/seq collision)


def pack_state(d: dict) -> dict:
    return {_STATE_PREFIX + k: v for k, v in d.items()}


def unpack_state(frame: dict) -> dict:
    return {k[len(_STATE_PREFIX):]: v for k, v in frame.items()
            if k.startswith(_STATE_PREFIX)}


class RemoteError(RuntimeError):
    """The worker raised something other than ShardDown/ValueError; carries
    the remote type name + message."""


def raise_remote(frame: dict) -> None:
    """Re-raise a worker-side failure response coordinator-side, mapping the
    two protocol-meaningful types back to themselves."""
    etype = _text(frame.get("error_type", "RuntimeError"))
    msg = _text(frame.get("error", ""))
    if etype == "ShardDown":
        raise ShardDown(msg)
    if etype == "ValueError":
        raise ValueError(msg)
    raise RemoteError(f"{etype}: {msg}")


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SupervisorConfig:
    """Process-mode knobs.  All times are WALL seconds — process mode has no
    virtual clock (real processes fail on real time)."""

    # per-RPC reply deadline (apply/heartbeat/checkpoint/state)
    call_timeout_s: float = 30.0
    # worker startup budget: interpreter + jax import + first connect
    connect_timeout_s: float = 120.0
    # recover() replays the WAL tail inside one RPC — allow longer
    recover_timeout_s: float = 120.0
    # bounded restart-with-backoff: respawn attempts per shard beyond the
    # first spawn; exhausted -> the slot stays down (ShardDown)
    max_restarts: int = 3
    restart_backoff_s: float = 0.2
    restart_backoff_factor: float = 2.0


class ShardProcess:
    """One worker subprocess + its connection.

    The supervisor side owns the listening socket for this shard (bound
    before the first spawn, reused across restarts and partitions — the
    worker end always connects/reconnects to the same path).  Socket paths
    live in a private short tmpdir, NOT under the tier root: ``AF_UNIX``
    paths are capped around 100 bytes and test tmp roots routinely blow
    past that."""

    def __init__(self, shard_id: int, cmd: list[str],
                 cfg: SupervisorConfig, env: dict | None = None):
        self.shard_id = int(shard_id)
        self.cmd = list(cmd)
        self.cfg = cfg
        self.env = env
        self._sockdir = tempfile.mkdtemp(prefix=f"procshard{shard_id}_")
        self.sock_path = os.path.join(self._sockdir, "s")
        self._listener: socket.socket | None = None
        self.proc: subprocess.Popen | None = None
        self.conn: socket.socket | None = None
        self.restarts = 0
        self.spawned_at: float | None = None

    # -- lifecycle ---------------------------------------------------------

    def _ensure_listener(self) -> None:
        if self._listener is not None:
            return
        lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        lst.bind(self.sock_path)
        lst.listen(2)
        self._listener = lst

    def spawn(self, cmd_extra: list[str] = ()) -> None:
        """Bind+listen first, then Popen — the worker's connect cannot race
        the listener into ECONNREFUSED.  Does NOT wait for the hello: all
        shards spawn back-to-back and pay the import cost concurrently; the
        first RPC blocks on accept."""
        self._ensure_listener()
        self.proc = subprocess.Popen(
            self.cmd + list(cmd_extra),
            stdin=subprocess.DEVNULL,
            env=self.env,
            start_new_session=True,  # coordinator ^C must not kill workers
        )
        self.spawned_at = time.monotonic()

    def proc_alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    def _accept(self, timeout: float) -> None:
        self._ensure_listener()
        self._listener.settimeout(timeout)
        try:
            conn, _ = self._listener.accept()
        except socket.timeout:
            if not self.proc_alive():
                raise ShardDown(
                    f"shard {self.shard_id}: worker process died before "
                    "connecting") from None
            raise Unreachable(
                f"shard {self.shard_id}: no connection within {timeout}s "
                "(process alive)") from None
        conn.settimeout(self.cfg.call_timeout_s)
        hello = recv_frame(conn)
        if _text(hello.get("op", "")) != "hello":
            conn.close()
            raise ConnectionError(
                f"shard {self.shard_id}: bad handshake {hello.keys()}")
        self.conn = conn

    def ensure_conn(self, timeout: float | None = None) -> socket.socket:
        if self.conn is None:
            if not self.proc_alive():
                raise ShardDown(f"shard {self.shard_id}: process is dead")
            # the full startup budget covers both a fresh spawn (interpreter
            # + jax import) and a near-instant reconnect after a partition
            self._accept(self.cfg.connect_timeout_s
                         if timeout is None else timeout)
        return self.conn

    def sever(self) -> None:
        """Partition realization: drop the accepted connection.  The worker
        sees EOF and reconnects to the (still listening) socket path; the
        next RPC re-accepts."""
        if self.conn is not None:
            try:
                self.conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.conn.close()
            self.conn = None

    def kill(self) -> None:
        """SIGKILL — the real thing.  Durable state (checkpoints + WAL on
        the shared filesystem) is all that survives."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()
        self.sever()

    def restart(self) -> None:
        """Bounded respawn with exponential backoff.  Raises ShardDown once
        the restart budget is exhausted — the slot stays down and queries
        degrade rather than the tier retrying forever."""
        if self.restarts >= self.cfg.max_restarts:
            raise ShardDown(
                f"shard {self.shard_id}: restart budget exhausted "
                f"({self.restarts}/{self.cfg.max_restarts})")
        delay = (self.cfg.restart_backoff_s
                 * self.cfg.restart_backoff_factor ** self.restarts)
        self.restarts += 1
        time.sleep(delay)
        self.kill()
        self.spawn()

    def shutdown(self, grace_s: float = 5.0) -> None:
        """Graceful stop: shutdown RPC, wait, escalate to SIGKILL."""
        if self.proc_alive() and self.conn is not None:
            try:
                self.conn.settimeout(grace_s)
                send_frame(self.conn, {"op": "shutdown"})
                recv_frame(self.conn)
            except (OSError, ConnectionError, socket.timeout):
                pass
        if self.proc is not None and self.proc.poll() is None:
            try:
                self.proc.wait(timeout=grace_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        self.sever()

    def close(self) -> None:
        self.shutdown()
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        shutil.rmtree(self._sockdir, ignore_errors=True)

    # -- one RPC -----------------------------------------------------------

    def rpc(self, req: dict, *, timeout: float | None = None) -> dict:
        """Send one request frame, read one response frame.  Transport
        failures are classified by process liveness: dead -> ShardDown,
        alive -> Unreachable (the coordinator's bounded retry handles it
        exactly like a stall; the connection is dropped so the retry
        re-accepts a clean stream)."""
        t = self.cfg.call_timeout_s if timeout is None else timeout
        try:
            conn = self.ensure_conn()
            conn.settimeout(t)
            send_frame(conn, req)
            resp = recv_frame(conn)
        except ShardDown:
            raise
        except socket.timeout:
            self.sever()  # a late reply would desync the next RPC
            if not self.proc_alive():
                raise ShardDown(
                    f"shard {self.shard_id}: process died mid-call") from None
            raise Unreachable(
                f"shard {self.shard_id}: no reply within {t}s") from None
        except (ConnectionError, OSError) as e:
            self.sever()
            if not self.proc_alive():
                raise ShardDown(
                    f"shard {self.shard_id}: process is dead ({e})") from None
            raise Unreachable(f"shard {self.shard_id}: {e}") from None
        if not bool(resp.get("ok", False)):
            raise_remote(resp)
        return resp


class ShardSupervisor:
    """Spawns and owns the worker subprocesses for one tier.

    Besides lifecycle (parallel spawn, restart budgets, graceful shutdown)
    it answers the liveness question the coordinator's retry logic needs —
    ``proc_alive(s)`` — and realizes the physical halves of the chaos
    vocabulary (``kill``/``sever``) that in-process injection could only
    name."""

    def __init__(self, base_config: StatsConfig, root, tier: TierConfig,
                 cfg: SupervisorConfig | None = None):
        self.cfg = cfg or SupervisorConfig()
        self.root = Path(root)
        self.tier = tier
        self.base_config = base_config
        self.procs: dict[int, ShardProcess] = {}

    def _worker_cmd(self, s: int, sock_path: str) -> list[str]:
        cfg_json = json.dumps(dataclasses.asdict(
            dataclasses.replace(self.base_config, ls=list(self.base_config.ls))))
        return [
            sys.executable, "-m", "repro.launch.shard_worker",
            "--socket", sock_path,
            "--shard-id", str(s),
            "--root", str(self.root),
            "--config-json", cfg_json,
            "--checkpoint-every", str(self.tier.checkpoint_every),
            "--retain-wal", str(int(self.tier.retain_wal)),
            "--fsync", str(int(self.tier.fsync)),
        ]

    def _worker_env(self) -> dict:
        """The child must import ``repro`` no matter how the coordinator was
        launched: prepend this package's source root to PYTHONPATH."""
        src_root = str(Path(__file__).resolve().parents[2])
        env = dict(os.environ)
        pp = env.get("PYTHONPATH", "")
        if src_root not in pp.split(os.pathsep):
            env["PYTHONPATH"] = (src_root + os.pathsep + pp) if pp else src_root
        return env

    def get(self, s: int) -> ShardProcess:
        sp = self.procs.get(s)
        if sp is None:
            sp = ShardProcess(s, [], self.cfg, env=self._worker_env())
            sp.cmd = self._worker_cmd(s, sp.sock_path)
            self.procs[s] = sp
            sp.spawn()
        return sp

    def close(self) -> None:
        for sp in self.procs.values():
            sp.close()
        self.procs.clear()


# ---------------------------------------------------------------------------
# Worker client (the ShardWorker surface over the wire)
# ---------------------------------------------------------------------------


class ProcWorkerClient:
    """ShardWorker-shaped client over one worker subprocess.

    ShardTier drives this exactly like the in-process worker: same method
    surface, same exception vocabulary (ShardDown terminal, Unreachable/
    Injected* retriable), same WAL attribute (coordinator-side instance on
    the shared filesystem — WAL-first ingest and exact pass II never touch
    the socket).  The fault schedule is realized here, against the real
    process, through ``FaultInjector.poll``."""

    def __init__(self, shard_id: int, base_config: StatsConfig,
                 supervisor: ShardSupervisor, *,
                 faults: FaultInjector, fsync: bool = True):
        self.shard_id = int(shard_id)
        self.base_config = base_config
        self.sup = supervisor
        self._faults = faults
        self.root = supervisor.root / f"shard_{self.shard_id:02d}"
        self.wal = ShardWAL(self.root / "wal", fsync=fsync)
        self.applied_seq = 0      # coordinator mirror (refreshed by acks)
        self._last_ckpt_seq = 0   # best-effort mirror (worker owns cadence)
        self.proc = supervisor.get(shard_id)

    # -- surface bookkeeping ----------------------------------------------

    @property
    def alive(self) -> bool:
        return self.proc.proc_alive()

    def _site(self, op: str) -> str:
        return f"shard{self.shard_id}.{op}"

    def crash(self) -> None:
        """The tier's kill hook — in process mode this is a real SIGKILL."""
        self.proc.kill()

    # -- fault-realized RPC ------------------------------------------------

    def _guarded_rpc(self, op: str, req: dict, *,
                     timeout: float | None = None) -> dict:
        """One RPC behind the shard's injection site, realized physically:

        crash      -> SEND the request, then SIGKILL.  The worker may or may
                      not have applied before dying — a genuine mid-ingest
                      race; recovery is bit-identical either way (the WAL
                      segment was durable before this call and ``recover``
                      rebuilds from durable state alone).
        stall      -> never send; sleep the latency; raise (retriable).
        partition  -> sever the live connection; raise (retriable; the
                      retry's RPC re-accepts the worker's reconnect).
        slow       -> sleep the latency, then proceed normally.
        lost_reply -> full RPC (the op RAN remotely), discard the reply.
        """
        site = self._site(op)
        ev = self._faults.poll(site)
        clock = self._faults.clock
        if ev is not None:
            if ev.kind == "crash":
                try:
                    conn = self.proc.ensure_conn()
                    send_frame(conn, req)
                except (ShardDown, Unreachable, ConnectionError, OSError):
                    pass  # the kill is the point; delivery is best-effort
                self.proc.kill()
                raise ShardDown(
                    f"shard {self.shard_id} SIGKILLed in {op}")
            if ev.kind == "stall":
                clock.advance(ev.param)
                raise InjectedStall(site, f"stalled {ev.param:g}s")
            if ev.kind == "partition":
                self.proc.sever()
                raise InjectedPartition(site)
            if ev.kind == "slow":
                clock.advance(ev.param)
        resp = self.proc.rpc(req, timeout=timeout)
        if ev is not None and ev.kind == "lost_reply":
            raise InjectedLostReply(site)
        return resp

    # -- ShardWorker surface ----------------------------------------------

    def heartbeat(self) -> int:
        resp = self._guarded_rpc("heartbeat", {"op": "heartbeat"})
        self.applied_seq = int(resp["applied_seq"])
        self._last_ckpt_seq = int(resp["last_ckpt_seq"])
        return self.applied_seq

    def apply(self, seq: int, keys, weights) -> int:
        resp = self._guarded_rpc("ingest", {
            "op": "apply", "seq": np.int64(seq),
            "keys": np.asarray(keys, np.int32),
            "weights": np.asarray(weights, np.float32),
        })
        self.applied_seq = int(resp["applied_seq"])
        self._last_ckpt_seq = int(resp["last_ckpt_seq"])
        return self.applied_seq

    def checkpoint(self) -> int:
        resp = self._guarded_rpc("checkpoint", {"op": "checkpoint"})
        self.applied_seq = int(resp["applied_seq"])
        self._last_ckpt_seq = self.applied_seq
        return self.applied_seq

    def service_view(self) -> StreamStatsService:
        """Fetch the worker's state_dict over the wire and rebuild a local
        service — state_dict round-trips bit-for-bit (tested since PR 9's
        checkpoint suite), so the local rebuild IS the worker's sketch."""
        resp = self._guarded_rpc("state", {"op": "state"})
        svc = StreamStatsService(dataclasses.replace(
            self.base_config, host_id=self.shard_id))
        svc.load_state_dict(unpack_state(resp))
        return svc

    def recover(self) -> int:
        """Process-mode recovery: repair/drop a torn WAL tail coordinator-
        side first (the WAL-first buffer lives HERE, not in the worker),
        respawn the process if it is dead (bounded restart-with-backoff),
        then one recover RPC — the worker restores its latest checkpoint
        and replays the WAL tail, both from the shared filesystem."""
        self.wal.check_tail()
        if not self.proc.proc_alive():
            self.proc.restart()  # raises ShardDown past the budget
        resp = self._guarded_rpc(
            "recover", {"op": "recover"},
            timeout=self.sup.cfg.recover_timeout_s)
        self.applied_seq = int(resp["applied_seq"])
        self._last_ckpt_seq = int(resp["last_ckpt_seq"])
        return self.applied_seq

    def runtime_status(self) -> dict:
        return {
            "alive": self.alive,
            "applied_seq": self.applied_seq,
            "last_checkpoint_seq": self._last_ckpt_seq,
            "wal_depth": len(self.wal.seqs()),
            "pid": None if self.proc.proc is None else self.proc.proc.pid,
            "restarts": self.proc.restarts,
        }


# ---------------------------------------------------------------------------
# The tier
# ---------------------------------------------------------------------------


class ProcShardTier(ShardTier):
    """ShardTier over real worker subprocesses.

    Differences from the in-process tier are confined to the worker factory
    and the clock: time is WALL time (heartbeat deadlines, retry backoff and
    injected stall/slow latencies really elapse), and the chaos schedule is
    realized physically by ProcWorkerClient.  Use as a context manager (or
    call ``close()``) — worker processes outlive an abandoned coordinator
    otherwise.
    """

    def __init__(self, config: StatsConfig, tier: TierConfig | None = None,
                 root=None, *, faults: FaultInjector | None = None,
                 supervisor: SupervisorConfig | None = None):
        if faults is None:
            faults = FaultInjector(clock=WallClock())
        if isinstance(faults.clock, WallClock) is False:
            raise ValueError(
                "ProcShardTier runs on wall time; construct the injector "
                "with clock=WallClock()")
        self.sup = ShardSupervisor(config, Path(root), tier or TierConfig(),
                                   supervisor)
        super().__init__(config, tier, root, faults=faults)

    def _make_worker(self, s: int):
        return ProcWorkerClient(s, self.base_config, self.sup,
                                faults=self._faults, fsync=self.tier.fsync)

    def close(self) -> None:
        self.sup.close()

    def __enter__(self) -> "ProcShardTier":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
