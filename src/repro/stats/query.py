"""Batched device-resident query plane: the estimation-side dual of the
multi-l ingestion path.

``QueryEngine`` takes a materialized set of per-l sketches (any mix of
1-pass / 2-pass, continuous / discrete / distinct / SH lanes) and answers a
whole batch of ``(FreqFn, Segment, lane)`` queries in **one jitted device
dispatch** over the stacked lane arrays, returning the estimates plus
per-query variance/CI diagnostics derived from the per-key estimates.

Bit-identity contract (property-tested in tests/test_query_engine.py): for
every query in the batch the answer is bit-identical to the scalar
``estimators.estimate(result, fn, segment)`` loop.  The engine achieves
this by splitting each estimator along the host/device boundary so the
device only ever executes *exactly-rounded* IEEE f64 ops (gather, compare,
min, multiply, divide, add), which numpy and XLA agree on bit-for-bit:

* **query-independent, transcendental-heavy** pieces are computed ONCE per
  lane on host with the very numpy code the scalar estimators run —
  2-pass inclusion probabilities Phi(w) (exp/pow), plug-in inclusion for
  the variance diagnostics — and cached on the engine;
* **per-(lane, fn)** coefficient tables (the discrete-spectrum beta tables
  of Thm 4.1 / eqs. 4-5, and f/f' value tables for transcendental or custom
  FreqFns) are host-built once and cached by ``FreqFn.cache_key``;
* **per-(lane, Segment)** masks are compiled once (``Segment.mask_np`` over
  the lane's sampled keys) and cached by Segment identity — no ``np.isin``
  per query;
* the jitted dispatch then evaluates the whole batch: gather each query's
  lane row, evaluate the device-exact FreqFn family ({cap_T}, total,
  distinct, threshold) as one array op (Thm 5.3 coefficient form f/min(1,
  l tau) + f'/tau, the inverse-probability exact path f/Phi, and the
  table-gather discrete form, selected per query), mask, and emit the
  per-key estimate matrix plus variance terms.

The final per-query reduction is an f64 ``np.sum`` over the lane's true
sample length on host — the same pairwise summation, over the same-length
contiguous array, as the scalar path, which is what turns per-key equality
into whole-estimate bit-identity.

Variance/CI: the per-key estimates a_x yield the Horvitz-Thompson variance
estimator  Var_hat = sum_{x in S} a_x^2 (1 - p_x)  with p_x the (plug-in)
inclusion probability (``estimators.inclusion_per_key``); ``ci_low``/
``ci_high`` are the normal-approximation 95% bounds.  Exact for the 2-pass
lanes under Poisson sampling; a calibrated heuristic for 1-pass lanes
(Monte-Carlo coverage is tested).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64 as _enable_x64

from ..core import estimators, freqfns
from ..core import segments as SEG
from ..core.samplers import SampleResult

# per-query estimator form, selected on host by mirroring the branch
# structure of estimators.estimate:
_PATH_F = 0        # est = f(c)           (tau=inf; discrete lanes via tables)
_PATH_INVPROB = 1  # est = f(w) / Phi(w)  (2-pass inverse probability)
_PATH_CONT = 2     # est = f(c)/d1 + f'(c)/d2   (Thm 5.3, d1=min(1,l tau), d2=tau)

_Z95 = 1.959963984540054  # normal 97.5% quantile


@dataclasses.dataclass(frozen=True)
class Query:
    """One (statistic, segment, lane) request.

    ``l=None`` lets the owner (StreamStatsService.query_batch) pick the lane
    from the statistic; the engine itself requires it resolved.  ``l`` is
    any hashable lane key of the engine's sketch dict — a float cap
    parameter for a single service, a ``(tenant, l)`` tuple for a stacked
    multi-tenant engine (stats.service.MultiTenantStats).
    """

    fn: freqfns.FreqFn
    segment: object = None
    l: object | None = None


@dataclasses.dataclass
class BatchResult:
    """Answers + diagnostics for one query batch (arrays indexed by query)."""

    estimates: np.ndarray   # [Q] f64 — bit-identical to the scalar loop
    variances: np.ndarray   # [Q] f64 HT plug-in variance estimates
    stderr: np.ndarray      # [Q] f64 sqrt(variance)
    ci_low: np.ndarray      # [Q] f64 normal-approx 95% lower bound
    ci_high: np.ndarray     # [Q] f64 normal-approx 95% upper bound
    n_keys: np.ndarray      # [Q] i32 sampled keys inside the segment
    lanes: np.ndarray       # [Q] f64 the l each query was answered from
    # degraded-mode provenance (stats.shardtier): a healthy single service
    # always answers with the defaults — coverage 1, nothing stale.  A
    # sharded tier answering from a subset of shards stamps the routed-
    # element coverage fraction, the count of elements routed to shards it
    # could NOT reach, the degraded flag, and how the answer was produced.
    coverage: float = 1.0         # routed elements reachable / routed total
    staleness_elements: int = 0   # routed elements missing from the answer
    degraded: bool = False        # True iff answered from a partial tier
    mode: str = "sketch"          # "sketch" | "approx" | "exact"

    def __len__(self) -> int:
        return len(self.estimates)


@functools.partial(jax.jit, static_argnames=("use_phi", "use_tabs"))
def _dispatch(counts, valid, phi, segbank, fbank, fpbank, ints, floats, *,
              use_phi: bool, use_tabs: bool):
    """The one device dispatch: [Q] queries over [L, K] stacked lanes.

    Everything O(Q*K)-sized lives device-resident between calls — the lane
    arrays, the compiled segment-mask bank and the coefficient-table banks —
    so a batch only ships two tiny [*, Q] index/scalar vectors.  The CPU
    path is gather-bandwidth-bound, so the unused [Q, K] gathers are
    compiled out per batch shape: ``use_phi`` is False when no query runs
    the 2-pass inverse-probability path, ``use_tabs`` when every query's
    statistic is device-evaluable (the common all-{cap_T} case).
    """
    lane_idx, path, kind_id, seg_idx, tab_idx = (ints[i] for i in range(5))
    param, d1, d2 = (floats[i][:, None] for i in range(3))
    c = counts[lane_idx]                      # [Q, K] f64 gather
    live = valid[lane_idx] & segbank[seg_idx]  # [Q, K]
    kf, kfp = freqfns.eval_kinds_batched(kind_id[:, None], param, c, jnp)
    if use_tabs:
        use_tab = (tab_idx > 0)[:, None]      # bank row 0 == "no table"
        fval = jnp.where(use_tab, fbank[tab_idx], kf)
        fpval = jnp.where(use_tab, fpbank[tab_idx], kfp)
    else:
        fval, fpval = kf, kfp
    p = path[:, None]
    cont = fval / d1 + fpval / d2
    if use_phi:
        est = jnp.where(
            p == _PATH_F, fval,
            jnp.where(p == _PATH_INVPROB, fval / phi[lane_idx], cont))
    else:
        est = jnp.where(p == _PATH_F, fval, cont)
    return jnp.where(live, est, 0.0)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class _Lane:
    """Host-side view of one materialized sketch + its per-lane caches.

    ``key`` is the engine's lane address (the sketch-dict key — a float l,
    or any hashable such as a (tenant, l) tuple); ``l`` is the numeric cap
    parameter reported back in BatchResult.lanes (the dict key when numeric,
    else the sketch's own l)."""

    def __init__(self, key, res: SampleResult):
        self.key = float(key) if isinstance(key, (int, float)) else key
        self.l = (float(key) if isinstance(key, (int, float))
                  else float(res.l))
        self.res = res
        self.n = len(res.keys)
        self.counts = np.asarray(res.counts, np.float64)
        # estimator path, mirroring estimators.estimate's branch order
        if math.isinf(res.tau):
            self.path = _PATH_F
            self.tabulated = False
        elif res.exact_weights:
            self.path = _PATH_INVPROB
            self.tabulated = False
        elif res.kind == "continuous":
            self.path = _PATH_CONT
            self.tabulated = False
        elif res.kind in ("discrete", "distinct", "sh"):
            self.path = _PATH_F
            self.tabulated = True  # per-(lane, fn) beta tables
        else:
            raise ValueError(res.kind)
        # d1/d2 of the Thm 5.3 coefficient form, f64 host scalars so the
        # device divisions reproduce cont.beta exactly.  Always res.l — the
        # dict key addressing this lane may legitimately differ from the
        # sketch's actual cap parameter (ad-hoc engines).
        if self.path == _PATH_CONT:
            self.d1 = min(1.0, float(res.l) * res.tau)
            self.d2 = float(res.tau)
        else:
            self.d1 = self.d2 = 1.0
        # query-independent transcendental pieces (host numpy, shared with
        # the scalar path):
        if self.path == _PATH_INVPROB:
            self.phi = np.asarray(
                estimators._inclusion_prob(res, self.counts), np.float64)
        else:
            self.phi = np.ones(self.n, np.float64)
        self.pincl = estimators.inclusion_per_key(res)

    def seg_mask(self, seg: SEG.Segment) -> np.ndarray:
        return np.ascontiguousarray(seg.mask_np(self.res.keys))

    def fn_tables(self, fn: freqfns.FreqFn) -> tuple[np.ndarray, np.ndarray]:
        """Per-key (f, f') value tables for fns the device can't evaluate
        exactly — and the discrete-spectrum beta tables, where the per-key
        estimate IS a host-built coefficient gathered by count."""
        if self.tabulated:
            vals = estimators.estimate_per_key(self.res, fn)
            return (np.asarray(vals, np.float64), np.zeros(self.n, np.float64))
        return (np.asarray(fn.f(self.counts), np.float64),
                np.asarray(fn.fprime(self.counts), np.float64))


class QueryEngine:
    """Answer batches of (FreqFn, Segment, lane) queries in one dispatch.

    Built from a ``{l: SampleResult}`` dict (the service's materialized
    sketches — 1-pass or reconciled 2-pass — or any ad-hoc collection of
    samples).  The engine is immutable w.r.t. the sketches: rebuild it when
    the underlying sample changes (StreamStatsService does this lazily).
    """

    def __init__(self, sketches: dict[float, SampleResult]):
        if not sketches:
            raise ValueError("QueryEngine needs at least one sketch lane")
        self.lanes = [_Lane(l, res) for l, res in sketches.items()]
        self._lane_of = {lane.key: i for i, lane in enumerate(self.lanes)}
        self.K = max(1, max(lane.n for lane in self.lanes))
        L = len(self.lanes)
        counts = np.zeros((L, self.K), np.float64)
        valid = np.zeros((L, self.K), bool)
        phi = np.ones((L, self.K), np.float64)
        pincl = np.ones((L, self.K), np.float64)
        for i, lane in enumerate(self.lanes):
            counts[i, : lane.n] = lane.counts
            valid[i, : lane.n] = True
            phi[i, : lane.n] = lane.phi
            pincl[i, : lane.n] = lane.pincl
        self._one_minus_pincl = 1.0 - pincl  # host [L, K], for the var matvec
        self._has_invprob = any(lane.path == _PATH_INVPROB for lane in self.lanes)
        with _enable_x64():
            self._counts = jnp.asarray(counts)
            self._valid = jnp.asarray(valid)
            self._phi = jnp.asarray(phi)
        # device-resident banks of compiled segment masks and coefficient
        # tables, grown on first use and cached across batches: a steady-
        # state batch ships only two [*, Q] vectors to the device
        self._seg_rows: list[np.ndarray] = []
        self._seg_counts: list[int] = []     # sampled keys per bank row
        self._seg_index: dict = {}           # (lane_i, Segment) -> bank row
        self._tab_f_rows = [np.zeros(self.K, np.float64)]   # row 0: no table
        self._tab_fp_rows = [np.zeros(self.K, np.float64)]
        self._tab_index: dict = {}           # (lane_i, fn.cache_key) -> row
        self._banks_dirty = True
        self._segbank_d = self._fbank_d = self._fpbank_d = None
        # growth bounds: a long-lived server fed never-repeating segments
        # must not grow host+device memory forever — crossing a limit resets
        # that bank (and the plans referencing its rows) wholesale; steady
        # workloads never hit it
        self._seg_rows_max = 1024
        self._tab_rows_max = 256
        # plans are pure functions of batch content (bank rows are append-
        # only between resets, so cached row indices never go stale) —
        # repeated production batches skip the per-query resolution loop
        self._plan_cache: dict = {}
        self._plan_cache_max = 512

    @property
    def ls(self) -> tuple[float, ...]:
        return tuple(lane.l for lane in self.lanes)

    @property
    def lane_keys(self) -> tuple:
        return tuple(lane.key for lane in self.lanes)

    def _lane_index(self, l) -> int:
        if l is None:
            if len(self.lanes) == 1:
                return 0
            raise ValueError(
                f"query needs an explicit lane key from {list(self._lane_of)} "
                "(StreamStatsService.query_batch resolves lanes automatically)")
        key = float(l) if isinstance(l, (int, float)) else l
        i = self._lane_of.get(key)
        if i is None:
            raise KeyError(
                f"no sketch lane {l!r}; have {list(self._lane_of)}")
        return i

    def _ensure_bank_capacity(self, n_queries: int) -> None:
        """Reset a bank (wholesale) BEFORE building a plan that could
        overflow it mid-batch — a mid-plan reset would strand row indices
        already assigned to earlier queries of the same batch.  Cached plans
        embed row indices, so every reset also drops the plan cache; the
        current batch then rebuilds from an empty bank (and may exceed the
        soft cap on its own, which the next batch's check claws back)."""
        if len(self._seg_rows) > max(0, self._seg_rows_max - n_queries):
            self._seg_rows, self._seg_counts = [], []
            self._seg_index = {}
            self._plan_cache.clear()
            self._banks_dirty = True
        if len(self._tab_f_rows) > max(1, self._tab_rows_max - n_queries):
            zero = np.zeros(self.K, np.float64)
            self._tab_f_rows, self._tab_fp_rows = [zero], [zero.copy()]
            self._tab_index = {}
            self._plan_cache.clear()
            self._banks_dirty = True

    def _seg_row(self, li: int, seg: SEG.Segment) -> int:
        key = (li, seg)
        idx = self._seg_index.get(key)
        if idx is None:
            lane = self.lanes[li]
            row = np.zeros(self.K, bool)
            row[: lane.n] = lane.seg_mask(seg)
            idx = self._seg_index[key] = len(self._seg_rows)
            self._seg_rows.append(row)
            self._seg_counts.append(int(row.sum()))
            self._banks_dirty = True
        return idx

    def _tab_row(self, li: int, fn: freqfns.FreqFn) -> int:
        key = (li, fn.cache_key)
        idx = self._tab_index.get(key)
        if idx is None:
            lane = self.lanes[li]
            fv, fpv = lane.fn_tables(fn)
            frow = np.zeros(self.K, np.float64)
            fprow = np.zeros(self.K, np.float64)
            frow[: lane.n] = fv
            fprow[: lane.n] = fpv
            idx = self._tab_index[key] = len(self._tab_f_rows)
            self._tab_f_rows.append(frow)
            self._tab_fp_rows.append(fprow)
            self._banks_dirty = True
        return idx

    def _banks(self):
        """Device copies of the mask/table banks (row counts padded to powers
        of two so bank growth reuses a handful of compiled shapes)."""
        if self._banks_dirty:
            S = _next_pow2(max(len(self._seg_rows), 1))
            T = _next_pow2(len(self._tab_f_rows))
            seg = np.zeros((S, self.K), bool)
            if self._seg_rows:
                seg[: len(self._seg_rows)] = np.stack(self._seg_rows)
            f = np.zeros((T, self.K), np.float64)
            fp = np.zeros((T, self.K), np.float64)
            f[: len(self._tab_f_rows)] = np.stack(self._tab_f_rows)
            fp[: len(self._tab_fp_rows)] = np.stack(self._tab_fp_rows)
            with _enable_x64():
                self._segbank_d = jnp.asarray(seg)
                self._fbank_d = jnp.asarray(f)
                self._fpbank_d = jnp.asarray(fp)
            self._banks_dirty = False
        return self._segbank_d, self._fbank_d, self._fpbank_d

    def _plan(self, queries):
        """Resolve each query to the dispatch index/scalar vectors (host),
        lane-sorted (the host reductions then work on contiguous row
        slices); ``order`` maps sorted rows back to request order.  Plans
        are cached by batch content."""
        segs = [SEG.as_segment(q.segment) for q in queries]
        cache_key = tuple(
            (q.fn.cache_key, seg, q.l) for q, seg in zip(queries, segs))
        hit = self._plan_cache.get(cache_key)
        if hit is not None:
            return hit
        self._ensure_bank_capacity(len(queries))
        Q = len(queries)
        Qp = _next_pow2(max(Q, 4))  # pad to pow2: few compiled shapes
        ints = np.zeros((5, Qp), np.int32)    # lane, path, kind, seg, tab
        floats = np.zeros((3, Qp), np.float64)  # param, d1, d2
        floats[1:] = 1.0
        for qi, q in enumerate(queries):
            li = self._lane_index(q.l)
            lane = self.lanes[li]
            fn = q.fn
            if lane.path == _PATH_CONT and fn.kind == "distinct":
                # continuity requirement of Thm 5.3 — same swap as the
                # scalar estimator (see estimators.estimate_per_key)
                fn = freqfns.cap(1.0)
            ints[0, qi] = li
            ints[1, qi] = lane.path
            ints[3, qi] = self._seg_row(li, segs[qi])
            floats[1, qi], floats[2, qi] = lane.d1, lane.d2
            if lane.tabulated or not fn.device_exact:
                ints[4, qi] = self._tab_row(li, fn)
            else:
                ints[2, qi] = freqfns.DEVICE_KIND_IDS[fn.kind]
                floats[0, qi] = fn.param
        order = np.argsort(ints[0, :Q], kind="stable").astype(np.int32)
        ints[:, :Q] = ints[:, order]
        floats[:, :Q] = floats[:, order]
        if len(self._plan_cache) >= self._plan_cache_max:
            self._plan_cache.pop(next(iter(self._plan_cache)))
        plan = (ints, floats, order)
        self._plan_cache[cache_key] = plan
        return plan

    def query_batch_async(self, queries) -> "PendingBatch":
        """Enqueue the device dispatch for a query batch WITHOUT waiting on
        it; the returned handle's ``result()`` performs the host reduction.

        This is the overlap hook of the serving plane (stats.scheduler): the
        per-key estimate matrix stays a device future between the two calls,
        so other work — e.g. the next ingest tick's dispatch — can be
        enqueued behind it before anything blocks on device compute.
        """
        queries = [q if isinstance(q, Query) else Query(*q) for q in queries]
        if not queries:
            raise ValueError("empty query batch")
        ints, floats, order = self._plan(queries)
        segbank, fbank, fpbank = self._banks()
        use_tabs = bool(ints[4].any())
        with _enable_x64():
            per_key = _dispatch(
                self._counts, self._valid, self._phi,
                segbank, fbank, fpbank, jnp.asarray(ints), jnp.asarray(floats),
                use_phi=self._has_invprob, use_tabs=use_tabs)
        return PendingBatch(self, per_key, ints, order, len(queries))

    def query_batch(self, queries) -> BatchResult:
        """Answer every query in one jitted dispatch + one host reduction.

        ``queries``: iterable of Query or (fn, segment[, l]) tuples.
        """
        return self.query_batch_async(queries).result()

    def _reduce(self, per_key_dev, ints, order, Q) -> BatchResult:
        """The host half of a batch: sync on the per-key estimate matrix and
        run the scalar-path-identical f64 reductions."""
        per_key = np.asarray(per_key_dev)
        lane_idx = ints[0, :Q]
        # the scalar path's reduction: f64 np.sum over the lane's true sample
        # length (identical pairwise grouping => identical bits); rows of one
        # lane reduce together (np.sum(axis=1) per contiguous row == np.sum
        # per row, bit-for-bit).  The HT variance diagnostic rides the same
        # pulled matrix as a per-lane matvec: Var_hat = sum a_x^2 (1 - p_x).
        ests = np.zeros(Q, np.float64)
        var = np.zeros(Q, np.float64)
        lo = 0
        while lo < Q:
            li = int(lane_idx[lo])
            hi = lo + int(np.searchsorted(lane_idx[lo:], li, side="right"))
            n = self.lanes[li].n
            block = per_key[lo:hi, :n]
            ests[order[lo:hi]] = np.sum(block, axis=1)
            var[order[lo:hi]] = np.square(block) @ self._one_minus_pincl[li, :n]
            lo = hi
        stderr = np.sqrt(var)
        inv_nk = np.zeros(Q, np.int32)
        inv_nk[order] = [self._seg_counts[si] for si in ints[3, :Q]]
        lanes = np.zeros(Q, np.float64)
        lanes[order] = [self.lanes[int(li)].l for li in lane_idx]
        return BatchResult(
            estimates=ests,
            variances=var,
            stderr=stderr,
            ci_low=ests - _Z95 * stderr,
            ci_high=ests + _Z95 * stderr,
            n_keys=inv_nk,
            lanes=lanes,
        )


class PendingBatch:
    """A dispatched-but-unreduced query batch: the device future plus the
    host plan needed to finish it.  ``result()`` blocks on the device value
    (once) and runs the bit-identity-preserving host reductions; repeated
    calls return the cached BatchResult."""

    def __init__(self, engine: QueryEngine, per_key_dev, ints, order, n):
        self._engine = engine
        self._per_key = per_key_dev
        self._ints = ints
        self._order = order
        self._n = n
        self._result: BatchResult | None = None

    def __len__(self) -> int:
        return self._n

    def result(self) -> BatchResult:
        if self._result is None:
            self._result = self._engine._reduce(
                self._per_key, self._ints, self._order, self._n)
            self._per_key = None  # drop the device buffer
        return self._result
