"""Fault-tolerant sharded ingestion tier: key-routed shards, WAL + checkpoint
recovery, heartbeat failure detection, degraded-mode queries.

The paper's sketches are mergeable, and **key-partitioned** shards make even
the cheap one-pass merge unbiased (tests/test_merge_bias.py measures the
envelope for the arbitrary-split case) — so a router that hashes keys to
shards can lose and recover shards without compromising correctness,
provided recovery is disciplined.  Because ALL sampling randomness hangs off
salted (key, eid) hashes (core.hashing; no PRNG state anywhere), replaying a
shard's stream after a crash reproduces *bit-identical* sketch state.  This
module turns that property into a crash-tolerant tier:

* ``route_keys`` / ``partition_batch`` — ``hash(key) % n_shards`` through
  the same counter-based hashing as the samplers (own salt), so the key
  partition is deterministic and stable across restarts;
* ``ShardWAL`` — a per-shard write-ahead log of routed batches, one fsynced
  ``.npz`` segment per sequence number (the write/fsync(file)/rename/
  fsync(dir) discipline of checkpoint.manager), truncated at each
  checkpoint unless ``retain_wal`` keeps the full stream for exact pass II;
* ``ShardWorker`` — one in-process shard: a StreamStatsService with
  ``host_id = shard_id`` (element randomness never aliases across shards),
  idempotent sequence-deduped ``apply`` (a retried lost-reply batch is an
  ack-only no-op), periodic checkpoint cadence, and ``recover()`` =
  checkpoint restore + WAL replay — bit-identical to the never-crashed
  worker because checkpoints round-trip bit-for-bit (remainder included)
  and the chunk partition of a stream is independent of batch boundaries;
* ``ShardTier`` — the coordinator: routes ingest WAL-first (durable before
  the shard call), runs heartbeat-based failure detection with a miss
  limit, wraps every shard call in bounded retry with exponential backoff +
  deadline (virtual clock under test), restarts shards through
  ``recover()``, and serves queries in three modes:

  - ``approx``  — fold the surviving shards' sketches into a scratch
    service (``StreamStatsService.merge_many``); with every shard up this
    is the tier's normal one-pass answer (coverage 1, not degraded);
  - ``exact``   — full two-pass: exact merge of the lossless summaries,
    then pass II replays every shard's complete WAL through
    ``reconcile()`` (requires ``retain_wal=True`` and every shard up);
  - ``auto``    — exact when available, else the degraded approx path.

  When a shard is down or mid-replay, answers come from the surviving
  shards only and carry an explicit **staleness/coverage stamp** on
  BatchResult: ``coverage`` = routed-element fraction reachable,
  ``staleness_elements`` = routed elements missing from the answer,
  ``degraded=True``, estimates scaled by the shard-inclusion
  Horvitz-Thompson factor 1/coverage with correspondingly widened
  variance/CI diagnostics.

Failure injection rides ``launch.faults``: every failure-prone operation is
wrapped in ``injector.site("shard{i}.<op>")`` hooks, so seeded fault
schedules (crash / stall / slow / lost reply) exercise every path in CI —
see DESIGN.md §13 for the fault model and the injection-site registry.
"""
from __future__ import annotations

import dataclasses
import io
import logging
import os
import struct
import zlib
from pathlib import Path

import numpy as np

from ..checkpoint import manager as ckpt_manager
from ..core import hashing
from ..core.incremental import normalize_keys
from ..launch.faults import (
    FaultInjector,
    InjectedCrash,
    InjectedLostReply,
    InjectedPartition,
    InjectedStall,
    Unreachable,
    VirtualClock,
)

from .query import BatchResult, Query
from .service import StatsConfig, StreamStatsService

log = logging.getLogger(__name__)

# routing salt: distinct from every sampling salt so the shard partition is
# independent of the sample (a key's shard must not correlate with its
# inclusion randomness)
SALT_ROUTE = 0x5A3D


# ---------------------------------------------------------------------------
# Key routing
# ---------------------------------------------------------------------------


def route_keys(keys, n_shards: int, *, salt: int = SALT_ROUTE) -> np.ndarray:
    """Deterministic shard id per key: ``hash(salt, key) % n_shards``.

    Same counter-based hashing as the samplers (core.hashing), so the
    partition is a pure function of (salt, key) — stable across restarts,
    platforms, and batch boundaries.  Key-partitioned shards are what make
    the tier's one-pass merges unbiased."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    keys = normalize_keys(keys)
    # keys first: the array part makes every mixing op array-shaped (0-d
    # uint32 chains trip numpy's scalar-overflow warning)
    h = hashing.hash_combine_np(keys, np.uint32(salt))
    return (h % np.uint32(n_shards)).astype(np.int64)


def partition_batch(keys, weights, n_shards: int, *, salt: int = SALT_ROUTE):
    """Split one ingest batch into per-shard (keys, weights) sub-batches,
    preserving arrival order within each shard (mask selection is stable)."""
    keys = normalize_keys(keys)
    if weights is None:
        weights = np.ones(len(keys), np.float32)
    else:
        weights = np.asarray(weights, np.float32)
        if weights.shape != keys.shape:
            raise ValueError("weights must match keys")
    sid = route_keys(keys, n_shards, salt=salt)
    return [(keys[sid == s], weights[sid == s]) for s in range(n_shards)]


# ---------------------------------------------------------------------------
# Exceptions
# ---------------------------------------------------------------------------


class ShardDown(RuntimeError):
    """The shard's in-memory state is gone (crashed or never recovered)."""


class ExactUnavailable(RuntimeError):
    """Exact two-pass answers cannot be produced right now (a shard is down
    or mid-replay, or the WAL no longer covers the full stream)."""


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------


class WALCorrupt(ValueError):
    """A WAL segment's bytes fail integrity verification (short file,
    missing trailer, or CRC mismatch)."""


# Segment trailer: written AFTER the payload so a truncated/torn file can
# never carry a valid trailer — magic + crc32(payload) + payload length.
_WAL_TRAILER_MAGIC = b"WSG1"
_WAL_TRAILER = struct.Struct("<4sIQ")


class ShardWAL:
    """Per-shard durable log of routed batches, one ``wal_<seq>.npz`` per
    sequence number (1-based, contiguous).  Segments commit with the same
    fsync discipline as checkpoints (checkpoint.manager.fsync_file/_dir):
    write tmp, fsync data, rename, fsync directory — a host crash never
    surfaces a torn segment, and ``entries`` only ever sees committed ones.

    Integrity: every segment carries a CRC32 trailer over its ``.npz``
    payload (magic + crc + length, written after the payload — a torn tail
    cannot end in a valid trailer).  Replay verifies each segment; a
    corrupt segment in the MIDDLE of the log is unrecoverable data loss and
    raises.  A corrupt TAIL segment — the one case fs reordering or torn
    disk writes can plausibly produce — is tolerated: ``entries`` repairs
    it from the in-memory WAL-first buffer (the coordinator appended the
    batch moments ago and still holds it) or, if this process never wrote
    it, drops the segment with a logged warning so ``recover()`` completes
    on the verified prefix instead of crashing.
    """

    def __init__(self, dirpath, *, fsync: bool = True):
        self.dir = Path(dirpath)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        # WAL-first buffer: the most recent append, kept in memory until
        # superseded — the repair source for a torn tail segment.
        self._last: tuple[int, np.ndarray, np.ndarray] | None = None

    def _path(self, seq: int) -> Path:
        return self.dir / f"wal_{seq:08d}.npz"

    def append(self, seq: int, keys, weights) -> None:
        if seq < 1:
            raise ValueError("WAL sequence numbers are 1-based")
        keys = np.asarray(keys, np.int32)
        weights = np.asarray(weights, np.float32)
        path = self._path(seq)
        tmp = path.with_suffix(".npz.tmp")
        buf = io.BytesIO()
        np.savez(buf, keys=keys, weights=weights)
        payload = buf.getvalue()
        trailer = _WAL_TRAILER.pack(_WAL_TRAILER_MAGIC,
                                    zlib.crc32(payload), len(payload))
        with open(tmp, "wb") as f:
            f.write(payload)
            f.write(trailer)
        if self.fsync:
            ckpt_manager.fsync_file(tmp)
        os.replace(tmp, path)
        if self.fsync:
            ckpt_manager.fsync_dir(self.dir)
        self._last = (seq, keys, weights)

    def read_segment(self, seq: int) -> tuple[np.ndarray, np.ndarray]:
        """Read and VERIFY one committed segment; raises WALCorrupt if the
        bytes fail the trailer/CRC check."""
        raw = self._path(seq).read_bytes()
        if len(raw) < _WAL_TRAILER.size:
            raise WALCorrupt(f"WAL seq {seq}: {len(raw)} bytes, shorter "
                             "than the integrity trailer")
        magic, crc, length = _WAL_TRAILER.unpack(raw[-_WAL_TRAILER.size:])
        payload = raw[:-_WAL_TRAILER.size]
        if magic != _WAL_TRAILER_MAGIC or length != len(payload):
            raise WALCorrupt(f"WAL seq {seq}: torn segment (bad trailer; "
                             f"payload {len(payload)} bytes, trailer "
                             f"claims {length})")
        if zlib.crc32(payload) != crc:
            raise WALCorrupt(f"WAL seq {seq}: CRC32 mismatch")
        with np.load(io.BytesIO(payload)) as d:
            return d["keys"], d["weights"]

    def seqs(self) -> list[int]:
        return sorted(int(p.name[4:12]) for p in self.dir.glob("wal_*.npz"))

    def last_seq(self) -> int:
        s = self.seqs()
        return s[-1] if s else 0

    def check_tail(self) -> int:
        """Verify the last segment, repairing/dropping a torn tail (see
        ``entries``).  Returns the last VALID sequence number (0 when
        empty).  The process-mode supervisor runs this coordinator-side —
        where the WAL-first buffer lives — before asking a remote worker
        (whose ShardWAL instance has no buffer) to replay the log."""
        seqs = self.seqs()
        if not seqs:
            return 0
        tail = seqs[-1]
        try:
            self.read_segment(tail)
            return tail
        except WALCorrupt as e:
            if not self._repair_tail(tail, e):
                return tail - 1
            return tail

    def _repair_tail(self, seq: int, err: WALCorrupt) -> bool:
        """Torn tail handling: rewrite from the WAL-first buffer when this
        process still holds the batch, else drop the segment (logged)."""
        if self._last is not None and self._last[0] == seq:
            log.warning("%s: %s — repaired from the WAL-first buffer",
                        self.dir, err)
            self.append(seq, self._last[1], self._last[2])
            return True
        log.warning("%s: %s — dropped torn tail segment (no WAL-first "
                    "buffer in this process; replay stops at seq %d)",
                    self.dir, err, seq - 1)
        self._path(seq).unlink()
        if self.fsync:
            ckpt_manager.fsync_dir(self.dir)
        return False

    def entries(self, after: int = 0):
        """Yield committed, VERIFIED ``(seq, keys, weights)`` with seq >
        ``after`` in sequence order, verifying contiguity — a gap means the
        log was truncated past ``after`` and replay from there would drop
        batches.  A corrupt tail segment is repaired from the WAL-first
        buffer or dropped (replay ends one batch early, logged); a corrupt
        interior segment raises WALCorrupt."""
        expect = after
        seqs = self.seqs()
        for seq in seqs:
            if seq <= after:
                continue
            expect += 1
            if seq != expect:
                raise ValueError(
                    f"WAL gap: expected seq {expect}, found {seq} — the log "
                    f"was truncated past the requested replay point {after}")
            try:
                keys, weights = self.read_segment(seq)
            except WALCorrupt as e:
                if seq != seqs[-1]:
                    raise WALCorrupt(
                        f"{e} — segment is INTERIOR (last is {seqs[-1]}): "
                        "replaying past it would silently drop a batch"
                    ) from None
                if not self._repair_tail(seq, e):
                    return
                keys, weights = self.read_segment(seq)
            yield seq, keys, weights

    def truncate_through(self, seq: int) -> None:
        """Drop segments <= ``seq`` (their batches are inside a committed
        checkpoint).  Crash-safe: deletion after checkpoint commit means a
        crash in between only leaves extra segments, never missing ones."""
        for s in self.seqs():
            if s <= seq:
                self._path(s).unlink()
        if self.fsync:
            ckpt_manager.fsync_dir(self.dir)

    def covers_from_origin(self, through: int | None = None) -> bool:
        """True iff the retained log is the COMPLETE stream — seqs 1..last
        with no truncation, reaching at least ``through`` when given (an
        empty log trivially "covers" nothing, so exact pass II must demand
        coverage through the shard's applied sequence)."""
        s = self.seqs()
        if s != list(range(1, len(s) + 1)):
            return False
        return through is None or len(s) >= through


# ---------------------------------------------------------------------------
# Shard worker
# ---------------------------------------------------------------------------


class ShardWorker:
    """One in-process shard: an incremental sampler bank (StreamStatsService
    with ``host_id = shard_id``) behind fault-injection hooks, with
    checkpoint + WAL recovery.

    Every public operation is wrapped in a named injection site
    (``shard<i>.<op>``, see launch.faults.SITES).  An injected crash kills
    the in-memory state (``alive = False``); the durable state — committed
    checkpoints plus WAL segments — is all ``recover()`` needs to rebuild
    the exact pre-crash sketch, bit for bit.
    """

    def __init__(self, shard_id: int, config: StatsConfig, root, *,
                 checkpoint_every: int = 8, retain_wal: bool = False,
                 faults: FaultInjector | None = None, fsync: bool = True):
        self.shard_id = int(shard_id)
        self.config = dataclasses.replace(config, host_id=self.shard_id)
        self.root = Path(root) / f"shard_{self.shard_id:02d}"
        self.ckpt_dir = self.root / "ckpt"
        self.wal = ShardWAL(self.root / "wal", fsync=fsync)
        self.checkpoint_every = int(checkpoint_every)
        self.retain_wal = bool(retain_wal)
        self._faults = faults if faults is not None else FaultInjector()
        self.service: StreamStatsService | None = StreamStatsService(self.config)
        self.applied_seq = 0      # last WAL sequence folded into the service
        self._last_ckpt_seq = 0
        self.alive = True

    # -- fault plumbing ----------------------------------------------------

    def _site(self, op: str) -> str:
        return f"shard{self.shard_id}.{op}"

    def _guarded(self, op: str, *, check_alive: bool = True) -> "_SiteGuard":
        """Injection wrapper: translates an injected crash into worker death
        (in-memory state gone) + ShardDown for the caller; stall/slow/lost-
        reply pass through as themselves (the coordinator's retry loop and
        idempotent apply handle those)."""
        return _SiteGuard(self, op, check_alive)

    def crash(self) -> None:
        """Simulate a process kill: in-memory state is gone; the durable
        checkpoint + WAL survive."""
        self.alive = False
        self.service = None

    def _check_alive(self) -> None:
        if not self.alive:
            raise ShardDown(f"shard {self.shard_id} is down")

    # -- operations (each behind its injection site) -----------------------

    def heartbeat(self) -> int:
        """Liveness probe; returns the applied sequence number (the
        coordinator's staleness signal)."""
        with self._guarded("heartbeat"):
            return self.applied_seq

    def apply(self, seq: int, keys, weights) -> int:
        """Fold one WAL batch into the sketch.  Idempotent: ``seq`` at or
        below ``applied_seq`` is an ack-only no-op — the retry path after a
        lost reply must not double-count elements.  Out-of-order gaps are an
        error (the coordinator always sends contiguous sequences)."""
        with self._guarded("ingest"):
            if seq > self.applied_seq:
                if seq != self.applied_seq + 1:
                    raise ValueError(
                        f"shard {self.shard_id}: apply gap — got seq {seq}, "
                        f"applied through {self.applied_seq}")
                self.service.observe(keys, weights)
                self.applied_seq = seq
        if (self.checkpoint_every
                and self.applied_seq - self._last_ckpt_seq >= self.checkpoint_every):
            self.checkpoint()
        return self.applied_seq

    def checkpoint(self) -> int:
        """Commit the sketch at the current applied sequence, then truncate
        the WAL through it (unless ``retain_wal``).  Commit-then-truncate:
        a crash in between leaves extra WAL segments, never a hole."""
        with self._guarded("checkpoint"):
            self.service.save_checkpoint(self.ckpt_dir, step=self.applied_seq)
            self._last_ckpt_seq = self.applied_seq
            if not self.retain_wal:
                self.wal.truncate_through(self.applied_seq)
            return self.applied_seq

    def service_view(self) -> StreamStatsService:
        """The live sketch service, for the coordinator's merge fold (the
        fold reads flushed state; it never mutates the worker)."""
        with self._guarded("query"):
            return self.service

    def recover(self) -> int:
        """Rebuild from durable state: restore the latest committed
        checkpoint (if any), then replay the WAL tail through ``observe``.

        Bit-identity property (tested): the rebuilt sketch equals the
        never-crashed worker's, because (a) checkpoints round-trip the full
        sampler state bit-for-bit including the sub-chunk remainder, and
        (b) the chunk partition of a stream depends only on the element
        sequence, which the WAL fixes.  Safe to call on a live worker too
        (e.g. to catch a stalled-but-alive shard up with its WAL): the
        rebuild is idempotent."""
        with self._guarded("recover", check_alive=False):
            svc = StreamStatsService(self.config)
            step = ckpt_manager.latest_step(self.ckpt_dir)
            applied = 0
            if step is not None:
                svc.restore_checkpoint(self.ckpt_dir, step)
                applied = step
            for seq, keys, weights in self.wal.entries(after=applied):
                svc.observe(keys, weights)
                applied = seq
            self.service = svc
            self.applied_seq = applied
            self._last_ckpt_seq = step or 0
            self.alive = True
            return applied

    @property
    def n_observed(self) -> int:
        self._check_alive()
        return self.service.n_observed

    def runtime_status(self) -> dict:
        """Coordinator-visible worker facts for the status plane.  NOT an
        RPC (no injection site): the coordinator reads its own bookkeeping
        mirror of the worker, so a down shard still reports.  The process-
        mode supervisor overrides this to add pid/restart facts."""
        return {
            "alive": self.alive,
            "applied_seq": self.applied_seq,
            "last_checkpoint_seq": self._last_ckpt_seq,
            "wal_depth": len(self.wal.seqs()),
        }


class _SiteGuard:
    """``with worker._guarded(op):`` — liveness check + injection site +
    crash translation, as a context manager usable around return-bearing
    bodies (a lost reply fires on exit, AFTER the body ran)."""

    def __init__(self, worker: ShardWorker, op: str, check_alive: bool = True):
        self.worker = worker
        self.op = op
        self.check_alive = check_alive
        self._cm = None

    def __enter__(self):
        if self.check_alive:
            self.worker._check_alive()
        self._cm = self.worker._faults.site(self.worker._site(self.op))
        try:
            self._cm.__enter__()
        except InjectedCrash:
            self._cm = None
            self.worker.crash()
            raise ShardDown(
                f"shard {self.worker.shard_id} crashed in {self.op}") from None
        return self

    def __exit__(self, exc_type, exc, tb):
        cm, self._cm = self._cm, None
        if cm is None:
            return False
        try:
            return cm.__exit__(exc_type, exc, tb)
        except InjectedCrash:
            self.worker.crash()
            raise ShardDown(
                f"shard {self.worker.shard_id} crashed in {self.op}") from None


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TierConfig:
    n_shards: int = 4
    # applied WAL batches between shard checkpoints (the durability/
    # recovery-time cadence measured by benchmarks/serve_throughput.py)
    checkpoint_every: int = 8
    # consecutive failed heartbeats before a shard is declared down
    heartbeat_miss_limit: int = 3
    # bounded retry on shard calls: attempts beyond the first
    max_retries: int = 3
    backoff_base_s: float = 0.05     # first retry delay
    backoff_factor: float = 2.0      # exponential growth per retry
    call_deadline_s: float = 2.0     # give up when backoff would pass this
    # keep the complete WAL (never truncate at checkpoints): required for
    # exact two-pass queries, costs O(stream) disk
    retain_wal: bool = False
    # immediately attempt recover() when a shard is declared down
    auto_recover: bool = True
    route_salt: int = SALT_ROUTE
    fsync: bool = True
    # Background exact-merge cadence (DESIGN.md §14): every N ingested
    # batches and/or every S (clock) seconds, fold the shard WALs into a
    # reconciled exact snapshot (merge_many(mode="exact") + full pass II)
    # served by query mode="snapshot" — exact as of its watermark, stamped
    # with element staleness — while approx queries keep serving from the
    # live sketches.  Requires retain_wal.  None disables the cadence.
    merge_every_n_batches: int | None = None
    merge_every_s: float | None = None


class ShardTier:
    """Coordinator over N key-partitioned shard workers.

    Ingest is WAL-first: every routed sub-batch is durable in the target
    shard's log *before* the shard call, so a crash at any point loses
    nothing — recovery replays the log.  Down shards keep accumulating WAL
    (their keys still route to them); ``recover_shard`` catches them up.

    Failure detection: ``check_health()`` heartbeats every member shard,
    counts consecutive misses, and declares a shard down past the miss
    limit (a crashed shard is declared immediately).  All shard calls run
    under bounded retry with exponential backoff + a deadline; with a
    ``VirtualClock`` (the default) backoff advances virtual time only, so
    chaos tests are fast and bit-deterministic.

    Membership: each slot is ``up`` / ``down`` / ``left``.  ``leave_shard``
    is the graceful decommission half of the elastic join/leave protocol
    (final checkpoint, slot keeps its WAL); ``join_shard`` revives the slot
    from durable state (launch/elastic.py demos the cycle).
    """

    def __init__(self, config: StatsConfig, tier: TierConfig | None = None,
                 root=None, *, faults: FaultInjector | None = None):
        if config.host_id is not None:
            raise ValueError(
                "ShardTier assigns host_ids (the shard ids); leave "
                "StatsConfig.host_id unset")
        self.tier = tier or TierConfig()
        if root is None:
            raise ValueError("ShardTier needs a durable root directory")
        self.root = Path(root)
        self.base_config = config
        self._faults = faults if faults is not None else FaultInjector()
        self.clock = self._faults.clock
        n = self.tier.n_shards
        if (self.tier.merge_every_n_batches or
                self.tier.merge_every_s is not None) and not self.tier.retain_wal:
            raise ValueError(
                "the background exact-merge cadence replays full WALs; set "
                "TierConfig.retain_wal=True with merge_every_*")
        self.workers = [self._make_worker(s) for s in range(n)]
        self.slots = ["up"] * n           # "up" | "down" | "left"
        self._next_seq = [1] * n          # next WAL sequence per shard
        self._routed = [0] * n            # elements routed per shard (truth)
        self._miss = [0] * n              # consecutive heartbeat misses
        self._version = 0                 # bumped on any state change
        self._merged_cache: dict = {}     # (mode, shards, version) -> service
        self.events: list[tuple[float, int, str, str]] = []  # observability
        # background exact-merge snapshot (None until the first refresh)
        self._snapshot: dict | None = None
        self._batches_since_merge = 0
        self._last_merge_t = self.clock.now()
        self._n_merges = 0
        self._n_merges_skipped = 0

    def _make_worker(self, s: int):
        """Worker factory — the ONE point subclasses override to swap the
        in-process ShardWorker for a real-subprocess client (procshard)."""
        return ShardWorker(s, self.base_config, self.root,
                           checkpoint_every=self.tier.checkpoint_every,
                           retain_wal=self.tier.retain_wal,
                           faults=self._faults, fsync=self.tier.fsync)

    # -- bookkeeping -------------------------------------------------------

    def _bump(self) -> None:
        self._version += 1
        self._merged_cache.clear()

    def _log_event(self, shard: int, event: str, detail: str = "") -> None:
        self.events.append((self.clock.now(), shard, event, detail))

    def membership(self) -> dict[int, str]:
        return {s: self.slots[s] for s in range(self.tier.n_shards)}

    def live_shards(self) -> list[int]:
        return [s for s in range(self.tier.n_shards) if self.slots[s] == "up"]

    @property
    def n_observed(self) -> int:
        """Total elements routed into the tier (independent of shard state)."""
        return sum(self._routed)

    # -- bounded retry -----------------------------------------------------

    # Faults that are worth retrying: the callee may be alive and the
    # operation idempotent.  Partition (process mode: connection severed)
    # and Unreachable (real socket timeout) behave exactly like a stall.
    _RETRIABLE = (InjectedStall, InjectedLostReply, InjectedPartition,
                  Unreachable)

    def _call(self, s: int, desc: str, fn):
        """Run one shard call under bounded retry with exponential backoff
        and a deadline.  Crash -> immediate down (retrying a dead process
        is pointless); stall/lost-reply/partition/unreachable -> retry
        (apply is idempotent, so a lost reply retried is an ack-only
        no-op); budget exhausted -> down.  Returns ``(ok, value)``.

        A SUCCESSFUL call resets the shard's heartbeat miss counter: any
        completed operation proves liveness, so a shard that is slow on
        heartbeats but still applying batches is never flapped to dead by
        heartbeat misses alone (the flap regression in
        tests/test_shardtier.py pins this)."""
        cfg = self.tier
        delay = cfg.backoff_base_s
        deadline = self.clock.now() + cfg.call_deadline_s
        attempt = 0
        while True:
            try:
                out = fn()
            except ShardDown as e:
                self._mark_down(s, f"{desc}: {e}")
                return False, None
            except self._RETRIABLE as e:
                attempt += 1
                if attempt > cfg.max_retries or self.clock.now() + delay > deadline:
                    self._mark_down(
                        s, f"{desc}: retry budget exhausted after {attempt} "
                           f"attempts ({type(e).__name__})")
                    return False, None
                self.clock.sleep(delay)
                delay *= cfg.backoff_factor
            else:
                self._miss[s] = 0
                return True, out

    def _mark_down(self, s: int, reason: str) -> None:
        if self.slots[s] == "down":
            return
        self.slots[s] = "down"
        self._miss[s] = 0
        self._bump()
        self._log_event(s, "down", reason)
        if self.tier.auto_recover:
            self.recover_shard(s)

    # -- failure detection -------------------------------------------------

    def check_health(self) -> dict[int, str]:
        """One heartbeat round over every member shard.  A crashed shard is
        declared down immediately; a stalled/unresponsive one accumulates
        misses and is declared down at the miss limit.  A responsive shard
        currently marked down (e.g. recovery succeeded but its reply was
        lost) is brought back through ``recover_shard`` — recovery is
        idempotent, so this also catches the shard up with any WAL batches
        routed while it was out.  A DEAD shard already marked down (its
        recovery attempt itself crashed) is retried under ``auto_recover``:
        ``_mark_down`` is a no-op on an already-down shard, so without the
        retry here a crash-during-recover would wedge the slot forever."""
        for s in range(self.tier.n_shards):
            if self.slots[s] == "left":
                continue
            try:
                self.workers[s].heartbeat()
            except ShardDown as e:
                was_down = self.slots[s] == "down"
                self._mark_down(s, f"heartbeat: {e}")
                if was_down and self.tier.auto_recover:
                    self.recover_shard(s)
                continue
            except self._RETRIABLE as e:
                self._miss[s] += 1
                self._log_event(s, "miss",
                                f"{self._miss[s]}/{self.tier.heartbeat_miss_limit}"
                                f" ({type(e).__name__})")
                if self._miss[s] >= self.tier.heartbeat_miss_limit:
                    self._mark_down(s, "heartbeat miss limit")
                continue
            self._miss[s] = 0
            if self.slots[s] == "down":
                self.recover_shard(s)
        return self.membership()

    # -- recovery ----------------------------------------------------------

    def recover_shard(self, s: int) -> bool:
        """Restart shard ``s`` from its durable state (checkpoint restore +
        WAL replay).  On success the shard is up AND caught up with every
        batch routed to it, including ones routed while it was down."""
        if self.slots[s] == "left":
            raise ValueError(f"shard {s} left the tier; use join_shard")
        self._bump()
        t0 = self.clock.now()
        try:
            applied = self.workers[s].recover()
        except ShardDown:
            self._log_event(s, "recover_failed", "crashed during recovery")
            self.slots[s] = "down"
            return False
        except self._RETRIABLE as e:
            # a lost recovery reply may leave the worker healthy; the next
            # health round's heartbeat brings the slot back
            self._log_event(s, "recover_failed", type(e).__name__)
            self.slots[s] = "down"
            return False
        self.slots[s] = "up"
        self._miss[s] = 0
        self._log_event(s, "recovered",
                        f"applied through seq {applied} "
                        f"in {self.clock.now() - t0:g}s")
        return True

    def kill_shard(self, s: int) -> None:
        """Test/chaos hook: hard-kill a shard's in-memory state without
        telling the coordinator (detection happens via heartbeats/calls)."""
        self.workers[s].crash()

    # -- ingest ------------------------------------------------------------

    def ingest(self, keys, weights=None) -> dict[int, int]:
        """Route one batch to the shards, WAL-first.  Returns the number of
        elements routed per shard.  Down/left shards still get their WAL
        appends (losing a shard must not lose its keys' data) and catch up
        at recovery/join."""
        parts = partition_batch(keys, weights, self.tier.n_shards,
                                salt=self.tier.route_salt)
        self._bump()
        routed = {}
        for s, (pk, pw) in enumerate(parts):
            if len(pk) == 0:
                continue
            seq = self._next_seq[s]
            self.workers[s].wal.append(seq, pk, pw)  # durable BEFORE the call
            self._next_seq[s] = seq + 1
            self._routed[s] += len(pk)
            routed[s] = len(pk)
            if self.slots[s] != "up":
                continue  # replayed at recovery
            self._call(s, f"apply seq {seq}",
                       lambda w=self.workers[s], q=seq, a=pk, b=pw:
                       w.apply(q, a, b))
        self._batches_since_merge += 1
        self._maybe_refresh_snapshot()
        return routed

    # -- background exact-merge snapshot -----------------------------------

    def _merge_due(self) -> bool:
        cfg = self.tier
        if (cfg.merge_every_n_batches
                and self._batches_since_merge >= cfg.merge_every_n_batches):
            return True
        if (cfg.merge_every_s is not None
                and self.clock.now() - self._last_merge_t >= cfg.merge_every_s):
            return True
        return False

    def _maybe_refresh_snapshot(self) -> bool:
        """Cadence hook (end of every ingest): refresh the exact snapshot
        when the configured cadence has elapsed.  A refresh that cannot run
        right now (shard down, WAL truncated) is SKIPPED, not fatal —
        approx queries keep serving and the cadence retries next batch."""
        if not self._merge_due():
            return False
        return self.refresh_snapshot()

    def refresh_snapshot(self) -> bool:
        """Fold every shard's WAL into a reconciled exact snapshot NOW.

        The snapshot is a frozen scratch service answering ``mode=
        "snapshot"`` queries — exact as of its watermark (every element
        routed before the fold), stamped with how many elements arrived
        since.  Returns False (and logs a ``merge_skipped`` event) when
        exact state is unreachable; the live approx path is unaffected."""
        t0 = self.clock.now()
        try:
            scratch = self._merged_exact()
        except ExactUnavailable as e:
            self._n_merges_skipped += 1
            self._log_event(-1, "merge_skipped", str(e))
            return False
        self._snapshot = {
            "service": scratch,
            "watermark_elements": self.n_observed,
            "watermark_seqs": tuple(q - 1 for q in self._next_seq),
            "built_at": self.clock.now(),
            "build_s": self.clock.now() - t0,
        }
        self._n_merges += 1
        self._batches_since_merge = 0
        self._last_merge_t = self.clock.now()
        self._log_event(-1, "merged",
                        f"exact snapshot at {self.n_observed} elements "
                        f"in {self._snapshot['build_s']:g}s")
        return True

    def snapshot_staleness(self) -> int | None:
        """Elements routed since the current exact snapshot's watermark
        (None when no snapshot exists yet) — the estimate-staleness the
        merge cadence trades against merge cost (BENCH_serve.json v4)."""
        if self._snapshot is None:
            return None
        return self.n_observed - self._snapshot["watermark_elements"]

    # -- status plane ------------------------------------------------------

    def status(self, *, events_tail: int = 32) -> dict:
        """Flexlb-style load/status plane: one JSON-serializable dict the
        serving layer can poll/scrape without touching any worker RPC —
        everything here is coordinator bookkeeping plus each worker's
        ``runtime_status`` mirror, so a wedged shard cannot wedge status.

        Shape::

            {"shards": {i: {state, load, share, heartbeat_misses,
                            alive, applied_seq, last_checkpoint_seq,
                            wal_depth, ...proc facts...}},
             "coverage": float,       # routed-element fraction on up shards
             "n_observed": int, "membership": {...},
             "snapshot": {...} | None,  # exact-merge tier watermark/cadence
             "events": [[t, shard, event, detail], ...]}  # most recent
        """
        total = sum(self._routed)
        shards: dict[int, dict] = {}
        covered = 0
        for s in range(self.tier.n_shards):
            st = {
                "state": self.slots[s],
                "load": self._routed[s],
                "share": (self._routed[s] / total) if total else 0.0,
                "heartbeat_misses": self._miss[s],
            }
            st.update(self.workers[s].runtime_status())
            shards[s] = st
            if self.slots[s] == "up":
                covered += self._routed[s]
        snap = None
        if self._snapshot is not None:
            snap = {
                "watermark_elements": self._snapshot["watermark_elements"],
                "staleness_elements": self.snapshot_staleness(),
                "built_at": self._snapshot["built_at"],
                "build_s": self._snapshot["build_s"],
            }
        return {
            "shards": shards,
            "coverage": (covered / total) if total else 1.0,
            "n_observed": total,
            "membership": self.membership(),
            "snapshot": snap,
            "merges": {"done": self._n_merges,
                       "skipped": self._n_merges_skipped,
                       "batches_since": self._batches_since_merge},
            "events": [list(e) for e in self.events[-events_tail:]],
        }

    # -- queries -----------------------------------------------------------

    def _shard_services(self):
        """Collect the live shards' service views (a failing view marks that
        shard down and excludes it).  Returns ``[(shard, service), ...]``."""
        views = []
        for s in list(self.live_shards()):
            ok, svc = self._call(s, "query view",
                                 lambda w=self.workers[s]: w.service_view())
            if ok and self.slots[s] == "up":
                views.append((s, svc))
        return views

    def _merged_approx(self):
        """One-pass fold of the surviving shards into a scratch service.
        Cached per (membership, version) — repeated queries between state
        changes reuse the fold AND the scratch service's engine caches."""
        views = self._shard_services()
        shards = tuple(s for s, _ in views)
        key = ("approx", shards, self._version)
        hit = self._merged_cache.get(key)
        if hit is not None:
            return hit
        scratch = StreamStatsService(dataclasses.replace(
            self.base_config, host_id=self.tier.n_shards))
        # key-partitioned shards: the one-pass fold is unbiased even for a
        # subset (each key's full stream is on exactly one shard)
        scratch.merge_many([svc for _, svc in views], mode="approx")
        self._merged_cache = {key: (scratch, shards)}
        return scratch, shards

    def _merged_exact(self):
        """Full two-pass: exact merge of every shard's lossless summaries,
        then pass II replays each complete WAL through ``reconcile``."""
        n = self.tier.n_shards
        not_up = [s for s in range(n) if self.slots[s] != "up"]
        if not_up:
            raise ExactUnavailable(
                f"shards {not_up} are not up — pass II cannot reach the "
                "whole stream")
        key = ("exact", tuple(range(n)), self._version)
        hit = self._merged_cache.get(key)
        if hit is not None:
            return hit
        for s in range(n):
            if not self.workers[s].wal.covers_from_origin(
                    self.workers[s].applied_seq):
                raise ExactUnavailable(
                    f"shard {s}'s WAL was truncated at a checkpoint — exact "
                    "pass II needs the full stream (TierConfig.retain_wal)")
        views = self._shard_services()
        if len(views) != n:
            raise ExactUnavailable(
                "lost a shard while collecting pass-I summaries")
        scratch = StreamStatsService(dataclasses.replace(
            self.base_config, host_id=n))
        scratch.merge_many([svc for _, svc in views], mode="exact")
        scratch.begin_reconcile()
        for s in range(n):
            for _seq, keys, weights in self.workers[s].wal.entries(after=0):
                scratch.reconcile(keys, weights)
        self._merged_cache = {key: scratch}
        return scratch

    def _stamp(self, res: BatchResult, *, coverage: float, stale: int,
               degraded: bool, mode: str) -> BatchResult:
        if degraded and 0.0 < coverage < 1.0:
            # shard-inclusion Horvitz-Thompson scaling: a key's whole stream
            # lives on one shard, and the reachable shards cover ``coverage``
            # of the routed elements — scale up by the inverse, and widen
            # the variance by the unscaled estimator's variance growth plus
            # a missing-mass term (the unseen shards' contribution is
            # unknown, so the stamp is a diagnostic envelope, not a CI)
            from .query import _Z95
            inv = 1.0 / coverage
            est = res.estimates * inv
            var = res.variances * inv * inv + np.square(est) * (1.0 - coverage)
            stderr = np.sqrt(var)
            res = dataclasses.replace(
                res, estimates=est, variances=var, stderr=stderr,
                ci_low=est - _Z95 * stderr, ci_high=est + _Z95 * stderr)
        return dataclasses.replace(
            res, coverage=coverage, staleness_elements=stale,
            degraded=degraded, mode=mode)

    def query_batch(self, queries, *, mode: str = "approx") -> BatchResult:
        """Answer a query batch from the tier.

        mode="approx": one-pass fold of the surviving shards.  With every
        shard up this is the tier's normal answer (coverage 1.0, not
        degraded).  With shards down, answers carry the degradation stamp:
        coverage fraction, staleness count, HT-scaled estimates, widened
        diagnostics.

        mode="exact": the full two-pass answer (requires ``retain_wal`` and
        every shard up), bit-identical across crash/recover histories.
        Raises ExactUnavailable otherwise.

        mode="auto": exact when available, degraded approx fallback.

        mode="snapshot": serve from the background exact-merge snapshot —
        exact as of its watermark, stamped with ``staleness_elements`` =
        elements routed since (coverage 1.0, not degraded: the answer is
        exact over everything it claims to cover).  Raises ExactUnavailable
        before the first snapshot exists.
        """
        if mode not in ("approx", "exact", "auto", "snapshot"):
            raise ValueError(f"unknown tier query mode {mode!r}")
        if mode == "snapshot":
            snap = self._snapshot
            if snap is None:
                raise ExactUnavailable(
                    "no exact snapshot yet — set a merge cadence "
                    "(TierConfig.merge_every_*) or call refresh_snapshot()")
            res = snap["service"].query_batch(queries, exact=True)
            return self._stamp(
                res, coverage=1.0,
                stale=self.n_observed - snap["watermark_elements"],
                degraded=False, mode="snapshot")
        if mode in ("exact", "auto"):
            try:
                scratch = self._merged_exact()
                res = scratch.query_batch(queries, exact=True)
                return self._stamp(res, coverage=1.0, stale=0,
                                   degraded=False, mode="exact")
            except ExactUnavailable:
                if mode == "exact":
                    raise
        scratch, shards = self._merged_approx()
        res = scratch.query_batch(queries, exact=False)
        total = sum(self._routed)
        covered = sum(self._routed[s] for s in shards)
        coverage = (covered / total) if total else 1.0
        return self._stamp(res, coverage=coverage, stale=total - covered,
                           degraded=coverage < 1.0, mode="approx")

    def query_cap(self, T: float, segment=None, *, mode: str = "approx") -> float:
        from ..core import freqfns
        r = self.query_batch([Query(freqfns.cap(T), segment)], mode=mode)
        return float(r.estimates[0])

    def query_distinct(self, segment=None, *, mode: str = "approx") -> float:
        from ..core import freqfns
        r = self.query_batch([Query(freqfns.distinct(), segment)], mode=mode)
        return float(r.estimates[0])

    def query_total(self, segment=None, *, mode: str = "approx") -> float:
        from ..core import freqfns
        r = self.query_batch([Query(freqfns.total(), segment)], mode=mode)
        return float(r.estimates[0])

    # -- elastic membership ------------------------------------------------

    def leave_shard(self, s: int) -> Path:
        """Graceful decommission: final checkpoint, slot marked ``left``.
        The slot's WAL keeps accumulating (its keys still route to it), so
        a later ``join_shard`` catches the replacement up losslessly.
        Returns the slot's durable state directory (the handoff blob)."""
        if self.slots[s] != "up":
            raise ValueError(f"shard {s} is {self.slots[s]}; cannot leave")
        ok, _ = self._call(s, "leave checkpoint",
                           lambda w=self.workers[s]: w.checkpoint())
        if not ok:
            raise RuntimeError(
                f"shard {s} failed its final checkpoint; recover it first")
        self.workers[s].crash()  # release in-memory state
        self.slots[s] = "left"
        self._bump()
        self._log_event(s, "left", "graceful decommission")
        return self.workers[s].root

    def join_shard(self, s: int) -> bool:
        """Revive slot ``s`` as a fresh worker (a new process) from the
        slot's durable state: checkpoint restore + WAL tail replay."""
        if self.slots[s] != "left":
            raise ValueError(f"shard {s} is {self.slots[s]}; join revives "
                             "decommissioned slots (use recover_shard for "
                             "crashed ones)")
        self.workers[s] = self._make_worker(s)
        self.slots[s] = "down"  # recover_shard flips to up on success
        self._bump()
        self._log_event(s, "joining", "fresh worker over durable slot state")
        return self.recover_shard(s)
