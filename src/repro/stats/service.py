"""StreamStatsService: frequency-cap statistics as a first-class framework
feature (the paper's ad-campaign application, generalized) — a true
incremental service with an **exact multi-host mode**.

Attach a service to any input pipeline; it maintains one fixed-k continuous
SH_l sketch per configured l over the stream of keys flowing through
training/serving and answers

    service.query_cap(T, segment)  ~=  Q(cap_T, segment)

**State is O(k * |ls|), independent of stream length.**  ``observe()``
advances every sketch of the l-grid in a single jitted device dispatch with
donated state buffers (core.incremental.MultiSampler): the fused multi-l
capscore kernel scores all lanes in one VMEM-resident pass over the batch,
then the merge/evict step runs vmapped across lanes.  Nothing is buffered
except the sub-chunk remainder (< chunk elements) awaiting alignment;
queries finalize the resident sketches lazily (cached until the next
``observe``) — no replay, no recompute.

Uses: ad-campaign reach forecasting (recsys archs: keys = (user, campaign)
pairs, answer = number of qualifying impressions under a per-user cap T);
token-frequency statistics for LM data mixing; degree statistics for GNN
samplers; expert-load statistics for MoE routing diagnostics.

Multi-host contract (DESIGN.md §5):

* Give every host a distinct ``StatsConfig.host_id`` (same k/ls/chunk/salt).
  Key randomness (KeyBase hashes) is shared through the salt — that is the
  coordination that makes merges meaningful — while element randomness is
  host-disambiguated so shards never alias.
* ``merge(other)`` (mode="exact", the default) min-merges each lane's
  *lossless* bottom-(k+1) (key, seed) summary — exact for ANY split of
  elements across hosts, including keys straddling hosts (paper §3.1) — and
  also folds the 1-pass fixed-k sketches so approximate queries keep working.
* ``reconcile(keys, weights)`` is the paper's pass II: re-scan each host's
  shard (stream it through in any batch sizes; or use
  core.distributed.pass2_shard_multi on a mesh) to accumulate the exact
  weights of the sampled keys.  Once every shard has been reconciled,
  queries flow through the 2-pass inverse-probability estimators
  (``exact_weights=True``) with **zero merge bias**.
* ``merge(other, mode="approx")`` skips the summaries: cheapest, unbiased
  for key-partitioned shards, but carries up to ~10% bias at k=512 when
  keys straddle hosts (measured in tests/test_merge_bias.py).  Exact mode
  exists precisely to kill that bias.

The service state is a pytree: ``state_dict()`` is a flat dict of fixed-size
arrays (sketches + summaries + remainder) that checkpoints through
checkpoint.manager (``save_checkpoint`` / ``restore_checkpoint`` below) and
resumes bit-for-bit mid-stream.
"""
from __future__ import annotations

import dataclasses
import math
from pathlib import Path
from typing import Sequence

import numpy as np

from ..checkpoint import manager as ckpt_manager
from ..core import estimators, freqfns, incremental
from ..core.samplers import SampleResult
from ..core.segments import EMPTY


@dataclasses.dataclass
class StatsConfig:
    k: int = 4096                      # sample size per sketch
    ls: Sequence[float] = (1.0, 16.0, 256.0, 4096.0)  # geometric l-grid (§6)
    chunk: int = 2048
    salt: int = 0x5EED
    host_id: int | None = None         # REQUIRED (distinct) for exact merges


@dataclasses.dataclass
class _LaneSample:
    """Frozen pass-1 outcome of one l lane + its pass-2 accumulator."""

    l: float
    keys: np.ndarray       # sorted sampled keys (<= k)
    tau: float             # (k+1)-smallest seed, inf if everything sampled
    weights: np.ndarray    # exact-weight accumulator (float64)


class StreamStatsService:
    """Incremental multi-l sketch service over the jitted chunked samplers.

    For each l in the grid we keep a fixed-k continuous SH_l sketch plus the
    lossless bottom-(k+1) summary that powers the exact distributed mode.  A
    cap_T query is answered from the sketch with l closest to T in log-space
    (the paper's recommendation preceding §6.1: pick l within sqrt(2) of T).
    """

    def __init__(self, config: StatsConfig):
        self.config = config
        self._sampler = incremental.MultiSampler(
            tuple(float(l) for l in config.ls), k=config.k,
            chunk=config.chunk, salt=config.salt, host_id=config.host_id,
        )
        self._results: dict[float, SampleResult] | None = None
        self._lanes: list[_LaneSample] | None = None  # reconcile accumulators
        self._recon_n = 0  # elements re-scanned by the current reconcile
        self._recon_discarded = False  # a begun reconcile was invalidated
        self._exact_ok = True  # summaries valid (invalidated by approx merge)
        # every host whose stream this service has absorbed (exact mode must
        # never merge two streams sharing an element-id namespace)
        self._host_ids: set[int] = (
            set() if config.host_id is None else {config.host_id})

    # -- ingestion ---------------------------------------------------------

    def observe(self, keys, weights=None) -> None:
        """Feed a batch of stream elements (host arrays ok).

        One jitted dispatch advances all |ls| sketches; only the sub-chunk
        remainder stays on host until the next batch aligns it.
        """
        self._sampler.observe(np.asarray(keys).reshape(-1), weights)
        self._results = None
        self._invalidate_reconcile()

    def _invalidate_reconcile(self) -> None:
        """New elements / merges change the pass-1 sample: any accumulated
        pass-II weights refer to a stale sample and must be discarded."""
        if self._lanes is not None:
            self._lanes = None
            self._recon_discarded = True

    @property
    def n_observed(self) -> int:
        return self._sampler.n_observed

    # -- sketch materialization --------------------------------------------

    def _materialize(self) -> dict[float, SampleResult]:
        if self._results is None:
            self._results = self._sampler.finalize()
        return self._results

    def sketches(self) -> dict[float, SampleResult]:
        return self._materialize()

    # -- queries -------------------------------------------------------------

    def pick_l(self, T: float) -> float:
        ls = np.asarray(self.config.ls, dtype=np.float64)
        return float(ls[np.argmin(np.abs(np.log(ls) - math.log(max(T, 1e-9))))])

    @property
    def _reconcile_complete(self) -> bool:
        """Every observed element has been streamed back through reconcile
        (each shard exactly once re-scans the whole logical stream)."""
        return self._lanes is not None and self._recon_n >= self.n_observed

    def _result_for(self, l: float, exact: bool | None) -> SampleResult:
        # auto mode only trusts the exact path once pass II covered the whole
        # stream — a half-reconciled accumulator would silently report
        # partial sums (or 0/0 = nan for zero-weight keys)
        use_exact = exact if exact is not None else self._reconcile_complete
        if use_exact:
            if not self._reconcile_complete:
                raise ValueError(
                    f"exact query before reconcile completed: {self._recon_n} "
                    f"of {self.n_observed} observed elements re-scanned — "
                    "stream every shard through reconcile() first")
            return self.exact_sketches()[l]
        return self._materialize()[l]

    def query_cap(self, T: float, segment=None, *, exact: bool | None = None) -> float:
        """Estimate Q(cap_T, segment).

        ``exact=None`` (default) uses the reconciled 2-pass estimates when a
        reconcile pass has run, else the resident 1-pass sketches; force one
        path with True/False.
        """
        res = self._result_for(self.pick_l(T), exact)
        return estimators.estimate(res, freqfns.cap(T), segment)

    def query_distinct(self, segment=None, *, exact: bool | None = None) -> float:
        res = self._result_for(self.pick_l(1.0), exact)
        return estimators.estimate(res, freqfns.distinct(), segment)

    def query_total(self, segment=None, *, exact: bool | None = None) -> float:
        res = self._result_for(max(self.config.ls), exact)
        return estimators.estimate(res, freqfns.total(), segment)

    def campaign_forecast(self, cap_per_user: float, segment=None, *,
                          exact: bool | None = None) -> float:
        """The paper's motivating query: qualifying impressions under a
        per-user frequency cap, for the user segment H."""
        return self.query_cap(cap_per_user, segment, exact=exact)

    # -- hot-key extraction (embedding-sharding integration) -----------------

    def hot_keys(self, top: int) -> np.ndarray:
        """Keys with the largest sampled counts — candidates for replicated
        'hot' embedding-table placement.  Uses the largest-l sketch (closest
        to pps-by-frequency)."""
        res = self._materialize()[max(self.config.ls)]
        order = np.argsort(-res.counts)
        return res.keys[order[:top]]

    # -- multi-host merge ----------------------------------------------------

    def merge(self, other: "StreamStatsService", mode: str = "exact") -> None:
        """Absorb another host's state.  Both services must share
        (k, ls, chunk, salt).

        mode="exact": additionally min-merge the lossless per-lane
        bottom-(k+1) summaries (paper §3.1 mergeability) — requires the two
        hosts to carry **distinct** ``host_id``s, otherwise their element
        randomness aliases and the merged summary is silently biased.  Run
        ``reconcile`` over every shard afterwards to unlock exact queries.

        mode="approx": 1-pass ``merge_fixed_k`` only — cheap, unbiased for
        key-partitioned shards, ~10% bias for arbitrary element splits;
        exact queries become unavailable.
        """
        if (tuple(other.config.ls) != tuple(self.config.ls)
                or other.config.k != self.config.k
                or other.config.salt != self.config.salt
                or other.config.chunk != self.config.chunk):
            # salt especially: kb/seed/tau from different hash functions
            # would union into a silently biased sketch
            raise ValueError("merge requires identical (k, ls, chunk, salt) configs")
        if mode not in ("exact", "approx"):
            raise ValueError(f"unknown merge mode {mode!r}")
        if mode == "exact":
            if self.config.host_id is None or other.config.host_id is None:
                raise ValueError(
                    "exact merge requires a host_id on both services: shared "
                    "element-id namespaces alias randomness across shards")
            overlap = self._host_ids & other._host_ids
            if overlap:
                # not just pairwise: hosts absorbed earlier count too (two
                # absorbed shards sharing an id namespace are just as biased)
                raise ValueError(
                    "exact merge requires distinct host_ids across ALL "
                    f"absorbed hosts; {sorted(overlap)} appear on both sides")
            if not (self._exact_ok and other._exact_ok):
                raise ValueError(
                    "exact merge unavailable: a prior mode='approx' merge "
                    "invalidated the lossless summaries")
        self._sampler.absorb(other._sampler, k=self.config.k,
                             merge_summaries=(mode == "exact"))
        self._host_ids |= other._host_ids
        if mode == "approx":
            self._exact_ok = False
        self._results = None
        self._invalidate_reconcile()

    # -- exact second pass (paper pass II) -----------------------------------

    def begin_reconcile(self) -> None:
        """Freeze the pass-1 sample (per-lane bottom-k keys + threshold) and
        reset the exact-weight accumulators.  Called implicitly by the first
        ``reconcile``; must be called EXPLICITLY to restart after an
        ``observe``/``merge`` discarded a begun reconcile."""
        if not self._exact_ok:
            raise ValueError(
                "exact pass unavailable after a mode='approx' merge")
        self._recon_discarded = False
        self._recon_n = 0
        bk_keys, bk_seeds = self._sampler.bottomk_summaries()
        k = self.config.k
        self._lanes = []
        for j, l in enumerate(self.config.ls):
            keys_j, seeds_j = bk_keys[j], bk_seeds[j]
            valid = keys_j != int(EMPTY)
            kk, ss = keys_j[valid], seeds_j[valid]
            order = np.argsort(ss)
            if len(kk) > k:
                tau = float(ss[order[k]])
                kk = kk[order[:k]]
            else:
                tau = math.inf
            kk = np.sort(kk)
            self._lanes.append(_LaneSample(
                l=float(l), keys=kk, tau=tau,
                weights=np.zeros(len(kk), np.float64)))

    def reconcile(self, keys, weights=None) -> None:
        """Accumulate exact weights of the sampled keys from a batch of the
        original stream (pass II).  Stream EVERY shard's elements through
        this (any batch sizes, any order) before exact queries; weights of
        un-reconciled elements are simply missing from the estimates.
        On a mesh, core.distributed.pass2_shard_multi + psum is the
        equivalent collective form."""
        if self._lanes is None:
            if self._recon_discarded:
                # an observe()/merge() changed the pass-1 sample after a
                # reconcile began: silently re-beginning would drop the
                # weights accumulated so far and report partial sums as exact
                raise ValueError(
                    "reconcile was invalidated by observe()/merge(): the "
                    "accumulated pass-II weights were discarded — call "
                    "begin_reconcile() and re-stream EVERY shard")
            self.begin_reconcile()
        keys = np.asarray(keys, np.int32).reshape(-1)
        w = (np.ones(len(keys), np.float64) if weights is None
             else np.asarray(weights, np.float64).reshape(-1))
        self._recon_n += len(keys)
        for lane in self._lanes:
            if not len(lane.keys):
                continue
            loc = np.searchsorted(lane.keys, keys)
            loc = np.clip(loc, 0, len(lane.keys) - 1)
            match = lane.keys[loc] == keys
            np.add.at(lane.weights, loc[match], w[match])

    def exact_sketches(self) -> dict[float, SampleResult]:
        """Per-lane 2-pass SampleResults (exact weights) from the reconciled
        accumulators.  Available only once pass II covered the whole stream
        — partial accumulators stamped ``exact_weights=True`` would be the
        silent-wrong-answer path this API exists to kill."""
        if not self._reconcile_complete:
            raise ValueError(
                f"no complete exact sample: {self._recon_n} of "
                f"{self.n_observed} observed elements re-scanned — run "
                "reconcile(keys, weights) over every shard of the stream")
        return {
            lane.l: SampleResult(
                keys=lane.keys, counts=lane.weights.copy(), tau=lane.tau,
                l=lane.l, kind="continuous", exact_weights=True)
            for lane in self._lanes
        }

    # -- checkpointing --------------------------------------------------------

    def state_dict(self) -> dict:
        """O(k * |ls| + chunk) pytree of fixed-size arrays — the size is
        independent of how many elements were observed.  Includes the
        lossless bottom-(k+1) summary buffers and their validity flag.

        Checkpoint per host, before merging: the set of absorbed host_ids is
        deliberately not serialized (variable length), so a restored service
        only knows its own configured host_id."""
        d = self._sampler.state_dict()
        d["exact_ok"] = np.bool_(self._exact_ok)
        return d

    def load_state_dict(self, d: dict) -> None:
        self._sampler.load_state_dict(d)
        # pre-summary blobs load with empty summaries: exact mode stays off
        self._exact_ok = ("bk_keys" in d) and bool(d.get("exact_ok", True))
        self._results = None
        self._lanes = None
        self._recon_n = 0
        self._recon_discarded = False
        self._host_ids = (set() if self.config.host_id is None
                          else {self.config.host_id})

    @property
    def resident_bytes(self) -> int:
        """Bytes held by the sketches + summaries + remainder (the whole
        service state)."""
        return self._sampler.resident_bytes

    def save_checkpoint(self, ckpt_dir: str | Path, step: int) -> Path:
        """Write the service state through checkpoint.manager (atomic commit,
        retention); composes with a training state living in the same dir."""
        return ckpt_manager.save(ckpt_dir, step, self.state_dict())

    def restore_checkpoint(self, ckpt_dir: str | Path, step: int | None = None) -> int:
        """Load the latest (or a specific) committed step; returns the step."""
        if step is None:
            step = ckpt_manager.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
        tree = ckpt_manager.restore(ckpt_dir, step, self.state_dict())
        self.load_state_dict(tree)
        return step
