"""StreamStatsService: frequency-cap statistics as a first-class framework
feature (the paper's ad-campaign application, generalized) — a true
incremental service with an **exact multi-host mode**.

Attach a service to any input pipeline; it maintains one fixed-k continuous
SH_l sketch per configured l over the stream of keys flowing through
training/serving and answers

    service.query_cap(T, segment)  ~=  Q(cap_T, segment)
    service.query_batch([(fn, segment), ...])   # many (T x segment) cells,
                                                # ONE jitted device dispatch

Queries ride the batched query plane (stats/query.py, DESIGN.md §7): the
whole batch is answered in one jitted dispatch over the stacked lane
arrays — bit-identical to looping the scalar estimators — with per-query
variance/CI diagnostics; segment masks and estimator coefficient tables
are compiled once per sketch and cached device-resident.
``launch.stats_serve`` wraps this in a request-batching server loop.

**State is O(k * |ls|), independent of stream length.**  ``observe()``
advances every sketch of the l-grid in a single jitted device dispatch with
donated state buffers (core.incremental.MultiSampler): each chunk is
permuted into key order once, then the fused multi-l ``capscore_agg``
kernel scores all lanes AND segment-reduces them to per-key aggregate
columns in the same pass (the per-element [L, chunk] scores never
materialize; DESIGN.md §9), then the sorted-runs merge/evict step runs
vmapped across lanes.  Nothing is buffered
except the sub-chunk remainder (< chunk elements) awaiting alignment;
queries finalize the resident sketches lazily (cached until the next
``observe``) — no replay, no recompute.

Uses: ad-campaign reach forecasting (recsys archs: keys = (user, campaign)
pairs, answer = number of qualifying impressions under a per-user cap T);
token-frequency statistics for LM data mixing; degree statistics for GNN
samplers; expert-load statistics for MoE routing diagnostics.

Multi-host contract (DESIGN.md §5):

* Give every host a distinct ``StatsConfig.host_id`` (same k/ls/chunk/salt).
  Key randomness (KeyBase hashes) is shared through the salt — that is the
  coordination that makes merges meaningful — while element randomness is
  host-disambiguated so shards never alias.
* ``merge(other)`` (mode="exact", the default) min-merges each lane's
  *lossless* bottom-(k+1) (key, seed) summary — exact for ANY split of
  elements across hosts, including keys straddling hosts (paper §3.1) — and
  also folds the 1-pass fixed-k sketches so approximate queries keep working.
* ``reconcile(keys, weights)`` is the paper's pass II: re-scan each host's
  shard (stream it through in any batch sizes; or use
  core.distributed.pass2_shard_multi on a mesh) to accumulate the exact
  weights of the sampled keys.  Once every shard has been reconciled,
  queries flow through the 2-pass inverse-probability estimators
  (``exact_weights=True``) with **zero merge bias**.
* ``merge(other, mode="approx")`` skips the summaries: cheapest, unbiased
  for key-partitioned shards, but carries up to ~10% bias at k=512 when
  keys straddle hosts (measured in tests/test_merge_bias.py).  Exact mode
  exists precisely to kill that bias.

The service state is a pytree: ``state_dict()`` is a flat dict of fixed-size
arrays (sketches + summaries + remainder) that checkpoints through
checkpoint.manager (``save_checkpoint`` / ``restore_checkpoint`` below) and
resumes bit-for-bit mid-stream.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from pathlib import Path
from typing import Sequence

import numpy as np

from ..checkpoint import manager as ckpt_manager
from ..core import freqfns, incremental
from ..core.samplers import SampleResult
from ..core.segments import EMPTY
from .query import BatchResult, PendingBatch, Query, QueryEngine

# the paper's guidance (preceding §6.1): a geometric l-grid with ratio
# sqrt(2)^2 = 2 keeps every T within sqrt(2) of a lane in log space
_L_GRID_FACTOR = 0.5 * math.log(2.0)  # log(sqrt(2))


def _nearest_lane(ls, T: float) -> tuple[float, float]:
    """(nearest-in-log lane l, log-space distance) for a cap parameter T."""
    ls = np.asarray(ls, dtype=np.float64)
    dist = np.abs(np.log(ls) - math.log(max(T, 1e-9)))
    j = int(np.argmin(dist))
    return float(ls[j]), float(dist[j])


def _grid_warning(T: float, l: float, dist: float) -> str:
    return (
        f"cap T={T:g} is {math.exp(dist):.2f}x away from the "
        f"nearest configured lane l={l:g} — beyond the paper's "
        "sqrt(2) log-space factor, so the estimate's CV degrades with "
        "the disparity max(T/l, l/T) (Thm 5.4).  Densify StatsConfig.ls "
        "toward a geometric grid of ratio <= 2 over the queried T range "
        "(and extend its ends if T falls outside).  "
        "(warning shown once per service)")


@dataclasses.dataclass
class StatsConfig:
    k: int = 4096                      # sample size per sketch
    ls: Sequence[float] = (1.0, 16.0, 256.0, 4096.0)  # geometric l-grid (§6)
    chunk: int = 2048
    salt: int = 0x5EED
    host_id: int | None = None         # REQUIRED (distinct) for exact merges
    # eviction amortization period E (DESIGN.md §8): capacity grows to
    # k + E*chunk and the sketches evict every E chunks.  E=1 (default) is
    # bit-compatible with the one-shot samplers; E>1 trades that per-run
    # identity (NOT correctness — the count law and unbiasedness hold, see
    # tests/test_ingest_order.py) for skipping eviction work on E-1 of every
    # E chunks.  The lossless bottom-(k+1) summaries and the exact two-pass
    # mode are unaffected by E.
    evict_every: int = 1
    # backend of the fused score+aggregate ingest stage (capscore_agg):
    # None auto-picks per accelerator (compiled Pallas on TPU, XLA
    # elsewhere); 'xla' | 'pallas' force a path.  Does not gate merging —
    # the XLA path is bit-identical everywhere, Pallas only reassociates
    # in-block f32 sums.
    ingest_backend: str | None = None


@dataclasses.dataclass
class _LaneSample:
    """Frozen pass-1 outcome of one l lane (the pass-2 exact-weight
    accumulators live stacked on device, see ``reconcile``)."""

    l: float
    keys: np.ndarray       # sorted sampled keys (<= k)
    tau: float             # (k+1)-smallest seed, inf if everything sampled


class StreamStatsService:
    """Incremental multi-l sketch service over the jitted chunked samplers.

    For each l in the grid we keep a fixed-k continuous SH_l sketch plus the
    lossless bottom-(k+1) summary that powers the exact distributed mode.  A
    cap_T query is answered from the sketch with l closest to T in log-space
    (the paper's recommendation preceding §6.1: pick l within sqrt(2) of T).
    """

    def __init__(self, config: StatsConfig):
        self.config = config
        self._sampler = incremental.MultiSampler(
            tuple(float(l) for l in config.ls), k=config.k,
            chunk=config.chunk, salt=config.salt, host_id=config.host_id,
            evict_every=config.evict_every, backend=config.ingest_backend,
        )
        self._results: dict[float, SampleResult] | None = None
        self._engines: dict[bool, QueryEngine] = {}  # query plane, per path
        self._lanes: list[_LaneSample] | None = None  # frozen pass-1 samples
        self._recon_keys = None  # [L, kmax] device sorted sample keys
        self._recon_acc = None   # [L, kmax] device f64 exact-weight accs
        self._recon_n = 0  # elements re-scanned by the current reconcile
        self._recon_discarded = False  # a begun reconcile was invalidated
        self._exact_ok = True  # summaries valid (invalidated by approx merge)
        self._l_grid_warned = False  # pick_l out-of-grid warning (once)
        self._pick_l_cache: dict[float, float] = {}
        # every host whose stream this service has absorbed (exact mode must
        # never merge two streams sharing an element-id namespace)
        self._host_ids: set[int] = (
            set() if config.host_id is None else {config.host_id})

    # -- ingestion ---------------------------------------------------------

    def observe(self, keys, weights=None) -> None:
        """Feed a batch of stream elements (host arrays ok).

        One jitted dispatch advances all |ls| sketches; only the sub-chunk
        remainder stays on host until the next batch aligns it.  Keys are
        validated through the same ``incremental.normalize_keys`` helper as
        ``reconcile`` — never silently wrapped to int32.
        """
        self._sampler.observe(keys, weights)
        self._results = None
        self._engines.clear()
        self._invalidate_reconcile()

    def _invalidate_reconcile(self) -> None:
        """New elements / merges change the pass-1 sample: any accumulated
        pass-II weights refer to a stale sample and must be discarded."""
        if self._lanes is not None:
            self._lanes = None
            self._recon_keys = self._recon_acc = None
            self._recon_discarded = True
            self._engines.pop(True, None)

    @property
    def n_observed(self) -> int:
        return self._sampler.n_observed

    # -- sketch materialization --------------------------------------------

    def _materialize(self) -> dict[float, SampleResult]:
        if self._results is None:
            self._results = self._sampler.finalize()
        return self._results

    def sketches(self) -> dict[float, SampleResult]:
        return self._materialize()

    # -- queries -------------------------------------------------------------

    _L_GRID_FACTOR = _L_GRID_FACTOR  # see module level (shared with the bank)

    def pick_l(self, T: float) -> float:
        cached = self._pick_l_cache.get(T)
        if cached is not None:
            return cached
        l, dist = _nearest_lane(self.config.ls, T)
        if dist > self._L_GRID_FACTOR + 1e-9 and not self._l_grid_warned:
            self._l_grid_warned = True
            warnings.warn(_grid_warning(T, l, dist), RuntimeWarning,
                          stacklevel=2)
        self._pick_l_cache[T] = l
        return l

    @property
    def _reconcile_complete(self) -> bool:
        """Every observed element has been streamed back through reconcile
        (each shard exactly once re-scans the whole logical stream)."""
        return self._lanes is not None and self._recon_n >= self.n_observed

    def _use_exact(self, exact: bool | None) -> bool:
        # auto mode only trusts the exact path once pass II covered the whole
        # stream — a half-reconciled accumulator would silently report
        # partial sums (or 0/0 = nan for zero-weight keys)
        use_exact = exact if exact is not None else self._reconcile_complete
        if use_exact and not self._reconcile_complete:
            raise ValueError(
                f"exact query before reconcile completed: {self._recon_n} "
                f"of {self.n_observed} observed elements re-scanned — "
                "stream every shard through reconcile() first")
        return use_exact

    def _engine(self, exact: bool | None) -> QueryEngine:
        """The batched query plane over the current sketches (lazily built,
        cached until the underlying sample changes)."""
        use_exact = self._use_exact(exact)
        engine = self._engines.get(use_exact)
        if engine is None:
            sketches = (self.exact_sketches() if use_exact
                        else self._materialize())
            engine = self._engines[use_exact] = QueryEngine(sketches)
        return engine

    def _resolve_lane(self, q: Query) -> Query:
        if q.l is not None:
            return q
        kind = q.fn.kind
        if kind in ("cap", "threshold"):
            l = self.pick_l(q.fn.param)
        elif kind == "distinct":
            l = self.pick_l(1.0)
        else:  # total / moment / log1p / custom: weight-proportional regime
            l = max(self.config.ls)
        return Query(q.fn, q.segment, l)

    def query_batch(self, queries, *, exact: bool | None = None) -> BatchResult:
        """Answer a whole batch of (FreqFn, segment[, lane]) queries in one
        jitted device dispatch over the stacked lane arrays.

        Each element of ``queries`` is a ``stats.query.Query`` or an
        ``(fn, segment)`` / ``(fn, segment, l)`` tuple; unresolved lanes are
        picked per statistic exactly like the scalar wrappers (``cap_T`` /
        ``threshold_T`` -> nearest-in-log lane, ``distinct`` -> pick_l(1),
        everything else -> max l).  Answers are bit-identical to looping
        ``estimators.estimate`` over the same sketches, and arrive with
        per-query variance/CI diagnostics (see stats.query).
        """
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        engine = self._engine(exact)
        return engine.query_batch([self._resolve_lane(q) for q in qs])

    def query_cap(self, T: float, segment=None, *, exact: bool | None = None) -> float:
        """Estimate Q(cap_T, segment).

        ``exact=None`` (default) uses the reconciled 2-pass estimates when a
        reconcile pass has run, else the resident 1-pass sketches; force one
        path with True/False.  Thin wrapper over ``query_batch`` (one-query
        batch), bit-compatible with the scalar estimator path.
        """
        r = self.query_batch([Query(freqfns.cap(T), segment)], exact=exact)
        return float(r.estimates[0])

    def query_distinct(self, segment=None, *, exact: bool | None = None) -> float:
        r = self.query_batch([Query(freqfns.distinct(), segment)], exact=exact)
        return float(r.estimates[0])

    def query_total(self, segment=None, *, exact: bool | None = None) -> float:
        r = self.query_batch([Query(freqfns.total(), segment)], exact=exact)
        return float(r.estimates[0])

    def campaign_forecast(self, cap_per_user: float, segment=None, *,
                          exact: bool | None = None) -> float:
        """The paper's motivating query: qualifying impressions under a
        per-user frequency cap, for the user segment H."""
        return self.query_cap(cap_per_user, segment, exact=exact)

    # -- hot-key extraction (embedding-sharding integration) -----------------

    def hot_keys(self, top: int) -> np.ndarray:
        """Keys with the largest sampled counts — candidates for replicated
        'hot' embedding-table placement.  Uses the largest-l sketch (closest
        to pps-by-frequency)."""
        res = self._materialize()[max(self.config.ls)]
        order = np.argsort(-res.counts)
        return res.keys[order[:top]]

    # -- multi-host merge ----------------------------------------------------

    def merge(self, other: "StreamStatsService", mode: str = "exact") -> None:
        """Absorb another host's state.  Both services must share
        (k, ls, chunk, salt).

        mode="exact": additionally min-merge the lossless per-lane
        bottom-(k+1) summaries (paper §3.1 mergeability) — requires the two
        hosts to carry **distinct** ``host_id``s, otherwise their element
        randomness aliases and the merged summary is silently biased.  Run
        ``reconcile`` over every shard afterwards to unlock exact queries.

        mode="approx": 1-pass ``merge_fixed_k`` only — cheap, unbiased for
        key-partitioned shards, ~10% bias for arbitrary element splits;
        exact queries become unavailable.
        """
        self.merge_many([other], mode=mode)

    def merge_many(self, others, mode: str = "exact") -> None:
        """Absorb ANY number of other hosts' states in one pairwise-tree
        fold (same validation as ``merge``, applied across the whole group;
        a single ``other`` is exactly ``merge``).  The shard-tier
        coordinator uses this to fold the surviving shards of a degraded
        tier — or all shards of a healthy one — into a scratch service.
        An empty sequence is a no-op."""
        others = list(others)
        if not others:
            return
        for other in others:
            if (tuple(other.config.ls) != tuple(self.config.ls)
                    or other.config.k != self.config.k
                    or other.config.salt != self.config.salt
                    or other.config.chunk != self.config.chunk
                    or other.config.evict_every != self.config.evict_every):
                # salt especially: kb/seed/tau from different hash functions
                # would union into a silently biased sketch; evict_every
                # because the lane-wise table merge requires equal capacities
                raise ValueError(
                    "merge requires identical (k, ls, chunk, salt, evict_every) configs")
        if mode not in ("exact", "approx"):
            raise ValueError(f"unknown merge mode {mode!r}")
        if mode == "exact":
            if self.config.host_id is None or any(
                    o.config.host_id is None for o in others):
                raise ValueError(
                    "exact merge requires a host_id on both services: shared "
                    "element-id namespaces alias randomness across shards")
            ids = set(self._host_ids)
            for other in others:
                overlap = ids & other._host_ids
                if overlap:
                    # not just pairwise: hosts absorbed earlier count too (two
                    # absorbed shards sharing an id namespace are just as
                    # biased)
                    raise ValueError(
                        "exact merge requires distinct host_ids across ALL "
                        f"absorbed hosts; {sorted(overlap)} appear on both sides")
                ids |= other._host_ids
            if not (self._exact_ok and all(o._exact_ok for o in others)):
                raise ValueError(
                    "exact merge unavailable: a prior mode='approx' merge "
                    "invalidated the lossless summaries")
        self._sampler.absorb_many([o._sampler for o in others],
                                  k=self.config.k,
                                  merge_summaries=(mode == "exact"))
        for other in others:
            self._host_ids |= other._host_ids
        if mode == "approx":
            self._exact_ok = False
        self._results = None
        self._engines.clear()
        self._invalidate_reconcile()

    # -- exact second pass (paper pass II) -----------------------------------

    def begin_reconcile(self) -> None:
        """Freeze the pass-1 sample (per-lane bottom-k keys + threshold) and
        reset the exact-weight accumulators.  Called implicitly by the first
        ``reconcile``; must be called EXPLICITLY to restart after an
        ``observe``/``merge`` discarded a begun reconcile."""
        if not self._exact_ok:
            raise ValueError(
                "exact pass unavailable after a mode='approx' merge")
        self._recon_discarded = False
        self._recon_n = 0
        self._engines.pop(True, None)
        bk_keys, bk_seeds = self._sampler.bottomk_summaries()
        k = self.config.k
        self._lanes = []
        for j, l in enumerate(self.config.ls):
            keys_j, seeds_j = bk_keys[j], bk_seeds[j]
            valid = keys_j != int(EMPTY)
            kk, ss = keys_j[valid], seeds_j[valid]
            order = np.argsort(ss)
            if len(kk) > k:
                tau = float(ss[order[k]])
                kk = kk[order[:k]]
            else:
                tau = math.inf
            kk = np.sort(kk)
            self._lanes.append(_LaneSample(l=float(l), keys=kk, tau=tau))
        # stacked device accumulators: every lane advances per reconcile
        # batch in one jitted dispatch (core.incremental.pass2_accumulate)
        self._recon_keys, self._recon_acc = incremental.init_pass2(
            [lane.keys for lane in self._lanes])

    def reconcile(self, keys, weights=None) -> None:
        """Accumulate exact weights of the sampled keys from a batch of the
        original stream (pass II).  Stream EVERY shard's elements through
        this (any batch sizes, any order) before exact queries; weights of
        un-reconciled elements are simply missing from the estimates.

        All |ls| lanes advance in a single jitted device dispatch over the
        stacked bottom-k keys, with the accumulator buffers donated between
        batches.  Keys are validated (dtype / int32 range / reserved EMPTY)
        by the same helper as ``observe`` — never silently wrapped.  On a
        mesh, core.distributed.pass2_shard_multi + psum is the equivalent
        collective form."""
        if self._lanes is None:
            if self._recon_discarded:
                # an observe()/merge() changed the pass-1 sample after a
                # reconcile began: silently re-beginning would drop the
                # weights accumulated so far and report partial sums as exact
                raise ValueError(
                    "reconcile was invalidated by observe()/merge(): the "
                    "accumulated pass-II weights were discarded — call "
                    "begin_reconcile() and re-stream EVERY shard")
            self.begin_reconcile()
        keys = incremental.normalize_keys(keys)
        self._recon_acc = incremental.pass2_accumulate(
            self._recon_keys, self._recon_acc, keys, weights)
        self._recon_n += len(keys)
        self._engines.pop(True, None)

    def exact_sketches(self) -> dict[float, SampleResult]:
        """Per-lane 2-pass SampleResults (exact weights) from the reconciled
        accumulators.  Available only once pass II covered the whole stream
        — partial accumulators stamped ``exact_weights=True`` would be the
        silent-wrong-answer path this API exists to kill."""
        if not self._reconcile_complete:
            raise ValueError(
                f"no complete exact sample: {self._recon_n} of "
                f"{self.n_observed} observed elements re-scanned — run "
                "reconcile(keys, weights) over every shard of the stream")
        acc = np.asarray(self._recon_acc, dtype=np.float64)
        return {
            lane.l: SampleResult(
                keys=lane.keys, counts=acc[j, : len(lane.keys)].copy(),
                tau=lane.tau, l=lane.l, kind="continuous", exact_weights=True)
            for j, lane in enumerate(self._lanes)
        }

    # -- checkpointing --------------------------------------------------------

    def state_dict(self) -> dict:
        """O(k * |ls| + chunk) pytree of fixed-size arrays — the size is
        independent of how many elements were observed.  Includes the
        lossless bottom-(k+1) summary buffers and their validity flag.

        Checkpoint per host, before merging: the set of absorbed host_ids is
        deliberately not serialized (variable length), so a restored service
        only knows its own configured host_id."""
        d = self._sampler.state_dict()
        d["exact_ok"] = np.bool_(self._exact_ok)
        return d

    def load_state_dict(self, d: dict) -> None:
        self._sampler.load_state_dict(d)
        # pre-summary blobs load with empty summaries: exact mode stays off
        self._exact_ok = ("bk_keys" in d) and bool(d.get("exact_ok", True))
        self._results = None
        self._engines.clear()
        self._lanes = None
        self._recon_keys = self._recon_acc = None
        self._recon_n = 0
        self._recon_discarded = False
        self._host_ids = (set() if self.config.host_id is None
                          else {self.config.host_id})

    @property
    def resident_bytes(self) -> int:
        """Bytes held by the sketches + summaries + remainder (the whole
        service state)."""
        return self._sampler.resident_bytes

    def save_checkpoint(self, ckpt_dir: str | Path, step: int) -> Path:
        """Write the service state through checkpoint.manager (atomic commit,
        retention); composes with a training state living in the same dir."""
        return ckpt_manager.save(ckpt_dir, step, self.state_dict())

    def restore_checkpoint(self, ckpt_dir: str | Path, step: int | None = None) -> int:
        """Load the latest (or a specific) committed step; returns the step."""
        if step is None:
            step = ckpt_manager.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
        tree = ckpt_manager.restore(ckpt_dir, step, self.state_dict())
        self.load_state_dict(tree)
        return step


# ---------------------------------------------------------------------------
# Multi-tenant serving plane: one stacked bank, one coalesced query engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantQuery:
    """One (tenant, statistic, segment[, lane]) request against a bank."""

    tenant: int
    fn: freqfns.FreqFn
    segment: object = None
    l: float | None = None


class MultiTenantStats:
    """N independent per-tenant stat services served from ONE device plane.

    The serving-tier face of ``core.incremental.TenantBank`` (DESIGN.md §10):
    every tenant keeps its own l-grid of fixed-k sketches, but all
    ``n_tenants * |ls|`` sketches live as one stacked pytree — a single
    vmapped/jitted dispatch per ``tick`` advances every tenant with a full
    chunk buffered, and a single ``QueryEngine`` over ``(tenant, l)`` lane
    keys answers a query batch that mixes tenants in ONE device dispatch.

    Per-tenant answers are bit-identical to running ``n_tenants`` standalone
    ``StreamStatsService`` instances over the same streams (property-tested
    in tests/test_serving.py) — the bank changes the dispatch count, not one
    bit of any tenant's sample or estimate.

    Snapshot semantics: queries are answered from the engine built at the
    last ``refresh()`` — the materialized sketches as of that point.  The
    continuous-batching scheduler (stats.scheduler) controls the refresh
    cadence explicitly (``auto_refresh=False``) so ingest dispatch for tick
    t+1 can overlap query evaluation against the tick-t snapshot; direct
    callers get refresh-on-demand by default.
    """

    def __init__(self, config: StatsConfig, *, n_tenants: int,
                 tenant_salts=None):
        self.config = config
        self.n_tenants = int(n_tenants)
        salts = config.salt if tenant_salts is None else tenant_salts
        self._bank = incremental.TenantBank(
            config.ls, n_tenants=n_tenants, k=config.k, chunk=config.chunk,
            salts=salts, host_id=config.host_id,
            evict_every=config.evict_every, backend=config.ingest_backend)
        self._engine: QueryEngine | None = None
        self._engine_tenants: set[int] | None = None  # None = all tenants
        self._stale = True
        self._l_grid_warned = False
        self._pick_l_cache: dict[float, float] = {}

    # -- ingestion ---------------------------------------------------------

    def observe(self, tenant: int, keys, weights=None) -> None:
        """Stage stream elements for one tenant (advanced at the next tick)."""
        self._bank.observe(tenant, keys, weights)
        self._stale = True

    def tick(self) -> int:
        """One stacked ingest dispatch (every tenant with a full buffered
        chunk advances by one chunk); returns the active-tenant count."""
        n = self._bank.tick()
        if n:
            self._stale = True
        return n

    def drain(self) -> int:
        n = self._bank.drain()
        if n:
            self._stale = True
        return n

    def backlog_chunks(self) -> np.ndarray:
        return self._bank.backlog_chunks()

    def n_observed(self, tenant: int) -> int:
        return self._bank.n_observed(tenant)

    # -- query plane -------------------------------------------------------

    @property
    def stale(self) -> bool:
        """True when elements were observed/ticked since the last refresh."""
        return self._stale or self._engine is None

    @property
    def has_engine(self) -> bool:
        return self._engine is not None

    def refresh(self, tenants=None) -> QueryEngine:
        """(Re)build the coalesced query snapshot: ONE device extraction,
        one engine over the (tenant, l) lanes.  This is the only query-plane
        point that synchronizes with in-flight ingest dispatches — the
        scheduler calls it at a controlled cadence.

        ``tenants`` restricts the snapshot to a subset (the scheduler passes
        the tenants of the admitted query batch): only those rows are copied
        off device and materialized as engine lanes — the dominant refresh
        cost when a batch touches few of many tenants.  Queries for a tenant
        outside the subset trigger an automatic widening refresh (their
        lanes then reflect the state at THAT point — per-tenant snapshot
        ages can differ under a partial-refresh policy)."""
        if tenants is None:
            sketches = {(t, float(l)): res
                        for t, per in enumerate(self._bank.finalize_all())
                        for l, res in per.items()}
            self._engine_tenants = None
        else:
            sub = self._bank.finalize_some(tenants)
            sketches = {(t, float(l)): res
                        for t, per in sub.items() for l, res in per.items()}
            self._engine_tenants = set(sub)
        self._engine = QueryEngine(sketches)
        self._stale = False
        return self._engine

    def _ensure_engine(self, auto_refresh: bool, needed: set[int]) -> QueryEngine:
        if self._engine is None or (auto_refresh and self._stale):
            return self.refresh()
        covered = self._engine_tenants
        if covered is not None and not needed <= covered:
            return self.refresh(tenants=covered | needed)
        return self._engine

    def pick_l(self, T: float) -> float:
        cached = self._pick_l_cache.get(T)
        if cached is not None:
            return cached
        l, dist = _nearest_lane(self.config.ls, T)
        if dist > _L_GRID_FACTOR + 1e-9 and not self._l_grid_warned:
            self._l_grid_warned = True
            warnings.warn(_grid_warning(T, l, dist), RuntimeWarning,
                          stacklevel=2)
        self._pick_l_cache[T] = l
        return l

    def _resolve(self, q: TenantQuery) -> Query:
        if not (0 <= q.tenant < self.n_tenants):
            raise ValueError(
                f"tenant {q.tenant} out of range [0, {self.n_tenants})")
        l = q.l
        if l is None:
            kind = q.fn.kind
            if kind in ("cap", "threshold"):
                l = self.pick_l(q.fn.param)
            elif kind == "distinct":
                l = self.pick_l(1.0)
            else:  # total / moment / log1p / custom: weight-proportional
                l = max(self.config.ls)
        return Query(q.fn, q.segment, (int(q.tenant), float(l)))

    def resolve_queries(self, requests) -> list[Query]:
        """Normalize (tenant, fn, segment[, l]) tuples / TenantQuery objects
        into engine-addressed Query objects (lane key = (tenant, l))."""
        qs = [r if isinstance(r, TenantQuery) else TenantQuery(*r)
              for r in requests]
        return [self._resolve(q) for q in qs]

    def query_batch(self, requests, *, auto_refresh: bool = True) -> BatchResult:
        """Answer a batch mixing tenants in one jitted device dispatch.

        Each request is a ``TenantQuery`` or a ``(tenant, fn, segment[, l])``
        tuple.  Answers (and diagnostics) are bit-identical to querying each
        tenant's standalone service."""
        return self.query_batch_async(
            requests, auto_refresh=auto_refresh).result()

    def query_batch_async(self, requests, *,
                          auto_refresh: bool = True) -> PendingBatch:
        """Enqueue the batch's device dispatch without blocking (see
        QueryEngine.query_batch_async) — the scheduler's overlap hook."""
        qs = self.resolve_queries(requests)
        engine = self._ensure_engine(auto_refresh, {q.l[0] for q in qs})
        return engine.query_batch_async(qs)

    def query_cap(self, tenant: int, T: float, segment=None) -> float:
        r = self.query_batch([TenantQuery(tenant, freqfns.cap(T), segment)])
        return float(r.estimates[0])

    def query_distinct(self, tenant: int, segment=None) -> float:
        r = self.query_batch(
            [TenantQuery(tenant, freqfns.distinct(), segment)])
        return float(r.estimates[0])

    def query_total(self, tenant: int, segment=None) -> float:
        r = self.query_batch([TenantQuery(tenant, freqfns.total(), segment)])
        return float(r.estimates[0])

    # -- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        """[T, ...]-stacked flat dict (see TenantBank.state_dict); slices
        per tenant through ``tenant_state_dict`` / manager.restore_slice."""
        return self._bank.state_dict()

    def load_state_dict(self, d: dict) -> None:
        self._bank.load_state_dict(d)
        self._engine = None
        self._stale = True

    def tenant_state_dict(self, tenant: int) -> dict:
        """One tenant in ``StreamStatsService``-loadable form (handoff)."""
        return self._bank.tenant_state_dict(tenant)

    def load_tenant_state_dict(self, tenant: int, d: dict) -> None:
        """Splice one tenant's blob into the bank (join/handoff)."""
        self._bank.load_tenant_state_dict(tenant, d)
        self._engine = None
        self._stale = True

    @property
    def resident_bytes(self) -> int:
        return self._bank.resident_bytes

    def save_checkpoint(self, ckpt_dir: str | Path, step: int) -> Path:
        return ckpt_manager.save(ckpt_dir, step, self.state_dict())

    def restore_checkpoint(self, ckpt_dir: str | Path,
                           step: int | None = None) -> int:
        if step is None:
            step = ckpt_manager.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no committed checkpoint under {ckpt_dir}")
        tree = ckpt_manager.restore(ckpt_dir, step, self.state_dict())
        self.load_state_dict(tree)
        return step
