"""StreamStatsService: frequency-cap statistics as a first-class framework
feature (the paper's ad-campaign application, generalized) — now a true
incremental service.

Attach a service to any input pipeline; it maintains one fixed-k continuous
SH_l sketch per configured l over the stream of keys flowing through
training/serving and answers

    service.query_cap(T, segment)  ~=  Q(cap_T, segment)

**State is O(k * |ls|), independent of stream length.**  ``observe()``
advances every sketch of the l-grid in a single jitted device dispatch with
donated state buffers (core.incremental.MultiSampler): the fused multi-l
capscore kernel scores all lanes in one VMEM-resident pass over the batch,
then the merge/evict step runs vmapped across lanes.  Nothing is buffered
except the sub-chunk remainder (< chunk elements) awaiting alignment;
queries finalize the resident sketches lazily (cached until the next
``observe``) — no replay, no recompute.

Uses: ad-campaign reach forecasting (recsys archs: keys = (user, campaign)
pairs, answer = number of qualifying impressions under a per-user cap T);
token-frequency statistics for LM data mixing; degree statistics for GNN
samplers; expert-load statistics for MoE routing diagnostics.

The service state is a pytree: ``state_dict()`` is a flat dict of fixed-size
arrays that checkpoints through checkpoint.manager (``save_checkpoint`` /
``restore_checkpoint`` below) and resumes bit-for-bit mid-stream.  Per-host
services merge across hosts with core.distributed.merge_fixed_k (see
``merge()``): unbiased for key-partitioned shards, approximate for arbitrary
element splits.
"""
from __future__ import annotations

import dataclasses
import math
from pathlib import Path
from typing import Sequence

import numpy as np

from ..checkpoint import manager as ckpt_manager
from ..core import distributed as DZ
from ..core import estimators, freqfns, incremental
from ..core.samplers import SampleResult


@dataclasses.dataclass
class StatsConfig:
    k: int = 4096                      # sample size per sketch
    ls: Sequence[float] = (1.0, 16.0, 256.0, 4096.0)  # geometric l-grid (§6)
    chunk: int = 2048
    salt: int = 0x5EED


class StreamStatsService:
    """Incremental multi-l sketch service over the jitted chunked samplers.

    For each l in the grid we keep a fixed-k continuous SH_l sketch.  A
    cap_T query is answered from the sketch with l closest to T in log-space
    (the paper's recommendation preceding §6.1: pick l within sqrt(2) of T).
    """

    def __init__(self, config: StatsConfig):
        self.config = config
        self._sampler = incremental.MultiSampler(
            tuple(float(l) for l in config.ls), k=config.k,
            chunk=config.chunk, salt=config.salt,
        )
        self._results: dict[float, SampleResult] | None = None

    # -- ingestion ---------------------------------------------------------

    def observe(self, keys, weights=None) -> None:
        """Feed a batch of stream elements (host arrays ok).

        One jitted dispatch advances all |ls| sketches; only the sub-chunk
        remainder stays on host until the next batch aligns it.
        """
        self._sampler.observe(np.asarray(keys).reshape(-1), weights)
        self._results = None

    @property
    def n_observed(self) -> int:
        return self._sampler.n_observed

    # -- sketch materialization --------------------------------------------

    def _materialize(self) -> dict[float, SampleResult]:
        if self._results is None:
            self._results = self._sampler.finalize()
        return self._results

    def sketches(self) -> dict[float, SampleResult]:
        return self._materialize()

    # -- queries -------------------------------------------------------------

    def pick_l(self, T: float) -> float:
        ls = np.asarray(self.config.ls, dtype=np.float64)
        return float(ls[np.argmin(np.abs(np.log(ls) - math.log(max(T, 1e-9))))])

    def query_cap(self, T: float, segment=None) -> float:
        """Estimate Q(cap_T, segment)."""
        res = self._materialize()[self.pick_l(T)]
        return estimators.estimate(res, freqfns.cap(T), segment)

    def query_distinct(self, segment=None) -> float:
        res = self._materialize()[self.pick_l(1.0)]
        return estimators.estimate(res, freqfns.distinct(), segment)

    def query_total(self, segment=None) -> float:
        res = self._materialize()[self.pick_l(max(self.config.ls))]
        return estimators.estimate(res, freqfns.total(), segment)

    def campaign_forecast(self, cap_per_user: float, segment=None) -> float:
        """The paper's motivating query: qualifying impressions under a
        per-user frequency cap, for the user segment H."""
        return self.query_cap(cap_per_user, segment)

    # -- hot-key extraction (embedding-sharding integration) -----------------

    def hot_keys(self, top: int) -> np.ndarray:
        """Keys with the largest sampled counts — candidates for replicated
        'hot' embedding-table placement.  Uses the largest-l sketch (closest
        to pps-by-frequency)."""
        res = self._materialize()[max(self.config.ls)]
        order = np.argsort(-res.counts)
        return res.keys[order[:top]]

    # -- multi-host merge ----------------------------------------------------

    def merge(self, other: "StreamStatsService") -> None:
        """Absorb another host's sketches (lane-wise merge_fixed_k under the
        shared per-lane threshold).  Both services must share a config."""
        if (tuple(other.config.ls) != tuple(self.config.ls)
                or other.config.k != self.config.k
                or other.config.salt != self.config.salt
                or other.config.chunk != self.config.chunk):
            # salt especially: kb/seed/tau from different hash functions
            # would union into a silently biased sketch
            raise ValueError("merge requires identical (k, ls, chunk, salt) configs")
        mine, theirs = self._sampler.state, other._sampler.state
        merged = DZ.merge_fixed_k_multi(
            mine.table, theirs.table, mine.l, mine.salt, k=self.config.k)
        self._sampler.state = incremental.SamplerState(
            table=merged,
            n_seen=mine.n_seen + theirs.n_seen,
            l=mine.l, salt=mine.salt,
        )
        # the other host's sub-chunk remainder joins ours through observe()
        rem = other._sampler._rem
        if len(rem.keys):
            self._sampler.observe(rem.keys, rem.weights)
        self._results = None

    # -- checkpointing --------------------------------------------------------

    def state_dict(self) -> dict:
        """O(k * |ls| + chunk) pytree of fixed-size arrays — the size is
        independent of how many elements were observed."""
        return self._sampler.state_dict()

    def load_state_dict(self, d: dict) -> None:
        self._sampler.load_state_dict(d)
        self._results = None

    @property
    def resident_bytes(self) -> int:
        """Bytes held by the sketches + remainder (the whole service state)."""
        return self._sampler.resident_bytes

    def save_checkpoint(self, ckpt_dir: str | Path, step: int) -> Path:
        """Write the service state through checkpoint.manager (atomic commit,
        retention); composes with a training state living in the same dir."""
        return ckpt_manager.save(ckpt_dir, step, self.state_dict())

    def restore_checkpoint(self, ckpt_dir: str | Path, step: int | None = None) -> int:
        """Load the latest (or a specific) committed step; returns the step."""
        if step is None:
            step = ckpt_manager.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
        tree = ckpt_manager.restore(ckpt_dir, step, self.state_dict())
        self.load_state_dict(tree)
        return step
