"""StreamStatsService: frequency-cap statistics as a first-class framework
feature (the paper's ad-campaign application, generalized).

Attach a service to any input pipeline; it maintains SH_l sketches (one per
configured l, or a coordinated multi-objective set) over the stream of keys
flowing through training/serving, with O(k) state per sketch, and answers

    service.query(T, segment)  ~=  Q(cap_T, segment)

Uses: ad-campaign reach forecasting (recsys archs: keys = (user, campaign)
pairs, answer = number of qualifying impressions under a per-user cap T);
token-frequency statistics for LM data mixing; degree statistics for GNN
samplers; expert-load statistics for MoE routing diagnostics.

The service state is a pytree -> it checkpoints with the training state and
merges across hosts (core.distributed) because sketches are mergeable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from ..core import estimators, freqfns
from ..core.samplers import SampleResult
from ..core import vectorized as VZ


@dataclasses.dataclass
class StatsConfig:
    k: int = 4096                      # sample size per sketch
    ls: Sequence[float] = (1.0, 16.0, 256.0, 4096.0)  # geometric l-grid (§6)
    chunk: int = 2048
    salt: int = 0x5EED


class StreamStatsService:
    """Host-side orchestrator around the jitted chunked samplers.

    For each l in the grid we keep a fixed-k continuous SH_l sketch.  A
    cap_T query is answered from the sketch with l closest to T in log-space
    (the paper's recommendation preceding §6.1: pick l within sqrt(2) of T).
    """

    def __init__(self, config: StatsConfig):
        self.config = config
        self._chunks_keys: list[np.ndarray] = []
        self._chunks_weights: list[np.ndarray] = []
        self._n_elements = 0
        self._results: dict[float, SampleResult] | None = None

    # -- ingestion ---------------------------------------------------------

    def observe(self, keys, weights=None) -> None:
        """Feed a batch of stream elements (host arrays ok)."""
        keys = np.asarray(keys).reshape(-1)
        if weights is None:
            weights = np.ones(len(keys), dtype=np.float32)
        self._chunks_keys.append(keys.astype(np.int64))
        self._chunks_weights.append(np.asarray(weights, np.float32).reshape(-1))
        self._n_elements += len(keys)
        self._results = None

    # -- sketch materialization --------------------------------------------

    def _materialize(self) -> dict[float, SampleResult]:
        if self._results is None:
            keys = np.concatenate(self._chunks_keys) if self._chunks_keys else np.zeros(0, np.int64)
            w = np.concatenate(self._chunks_weights) if self._chunks_weights else np.zeros(0, np.float32)
            out = {}
            for l in self.config.ls:
                out[l] = VZ.sample_fixed_k(
                    keys, w, k=self.config.k, l=l,
                    salt=self.config.salt, chunk=self.config.chunk,
                )
            self._results = out
        return self._results

    def sketches(self) -> dict[float, SampleResult]:
        return self._materialize()

    # -- queries -------------------------------------------------------------

    def pick_l(self, T: float) -> float:
        ls = np.asarray(self.config.ls, dtype=np.float64)
        return float(ls[np.argmin(np.abs(np.log(ls) - math.log(max(T, 1e-9))))])

    def query_cap(self, T: float, segment=None) -> float:
        """Estimate Q(cap_T, segment)."""
        res = self._materialize()[self.pick_l(T)]
        return estimators.estimate(res, freqfns.cap(T), segment)

    def query_distinct(self, segment=None) -> float:
        res = self._materialize()[self.pick_l(1.0)]
        return estimators.estimate(res, freqfns.distinct(), segment)

    def query_total(self, segment=None) -> float:
        res = self._materialize()[self.pick_l(max(self.config.ls))]
        return estimators.estimate(res, freqfns.total(), segment)

    def campaign_forecast(self, cap_per_user: float, segment=None) -> float:
        """The paper's motivating query: qualifying impressions under a
        per-user frequency cap, for the user segment H."""
        return self.query_cap(cap_per_user, segment)

    # -- hot-key extraction (embedding-sharding integration) -----------------

    def hot_keys(self, top: int) -> np.ndarray:
        """Keys with the largest sampled counts — candidates for replicated
        'hot' embedding-table placement.  Uses the largest-l sketch (closest
        to pps-by-frequency)."""
        res = self._materialize()[max(self.config.ls)]
        order = np.argsort(-res.counts)
        return res.keys[order[:top]]

    # -- checkpointing --------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "keys": self._chunks_keys,
            "weights": self._chunks_weights,
            "n": self._n_elements,
        }

    def load_state_dict(self, d: dict) -> None:
        self._chunks_keys = list(d["keys"])
        self._chunks_weights = list(d["weights"])
        self._n_elements = int(d["n"])
        self._results = None
