"""Sequential sampling oracles: direct transcriptions of the paper's
Algorithms 1-5.  These are the *paper-faithful baseline* — deliberately
per-element, cache-machine implementations (numpy/python dict/heap), used as
correctness oracles for the TPU-native vectorized/chunked samplers and for
the paper-validation benchmarks.

Randomness is counter-based hashing (core.hashing), so that fixed-threshold
runs are *bit-identical* between the oracle and the vectorized sampler:
the score of element i is a pure function of (salt, key_i, i).

Transcription notes (kept verbatim-faithful except where the camera-ready
pseudocode is garbled):

* Algorithm 5's eviction block prints ``Counters[x] <- -ln(1-r_x)/max(1/l,t*)``
  for surviving keys; the surrounding text ("...with count c_x - l(-ln(1-r_x))",
  §5.2) shows the intended update is ``c_x <- c_x - e_x / max(1/l, tau*)`` with
  e_x = -ln(1-r_x): re-simulating the key's entry as a fresh element of weight
  c_x under the lower threshold.  We implement the text's version; the count
  stays positive by construction (z_x < tau* implies e_x / max(1/l,tau*) < c_x).
* The eviction threshold z_x includes the KeyBase collapse for the race
  branch: race_x = e_x / c_x if e_x / c_x >= 1/l else KeyBase(x) (matching
  the entry rule reversal described in §5.2).
"""
from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from . import hashing as H

# Salt lanes, so each use of randomness is an independent hash function.
SALT_ELEM = 0x01
SALT_BUCKET = 0x02
SALT_KEYBASE = 0x03
SALT_EVICT_U = 0x04
SALT_EVICT_R = 0x05
SALT_SHARD = 0x06  # shard/host disambiguation of element ids


@dataclasses.dataclass
class SampleResult:
    keys: np.ndarray          # sampled key ids
    counts: np.ndarray        # c_x (1-pass) or exact w_x (2-pass)
    tau: float                # threshold ((k+1)-smallest seed for fixed-k)
    l: float                  # cap parameter of the scheme
    kind: str                 # "discrete" | "continuous" | "distinct" | "sh"
    exact_weights: bool = False

    def asdict(self) -> dict:
        return dict(zip(self.keys.tolist(), self.counts.tolist()))


# ---------------------------------------------------------------------------
# Element scoring (vectorized helpers shared by oracle + tests)
# ---------------------------------------------------------------------------


def keybase_np(keys, l: float, salt: int):
    """KeyBase(x) = Hash(x)/l ~ U[0, 1/l]."""
    return H.uniform01_np(H.hash_combine_np(keys, np.uint32(SALT_KEYBASE), np.uint32(salt))) / l


def elem_uniform_np(eids, salt: int):
    return H.uniform01_np(H.hash_combine_np(eids, np.uint32(SALT_ELEM), np.uint32(salt)))


def discrete_score_np(keys, eids, l: int, salt: int):
    """Eq. (6): bucket b = floor(l * rand()); score = Hash(x, b)."""
    u = H.uniform01_np(H.hash_combine_np(eids, np.uint32(SALT_BUCKET), np.uint32(salt)))
    bucket = np.minimum((u * l).astype(np.int64), l - 1)
    return H.uniform01_np(H.hash_combine_np(keys, bucket, np.uint32(salt)))


def distinct_score_np(keys, salt: int):
    """§3.6: ElementScore(h) = Hash(x)."""
    return H.uniform01_np(H.hash_combine_np(keys, np.uint32(salt)))


def sh_score_np(eids, salt: int):
    """§3.7: ElementScore(h) ~ U[0,1] independent per element."""
    return elem_uniform_np(eids, salt)


def continuous_score_np(keys, eids, weights, l: float, salt: int):
    """Eq. (10): v ~ Exp[w]; score = KeyBase(x) if v <= 1/l else v."""
    u = elem_uniform_np(eids, salt)
    v = H.exp_from_u(u, np.asarray(weights, dtype=np.float64))
    kb = keybase_np(keys, l, salt)
    return np.where(v <= 1.0 / l, kb, v)


def shard_eids_np(shard_no, idx):
    """Element ids for position ``idx`` of shard/host ``shard_no``.

    Hash-derived rather than ``shard_no * n + idx``: the arithmetic form
    overflows int32 once P*n > 2^31, silently aliasing element randomness
    across shards.  Bit-identical to the device twin
    (core.vectorized.shard_eids) after the uint32 cast both apply.
    """
    idx = np.asarray(idx)
    # broadcast the scalar parts: numpy warns on (wrapping) scalar uint32
    # arithmetic but not on the identical array ops
    salt_part = np.broadcast_to(np.uint32(SALT_SHARD), idx.shape)
    shard_part = np.broadcast_to(np.asarray(shard_no, np.uint32), idx.shape)
    return H.hash_combine_np(salt_part, shard_part, idx)


# ---------------------------------------------------------------------------
# Algorithm 1: 2-pass stream sampling, fixed size k
# ---------------------------------------------------------------------------


def alg1_two_pass(keys, weights, k: int, *, l: float, kind: str = "continuous", salt: int = 0) -> SampleResult:
    """Pass I: bottom-k keys by seed; Pass II: exact weights of sampled keys."""
    keys = np.asarray(keys)
    n = len(keys)
    weights = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    eids = np.arange(n, dtype=np.int64)
    if kind == "continuous":
        scores = continuous_score_np(keys, eids, weights, l, salt)
    elif kind == "discrete":
        scores = discrete_score_np(keys, eids, int(l), salt)
    elif kind == "distinct":
        scores = distinct_score_np(keys, salt)
    elif kind == "sh":
        scores = sh_score_np(eids, salt)
    else:
        raise ValueError(kind)

    # Pass I (faithful cache walk).
    seed: dict = {}
    tau = math.inf
    for i in range(n):
        x = keys[i].item()
        s = scores[i]
        if x in seed:
            seed[x] = min(seed[x], s)
        elif s < tau:
            seed[x] = s
            if len(seed) == k + 1:
                y = max(seed, key=seed.get)
                tau = seed[y]
                del seed[y]
    # Pass II: exact weights for sampled keys.
    sampled = np.array(sorted(seed), dtype=keys.dtype)
    mask = np.isin(keys, sampled)
    w_x = {x: 0.0 for x in sampled.tolist()}
    for i in np.nonzero(mask)[0]:
        w_x[keys[i].item()] += weights[i]
    return SampleResult(
        keys=sampled,
        counts=np.array([w_x[x] for x in sampled.tolist()]),
        tau=tau, l=l, kind=kind, exact_weights=True,
    )


# ---------------------------------------------------------------------------
# Algorithm 2: discrete fixed-threshold stream sampling (uniform weights)
# ---------------------------------------------------------------------------


def alg2_fixed_tau_discrete(keys, tau: float, *, l: int | float, salt: int = 0, kind: str = "discrete") -> SampleResult:
    keys = np.asarray(keys)
    n = len(keys)
    eids = np.arange(n, dtype=np.int64)
    if kind == "discrete":
        scores = discrete_score_np(keys, eids, int(l), salt) if not math.isinf(l) else sh_score_np(eids, salt)
    elif kind == "distinct":
        scores = distinct_score_np(keys, salt)
    elif kind == "sh":
        scores = sh_score_np(eids, salt)
    else:
        raise ValueError(kind)
    counters: dict = {}
    for i in range(n):
        x = keys[i].item()
        if x in counters:
            counters[x] += 1
        elif scores[i] < tau:
            counters[x] = 1
    ks = np.array(sorted(counters), dtype=keys.dtype)
    return SampleResult(
        keys=ks, counts=np.array([counters[x] for x in ks.tolist()], dtype=np.int64),
        tau=tau, l=l, kind=kind,
    )


# ---------------------------------------------------------------------------
# Algorithm 3: discrete fixed-size stream sampling (uniform weights)
# ---------------------------------------------------------------------------


def alg3_fixed_k_discrete(keys, k: int, *, l: int | float, salt: int = 0, kind: str = "discrete") -> SampleResult:
    keys = np.asarray(keys)
    n = len(keys)
    eids = np.arange(n, dtype=np.int64)
    if kind == "discrete" and math.isinf(l):
        kind = "sh"
    if kind == "discrete":
        scores = discrete_score_np(keys, eids, int(l), salt)
    elif kind == "distinct":
        scores = distinct_score_np(keys, salt)
    elif kind == "sh":
        scores = sh_score_np(eids, salt)
    else:
        raise ValueError(kind)

    # Fresh scores for the lazy-seed rescoring walk, keyed by (x, counter).
    rescore_ctr: dict = {}

    def rescore(x: int) -> float:
        c = rescore_ctr.get(x, 0)
        rescore_ctr[x] = c + 1
        eid = np.int64(n + c)  # disjoint from stream eids
        if kind == "discrete":
            return float(discrete_score_np(np.array([x]), np.array([eid]), int(l), salt + 0x10)[0])
        if kind == "distinct":
            return float(distinct_score_np(np.array([x]), salt)[0])  # constant: Hash(x)
        return float(sh_score_np(np.array([eid]), salt + 0x10)[0])

    counters: dict = {}
    seed: dict = {}
    heap: list = []  # max-heap over seeds: (-seed, x)
    tau = 1.0  # supremum of the score range
    for i in range(n):
        x = keys[i].item()
        if x in counters:
            counters[x] += 1
            continue
        s = scores[i]
        if s >= tau:
            continue
        seed[x] = s
        counters[x] = 1
        heapq.heappush(heap, (-s, x))
        while len(counters) > k:
            # pop the key with maximum *current* seed (lazy heap).
            while True:
                negs, y = heapq.heappop(heap)
                if y in counters and seed[y] == -negs:
                    break
            tau = seed[y]
            while counters[y] > 0 and seed[y] >= tau:
                counters[y] -= 1
                seed[y] = rescore(y)
            if counters[y] == 0:
                del counters[y], seed[y]
            else:
                heapq.heappush(heap, (-seed[y], y))
    ks = np.array(sorted(counters), dtype=keys.dtype)
    return SampleResult(
        keys=ks, counts=np.array([counters[x] for x in ks.tolist()], dtype=np.int64),
        tau=tau, l=l, kind=kind,
    )


# ---------------------------------------------------------------------------
# Algorithm 4: continuous SH_l fixed-threshold stream sampling
# ---------------------------------------------------------------------------


def alg4_fixed_tau_continuous(keys, weights, tau: float, *, l: float, salt: int = 0) -> SampleResult:
    keys = np.asarray(keys)
    n = len(keys)
    weights = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    eids = np.arange(n, dtype=np.int64)
    u = elem_uniform_np(eids, salt)
    kb = keybase_np(keys, l, salt)
    r = max(1.0 / l, tau)
    counters: dict = {}
    for i in range(n):
        x = keys[i].item()
        w = weights[i]
        if x in counters:
            counters[x] += w
            continue
        delta = -math.log1p(-u[i]) / r
        if delta < w and (tau * l > 1 or kb[i] < tau):
            counters[x] = w - delta
    ks = np.array(sorted(counters), dtype=keys.dtype)
    return SampleResult(
        keys=ks, counts=np.array([counters[x] for x in ks.tolist()]),
        tau=tau, l=l, kind="continuous",
    )


# ---------------------------------------------------------------------------
# Algorithm 5: continuous SH_l fixed-size stream sampling
# ---------------------------------------------------------------------------


def alg5_fixed_k_continuous(
    keys, weights, k: int, *, l: float, salt: int = 0, batch_evict: int = 1
) -> SampleResult:
    """Fixed-k continuous SH_l with the (optionally batched, §5.2) eviction."""
    keys = np.asarray(keys)
    n = len(keys)
    weights = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    eids = np.arange(n, dtype=np.int64)
    u_elem = elem_uniform_np(eids, salt)
    kb_all = keybase_np(keys, l, salt)
    kb: dict = {}

    counters: dict = {}
    tau = math.inf
    round_ctr = 0

    def evict(delta_evict: int) -> None:
        nonlocal tau, round_ctr
        round_ctr += 1
        items = list(counters.items())
        xs = np.array([x for x, _ in items], dtype=np.int64)
        cs = np.array([c for _, c in items], dtype=np.float64)
        if tau * l > 1:
            ux = H.uniform01_np(H.hash_combine_np(xs, np.uint32(SALT_EVICT_U), np.uint32(round_ctr), np.uint32(salt)))
            rx = H.uniform01_np(H.hash_combine_np(xs, np.uint32(SALT_EVICT_R), np.uint32(round_ctr), np.uint32(salt)))
            ex = -np.log1p(-rx)
            kbs = np.array([kb[x] for x in xs.tolist()])
            race = np.where(ex / cs >= 1.0 / l, ex / cs, kbs)
            seed_part = np.where(np.isinf(tau), np.inf, tau * ux)
            # Score-collapse correction (eq. 10): a (resampled) entry-point
            # score below 1/l means the key's effective seed is KeyBase(x),
            # so its survival threshold via the entry branch is KeyBase(x).
            # The printed z_x = min(tau*u_x, ...) omits this; without it the
            # estimator shows a measurable negative bias once tau crosses 1/l
            # (-2% at k=100 in our Zipf validation; 0 after the fix).
            entry_thresh = np.where(seed_part >= 1.0 / l, seed_part, kbs)
            z = np.minimum(entry_thresh, race)
            order = np.argsort(-z)
            evict_idx = order[:delta_evict]
            tau_star = z[evict_idx[-1]]
            new_rate = max(1.0 / l, tau_star)
            for j in range(len(xs)):
                x = xs[j].item()
                if z[j] >= tau_star:
                    del counters[x]
                else:
                    # survivor count adjustment: only when survival came via
                    # the re-entry race (the entry branch no longer qualifies)
                    if entry_thresh[j] >= tau_star:
                        counters[x] = cs[j] - ex[j] / new_rate
            tau = tau_star
        else:
            kbs = np.array([kb[x] for x in xs.tolist()])
            order = np.argsort(-kbs)
            evict_idx = order[:delta_evict]
            tau_star = kbs[evict_idx[-1]]
            for j in evict_idx:
                del counters[xs[j].item()]
            tau = tau_star

    for i in range(n):
        x = keys[i].item()
        w = weights[i]
        if x in counters:
            counters[x] += w
            continue
        r = max(1.0 / l, 0.0 if math.isinf(tau) else tau)
        if math.isinf(tau):
            r = 1.0 / l  # max(1/l, tau)=inf would make Delta=0; entry is then
            # governed solely by Delta<w vs the 1/l race... but with tau=inf the
            # printed rule max{l^-1, tau} = inf gives Delta = 0: every key
            # enters with full weight, matching SH's warm-up phase.
            delta = 0.0
        else:
            delta = -math.log1p(-u_elem[i]) / r
        if delta < w and ((tau * l > 1 if not math.isinf(tau) else True) or kb_all[i] < tau):
            kb[x] = kb_all[i]
            counters[x] = w - delta
            if len(counters) == k + 1:
                # delta=1 is Algorithm 5 verbatim; delta>1 is the paper's
                # "batch evictions" optimization (§5.2): new tau* is the
                # delta-th largest z_x and all keys with z >= tau* go.
                evict(min(batch_evict, k))
    ks = np.array(sorted(counters), dtype=keys.dtype)
    return SampleResult(
        keys=ks, counts=np.array([counters[x] for x in ks.tolist()]),
        tau=tau, l=l, kind="continuous",
    )
