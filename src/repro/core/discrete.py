"""Discrete SH_l spectrum (paper §4): the phi / psi / beta machinery.

Element scoring (eq. 6): an element of key x draws a uniform bucket
b ~ U[1..l] and scores Hash(x, b).  Distinct sampling is SH_1, classic SH is
SH_inf.

Estimation (§4.1): sampling acts on the key-frequency histogram m as an
upper-triangular transform  E[o] = Y(phi) m, where

    phi_i = P[the i-th element of a key is the first one counted]
          = tau * sum_j a_{i-1,j} (1-tau)^j (l-j)/l                (paper)

with a_{ij} = P[exactly j distinct buckets used in the first i elements],
computed by the recurrence (eq. 8)

    a_{ij} = a_{i-1,j} * j/l + a_{i-1,j-1} * (l-j+1)/l .

The inverse transform Y(psi) = Y(phi)^{-1} gives the unique unbiased
("admissible", Thm 4.1) coefficient-form estimator

    Qhat(f, H) = sum_{x in S∩H} beta_{c_x},
    beta_i = sum_{j=1..i} psi_j f_{i-j+1} .

Theorem 4.2 guarantees beta >= 0 for monotone non-decreasing f; tests assert
both the closed-form special cases (l=1 distinct: psi = [1/tau]; l=inf SH:
psi = [1/tau, -(1-tau)/tau]) and nonnegativity.

Everything here runs on the host in float64 (estimation is a post-processing
step on O(k)-size samples; the device-side hot path lives in vectorized.py /
kernels/).
"""
from __future__ import annotations

import math

import numpy as np


def phi_vector(l: int | float, tau: float, max_len: int = 200_000, tol: float = 1e-15) -> np.ndarray:
    """phi[i-1] = P[i-th element of a key is first counted], i = 1.. .

    Truncated adaptively once entries fall below ``tol * tau`` (the paper's
    M = O(min(l log l, tau^-1 log tau^-1)) bound); callers treat missing tail
    entries as 0.
    """
    if not (0 < tau <= 1):
        raise ValueError(f"tau must be in (0,1], got {tau}")
    if l == 1:
        return np.array([tau], dtype=np.float64)
    if math.isinf(l):
        # Classic SH: geometric.
        n = min(max_len, max(8, int(math.ceil(-50.0 / math.log1p(-min(tau, 1 - 1e-12))))))
        i = np.arange(1, n + 1, dtype=np.float64)
        return tau * (1.0 - tau) ** (i - 1.0)
    l = int(l)
    # Rolling row of a_{i,j}, j = 0..l.  a_{1,1} = 1.
    a = np.zeros(l + 1, dtype=np.float64)
    a[1] = 1.0
    j = np.arange(l + 1, dtype=np.float64)
    decay = (1.0 - tau) ** j
    fresh = (l - j) / l  # probability next element draws an unused bucket
    phis = [tau]  # phi_1 = tau (first element always uses a fresh bucket)
    for i in range(2, max_len + 1):
        # phi_i from a_{i-1, j}
        phi_i = tau * float(np.sum(a * decay * fresh))
        phis.append(phi_i)
        if phi_i < tol * tau and i > 8:
            break
        # advance a_{i-1} -> a_i  (recurrence (8))
        a_shift = np.zeros_like(a)
        a_shift[1:] = a[:-1]
        a = a * (j / l) + a_shift * ((l - j + 1.0) / l)
    return np.asarray(phis, dtype=np.float64)


def inclusion_prob(w, phi: np.ndarray):
    """Phi_{tau,l}(w) = sum_{j<=w} phi_j  (2-pass inverse-probability weight)."""
    w = np.asarray(w)
    cum = np.concatenate([[0.0], np.cumsum(phi)])
    idx = np.clip(w.astype(np.int64), 0, len(phi))
    return cum[idx]


def psi_vector(phi: np.ndarray, n: int) -> np.ndarray:
    """Invert the upper-triangular transform: psi = first row of Y(phi)^{-1}.

    psi_1 = 1/phi_1 ; psi_i = -(sum_{j<i} phi_{1+i-j} psi_j) / phi_1 .
    """
    phi_full = np.zeros(n + 1, dtype=np.float64)
    m = min(len(phi), n + 1)
    phi_full[:m] = phi[:m]
    psi = np.zeros(n, dtype=np.float64)
    psi[0] = 1.0 / phi_full[0]
    for i in range(2, n + 1):
        # sum_{j=1}^{i-1} phi_{1+i-j} psi_j   (1-indexed)
        s = float(np.dot(phi_full[i - 1 : 0 : -1], psi[: i - 1]))
        psi[i - 1] = -s / phi_full[0]
    return psi


def beta_coefficients(fvals: np.ndarray, psi: np.ndarray) -> np.ndarray:
    """beta_i = sum_{j=1..i} psi_j f_{i-j+1}, i = 1..n  (Thm 4.1).

    ``fvals`` is the table f_0..f_n (f_0 = f(0) = 0 unused);
    returns beta[0..n-1] for counts 1..n.
    """
    n = len(psi)
    f1 = np.asarray(fvals, dtype=np.float64)[1 : n + 1]
    if len(f1) < n:
        f1 = np.pad(f1, (0, n - len(f1)))
    # beta = psi (*) f  restricted: beta_i = sum psi_j f_{i-j+1}
    beta = np.convolve(psi, f1)[:n]
    return beta


def estimator_coefficients(fvals: np.ndarray, l: int | float, tau: float, n: int) -> np.ndarray:
    """End-to-end: coefficients beta_1..beta_n for the 1-pass SH_l estimator."""
    if l == 1:
        # Distinct sampling (eq. 4): beta_i = f_i / tau.
        f1 = np.asarray(fvals, dtype=np.float64)[1 : n + 1]
        return f1 / tau
    if math.isinf(l):
        # Classic SH (eq. 5): beta_i = (f_i - f_{i-1}(1-tau)) / tau.
        f = np.asarray(fvals, dtype=np.float64)
        f1 = f[1 : n + 1]
        f0 = f[0:n]
        return (f1 - f0 * (1.0 - tau)) / tau
    phi = phi_vector(l, tau)
    psi = psi_vector(phi, n)
    return beta_coefficients(fvals, psi)


def estimate(counts: np.ndarray, fvals: np.ndarray, l: int | float, tau: float) -> float:
    """Qhat(f) = sum_x beta_{c_x} over sampled keys with integer counts c_x."""
    counts = np.asarray(counts, dtype=np.int64)
    if len(counts) == 0:
        return 0.0
    n = int(counts.max())
    beta = estimator_coefficients(fvals, l, tau, n)
    return float(np.sum(beta[counts - 1]))
