"""Sort-and-segment utilities + first-class query ``Segment`` objects.

Two related meanings of "segment" live here on purpose:

1. **Sorted-run segments** (the original contents): the TPU-native
   replacement for hash tables.  The sequential algorithms probe a dict per
   element; the vectorized samplers instead sort a chunk by key and reduce
   with ``jax.ops.segment_*``.  These helpers are shared by the samplers,
   the GNN message passing and the recsys EmbeddingBag (JAX has no native
   EmbeddingBag/CSR — segment ops ARE the substrate).

2. **Query segments** (the H in Q(f, H), paper §2): first-class,
   *hashable* predicates over key ids.  Every query surface
   (``estimators.estimate``, ``freqfns.exact_statistic``, the batched
   ``stats.query.QueryEngine``) coerces its ``segment`` argument through
   ``as_segment`` so id-lists, Python predicates, boolean masks and hash
   buckets all mean the same thing everywhere — and so the query engine can
   compile a segment ONCE per sketch lane into a device mask and cache it by
   ``Segment`` identity instead of re-running ``np.isin`` per query.

Conventions: padding key is ``EMPTY = int32 max`` so padded slots sort last;
all shapes are static (chunk size / capacity are compile-time constants).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import hashing as H

EMPTY = jnp.int32(2**31 - 1)

# salt lane for HashBucket segments (disjoint from the sampler salt lanes in
# core.samplers, which start at 0x01)
SALT_SEGMENT = 0x5E


# ---------------------------------------------------------------------------
# Query segments: the H in Q(f, H)
# ---------------------------------------------------------------------------


class Segment:
    """A set of key ids, evaluable as a boolean mask over any key array.

    Subclasses implement ``mask_np(keys) -> bool[len(keys)]`` and are
    hashable/equatable by *content* (or by held-object identity for opaque
    predicates), so compiled per-lane masks can be cached with the Segment
    itself as the cache key — holding the Segment in the cache keeps any
    captured callable alive, which keeps identity-based keys valid.
    """

    def mask_np(self, keys: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class AllKeys(Segment):
    """H = all keys (segment=None everywhere coerces to this)."""

    def mask_np(self, keys):
        return np.ones(len(keys), dtype=bool)

    def __eq__(self, other):
        return type(other) is AllKeys

    def __hash__(self):
        return hash(AllKeys)

    def describe(self):
        return "all"


class IdSet(Segment):
    """Membership in an explicit id set (kept sorted; content-hashed)."""

    def __init__(self, ids):
        self.ids = np.unique(np.asarray(ids).reshape(-1))
        self._digest = hash((len(self.ids), self.ids.tobytes()))

    def mask_np(self, keys):
        # np.isin == the historical estimators._segment_mask id-list semantics
        return np.isin(keys, self.ids)

    def __eq__(self, other):
        return (type(other) is IdSet and self._digest == other._digest
                and np.array_equal(self.ids, other.ids))

    def __hash__(self):
        return self._digest

    def describe(self):
        return f"ids[{len(self.ids)}]"


class Mask(Segment):
    """A precomputed boolean mask aligned with a specific key array.

    This is the historical ``freqfns.exact_statistic`` calling convention;
    the mask length must match the key array it is applied to.
    """

    def __init__(self, mask):
        self.mask = np.asarray(mask, dtype=bool).reshape(-1)
        self._digest = hash((len(self.mask), self.mask.tobytes()))

    def mask_np(self, keys):
        if len(self.mask) != len(keys):
            raise ValueError(
                f"Mask segment of length {len(self.mask)} applied to "
                f"{len(keys)} keys — mask segments are positional; use IdSet/"
                "Predicate/HashBucket for key-id semantics")
        return self.mask

    def __eq__(self, other):
        return (type(other) is Mask and self._digest == other._digest
                and np.array_equal(self.mask, other.mask))

    def __hash__(self):
        return self._digest

    def describe(self):
        return f"mask[{int(self.mask.sum())}/{len(self.mask)}]"


class Predicate(Segment):
    """An arbitrary vectorized predicate over key ids (host-evaluated).

    Equality/hash are by callable identity: two Predicates wrapping the same
    function object compare equal (and hit the same compiled-mask cache);
    distinct lambdas are distinct segments even if textually identical.
    """

    def __init__(self, fn, name: str | None = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "predicate")

    def mask_np(self, keys):
        return np.asarray(self.fn(keys), dtype=bool).reshape(len(keys))

    def __eq__(self, other):
        return type(other) is Predicate and self.fn is other.fn

    def __hash__(self):
        return hash(self.fn)

    def describe(self):
        return self.name


class HashBucket(Segment):
    """H = keys hashing into bucket ``bucket`` of ``n_buckets`` (A/B slices).

    Uses the shared counter-based hashing substrate (core.hashing), so the
    same (n_buckets, bucket, salt) triple selects the same keys on every
    host and backend.
    """

    def __init__(self, n_buckets: int, bucket: int, salt: int = 0):
        if not 0 <= bucket < n_buckets:
            raise ValueError(f"bucket {bucket} not in [0, {n_buckets})")
        self.n_buckets, self.bucket, self.salt = int(n_buckets), int(bucket), int(salt)

    def mask_np(self, keys):
        h = H.hash_combine_np(np.asarray(keys), np.uint32(SALT_SEGMENT),
                              np.uint32(self.salt))
        return (h % np.uint32(self.n_buckets)) == np.uint32(self.bucket)

    def __eq__(self, other):
        return (type(other) is HashBucket
                and (self.n_buckets, self.bucket, self.salt)
                == (other.n_buckets, other.bucket, other.salt))

    def __hash__(self):
        return hash((HashBucket, self.n_buckets, self.bucket, self.salt))

    def describe(self):
        return f"bucket {self.bucket}/{self.n_buckets}"


def as_segment(segment) -> Segment:
    """Coerce every historical ``segment=`` convention to a Segment.

    None -> AllKeys; Segment -> itself; callable -> Predicate; boolean
    array -> positional Mask; any other array-like -> IdSet membership.
    """
    if segment is None:
        return AllKeys()
    if isinstance(segment, Segment):
        return segment
    if callable(segment):
        return Predicate(segment)
    arr = np.asarray(segment)
    if arr.dtype == bool:
        return Mask(arr)
    return IdSet(arr)


def sort_by_key(keys, *arrays):
    """Stable-sort ``keys`` ascending; apply the permutation to all arrays."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], tuple(a[order] for a in arrays)


def segment_ids(sorted_keys):
    """Segment ids (0..n_seg-1) for a sorted key array; padding gets its own
    trailing segment(s)."""
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    return jnp.cumsum(first) - 1, first


def scatter_unique(sorted_keys, seg, fill, values=None):
    """Place per-segment values at positions 0..n_seg-1 of a fixed-size array.

    Returns (unique_keys, value_array or None).  Slots past n_seg keep
    ``EMPTY`` / ``fill``.
    """
    n = sorted_keys.shape[0]
    ukeys = jnp.full((n,), EMPTY, dtype=sorted_keys.dtype).at[seg].set(sorted_keys)
    if values is None:
        return ukeys, None
    vals = jnp.full((n,), fill, dtype=values.dtype).at[seg].set(values)
    return ukeys, vals


def compact_valid(valid, *arrays, fills):
    """Move entries with valid=True to the front (stable), padding the rest."""
    order = jnp.argsort(~valid, stable=True)
    out = []
    for a, fill in zip(arrays, fills):
        b = a[order]
        v = valid[order]
        out.append(jnp.where(v, b, jnp.asarray(fill, dtype=b.dtype)))
    return tuple(out)


def bottom_k_by(score, k, *arrays, fills):
    """Keep the k entries with smallest score; pad the rest.

    Returns (scores_k, arrays_k...).  Uses top_k on negated scores (TPU native).
    """
    neg = -score
    _, idx = jax.lax.top_k(neg, k)
    outs = [score[idx]]
    for a, fill in zip(arrays, fills):
        outs.append(a[idx])
    # entries with +inf score are padding
    validk = jnp.isfinite(outs[0])
    outs = [outs[0]] + [
        jnp.where(validk, a, jnp.asarray(fill, dtype=a.dtype))
        for a, fill in zip(outs[1:], fills)
    ]
    return tuple(outs)
