"""Sort-and-segment utilities: the TPU-native replacement for hash tables.

The sequential algorithms probe a dict per element; the vectorized samplers
instead sort a chunk by key and reduce with ``jax.ops.segment_*``.  These
helpers are shared by the samplers, the GNN message passing and the recsys
EmbeddingBag (JAX has no native EmbeddingBag/CSR — segment ops ARE the
substrate, per the assignment notes).

Conventions: padding key is ``EMPTY = int32 max`` so padded slots sort last;
all shapes are static (chunk size / capacity are compile-time constants).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(2**31 - 1)


def sort_by_key(keys, *arrays):
    """Stable-sort ``keys`` ascending; apply the permutation to all arrays."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], tuple(a[order] for a in arrays)


def segment_ids(sorted_keys):
    """Segment ids (0..n_seg-1) for a sorted key array; padding gets its own
    trailing segment(s)."""
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    return jnp.cumsum(first) - 1, first


def scatter_unique(sorted_keys, seg, fill, values=None):
    """Place per-segment values at positions 0..n_seg-1 of a fixed-size array.

    Returns (unique_keys, value_array or None).  Slots past n_seg keep
    ``EMPTY`` / ``fill``.
    """
    n = sorted_keys.shape[0]
    ukeys = jnp.full((n,), EMPTY, dtype=sorted_keys.dtype).at[seg].set(sorted_keys)
    if values is None:
        return ukeys, None
    vals = jnp.full((n,), fill, dtype=values.dtype).at[seg].set(values)
    return ukeys, vals


def compact_valid(valid, *arrays, fills):
    """Move entries with valid=True to the front (stable), padding the rest."""
    order = jnp.argsort(~valid, stable=True)
    out = []
    for a, fill in zip(arrays, fills):
        b = a[order]
        v = valid[order]
        out.append(jnp.where(v, b, jnp.asarray(fill, dtype=b.dtype)))
    return tuple(out)


def bottom_k_by(score, k, *arrays, fills):
    """Keep the k entries with smallest score; pad the rest.

    Returns (scores_k, arrays_k...).  Uses top_k on negated scores (TPU native).
    """
    neg = -score
    _, idx = jax.lax.top_k(neg, k)
    outs = [score[idx]]
    for a, fill in zip(arrays, fills):
        outs.append(a[idx])
    # entries with +inf score are padding
    validk = jnp.isfinite(outs[0])
    outs = [outs[0]] + [
        jnp.where(validk, a, jnp.asarray(fill, dtype=a.dtype))
        for a, fill in zip(outs[1:], fills)
    ]
    return tuple(outs)
