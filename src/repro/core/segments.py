"""Sort-and-segment utilities + first-class query ``Segment`` objects.

Two related meanings of "segment" live here on purpose:

1. **Sorted-run segments** (the original contents): the TPU-native
   replacement for hash tables.  The sequential algorithms probe a dict per
   element; the vectorized samplers instead sort a chunk by key and reduce
   with ``jax.ops.segment_*``.  These helpers are shared by the samplers,
   the GNN message passing and the recsys EmbeddingBag (JAX has no native
   EmbeddingBag/CSR — segment ops ARE the substrate).

2. **Query segments** (the H in Q(f, H), paper §2): first-class,
   *hashable* predicates over key ids.  Every query surface
   (``estimators.estimate``, ``freqfns.exact_statistic``, the batched
   ``stats.query.QueryEngine``) coerces its ``segment`` argument through
   ``as_segment`` so id-lists, Python predicates, boolean masks and hash
   buckets all mean the same thing everywhere — and so the query engine can
   compile a segment ONCE per sketch lane into a device mask and cache it by
   ``Segment`` identity instead of re-running ``np.isin`` per query.

Conventions: padding key is ``EMPTY = int32 max`` so padded slots sort last;
all shapes are static (chunk size / capacity are compile-time constants).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from . import hashing as H

EMPTY = jnp.int32(2**31 - 1)
_EMPTY_INT = int(EMPTY)


def is_empty(keys):
    """Canonical "is this slot padding?" test for key arrays.

    Works on traced jnp arrays and host numpy arrays alike (numpy stays on
    host — no implicit device round-trip) and is the single point where the
    EMPTY encoding is compared, so the sentinel stays changeable in one
    place. Enforced by reprolint RPL006 on hot-path modules.
    """
    if isinstance(keys, np.ndarray):
        return keys == _EMPTY_INT
    return keys == EMPTY


def is_live(keys):
    """Negation of :func:`is_empty`; same contract."""
    if isinstance(keys, np.ndarray):
        return keys != _EMPTY_INT
    return keys != EMPTY

# salt lane for HashBucket segments (disjoint from the sampler salt lanes in
# core.samplers, which start at 0x01)
SALT_SEGMENT = 0x5E


# ---------------------------------------------------------------------------
# Query segments: the H in Q(f, H)
# ---------------------------------------------------------------------------


class Segment:
    """A set of key ids, evaluable as a boolean mask over any key array.

    Subclasses implement ``mask_np(keys) -> bool[len(keys)]`` and are
    hashable/equatable by *content* (or by held-object identity for opaque
    predicates), so compiled per-lane masks can be cached with the Segment
    itself as the cache key — holding the Segment in the cache keeps any
    captured callable alive, which keeps identity-based keys valid.
    """

    def mask_np(self, keys: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class AllKeys(Segment):
    """H = all keys (segment=None everywhere coerces to this)."""

    def mask_np(self, keys):
        return np.ones(len(keys), dtype=bool)

    def __eq__(self, other):
        return type(other) is AllKeys

    def __hash__(self):
        return hash(AllKeys)

    def describe(self):
        return "all"


class IdSet(Segment):
    """Membership in an explicit id set (kept sorted; content-hashed)."""

    def __init__(self, ids):
        self.ids = np.unique(np.asarray(ids).reshape(-1))
        self._digest = hash((len(self.ids), self.ids.tobytes()))

    def mask_np(self, keys):
        # np.isin == the historical estimators._segment_mask id-list semantics
        return np.isin(keys, self.ids)

    def __eq__(self, other):
        return (type(other) is IdSet and self._digest == other._digest
                and np.array_equal(self.ids, other.ids))

    def __hash__(self):
        return self._digest

    def describe(self):
        return f"ids[{len(self.ids)}]"


class Mask(Segment):
    """A precomputed boolean mask aligned with a specific key array.

    This is the historical ``freqfns.exact_statistic`` calling convention;
    the mask length must match the key array it is applied to.
    """

    def __init__(self, mask):
        self.mask = np.asarray(mask, dtype=bool).reshape(-1)
        self._digest = hash((len(self.mask), self.mask.tobytes()))

    def mask_np(self, keys):
        if len(self.mask) != len(keys):
            raise ValueError(
                f"Mask segment of length {len(self.mask)} applied to "
                f"{len(keys)} keys — mask segments are positional; use IdSet/"
                "Predicate/HashBucket for key-id semantics")
        return self.mask

    def __eq__(self, other):
        return (type(other) is Mask and self._digest == other._digest
                and np.array_equal(self.mask, other.mask))

    def __hash__(self):
        return self._digest

    def describe(self):
        return f"mask[{int(self.mask.sum())}/{len(self.mask)}]"


class Predicate(Segment):
    """An arbitrary vectorized predicate over key ids (host-evaluated).

    Equality/hash are by callable identity: two Predicates wrapping the same
    function object compare equal (and hit the same compiled-mask cache);
    distinct lambdas are distinct segments even if textually identical.
    """

    def __init__(self, fn, name: str | None = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "predicate")

    def mask_np(self, keys):
        return np.asarray(self.fn(keys), dtype=bool).reshape(len(keys))

    def __eq__(self, other):
        return type(other) is Predicate and self.fn is other.fn

    def __hash__(self):
        return hash(self.fn)

    def describe(self):
        return self.name


class HashBucket(Segment):
    """H = keys hashing into bucket ``bucket`` of ``n_buckets`` (A/B slices).

    Uses the shared counter-based hashing substrate (core.hashing), so the
    same (n_buckets, bucket, salt) triple selects the same keys on every
    host and backend.
    """

    def __init__(self, n_buckets: int, bucket: int, salt: int = 0):
        if not 0 <= bucket < n_buckets:
            raise ValueError(f"bucket {bucket} not in [0, {n_buckets})")
        self.n_buckets, self.bucket, self.salt = int(n_buckets), int(bucket), int(salt)

    def mask_np(self, keys):
        h = H.hash_combine_np(np.asarray(keys), np.uint32(SALT_SEGMENT),
                              np.uint32(self.salt))
        return (h % np.uint32(self.n_buckets)) == np.uint32(self.bucket)

    def __eq__(self, other):
        return (type(other) is HashBucket
                and (self.n_buckets, self.bucket, self.salt)
                == (other.n_buckets, other.bucket, other.salt))

    def __hash__(self):
        return hash((HashBucket, self.n_buckets, self.bucket, self.salt))

    def describe(self):
        return f"bucket {self.bucket}/{self.n_buckets}"


def as_segment(segment) -> Segment:
    """Coerce every historical ``segment=`` convention to a Segment.

    None -> AllKeys; Segment -> itself; callable -> Predicate; boolean
    array -> positional Mask; any other array-like -> IdSet membership.
    """
    if segment is None:
        return AllKeys()
    if isinstance(segment, Segment):
        return segment
    if callable(segment):
        return Predicate(segment)
    arr = np.asarray(segment)
    if arr.dtype == bool:
        return Mask(arr)
    return IdSet(arr)


def normalize_keys(keys) -> np.ndarray:
    """Validate and convert stream keys to the canonical int32 form.

    Every ingestion surface — the stateful ``observe``/``reconcile`` AND the
    one-shot samplers (``vectorized._prep``) — funnels through this one helper
    so keys can never be *silently* wrapped by an ``np.asarray(keys, np.int32)``
    cast: non-integer dtypes, values outside int32 range, and the reserved
    padding id ``EMPTY`` (int32 max) all raise instead of corrupting the
    per-key randomness.
    """
    arr = np.asarray(keys).reshape(-1)
    if arr.dtype == np.int32:
        out = arr
    else:
        if not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(
                f"stream keys must be integers, got dtype {arr.dtype} — "
                "casting floats/objects would silently truncate key ids")
        if arr.size and (arr.min() < -_EMPTY_INT - 1 or arr.max() > _EMPTY_INT):
            bad = arr[(arr < -_EMPTY_INT - 1) | (arr > _EMPTY_INT)][0]
            raise ValueError(
                f"stream key {bad} outside int32 range — int32 is the key "
                "domain of the sketches; remap ids before ingestion")
        out = arr.astype(np.int32)
    if out.size and out.max() == _EMPTY_INT:
        raise ValueError(
            f"stream key {_EMPTY_INT} is the reserved EMPTY padding id — "
            "remap it before ingestion")
    return out


def sort_by_key(keys, *arrays):
    """Stable-sort ``keys`` ascending; apply the permutation to all arrays."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], tuple(a[order] for a in arrays)


def stable_sort_with_perm(keys):
    """Registered XLA sort dual: ``(keys[perm], perm)`` under the stable
    ascending argsort.  The chunksort Pallas kernel pins bit-identity against
    exactly this function; it is also the fallback route when a backend has
    no compiled sort lowering."""
    perm = jnp.argsort(keys, stable=True)
    return keys[perm], perm


class ChunkOrder(NamedTuple):
    """The shared sort of one stream chunk: computed ONCE per chunk, consumed
    by every per-lane reduction (aggregate, bottom-k summary, merge).

    The key insight behind the single-sort ingest path: the permutation that
    sorts a chunk by key depends only on the keys, never on the per-lane
    payloads, so L lanes can share it.  ``ks = keys[perm]`` is ascending with
    EMPTY (int32 max) last; ``seg`` are its segment ids; ``ukeys`` the unique
    keys compacted to the front (ascending, EMPTY padded) — exactly what
    ``sort_by_key`` + ``segment_ids`` + ``scatter_unique`` produce, shared.

    ``eids``/``ws`` (optional) are the **pre-gathered view**: the chunk's
    element ids and weights already permuted into key order.  Element
    randomness depends only on the (key, eid) *values*, never on stream
    position, so scoring the pre-gathered view emits every per-element score
    already key-sorted — scoring is permutation-covariant,
    ``score(x[perm]) == score(x)[perm]`` bit for bit — and the downstream
    segment reductions need no per-lane gathers at all (the score-in-key-order
    ingest path; DESIGN.md §9).
    """

    ks: jax.Array     # [C] keys sorted ascending (stable; EMPTY last)
    perm: jax.Array   # [C] permutation: ks == keys[perm]
    seg: jax.Array    # [C] segment ids of ks (0..n_seg-1)
    ukeys: jax.Array  # [C] unique keys, ascending, EMPTY padded
    eids: jax.Array | None = None  # [C] element ids in key order (= eids[perm])
    ws: jax.Array | None = None    # [C] weights in key order (= weights[perm])


def chunk_order(keys, eids=None, weights=None, *,
                sort_backend: str | None = None) -> ChunkOrder:
    """Sort a chunk by key once; derive (permutation, segments, uniques).

    Pass ``eids``/``weights`` to also attach the pre-gathered (key-ordered)
    view — three O(C) gathers paid once per chunk, shared by every lane.

    ``sort_backend`` routes the shared key sort: ``'pallas'`` runs the
    block-local bitonic + cross-block merge kernel (kernels/chunksort),
    ``'xla'`` the stable argsort dual above, ``None`` (auto) picks pallas on
    backends with a compiled lowering (TPU/GPU) and XLA elsewhere.  Both
    routes are bit-identical (the kernel sorts (key, index) pairs
    lexicographically, which *is* the stable argsort), so the choice is pure
    perf routing.
    """
    if sort_backend not in (None, "xla", "pallas"):
        raise ValueError(
            f"unknown sort backend {sort_backend!r}: use None (auto), 'xla' "
            "or 'pallas'")
    if sort_backend is None:
        # auto: compiled sort route only where a real lowering exists; on
        # CPU the argsort dual needs no kernel import at all
        sort_backend = ("pallas" if jax.default_backend() in ("tpu", "gpu")
                        else "xla")
    if sort_backend == "pallas":
        # deferred import: kernels.chunksort imports this module for EMPTY
        from ..kernels.chunksort.ops import sort_with_perm

        ks, perm = sort_with_perm(keys, backend="pallas")
    else:
        ks, perm = stable_sort_with_perm(keys)
    seg, first = segment_ids(ks)
    # gather-form unique compaction: each segment's first element, compacted
    # to the front — bit-identical to ``scatter_unique(ks, seg, ...)`` (same
    # keys land on the same slots) without paying an XLA:CPU scatter
    (ukeys,) = compact_valid(first, ks, fills=(EMPTY,))
    return ChunkOrder(
        ks=ks, perm=perm, seg=seg, ukeys=ukeys,
        eids=None if eids is None else eids[perm],
        ws=None if weights is None else weights[perm],
    )


def merge_sorted_runs(a, b):
    """Positions of two sorted runs in their stable merged order.

    ``a`` and ``b`` must each be sorted ascending.  Returns ``(pos_a, pos_b)``
    — a permutation of ``0..len(a)+len(b)-1`` such that scattering ``a`` to
    ``pos_a`` and ``b`` to ``pos_b`` yields exactly the array a stable sort of
    ``concatenate([a, b])`` would produce (ties: all of ``a``'s entries before
    ``b``'s, internal order preserved).  Cost is two ``searchsorted`` passes —
    O((|a|+|b|) log) comparisons with tiny constants — instead of a full
    O(N log N) sort, which is the point: the sampler table is already sorted,
    so merging a C-sized chunk aggregate into it never re-sorts the table.
    """
    na, nb = a.shape[0], b.shape[0]
    pos_a = jnp.arange(na) + searchsorted(b, a, side="left")
    pos_b = jnp.arange(nb) + searchsorted(a, b, side="right")
    return pos_a, pos_b


def merge_sorted_runs_gather(a, b, out_len: int | None = None):
    """Gather-form of ``merge_sorted_runs``: per merged slot, which run and
    which index feeds it.

    Returns ``(from_b, ia, ib)`` with merged[p] = b[ib[p]] if from_b[p] else
    a[ia[p]] — the exact inverse of the scatter positions above, recovered
    with one extra ``searchsorted`` over the (strictly increasing) insertion
    positions of ``b``.  The point: applying a merge to many payload columns
    costs one cheap gather per column, where the scatter form pays a scatter
    per column — and XLA CPU executes gathers ~50x faster than scatters.

    ``out_len`` truncates the merged view to its first ``out_len`` positions
    (callers that immediately slice the merge — the fixed-capacity table and
    summary folds — skip building rank information for slots they drop).

    The inverse rank map (``nb_before``: how many b-slots land at or before
    each merged position) is a unit-scatter + cumsum rather than a second
    ``searchsorted``: the insertion positions are strictly increasing and
    unique, so marking them and prefix-summing yields exactly the same
    integers — and XLA:CPU runs the scatter+cumsum ~4x faster than a binary
    search whose queries are the full iota.
    """
    na, nb = a.shape[0], b.shape[0]
    m = na + nb if out_len is None else min(out_len, na + nb)
    pos_b = jnp.arange(nb) + searchsorted(a, b, side="right")
    # out-of-window positions pile onto the sacrificial slot m (sliced off);
    # clipped positions stay non-decreasing, so the scatter-add is sorted
    ind = jnp.zeros((m + 1,), jnp.int32).at[
        jnp.minimum(pos_b, m)].add(1, indices_are_sorted=True)[:m]
    nb_before = jnp.cumsum(ind)  # == count of pos_b <= p, bit for bit
    ib = jnp.clip(nb_before - 1, 0, nb - 1)
    from_b = ind > 0
    ia = jnp.clip(jnp.arange(m) - nb_before, 0, na - 1)
    return from_b, ia, ib


def searchsorted(a, v, side: str = "left"):
    """``jnp.searchsorted`` pinned to ``method='scan_unrolled'``.

    Identical indices to the default ``'scan'`` lowering — the method only
    picks the loop form — but the unrolled binary search avoids XLA:CPU's
    per-iteration while-loop thunk overhead (~20% on the rank passes that
    dominate the sorted-runs merges).  All hot-path rank computations go
    through here.
    """
    return jnp.searchsorted(a, v, side=side, method="scan_unrolled")


def kth_smallest(x, r):
    """Exact r-th smallest value (0-indexed; ``r`` may be traced) of a float32
    array — without sorting.

    XLA:CPU lowers a full f32 sort at ~250ns/element, which made order-
    statistic thresholds (the eviction tau*, the bottom-cap seed threshold)
    the single hottest primitive of the ingest step.  A threshold does not
    need a sort: map f32 to uint32 by the standard monotone total-order
    bijection (negatives bit-flipped, non-negatives sign-bit set) and build
    the r-th smallest key bit by bit — 32 branchless rounds of
    compare-and-count, each a vectorized reduction.  ~15x faster than the
    sort at 8k elements and exact: the returned bits are the element's own
    bits (ties share bits; no NaNs expected — -0.0/+0.0 straddles are the
    only bit ambiguity, and every caller compares, never hashes, the result).
    """
    u = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    key = jnp.where(u >> 31 == 1, ~u, u | jnp.uint32(0x80000000))
    res = jnp.uint32(0)
    for bit in range(31, -1, -1):
        cand = res | (jnp.uint32(1) << bit)
        cnt = jnp.sum((key < cand).astype(jnp.int32))
        res = jnp.where(cnt <= r, cand, res)
    back = jnp.where(res >> 31 == 1, res ^ jnp.uint32(0x80000000), ~res)
    return jax.lax.bitcast_convert_type(back, jnp.float32)


def segment_ids(sorted_keys):
    """Segment ids (0..n_seg-1) for a sorted key array; padding gets its own
    trailing segment(s)."""
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    return jnp.cumsum(first) - 1, first


def scatter_unique(sorted_keys, seg, fill, values=None):
    """Place per-segment values at positions 0..n_seg-1 of a fixed-size array.

    Returns (unique_keys, value_array or None).  Slots past n_seg keep
    ``EMPTY`` / ``fill``.
    """
    n = sorted_keys.shape[0]
    ukeys = jnp.full((n,), EMPTY, dtype=sorted_keys.dtype).at[seg].set(sorted_keys)
    if values is None:
        return ukeys, None
    vals = jnp.full((n,), fill, dtype=values.dtype).at[seg].set(values)
    return ukeys, vals


def compact_valid(valid, *arrays, fills):
    """Move entries with valid=True to the front (stable), padding the rest.

    The source map (p-th output slot <- index of the (p+1)-th valid entry) is
    one unit int scatter: valid entry ``i`` owns output slot ``cs[i]-1``, and
    those slots are unique, so ``src.at[cs-1].set(i)`` builds the map
    directly — bit-identical to the historical ``searchsorted(cs, iota)``
    form (both compute the same stable ranks) but ~2x faster on XLA:CPU,
    where iota-query binary searches lower poorly.  Payload columns then pay
    one cheap gather each.  Order-preserving: compacting an ascending array
    yields an ascending array, which is what maintains the sorted-table
    invariant of core.vectorized.
    """
    n = valid.shape[0]
    cs = jnp.cumsum(valid)
    # invalid entries target the sacrificial slot n (sliced off), keeping
    # every target in-bounds — valid targets are unique by construction
    src = jnp.zeros((n + 1,), cs.dtype).at[
        jnp.where(valid, cs - 1, n)].set(jnp.arange(n))[:n]
    keep = jnp.arange(n) < cs[-1]
    out = []
    for a, fill in zip(arrays, fills):
        out.append(jnp.where(keep, a[src], jnp.asarray(fill, dtype=a.dtype)))
    return tuple(out)


def bottom_k_by(score, k, *arrays, fills):
    """Keep the k entries with smallest score; pad the rest.

    Returns (scores_k, arrays_k...).  Uses top_k on negated scores (TPU native).
    """
    neg = -score
    _, idx = jax.lax.top_k(neg, k)
    outs = [score[idx]]
    for a, fill in zip(arrays, fills):
        outs.append(a[idx])
    # entries with +inf score are padding
    validk = jnp.isfinite(outs[0])
    outs = [outs[0]] + [
        jnp.where(validk, a, jnp.asarray(fill, dtype=a.dtype))
        for a, fill in zip(outs[1:], fills)
    ]
    return tuple(outs)
