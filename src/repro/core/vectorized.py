"""TPU-native chunked stream samplers (the hardware adaptation of Algs 1-5).

The paper's cache machines are recast as dataflow (see DESIGN.md §3):

    score elements  ->  per-chunk segment reduce  ->  merge with carried
    fixed-size state  ->  (fixed-k only) batched eviction.

The whole sampler is a ``jax.lax.scan`` over stream chunks with O(k + chunk)
state, so it jit-compiles, shards (each device samples its shard; states
merge — see core/distributed.py), and checkpoints.

Faithfulness contract, verified in tests/test_equivalence.py:

* fixed-threshold samplers are *element-exact* reimplementations of
  Algorithms 2/4: identical per-element randomness (same hashes) => identical
  samples and counts (up to float32-vs-float64 rounding of the oracle).
* the fixed-k continuous sampler implements Algorithm 5 with the paper's own
  batched-eviction variant (§5.2); equality is distributional (Thm 5.2 count
  law + unbiased estimates), not per-run.
* the 2-pass sampler is exact bottom-k by seed (merging bottom-k summaries is
  lossless, §3.1) + exact pass-2 weights.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing as H
from .samplers import (
    SALT_BUCKET,
    SALT_ELEM,
    SALT_EVICT_R,
    SALT_EVICT_U,
    SALT_KEYBASE,
    SALT_SHARD,
    SampleResult,
)
from .segments import (
    EMPTY,
    ChunkOrder,
    bottom_k_by,
    chunk_order,
    compact_valid,
    is_empty,
    is_live,
    kth_smallest,
    merge_sorted_runs_gather,
    normalize_keys,
    searchsorted,
    scatter_unique,
    segment_ids,
    sort_by_key,
)

INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# Element scoring (jnp; mirrors samplers.*_np)
# ---------------------------------------------------------------------------


def keybase(keys, l, salt):
    u = H.uniform01(H.hash_combine(keys, jnp.uint32(SALT_KEYBASE), jnp.uint32(salt)))
    return u / jnp.float32(l)


def elem_uniform(eids, salt):
    return H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_ELEM), jnp.uint32(salt)))


def shard_eids(shard_no, idx):
    """Element ids for positions ``idx`` of shard/host ``shard_no``.

    Hash-derived, so ids from distinct shards never systematically alias —
    the arithmetic form ``shard_no * n + idx`` overflows int32 once
    P*n > 2^31 and silently reuses the same element randomness on different
    shards.  Downstream hashing casts to uint32, so the int32 bit pattern
    returned here matches samplers.shard_eids_np exactly.
    """
    return H.hash_combine(jnp.uint32(SALT_SHARD), shard_no, idx).astype(jnp.int32)


def element_scores(kind: str, keys, eids, weights, l, salt):
    """ElementScore(h) for each scheme; EMPTY-keyed elements get +inf."""
    if kind == "distinct":
        s = H.uniform01(H.hash_combine(keys, jnp.uint32(salt)))
    elif kind == "sh":
        s = elem_uniform(eids, salt)
    elif kind == "discrete":
        u = H.uniform01(H.hash_combine(eids, jnp.uint32(SALT_BUCKET), jnp.uint32(salt)))
        bucket = jnp.minimum((u * l).astype(jnp.int32), (jnp.float32(l) - 1).astype(jnp.int32))
        s = H.uniform01(H.hash_combine(keys, bucket, jnp.uint32(salt)))
    elif kind == "continuous":
        u = elem_uniform(eids, salt)
        v = -jnp.log1p(-u) / weights
        kb = keybase(keys, l, salt)
        s = jnp.where(v <= 1.0 / l, kb, v)
    else:
        raise ValueError(kind)
    return jnp.where(is_empty(keys), INF, s.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Per-chunk aggregation
# ---------------------------------------------------------------------------


class ChunkAgg(NamedTuple):
    ukeys: jax.Array      # [C] unique keys (EMPTY padded)
    w_total: jax.Array    # [C] total chunk weight per key
    entered: jax.Array    # [C] bool: an entry event occurred in this chunk
    contrib: jax.Array    # [C] count contribution from entry onward
    kb: jax.Array         # [C] KeyBase(x) (continuous) or min score (others)
    min_score: jax.Array  # [C] min element score (for seed/bottom-k schemes)


def _aggregate_ordered(order: ChunkOrder, weights, entry, at_entry_count,
                       scores, kb_elem) -> ChunkAgg:
    """Shared segment machinery on a precomputed chunk sort (``ChunkOrder``).

    ``entry``: per-element entry-event flag; ``at_entry_count``: count value
    contributed by the entry element itself (w - Delta for continuous, 1 for
    discrete); elements after the first entry contribute their full weight.
    All per-element arrays arrive in *stream order*; the shared permutation
    gathers them into key order (O(C) gathers — the sort itself was paid once
    per chunk, not once per lane) and the reduction proper is shared with the
    pre-ordered path below.  Bit-identical to sorting inline.
    """
    p = order.perm
    return _aggregate_preordered(
        order._replace(ws=weights[p]), entry[p], at_entry_count[p],
        scores[p], kb_elem[p])


def _aggregate(keys, weights, entry, at_entry_count, scores, kb_elem,
               order: ChunkOrder | None = None):
    """Group a chunk by key and reduce (sorts inline unless ``order`` given)."""
    if order is None:
        order = chunk_order(keys)
    return _aggregate_ordered(order, weights, entry, at_entry_count, scores, kb_elem)


def _aggregate_preordered(order: ChunkOrder, entry, at_entry_count, scores,
                          kb_elem) -> ChunkAgg:
    """``_aggregate_ordered`` when the per-element columns are ALREADY in key
    order — i.e. they were computed on the pre-gathered ``ChunkOrder`` view
    (``order.ks/eids/ws``), so the per-lane gathers vanish entirely.

    Bit-identical to ``_aggregate_ordered`` on the stream-order columns:
    element scoring is elementwise in (key, eid, weight), hence permutation-
    covariant, so the segment reductions receive exactly the same values in
    exactly the same (sorted) positions.
    """
    C = order.ks.shape[0]
    ks, seg, ws = order.ks, order.seg, order.ws
    es, aec = entry, at_entry_count
    sc, kbe = scores, kb_elem
    idx = jnp.arange(C)
    entry_idx = jnp.where(es, idx, C)
    first_entry = jax.ops.segment_min(entry_idx, seg, num_segments=C)
    fe = first_entry[seg]
    after = idx > fe
    at = (idx == fe) & es
    contrib_elem = jnp.where(after, ws, 0.0) + jnp.where(at, aec, 0.0)
    live = is_live(ks)
    w_live = jnp.where(live, ws, 0.0)
    contrib = jax.ops.segment_sum(jnp.where(live, contrib_elem, 0.0), seg, num_segments=C)
    w_total = jax.ops.segment_sum(w_live, seg, num_segments=C)
    entered = jax.ops.segment_max(jnp.where(live, es, False).astype(jnp.int32), seg, num_segments=C) > 0
    min_score = jax.ops.segment_min(jnp.where(live, sc, INF), seg, num_segments=C)
    kb_min = jax.ops.segment_min(jnp.where(live, kbe, INF), seg, num_segments=C)
    return ChunkAgg(
        ukeys=order.ukeys,
        w_total=w_total,
        entered=entered,
        contrib=contrib,
        kb=kb_min,
        min_score=min_score,
    )


def _aggregate_ref(keys, weights, entry, at_entry_count, scores, kb_elem):
    """The pre-ChunkOrder aggregate, verbatim (inline ``sort_by_key`` of the
    payload columns) — the bit-identity oracle for ``_aggregate_ordered``,
    used only by the reference chunk step.  Not on any production path."""
    C = keys.shape[0]
    ks, (ws, es, aec, sc, kbe, _pos) = sort_by_key(
        keys, weights, entry, at_entry_count, scores, kb_elem, jnp.arange(C)
    )
    seg, _ = segment_ids(ks)
    idx = jnp.arange(C)
    entry_idx = jnp.where(es, idx, C)
    first_entry = jax.ops.segment_min(entry_idx, seg, num_segments=C)
    fe = first_entry[seg]
    after = idx > fe
    at = (idx == fe) & es
    contrib_elem = jnp.where(after, ws, 0.0) + jnp.where(at, aec, 0.0)
    live = is_live(ks)
    w_live = jnp.where(live, ws, 0.0)
    contrib = jax.ops.segment_sum(jnp.where(live, contrib_elem, 0.0), seg, num_segments=C)
    w_total = jax.ops.segment_sum(w_live, seg, num_segments=C)
    entered = jax.ops.segment_max(jnp.where(live, es, False).astype(jnp.int32), seg, num_segments=C) > 0
    min_score = jax.ops.segment_min(jnp.where(live, sc, INF), seg, num_segments=C)
    kb_min = jax.ops.segment_min(jnp.where(live, kbe, INF), seg, num_segments=C)
    ukeys, _ = scatter_unique(ks, seg, 0.0)
    return ChunkAgg(ukeys=ukeys, w_total=w_total, entered=entered,
                    contrib=contrib, kb=kb_min, min_score=min_score)


def _continuous_entry(keys, weights, eids, tau, l, salt):
    """Per-element entry/at-entry-count/score/kb of Algorithm 4 under the
    *current* threshold tau (shared by the fast and reference aggregates)."""
    u = elem_uniform(eids, salt)
    rate = jnp.maximum(jnp.float32(1.0 / l), tau)
    delta = -jnp.log1p(-u) / rate  # rate=inf (tau=inf) -> delta=0
    kb = keybase(keys, l, salt)
    ok_regime = jnp.where(tau * l > 1.0, True, kb < tau)
    entry = (delta < weights) & ok_regime & is_live(keys)
    v = -jnp.log1p(-u) / weights
    scores = jnp.where(v <= 1.0 / l, kb, v)
    scores = jnp.where(is_empty(keys), INF, scores)
    return entry, weights - delta, scores, kb


def aggregate_continuous(keys, weights, eids, tau, l, salt,
                         order: ChunkOrder | None = None) -> ChunkAgg:
    """Entry semantics of Algorithm 4 under the *current* threshold tau.

    When ``order`` carries the pre-gathered view (or is omitted, in which
    case it is built with one), the elements are scored directly in key order
    and reduced in the same pass — the score-in-key-order path (DESIGN.md
    §9), bit-identical to score-then-gather by permutation covariance.  An
    ``order`` without the view falls back to gathering the scored columns.
    """
    if order is None:
        order = chunk_order(keys, eids, weights)
    if order.eids is not None:
        entry, aec, scores, kb = _continuous_entry(
            order.ks, order.ws, order.eids, tau, l, salt)
        return _aggregate_preordered(order, entry, aec, scores, kb)
    entry, aec, scores, kb = _continuous_entry(keys, weights, eids, tau, l, salt)
    return _aggregate(keys, weights, entry, aec, scores, kb, order)


def aggregate_continuous_ref(keys, weights, eids, tau, l, salt) -> ChunkAgg:
    """``aggregate_continuous`` through the verbatim pre-ChunkOrder reducer
    (bit-identity oracle; tests only)."""
    entry, aec, scores, kb = _continuous_entry(keys, weights, eids, tau, l, salt)
    return _aggregate_ref(keys, weights, entry, aec, scores, kb)


def aggregate_discrete(keys, weights, eids, tau, kind, l, salt,
                       order: ChunkOrder | None = None) -> ChunkAgg:
    """Entry semantics of Algorithm 2: first element whose score < tau.

    Scores in key order when the pre-gathered view is available (every
    ``element_scores`` kind is elementwise, hence permutation-covariant);
    see ``aggregate_continuous``.
    """
    if order is None:
        order = chunk_order(keys, eids, weights)
    if order.eids is not None:
        scores = element_scores(kind, order.ks, order.eids, order.ws, l, salt)
        entry = (scores < tau) & is_live(order.ks)
        return _aggregate_preordered(order, entry, order.ws, scores, scores)
    scores = element_scores(kind, keys, eids, weights, l, salt)
    entry = (scores < tau) & is_live(keys)
    return _aggregate(keys, weights, entry, weights, scores, scores, order)


def aggregate_discrete_ref(keys, weights, eids, tau, kind, l, salt) -> ChunkAgg:
    """``aggregate_discrete`` through the verbatim pre-ChunkOrder reducer
    (bit-identity oracle; tests only)."""
    scores = element_scores(kind, keys, eids, weights, l, salt)
    entry = (scores < tau) & is_live(keys)
    return _aggregate_ref(keys, weights, entry, weights, scores, scores)


def aggregate_continuous_scored(keys, weights, score, delta, entry, kb,
                                order: ChunkOrder | None = None) -> ChunkAgg:
    """``aggregate_continuous`` on precomputed per-element scoring outputs.

    ``score/delta/entry`` are exactly what the fused capscore kernel emits
    (kernels/capscore), so the multi-l update can score every l lane in one
    device pass and feed each lane through the same segment machinery.  Pass
    the chunk's shared ``order`` so the L lanes reuse one key sort.
    """
    entry = entry.astype(bool) & is_live(keys)
    score = jnp.where(is_empty(keys), INF, score)
    return _aggregate(keys, weights, entry, weights - delta, score, kb, order)


# ---------------------------------------------------------------------------
# State merge (state table + chunk aggregates -> combined table)
# ---------------------------------------------------------------------------


class TableState(NamedTuple):
    keys: jax.Array    # [cap]
    counts: jax.Array  # [cap] float32
    kb: jax.Array      # [cap] KeyBase / min-score payload
    seed: jax.Array    # [cap] running min element score (the key's bottom-k
    #                    seed over observed elements) — the coordinated-merge
    #                    handle of core.distributed.merge_fixed_k
    tau: jax.Array     # scalar float32
    step: jax.Array    # scalar int32 (eviction round counter)
    overflow: jax.Array  # scalar int32 (fixed-tau capacity overflow count)


def _merge_reduce(ks, st, cn, wt, en, ct, kb, sd):
    """Shared tail of both table merges: segment-reduce the key-ordered union
    columns and compact the combined entries to the front.

    cached key:   count += chunk total weight (Alg 2/4/5 cached branch)
    new key:      insert iff an entry event happened, count = contrib
    seed:         running min element score (both branches)
    """
    N = ks.shape[0]
    seg, _ = segment_ids(ks)
    present = jax.ops.segment_max(st.astype(jnp.int32), seg, num_segments=N) > 0
    s_count = jax.ops.segment_sum(cn, seg, num_segments=N)
    c_w = jax.ops.segment_sum(wt, seg, num_segments=N)
    c_ent = jax.ops.segment_max(en.astype(jnp.int32), seg, num_segments=N) > 0
    c_ctr = jax.ops.segment_sum(ct, seg, num_segments=N)
    kb_m = jax.ops.segment_min(kb, seg, num_segments=N)
    sd_m = jax.ops.segment_min(sd, seg, num_segments=N)
    ukeys, _ = scatter_unique(ks, seg, 0.0)

    new_count = jnp.where(present, s_count + c_w, jnp.where(c_ent, c_ctr, 0.0))
    valid = is_live(ukeys) & (present | c_ent)
    keys_c, counts_c, kb_c, seed_c = compact_valid(
        valid, ukeys, new_count, kb_m, sd_m,
        fills=(EMPTY, 0.0, jnp.float32(jnp.inf), jnp.float32(jnp.inf)),
    )
    n_valid = jnp.sum(valid.astype(jnp.int32))
    return keys_c, counts_c, kb_c, seed_c, n_valid


def _merge_table(state: TableState, agg: ChunkAgg):
    """Combine the cached table with chunk aggregates (reference form).

    Concatenates table + aggregate and re-sorts all ``cap + C`` entries per
    call.  Makes no assumption about the table's key order, so it remains the
    bit-identity oracle for ``_merge_table_sorted`` (tests/test_ingest_order)
    and the baseline of the ingest benchmark; the hot paths use the
    sorted-runs form below.
    """
    cap = state.keys.shape[0]
    C = agg.ukeys.shape[0]
    keys2 = jnp.concatenate([state.keys, agg.ukeys])
    is_state = jnp.concatenate([is_live(state.keys), jnp.zeros((C,), bool)])
    cnt2 = jnp.concatenate([state.counts, jnp.zeros((C,), state.counts.dtype)])
    wtot2 = jnp.concatenate([jnp.zeros((cap,)), agg.w_total])
    ent2 = jnp.concatenate([jnp.zeros((cap,), bool), agg.entered])
    ctr2 = jnp.concatenate([jnp.zeros((cap,)), agg.contrib])
    kb2 = jnp.concatenate([state.kb, agg.kb])
    sd2 = jnp.concatenate([state.seed, agg.min_score])

    ks, (st, cn, wt, en, ct, kb, sd) = sort_by_key(
        keys2, is_state, cnt2, wtot2, ent2, ctr2, kb2, sd2
    )
    return _merge_reduce(ks, st, cn, wt, en, ct, kb, sd)


def _merge_table_sorted(state: TableState, agg: ChunkAgg):
    """``_merge_table`` as a pairwise two-sorted-runs merge — no sort, no
    segment ops.

    Requires the **sorted-table invariant**: ``state.keys`` ascending, unique,
    with all EMPTY slots compacted to the back (established at init, preserved
    by every step function below), and ``agg.ukeys`` ascending unique
    EMPTY-padded (which ``scatter_unique`` guarantees by construction).

    Because BOTH runs hold unique keys, every "segment" of the merged union
    has at most two members — one table entry, one chunk aggregate — so the
    general segment-reduce machinery of ``_merge_reduce`` collapses to a
    gather-and-combine: match the runs against each other with two
    ``searchsorted`` rank passes, add/min the matched payloads directly,
    compact the genuinely new keys, and scatter both runs into their merged
    positions.  O(N) gathers/scatters + O(C log cap) binary searches per lane
    per chunk, versus the reference's O(N log N) sort + seven scatter-based
    segment reductions.  Bit-identical to ``_merge_table`` (the reductions it
    replaces touch at most two values per key: float adds against 0.0 and
    mins against inf are exact).
    """
    cap = state.keys.shape[0]
    C = agg.ukeys.shape[0]
    inf = jnp.float32(jnp.inf)
    a_keys, b_keys = state.keys, agg.ukeys
    a_live = is_live(a_keys)
    b_live = is_live(b_keys)

    # table entries matched against the chunk aggregate (cached-key branch:
    # count += chunk total weight, kb/seed min with the chunk's)
    loc_ab = jnp.clip(searchsorted(b_keys, a_keys), 0, C - 1)
    hit_a = (b_keys[loc_ab] == a_keys) & a_live
    counts_a = state.counts + jnp.where(hit_a, agg.w_total[loc_ab], 0.0)
    kb_a = jnp.minimum(state.kb, jnp.where(hit_a, agg.kb[loc_ab], inf))
    sd_a = jnp.minimum(state.seed, jnp.where(hit_a, agg.min_score[loc_ab], inf))

    # chunk keys not in the table: inserted iff an entry event happened
    loc_ba = jnp.clip(searchsorted(a_keys, b_keys), 0, cap - 1)
    in_table = a_keys[loc_ba] == b_keys
    new = b_live & ~in_table & agg.entered
    newk, newcnt, newkb, newsd = compact_valid(
        new, b_keys, agg.contrib, agg.kb, agg.min_score,
        fills=(EMPTY, 0.0, inf, inf))

    # interleave the (still sorted) table run with the compacted new keys —
    # gather form: one searchsorted, then a cheap gather per payload column.
    # Only the first ``cap`` merged positions are built: every caller slices
    # the merge to table capacity anyway (fixed-k capacity never overflows by
    # construction; fixed-tau counts the overflow separately from n_valid).
    from_b, ia, ib = merge_sorted_runs_gather(a_keys, newk, out_len=cap)
    pick = lambda av, bv: jnp.where(from_b, bv[ib], av[ia])
    keys_c = pick(a_keys, newk)
    counts_c = pick(counts_a, newcnt)
    kb_c = pick(kb_a, newkb)
    sd_c = pick(sd_a, newsd)
    n_valid = (jnp.sum(a_live.astype(jnp.int32))
               + jnp.sum(new.astype(jnp.int32)))
    return keys_c, counts_c, kb_c, sd_c, n_valid


# ---------------------------------------------------------------------------
# Single-chunk streaming steps (shared by the scan bodies below and by the
# incremental state API in core/incremental.py — same function, same jit)
# ---------------------------------------------------------------------------


def init_table(capacity: int, tau=jnp.inf) -> TableState:
    """Fresh O(capacity) sampler table (the scan carry / streaming state)."""
    return TableState(
        keys=jnp.full((capacity,), EMPTY, dtype=jnp.int32),
        counts=jnp.zeros((capacity,), jnp.float32),
        kb=jnp.full((capacity,), jnp.inf, jnp.float32),
        seed=jnp.full((capacity,), jnp.inf, jnp.float32),
        tau=jnp.float32(tau),
        step=jnp.int32(0),
        overflow=jnp.int32(0),
    )


def fixed_tau_step(state: TableState, keys, weights, eids, l, salt, *, kind,
                   order: ChunkOrder | None = None) -> TableState:
    """Advance a fixed-threshold sampler (Alg 2/4) by one chunk of elements."""
    capacity = state.keys.shape[0]
    if order is None:
        order = chunk_order(keys, eids, weights)
    if kind == "continuous":
        agg = aggregate_continuous(keys, weights, eids, state.tau, l, salt, order)
    else:
        agg = aggregate_discrete(keys, weights, eids, state.tau, kind, l, salt, order)
    keys_c, counts_c, kb_c, seed_c, n_valid = _merge_table_sorted(state, agg)
    over = state.overflow + jnp.maximum(n_valid - capacity, 0)
    return TableState(keys_c[:capacity], counts_c[:capacity], kb_c[:capacity],
                      seed_c[:capacity], state.tau, state.step + 1, over)


def fixed_k_merge(state: TableState, agg: ChunkAgg) -> TableState:
    """Fold a chunk aggregate into a fixed-k table WITHOUT evicting.

    Increments the eviction-round/step counter; the caller is responsible for
    running ``evict_table`` before the table's capacity can overflow (the
    incremental spec sizes capacity as ``k + evict_every * chunk`` for exactly
    this reason).  Preserves the sorted-table invariant.
    """
    capacity = state.keys.shape[0]
    keys_c, counts_c, kb_c, seed_c, _ = _merge_table_sorted(state, agg)
    return TableState(keys_c[:capacity], counts_c[:capacity], kb_c[:capacity],
                      seed_c[:capacity], state.tau, state.step + 1, state.overflow)


def evict_table(table: TableState, *, k, l, salt, max_evict=None,
                select: str = "auto") -> TableState:
    """Batched eviction of a merged table back down to <= k valid keys, then
    re-compaction so the sorted-table invariant survives the EMPTY holes the
    eviction punches.  ``max_evict`` bounds the eviction count and ``select``
    the threshold-selection lowering (see ``_evict_to_k``); the round number
    is the table's step counter."""
    keys_e, counts_e, kb_e, seed_e, tau_e = _evict_to_k(
        table.keys, table.counts, table.kb, table.seed, table.tau, k, l, salt,
        table.step, max_evict=max_evict, select=select)
    keys_c, counts_c, kb_c, seed_c = compact_valid(
        is_live(keys_e), keys_e, counts_e, kb_e, seed_e,
        fills=(EMPTY, 0.0, jnp.float32(jnp.inf), jnp.float32(jnp.inf)),
    )
    return TableState(keys_c, counts_c, kb_c, seed_c, tau_e, table.step,
                      table.overflow)


def fixed_k_step(state: TableState, keys, weights, eids, l, salt, *, k,
                 order: ChunkOrder | None = None) -> TableState:
    """Advance a fixed-k continuous sampler (Alg 5) by one chunk: aggregate
    under the current threshold, merge, batch-evict back down to <= k.

    Precondition (holds by construction inside the scan loops): the incoming
    table carries <= k valid keys, so at most ``chunk`` keys can be evicted.
    """
    if order is None:
        order = chunk_order(keys, eids, weights)
    agg = aggregate_continuous(keys, weights, eids, state.tau, l, salt, order)
    merged = fixed_k_merge(state, agg)
    return evict_table(merged, k=k, l=l, salt=salt, max_evict=keys.shape[0])


def fixed_k_step_scored(state: TableState, keys, weights, score, delta, entry, kb,
                        *, k, l, salt, order: ChunkOrder | None = None) -> TableState:
    """``fixed_k_step`` on precomputed capscore outputs (multi-l fused path)."""
    if order is None:
        order = chunk_order(keys)
    agg = aggregate_continuous_scored(keys, weights, score, delta, entry, kb, order)
    merged = fixed_k_merge(state, agg)
    return evict_table(merged, k=k, l=l, salt=salt, max_evict=keys.shape[0])


def fixed_k_step_scored_ref(state: TableState, keys, weights, score, delta,
                            entry, kb, *, k, l, salt) -> TableState:
    """The pre-single-sort chunk step, kept verbatim as the bit-identity
    oracle: per-lane inline key sort (``_aggregate_ref``), concat-and-re-sort
    table merge, and a full descending sort in the eviction.  Used by
    tests/test_ingest_order and the `reference` path of the ingest benchmark
    — not by production."""
    capacity = state.keys.shape[0]
    e = entry.astype(bool) & is_live(keys)
    s = jnp.where(is_empty(keys), INF, score)
    agg = _aggregate_ref(keys, weights, e, weights - delta, s, kb)
    keys_c, counts_c, kb_c, seed_c, _ = _merge_table(state, agg)
    keys_e, counts_e, kb_e, seed_e, tau_e = _evict_to_k_ref(
        keys_c[:capacity], counts_c[:capacity], kb_c[:capacity], seed_c[:capacity],
        state.tau, k, l, salt, state.step + 1,
    )
    return TableState(keys_e, counts_e, kb_e, seed_e, tau_e, state.step + 1,
                      state.overflow)


def chunk_bottomk_summary(keys, eids, weights, l, salt, *, kind):
    """Per-chunk (unique key, min element score) summary for pass-1 bottom-k."""
    chunk = keys.shape[0]
    scores = element_scores(kind, keys, eids, weights, l, salt)
    ks, (sc,) = sort_by_key(keys, scores)
    seg, _ = segment_ids(ks)
    mins = jax.ops.segment_min(jnp.where(is_live(ks), sc, INF), seg, num_segments=chunk)
    ukeys, _ = scatter_unique(ks, seg, 0.0)
    return ukeys, jnp.where(is_live(ukeys), mins, INF)


def merge_bottomk_summary(skeys, sseeds, ukeys, useeds, cap):
    """Merge two (key, seed) summaries: min-seed per duplicate key, bottom-cap.

    Lossless for the bottom-cap of the union (paper §3.1) — the building
    block of pass-1 chunk accumulation, the incremental per-lane summaries
    and every cross-shard merge in core.distributed.
    """
    keys2 = jnp.concatenate([skeys, ukeys])
    seeds2 = jnp.concatenate([sseeds, useeds])
    ks2, (sd2,) = sort_by_key(keys2, seeds2)
    seg2, _ = segment_ids(ks2)
    N = ks2.shape[0]
    sd_m = jax.ops.segment_min(sd2, seg2, num_segments=N)
    uk2, _ = scatter_unique(ks2, seg2, 0.0)
    sd_m = jnp.where(is_live(uk2), sd_m, INF)
    sd_k, uk_k = bottom_k_by(sd_m, cap, uk2, fills=(EMPTY,))
    return uk_k, sd_k


def pass1_step(carry, keys, weights, eids, l, salt, *, kind, cap):
    """Advance a bottom-k-by-seed summary (Alg 1 pass I) by one chunk."""
    skeys, sseeds = carry
    ukeys, mins = chunk_bottomk_summary(keys, eids, weights, l, salt, kind=kind)
    return merge_bottomk_summary(skeys, sseeds, ukeys, mins, cap)


def chunk_bottomk_summary_scored(keys, scores, order: ChunkOrder | None = None):
    """Per-lane (unique key, min element score) chunk summaries from
    precomputed multi-lane scores [L, C] (the fused capscore pass-1 path).

    One sort of the chunk by key is shared by all lanes (pass the chunk's
    ``ChunkOrder`` to share it with the sketch advance too); the per-lane
    work is a single segment_min.  Returns (ukeys [C], mins [L, C]).
    """
    C = keys.shape[0]
    if order is None:
        order = chunk_order(keys)
    live = is_live(order.ks)
    mins = jax.vmap(
        lambda s: jax.ops.segment_min(jnp.where(live, s[order.perm], INF),
                                      order.seg, num_segments=C)
    )(scores)
    return order.ukeys, jnp.where(is_live(order.ukeys), mins, INF)


def pass1_step_multi(carry, keys, scores, *, cap, order: ChunkOrder | None = None):
    """Advance stacked per-lane bottom-cap summaries ([L, cap] keys/seeds) by
    one chunk whose multi-lane scores were already computed (capscore_multi)."""
    skeys, sseeds = carry
    ukeys, mins = chunk_bottomk_summary_scored(keys, scores, order)
    return jax.vmap(
        lambda sk, ss, mn: merge_bottomk_summary(sk, ss, ukeys, mn, cap)
    )(skeys, sseeds, mins)


# -- key-sorted summary carry: the in-scan form of the bottom-cap summaries --
#
# ``merge_bottomk_summary`` pays an argsort of (cap + C) keys plus three
# scatter-shaped segment ops and a TopK per lane per chunk — the single most
# expensive stage of the multi-lane ingest step on CPU.  Inside a scan the
# summary can instead be carried KEY-sorted (ascending, unique, EMPTY last —
# the same invariant as the sampler table), which turns the whole advance
# into searchsorted + gather/cumsum primitives:
#
#   * duplicate keys min-merge by two searchsorted rank passes (pairwise,
#     since both runs are unique — exactly the _merge_table_sorted trick);
#   * the bottom-cap truncation selects the cap-th smallest seed with a
#     plain VALUE sort (no TopK, no argsort) and compacts survivors in key
#     order.
#
# Bit-identity with the seed-sorted iterated form (property-tested): bottom-k
# sketches are exactly composable (paper §3.1) — any entry dropped by a
# truncation can never re-enter the final bottom-cap, and a surviving key's
# stored seed is its true min — so the final bottom-cap (set, seeds) is
# invariant to the carry layout.  Ties at the truncation threshold break the
# same way too: ``bottom_k_by``'s top_k prefers lower indices, and its input
# array is key-ascending, so tied entries survive smallest-key-first — which
# is precisely what compacting a key-sorted carry keeps.  Converting the
# final carry through ``summary_from_keysorted`` therefore reproduces the
# reference arrays bit for bit (same multiset, same seed-ascending order,
# same index tie-break).


def summary_to_keysorted(skeys, sseeds):
    """Re-lay a bottom-cap summary (seed-sorted, the state/checkpoint form)
    as the key-sorted scan carry: ascending unique keys, EMPTY (+inf) last."""
    # reprolint: disable=RPL002 -- once-per-restore boundary conversion (state
    # checkpoint -> scan carry), not on the per-chunk path; a full argsort is fine
    o = jnp.argsort(skeys, stable=True)
    return skeys[o], sseeds[o]


def summary_from_keysorted(skeys, sseeds, cap):
    """Back to the state/checkpoint layout: seed-ascending via the same
    ``bottom_k_by`` selection every ``merge_bottomk_summary`` call ends with
    (a no-op selection here — the carry already holds <= cap entries)."""
    sd_k, uk_k = bottom_k_by(sseeds, cap, skeys, fills=(EMPTY,))
    return uk_k, sd_k


def pass1_fold_keysorted(skeys, sseeds, ukeys, mins, cap):
    """One chunk of bottom-cap summary advance on the key-sorted carry.

    ``skeys``/``sseeds``: the [cap] key-sorted carry.  ``ukeys``/``mins``:
    the chunk's unique keys (ascending, EMPTY-padded — ``ChunkOrder.ukeys``)
    and their per-key min element scores (e.g. the fused aggregate's
    ``min_score`` column, which equals the pass-1 chunk summary because
    element scores are tau-independent).  No sort of the union, no TopK, no
    segment ops — see the block comment above for the bit-identity argument.
    """
    C = ukeys.shape[0]
    cap_s = skeys.shape[0]
    a_keys, a_live = skeys, is_live(skeys)
    b_keys, b_live = ukeys, is_live(ukeys)

    # rank passes (kept UNclipped: the raw rank is also the count of
    # other-run keys below, which the position formulas below need even at
    # the array-end edge)
    loc_ab_raw = searchsorted(b_keys, a_keys)
    loc_ba_raw = searchsorted(a_keys, b_keys)

    # carried keys matched against the chunk summary: seed = min of both
    loc_ab = jnp.minimum(loc_ab_raw, C - 1)
    hit_a = (b_keys[loc_ab] == a_keys) & a_live
    sd_a = jnp.minimum(sseeds, jnp.where(hit_a, mins[loc_ab], INF))

    # chunk keys not yet carried: candidate insertions
    loc_ba = jnp.minimum(loc_ba_raw, cap_s - 1)
    new = b_live & ~(a_keys[loc_ba] == b_keys)

    # bottom-cap threshold: cap-th smallest seed of the union, by rank
    # selection (``kth_smallest`` — no sort, no argsort, no TopK, all of
    # which XLA:CPU lowers as scalar comparator loops)
    sd_a_live = jnp.where(a_live, sd_a, INF)
    sd_b_new = jnp.where(new, mins, INF)
    thr = kth_smallest(jnp.concatenate([sd_a_live, sd_b_new]), cap - 1)

    # selection must match ``bottom_k_by`` exactly under seed TIES at thr:
    # every seed strictly below thr survives (value order dominates), and
    # the remaining quota goes to thr-tied entries smallest-key-first
    # (top_k's lowest-index tie-break on a key-ascending array).  The tied
    # key-order rank is assembled from the same cross-run prefix counts as
    # the merge positions below.
    below_a = a_live & (sd_a < thr)
    below_b = new & (mins < thr)
    tied_a = a_live & (sd_a == thr)
    tied_b = new & (mins == thr)
    quota = cap - (jnp.sum(below_a.astype(jnp.int32))
                   + jnp.sum(below_b.astype(jnp.int32)))
    cst_a = jnp.cumsum(tied_a)
    cst_b = jnp.cumsum(tied_b)
    tb_lt = jnp.where(loc_ab_raw > 0, cst_b[jnp.maximum(loc_ab_raw - 1, 0)], 0)
    ta_lt = jnp.where(loc_ba_raw > 0, cst_a[jnp.maximum(loc_ba_raw - 1, 0)], 0)
    keep_a = below_a | (tied_a & (cst_a - 1 + tb_lt < quota))
    keep_b = below_b | (tied_b & (cst_b - 1 + ta_lt < quota))

    # every survivor's merged position is already determined by the ranks in
    # hand (kept keys of the two runs are disjoint and each run is sorted):
    #   pos = (kept same-run entries before it) + (kept other-run keys below
    #   it, read off the loc_ab/loc_ba ranks) — so the merged carry
    # assembles with two direct scatters per column, no compaction passes
    # and no interleave rank pass.  Overflow survivors (the > cap tail that
    # only a seed tie at thr can produce) land on the sacrificial slot.
    csa = jnp.cumsum(keep_a)
    csb = jnp.cumsum(keep_b)
    nb_lt = jnp.where(loc_ab_raw > 0, csb[jnp.maximum(loc_ab_raw - 1, 0)], 0)
    na_lt = jnp.where(loc_ba_raw > 0, csa[jnp.maximum(loc_ba_raw - 1, 0)], 0)
    pos_a = jnp.where(keep_a, csa - 1 + nb_lt, cap_s)
    pos_b = jnp.where(keep_b, csb - 1 + na_lt, cap_s)
    pos_a = jnp.minimum(pos_a, cap_s)
    pos_b = jnp.minimum(pos_b, cap_s)
    kk = (jnp.full((cap_s + 1,), EMPTY, a_keys.dtype)
          .at[pos_a].set(a_keys).at[pos_b].set(b_keys)[:cap_s])
    ss = (jnp.full((cap_s + 1,), INF, sd_a.dtype)
          .at[pos_a].set(sd_a).at[pos_b].set(mins)[:cap_s])
    return kk, ss


# ---------------------------------------------------------------------------
# Fixed-threshold samplers (exact Algorithm 2 / 4)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("kind", "capacity", "chunk"))
def _run_fixed_tau(keys, weights, l, salt, tau, *, kind, capacity, chunk):
    n = keys.shape[0]
    n_chunks = n // chunk
    keys = keys.reshape(n_chunks, chunk)
    weights = weights.reshape(n_chunks, chunk)
    eids = jnp.arange(n, dtype=jnp.int32).reshape(n_chunks, chunk)

    init = init_table(capacity, tau)

    def body(state: TableState, xs):
        ck, cw, ce = xs
        return fixed_tau_step(state, ck, cw, ce, l, salt, kind=kind), None

    state, _ = jax.lax.scan(body, init, (keys, weights, eids))
    return state


def sample_fixed_tau(keys, weights=None, *, tau, l, kind="continuous", salt=0,
                     chunk=2048, capacity=8192) -> SampleResult:
    keys, weights = _prep(keys, weights, chunk)
    st = _run_fixed_tau(keys, weights, jnp.float32(l), jnp.uint32(salt), jnp.float32(tau),
                        kind=kind, capacity=capacity, chunk=chunk)
    overflow = int(jax.device_get(st.overflow))
    if overflow > 0:
        raise RuntimeError(f"fixed-tau capacity overflow ({overflow}); raise capacity")
    return _to_result(st, l=l, kind=kind, tau=float(tau))


# ---------------------------------------------------------------------------
# Fixed-k continuous sampler (Algorithm 5, batched evictions)
# ---------------------------------------------------------------------------


def _evict_z(state_keys, counts, kb, tau, l, salt, round_no):
    """Per-key eviction race scores z (§5.2) + the pieces the survivor-count
    adjustment needs.  Shared by the top_k and reference eviction forms."""
    valid = is_live(state_keys)
    ux = H.uniform01(H.hash_combine(state_keys, jnp.uint32(SALT_EVICT_U),
                                    round_no.astype(jnp.uint32), jnp.uint32(salt)))
    rx = H.uniform01(H.hash_combine(state_keys, jnp.uint32(SALT_EVICT_R),
                                    round_no.astype(jnp.uint32), jnp.uint32(salt)))
    ex = -jnp.log1p(-rx)
    inv_l = jnp.float32(1.0 / l)
    safe_counts = jnp.maximum(counts, 1e-30)
    race = jnp.where(ex / safe_counts >= inv_l, ex / safe_counts, kb)
    seed_part = tau * ux  # tau=inf -> inf
    # Score-collapse correction (see samplers.py): entry branch threshold
    # becomes KeyBase(x) when the resampled entry score drops below 1/l.
    entry_thresh = jnp.where(seed_part >= inv_l, seed_part, kb)
    z_hi = jnp.minimum(entry_thresh, race)     # tau*l > 1 regime
    z_lo = kb                                  # tau*l <= 1 regime (distinct-like)
    z = jnp.where(tau * l > 1.0, z_hi, z_lo)
    z = jnp.where(valid, z, -INF)
    return valid, z, entry_thresh, ex, inv_l


def _evict_apply(state_keys, counts, kb, seed, tau, l, delta, tau_star,
                 valid, z, entry_thresh, ex, inv_l):
    """Apply an eviction threshold tau*: drop z >= tau*, adjust survivors."""
    evict = valid & (z >= tau_star) & (delta > 0)

    # survivor count adjustment (tau*l>1 regime only; see samplers.py notes)
    new_rate = jnp.maximum(inv_l, tau_star)
    guard = (entry_thresh >= tau_star) & (tau * l > 1.0)
    adj = counts - ex / new_rate
    counts = jnp.where(valid & ~evict & guard & (delta > 0), adj, counts)

    keys_o = jnp.where(evict, EMPTY, state_keys)
    counts_o = jnp.where(evict, 0.0, counts)
    kb_o = jnp.where(evict, INF, kb)
    seed_o = jnp.where(evict, INF, seed)
    tau_o = jnp.where(delta > 0, tau_star, tau)
    return keys_o, counts_o, kb_o, seed_o, tau_o


def _evict_to_k(state_keys, counts, kb, seed, tau, k, l, salt, round_no, *,
                max_evict: int | None = None, select: str = "auto"):
    """Batched eviction (§5.2): tau* = delta-th largest z; drop z >= tau*.

    Only the THRESHOLD is needed, so the selection is a pure lowering
    decision — every route returns the same value:

    * ``'topk'``: ``jax.lax.top_k`` over the ``max_evict`` largest z (native
      partial selection on TPU; valid whenever the caller can bound
      delta = n_valid - k — the chunk steps pass the chunk size, since a
      table that was <= k valid gains at most ``chunk`` keys per merge;
      ``max_evict=None`` keeps the full width, the cross-host merge path).
    * ``'rank'``: ``segments.kth_smallest`` bit-prefix rank selection — no
      sort at all.  XLA:CPU lowers both TopK and full sorts at ~250ns/elem
      (scalar comparator loops), which made threshold selection the
      hottest primitive of the whole ingest step; the rank select is ~15x
      cheaper there.
    * ``'auto'``: backend-derived at trace time (top_k on TPU, rank
      elsewhere).

    Bit-identical across routes and to the reference full descending sort:
    the delta-th largest z is the same multiset order statistic however it
    is found (tests/test_ingest_order.py pins all three).
    """
    n = state_keys.shape[0]
    valid, z, entry_thresh, ex, inv_l = _evict_z(
        state_keys, counts, kb, tau, l, salt, round_no)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    delta = jnp.maximum(n_valid - k, 0)
    if select == "auto":
        select = "topk" if jax.default_backend() == "tpu" else "rank"
    if select == "rank":
        # delta-th largest == (n - delta)-th smallest (0-indexed)
        z_sel = kth_smallest(z, jnp.clip(n - delta, 0, n - 1))
    else:
        top = n if max_evict is None else min(int(max_evict), n)
        # reprolint: disable=RPL002 -- select='topk' is the opt-in TPU-native
        # route; the XLA:CPU default is select='kth' via kth_smallest below
        z_top = jax.lax.top_k(z, top)[0]
        z_sel = z_top[jnp.maximum(delta - 1, 0)]
    tau_star = jnp.where(delta > 0, z_sel, tau)
    return _evict_apply(state_keys, counts, kb, seed, tau, l, delta, tau_star,
                        valid, z, entry_thresh, ex, inv_l)


def _evict_to_k_ref(state_keys, counts, kb, seed, tau, k, l, salt, round_no):
    """Reference eviction: full descending sort for tau* (the pre-top_k form,
    kept as the bit-identity oracle and benchmark baseline)."""
    valid, z, entry_thresh, ex, inv_l = _evict_z(
        state_keys, counts, kb, tau, l, salt, round_no)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    delta = jnp.maximum(n_valid - k, 0)
    # reprolint: disable=RPL002 -- verbatim pre-top_k oracle; the full sort IS
    # the reference semantics the fast path is bit-tested against
    z_desc = -jnp.sort(-z)
    tau_star = jnp.where(delta > 0, z_desc[jnp.maximum(delta - 1, 0)], tau)
    return _evict_apply(state_keys, counts, kb, seed, tau, l, delta, tau_star,
                        valid, z, entry_thresh, ex, inv_l)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def _run_fixed_k_continuous(keys, weights, l, salt, *, k, chunk):
    n = keys.shape[0]
    n_chunks = n // chunk
    capacity = k + chunk  # merge never overflows: <=k valid + <=chunk new
    keys = keys.reshape(n_chunks, chunk)
    weights = weights.reshape(n_chunks, chunk)
    eids = jnp.arange(n, dtype=jnp.int32).reshape(n_chunks, chunk)

    init = init_table(capacity)

    def body(state: TableState, xs):
        ck, cw, ce = xs
        return fixed_k_step(state, ck, cw, ce, l, salt, k=k), None

    state, _ = jax.lax.scan(body, init, (keys, weights, eids))
    return state


def sample_fixed_k(keys, weights=None, *, k, l, salt=0, chunk=2048) -> SampleResult:
    """1-pass fixed-size continuous SH_l sample (the paper's recommended scheme)."""
    keys, weights = _prep(keys, weights, chunk)
    st = _run_fixed_k_continuous(keys, weights, jnp.float32(l), jnp.uint32(salt), k=k, chunk=chunk)
    return _to_result(st, l=l, kind="continuous", tau=float(jax.device_get(st.tau)))


# ---------------------------------------------------------------------------
# 2-pass sampler (Algorithm 1): exact bottom-k by seed + exact weights
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("kind", "k", "chunk"))
def _run_pass1(keys, weights, l, salt, *, kind, k, chunk):
    n = keys.shape[0]
    n_chunks = n // chunk
    keys = keys.reshape(n_chunks, chunk)
    weights = weights.reshape(n_chunks, chunk)
    eids = jnp.arange(n, dtype=jnp.int32).reshape(n_chunks, chunk)
    cap = k + 1  # bottom-(k+1) is mergeable and yields tau exactly

    init_keys = jnp.full((cap,), EMPTY, dtype=jnp.int32)
    init_seeds = jnp.full((cap,), jnp.inf, jnp.float32)

    def body(carry, xs):
        ck, cw, ce = xs
        return pass1_step(carry, ck, cw, ce, l, salt, kind=kind, cap=cap), None

    (skeys, sseeds), _ = jax.lax.scan(body, (init_keys, init_seeds), (keys, weights, eids))
    return skeys, sseeds


@functools.partial(jax.jit, static_argnames=("chunk",))
def _run_pass2(keys, weights, sampled_sorted, *, chunk):
    n = keys.shape[0]
    n_chunks = n // chunk
    keys = keys.reshape(n_chunks, chunk)
    weights = weights.reshape(n_chunks, chunk)
    k = sampled_sorted.shape[0]

    def body(acc, xs):
        ck, cw = xs
        loc = searchsorted(sampled_sorted, ck)
        loc = jnp.clip(loc, 0, k - 1)
        match = (sampled_sorted[loc] == ck) & is_live(ck)
        return acc.at[loc].add(jnp.where(match, cw, 0.0)), None

    # reprolint: disable=RPL004 -- dtype dispatch, not a literal: f64 only when
    # the caller already enabled x64 and handed us f64 weights
    acc, _ = jax.lax.scan(body, jnp.zeros((k,), jnp.float64 if weights.dtype == jnp.float64 else jnp.float32), (keys, weights))
    return acc


def sample_two_pass(keys, weights=None, *, k, l, kind="continuous", salt=0, chunk=2048) -> SampleResult:
    keys, weights = _prep(keys, weights, chunk)
    skeys, sseeds = _run_pass1(keys, weights, jnp.float32(l), jnp.uint32(salt), kind=kind, k=k, chunk=chunk)
    skeys = np.asarray(skeys)
    sseeds = np.asarray(sseeds)
    valid = is_live(skeys)
    order = np.argsort(sseeds[valid])
    kk = skeys[valid][order]
    if len(kk) > k:
        tau = float(sseeds[valid][order][k])
        kk = kk[:k]
    else:
        tau = math.inf
    sampled_sorted = np.sort(kk)
    wts = _run_pass2(keys, weights, jnp.asarray(sampled_sorted), chunk=chunk)
    return SampleResult(
        keys=sampled_sorted, counts=np.asarray(wts, dtype=np.float64), tau=tau,
        l=l, kind=kind, exact_weights=True,
    )


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _prep(keys, weights, chunk):
    # same validation surface as the streaming observe()/reconcile() path:
    # bad dtypes / out-of-int32 ids / the reserved EMPTY id raise instead of
    # silently wrapping into another key's randomness
    keys = normalize_keys(keys)
    n = len(keys)
    if weights is None:
        weights = np.ones(n, dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    pad = (-n) % chunk
    if pad:
        keys = np.concatenate([keys, np.full(pad, int(EMPTY), dtype=np.int32)])
        weights = np.concatenate([weights, np.zeros(pad, dtype=np.float32)])
    return jnp.asarray(keys), jnp.asarray(weights)


def _to_result(st: TableState, *, l, kind, tau) -> SampleResult:
    keys = np.asarray(st.keys)
    counts = np.asarray(st.counts, dtype=np.float64)
    valid = is_live(keys)
    order = np.argsort(keys[valid])
    return SampleResult(
        keys=keys[valid][order], counts=counts[valid][order], tau=tau, l=l, kind=kind,
    )
