"""Distributed stream sampling: the multi-pod story of the paper (§2, §3.1).

Mergeability is the paper's key systems property: bottom-k summaries of two
streams merge losslessly into the bottom-k summary of the union.  We map it
onto the mesh:

* every device runs the chunked sampler (core.vectorized) over its *stream
  shard* inside ``shard_map``, with shard-hashed element ids
  (``vectorized.shard_eids``) so randomness never aliases across shards;
* states merge with ``jax.lax`` collectives:
    - `all_gather` merge: one hop, O(P * k) state per device — right for
      small k, final extraction, and non-power-of-two axes;
    - butterfly merge via `ppermute`: log2(P) hops of bottom-k merges,
      O(k) live state — right for large k on power-of-two axes (other sizes
      fall back to all_gather automatically);
* pass 2 (exact weights of sampled keys) is a per-shard segment-sum followed
  by a `psum` — exactly the paper's 2-pass distributed scheme;
* ``make_distributed_two_pass_multi`` runs the whole l-grid in one program:
  chunks are scored once through the fused multi-l capscore kernel
  (kernels/capscore) and every lane reuses the element hashes.

Two cross-host merge families (contracts in DESIGN.md §5.2, regression
tests in tests/test_merge_bias.py):

* ``merge_bottomk`` / ``merge_bottomk_multi`` — lossless summary merges,
  exact for any element split (the service's exact mode);
* ``merge_fixed_k`` / ``merge_fixed_k_multi`` — 1-pass sketch merges,
  unbiased for key-partitioned shards, ~10% bias for element splits.

All functions are pure and shard_map-compatible; they are exercised on real
multi-device meshes in tests (subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count={3,6,8}) and in the
dry-run at 512 devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .segments import (
    EMPTY,
    compact_valid,
    is_live,
    scatter_unique,
    searchsorted,
    segment_ids,
    sort_by_key,
)
from . import vectorized as VZ


# ---------------------------------------------------------------------------
# Mergeable bottom-k summaries
# ---------------------------------------------------------------------------


def merge_bottomk(keys_a, seeds_a, keys_b, seeds_b, k: int):
    """Merge two bottom-k (key, seed) summaries: min-seed per key, bottom-k.

    Lossless for bottom-k of the union (paper §3.1).
    """
    return VZ.merge_bottomk_summary(keys_a, seeds_a, keys_b, seeds_b, k)


def _lanewise_merge_bottomk(keys_a, seeds_a, keys_b, seeds_b, cap: int):
    """vmap of merge_bottomk over stacked lanes — the one definition shared
    by merge_bottomk_multi and both collective multi-lane mergers."""
    return jax.vmap(
        lambda ka, sa, kb, sb: merge_bottomk(ka, sa, kb, sb, cap)
    )(keys_a, seeds_a, keys_b, seeds_b)


@functools.partial(jax.jit, static_argnames=("cap",))
def merge_bottomk_multi(keys_a, seeds_a, keys_b, seeds_b, *, cap):
    """Lane-wise lossless min-merge of stacked bottom-cap summaries [L, cap] —
    the exact-mode multi-host path of stats.service.StreamStatsService."""
    return _lanewise_merge_bottomk(keys_a, seeds_a, keys_b, seeds_b, cap)


def _axis_size(axis_name: str) -> int:
    return (jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size")
            else jax.lax.psum(1, axis_name))  # older jax spelling


def tree_merge_bottomk(keys, seeds, k: int, axis_name: str):
    """Butterfly (recursive-halving) bottom-k merge across a mesh axis.

    log2(P) ppermute hops, each exchanging O(k) state: collective bytes
    O(k log P) per device versus O(k P) for the all_gather merge.

    The butterfly permutation ``i ^ stage`` is only a valid pairing when the
    axis size is a power of two; other sizes fall back to the one-hop
    all_gather merge (same result, O(k P) bytes).
    """
    size = _axis_size(axis_name)
    if size & (size - 1):
        return allgather_merge_bottomk(keys, seeds, k, axis_name)
    stage = 1
    while stage < size:
        perm = [(i, i ^ stage) for i in range(size)]
        other_keys = jax.lax.ppermute(keys, axis_name, perm)
        other_seeds = jax.lax.ppermute(seeds, axis_name, perm)
        keys, seeds = merge_bottomk(keys, seeds, other_keys, other_seeds, k)
        stage *= 2
    return keys, seeds


def allgather_merge_bottomk(keys, seeds, k: int, axis_name: str):
    """One-hop merge: all_gather all summaries then local bottom-k."""
    all_keys = jax.lax.all_gather(keys, axis_name).reshape(-1)
    all_seeds = jax.lax.all_gather(seeds, axis_name).reshape(-1)
    # combine duplicates + bottom-k
    return merge_bottomk(
        all_keys, all_seeds,
        jnp.full((1,), EMPTY, all_keys.dtype), jnp.full((1,), jnp.inf, all_seeds.dtype),
        k,
    )


def tree_merge_bottomk_multi(keys, seeds, cap: int, axis_name: str):
    """Butterfly merge of stacked per-lane summaries ([L, cap] per device):
    each hop exchanges the whole stack once, then merges lane-wise locally.
    Non-power-of-two axes fall back to the all_gather merge."""
    size = _axis_size(axis_name)
    if size & (size - 1):
        return allgather_merge_bottomk_multi(keys, seeds, cap, axis_name)
    stage = 1
    while stage < size:
        perm = [(i, i ^ stage) for i in range(size)]
        other_keys = jax.lax.ppermute(keys, axis_name, perm)
        other_seeds = jax.lax.ppermute(seeds, axis_name, perm)
        keys, seeds = _lanewise_merge_bottomk(keys, seeds, other_keys, other_seeds, cap)
        stage *= 2
    return keys, seeds


def allgather_merge_bottomk_multi(keys, seeds, cap: int, axis_name: str):
    """One-hop merge of stacked per-lane summaries [L, cap]."""
    L = keys.shape[0]
    all_keys = jnp.moveaxis(jax.lax.all_gather(keys, axis_name), 0, 1).reshape(L, -1)
    all_seeds = jnp.moveaxis(jax.lax.all_gather(seeds, axis_name), 0, 1).reshape(L, -1)
    empty_k = jnp.full((L, 1), EMPTY, keys.dtype)
    empty_s = jnp.full((L, 1), jnp.inf, seeds.dtype)
    return _lanewise_merge_bottomk(all_keys, all_seeds, empty_k, empty_s, cap)


# ---------------------------------------------------------------------------
# Mergeable fixed-k continuous states (1-pass sketches across hosts)
# ---------------------------------------------------------------------------


# reprolint: disable=RPL003 -- cross-host merge: both inputs may alias live
# resident states the caller keeps serving from (service.absorb merges into
# self.state); donating would invalidate them
@functools.partial(jax.jit, static_argnames=("k",))
def merge_fixed_k(table_a, table_b, l, salt, *, k):
    """Merge two per-host fixed-k continuous sampler states (core.vectorized
    ``TableState``) under a shared threshold.

    Procedure: union the tables; combine duplicate keys (counts add, KeyBase
    and seed min, plus one expected entry clip ``1/max(1/l, tau)`` per extra
    host — a key that entered on m hosts paid m entry-time clips while the
    continuous estimator corrects for exactly one); adopt the *lower*
    threshold; run one batched eviction round (§5.2 machinery) back down to
    <= k keys.  The result is a valid fixed-k state with ``table_a``'s
    capacity, so it can keep ingesting or merge again — pairwise folds give
    multi-host trees, the same topology as the bottom-k merges above.

    Accuracy contract (measured in tests/test_incremental.py): with
    **key-partitioned** shards (each key lives on one host — the natural
    sharding for user-keyed streams) merged estimates are unbiased within
    noise, like a single-stream run.  With arbitrary element-level splits,
    keys straddling hosts make the 1-pass merge inherently approximate
    (per-host entry events condition on per-host thresholds; cross-shard
    mass of unsampled keys is unrecoverable) — expect up to ~10% bias at
    k=512.  Use the 2-pass path (lossless bottom-k merge + exact pass-2
    weights) when cross-host exactness is required.
    """
    cap = table_a.keys.shape[0]
    tau = jnp.minimum(table_a.tau, table_b.tau)
    keys2 = jnp.concatenate([table_a.keys, table_b.keys])
    counts2 = jnp.concatenate([table_a.counts, table_b.counts])
    kb2 = jnp.concatenate([table_a.kb, table_b.kb])
    seed2 = jnp.concatenate([table_a.seed, table_b.seed])

    ks, (cn, kb, sd) = sort_by_key(keys2, counts2, kb2, seed2)
    seg, _ = segment_ids(ks)
    N = ks.shape[0]
    live = is_live(ks)
    cnt = jax.ops.segment_sum(jnp.where(live, cn, 0.0), seg, num_segments=N)
    dup = jax.ops.segment_sum(jnp.where(live, 1.0, 0.0), seg, num_segments=N)
    kbm = jax.ops.segment_min(jnp.where(live, kb, jnp.inf), seg, num_segments=N)
    sdm = jax.ops.segment_min(jnp.where(live, sd, jnp.inf), seg, num_segments=N)
    uk, _ = scatter_unique(ks, seg, 0.0)

    # duplicate-entry clip correction (m hosts -> m-1 extra clips)
    rate = jnp.maximum(1.0 / l, tau)
    cnt = cnt + jnp.maximum(dup - 1.0, 0.0) / rate
    uk_live = is_live(uk)
    cnt = jnp.where(uk_live, cnt, 0.0)
    kbm = jnp.where(uk_live, kbm, jnp.inf)
    sdm = jnp.where(uk_live, sdm, jnp.inf)

    # eviction randomness is hashed on the round counter: the merged state
    # stores this same round as its step so NO later per-chunk eviction can
    # reuse it (max(a,b)+1 would collide with a future round, replaying the
    # same ux/rx draws and correlating evictions)
    round_no = table_a.step + table_b.step + 1
    keys_e, counts_e, kb_e, seed_e, tau_e = VZ._evict_to_k(
        uk, cnt, kbm, sdm, tau, k, l, salt, round_no)

    # compact the <= k survivors back into table_a's capacity
    keys_c, counts_c, kb_c, seed_c = compact_valid(
        is_live(keys_e), keys_e, counts_e, kb_e, seed_e,
        fills=(EMPTY, 0.0, jnp.float32(jnp.inf), jnp.float32(jnp.inf)),
    )
    return VZ.TableState(
        keys=keys_c[:cap], counts=counts_c[:cap], kb=kb_c[:cap],
        seed=seed_c[:cap],
        tau=tau_e,
        step=round_no,
        overflow=table_a.overflow + table_b.overflow,
    )


def merge_fixed_k_states(tables, l, salt, *, k):
    """Fold a sequence of per-host fixed-k states into one (pairwise tree)."""
    tables = list(tables)
    if not tables:
        raise ValueError("no states to merge")
    while len(tables) > 1:
        nxt = [
            merge_fixed_k(tables[i], tables[i + 1], l, salt, k=k)
            if i + 1 < len(tables) else tables[i]
            for i in range(0, len(tables), 2)
        ]
        tables = nxt
    return tables[0]


# reprolint: disable=RPL003 -- cross-host merge, inputs alias live states
# (see merge_fixed_k)
@functools.partial(jax.jit, static_argnames=("k",))
def merge_fixed_k_multi(table_a, table_b, ls, salt, *, k):
    """Lane-wise merge of two stacked multi-l states (leading axis |ls|) —
    the multi-host path of stats.service.StreamStatsService."""
    return jax.vmap(
        lambda ta, tb, l: merge_fixed_k(ta, tb, l, salt, k=k),
        in_axes=(0, 0, 0),
    )(table_a, table_b, ls)


def merge_fixed_k_multi_states(tables, ls, salt, *, k, fold="left"):
    """Fold any subset of stacked multi-l states into one.

    The partial-merge surface of the sharded ingestion tier
    (stats.shardtier): the coordinator folds the *surviving* shards'
    states for degraded-mode queries — with key-partitioned shards every
    subset fold is itself an unbiased sketch of the covered key space.
    A single-element sequence folds to itself (no merge dispatch).

    ``fold="left"`` (default) is bit-compatible with a chain of pairwise
    merges — the fixed-k merge heuristic is order-sensitive, so the fold
    shape IS the answer's identity (MultiSampler.absorb_many relies on
    this to stay bit-equal to repeated ``absorb``); ``fold="tree"`` halves
    the critical path for genuinely parallel (mesh) folds at the cost of
    that compatibility."""
    tables = list(tables)
    if not tables:
        raise ValueError("no states to merge")
    if fold == "left":
        acc = tables[0]
        for t in tables[1:]:
            acc = merge_fixed_k_multi(acc, t, ls, salt, k=k)
        return acc
    if fold != "tree":
        raise ValueError(f"unknown fold {fold!r}")
    while len(tables) > 1:
        tables = [
            merge_fixed_k_multi(tables[i], tables[i + 1], ls, salt, k=k)
            if i + 1 < len(tables) else tables[i]
            for i in range(0, len(tables), 2)
        ]
    return tables[0]


def merge_bottomk_multi_states(summaries, *, cap):
    """Fold stacked per-lane bottom-cap summaries ``[(keys, seeds), ...]``
    into one pair — the exact-mode half of the tier's partial merge.
    Min-merge is associative and commutative, so (unlike the fixed-k fold
    above) the fold shape cannot change a bit of the result; the left fold
    keeps the dispatch sequence aligned with the table fold."""
    summaries = list(summaries)
    if not summaries:
        raise ValueError("no summaries to merge")
    ka, sa = summaries[0]
    for kb, sb in summaries[1:]:
        ka, sa = merge_bottomk_multi(ka, sa, kb, sb, cap=cap)
    return ka, sa


# ---------------------------------------------------------------------------
# Distributed 2-pass sampling (shard_map bodies)
# ---------------------------------------------------------------------------


def pass1_shard(keys_shard, weights_shard, *, kind, l, salt, k, chunk, axis_name, merge="tree"):
    """Per-device pass 1 over the local stream shard + cross-device merge.

    Element ids are disambiguated by hashing the shard index into the id
    (``vectorized.shard_eids``), so ids from different shards never alias —
    the previous ``shard_no * n`` arithmetic overflowed int32 once P*n > 2^31,
    silently correlating element randomness across shards.
    """
    shard_no = jax.lax.axis_index(axis_name)
    n = keys_shard.shape[0]
    n_chunks = n // chunk
    kshape = keys_shard.reshape(n_chunks, chunk)
    wshape = weights_shard.reshape(n_chunks, chunk)
    eids = VZ.shard_eids(shard_no, jnp.arange(n, dtype=jnp.int32)).reshape(n_chunks, chunk)

    cap = k + 1

    def body(carry, xs):
        skeys, sseeds = carry
        ck, cw, ce = xs
        uk, mins = VZ.chunk_bottomk_summary(ck, ce, cw, l, salt, kind=kind)
        return merge_bottomk(skeys, sseeds, uk, mins, cap), None

    init = (jnp.full((cap,), EMPTY, jnp.int32), jnp.full((cap,), jnp.inf, jnp.float32))
    # mark the carry as varying over the mesh axis (its value depends on the
    # shard's data from step 1 on); older jax (< pcast) doesn't track varying
    # axes, so the cast is unnecessary there
    if hasattr(jax.lax, "pcast"):
        init = jax.lax.pcast(init, (axis_name,), to="varying")
    (skeys, sseeds), _ = jax.lax.scan(body, init, (kshape, wshape, eids))
    if merge == "tree":
        return tree_merge_bottomk(skeys, sseeds, cap, axis_name)
    return allgather_merge_bottomk(skeys, sseeds, cap, axis_name)


def pass2_shard(keys_shard, weights_shard, sampled_sorted, *, axis_name):
    """Per-device exact-weight accumulation + psum (paper pass II)."""
    kk = sampled_sorted.shape[0]
    loc = searchsorted(sampled_sorted, keys_shard)
    loc = jnp.clip(loc, 0, kk - 1)
    match = (sampled_sorted[loc] == keys_shard) & is_live(keys_shard)
    local = jnp.zeros((kk,), jnp.float32).at[loc].add(jnp.where(match, weights_shard, 0.0))
    return jax.lax.psum(local, axis_name)


def make_distributed_two_pass(mesh, *, kind, l, salt, k, chunk, axis_name="data", merge="tree"):
    """Build a jitted shard_map program computing the distributed 2-pass sample.

    Returns fn(keys [P*n], weights [P*n]) -> (sampled_keys [k+1], seeds [k+1],
    weights [k+1]) replicated.
    """
    from jax.experimental.shard_map import shard_map

    def program(keys, weights):
        def shard_body(kshard, wshard):
            skeys, sseeds = pass1_shard(
                kshard.reshape(-1), wshard.reshape(-1),
                kind=kind, l=l, salt=salt, k=k, chunk=chunk,
                axis_name=axis_name, merge=merge,
            )
            # reprolint: disable=RPL002 -- sorts the [k+1] sampled summary once
            # per two-pass program, not per chunk; k+1 << stream length
            order = jnp.argsort(skeys)
            sorted_keys = skeys[order]
            w = pass2_shard(kshard.reshape(-1), wshard.reshape(-1), sorted_keys, axis_name=axis_name)
            return sorted_keys[None], sseeds[order][None], w[None]

        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P(axis_name), P(axis_name)),
        )(keys, weights)

    return jax.jit(program)


# ---------------------------------------------------------------------------
# Multi-l distributed 2-pass: the whole l-grid in one program
# ---------------------------------------------------------------------------


def pass1_shard_multi(keys_shard, weights_shard, *, ls, salt, k, chunk,
                      axis_name, merge="tree"):
    """Per-device pass 1 for every l of a grid + cross-device lane-wise merge.

    Chunks are scored once through the fused multi-l capscore kernel
    (kernels/capscore; Pallas on TPU, lane-exact XLA reference elsewhere):
    the element hashes are computed once and every (l) lane reuses them, so
    the whole grid costs barely more than a single-l pass 1.  Element ids are
    shard-hashed (``vectorized.shard_eids``).  Returns ([L, k+1] keys,
    [L, k+1] seeds), the per-lane bottom-(k+1) summaries of the union.
    """
    from ..kernels.capscore.ops import capscore_multi

    shard_no = jax.lax.axis_index(axis_name)
    n = keys_shard.shape[0]
    n_chunks = n // chunk
    kshape = keys_shard.reshape(n_chunks, chunk)
    wshape = weights_shard.reshape(n_chunks, chunk)
    eids = VZ.shard_eids(shard_no, jnp.arange(n, dtype=jnp.int32)).reshape(n_chunks, chunk)

    ls = jnp.asarray(ls, jnp.float32)
    L = ls.shape[0]
    cap = k + 1
    # element scores don't depend on tau; feed inert thresholds to the kernel
    taus = jnp.full((L,), jnp.inf, jnp.float32)

    def body(carry, xs):
        ck, cw, ce = xs
        score, _, _, _ = capscore_multi(ck, ce, cw, ls, taus, salt)
        return VZ.pass1_step_multi(carry, ck, score, cap=cap), None

    init = (jnp.full((L, cap), EMPTY, jnp.int32),
            jnp.full((L, cap), jnp.inf, jnp.float32))
    if hasattr(jax.lax, "pcast"):
        init = jax.lax.pcast(init, (axis_name,), to="varying")
    (skeys, sseeds), _ = jax.lax.scan(body, init, (kshape, wshape, eids))
    if merge == "tree":
        return tree_merge_bottomk_multi(skeys, sseeds, cap, axis_name)
    return allgather_merge_bottomk_multi(skeys, sseeds, cap, axis_name)


def pass2_shard_multi(keys_shard, weights_shard, sampled_sorted, *, axis_name):
    """Per-device exact-weight accumulation for every lane + one psum.

    ``sampled_sorted``: [L, kk] per-lane sorted sampled keys (EMPTY-padded,
    EMPTY sorts last).  Returns [L, kk] exact weights, replicated.
    """
    def lane(ss):
        kk = ss.shape[0]
        loc = searchsorted(ss, keys_shard)
        loc = jnp.clip(loc, 0, kk - 1)
        match = (ss[loc] == keys_shard) & is_live(keys_shard)
        return jnp.zeros((kk,), jnp.float32).at[loc].add(
            jnp.where(match, weights_shard, 0.0))

    local = jax.vmap(lane)(sampled_sorted)
    return jax.lax.psum(local, axis_name)


def make_distributed_two_pass_multi(mesh, *, ls, salt, k, chunk,
                                    axis_name="data", merge="tree"):
    """Build a jitted shard_map program computing the exact distributed
    2-pass sample for EVERY l of the grid in one launch.

    Returns fn(keys [P*n], weights [P*n]) -> (sampled_keys [L, k+1],
    seeds [L, k+1], weights [L, k+1]) replicated; per lane, keys are sorted
    ascending (EMPTY-padded) with their seeds and exact pass-2 weights.
    """
    from jax.experimental.shard_map import shard_map

    def program(keys, weights):
        def shard_body(kshard, wshard):
            skeys, sseeds = pass1_shard_multi(
                kshard.reshape(-1), wshard.reshape(-1),
                ls=ls, salt=salt, k=k, chunk=chunk,
                axis_name=axis_name, merge=merge,
            )
            # reprolint: disable=RPL002 -- sorts the [L, k+1] sampled summary
            # once per two-pass program, not per chunk
            order = jnp.argsort(skeys, axis=1)
            sorted_keys = jnp.take_along_axis(skeys, order, axis=1)
            sorted_seeds = jnp.take_along_axis(sseeds, order, axis=1)
            w = pass2_shard_multi(kshard.reshape(-1), wshard.reshape(-1),
                                  sorted_keys, axis_name=axis_name)
            return sorted_keys[None], sorted_seeds[None], w[None]

        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P(axis_name), P(axis_name)),
        )(keys, weights)

    return jax.jit(program)
