"""Frequency statistics functions f(w) — the query side of Q(f, H) (eq. 1).

Each ``FreqFn`` carries the function and its a.e.-derivative (needed by the
continuous-spectrum estimator, Thm 5.3: beta(c) = f(c)/min(1, l*tau) + f'(c)/tau).

All standard statistics from the paper are provided:
  * ``cap(T)``      cap_T(w) = min(w, T)        (frequency cap — the headline)
  * ``distinct()``  cap_1 under unit weights    (L0)
  * ``total()``     f(w) = w                    (Sum / L1)
  * ``moment(p)``   f(w) = w**p                 (frequency moments)
  * ``log1p()``     f(w) = log(1+w)             (a smooth concave example)
  * ``threshold(T)``f(w) = 1[w >= T]            (monotone but discontinuous —
                       supported by the discrete estimator; the continuous
                       estimator requires a.e.-differentiability and treats it
                       as a step, exercised in tests for bias behaviour)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class FreqFn:
    name: str
    f: Callable[[np.ndarray], np.ndarray]
    fprime: Callable[[np.ndarray], np.ndarray]

    def __call__(self, w):
        return self.f(w)

    def table(self, n: int) -> np.ndarray:
        """f_i = f(i) for i = 0..n (discrete-spectrum coefficient form)."""
        return self.f(np.arange(n + 1, dtype=np.float64))


def cap(T: float) -> FreqFn:
    return FreqFn(
        name=f"cap_{T:g}",
        f=lambda w: np.minimum(np.asarray(w, dtype=np.float64), T),
        fprime=lambda w: (np.asarray(w, dtype=np.float64) < T).astype(np.float64),
    )


def distinct() -> FreqFn:
    # For unit weights, distinct == cap_1.  Defined directly as 1[w > 0].
    return FreqFn(
        name="distinct",
        f=lambda w: (np.asarray(w, dtype=np.float64) > 0).astype(np.float64),
        fprime=lambda w: np.zeros_like(np.asarray(w, dtype=np.float64)),
    )


def total() -> FreqFn:
    return FreqFn(
        name="sum",
        f=lambda w: np.asarray(w, dtype=np.float64),
        fprime=lambda w: np.ones_like(np.asarray(w, dtype=np.float64)),
    )


def moment(p: float) -> FreqFn:
    return FreqFn(
        name=f"moment_{p:g}",
        f=lambda w: np.asarray(w, dtype=np.float64) ** p,
        fprime=lambda w: p * np.asarray(w, dtype=np.float64) ** (p - 1),
    )


def log1p() -> FreqFn:
    return FreqFn(
        name="log1p",
        f=lambda w: np.log1p(np.asarray(w, dtype=np.float64)),
        fprime=lambda w: 1.0 / (1.0 + np.asarray(w, dtype=np.float64)),
    )


def threshold(T: float) -> FreqFn:
    return FreqFn(
        name=f"thresh_{T:g}",
        f=lambda w: (np.asarray(w, dtype=np.float64) >= T).astype(np.float64),
        fprime=lambda w: np.zeros_like(np.asarray(w, dtype=np.float64)),
    )


def exact_statistic(fn: FreqFn, weights: np.ndarray, segment: np.ndarray | None = None) -> float:
    """Ground-truth Q(f, H) from the aggregated view (for tests/benchmarks)."""
    w = np.asarray(weights, dtype=np.float64)
    vals = fn(w)
    if segment is not None:
        vals = vals[np.asarray(segment)]
    return float(np.sum(vals))
