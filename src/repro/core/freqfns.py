"""Frequency statistics functions f(w) — the query side of Q(f, H) (eq. 1).

Each ``FreqFn`` carries the function and its a.e.-derivative (needed by the
continuous-spectrum estimator, Thm 5.3: beta(c) = f(c)/min(1, l*tau) + f'(c)/tau)
in an **array-backend-agnostic form**: every standard statistic is registered
as a ``(kind, param)`` pair whose f / f' implementations take the array
namespace (``numpy`` or ``jax.numpy``) as a parameter.  The host-side
callables (``fn.f`` / ``fn.fprime``, float64 numpy — the historical API) and
the batched device evaluation (``eval_kinds_batched``, used by the jitted
``stats.query.QueryEngine`` to evaluate a whole family {cap_T} as one array
op) are therefore the *same formulas*, which is what makes the batched query
plane bit-identical to the scalar estimators.

Kinds whose formulas use only exactly-rounded IEEE ops (min, compare,
divide: ``cap``, ``total``, ``distinct``, ``threshold``) are flagged
``DEVICE_EXACT`` and evaluate on device bit-identically to numpy.
Transcendental kinds (``moment``, ``log1p``) and custom ``FreqFn``s are
evaluated on host into per-key coefficient tables instead (XLA's exp/log/pow
differ from numpy in the last ulp), which the engine ships to the device —
so bit-identity with the scalar path holds for every FreqFn.

All standard statistics from the paper are provided:
  * ``cap(T)``      cap_T(w) = min(w, T)        (frequency cap — the headline)
  * ``distinct()``  cap_1 under unit weights    (L0)
  * ``total()``     f(w) = w                    (Sum / L1)
  * ``moment(p)``   f(w) = w**p                 (frequency moments)
  * ``log1p()``     f(w) = log(1+w)             (a smooth concave example)
  * ``threshold(T)``f(w) = 1[w >= T]            (monotone but discontinuous —
                       supported by the discrete estimator; the continuous
                       estimator requires a.e.-differentiability and treats it
                       as a step, exercised in tests for bias behaviour)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from . import segments as SEG


# ---------------------------------------------------------------------------
# Kind registry: xp-generic f / f' implementations
# ---------------------------------------------------------------------------


def _f_cap(xp, T, w):
    return xp.minimum(w, T)


def _fp_cap(xp, T, w):
    return (w < T).astype(w.dtype)


def _f_total(xp, T, w):
    return w


def _fp_total(xp, T, w):
    return xp.ones_like(w)


def _f_distinct(xp, T, w):
    return (w > 0).astype(w.dtype)


def _f_threshold(xp, T, w):
    return (w >= T).astype(w.dtype)


def _fp_zero(xp, T, w):
    return xp.zeros_like(w)


def _f_moment(xp, p, w):
    return w**p


def _fp_moment(xp, p, w):
    return p * w ** (p - 1)


def _f_log1p(xp, p, w):
    return xp.log1p(w)


def _fp_log1p(xp, p, w):
    return 1.0 / (1.0 + w)


# kind -> (f(xp, param, w), fprime(xp, param, w), device_exact)
KIND_REGISTRY: dict[str, tuple] = {
    "cap": (_f_cap, _fp_cap, True),
    "total": (_f_total, _fp_total, True),
    "distinct": (_f_distinct, _fp_zero, True),
    "threshold": (_f_threshold, _fp_zero, True),
    "moment": (_f_moment, _fp_moment, False),
    "log1p": (_f_log1p, _fp_log1p, False),
}

# stable integer ids for the device-exact kinds (the jitted engine's
# where-chain dispatch); order is part of the compiled dispatch, keep fixed
DEVICE_KIND_IDS = {"cap": 0, "total": 1, "distinct": 2, "threshold": 3}


def eval_kinds_batched(kind_id, param, w, xp):
    """Evaluate a stacked family of device-exact kinds as one array op.

    ``kind_id``/``param`` broadcast against ``w`` (typically [Q, 1] against
    [Q, K] counts).  Returns (f(w), f'(w)).  Only exactly-rounded ops are
    used, so numpy and XLA agree bit-for-bit — the foundation of the query
    plane's bit-identity contract.
    """
    is_cap = kind_id == DEVICE_KIND_IDS["cap"]
    is_total = kind_id == DEVICE_KIND_IDS["total"]
    is_distinct = kind_id == DEVICE_KIND_IDS["distinct"]
    one = xp.ones_like(w)
    zero = xp.zeros_like(w)
    f = xp.where(
        is_cap, xp.minimum(w, param),
        xp.where(is_total, w,
                 xp.where(is_distinct, (w > 0).astype(w.dtype),
                          (w >= param).astype(w.dtype))))
    fp = xp.where(is_cap, (w < param).astype(w.dtype),
                  xp.where(is_total, one, zero))
    return f, fp


@dataclasses.dataclass(frozen=True)
class FreqFn:
    name: str
    f: Callable[[np.ndarray], np.ndarray]
    fprime: Callable[[np.ndarray], np.ndarray]
    kind: str = "custom"      # registry key, or "custom" for opaque callables
    param: float = 0.0        # the kind's parameter (T, p, ...)

    def __call__(self, w):
        return self.f(w)

    def table(self, n: int) -> np.ndarray:
        """f_i = f(i) for i = 0..n (discrete-spectrum coefficient form)."""
        return self.f(np.arange(n + 1, dtype=np.float64))

    @property
    def cache_key(self):
        """Hashable identity for per-(lane, fn) coefficient-table caches.

        Registered kinds key by (kind, param) — every ``cap(8.0)`` hits the
        same cache slot; custom FreqFns key by the (frozen, hashable) object
        itself, which the cache then keeps alive so identity stays valid.
        """
        if self.kind in KIND_REGISTRY:
            return ("kind", self.kind, float(self.param))
        return self

    @property
    def device_exact(self) -> bool:
        return bool(self.kind in KIND_REGISTRY and KIND_REGISTRY[self.kind][2])


def _registered(name: str, kind: str, param: float) -> FreqFn:
    fi, fpi, _ = KIND_REGISTRY[kind]

    def f(w, _fi=fi, _p=param):
        return _fi(np, _p, np.asarray(w, dtype=np.float64))

    def fprime(w, _fpi=fpi, _p=param):
        return _fpi(np, _p, np.asarray(w, dtype=np.float64))

    return FreqFn(name=name, f=f, fprime=fprime, kind=kind, param=float(param))


def cap(T: float) -> FreqFn:
    return _registered(f"cap_{T:g}", "cap", T)


def distinct() -> FreqFn:
    # For unit weights, distinct == cap_1.  Defined directly as 1[w > 0].
    return _registered("distinct", "distinct", 0.0)


def total() -> FreqFn:
    return _registered("sum", "total", 0.0)


def moment(p: float) -> FreqFn:
    return _registered(f"moment_{p:g}", "moment", p)


def log1p() -> FreqFn:
    return _registered("log1p", "log1p", 0.0)


def threshold(T: float) -> FreqFn:
    return _registered(f"thresh_{T:g}", "threshold", T)


def exact_statistic(fn: FreqFn, weights: np.ndarray, segment=None,
                    keys: np.ndarray | None = None) -> float:
    """Ground-truth Q(f, H) from the aggregated view (for tests/benchmarks).

    ``segment`` accepts everything ``estimators.estimate`` accepts — a
    Segment, an id-list, a predicate, or a positional boolean mask over
    ``weights`` (the historical convention) — via ``segments.as_segment``.
    Key-based segments (IdSet / Predicate / HashBucket) need the aligned
    ``keys`` array of the aggregated view.
    """
    w = np.asarray(weights, dtype=np.float64)
    seg = SEG.as_segment(segment)
    if isinstance(seg, SEG.AllKeys):
        return float(np.sum(fn(w)))
    if isinstance(seg, SEG.Mask):
        mask = seg.mask_np(w)  # positional: aligned with weights
    else:
        if keys is None:
            raise ValueError(
                f"segment {seg.describe()} selects by key id: pass the "
                "aligned keys= array of the aggregated view")
        mask = seg.mask_np(np.asarray(keys))
    return float(np.sum(np.where(mask, fn(w), 0.0)))
