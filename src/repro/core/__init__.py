"""capstream core: the paper's sampling framework.

Public API:
    freqfns      — f(w) statistics (cap_T, distinct, sum, moments)
    samplers     — sequential oracles (Algorithms 1-5, paper-faithful)
    vectorized   — TPU-native chunked samplers (jit/scan/shard-ready)
    discrete     — SH_l discrete-spectrum estimator machinery (§4)
    continuous   — SH_l continuous-spectrum machinery (§5)
    estimators   — unified Qhat(f, H) over any SampleResult
    segments     — first-class query Segments (the H in Q(f, H)) + the
                   sort/segment-reduce substrate of the vectorized samplers
    multiobjective — coordinated multi-l samples (§6)
    distributed  — shard_map samplers + mergeable-state collectives
"""
from . import continuous, discrete, estimators, freqfns, hashing, multiobjective, samplers, segments, vectorized  # noqa: F401
from .freqfns import cap, distinct, exact_statistic, moment, total  # noqa: F401
from .samplers import SampleResult  # noqa: F401
from .segments import AllKeys, HashBucket, IdSet, Predicate, Segment, as_segment  # noqa: F401
