"""Continuous SH_l spectrum (paper §5): scoring, inclusion, count law, estimator.

Element scoring (eq. 10), for element h = (x, w):

    v ~ Exp[w];  ElementScore(h) = KeyBase(x) if v <= 1/l else v,
    KeyBase(x) = Hash(x)/l ~ U[0, 1/l].

Seed law (Lemma 5.1):  seed(x) ~ U[0,1/l] w.p. 1-e^{-w_x/l}, else 1/l+Exp[w_x].

Inclusion probability (eq. 11):

    Phi_{tau,l}(w) = (1 - e^{-w max(1/l, tau)}) * min(1, tau*l).

1-pass count law (Thm 5.2):  c_x ~ max{0, w_x - phi},
    phi with density  tau * exp(-y * max(1/l, tau))  on  y in [0, w_x].

Estimator (Thm 5.3):  beta(c) = f(c)/min(1, l*tau) + f'(c)/tau.

numpy (host) and jnp-compatible variants where the device path needs them.
"""
from __future__ import annotations

import numpy as np

from .freqfns import FreqFn


def rate(tau: float, l: float):
    """The count-law / entry rate max(1/l, tau)."""
    return max(1.0 / l, tau)


def inclusion_prob(w, tau: float, l: float):
    """Phi_{tau,l}(w)  (eq. 11); works for scalar or array w (numpy)."""
    w = np.asarray(w, dtype=np.float64)
    return (1.0 - np.exp(-w * max(1.0 / l, tau))) * min(1.0, tau * l)


def beta(fn: FreqFn, c, tau: float, l: float):
    """Continuous-spectrum estimation coefficients (eq. 13)."""
    c = np.asarray(c, dtype=np.float64)
    return fn.f(c) / min(1.0, l * tau) + fn.fprime(c) / tau


def estimate(fn: FreqFn, counts, tau: float, l: float, segment=None) -> float:
    """Qhat(f,H) = sum_{x in S∩H} beta(c_x)  (eq. 12)."""
    counts = np.asarray(counts, dtype=np.float64)
    if segment is not None:
        counts = counts[np.asarray(segment)]
    if counts.size == 0:
        return 0.0
    return float(np.sum(beta(fn, counts, tau, l)))


def estimate_two_pass(fn: FreqFn, weights, tau: float, l: float, segment=None) -> float:
    """2-pass inverse-probability estimator: sum f(w_x)/Phi(w_x)  (eq. 2)."""
    w = np.asarray(weights, dtype=np.float64)
    if segment is not None:
        w = w[np.asarray(segment)]
    if w.size == 0:
        return 0.0
    return float(np.sum(fn.f(w) / inclusion_prob(w, tau, l)))


# -- count law (Thm 5.2) -----------------------------------------------------


def count_zero_prob(w, tau: float, l: float):
    """P[c_x = 0] = 1 - Phi_{tau,l}(w): the key is never sampled."""
    return 1.0 - inclusion_prob(w, tau, l)


def conditional_count(w, tau: float, l: float, u):
    """Sample c_x | x in S: c = w - phi, phi ~ TruncExp(rate) on [0, w).

    Inverse-CDF with uniform(s) u: phi = -log(1 - u (1 - e^{-r w})) / r.
    Used by the vectorized fixed-k sampler's *distributional* count
    realization and by the statistical tests against Algorithm 5.
    """
    w = np.asarray(w, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    r = max(1.0 / l, tau)
    phi = -np.log1p(-u * (1.0 - np.exp(-r * w))) / r
    return w - phi


def count_density(y, w, tau: float, l: float):
    """Density of c_x at c = y in (0, w): tau * exp(-(w - y) * rate)."""
    y = np.asarray(y, dtype=np.float64)
    r = max(1.0 / l, tau)
    return np.where((y > 0) & (y < w), tau * np.exp(-(w - y) * r), 0.0)


# -- CV bounds (Thms 5.1 / 5.4) for validation -------------------------------

_E = np.e


def cv_bound_two_pass(T: float, l: float, q: float, k: int) -> float:
    """Thm 5.1: CV <= sqrt( e/(e-1) * max(T/l, l/T) / (q (k-1)) )."""
    disparity = max(T / l, l / T)
    return float(np.sqrt(_E / (_E - 1.0) * disparity / (q * (k - 1))))


def cv_bound_one_pass(T: float, l: float, q: float, k: int) -> float:
    """Thm 5.4 upper bound: sqrt( e/(e-1) (1 + max(l/T, T/l)) / (q (k-1)) )."""
    disparity = max(T / l, l / T)
    return float(np.sqrt(_E / (_E - 1.0) * (1.0 + disparity) / (q * (k - 1))))
