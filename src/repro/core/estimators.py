"""Unified estimation API over SampleResult objects.

Dispatches to the right estimator for the sample's provenance:

* 2-pass samples (exact weights): inverse probability  f(w)/Phi(w)  (eq. 2),
  with Phi from eq. (11) (continuous), §4.1 (discrete), tau^-1 (distinct) or
  1-e^{-w tau} (SH == ppswor, §3.7).
* 1-pass continuous samples: coefficient form  beta(c) = f(c)/min(1,l tau)
  + f'(c)/tau  (Thm 5.3).
* 1-pass discrete samples: coefficient form  beta_i = sum_j psi_j f_{i-j+1}
  (Thm 4.1), including the closed forms for distinct (eq. 4) and SH (eq. 5).

``segment`` is anything ``segments.as_segment`` coerces (the H in Q(f,H)):
a first-class Segment, an id-list, a vectorized predicate, or a boolean
mask aligned with the sample's keys; estimates restrict the sum to sampled
keys inside the segment (per-key estimates of keys outside the sample are
0, §3.5).

The scalar path is deliberately factored as *per-key estimates over the
whole sample, then a masked sum* — exactly the shape of the batched
``stats.query.QueryEngine`` device dispatch — so the engine's answers are
bit-identical to looping this module (same per-key values, same f64
reduction over the same array length).
"""
from __future__ import annotations

import math
from typing import Callable

import numpy as np

from . import continuous as cont
from . import discrete as disc
from . import segments as SEG
from .freqfns import FreqFn
from .samplers import SampleResult


def _segment_mask(keys: np.ndarray, segment) -> np.ndarray:
    return SEG.as_segment(segment).mask_np(keys)


def _inclusion_prob(result: SampleResult, w: np.ndarray) -> np.ndarray:
    tau, l = result.tau, result.l
    if result.kind == "continuous":
        return cont.inclusion_prob(w, tau, l)
    if result.kind == "distinct":
        return np.full_like(np.asarray(w, dtype=np.float64), min(tau, 1.0))
    if result.kind == "sh":
        # seed ~ Exp[w_x] transformed: P[min of w uniforms < tau] = 1-(1-tau)^w
        return 1.0 - (1.0 - tau) ** np.asarray(w, dtype=np.float64)
    if result.kind == "discrete":
        phi = disc.phi_vector(l, tau)
        return disc.inclusion_prob(np.asarray(w), phi)
    raise ValueError(result.kind)


def estimate(result: SampleResult, fn: FreqFn, segment=None) -> float:
    """Qhat(f, H) from a sample, choosing the right estimator.

    Per-key estimates over the whole sample, then a masked f64 sum — the
    reduction the batched query engine reproduces bit-for-bit.  (This
    replaced a compact-then-sum formulation; segment-restricted answers can
    differ from pre-query-plane releases in the last ulp because the
    pairwise-summation grouping changed.  The invariant maintained going
    forward is engine == this function, exactly.)
    """
    mask = _segment_mask(result.keys, segment)
    if not mask.any():
        return 0.0
    per_key = estimate_per_key(result, fn)
    return float(np.sum(np.where(mask, per_key, 0.0)))


def estimate_per_key(result: SampleResult, fn: FreqFn) -> np.ndarray:
    """Per-key unbiased estimates fhat(w_x) (variance diagnostics, and the
    building block of ``estimate``)."""
    vals = result.counts
    tau, l = result.tau, result.l
    if math.isinf(tau):
        # fewer than k+1 keys ever qualified: the sample IS the data set
        return fn(vals)
    if result.exact_weights:
        return fn(vals) / _inclusion_prob(result, vals)
    if result.kind == "continuous":
        # Thm 5.3 requires f continuous with f(0)=0; the distinct step
        # 1[w>0] violates it (E[beta(c)] = 1 - e^{-w max(1/l,tau)} != 1).
        # For weights >= 1 distinct == cap_1, which is continuous — swap it
        # (the 2-pass inverse-probability path above handles the raw step).
        from .freqfns import cap as _cap

        if fn.name == "distinct":
            fn = _cap(1.0)
        return cont.beta(fn, vals, tau, l)
    if result.kind in ("discrete", "distinct", "sh"):
        eff_l = {"distinct": 1, "sh": math.inf}.get(result.kind, l)
        n = int(np.max(vals)) if len(vals) else 1
        beta = disc.estimator_coefficients(fn.table(n), eff_l, tau, n)
        return beta[vals.astype(np.int64) - 1]
    raise ValueError(result.kind)


def inclusion_per_key(result: SampleResult, clip: float = 1e-12) -> np.ndarray:
    """Plug-in per-key inclusion probabilities p_x for variance diagnostics.

    Exact for 2-pass samples (Phi of the exact weight); for 1-pass samples
    the observed count c_x stands in for w_x — a plug-in heuristic whose
    calibration the Monte-Carlo CI tests check.  tau=inf means everything
    was kept: p = 1 and the variance diagnostic collapses to 0.
    """
    if math.isinf(result.tau):
        return np.ones(len(result.counts), dtype=np.float64)
    p = np.asarray(_inclusion_prob(result, result.counts), dtype=np.float64)
    return np.clip(p, clip, 1.0)


def relative_error(estimate_value: float, truth: float) -> float:
    return abs(estimate_value - truth) / max(abs(truth), 1e-12)
