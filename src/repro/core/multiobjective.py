"""Multi-objective samples (paper §6): one coordinated sample for all cap_T.

Coordination (§6.1): each key's randomness is the pair (Hash(x), y_x) with
y_x ~ Exp[w_x] the min over its elements of the Exp[w] score components.  The
SH_l seed for ANY l is then

    seed_l(x) = Hash(x)/l   if y_x <= 1/l   else   y_x .

S_l = bottom-k keys by seed_l; tau_l = (k+1)-smallest seed_l.  The union
S_L = U_l S_l over ALL l in (0, inf) has E|S_L| <= k ln n (Lemma 6.1): a key
is in some S_l iff its Hash rank within the y_x-order prefix is <= k.

Estimation (§6.2, Lemma 6.2): with fixed per-key inclusion thresholds
{tau_l^{-x}}, the combined inclusion probability is

    Phi(w_x) = P_{y~Exp[w_x], h~U[0,1]} [ exists l: y < max(tau_l^{-x}, 1/l)
                                           and  h < l * tau_l^{-x} ]

i.e. the (Exp x Uniform)-measure of a union of axis-aligned rectangles — we
integrate the upper staircase envelope exactly.

This module implements the finite-grid variant (l in a geometric grid, the
deployment recommendation at the top of §6) on top of the 2-pass machinery;
`union_sample_all_l` also realizes the full L = (0, inf) union for the
Lemma 6.1 size experiments.
"""
from __future__ import annotations

import math

import numpy as np

from . import hashing as H
from .freqfns import FreqFn
from .samplers import SALT_ELEM, SALT_KEYBASE, SampleResult


def per_key_randomness(keys_stream, weights_stream, salt: int = 0):
    """Aggregate the coordinated per-key randomness (Hash(x), y_x) and exact
    weights from an unaggregated stream (vectorized host implementation;
    the device path reuses core.vectorized pass-1 with kind='continuous')."""
    keys_stream = np.asarray(keys_stream)
    n = len(keys_stream)
    w = np.ones(n) if weights_stream is None else np.asarray(weights_stream, dtype=np.float64)
    eids = np.arange(n, dtype=np.int64)
    u = H.uniform01_np(H.hash_combine_np(eids, np.uint32(SALT_ELEM), np.uint32(salt)))
    v = -np.log1p(-u) / w
    ukeys, inv = np.unique(keys_stream, return_inverse=True)
    y = np.full(len(ukeys), np.inf)
    np.minimum.at(y, inv, v)
    wx = np.zeros(len(ukeys))
    np.add.at(wx, inv, w)
    hx = H.uniform01_np(H.hash_combine_np(ukeys, np.uint32(SALT_KEYBASE), np.uint32(salt)))
    return ukeys, hx, y, wx


def seed_for_l(hx, y, l: float):
    return np.where(y <= 1.0 / l, hx / l, y)


def sample_for_l(ukeys, hx, y, k: int, l: float):
    """S_l and tau_l from coordinated randomness."""
    s = seed_for_l(hx, y, l)
    order = np.argsort(s)
    if len(ukeys) <= k:
        return ukeys[order], math.inf
    return ukeys[order[:k]], float(s[order[k]])


def union_sample_grid(ukeys, hx, y, k: int, ls) -> dict:
    """Coordinated union over a finite l-grid; returns {l: (S_l, tau_l)}."""
    return {l: sample_for_l(ukeys, hx, y, k, l) for l in ls}


def union_sample_all_l(ukeys, hx, y, k: int):
    """S_L for L = (0, inf) (Lemma 6.1 construction): x in S_L iff Hash(x)
    ranks <= k within the prefix of keys ordered by increasing y."""
    order = np.argsort(y)
    hs = hx[order]
    member = np.zeros(len(ukeys), dtype=bool)
    import heapq

    heap: list = []  # max-heap of -h of current top-k
    for i in range(len(order)):
        h = hs[i]
        if len(heap) < k:
            heapq.heappush(heap, -h)
            member[order[i]] = True
        elif h < -heap[0]:
            heapq.heapreplace(heap, -h)
            member[order[i]] = True
    return ukeys[member]


def combined_inclusion_prob(w: float, taus: dict[float, float]) -> float:
    """Lemma 6.2 for a finite grid: P[exists l: y < max(tau_l, 1/l) and
    h < l*tau_l] with y ~ Exp[w], h ~ U[0,1].

    Union of rectangles [0, a_l) x [0, b_l), a_l = max(tau_l, 1/l),
    b_l = min(l*tau_l, 1).  Exact integration of the staircase envelope.
    """
    rects = []
    for l, tau in taus.items():
        if math.isinf(tau):
            return 1.0
        rects.append((max(tau, 1.0 / l), min(l * tau, 1.0)))
    # envelope: sort by a ascending; the maximal b among rects with a >= y
    rects.sort()
    a_vals = [r[0] for r in rects]
    # suffix max of b
    b_suffix = [0.0] * (len(rects) + 1)
    for i in range(len(rects) - 1, -1, -1):
        b_suffix[i] = max(b_suffix[i + 1], rects[i][1])
    prob = 0.0
    prev_a = 0.0
    for i in range(len(rects)):
        a = a_vals[i]
        if a > prev_a:
            # y in [prev_a, a): covered rectangles are those with a_l >= a
            seg = (math.exp(-w * prev_a) - math.exp(-w * a)) * b_suffix[i]
            prob += seg
            prev_a = a
    return prob


def estimate_multi(fn: FreqFn, ukeys_sampled, wx_sampled, taus_per_key) -> float:
    """Inverse-probability estimate using the combined Phi (§6.2)."""
    total = 0.0
    for key, w, taus in zip(ukeys_sampled, wx_sampled, taus_per_key):
        p = combined_inclusion_prob(w, taus)
        total += fn(np.array([w]))[0] / p
    return float(total)


def multiobjective_sample(keys_stream, weights_stream, k: int, ls, salt: int = 0):
    """End-to-end: coordinated 2-pass multi-objective sample over an l-grid.

    Returns (union_keys, union_weights, taus_per_key, per_l_samples).

    tau_l^{-x} handling (Lemma 6.2 requires per-key thresholds that are
    *independent of x's own randomness*): for EVERY union key x — member of
    S_l or not — tau_l^{-x} is the k-th smallest seed among the OTHER keys.
    x is in S_l exactly when seed_l(x) < tau_l^{-x}, and Phi integrates that
    event's probability, so using the same quantity for members and
    non-members is what makes the estimator unbiased.  (An earlier docstring
    claimed non-members use the (k+1)-smallest overall; that was never what
    the code computed — the k-th smallest of others IS the k-th smallest
    overall when x ranks above it.)
    """
    ukeys, hx, y, wx = per_key_randomness(keys_stream, weights_stream, salt)
    per_l = union_sample_grid(ukeys, hx, y, k, ls)
    union_keys = sorted(set().union(*[set(s.tolist()) for s, _ in per_l.values()]))
    union_keys = np.asarray(union_keys, dtype=ukeys.dtype)
    key_to_idx = {x: i for i, x in enumerate(ukeys.tolist())}

    # per-l seeds for exclusion-adjusted thresholds
    seeds = {l: seed_for_l(hx, y, l) for l in ls}
    sorted_seeds = {l: np.sort(s) for l, s in seeds.items()}

    taus_per_key = []
    w_sampled = []
    for x in union_keys.tolist():
        i = key_to_idx[x]
        w_sampled.append(wx[i])
        taus = {}
        for l in ls:
            s_sorted = sorted_seeds[l]
            if len(s_sorted) <= k:
                # k or fewer keys total: every key is sampled and fewer than
                # k OTHER seeds exist, so the exclusion threshold is +inf
                # (the estimator then uses Phi = 1: the sample is the data).
                taus[l] = math.inf
                continue
            own = seeds[l][i]
            # k-th smallest among OTHERS.  With own removed from the sorted
            # array, that is s_sorted[k] when own ranks within the bottom k
            # (own <= s_sorted[k-1]) and s_sorted[k-1] otherwise.  Under an
            # exact tie own == s_sorted[k-1] == s_sorted[k] both branches
            # return the same value, so <= vs < is immaterial (and ties are
            # hash collisions: measure-zero for the continuous seed law).
            if own <= s_sorted[k - 1]:
                taus[l] = float(s_sorted[k])
            else:
                taus[l] = float(s_sorted[k - 1])
        taus_per_key.append(taus)
    return union_keys, np.asarray(w_sampled), taus_per_key, per_l
