"""Counter-based stateless hashing: the randomness substrate of every sampler.

The paper assumes "perfectly random numbers and hash functions" (§3.4).  We
realize that with splittable integer hashing so that

* the score of an element is a pure function of ``(salt, key, element_id)`` —
  reproducible across stream shards, restarts and the sequential/vectorized
  implementations (this is what makes the fixed-threshold equivalence tests
  *exact*, not statistical);
* per-key randomness (``Hash(x)`` / ``KeyBase(x)``) is a pure function of
  ``(salt, key)``.

Both numpy (host oracle) and jax.numpy (device) variants are provided and are
bit-identical: they share the same uint32 mixing constants (Murmur3-style
avalanche finalizer, strengthened per splitmix32).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_C1 = np.uint32(0x7FEB352D)
_C2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)

# ---------------------------------------------------------------------------
# numpy variants (host / oracle)
# ---------------------------------------------------------------------------


def mix32_np(x: np.ndarray) -> np.ndarray:
    """Avalanche-mix a uint32 array (splitmix32 finalizer)."""
    x = np.array(x, dtype=np.uint32, copy=True)  # never mutate the caller
    x ^= x >> np.uint32(16)
    x = (x * _C1).astype(np.uint32)
    x ^= x >> np.uint32(15)
    x = (x * _C2).astype(np.uint32)
    x ^= x >> np.uint32(16)
    return x


def hash_combine_np(*parts) -> np.ndarray:
    """Hash a tuple of int arrays into uint32 (order-sensitive)."""
    h = np.uint32(0x243F6A88)  # pi fractional bits
    for p in parts:
        p32 = np.asarray(p).astype(np.uint32)
        h = mix32_np(h ^ (p32 + _GOLDEN + (h << np.uint32(6)) + (h >> np.uint32(2))))
    return h


def uniform01_np(h: np.ndarray) -> np.ndarray:
    """uint32 -> float64 in (0, 1): (h + 0.5) / 2^32."""
    return (np.asarray(h, dtype=np.uint64).astype(np.float64) + 0.5) / 4294967296.0


# ---------------------------------------------------------------------------
# jax variants (device) — bit-identical mixing
# ---------------------------------------------------------------------------


def mix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 15)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_combine(*parts):
    h = jnp.uint32(0x243F6A88)
    for p in parts:
        p32 = jnp.asarray(p).astype(jnp.uint32)
        h = mix32(h ^ (p32 + _GOLDEN + (h << 6) + (h >> 2)))
    return h


def uniform01(h):
    """uint32 -> float32 in (0,1).  Uses the top 24 bits for an exact float."""
    return ((h >> 8).astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 16777216.0)


def uniform01_f64_like(h):
    """Match uniform01_np semantics in float32 (for cross-checks)."""
    return (h.astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 4294967296.0)


def exp_from_u(u, rate):
    """Exp[rate] sample from a uniform: -log(1-u)/rate (numpy or jnp)."""
    xp = jnp if isinstance(u, jnp.ndarray) else np
    return -xp.log1p(-u) / rate
