"""Incremental sampler state API: the streaming face of core.vectorized.

The chunked samplers were born as ``lax.scan`` loops over a fully
materialized stream.  This module exposes the *same* per-chunk step
functions as an explicit state machine so long-lived services ingest a
stream piece by piece with O(k) resident state and zero recompute:

    state = init_state(l=20.0, k=4096, chunk=2048)
    state = update(state, key_chunk, weight_chunk)      # one jitted dispatch
    ...
    result = finalize(state)                            # SampleResult

Contracts (verified in tests/test_incremental.py):

* **Same function, same bits.**  ``update`` applies exactly the step the
  one-shot scan applies (``vectorized.fixed_tau_step`` / ``fixed_k_step``),
  with element ids continuing from ``state.n_seen``.  Feeding a stream
  through ``update`` in chunk-aligned pieces and finalizing reproduces the
  one-shot sampler on the concatenated stream **element-exactly** (fixed
  threshold) / identically per lane (fixed-k, same chunk boundaries).
* **Donated buffers.**  The update jits donate the incoming state pytree, so
  steady-state ingestion performs no state copies; never reuse a state you
  passed to ``update`` — use its return value.
* **Multi-l in one dispatch.**  ``init_multi_state`` stacks one fixed-k
  continuous sketch per l of a grid (leading axis |ls|); ``update_multi``
  advances *all* of them per batch in a single device dispatch: the fused
  multi-l capscore kernel (kernels/capscore) scores every lane in one
  VMEM-resident pass over the elements, then the merge/evict step runs
  vmapped across lanes.
* **O(k) checkpoints.**  A state is a flat pytree of fixed-size arrays —
  serialize it with ``jax.tree`` utilities or checkpoint.manager; size is
  independent of how many elements were observed.

Unaligned batches (sizes not a multiple of ``chunk``) are the caller's
concern by design — the pure functions stay shape-static for jit.  The
``IncrementalSampler`` / ``MultiSampler`` wrappers below carry the O(chunk)
host-side remainder buffer and do the padding at finalize, mirroring the
one-shot samplers' end-of-stream padding so exactness is preserved.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64 as _enable_x64

from ..kernels.capscore.ops import capscore_agg, capscore_multi
from .samplers import SampleResult
from . import segments as SG
from .segments import EMPTY, chunk_order, normalize_keys  # noqa: F401 (re-export)
from . import vectorized as VZ

_EMPTY_INT = int(EMPTY)

# normalize_keys lives in core.segments now (so the one-shot samplers'
# ``vectorized._prep`` shares it without an import cycle); re-exported here
# because this module was its historical home.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SamplerState:
    """Streaming sampler state: the scan carry, liberated from the scan.

    ``table`` leaves are [capacity] for a single sketch or [L, capacity] for
    a stacked multi-l state; ``l`` is scalar or [L] to match; ``n_seen`` is
    the stream position (it seeds element ids, shared by all lanes).

    ``bk_keys``/``bk_seeds`` (multi-l states only, else None) carry the
    *lossless* per-lane bottom-(k+1) (key, min element score) summary of
    everything observed — the coordinated-randomness handle that makes
    cross-host merges exact (paper §3.1; core.distributed.merge_bottomk_multi
    + the service reconcile pass).
    """

    table: VZ.TableState
    n_seen: jax.Array   # int32 scalar: elements consumed so far
    l: jax.Array        # float32: cap parameter(s)
    salt: jax.Array     # uint32 scalar
    bk_keys: jax.Array | None = None   # [L, k+1] int32 bottom-k summary keys
    bk_seeds: jax.Array | None = None  # [L, k+1] f32 per-key min element score

    def tree_flatten(self):
        return (self.table, self.n_seen, self.l, self.salt,
                self.bk_keys, self.bk_seeds), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.table.keys.shape[-1]


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Static (compile-time) configuration of an incremental sampler.

    ``host_id`` disambiguates element randomness across hosts that ingest
    disjoint shards of one logical stream: ids become
    ``hash(SALT_SHARD, host_id, position)`` (vectorized.shard_eids) instead
    of the raw position, so no two hosts ever share an element's randomness —
    the precondition for both merge modes of stats.service.  ``None`` (the
    default) keeps raw positions, preserving bit-exact equivalence with the
    one-shot samplers.

    ``evict_every`` (fixed-k only) amortizes the batched eviction: the table
    capacity grows to ``k + evict_every * chunk`` and the eviction pass runs
    only every ``evict_every``-th chunk, so steady-state chunks pay merge
    cost alone.  E=1 (default) is bit-compatible with the one-shot samplers;
    E>1 changes the eviction randomness *schedule* — the sample stays a valid
    fixed-k SH_l sample (count law / unbiasedness are Monte-Carlo validated
    in tests/test_ingest_order.py) but is no longer per-run identical to E=1.

    ``backend`` routes the fused score+aggregate stage of the multi-l update
    (kernels.capscore.ops.capscore_agg): None auto-picks per detected
    accelerator (compiled Pallas on TPU/GPU, XLA elsewhere); 'xla' | 'pallas'
    force a path.  The XLA path is bit-identical to the reference pipeline;
    Pallas reassociates the f32 segment sums in-block (see the kernel).

    ``sort_backend`` routes the shared chunk-order key sort
    (segments.chunk_order): 'pallas' selects the block-local bitonic +
    cross-block merge kernel (kernels.chunksort), 'xla' the stable argsort
    dual.  ``None`` (default) follows ``backend``, so a single knob moves
    the whole chunk step; set it separately to mix routes — both sort routes
    are bit-identical, so this is pure perf routing.
    """

    kind: str = "continuous"
    k: int | None = None          # fixed-k mode when set, else fixed-tau
    chunk: int = 2048
    host_id: int | None = None    # element-id namespace for multi-host runs
    evict_every: int = 1          # fixed-k eviction period E (chunks)
    backend: str | None = None    # capscore_agg dispatch: None|'xla'|'pallas'
    sort_backend: str | None = None  # chunk_order sort; None: follow backend

    @property
    def mode(self) -> str:
        return "fixed_k" if self.k is not None else "fixed_tau"

    @property
    def sort_route(self) -> str | None:
        """Effective chunk_order sort backend (sort_backend, else backend)."""
        return self.sort_backend if self.sort_backend is not None else self.backend

    def eids(self, pos):
        """Element ids for one chunk starting at stream position ``pos``."""
        base = pos + jnp.arange(self.chunk, dtype=jnp.int32)
        if self.host_id is None:
            return base
        return VZ.shard_eids(jnp.uint32(self.host_id), base)


def init_state(l, *, k=None, tau=None, kind="continuous", chunk=2048,
               capacity=8192, salt=0, evict_every=1) -> tuple[SamplerState, SamplerSpec]:
    """Fresh O(k)/O(capacity) sampler state + its static spec.

    Fixed-k (``k`` set): capacity is k + evict_every*chunk so the merges of a
    whole eviction period never overflow before the scheduled eviction (only
    ``kind="continuous"`` supports one-pass fixed-k, as in the one-shot
    sampler).  Fixed-tau (``tau`` set): table of ``capacity`` slots, overflow
    counted and raised at finalize.
    """
    if (k is None) == (tau is None):
        raise ValueError("exactly one of k= / tau= must be given")
    if evict_every < 1:
        raise ValueError(f"evict_every must be >= 1, got {evict_every}")
    if k is not None:
        if kind != "continuous":
            raise ValueError("one-pass fixed-k requires kind='continuous'")
        table = VZ.init_table(k + evict_every * chunk)
    else:
        if evict_every != 1:
            raise ValueError("evict_every applies to fixed-k samplers only")
        table = VZ.init_table(capacity, tau)
    state = SamplerState(
        table=table,
        n_seen=jnp.int32(0),
        l=jnp.float32(l),
        salt=jnp.asarray(salt, jnp.uint32),
    )
    return state, SamplerSpec(kind=kind, k=k, chunk=chunk, evict_every=evict_every)


def _scheduled_evict(table, spec: SamplerSpec, evict_fn):
    """Run ``evict_fn`` on the merged table at the spec's eviction cadence.

    E=1 calls it unconditionally (bit-compatible fast path, no cond); E>1
    evicts only when the chunk counter hits a multiple of E — the lazy
    partition-based schedule.  ``table.step`` may be scalar or [L] (all lanes
    advance in lockstep, so lane 0 decides)."""
    if spec.evict_every == 1:
        return evict_fn(table)
    step = table.step if table.step.ndim == 0 else table.step[0]
    return jax.lax.cond(step % spec.evict_every == 0, evict_fn,
                        lambda t: t, table)


def _update_impl(state: SamplerState, keys, weights, spec: SamplerSpec) -> SamplerState:
    chunk = spec.chunk
    n = keys.shape[0]
    if n % chunk:
        raise ValueError(f"update batch ({n}) must be a multiple of chunk ({chunk})")
    kc = keys.reshape(n // chunk, chunk)
    wc = weights.reshape(n // chunk, chunk)
    max_evict = spec.evict_every * chunk

    def body(carry, xs):
        table, pos = carry
        ck, cw = xs
        eids = spec.eids(pos)
        if spec.mode == "fixed_k":
            # pre-gathered view: score in key order, reduce in the same pass
            order = chunk_order(ck, eids, cw, sort_backend=spec.sort_route)
            agg = VZ.aggregate_continuous(ck, cw, eids, table.tau, state.l,
                                          state.salt, order)
            table = _scheduled_evict(
                VZ.fixed_k_merge(table, agg), spec,
                lambda t: VZ.evict_table(t, k=spec.k, l=state.l,
                                         salt=state.salt, max_evict=max_evict))
        else:
            table = VZ.fixed_tau_step(table, ck, cw, eids, state.l, state.salt,
                                      kind=spec.kind)
        return (table, pos + chunk), None

    (table, pos), _ = jax.lax.scan(body, (state.table, state.n_seen), (kc, wc))
    return SamplerState(table, pos, state.l, state.salt)


_update_donated = functools.partial(jax.jit, static_argnames=("spec",),
                                    donate_argnums=(0,))(_update_impl)
# reprolint: disable=RPL003 -- the flush path (lazy finalize) must keep the
# input state alive and usable after the call; donation would invalidate it
_update_fresh = functools.partial(jax.jit, static_argnames=("spec",))(_update_impl)


def update(state: SamplerState, keys, weights, spec: SamplerSpec, *,
           donate: bool = True) -> SamplerState:
    """Advance the sampler over a chunk-aligned batch in one jitted dispatch.

    With ``donate=True`` (default) the input state's buffers are donated to
    the output — do not touch ``state`` afterwards.  ``donate=False`` leaves
    the input intact (the lazy-finalize flush path).
    """
    fn = _update_donated if donate else _update_fresh
    return fn(state, jnp.asarray(keys), jnp.asarray(weights), spec)


# reprolint: disable=RPL003 -- non-destructive projection: finalize must leave
# the resident table intact so the sampler keeps ingesting after extraction
@functools.partial(jax.jit, static_argnames=("spec",))
def _final_evict(table, l, salt, spec: SamplerSpec):
    """Project a lazily-evicted table down to <= k for extraction.

    With ``evict_every > 1`` the resident table may hold up to
    ``k + E*chunk`` keys between scheduled evictions; finalize runs one
    (non-persisted) eviction round at the current step so the extracted
    sample is a valid fixed-k sample.  Deterministic in the state, so
    repeated finalize calls agree; no-op whenever the table is <= k."""
    return VZ.evict_table(table, k=spec.k, l=l, salt=salt,
                          max_evict=spec.evict_every * spec.chunk)


# reprolint: disable=RPL003 -- non-destructive projection (see _final_evict)
@functools.partial(jax.jit, static_argnames=("spec",))
def _final_evict_multi(table, ls, salt, spec: SamplerSpec):
    return jax.vmap(
        lambda t, l: VZ.evict_table(t, k=spec.k, l=l, salt=salt,
                                    max_evict=spec.evict_every * spec.chunk)
    )(table, ls)


def finalize(state: SamplerState, spec: SamplerSpec) -> SampleResult:
    """Extract the SampleResult; the state remains usable for more updates."""
    st = state.table
    overflow = int(jax.device_get(st.overflow))
    if overflow > 0:
        raise RuntimeError(
            f"fixed-tau capacity overflow ({overflow}); raise capacity")
    if spec.mode == "fixed_k" and spec.evict_every > 1:
        st = _final_evict(st, state.l, state.salt, spec)
    l_host, tau_host = jax.device_get((state.l, st.tau))
    return VZ._to_result(st, l=float(l_host), kind=spec.kind, tau=float(tau_host))


# ---------------------------------------------------------------------------
# Stacked multi-l state: every sketch of an l-grid advances per dispatch
# ---------------------------------------------------------------------------


def init_multi_state(ls, *, k, chunk=2048, salt=0, host_id=None,
                     evict_every=1, backend=None,
                     sort_backend=None) -> tuple[SamplerState, SamplerSpec]:
    """One fixed-k continuous sketch per l, stacked on a leading axis, plus a
    lossless per-lane bottom-(k+1) summary for exact cross-host merging.

    ``evict_every=E`` opts into amortized eviction: capacity k + E*chunk,
    eviction every E chunks (see SamplerSpec; E=1 is bit-compatible with
    the one-shot samplers).  ``backend`` routes the fused score+aggregate
    stage and ``sort_backend`` the shared chunk-order sort (see
    SamplerSpec)."""
    if evict_every < 1:
        raise ValueError(f"evict_every must be >= 1, got {evict_every}")
    ls = np.asarray(ls, np.float32)
    L = len(ls)
    capacity = k + evict_every * chunk
    table = VZ.TableState(
        keys=jnp.full((L, capacity), EMPTY, dtype=jnp.int32),
        counts=jnp.zeros((L, capacity), jnp.float32),
        kb=jnp.full((L, capacity), jnp.inf, jnp.float32),
        seed=jnp.full((L, capacity), jnp.inf, jnp.float32),
        tau=jnp.full((L,), jnp.inf, jnp.float32),
        step=jnp.zeros((L,), jnp.int32),
        overflow=jnp.zeros((L,), jnp.int32),
    )
    state = SamplerState(
        table=table,
        n_seen=jnp.int32(0),
        l=jnp.asarray(ls),
        salt=jnp.asarray(salt, jnp.uint32),
        bk_keys=jnp.full((L, k + 1), EMPTY, dtype=jnp.int32),
        bk_seeds=jnp.full((L, k + 1), jnp.inf, jnp.float32),
    )
    return state, SamplerSpec(kind="continuous", k=k, chunk=chunk,
                              host_id=host_id, evict_every=evict_every,
                              backend=backend, sort_backend=sort_backend)


def _multi_chunk_step(table, bk_keys, bk_seeds, pos, ck, cw, l, salt,
                      spec: SamplerSpec):
    """One chunk through the fused multi-l step (summaries carried KEY-sorted).

    The shared body of ``_update_multi_impl``'s scan and the per-tenant vmap
    of ``_update_bank_impl``:

    1. **Permute once**: the chunk is sorted by key exactly once
       (``chunk_order``), WITH the pre-gathered (eids, weights) view — the
       only gathers of the whole step.
    2. **Score in key order, reduce in the same pass**: ``capscore_agg``
       scores every l lane on the pre-gathered view (element randomness
       hangs off (key, eid) values, so scoring is permutation-covariant) and
       segment-reduces the scores into the per-unique-key ChunkAgg columns
       [L, C] directly — the [L, N] score/delta/entry/kb intermediates never
       exist as arrays between stages, and the lane-independent ``w_total``
       is computed once instead of L times.
    3. The per-lane sorted-runs table merges consume the already-key-sorted
       aggregate columns; eviction runs on the spec's cadence with a
       backend-fastest threshold selection.
    4. The aggregate's ``min_score`` column IS the pass-1 chunk summary
       (element scores are tau-independent), so the lossless bottom-(k+1)
       summaries advance with no re-scoring and no reorder — on a KEY-sorted
       carry (``pass1_fold_keysorted``: searchsorted/gather/value-sort, no
       argsort, no TopK, no segment scatters), converted to/from the
       seed-sorted state layout once per batch at the scan boundary.
    """
    cap_bk = bk_keys.shape[-1]
    max_evict = spec.evict_every * spec.chunk
    eids = spec.eids(pos)
    # the ONE chunk sort, with the pre-gathered view for ordered scoring
    order = chunk_order(ck, eids, cw, sort_backend=spec.sort_route)
    # fused: score every l lane AND reduce to per-key columns in one pass
    w_total, entered, contrib, kb_min, min_score = capscore_agg(
        order.ks, order.eids, order.ws, order.seg, l, table.tau,
        salt, backend=spec.backend)

    def lane_merge(tab, en, ct, kbm, ms):
        # l is already baked into the per-lane aggregate columns; the
        # merge itself is l-independent (w_total/ukeys shared by closure)
        agg = VZ.ChunkAgg(ukeys=order.ukeys, w_total=w_total, entered=en,
                          contrib=ct, kb=kbm, min_score=ms)
        return VZ.fixed_k_merge(tab, agg)

    table = jax.vmap(lane_merge)(table, entered, contrib, kb_min, min_score)
    table = _scheduled_evict(
        table, spec,
        lambda t: jax.vmap(
            lambda tab, ll: VZ.evict_table(tab, k=spec.k, l=ll, salt=salt,
                                           max_evict=max_evict)
        )(t, l))
    # min_score doubles as the (already key-ordered) pass-1 chunk
    # summary; the key-sorted carry folds it in sort-free
    bk_keys, bk_seeds = jax.vmap(
        lambda sk, ss, mn: VZ.pass1_fold_keysorted(sk, ss, order.ukeys,
                                                   mn, cap_bk)
    )(bk_keys, bk_seeds, min_score)
    return table, bk_keys, bk_seeds, pos + spec.chunk


def _update_multi_impl(state: SamplerState, keys, weights, spec: SamplerSpec) -> SamplerState:
    """The permute-once / score-ordered / reduce-fused multi-l batch update:
    a scan of ``_multi_chunk_step`` with the bottom-(k+1) summaries converted
    to/from the key-sorted carry layout once per batch at the scan boundary.

    Bit-identical per lane to the pre-restructure path
    (``_update_multi_reference_impl``) at evict_every=1 — tables, taus, AND
    summaries (tests/test_ingest_order.py).
    """
    chunk = spec.chunk
    n = keys.shape[0]
    if n % chunk:
        raise ValueError(f"update batch ({n}) must be a multiple of chunk ({chunk})")
    kc = keys.reshape(n // chunk, chunk)
    wc = weights.reshape(n // chunk, chunk)

    cap_bk = state.bk_keys.shape[1]
    bkk0, bks0 = jax.vmap(VZ.summary_to_keysorted)(state.bk_keys, state.bk_seeds)

    def body(carry, xs):
        table, bk_keys, bk_seeds, pos = carry
        ck, cw = xs
        table, bk_keys, bk_seeds, pos = _multi_chunk_step(
            table, bk_keys, bk_seeds, pos, ck, cw, state.l, state.salt, spec)
        return (table, bk_keys, bk_seeds, pos), None

    (table, bkk, bks, pos), _ = jax.lax.scan(
        body, (state.table, bkk0, bks0, state.n_seen), (kc, wc))
    bk_keys, bk_seeds = jax.vmap(
        lambda kk, ss: VZ.summary_from_keysorted(kk, ss, cap_bk))(bkk, bks)
    return SamplerState(table, pos, state.l, state.salt, bk_keys, bk_seeds)


def _update_multi_reference_impl(state: SamplerState, keys, weights,
                                 spec: SamplerSpec) -> SamplerState:
    """The pre-PR multi-l chunk step, verbatim: every lane re-sorts the chunk
    inside its aggregate, re-sorts the whole table in its merge, and
    full-sorts the eviction race; the summary advance sorts the chunk once
    more.  L+1 chunk sorts + L table sorts per chunk.  Kept as the
    bit-identity oracle (tests/test_ingest_order.py) and the baseline of
    benchmarks/sampler_throughput.py — supports evict_every=1 only."""
    if spec.evict_every != 1:
        raise ValueError("reference path supports evict_every=1 only")
    chunk = spec.chunk
    n = keys.shape[0]
    if n % chunk:
        raise ValueError(f"update batch ({n}) must be a multiple of chunk ({chunk})")
    kc = keys.reshape(n // chunk, chunk)
    wc = weights.reshape(n // chunk, chunk)

    def lane_step(table, ck, cw, score, delta, entry, kb, l):
        return VZ.fixed_k_step_scored_ref(table, ck, cw, score, delta, entry, kb,
                                          k=spec.k, l=l, salt=state.salt)

    vstep = jax.vmap(lane_step, in_axes=(0, None, None, 0, 0, 0, 0, 0))

    cap_bk = state.bk_keys.shape[1]

    def body(carry, xs):
        table, bk_keys, bk_seeds, pos = carry
        ck, cw = xs
        eids = spec.eids(pos)
        # spec.backend keeps the oracle's scoring on the same kernel route as
        # the fused path per bench leg; capscore_multi is elementwise, so the
        # routes are bit-identical and the oracle's answers never move
        score, delta, entry, kb = capscore_multi(ck, eids, cw, state.l, table.tau,
                                                 state.salt, backend=spec.backend)
        table = vstep(table, ck, cw, score, delta, entry, kb, state.l)
        bk_keys, bk_seeds = VZ.pass1_step_multi(
            (bk_keys, bk_seeds), ck, score, cap=cap_bk)
        return (table, bk_keys, bk_seeds, pos + chunk), None

    (table, bk_keys, bk_seeds, pos), _ = jax.lax.scan(
        body, (state.table, state.bk_keys, state.bk_seeds, state.n_seen), (kc, wc))
    return SamplerState(table, pos, state.l, state.salt, bk_keys, bk_seeds)


_update_multi_donated = functools.partial(jax.jit, static_argnames=("spec",),
                                          donate_argnums=(0,))(_update_multi_impl)
# reprolint: disable=RPL003 -- flush path: input state must survive the call
_update_multi_fresh = functools.partial(jax.jit, static_argnames=("spec",))(_update_multi_impl)
_update_multi_ref_donated = functools.partial(
    jax.jit, static_argnames=("spec",), donate_argnums=(0,))(_update_multi_reference_impl)
# reprolint: disable=RPL003 -- flush path: input state must survive the call
_update_multi_ref_fresh = functools.partial(
    jax.jit, static_argnames=("spec",))(_update_multi_reference_impl)


def update_multi(state: SamplerState, keys, weights, spec: SamplerSpec, *,
                 donate: bool = True, reference: bool = False) -> SamplerState:
    """Advance every l-lane sketch over a chunk-aligned batch: one dispatch.

    ``reference=True`` routes through the pre-single-sort step (bit-identical
    results at evict_every=1, strictly slower) — benchmarking/testing only.
    """
    if reference:
        fn = _update_multi_ref_donated if donate else _update_multi_ref_fresh
    else:
        fn = _update_multi_donated if donate else _update_multi_fresh
    return fn(state, jnp.asarray(keys), jnp.asarray(weights), spec)


def finalize_multi(state: SamplerState, spec: SamplerSpec,
                   ls=None) -> dict[float, SampleResult]:
    """Per-lane SampleResults, keyed by l (host-side extraction).

    ``ls`` supplies the dict keys (the caller's original, full-precision l
    values); defaults to the f32 lane values stored in the state.  Pass the
    configured grid so lookups like ``results[3.3]`` don't miss on f32
    rounding.
    """
    table = state.table
    if spec.evict_every > 1:
        table = _final_evict_multi(table, state.l, state.salt, spec)
    tables = jax.device_get(table)
    if ls is None:
        ls = np.asarray(state.l)
    out = {}
    for j, l in enumerate(ls):
        st = jax.tree.map(lambda a: a[j], tables)
        out[float(l)] = VZ._to_result(st, l=float(l), kind=spec.kind,
                                      tau=float(st.tau))
    return out


# ---------------------------------------------------------------------------
# Stacked tenant banks: N resident sampler instances (tenant x l-grid) in one
# pytree, all advanced by a single vmapped/jitted dispatch per ingest tick —
# the multi-tenant analogue of the multi-l lane stacking above.
# ---------------------------------------------------------------------------


def init_bank_state(ls, *, n_tenants, k, chunk=2048, salts=0, host_id=None,
                    evict_every=1, backend=None,
                    sort_backend=None) -> tuple[SamplerState, SamplerSpec]:
    """A stacked bank of ``n_tenants`` independent multi-l sampler instances.

    Leaves gain a leading tenant axis: table leaves are [T, L, capacity],
    summaries [T, L, k+1], ``n_seen`` [T] (every tenant is its own stream
    with its own element-id positions), ``salt`` [T] (``salts`` may be one
    int shared by all tenants or a per-tenant sequence — per-tenant salts
    decorrelate the tenants' key randomness, shared salts keep each tenant
    bit-identical to a standalone sampler built with that salt).  ``l`` stays
    [L]: the grid is shared bank-wide (static shapes are what make the one
    stacked dispatch possible).
    """
    if evict_every < 1:
        raise ValueError(f"evict_every must be >= 1, got {evict_every}")
    if n_tenants < 1:
        raise ValueError(f"n_tenants must be >= 1, got {n_tenants}")
    ls = np.asarray(ls, np.float32)
    T, L = int(n_tenants), len(ls)
    salts_arr = np.broadcast_to(np.asarray(salts, np.uint32), (T,)).copy()
    capacity = k + evict_every * chunk
    table = VZ.TableState(
        keys=jnp.full((T, L, capacity), EMPTY, dtype=jnp.int32),
        counts=jnp.zeros((T, L, capacity), jnp.float32),
        kb=jnp.full((T, L, capacity), jnp.inf, jnp.float32),
        seed=jnp.full((T, L, capacity), jnp.inf, jnp.float32),
        tau=jnp.full((T, L), jnp.inf, jnp.float32),
        step=jnp.zeros((T, L), jnp.int32),
        overflow=jnp.zeros((T, L), jnp.int32),
    )
    state = SamplerState(
        table=table,
        n_seen=jnp.zeros((T,), jnp.int32),
        l=jnp.asarray(ls),
        salt=jnp.asarray(salts_arr),
        bk_keys=jnp.full((T, L, k + 1), EMPTY, dtype=jnp.int32),
        bk_seeds=jnp.full((T, L, k + 1), jnp.inf, jnp.float32),
    )
    return state, SamplerSpec(kind="continuous", k=k, chunk=chunk,
                              host_id=host_id, evict_every=evict_every,
                              backend=backend, sort_backend=sort_backend)


def _mask_tenants(active, new, old):
    """Per-leaf select: tenants with ``active[t]`` take the updated leaf row,
    the rest keep their previous state bit-for-bit (their dispatch lane ran
    on an EMPTY padding chunk whose results are discarded here)."""
    sel = lambda n, o: jnp.where(
        active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
    return jax.tree.map(sel, new, old)


def _update_bank_impl(state: SamplerState, keys, weights, active,
                      spec: SamplerSpec) -> SamplerState:
    """One bank tick: ONE chunk per tenant, every tenant's L lanes advanced by
    a single vmapped dispatch of the fused multi-l chunk step.

    ``keys``/``weights`` are [T, chunk] (EMPTY/0 rows for inactive tenants),
    ``active`` is a [T] bool mask.  Inactive tenants' lanes still flow through
    the vmapped compute (static shapes) but their state — table, summaries
    AND stream position — passes through unchanged, so a tenant's trajectory
    depends only on ITS chunk sequence: each tenant stays bit-identical to a
    standalone ``MultiSampler`` fed the same chunks (property-tested in
    tests/test_serving.py).
    """
    cap_bk = state.bk_keys.shape[-1]
    bkk0, bks0 = jax.vmap(jax.vmap(VZ.summary_to_keysorted))(
        state.bk_keys, state.bk_seeds)

    def tenant_step(table, bkk, bks, pos, ck, cw, salt):
        return _multi_chunk_step(table, bkk, bks, pos, ck, cw, state.l, salt,
                                 spec)

    table, bkk, bks, pos = jax.vmap(tenant_step)(
        state.table, bkk0, bks0, state.n_seen, keys, weights, state.salt)
    bk_keys, bk_seeds = jax.vmap(jax.vmap(
        lambda kk, ss: VZ.summary_from_keysorted(kk, ss, cap_bk)))(bkk, bks)

    table = _mask_tenants(active, table, state.table)
    bk_keys = _mask_tenants(active, bk_keys, state.bk_keys)
    bk_seeds = _mask_tenants(active, bk_seeds, state.bk_seeds)
    pos = jnp.where(active, pos, state.n_seen)
    return SamplerState(table, pos, state.l, state.salt, bk_keys, bk_seeds)


_update_bank_donated = functools.partial(jax.jit, static_argnames=("spec",),
                                         donate_argnums=(0,))(_update_bank_impl)
# reprolint: disable=RPL003 -- flush path: input state must survive the call
_update_bank_fresh = functools.partial(jax.jit, static_argnames=("spec",))(_update_bank_impl)


def update_bank(state: SamplerState, keys, weights, active, spec: SamplerSpec,
                *, donate: bool = True) -> SamplerState:
    """Advance every active tenant's l-grid by one chunk: one device dispatch
    for the whole bank.  Same donation contract as ``update``/``update_multi``.
    """
    fn = _update_bank_donated if donate else _update_bank_fresh
    return fn(state, jnp.asarray(keys), jnp.asarray(weights),
              jnp.asarray(active), spec)


# reprolint: disable=RPL003 -- non-destructive projection (see _final_evict)
@functools.partial(jax.jit, static_argnames=("spec",))
def _final_evict_bank(table, ls, salts, spec: SamplerSpec):
    return jax.vmap(lambda t, s: jax.vmap(
        lambda tab, l: VZ.evict_table(tab, k=spec.k, l=l, salt=s,
                                      max_evict=spec.evict_every * spec.chunk)
    )(t, ls))(table, salts)


# ---------------------------------------------------------------------------
# Jitted multi-lane pass II: exact-weight accumulation over stacked bottom-k
# ---------------------------------------------------------------------------


def init_pass2(lane_keys: list[np.ndarray], cap: int | None = None):
    """Device-resident pass-II accumulator over per-lane sorted sample keys.

    ``lane_keys``: one *sorted* int32 key array per lane (each <= k long, no
    EMPTY).  Returns (stacked_keys [L, cap] jnp int32 EMPTY-padded,
    acc [L, cap] jnp float64 zeros).  Run every shard of the stream through
    ``pass2_accumulate``; slice ``acc[j, :len(lane_keys[j])]`` at the end.
    """
    L = len(lane_keys)
    cap = max(1, cap if cap is not None else max((len(k) for k in lane_keys),
                                                 default=1))
    keys = np.full((L, cap), _EMPTY_INT, np.int32)
    for j, kk in enumerate(lane_keys):
        keys[j, : len(kk)] = kk
    with _enable_x64():
        return jnp.asarray(keys), jnp.zeros((L, cap), jnp.float64)


@functools.partial(jax.jit, donate_argnums=(1,))
def _pass2_accum_impl(skeys, acc, keys, w):
    def lane(sk, a):
        loc = jnp.clip(SG.searchsorted(sk, keys), 0, sk.shape[0] - 1)
        match = sk[loc] == keys
        return a.at[loc].add(jnp.where(match, w, 0.0))

    return jax.vmap(lane)(skeys, acc)


def pass2_accumulate(skeys, acc, keys, weights=None, *, pad_to: int = 256):
    """Advance every lane's exact-weight accumulator by one stream batch in a
    single jitted dispatch (the device form of the paper's pass II).

    Replaces the historical per-lane host loop of ``np.searchsorted`` +
    ``np.add.at``: all lanes share one device dispatch, the scatter-add is
    bit-identical to ``np.add.at`` on CPU, and the donated accumulator makes
    steady-state reconciliation copy-free.  Batches are padded to power-of-
    two buckets (>= ``pad_to``) with EMPTY keys / zero weights so arbitrary
    batch sizes reuse a handful of compiled shapes.
    """
    keys = normalize_keys(keys)
    n = len(keys)
    w = (np.ones(n, np.float64) if weights is None
         else np.asarray(weights, np.float64).reshape(-1))
    if len(w) != n:
        raise ValueError(f"weights length {len(w)} != keys length {n}")
    m = max(pad_to, 1 << max(0, (n - 1).bit_length()))
    if m != n:
        keys = np.concatenate([keys, np.full(m - n, _EMPTY_INT, np.int32)])
        w = np.concatenate([w, np.zeros(m - n, np.float64)])
    with _enable_x64():
        return _pass2_accum_impl(skeys, acc, jnp.asarray(keys), jnp.asarray(w))


# ---------------------------------------------------------------------------
# Host-side wrappers: remainder buffering for unaligned batches
# ---------------------------------------------------------------------------


class _RemainderBuffer:
    """O(chunk) staging area between arbitrary observe() batches and the
    chunk-aligned jitted update."""

    def __init__(self, chunk: int):
        self.chunk = chunk
        self.keys = np.zeros(0, np.int32)
        self.weights = np.zeros(0, np.float32)

    def add(self, keys, weights):
        """Append; return the chunk-aligned prefix ready for dispatch.

        ``keys`` must already be normalized (``normalize_keys``) — both
        stateful samplers do this in ``observe``.
        """
        keys = np.concatenate([self.keys, np.asarray(keys, np.int32).reshape(-1)])
        if weights is None:
            weights = np.ones(len(keys) - len(self.weights), np.float32)
        weights = np.concatenate(
            [self.weights, np.asarray(weights, np.float32).reshape(-1)])
        m = (len(keys) // self.chunk) * self.chunk
        self.keys, self.weights = keys[m:], weights[m:]
        return (keys[:m], weights[:m]) if m else (None, None)

    def flush_padded(self):
        """The trailing partial chunk, EMPTY/0-padded to one full chunk —
        exactly the padding the one-shot samplers apply at end-of-stream."""
        if not len(self.keys):
            return None, None
        pad = self.chunk - len(self.keys)
        keys = np.concatenate([self.keys, np.full(pad, int(EMPTY), np.int32)])
        weights = np.concatenate([self.weights, np.zeros(pad, np.float32)])
        return keys, weights

    def state_dict(self) -> dict:
        """Fixed-shape payload ([chunk] + a length scalar) so checkpoints
        restore into a fresh buffer regardless of current fill level."""
        pad = self.chunk - len(self.keys)
        return {
            "rem_keys": np.concatenate([self.keys, np.zeros(pad, np.int32)]),
            "rem_weights": np.concatenate([self.weights, np.zeros(pad, np.float32)]),
            "rem_len": np.int32(len(self.keys)),
        }

    def load_state_dict(self, d: dict) -> None:
        m = int(d["rem_len"])
        self.keys = np.asarray(d["rem_keys"], np.int32)[:m]
        self.weights = np.asarray(d["rem_weights"], np.float32)[:m]

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.weights.nbytes


class IncrementalSampler:
    """Single-sketch streaming sampler with arbitrary batch sizes.

    Thin stateful shell over the pure API: buffers the sub-chunk remainder on
    host, dispatches chunk-aligned prefixes through the donated update, and
    pads only at (non-destructive) finalize.
    """

    def __init__(self, l, *, k=None, tau=None, kind="continuous", chunk=2048,
                 capacity=8192, salt=0, host_id=None, evict_every=1):
        self.state, self.spec = init_state(
            l, k=k, tau=tau, kind=kind, chunk=chunk, capacity=capacity, salt=salt,
            evict_every=evict_every)
        if host_id is not None:
            self.spec = dataclasses.replace(self.spec, host_id=host_id)
        self._rem = _RemainderBuffer(chunk)

    def observe(self, keys, weights=None) -> None:
        bk, bw = self._rem.add(normalize_keys(keys), weights)
        if bk is not None:
            self.state = update(self.state, bk, bw, self.spec)

    def flushed_state(self) -> SamplerState:
        """State with the (padded) sub-chunk remainder folded in — what
        finalize sees; the live state is left untouched."""
        state = self.state
        fk, fw = self._rem.flush_padded()
        if fk is not None:
            state = update(state, fk, fw, self.spec, donate=False)
        return state

    def finalize(self) -> SampleResult:
        """Current sample over everything observed; ingestion may continue."""
        return finalize(self.flushed_state(), self.spec)

    @property
    def n_observed(self) -> int:
        return int(self.state.n_seen) + len(self._rem.keys)


class MultiSampler:
    """l-grid streaming sampler: all lanes advance in one dispatch/batch.

    Besides the fixed-k sketches, every lane carries the lossless
    bottom-(k+1) (key, seed) summary of the observed stream — O(k) extra
    state that makes cross-host merges exact (see stats.service).  Multi-host
    deployments must give each host a distinct ``host_id`` so element
    randomness never aliases across shards.
    """

    def __init__(self, ls, *, k, chunk=2048, salt=0, host_id=None,
                 evict_every=1, backend=None, sort_backend=None):
        self.ls = tuple(float(l) for l in ls)  # full-precision query keys
        self.state, self.spec = init_multi_state(
            ls, k=k, chunk=chunk, salt=salt, host_id=host_id,
            evict_every=evict_every, backend=backend,
            sort_backend=sort_backend)
        self._rem = _RemainderBuffer(chunk)
        self._n_real = 0  # real (non-padding) elements, incl. merged-in hosts

    def observe(self, keys, weights=None) -> None:
        keys = normalize_keys(keys)
        self._n_real += len(keys)
        bk, bw = self._rem.add(keys, weights)
        if bk is not None:
            self.state = update_multi(self.state, bk, bw, self.spec)

    def flushed_state(self) -> SamplerState:
        """State with the (padded) sub-chunk remainder folded in — what
        finalize sees; the live state is left untouched.  Use this when
        handing the table to merge_fixed_k so trailing elements count."""
        state = self.state
        fk, fw = self._rem.flush_padded()
        if fk is not None:
            state = update_multi(state, fk, fw, self.spec, donate=False)
        return state

    def absorb(self, other: "MultiSampler", *, k, merge_summaries: bool) -> None:
        """Fold another host's sampler into this one (both flushed first).

        The fixed-k tables merge through the 1-pass heuristic
        (distributed.merge_fixed_k_multi); with ``merge_summaries`` the
        lossless bottom-(k+1) summaries min-merge too (exact mode).  Both
        remainders are flushed *in their own host's element-id namespace* —
        never re-scored under the absorbing host's ids, which would draw
        fresh randomness for already-scored elements and bias the summaries.
        """
        self.absorb_many([other], k=k, merge_summaries=merge_summaries)

    def absorb_many(self, others, *, k, merge_summaries: bool) -> None:
        """Fold any number of other hosts' samplers into this one at once —
        bit-identical to calling ``absorb`` on each in sequence (the fixed-k
        fold is a left fold; see distributed.merge_fixed_k_multi_states).
        This is the partial-merge surface the shard-tier coordinator uses to
        fold a subset of surviving shards in one shot."""
        from . import distributed as DZ

        others = list(others)
        if not others:
            return
        states = [self.flushed_state()] + [o.flushed_state() for o in others]
        mine = states[0]
        table = DZ.merge_fixed_k_multi_states(
            [s.table for s in states], mine.l, mine.salt, k=k)
        if merge_summaries:
            bk_keys, bk_seeds = DZ.merge_bottomk_multi_states(
                [(s.bk_keys, s.bk_seeds) for s in states],
                cap=mine.bk_keys.shape[1])
        else:
            bk_keys, bk_seeds = mine.bk_keys, mine.bk_seeds
        n_seen = mine.n_seen
        for s in states[1:]:
            n_seen = n_seen + s.n_seen
        self.state = SamplerState(
            table=table,
            n_seen=n_seen,
            l=mine.l, salt=mine.salt,
            bk_keys=bk_keys, bk_seeds=bk_seeds,
        )
        # remainders are inside the merged state now
        self._n_real += sum(o._n_real for o in others)
        self._rem = _RemainderBuffer(self.spec.chunk)

    def finalize(self) -> dict[float, SampleResult]:
        return finalize_multi(self.flushed_state(), self.spec, ls=self.ls)

    def bottomk_summaries(self) -> tuple[np.ndarray, np.ndarray]:
        """Host copies of the flushed per-lane bottom-(k+1) summaries:
        ([L, k+1] keys, [L, k+1] seeds)."""
        st = self.flushed_state()
        return np.asarray(st.bk_keys), np.asarray(st.bk_seeds)

    @property
    def n_observed(self) -> int:
        return self._n_real

    # -- serialization (O(k * |ls| + chunk), independent of stream length) --

    def state_dict(self) -> dict:
        st = jax.device_get(self.state)
        t = st.table
        d = {
            "keys": t.keys, "counts": t.counts, "kb": t.kb, "seed": t.seed,
            "tau": t.tau, "step": t.step, "overflow": t.overflow,
            "bk_keys": st.bk_keys, "bk_seeds": st.bk_seeds,
            "n_seen": np.int32(st.n_seen),
            "n_real": np.int64(self._n_real),
            "ls": np.asarray(st.l),
            "salt": np.uint32(st.salt),
        }
        d.update(self._rem.state_dict())
        return d

    def load_state_dict(self, d: dict) -> None:
        # re-canonicalize the table layout: blobs written before the
        # single-sort ingest path stored eviction holes in place, while the
        # sorted-runs merge requires ascending keys with EMPTY compacted last
        # (a stable per-lane key sort is a no-op on current-format blobs)
        blob_keys = np.asarray(d["keys"], np.int32)
        if blob_keys.shape[-1] != self.state.capacity:
            # capacity is k + evict_every*chunk: a blob written under a
            # different evict_every would silently truncate merges (E too
            # small) or overflow the top_k eviction window (E too large)
            raise ValueError(
                f"state blob table capacity {blob_keys.shape[-1]} != configured "
                f"capacity {self.state.capacity} (k + evict_every*chunk) — "
                "restore with the same (k, chunk, evict_every) the blob was "
                "written with")
        ord_ = np.argsort(blob_keys, axis=1, kind="stable")
        tab = lambda name, dt: jnp.asarray(
            np.take_along_axis(np.asarray(d[name], dt), ord_, axis=1))
        table = VZ.TableState(
            keys=tab("keys", np.int32), counts=tab("counts", np.float32),
            kb=tab("kb", np.float32), seed=tab("seed", np.float32),
            tau=jnp.asarray(d["tau"]),
            step=jnp.asarray(d["step"]), overflow=jnp.asarray(d["overflow"]),
        )
        # blobs written before the summary buffers existed load with fresh
        # (empty) summaries — the caller must treat them as invalid for
        # exact merging (stats.service keys this off the same absence)
        L, cap_bk = table.keys.shape[0], (self.spec.k or 0) + 1
        bk_keys = (jnp.asarray(d["bk_keys"], jnp.int32) if "bk_keys" in d
                   else jnp.full((L, cap_bk), EMPTY, jnp.int32))
        bk_seeds = (jnp.asarray(d["bk_seeds"], jnp.float32) if "bk_seeds" in d
                    else jnp.full((L, cap_bk), jnp.inf, jnp.float32))
        self.state = SamplerState(
            table=table,
            n_seen=jnp.asarray(d["n_seen"], jnp.int32),
            l=jnp.asarray(d["ls"], jnp.float32),
            salt=jnp.asarray(d["salt"], jnp.uint32),
            bk_keys=bk_keys, bk_seeds=bk_seeds,
        )
        self._rem.load_state_dict(d)
        self._n_real = int(d["n_real"]) if "n_real" in d else (
            int(self.state.n_seen) + len(self._rem.keys))

    @property
    def resident_bytes(self) -> int:
        """Device-resident sketch bytes + host remainder bytes."""
        leaves = jax.tree.leaves(self.state)
        return sum(int(np.asarray(x).nbytes) for x in leaves) + self._rem.nbytes


class _PendingQueue:
    """Per-tenant ingest staging: an O(backlog) list of arrays with O(1)
    appends; ``take``/``peek`` concatenate lazily.  Unlike _RemainderBuffer
    this may hold many chunks — the bank drains one chunk per tick."""

    def __init__(self):
        self._keys: list[np.ndarray] = []
        self._weights: list[np.ndarray] = []
        self.size = 0

    def push(self, keys: np.ndarray, weights) -> None:
        """``keys`` must already be normalized (int32, validated)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        if weights is None:
            weights = np.ones(len(keys), np.float32)
        weights = np.asarray(weights, np.float32).reshape(-1)
        if len(weights) != len(keys):
            raise ValueError(
                f"weights length {len(weights)} != keys length {len(keys)}")
        if len(keys):
            self._keys.append(keys)
            self._weights.append(weights)
            self.size += len(keys)

    def _compact(self):
        if len(self._keys) > 1:
            self._keys = [np.concatenate(self._keys)]
            self._weights = [np.concatenate(self._weights)]

    def take(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Pop exactly the oldest ``n`` elements (requires size >= n)."""
        if n > self.size:
            raise ValueError(f"take({n}) from queue of {self.size}")
        self._compact()
        k, w = self._keys[0], self._weights[0]
        self._keys = [k[n:]] if len(k) > n else []
        self._weights = [w[n:]] if len(w) > n else []
        self.size -= n
        return k[:n], w[:n]

    def peek_all(self) -> tuple[np.ndarray, np.ndarray]:
        """Everything queued, without popping."""
        self._compact()
        if not self._keys:
            return np.zeros(0, np.int32), np.zeros(0, np.float32)
        return self._keys[0], self._weights[0]

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self._keys) + sum(
            a.nbytes for a in self._weights)


class TenantBank:
    """N resident multi-l sampler instances advanced as ONE stacked pytree.

    The multi-tenant analogue of ``MultiSampler``: ``observe(tenant, ...)``
    stages elements in per-tenant host queues; each ``tick()`` drains one
    chunk from EVERY tenant with a full chunk buffered and advances all of
    their l-grids in a single vmapped/jitted device dispatch with donated
    buffers.  Sub-chunk remainders stay queued (the per-tenant analogue of
    MultiSampler's remainder buffer) and are folded in — padded, without
    consuming real stream positions — only at finalize/state_dict time.

    Per-tenant bit-identity contract (tests/test_serving.py): tenant ``t`` of
    a bank fed some chunk sequence finalizes bit-identically (tables, taus,
    bottom-(k+1) summaries, query answers) to a standalone ``MultiSampler``
    constructed with ``salt=salts[t]`` and fed the same sequence — the bank
    is purely a dispatch-batching layout, not a statistical change.

    Checkpointing: ``state_dict`` is one flat dict of [T, ...]-stacked
    fixed-size arrays (saves through checkpoint.manager like any pytree);
    ``tenant_state_dict(t)`` slices out one tenant in the exact
    ``MultiSampler.state_dict`` format, and ``load_tenant_state_dict(t, d)``
    splices one back in — the join/leave handoff surface (see
    checkpoint.manager.restore_slice for restoring a single tenant without
    an example bank).
    """

    def __init__(self, ls, *, n_tenants, k, chunk=2048, salts=0, host_id=None,
                 evict_every=1, backend=None, sort_backend=None):
        self.ls = tuple(float(l) for l in ls)
        self.n_tenants = int(n_tenants)
        self.state, self.spec = init_bank_state(
            ls, n_tenants=n_tenants, k=k, chunk=chunk, salts=salts,
            host_id=host_id, evict_every=evict_every, backend=backend,
            sort_backend=sort_backend)
        self._queues = [_PendingQueue() for _ in range(self.n_tenants)]
        self._n_real = np.zeros(self.n_tenants, np.int64)

    # -- ingestion ---------------------------------------------------------

    def observe(self, tenant: int, keys, weights=None) -> None:
        """Stage a batch of tenant ``tenant``'s stream (host arrays ok); the
        device state advances at the next ``tick``."""
        keys = normalize_keys(keys)
        self._n_real[tenant] += len(keys)
        self._queues[tenant].push(keys, weights)

    def backlog_chunks(self) -> np.ndarray:
        """Full chunks currently buffered, per tenant."""
        return np.asarray([q.size // self.spec.chunk for q in self._queues],
                          np.int64)

    def tick(self) -> int:
        """One stacked dispatch: every tenant with >= 1 full chunk buffered
        advances by exactly one chunk (inherently fair — no tenant can take
        more than one chunk per tick).  Returns the number of active tenants
        (0 = nothing to do, no dispatch issued).  The dispatch is enqueued
        asynchronously — this never blocks on device compute."""
        chunk = self.spec.chunk
        active = np.asarray([q.size >= chunk for q in self._queues])
        if not active.any():
            return 0
        K = np.full((self.n_tenants, chunk), _EMPTY_INT, np.int32)
        W = np.zeros((self.n_tenants, chunk), np.float32)
        for t in np.nonzero(active)[0]:
            K[t], W[t] = self._queues[t].take(chunk)
        self.state = update_bank(self.state, K, W, active, self.spec)
        return int(active.sum())

    def drain(self) -> int:
        """Tick until no tenant holds a full chunk; returns ticks issued."""
        ticks = 0
        while self.tick():
            ticks += 1
        return ticks

    # -- extraction --------------------------------------------------------

    def flushed_state(self) -> SamplerState:
        """Bank state with every queued element folded in: full chunks are
        drained for real, then each non-empty sub-chunk remainder is EMPTY/0
        padded to one chunk and applied WITHOUT donating (live state and
        queues untouched by the padding pass) — exactly the padding a
        standalone MultiSampler applies at finalize."""
        self.drain()
        chunk = self.spec.chunk
        active = np.asarray([q.size > 0 for q in self._queues])
        if not active.any():
            return self.state
        K = np.full((self.n_tenants, chunk), _EMPTY_INT, np.int32)
        W = np.zeros((self.n_tenants, chunk), np.float32)
        for t in np.nonzero(active)[0]:
            kk, ww = self._queues[t].peek_all()
            K[t, : len(kk)], W[t, : len(ww)] = kk, ww
        return update_bank(self.state, K, W, active, self.spec, donate=False)

    def finalize_all(self) -> list[dict[float, SampleResult]]:
        """Every tenant's per-lane SampleResults in ONE device extraction
        (vmapped final eviction + a single device_get of the stacked table),
        indexed ``out[tenant][l]``."""
        st = self.flushed_state()
        table = st.table
        if self.spec.evict_every > 1:
            table = _final_evict_bank(table, st.l, st.salt, self.spec)
        tables = jax.device_get(table)
        out = []
        for t in range(self.n_tenants):
            per = {}
            for j, l in enumerate(self.ls):
                tab = jax.tree.map(lambda a: a[t, j], tables)
                per[l] = VZ._to_result(tab, l=l, kind=self.spec.kind,
                                       tau=float(tab.tau))
            out.append(per)
        return out

    def finalize_some(self, tenants) -> dict[int, dict[float, SampleResult]]:
        """A SUBSET of tenants' per-lane SampleResults, extracting (and
        host-materializing) only those rows of the bank — the serving-tier
        fast path when a query batch touches few of many tenants (the whole
        bank still flushes; only the device→host copy and the per-lane
        result construction are restricted)."""
        st = self.flushed_state()
        idx = np.asarray(sorted({int(t) for t in tenants}), np.int64)
        table = jax.tree.map(lambda a: a[idx], st.table)
        if self.spec.evict_every > 1:
            table = _final_evict_bank(table, st.l, st.salt[idx], self.spec)
        tables = jax.device_get(table)
        out: dict[int, dict[float, SampleResult]] = {}
        for i, t in enumerate(idx.tolist()):
            per = {}
            for j, l in enumerate(self.ls):
                tab = jax.tree.map(lambda a: a[i, j], tables)
                per[l] = VZ._to_result(tab, l=l, kind=self.spec.kind,
                                       tau=float(tab.tau))
            out[t] = per
        return out

    def finalize(self, tenant: int) -> dict[float, SampleResult]:
        """One tenant's per-lane SampleResults (subset extraction; use
        ``finalize_all`` when you need every tenant)."""
        return self.finalize_some([tenant])[tenant]

    def n_observed(self, tenant: int) -> int:
        return int(self._n_real[tenant])

    # -- serialization (O(T * k * |ls| + T * chunk)) -------------------------

    def _remainders(self) -> dict:
        """Fixed-shape per-tenant remainder payload (full chunks drained
        first so every queue fits one [chunk] row)."""
        self.drain()
        chunk = self.spec.chunk
        rk = np.zeros((self.n_tenants, chunk), np.int32)
        rw = np.zeros((self.n_tenants, chunk), np.float32)
        rl = np.zeros(self.n_tenants, np.int32)
        for t, q in enumerate(self._queues):
            kk, ww = q.peek_all()
            rk[t, : len(kk)], rw[t, : len(ww)] = kk, ww
            rl[t] = len(kk)
        return {"rem_keys": rk, "rem_weights": rw, "rem_len": rl}

    def state_dict(self) -> dict:
        """Flat dict of [T, ...]-stacked arrays, leaf-for-leaf parallel to
        ``MultiSampler.state_dict`` (same key names, one extra leading tenant
        axis on per-tenant leaves) so ``checkpoint.manager.restore_slice``
        can restore any single tenant against a MultiSampler-shaped example.
        Drains queued full chunks first (they belong in the checkpoint)."""
        rem = self._remainders()  # drains full chunks INTO the state first
        st = jax.device_get(self.state)
        t = st.table
        d = {
            "keys": t.keys, "counts": t.counts, "kb": t.kb, "seed": t.seed,
            "tau": t.tau, "step": t.step, "overflow": t.overflow,
            "bk_keys": st.bk_keys, "bk_seeds": st.bk_seeds,
            "n_seen": np.asarray(st.n_seen, np.int32),
            "n_real": self._n_real.copy(),
            "ls": np.asarray(st.l),
            "salt": np.asarray(st.salt, np.uint32),
        }
        d.update(rem)
        return d

    def tenant_state_dict(self, tenant: int) -> dict:
        """One tenant, in the exact ``MultiSampler.state_dict`` format —
        loads into a standalone ``MultiSampler``/``StreamStatsService`` (the
        leave/handoff path) bit-for-bit."""
        d = self.state_dict()
        shared = {"ls"}
        return {k: (v if k in shared else v[tenant]) for k, v in d.items()}

    def load_tenant_state_dict(self, tenant: int, d: dict) -> None:
        """Splice a ``MultiSampler``-format blob into one bank row (the join
        path).  Validated by round-tripping through a scratch MultiSampler
        loader (same capacity/layout canonicalization)."""
        probe = MultiSampler(self.ls, k=self.spec.k, chunk=self.spec.chunk,
                             evict_every=self.spec.evict_every)
        probe.load_state_dict(d)
        ps = jax.device_get(probe.state)
        at = lambda arr, new: jnp.asarray(np.asarray(arr)).at[tenant].set(new)
        table = VZ.TableState(
            keys=at(self.state.table.keys, ps.table.keys),
            counts=at(self.state.table.counts, ps.table.counts),
            kb=at(self.state.table.kb, ps.table.kb),
            seed=at(self.state.table.seed, ps.table.seed),
            tau=at(self.state.table.tau, ps.table.tau),
            step=at(self.state.table.step, ps.table.step),
            overflow=at(self.state.table.overflow, ps.table.overflow),
        )
        self.state = SamplerState(
            table=table,
            n_seen=at(self.state.n_seen, ps.n_seen),
            l=self.state.l,
            salt=at(self.state.salt, ps.salt),
            bk_keys=at(self.state.bk_keys, ps.bk_keys),
            bk_seeds=at(self.state.bk_seeds, ps.bk_seeds),
        )
        self._queues[tenant] = _PendingQueue()
        self._queues[tenant].push(
            np.asarray(d["rem_keys"], np.int32)[: int(d["rem_len"])],
            np.asarray(d["rem_weights"], np.float32)[: int(d["rem_len"])])
        self._n_real[tenant] = int(d["n_real"]) if "n_real" in d else 0

    def load_state_dict(self, d: dict) -> None:
        T = self.n_tenants
        if np.asarray(d["keys"]).shape[0] != T:
            raise ValueError(
                f"bank blob has {np.asarray(d['keys']).shape[0]} tenants, "
                f"bank configured with {T}")
        if np.asarray(d["keys"]).shape[-1] != self.state.capacity:
            raise ValueError(
                f"state blob table capacity {np.asarray(d['keys']).shape[-1]} "
                f"!= configured capacity {self.state.capacity} "
                "(k + evict_every*chunk) — restore with the same "
                "(k, chunk, evict_every) the blob was written with")
        # same per-lane layout re-canonicalization as MultiSampler: stable
        # key sort per (tenant, lane) row is a no-op on current-format blobs
        blob_keys = np.asarray(d["keys"], np.int32)
        ord_ = np.argsort(blob_keys, axis=-1, kind="stable")
        tab = lambda name, dt: jnp.asarray(
            np.take_along_axis(np.asarray(d[name], dt), ord_, axis=-1))
        table = VZ.TableState(
            keys=tab("keys", np.int32), counts=tab("counts", np.float32),
            kb=tab("kb", np.float32), seed=tab("seed", np.float32),
            tau=jnp.asarray(d["tau"]),
            step=jnp.asarray(d["step"]), overflow=jnp.asarray(d["overflow"]),
        )
        self.state = SamplerState(
            table=table,
            n_seen=jnp.asarray(d["n_seen"], jnp.int32),
            l=jnp.asarray(d["ls"], jnp.float32),
            salt=jnp.asarray(d["salt"], jnp.uint32),
            bk_keys=jnp.asarray(d["bk_keys"], jnp.int32),
            bk_seeds=jnp.asarray(d["bk_seeds"], jnp.float32),
        )
        self._queues = [_PendingQueue() for _ in range(T)]
        rl = np.asarray(d["rem_len"], np.int32)
        for t in range(T):
            self._queues[t].push(
                np.asarray(d["rem_keys"], np.int32)[t, : rl[t]],
                np.asarray(d["rem_weights"], np.float32)[t, : rl[t]])
        self._n_real = np.asarray(d["n_real"], np.int64).copy()

    @property
    def resident_bytes(self) -> int:
        leaves = jax.tree.leaves(self.state)
        return sum(int(np.asarray(x).nbytes) for x in leaves) + sum(
            q.nbytes for q in self._queues)
