"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients for the DP all-reduce: each 256-value block
stores one f32 scale + int8 payload (~4x smaller collective). The residual
(quantization error) is carried in an error-feedback buffer and re-added next
step — the standard EF-SGD construction that keeps convergence.

The compression is simulated end-to-end inside the step function so XLA sees
the actual int8 collective sizes on the DP axis (visible in §Roofline's
collective term when enabled).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g32):
    n = g32.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([g32.reshape(-1), jnp.zeros((pad,), g32.dtype)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n, pad


def _dequantize(q, scale, n, pad, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        deq = deq[:n]
    return deq.reshape(shape)


def compress_leaf(g, ef):
    g32 = g.astype(jnp.float32) + ef
    q, scale, n, pad = _quantize(g32)
    deq = _dequantize(q, scale, n, pad, g32.shape)
    new_ef = g32 - deq
    return deq.astype(g.dtype), new_ef


def compress_gradients_ef(grads, ef_state):
    """Apply EF-int8 compression to every gradient leaf."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )
