"""AdamW with gradient clipping, cosine schedule, and ZeRO-1 state sharding.

The optimizer state (m, v in f32) dominates memory at scale; `zero1_specs`
shards it over the "data" axis on top of the parameter's TP sharding —
classic ZeRO-1 (each data-parallel rank owns a slice of the states; the
reduce-scatter/all-gather pair this implies shows up in the §Roofline
collective term of train cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def update(cfg: AdamWConfig, params, grads, state):
    count = state["count"] + 1
    # global-norm clip in f32
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gn = jnp.sqrt(
        jax.tree.reduce(lambda a, g: a + jnp.sum(g * g), g32, jnp.float32(0.0))
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    lr = _schedule(cfg, count.astype(jnp.float32))
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        step_ = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(g32)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gn


def zero1_specs(param_shapes, param_specs, *, data_axes=("data",), data_size: int = 1):
    """Optimizer-state PartitionSpecs: param spec + shard the largest
    unsharded, divisible axis over the data axes (ZeRO-1)."""

    def transform(shape_struct, spec):
        shape = shape_struct.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        best, best_dim = None, 0
        for i, (dim, s) in enumerate(zip(shape, parts)):
            if s is None and dim % data_size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None and data_size > 1:
            parts[best] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*parts)

    st = jax.tree.map(transform, param_shapes, param_specs)
    return {"m": st, "v": st, "count": P()}
