"""Mixture-of-Experts layer: top-k routing with capacity-factor dispatch.

GShard-style einsum dispatch, grouped so the one-hot dispatch tensor stays
bounded: tokens are split into G groups of g tokens; per group the dispatch
tensor is [g, E, C] with C = ceil(g * topk / E * capacity_factor).  Under the
production mesh the group axis shards over ("pod","data") and the expert
axis over "model" (expert parallelism) — the all-to-all XLA inserts for the
[G, E, C, D] <-> [G, g, D] exchanges is the EP collective measured in
§Roofline.

Aux losses: load-balancing (Switch) + router z-loss, returned for logging.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init, shard_hint


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int           # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 4096  # tokens per dispatch group
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2


def init_moe(rng, cfg: MoEConfig, dtype):
    ks = jax.random.split(rng, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    import numpy as np

    return {
        "router": dense_init(ks[0], D, E, jnp.float32, scale=0.02),
        "w1": (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)).astype(dtype),
    }


def moe_apply(p, cfg: MoEConfig, x):
    """x: [B, S, D] -> (y [B, S, D], aux dict)."""
    B, S, D = x.shape
    T = B * S
    g = min(cfg.group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    E = cfg.n_experts
    C = max(1, int(g * cfg.top_k / E * cfg.capacity_factor))
    xt = x.reshape(G, g, D)
    xt = shard_hint(xt, P(("pod", "data"), None, None))

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [G,g,E]
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection, one expert at a time (k one-hots)
    dispatch = jnp.zeros((G, g, E, C), dtype=xt.dtype)
    combine = jnp.zeros((G, g, E, C), dtype=xt.dtype)  # bf16: halves the
    # biggest MoE tensor; gate precision loss is ~1e-3 relative (tested)
    remaining = probs
    fill = jnp.zeros((G, E), jnp.int32)
    for _ in range(cfg.top_k):
        sel = jnp.argmax(remaining, axis=-1)                      # [G,g]
        onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)        # [G,g,E]
        pos = jnp.cumsum(onehot, axis=1) - onehot + fill[:, None, :]
        keep = (pos < C) * onehot                                  # [G,g,E]
        posc = jnp.clip(pos, 0, C - 1).astype(jnp.int32)
        oh_c = jax.nn.one_hot(posc, C, dtype=xt.dtype) * keep[..., None].astype(xt.dtype)
        dispatch = dispatch + oh_c
        gate = jnp.take_along_axis(probs, sel[..., None], axis=-1)[..., 0]  # [G,g]
        combine = combine + oh_c * gate[..., None, None].astype(xt.dtype)
        fill = fill + jnp.sum(keep, axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)

    # dispatch -> expert compute -> combine
    dispatch = shard_hint(dispatch, P(("pod", "data"), None, "model", None))
    combine = shard_hint(combine, P(("pod", "data"), None, "model", None))
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xt)               # [G,E,C,D]
    xin = shard_hint(xin, P(("pod", "data"), "model", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w1"]).astype(jnp.float32)).astype(
        xt.dtype
    ) * jnp.einsum("gecd,edf->gecf", xin, p["w3"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w2"])                 # [G,E,C,D]
    y = jnp.einsum("gtec,gecd->gtd", combine, out)
    y = shard_hint(y, P(("pod", "data"), None, None))

    # aux: Switch load-balance + router z-loss (see below)
    aux = _aux_losses(cfg, logits, probs, fill, C)
    return y.reshape(B, S, D), aux


def moe_apply_dense(p, cfg: MoEConfig, x):
    """No-drop MoE for decode: every expert runs on every token; the router
    gates the combine.  Batch-size independent (prefill/decode consistent).

    Memory-traffic argument (decode is memory-bound): reading all expert
    weights once costs E*3*D*F bytes/step, identical to what per-token weight
    gathers would re-read whenever B*top_k >= E — so for decode batches >= E
    this is the traffic-optimal no-drop schedule, and it avoids the gather's
    unaligned HBM access.  FLOPs rise E/top_k-fold but stay far below the
    memory roofline at decode shapes (verified in §Roofline).
    """
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    # top-k gate mask
    thresh = jnp.sort(probs, axis=-1)[:, -cfg.top_k][:, None]
    gates = jnp.where(probs >= thresh, probs, 0.0)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w1"]).astype(jnp.float32)).astype(
        xt.dtype
    ) * jnp.einsum("td,edf->tef", xt, p["w3"])
    out = jnp.einsum("tef,efd->ted", h, p["w2"])                   # [T,E,D]
    y = jnp.einsum("te,ted->td", gates.astype(xt.dtype), out)
    return y.reshape(B, S, D), {}


def _aux_losses(cfg: MoEConfig, logits, probs, fill, C):
    E = cfg.n_experts
    me = jnp.mean(probs, axis=1)                                   # [G,E]
    ce = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=jnp.float32), axis=1
    )
    balance = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {
        "balance_loss": cfg.balance_coef * balance,
        "router_z_loss": cfg.router_z_coef * z,
        "expert_fill": fill.astype(jnp.float32).mean() / C,
    }
