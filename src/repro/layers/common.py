"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


import os


def shard_hint(x, spec: P, tag: str = "generic"):
    """with_sharding_constraint that degrades to a no-op off-mesh.

    Inside jit under a concrete mesh (dry-run / production) this pins the
    layout XLA must produce; in single-device tests it vanishes.  Axis names
    missing from the active mesh are dropped (so specs can reference the
    superset vocabulary "pod"/"data"/"model").

    REPRO_HINTS selects which constraint classes apply ("all" | "sp" |
    "none"): the §Perf hillclimb measured that over-constraining (tag
    "generic" everywhere) forces GSPMD resharding materializations — on
    moonshot train_4k, peak memory 47.1 GiB with all hints vs 20.4 GiB with
    SP-only.  Default is "sp": residual-stream sequence-parallel hints only.
    """
    mode = os.environ.get("REPRO_HINTS", "sp")
    if mode == "none" or (mode == "sp" and tag != "sp"):
        return x
    try:
        from ..parallel.sharding import filter_spec

        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or np.prod(list(mesh.shape.values())) == 1:
            return x
        return jax.lax.with_sharding_constraint(x, filter_spec(spec, tuple(mesh.axis_names)))
    except Exception:
        return x


def dense_init(rng, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    nrm = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (nrm * gamma.astype(jnp.float32)).astype(dt)


def rope_angles(positions, d_head, theta=10000.0):
    """positions: [...]; returns (cos, sin) of shape [..., d_head//2]."""
    inv = 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, D]; cos/sin: [..., S, D//2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """LLaMA-family gated MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu((x @ w1).astype(jnp.float32)).astype(x.dtype) * (x @ w3)
    return h @ w2


def softmax_xent(logits, labels, *, z_loss: float = 0.0):
    """Token cross-entropy in f32 with optional z-loss; labels -100 ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    mask = labels >= 0
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1)
