"""GQA attention layer: train (chunked, differentiable), prefill, decode.

The sharding convention is Megatron-style tensor parallelism over the head
dimension ("model" axis): q/k/v projections column-sharded, output projection
row-sharded; activations between them live as [batch*, seq, heads/model, d].
Decode keeps the KV cache sharded over heads ("model") so a 512k-token cache
fits per-device HBM (see DESIGN.md long_500k note).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.flash_attention.ops import attention as attention_op
from .common import apply_rope, dense_init, rms_norm, rope_angles, shard_hint


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attention_chunk: int = 512
    backend: str | None = "xla_chunked"  # dry-run/train path; pallas on TPU serve
    shard_kv: bool = False  # shard kv heads over "model" only when divisible


def init_attention(rng, cfg: AttentionConfig, dtype):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv * cfg.d_head, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv * cfg.d_head, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), dtype)
        p["k_norm"] = jnp.ones((cfg.d_head,), dtype)
    return p


def _project_qkv(p, cfg: AttentionConfig, x, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard_hint(q, P(("pod", "data"), None, "model", None))
    kv_spec = P(("pod", "data"), None, "model" if cfg.shard_kv else None, None)
    k = shard_hint(k, kv_spec)
    v = shard_hint(v, kv_spec)
    return q, k, v


def attention_train(p, cfg: AttentionConfig, x, positions):
    """Full causal self-attention (training / prefill compute)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = attention_op(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, backend=cfg.backend, chunk=min(cfg.attention_chunk, S),
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ p["wo"]


def attention_prefill(p, cfg: AttentionConfig, x, positions):
    """Like train, but also returns the KV cache [B, S, n_kv, d]."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = attention_op(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, backend=cfg.backend, chunk=min(cfg.attention_chunk, S),
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], (k, v)


def attention_decode(p, cfg: AttentionConfig, x, cache, pos, cache_len):
    """One-token decode against a KV cache.

    x: [B, 1, d_model]; cache: (k, v) each [B, C, n_kv, d] (C = max context);
    pos: [B] current positions; cache entries at index `pos` are written.
    """
    B = x.shape[0]
    ck, cv = cache
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.d_head)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_angles(pos[:, None].astype(jnp.float32), cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # write new kv at pos.  A scatter into the context-sharded cache makes
    # GSPMD replicate the whole cache ("involuntary full rematerialization");
    # a one-hot masked select is elementwise over the sharded dim, so each
    # shard applies it locally.  (Costs a full cache read+write per step —
    # the shard_map local-scatter variant removes that; see §Perf.)
    C = ck.shape[1]
    at_pos = (jnp.arange(C)[None, :] == pos[:, None])[..., None, None]  # [B,C,1,1]
    ck = jnp.where(at_pos, k[:, 0][:, None], ck)
    cv = jnp.where(at_pos, v[:, 0][:, None], cv)

    group = cfg.n_heads // cfg.n_kv
    qg = q.reshape(B, cfg.n_kv, group, cfg.d_head)
    # scores vs whole cache, masked beyond pos  [B, n_kv, group, C]
    s = jnp.einsum("bkgd,bckd->bkgc", qg, ck, preferred_element_type=jnp.float32)
    s = s / (cfg.d_head**0.5)
    valid = (jnp.arange(ck.shape[1])[None, :] <= pos[:, None])[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", w.astype(ck.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    return o @ p["wo"], (ck, cv)
