"""RecSys model zoo: DIN, BST, MIND, two-tower retrieval.

Embedding tables are the hot path (assignment note): lookups are
``jnp.take`` + ``jax.ops.segment_sum`` (EmbeddingBag — JAX has no native
one), tables row-sharded over the "model" axis, with an optional replicated
hot-table split driven by the paper's frequency sketches
(stats.StreamStatsService.hot_keys — see models/embedding_sharding.py).

This is also where the paper's motivating application lives: impression
streams feed SH_l sketches; Q(cap_T, segment) forecasts campaign reach
(examples/ad_campaign_stats.py), and two-tower's sampled softmax uses
sketch-estimated item frequencies for logQ correction.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..layers.common import dense_init, shard_hint


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def embed_lookup(table, ids):
    """Row lookup; id 0 is the padding row by convention."""
    return jnp.take(table, ids, axis=0)


def masked_mean(emb, ids):
    """Mean-pool a [B, S, D] history with 0 = padding."""
    mask = (ids > 0).astype(emb.dtype)[..., None]
    s = jnp.sum(emb * mask, axis=1)
    n = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    return s / n


def mlp_init(rng, dims, dtype):
    ks = jax.random.split(rng, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], dims[i], dims[i + 1], dtype), "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def mlp_specs(dims, shard_last=False):
    out = []
    for i in range(len(dims) - 1):
        out.append({"w": P(None, "model") if i == 0 else P("model", None) if i == 1 else P(None, None),
                    "b": P(None)})
    return out


def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# DIN — Deep Interest Network (arXiv:1706.06978)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 10_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    dtype: Any = jnp.float32

    @property
    def n_params(self) -> int:
        d = self.embed_dim
        attn = 4 * d * self.attn_mlp[0] + self.attn_mlp[0] * self.attn_mlp[1] + self.attn_mlp[1]
        top_in = 3 * d
        top = top_in * self.mlp[0] + self.mlp[0] * self.mlp[1] + self.mlp[1]
        return self.n_items * d + attn + top


def din_init(rng, cfg: DINConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "items": dense_init(k1, cfg.n_items, d, cfg.dtype, scale=0.01),
        "attn": mlp_init(k2, (4 * d, *cfg.attn_mlp, 1), cfg.dtype),
        "top": mlp_init(k3, (3 * d, *cfg.mlp, 1), cfg.dtype),
    }


def din_specs(cfg: DINConfig):
    return {
        "items": P("model", None),  # row-sharded table
        "attn": [{"w": P(None, None), "b": P(None)} for _ in range(len(cfg.attn_mlp) + 1)],
        "top": [{"w": P(None, None), "b": P(None)} for _ in range(len(cfg.mlp) + 1)],
    }


def din_forward(params, cfg: DINConfig, batch):
    hist, target = batch["hist"], batch["target"]
    h = embed_lookup(params["items"], hist)            # [B,S,d]
    t = embed_lookup(params["items"], target)          # [B,d]
    h = shard_hint(h, P(("pod", "data"), None, None))
    tb = jnp.broadcast_to(t[:, None, :], h.shape)
    z = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)
    w = mlp_apply(params["attn"], z, act=jax.nn.sigmoid)[..., 0]   # [B,S] (no softmax, per DIN)
    w = w * (hist > 0)
    pooled = jnp.einsum("bs,bsd->bd", w.astype(h.dtype), h)
    x = jnp.concatenate([pooled, t, pooled * t], axis=-1)
    return mlp_apply(params["top"], x)[..., 0]


def din_loss(params, cfg: DINConfig, batch):
    return bce_loss(din_forward(params, cfg, batch), batch["label"])


# ---------------------------------------------------------------------------
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 10_000_000
    embed_dim: int = 32
    seq_len: int = 20          # history (incl. target as last position)
    n_heads: int = 8
    n_blocks: int = 1
    d_ff: int = 128
    mlp: tuple = (1024, 512, 256)
    dtype: Any = jnp.float32

    @property
    def n_params(self) -> int:
        d = self.embed_dim
        blk = 4 * d * d + 2 * d * self.d_ff
        flat = self.seq_len * d
        top = flat * self.mlp[0] + self.mlp[0] * self.mlp[1] + self.mlp[1] * self.mlp[2] + self.mlp[2]
        return self.n_items * d + self.seq_len * d + self.n_blocks * blk + top


def bst_init(rng, cfg: BSTConfig):
    ks = jax.random.split(rng, 4 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for b in range(cfg.n_blocks):
        k = ks[4 + 6 * b : 10 + 6 * b]
        blocks.append(
            {
                "wq": dense_init(k[0], d, d, cfg.dtype),
                "wk": dense_init(k[1], d, d, cfg.dtype),
                "wv": dense_init(k[2], d, d, cfg.dtype),
                "wo": dense_init(k[3], d, d, cfg.dtype),
                "w1": dense_init(k[4], d, cfg.d_ff, cfg.dtype),
                "w2": dense_init(k[5], cfg.d_ff, d, cfg.dtype),
                "ln1": jnp.ones((d,), cfg.dtype),
                "ln2": jnp.ones((d,), cfg.dtype),
            }
        )
    return {
        "items": dense_init(ks[0], cfg.n_items, d, cfg.dtype, scale=0.01),
        "pos": dense_init(ks[1], cfg.seq_len, d, cfg.dtype, scale=0.01),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "top": mlp_init(ks[2], (cfg.seq_len * d, *cfg.mlp, 1), cfg.dtype),
    }


def bst_specs(cfg: BSTConfig):
    blk = {k: P(None, None, None) for k in ("wq", "wk", "wv", "wo", "w1", "w2")}
    blk["ln1"] = P(None, None)
    blk["ln2"] = P(None, None)
    return {
        "items": P("model", None),
        "pos": P(None, None),
        "blocks": blk,
        "top": [
            {"w": P(None, "model"), "b": P("model")},
            {"w": P("model", None), "b": P(None)},
            {"w": P(None, None), "b": P(None)},
            {"w": P(None, None), "b": P(None)},
        ],
    }


def _bst_block(bp, cfg: BSTConfig, x):
    from ..layers.common import rms_norm

    B, S, d = x.shape
    hd = d // cfg.n_heads
    z = rms_norm(x, bp["ln1"])
    q = (z @ bp["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (z @ bp["wk"]).reshape(B, S, cfg.n_heads, hd)
    v = (z @ bp["wv"]).reshape(B, S, cfg.n_heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) / np.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).reshape(B, S, d).astype(x.dtype)
    x = x + o @ bp["wo"]
    z = rms_norm(x, bp["ln2"])
    return x + jax.nn.leaky_relu((z @ bp["w1"]).astype(jnp.float32)).astype(x.dtype) @ bp["w2"]


def bst_forward(params, cfg: BSTConfig, batch):
    seq = jnp.concatenate([batch["hist"], batch["target"][:, None]], axis=1)
    seq = seq[:, -cfg.seq_len :]
    x = embed_lookup(params["items"], seq) + params["pos"][None]
    x = shard_hint(x, P(("pod", "data"), None, None))

    def body(x_, bp):
        return _bst_block(bp, cfg, x_), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    flat = x.reshape(x.shape[0], -1)
    return mlp_apply(params["top"], flat, act=jax.nn.leaky_relu)[..., 0]


def bst_loss(params, cfg: BSTConfig, batch):
    return bce_loss(bst_forward(params, cfg, batch), batch["label"])


# ---------------------------------------------------------------------------
# MIND — Multi-Interest Network with Dynamic routing (arXiv:1904.08030)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 10_000_000
    embed_dim: int = 64
    seq_len: int = 50
    n_interests: int = 4
    capsule_iters: int = 3
    label_pow: float = 2.0
    dtype: Any = jnp.float32

    @property
    def n_params(self) -> int:
        d = self.embed_dim
        return self.n_items * d + d * d


def mind_init(rng, cfg: MINDConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "items": dense_init(k1, cfg.n_items, cfg.embed_dim, cfg.dtype, scale=0.01),
        "bilinear": dense_init(k2, cfg.embed_dim, cfg.embed_dim, cfg.dtype),
    }


def mind_specs(cfg: MINDConfig):
    return {"items": P("model", None), "bilinear": P(None, None)}


def _squash(s):
    n2 = jnp.sum(s.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (n2 / (1 + n2) * s.astype(jnp.float32) / jnp.sqrt(n2 + 1e-9)).astype(s.dtype)


def mind_interests(params, cfg: MINDConfig, hist):
    """Behavior-to-interest dynamic routing -> [B, K, d] interest capsules."""
    e = embed_lookup(params["items"], hist)          # [B,S,d]
    e = shard_hint(e, P(("pod", "data"), None, None))
    eh = e @ params["bilinear"]                       # [B,S,d]
    mask = (hist > 0).astype(jnp.float32)
    B, S, d = e.shape
    # fixed (hash-derived) routing-logit init, as in the paper's random init
    b0 = jnp.sin(jnp.arange(S * cfg.n_interests, dtype=jnp.float32) * 12.9898).reshape(
        1, S, cfg.n_interests
    ) * 0.1
    b = jnp.broadcast_to(b0, (B, S, cfg.n_interests))

    v = None
    for _ in range(cfg.capsule_iters):
        c = jax.nn.softmax(b, axis=-1) * mask[..., None]           # [B,S,K]
        s = jnp.einsum("bsk,bsd->bkd", c, eh.astype(jnp.float32))  # [B,K,d]
        v = _squash(s)
        b = b + jnp.einsum("bkd,bsd->bsk", v, eh.astype(jnp.float32))
    return v.astype(cfg.dtype)


def mind_loss(params, cfg: MINDConfig, batch):
    """Label-aware attention + in-batch sampled softmax."""
    v = mind_interests(params, cfg, batch["hist"])     # [B,K,d]
    t = embed_lookup(params["items"], batch["target"])  # [B,d]
    att = jax.nn.softmax(
        (jnp.einsum("bkd,bd->bk", v.astype(jnp.float32), t.astype(jnp.float32))) ** cfg.label_pow,
        axis=-1,
    )
    u = jnp.einsum("bk,bkd->bd", att, v.astype(jnp.float32))       # [B,d]
    logits = u @ t.astype(jnp.float32).T                            # in-batch softmax
    if "logq" in batch:
        logits = logits - batch["logq"][None, :]
    labels = jnp.arange(logits.shape[0])
    return jnp.mean(
        jax.nn.logsumexp(logits, axis=-1)
        - jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    )


def mind_point_serve(params, cfg: MINDConfig, batch):
    """Pointwise (user, target) scoring: max over interest capsules."""
    v = mind_interests(params, cfg, batch["hist"])     # [B,K,d]
    t = embed_lookup(params["items"], batch["target"])  # [B,d]
    s = jnp.einsum("bkd,bd->bk", v.astype(jnp.float32), t.astype(jnp.float32))
    return jnp.max(s, axis=-1)


def mind_serve(params, cfg: MINDConfig, batch):
    """Score candidates: max over interests (retrieval scoring)."""
    v = mind_interests(params, cfg, batch["hist"])     # [B,K,d]
    cand = embed_lookup(params["items"], batch["candidates"])  # [NC,d]
    scores = jnp.einsum("bkd,nd->bkn", v.astype(jnp.float32), cand.astype(jnp.float32))
    return jnp.max(scores, axis=1)                      # [B,NC]


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube, RecSys'19)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_items: int = 10_000_000
    n_users: int = 50_000_000
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    seq_len: int = 50
    dtype: Any = jnp.float32
    # §Perf: shard embedding rows over BOTH mesh axes (256/512-way) so the
    # dense table gradient needs no data-axis all-reduce (each device owns
    # distinct rows).  Row counts padded to multiples of 512.
    table_shard_2d: bool = False

    @property
    def n_params(self) -> int:
        d = self.embed_dim
        tower = lambda d_in: d_in * self.tower_mlp[0] + self.tower_mlp[0] * self.tower_mlp[1] + \
            self.tower_mlp[1] * self.tower_mlp[2]
        return (self.n_items + self.n_users) * d + tower(2 * d) + tower(d)


def twotower_init(rng, cfg: TwoTowerConfig):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.embed_dim
    return {
        "items": dense_init(k1, cfg.n_items, d, cfg.dtype, scale=0.01),
        "users": dense_init(k2, cfg.n_users, d, cfg.dtype, scale=0.01),
        "user_tower": mlp_init(k3, (2 * d, *cfg.tower_mlp), cfg.dtype),
        "item_tower": mlp_init(k4, (d, *cfg.tower_mlp), cfg.dtype),
    }


def twotower_specs(cfg: TwoTowerConfig):
    tower = [
        {"w": P(None, "model"), "b": P("model")},
        {"w": P("model", None), "b": P(None)},
        {"w": P(None, "model"), "b": P("model")},
    ]
    rows = P(("data", "model"), None) if cfg.table_shard_2d else P("model", None)
    return {
        "items": rows,
        "users": rows,
        "user_tower": tower,
        "item_tower": tower,
    }


def _user_vec(params, cfg, batch):
    hist_emb = embed_lookup(params["items"], batch["hist"])
    pooled = masked_mean(hist_emb, batch["hist"])
    ue = embed_lookup(params["users"], batch["user_id"])
    x = jnp.concatenate([ue, pooled], axis=-1)
    u = mlp_apply(params["user_tower"], x, final_act=False)
    return u / (jnp.linalg.norm(u.astype(jnp.float32), axis=-1, keepdims=True) + 1e-6).astype(u.dtype)


def _item_vec(params, cfg, ids):
    ie = embed_lookup(params["items"], ids)
    v = mlp_apply(params["item_tower"], ie, final_act=False)
    return v / (jnp.linalg.norm(v.astype(jnp.float32), axis=-1, keepdims=True) + 1e-6).astype(v.dtype)


def twotower_loss(params, cfg: TwoTowerConfig, batch, temperature: float = 0.05):
    """In-batch sampled softmax with logQ correction.

    batch["logq"]: log sampling probability of each in-batch item — in
    production estimated from the SH_l frequency sketch (the paper's
    technique closing the loop; examples/recsys_train.py wires it)."""
    u = _user_vec(params, cfg, batch)
    v = _item_vec(params, cfg, batch["target"])
    logits = (u.astype(jnp.float32) @ v.astype(jnp.float32).T) / temperature
    if "logq" in batch:
        logits = logits - batch["logq"][None, :]
    labels = jnp.arange(logits.shape[0])
    return jnp.mean(
        jax.nn.logsumexp(logits, axis=-1)
        - jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    )


def twotower_serve(params, cfg: TwoTowerConfig, batch):
    """CTR-style pointwise scoring of (user, target) pairs."""
    u = _user_vec(params, cfg, batch)
    v = _item_vec(params, cfg, batch["target"])
    return jnp.sum(u.astype(jnp.float32) * v.astype(jnp.float32), axis=-1)


def twotower_retrieve(params, cfg: TwoTowerConfig, batch):
    """batch=1 user vs n_candidates items: batched dot (NOT a loop) + top-k."""
    u = _user_vec(params, cfg, batch)                       # [1, d']
    cand = _item_vec(params, cfg, batch["candidates"])      # [NC, d']
    scores = (cand.astype(jnp.float32) @ u.astype(jnp.float32).T)[:, 0]
    vals, idx = jax.lax.top_k(scores, 100)
    return vals, idx
