"""Transformer LM (dense + MoE): train / prefill / decode.

Covers the five assigned LM architectures (yi-6b, codeqwen1.5-7b, qwen3-8b,
phi3.5-moe, moonshot-v1): pre-norm RMSNorm blocks, RoPE GQA attention
(optional qk-norm, per qwen3), SwiGLU MLP or top-k MoE FFN.

Layer parameters are stacked on a leading [L, ...] axis and the forward is a
``jax.lax.scan`` with per-layer ``jax.checkpoint`` (remat) — the memory policy
that keeps train_4k within a v5e's HBM.  The roofline tool compiles one layer
separately to correct the scan-counts-once FLOP accounting (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..layers.attention import (
    AttentionConfig,
    attention_decode,
    attention_prefill,
    attention_train,
    init_attention,
)
from ..layers.common import dense_init, rms_norm, shard_hint, softmax_xent, swiglu
from ..layers.moe import MoEConfig, init_moe, moe_apply, moe_apply_dense


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attention_chunk: int = 512
    attention_backend: str | None = "xla_chunked"
    scan_layers: bool = True
    tie_embeddings: bool = False
    # Megatron-style sequence parallelism: the residual stream (and thus the
    # per-layer remat-saved activations) is sharded over "model" along the
    # sequence axis; attention/MoE gather full sequences locally.  Converts
    # per-layer activation all-reduces into all-gather + reduce-scatter
    # (half the ring traffic) and divides saved-activation memory by the TP
    # degree.  §Perf iteration for the train cells.
    sequence_parallel: bool = False
    # remat policy: "full" rematerializes everything (min memory, re-runs the
    # per-layer TP all-reduces in the backward pass); "save_collectives"
    # checkpoints the post-all-reduce activations (attn_out / ffn_out) so the
    # backward never repeats forward collectives — affordable when combined
    # with sequence_parallel (saved tensors are S/TP-sized).  §Perf iteration.
    remat_policy: str = "full"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def attn_cfg(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            d_head=self.head_dim, qk_norm=self.qk_norm, rope_theta=self.rope_theta,
            attention_chunk=self.attention_chunk, backend=self.attention_backend,
            shard_kv=(self.n_kv % 16 == 0),
        )

    @property
    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv * 2)
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params
        d = self.d_model
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv * 2)
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(rng, cfg: TransformerConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "attn": init_attention(k1, cfg.attn_cfg(), cfg.dtype),
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = {
            "w1": dense_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
            "w3": dense_init(jax.random.fold_in(k2, 1), cfg.d_model, cfg.d_ff, cfg.dtype),
            "w2": dense_init(k3, cfg.d_ff, cfg.d_model, cfg.dtype),
        }
    return p


def init_params(rng, cfg: TransformerConfig):
    ke, kl, ko = jax.random.split(rng, 3)
    layers = [
        _init_layer(jax.random.fold_in(kl, i), cfg) for i in range(cfg.n_layers)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": dense_init(ke, cfg.vocab, cfg.d_model, cfg.dtype, scale=0.02),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        "head": dense_init(ko, cfg.d_model, cfg.vocab, cfg.dtype),
    }


def param_specs(cfg: TransformerConfig):
    """PartitionSpec tree matching init_params (Megatron TP over 'model')."""
    L = P(None)  # leading layer-stack axis

    def attn_spec():
        kv = P(None, None, "model") if cfg.n_kv % 16 == 0 else P(None, None, None)
        s = {
            "wq": P(None, None, "model"),
            "wk": kv,
            "wv": kv,
            "wo": P(None, "model", None),
        }
        if cfg.qk_norm:
            s["q_norm"] = P(None, None)
            s["k_norm"] = P(None, None)
        return s

    layer = {"attn": attn_spec(), "ln1": P(None, None), "ln2": P(None, None)}
    if cfg.moe is not None:
        layer["moe"] = {
            "router": P(None, None, None),
            "w1": P(None, "model", None, None),   # experts sharded (EP)
            "w3": P(None, "model", None, None),
            "w2": P(None, "model", None, None),
        }
    else:
        layer["mlp"] = {
            "w1": P(None, None, "model"),
            "w3": P(None, None, "model"),
            "w2": P(None, "model", None),
        }
    return {
        "embed": P(None, "model"),
        "layers": layer,
        "ln_f": P(None),
        "head": P(None, "model"),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _sp_spec(cfg):
    return P(("pod", "data"), "model" if cfg.sequence_parallel else None, None)


def _sp_hint(cfg, x):
    # only constrain when SP is on: constraining the residual to the default
    # layout measurably HURTS (forces GSPMD resharding; 47 GiB vs 20 GiB peak
    # on moonshot train_4k — §Perf iteration log)
    return shard_hint(x, _sp_spec(cfg), tag="sp") if cfg.sequence_parallel else x


def _layer_fwd(cfg: TransformerConfig, lp, x, positions):
    from jax.ad_checkpoint import checkpoint_name

    x = _sp_hint(cfg, x)
    h = attention_train(lp["attn"], cfg.attn_cfg(), rms_norm(x, lp["ln1"]), positions)
    h = _sp_hint(cfg, h)
    h = checkpoint_name(h, "attn_out")  # post-TP-all-reduce boundary
    x = x + h
    z = rms_norm(x, lp["ln2"])
    if cfg.moe is not None:
        y, aux = moe_apply(lp["moe"], cfg.moe, z)
        y = _sp_hint(cfg, y)
        y = checkpoint_name(y, "ffn_out")
        return x + y, aux["balance_loss"] + aux["router_z_loss"]
    y = swiglu(z, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
    y = _sp_hint(cfg, y)
    y = checkpoint_name(y, "ffn_out")
    return x + y, jnp.float32(0.0)


def forward(params, cfg: TransformerConfig, tokens):
    """tokens [B, S] -> logits [B, S, vocab] (f32) + aux loss."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _sp_hint(cfg, x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.float32), (B, S))

    body = lambda x_, lp: _layer_fwd(cfg, lp, x_, positions)
    if cfg.remat:
        if cfg.remat_policy == "save_collectives":
            pol = jax.checkpoint_policies.save_only_these_names("attn_out", "ffn_out")
            body = jax.checkpoint(body, policy=pol)
        else:
            body = jax.checkpoint(body)

    if cfg.scan_layers:
        def scan_body(x_, lp):
            x_, aux = body(x_, lp)
            return x_, aux

        x, auxs = jax.lax.scan(scan_body, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body(x, lp)
            aux = aux + a
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = shard_hint(logits, P(("pod", "data"), None, "model"))
    return logits, aux


def loss_fn(params, cfg: TransformerConfig, tokens, labels):
    logits, aux = forward(params, cfg, tokens)
    return softmax_xent(logits, labels) + aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, cfg: TransformerConfig, tokens):
    """Returns (last-position logits [B, vocab], caches list per layer)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.float32), (B, S))

    def scan_body(x_, lp):
        h, cache = attention_prefill(lp["attn"], cfg.attn_cfg(), rms_norm(x_, lp["ln1"]), positions)
        x_ = x_ + h
        z = rms_norm(x_, lp["ln2"])
        if cfg.moe is not None:
            y, _ = moe_apply(lp["moe"], cfg.moe, z)
        else:
            y = swiglu(z, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        return x_ + y, cache

    x, caches = jax.lax.scan(scan_body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = (x @ params["head"]).astype(jnp.float32)[:, 0]
    return logits, caches


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_step(params, cfg: TransformerConfig, token, cache, pos):
    """One decode step.  token [B] int32; cache stacked [L, B, C, n_kv, d];
    pos [B] int32 write positions.  Returns (logits [B, vocab], new cache)."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    x = shard_hint(x, P(("pod", "data"), None, None))

    def scan_body(x_, layer_in):
        lp, ck, cv = layer_in
        h, (ck2, cv2) = attention_decode(
            lp["attn"], cfg.attn_cfg(), rms_norm(x_, lp["ln1"]), (ck, cv), pos, None
        )
        x_ = x_ + h
        z = rms_norm(x_, lp["ln2"])
        if cfg.moe is not None:
            # decode uses the no-drop dense-combine path (batch-size
            # independent routing; see layers/moe.py traffic argument)
            y, _ = moe_apply_dense(lp["moe"], cfg.moe, z)
        else:
            y = swiglu(z, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        return x_ + y, (ck2, cv2)

    x, (ck_new, cv_new) = jax.lax.scan(scan_body, x, (params["layers"], cache[0], cache[1]))
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["head"]).astype(jnp.float32)[:, 0]
    return logits, (ck_new, cv_new)
