"""Frequency-driven hot/cold embedding placement (paper -> systems loop).

Large recsys tables are row-sharded over "model"; every lookup of a hot key
is then a cross-device gather.  The SH_l sketch over the impression stream
(stats.StreamStatsService) identifies the heavy keys *without aggregating the
stream*; the top-H keys get a small replicated "hot" table, the cold tail
stays row-sharded.  cap statistics give an unbiased estimate of the traffic
split: hot_traffic ~= Q(sum, hot) / Q(sum, X), used to size H.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import estimators, freqfns
from ..stats.service import StreamStatsService


@dataclasses.dataclass
class HotColdPlan:
    hot_ids_sorted: np.ndarray       # sorted hot key ids
    est_hot_traffic_frac: float      # estimated share of lookups hitting hot


def plan_hot_cold(service: StreamStatsService, n_hot: int) -> HotColdPlan:
    hot = np.sort(service.hot_keys(n_hot))
    sketch = service.sketches()[max(service.config.ls)]
    total = estimators.estimate(sketch, freqfns.total())
    hot_traffic = estimators.estimate(sketch, freqfns.total(), segment=hot)
    frac = float(hot_traffic / max(total, 1e-9))
    return HotColdPlan(hot_ids_sorted=hot, est_hot_traffic_frac=frac)


def split_table(table, plan: HotColdPlan):
    """Materialize (hot_table [H, D] to replicate, cold = original table)."""
    hot_ids = jnp.asarray(plan.hot_ids_sorted, jnp.int32)
    return jnp.take(table, hot_ids, axis=0), hot_ids


def hot_cold_lookup(cold_table, hot_table, hot_ids_sorted, ids):
    """Lookup ids, serving hot keys from the replicated table."""
    loc = jnp.searchsorted(hot_ids_sorted, ids)
    loc = jnp.clip(loc, 0, hot_ids_sorted.shape[0] - 1)
    is_hot = hot_ids_sorted[loc] == ids
    hot_rows = jnp.take(hot_table, loc, axis=0)
    cold_rows = jnp.take(cold_table, ids, axis=0)
    return jnp.where(is_hot[..., None], hot_rows, cold_rows)
