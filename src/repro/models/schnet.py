"""SchNet (arXiv:1706.08566): continuous-filter convolutions over graphs.

Kernel regime: triplet-free edge gather + segment reduction (taxonomy §GNN).
Message passing is implemented with ``jnp.take`` over the edge list and
``jax.ops.segment_sum`` scatter back to nodes — JAX-native sparse (BCOO-free),
exactly as the assignment mandates.  On TPU the segment reduction can route
through kernels/embedding_bag's MXU one-hot matmul kernel.

The assigned shapes span molecular (positions -> true distances) and citation
/product graphs (no geometry): for the latter the "distance" channel is a
provided per-edge scalar (hash-derived in the data pipeline) and node features
enter through a linear projection instead of the atom-type embedding — noted
in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..layers.common import dense_init, shard_hint


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100
    d_node_feat: int | None = None  # if set: feature graphs (linear proj input)
    dtype: Any = jnp.float32
    # §Perf toggles: TP over the (tiny, d=64) weight matrices, and whether
    # edges shard over the model axis too (vs data axes only)
    tp_weights: bool = True
    edge_shard_model: bool = True

    @property
    def n_params(self) -> int:
        d, r = self.d_hidden, self.n_rbf
        inp = (self.d_node_feat or self.n_atom_types) * d
        per_inter = r * d + d * d * 3 + 2 * d  # filter MLP + atomwise
        out = d * (d // 2) + (d // 2)
        return inp + self.n_interactions * per_inter + out


def shifted_softplus(x):
    return jax.nn.softplus(x) - float(np.log(2.0))


def rbf_expand(dist, cfg: SchNetConfig):
    """Gaussian radial basis: [E] -> [E, n_rbf]."""
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=jnp.float32)
    gamma = 10.0 / (cfg.cutoff / cfg.n_rbf) / cfg.cutoff  # ~paper width
    d = dist[:, None].astype(jnp.float32) - centers[None, :]
    return jnp.exp(-gamma * d * d).astype(cfg.dtype)


def init_params(rng, cfg: SchNetConfig):
    ks = jax.random.split(rng, 2 + 4 * cfg.n_interactions)
    d, r = cfg.d_hidden, cfg.n_rbf
    if cfg.d_node_feat is not None:
        embed = dense_init(ks[0], cfg.d_node_feat, d, cfg.dtype)
    else:
        embed = dense_init(ks[0], cfg.n_atom_types, d, cfg.dtype, scale=1.0)
    inters = []
    for i in range(cfg.n_interactions):
        k = ks[2 + 4 * i : 6 + 4 * i]
        inters.append(
            {
                "filter1": dense_init(k[0], r, d, cfg.dtype),
                "filter2": dense_init(k[1], d, d, cfg.dtype),
                "in_proj": dense_init(k[2], d, d, cfg.dtype),
                "out_proj": dense_init(k[3], d, d, cfg.dtype),
                "bias": jnp.zeros((d,), cfg.dtype),
            }
        )
    inters = jax.tree.map(lambda *xs: jnp.stack(xs), *inters)
    return {
        "embed": embed,
        "inters": inters,
        "out1": dense_init(ks[1], d, d // 2, cfg.dtype),
        "out2": dense_init(jax.random.fold_in(ks[1], 1), d // 2, 1, cfg.dtype),
    }


def param_specs(cfg: SchNetConfig):
    if cfg.tp_weights:
        inter = {
            "filter1": P(None, None, "model"),
            "filter2": P(None, "model", None),
            "in_proj": P(None, None, "model"),
            "out_proj": P(None, "model", None),
            "bias": P(None, None),
        }
    else:
        inter = {k: P(None, None, None) for k in ("filter1", "filter2", "in_proj", "out_proj")}
        inter["bias"] = P(None, None)
    return {
        "embed": P(None, None),
        "inters": inter,
        "out1": P(None, None),
        "out2": P(None, None),
    }


def forward(params, cfg: SchNetConfig, batch, n_graphs: int):
    """batch: dict with
        node_input: [N] int32 atom types  OR  [N, F] float features
        edge_src, edge_dst: [E] int32 (padding edges point at node 0 w/ dist>cutoff)
        edge_dist: [E] float32
        graph_ids: [N] int32 graph membership for batched graphs
    n_graphs is static (compile-time).
    Returns per-graph scalar predictions [n_graphs].
    """
    src, dst = batch["edge_src"], batch["edge_dst"]
    dist = batch["edge_dist"]
    n_nodes = batch["node_input"].shape[0]

    if cfg.d_node_feat is not None:
        x = batch["node_input"].astype(cfg.dtype) @ params["embed"]
    else:
        x = jnp.take(params["embed"], batch["node_input"], axis=0)
    x = shard_hint(x, P(("pod", "data"), None))

    rbf = rbf_expand(dist, cfg)
    edge_mask = (dist <= cfg.cutoff).astype(cfg.dtype)[:, None]

    def body(x_, ip):
        w = shifted_softplus(rbf @ ip["filter1"])
        w = shifted_softplus(w @ ip["filter2"]) * edge_mask      # [E, d]
        h = x_ @ ip["in_proj"]
        msg = jnp.take(h, src, axis=0) * w                        # gather * filter
        agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)  # scatter-add
        v = shifted_softplus(agg @ ip["out_proj"] + ip["bias"])
        return x_ + v

    # unrolled (n_interactions is 2-3): avoids XLA's scan-counts-once FLOP
    # undercount in the roofline and lets XLA overlap the per-iteration
    # all-gathers of the TP-sharded filters
    for i in range(cfg.n_interactions):
        x = body(x, jax.tree.map(lambda a: a[i], params["inters"]))
    h = shifted_softplus(x @ params["out1"])
    e = (h @ params["out2"])[:, 0]
    if n_graphs is None:
        return e  # node-level prediction (citation/product graphs)
    return jax.ops.segment_sum(e, batch["graph_ids"], num_segments=n_graphs)


def loss_fn(params, cfg: SchNetConfig, batch, n_graphs: int):
    pred = forward(params, cfg, batch, n_graphs)
    tgt = batch["targets"].astype(jnp.float32)
    return jnp.mean((pred.astype(jnp.float32) - tgt) ** 2)
