"""Sharded checkpointing with atomic commit and elastic restore.

Layout:
    <dir>/step_000123.tmp/...   (written first)
    <dir>/step_000123/          (atomic rename = commit)
        manifest.json           (tree structure, shapes, dtypes, specs)
        arrays.npz              (flattened leaves, host-gathered)
        extra.json              (data-pipeline cursors, stats sketches, rng)

Elastic restore: arrays are saved with *logical* (global) shapes plus their
PartitionSpecs; `restore` re-places them under whatever mesh is active now —
a job restarted on a different device count reshards transparently (ZeRO
state included).  Failure mid-write never corrupts the latest checkpoint:
readers only see committed directories; `latest_step` skips `.tmp`.

Durability contract: the atomic rename only orders the commit w.r.t. other
*readers*; it does NOT order it w.r.t. the disk.  On a host crash (power
cut) right after ``os.rename``, a filesystem that reorders data and
directory writes can surface a committed directory whose ``arrays.npz`` is
empty or torn.  ``save`` therefore fsyncs every file AND the ``.tmp``
directory before the rename, and the parent directory after it (the rename
itself becomes durable) — the standard write / fsync(file) / rename /
fsync(dir) discipline.  ``fsync_file`` / ``fsync_dir`` are public because
the shard-tier WAL (stats/shardtier.py) commits its log segments with the
same sequence.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


def fsync_file(path: str | Path) -> None:
    """Flush one file's data+metadata to stable storage."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str | Path) -> None:
    """Flush a directory's entries (creations/renames inside it) to disk."""
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(ckpt_dir: str | Path, step: int, tree, *, extra: dict | None = None,
         keep_last: int = 3, fsync: bool = True) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, treedef = _flatten_with_paths(tree)
    host = [np.asarray(x) for x in flat]
    np.savez(tmp / "arrays.npz", **{f"leaf_{i}": a for i, a in enumerate(host)})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if extra is not None:
        (tmp / "extra.json").write_text(json.dumps(extra))
    if fsync:
        # every byte of the checkpoint must be on stable storage BEFORE the
        # rename makes it visible — otherwise a host crash right after the
        # rename can commit an empty/torn checkpoint (module docstring).
        for p in sorted(tmp.iterdir()):
            fsync_file(p)
        fsync_dir(tmp)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit (readers never see partial state)
    if fsync:
        fsync_dir(ckpt_dir)  # make the rename itself durable

    # retention
    steps = sorted(p for p in ckpt_dir.iterdir() if p.is_dir() and not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, example_tree, *, shardings=None):
    """Restore into the structure of ``example_tree``; optional shardings
    (pytree of NamedSharding) re-place arrays under the current mesh."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    flat, treedef = jax.tree.flatten(example_tree)
    leaves = [data[f"leaf_{i}"] for i in range(len(flat))]
    for got, want in zip(leaves, flat):
        if hasattr(want, "shape") and tuple(got.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch: ckpt {got.shape} vs model {want.shape}")
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def restore_slice(ckpt_dir: str | Path, step: int, example_tree, index: int):
    """Restore ONE row of a stacked checkpoint into a per-instance tree.

    The multi-tenant serving plane (stats.service.MultiTenantStats)
    checkpoints its whole bank as [T, ...]-stacked leaves whose names are
    parallel to the single-instance state dict.  ``example_tree`` is the
    SINGLE-instance structure (e.g. ``StreamStatsService.state_dict()``);
    every stored leaf is matched against it by position:

    * equal shape            -> shared across tenants, kept whole;
    * ndim+1 with matching
      trailing dims          -> stacked, sliced at ``[index]``;
    * anything else          -> error (incompatible checkpoint).

    This is the tenant handoff path: restore one tenant out of a bank
    checkpoint into a standalone service (launch/elastic.py) without
    pulling the other T-1 tenants off disk into the destination process.
    """
    path = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    flat, treedef = jax.tree.flatten(example_tree)
    n_stored = json.loads((path / "manifest.json").read_text())["n_leaves"]
    if n_stored != len(flat):
        raise ValueError(
            f"leaf count mismatch: checkpoint has {n_stored}, example tree "
            f"has {len(flat)} — the example must be the single-instance "
            "form of the stacked state (same keys, minus the stack axis)")
    out = []
    for i, want in enumerate(flat):
        got = data[f"leaf_{i}"]
        wshape = tuple(np.asarray(want).shape)
        if tuple(got.shape) == wshape:
            out.append(got)
        elif got.ndim == len(wshape) + 1 and tuple(got.shape[1:]) == wshape:
            if not (0 <= index < got.shape[0]):
                raise IndexError(
                    f"slice index {index} out of range for stacked leaf_{i} "
                    f"with {got.shape[0]} instances")
            out.append(got[index])
        else:
            raise ValueError(
                f"leaf_{i}: ckpt shape {got.shape} is neither shared "
                f"({wshape}) nor stacked ((T,)+{wshape})")
    return jax.tree.unflatten(treedef, out)


def restore_extra(ckpt_dir: str | Path, step: int) -> dict:
    p = Path(ckpt_dir) / f"step_{step:08d}" / "extra.json"
    return json.loads(p.read_text()) if p.exists() else {}
