"""Synthetic data sources + the sharded, checkpointable stream iterator.

Production shape: every host pulls its shard of the element stream; the
iterator exposes a cursor (element offset) that is saved in checkpoints so a
restarted/re-sharded job resumes mid-epoch without replaying or skipping
data.  Straggler mitigation: `BoundedSkewPrefetcher` lets fast hosts run
ahead a bounded number of batches so one slow host doesn't stall the step
clock; because the paper's sketches are mergeable and order-independent
(§3.1), statistics stay exact under skew.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def zipf_keys(rng: np.random.Generator, n: int, alpha: float, n_keys: int) -> np.ndarray:
    """Zipf(alpha) keys truncated to [0, n_keys) — the paper's §7 generator."""
    z = rng.zipf(alpha, size=n)
    return (z % n_keys).astype(np.int64)


@dataclasses.dataclass
class StreamCursor:
    shard: int
    n_shards: int
    offset: int = 0
    epoch: int = 0


class ShardedStream:
    """Deterministic, seekable stream shard of (key, weight) elements."""

    def __init__(self, *, n_total: int, alpha: float, n_keys: int, seed: int,
                 cursor: StreamCursor):
        self.n_total = n_total
        self.alpha = alpha
        self.n_keys = n_keys
        self.seed = seed
        self.cursor = cursor

    def _shard_bounds(self):
        per = self.n_total // self.cursor.n_shards
        lo = self.cursor.shard * per
        return lo, lo + per

    def next_batch(self, batch: int):
        lo, hi = self._shard_bounds()
        start = lo + self.cursor.offset
        if start + batch > hi:
            self.cursor.epoch += 1
            self.cursor.offset = 0
            start = lo
        # counter-based generation: reproducible random access
        rng = np.random.default_rng([self.seed, self.cursor.epoch, start])
        keys = zipf_keys(rng, batch, self.alpha, self.n_keys)
        self.cursor.offset += batch
        return keys

    def state_dict(self):
        return dataclasses.asdict(self.cursor)

    def load_state_dict(self, d):
        self.cursor = StreamCursor(**d)


class BoundedSkewPrefetcher:
    """Allows up to `max_skew` batches of run-ahead per shard (host-side)."""

    def __init__(self, stream: ShardedStream, batch: int, max_skew: int = 4):
        self.stream = stream
        self.batch = batch
        self.max_skew = max_skew
        self._buf: list = []

    def fill(self):
        while len(self._buf) < self.max_skew:
            self._buf.append(self.stream.next_batch(self.batch))

    def get(self):
        if not self._buf:
            self.fill()
        out = self._buf.pop(0)
        return out
