"""Synthetic recsys impression/click streams with Zipf item popularity.

Doubles as the ad-campaign stream for the paper's motivating application:
elements are (user, item) impressions; frequency-cap queries run over user
keys segmented by campaign/demographic."""
from __future__ import annotations

import numpy as np

from .streams import zipf_keys


def impression_batch(rng: np.random.Generator, *, batch: int, seq_len: int,
                     n_items: int, n_users: int):
    """Training batch: history + target + click label."""
    hist = zipf_keys(rng, batch * seq_len, 1.2, n_items).reshape(batch, seq_len)
    hist[rng.uniform(size=hist.shape) < 0.1] = 0  # padding holes
    target = zipf_keys(rng, batch, 1.2, n_items)
    # label correlated with history overlap so models can actually learn
    overlap = (hist == target[:, None]).any(axis=1)
    p = np.where(overlap, 0.6, 0.15)
    label = (rng.uniform(size=batch) < p).astype(np.float32)
    user_id = rng.integers(0, n_users, size=batch)
    return {
        "hist": hist.astype(np.int32),
        "target": target.astype(np.int32),
        "label": label,
        "user_id": user_id.astype(np.int32),
    }


def impression_stream_elements(batch_dict):
    """Flatten a batch into (user, item) stream elements for the sketches."""
    b = batch_dict
    users = np.repeat(b["user_id"], b["hist"].shape[1])
    items = b["hist"].reshape(-1)
    keep = items > 0
    return users[keep], items[keep]
