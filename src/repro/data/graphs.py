"""Graph data: synthetic generators, CSR, and the neighbor sampler.

The fanout neighbor sampler (minibatch_lg) is REAL: CSR adjacency +
per-frontier bottom-k-by-seed selection — i.e. the paper's own sampling
primitive (ppswor with unit weights == uniform without replacement via
random seeds, §2) reused as the GNN sampler, with deterministic counter-based
seeds so distributed workers resample identically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import hashing as H


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # [N+1]
    indices: np.ndarray  # [E]
    n_nodes: int

    @staticmethod
    def random(rng: np.random.Generator, n_nodes: int, n_edges: int) -> "CSRGraph":
        # power-law-ish degree distribution
        dst = (rng.zipf(1.3, size=n_edges) % n_nodes).astype(np.int64)
        src = rng.integers(0, n_nodes, size=n_edges)
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(indptr=indptr, indices=dst, n_nodes=n_nodes)


def neighbor_sample(graph: CSRGraph, seeds: np.ndarray, fanouts, salt: int = 0):
    """Layered fanout sampling (GraphSAGE-style).

    Per frontier node, pick bottom-k neighbors by hash seed (uniform without
    replacement — exactly a k-sample in the paper's framework with
    ElementScore = Hash(edge)).  Returns (node_ids, edge_src, edge_dst) with
    edges in LOCAL indices; node_ids[0:len(seeds)] are the seeds.
    """
    nodes = list(seeds.tolist())
    local = {int(n): i for i, n in enumerate(nodes)}
    e_src, e_dst = [], []
    frontier = seeds
    for layer, k in enumerate(fanouts):
        nxt = []
        for u in frontier.tolist():
            lo, hi = graph.indptr[u], graph.indptr[u + 1]
            nbrs = graph.indices[lo:hi]
            if len(nbrs) == 0:
                continue
            if len(nbrs) > k:
                # bottom-k by counter-based seed (deterministic)
                sc = H.uniform01_np(
                    H.hash_combine_np(np.arange(lo, hi), np.uint32(salt), np.uint32(layer))
                )
                nbrs = nbrs[np.argsort(sc)[:k]]
            for v in nbrs.tolist():
                if v not in local:
                    local[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                e_src.append(local[v])
                e_dst.append(local[u])
        frontier = np.asarray(nxt, dtype=np.int64)
        if len(frontier) == 0:
            break
    return (
        np.asarray(nodes, dtype=np.int64),
        np.asarray(e_src, dtype=np.int32),
        np.asarray(e_dst, dtype=np.int32),
    )


def pad_graph_batch(node_input, e_src, e_dst, edge_dist, graph_ids, *, n_nodes, n_edges):
    """Pad a sampled subgraph to static shapes (padding edges get dist=inf,
    padding nodes belong to graph 0 with zero features)."""
    def pad_to(a, n, fill):
        if len(a) >= n:
            return a[:n]
        return np.concatenate([a, np.full(n - len(a), fill, dtype=a.dtype)])

    if node_input.ndim == 1:
        node_input = pad_to(node_input, n_nodes, 0)
    else:
        out = np.zeros((n_nodes, node_input.shape[1]), dtype=node_input.dtype)
        out[: min(len(node_input), n_nodes)] = node_input[:n_nodes]
        node_input = out
    return dict(
        node_input=node_input,
        edge_src=pad_to(e_src.astype(np.int32), n_edges, 0),
        edge_dst=pad_to(e_dst.astype(np.int32), n_edges, 0),
        edge_dist=pad_to(edge_dist.astype(np.float32), n_edges, np.float32(1e9)),
        graph_ids=pad_to(graph_ids.astype(np.int32), n_nodes, 0),
    )


def random_molecules(rng: np.random.Generator, batch: int, n_atoms: int, n_edges_per: int):
    """Batched small molecules with 3D positions -> true distances."""
    node_z, e_src, e_dst, dist, gid = [], [], [], [], []
    for g in range(batch):
        z = rng.integers(1, 20, size=n_atoms)
        pos = rng.normal(size=(n_atoms, 3)) * 2.0
        # k-nearest edges
        d2 = np.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
        np.fill_diagonal(d2, np.inf)
        k = max(1, n_edges_per // n_atoms)
        nn = np.argsort(d2, axis=1)[:, :k]
        for i in range(n_atoms):
            for j in nn[i]:
                e_src.append(g * n_atoms + j)
                e_dst.append(g * n_atoms + i)
                dist.append(np.sqrt(d2[i, j]))
        node_z.append(z)
        gid.append(np.full(n_atoms, g))
    return (
        np.concatenate(node_z).astype(np.int32),
        np.asarray(e_src, np.int32),
        np.asarray(e_dst, np.int32),
        np.asarray(dist, np.float32),
        np.concatenate(gid).astype(np.int32),
    )
