"""qwen3-8b [hf:Qwen/Qwen3-8B]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig

ARCH_ID = "qwen3-8b"
FAMILY = "lm"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=36, d_model=4096, n_heads=32, n_kv=8,
        d_ff=12288, vocab=151936, qk_norm=True, d_head=128, dtype=jnp.bfloat16,
        sequence_parallel=True,  # §Perf: +13-18pt roofline on train_4k
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_ff=128, vocab=512, qk_norm=True, dtype=jnp.float32, attention_chunk=64,
    )
