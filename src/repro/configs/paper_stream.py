"""The paper's own workload: SH_l sampling over Zipf streams (§7 setup)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperStreamConfig:
    name: str = "paper-stream"
    n_elements: int = 100_000
    zipf_alpha: float = 1.2
    n_keys: int = 50_000
    k: int = 100
    ls: tuple = (1.0, 5.0, 20.0, 50.0, 100.0, 1000.0, 10000.0)
    chunk: int = 2048


def full_config() -> PaperStreamConfig:
    return PaperStreamConfig()


def smoke_config() -> PaperStreamConfig:
    return PaperStreamConfig(name="paper-stream-smoke", n_elements=5000, n_keys=1000,
                             k=32, ls=(1.0, 20.0), chunk=256)
