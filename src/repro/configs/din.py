"""din [arXiv:1706.06978]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn."""
import jax.numpy as jnp

from ..models.recsys import DINConfig

ARCH_ID = "din"
FAMILY = "recsys"


def full_config() -> DINConfig:
    return DINConfig(name=ARCH_ID, n_items=10_000_000, embed_dim=18, seq_len=100,
                     attn_mlp=(80, 40), mlp=(200, 80), dtype=jnp.float32)


def smoke_config() -> DINConfig:
    return DINConfig(name=ARCH_ID + "-smoke", n_items=1000, embed_dim=8, seq_len=16,
                     attn_mlp=(16, 8), mlp=(32, 16), dtype=jnp.float32)
