"""schnet [arXiv:1706.08566]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10."""
import jax.numpy as jnp

from ..models.schnet import SchNetConfig

ARCH_ID = "schnet"
FAMILY = "gnn"


def full_config() -> SchNetConfig:
    return SchNetConfig(name=ARCH_ID, n_interactions=3, d_hidden=64, n_rbf=300,
                        cutoff=10.0, dtype=jnp.float32,
                        # §Perf: TP over d=64 matrices REDUCES throughput 2.6x
                        # (collective-bound); replicate the 100KB of weights.
                        tp_weights=False)


def smoke_config() -> SchNetConfig:
    return SchNetConfig(name=ARCH_ID + "-smoke", n_interactions=2, d_hidden=16,
                        n_rbf=24, cutoff=10.0, dtype=jnp.float32)
