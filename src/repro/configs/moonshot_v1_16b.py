"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]:
48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840, MoE 64e top-6."""
import jax.numpy as jnp

from ..layers.moe import MoEConfig
from ..models.transformer import TransformerConfig

ARCH_ID = "moonshot-v1-16b-a3b"
FAMILY = "lm"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv=16,
        d_ff=1408, vocab=163840, d_head=128,
        moe=MoEConfig(d_model=2048, d_ff=1408, n_experts=64, top_k=6,
                      capacity_factor=1.25, group_size=1024),
        dtype=jnp.bfloat16,
        sequence_parallel=True,  # §Perf (save_collectives refuted: A3)
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=48, vocab=512, d_head=16,
        moe=MoEConfig(d_model=64, d_ff=48, n_experts=8, top_k=3, group_size=64),
        dtype=jnp.float32, attention_chunk=64,
    )
