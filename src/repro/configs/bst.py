"""bst [arXiv:1905.06874]: embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256 interaction=transformer-seq."""
import jax.numpy as jnp

from ..models.recsys import BSTConfig

ARCH_ID = "bst"
FAMILY = "recsys"


def full_config() -> BSTConfig:
    return BSTConfig(name=ARCH_ID, n_items=10_000_000, embed_dim=32, seq_len=20,
                     n_heads=8, n_blocks=1, mlp=(1024, 512, 256), dtype=jnp.float32)


def smoke_config() -> BSTConfig:
    return BSTConfig(name=ARCH_ID + "-smoke", n_items=1000, embed_dim=16, seq_len=8,
                     n_heads=2, n_blocks=1, d_ff=32, mlp=(64, 32, 16), dtype=jnp.float32)
