"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: 32L d_model=4096 32H (kv=32, MHA)
d_ff=13440 vocab=92416."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig

ARCH_ID = "codeqwen1.5-7b"
FAMILY = "lm"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv=32,
        d_ff=13440, vocab=92416, dtype=jnp.bfloat16,
        sequence_parallel=True,  # §Perf: +13-18pt roofline on train_4k
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=512, dtype=jnp.float32, attention_chunk=64,
    )
