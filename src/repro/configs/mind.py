"""mind [arXiv:1904.08030]: embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest."""
import jax.numpy as jnp

from ..models.recsys import MINDConfig

ARCH_ID = "mind"
FAMILY = "recsys"


def full_config() -> MINDConfig:
    return MINDConfig(name=ARCH_ID, n_items=10_000_000, embed_dim=64, seq_len=50,
                      n_interests=4, capsule_iters=3, dtype=jnp.float32)


def smoke_config() -> MINDConfig:
    return MINDConfig(name=ARCH_ID + "-smoke", n_items=1000, embed_dim=16,
                      seq_len=12, n_interests=2, capsule_iters=2, dtype=jnp.float32)
