"""Architecture registry: --arch <id> -> configs, shapes, cell programs."""
from __future__ import annotations

from typing import Any

from . import (
    bst,
    codeqwen1_5_7b,
    din,
    mind,
    moonshot_v1_16b,
    phi3_5_moe_42b,
    qwen3_8b,
    schnet,
    two_tower_retrieval,
    yi_6b,
)
from .builders import (
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    CellProgram,
    build_gnn_cell,
    build_lm_cell,
    build_recsys_cell,
)

_MODULES = {
    m.ARCH_ID: m
    for m in (
        phi3_5_moe_42b, moonshot_v1_16b, yi_6b, codeqwen1_5_7b, qwen3_8b,
        schnet, mind, bst, din, two_tower_retrieval,
    )
}

ARCH_IDS = tuple(_MODULES)

_FAMILY_SHAPES = {
    "lm": tuple(LM_SHAPES),
    "gnn": tuple(GNN_SHAPES),
    "recsys": tuple(RECSYS_SHAPES),
}


def family(arch_id: str) -> str:
    return _MODULES[arch_id].FAMILY


def shapes_for(arch_id: str) -> tuple[str, ...]:
    return _FAMILY_SHAPES[family(arch_id)]


def get_config(arch_id: str, *, smoke: bool = False) -> Any:
    m = _MODULES[arch_id]
    return m.smoke_config() if smoke else m.full_config()


def build_cell(arch_id: str, shape_name: str, *, smoke: bool = False,
               overrides: dict | None = None) -> CellProgram:
    """overrides: dataclasses.replace kwargs applied to the model config
    (supports nested "moe.<field>" keys) — used by the §Perf hillclimb to
    lower A/B variants of a cell."""
    import dataclasses as _dc

    cfg = get_config(arch_id, smoke=smoke)
    if overrides:
        plain = {k: v for k, v in overrides.items() if "." not in k}
        moe_kw = {k.split(".", 1)[1]: v for k, v in overrides.items() if k.startswith("moe.")}
        if moe_kw and getattr(cfg, "moe", None) is not None:
            plain["moe"] = _dc.replace(cfg.moe, **moe_kw)
        cfg = _dc.replace(cfg, **plain)
    fam = family(arch_id)
    if fam == "lm":
        return build_lm_cell(cfg, shape_name)
    if fam == "gnn":
        return build_gnn_cell(cfg, shape_name)
    return build_recsys_cell(arch_id, cfg, shape_name)


def all_cells():
    """All 40 (arch x shape) cell ids."""
    out = []
    for a in ARCH_IDS:
        for s in shapes_for(a):
            out.append((a, s))
    return out
