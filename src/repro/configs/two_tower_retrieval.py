"""two-tower-retrieval [RecSys'19 (YouTube)]: embed_dim=256
tower_mlp=1024-512-256 interaction=dot, sampled-softmax retrieval."""
import jax.numpy as jnp

from ..models.recsys import TwoTowerConfig

ARCH_ID = "two-tower-retrieval"
FAMILY = "recsys"


def full_config() -> TwoTowerConfig:
    return TwoTowerConfig(name=ARCH_ID, n_items=10_000_000, n_users=50_000_000,
                          embed_dim=256, tower_mlp=(1024, 512, 256), dtype=jnp.float32)


def smoke_config() -> TwoTowerConfig:
    return TwoTowerConfig(name=ARCH_ID + "-smoke", n_items=1000, n_users=1000,
                          embed_dim=16, tower_mlp=(32, 24, 16), seq_len=8,
                          dtype=jnp.float32)
