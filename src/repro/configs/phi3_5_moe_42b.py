"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]:
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2."""
import jax.numpy as jnp

from ..layers.moe import MoEConfig
from ..models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "lm"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv=8,
        d_ff=6400, vocab=32064,
        moe=MoEConfig(d_model=4096, d_ff=6400, n_experts=16, top_k=2,
                      capacity_factor=1.25, group_size=2048),
        dtype=jnp.bfloat16,
        sequence_parallel=True,  # §Perf (save_collectives refuted: A3)
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_ff=96, vocab=512,
        moe=MoEConfig(d_model=64, d_ff=96, n_experts=4, top_k=2, group_size=64),
        dtype=jnp.float32, attention_chunk=64,
    )
