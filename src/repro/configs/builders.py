"""Cell builders: (architecture x input-shape) -> a lowerable program.

A *cell* packages everything the dry-run and roofline need:
  * ``fn``          — the jit-able step (train_step / serve_step)
  * ``in_shapes``   — ShapeDtypeStruct stand-ins (no allocation)
  * ``in_specs``    — PartitionSpecs for every input
  * ``out_specs``   — PartitionSpecs for every output
  * ``model_flops`` — analytic useful FLOPs (6*N*D / 2*N*D convention)
  * ``scan_correction`` — a single-layer program compiled separately to fix
    XLA's scan-counts-once FLOP accounting (DESIGN.md §6), as
    (fn, in_shapes, in_specs, multiplier).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import recsys as R
from ..models import schnet as G
from ..models import transformer as T
from ..optim import adamw

DP = ("pod", "data")  # batch axes (pod collapses out on the single-pod mesh)


@dataclasses.dataclass
class CellProgram:
    name: str
    kind: str
    fn: Callable
    in_shapes: tuple
    in_specs: tuple
    out_specs: Any
    model_flops: float
    scan_correction: tuple | None = None
    donate: tuple = ()
    dtype: str = "float32"
    notes: str = ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(init_fn):
    """Shape-evaluate an init function (no allocation)."""
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# LM transformer cells
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def lm_model_flops(cfg: T.TransformerConfig, kind: str, batch: int, seq: int) -> float:
    # 6*N*D with N = active params participating in matmuls: the embedding
    # table is a gather (0 flops), so it is excluded; the output head counts.
    n = cfg.n_active_params - cfg.vocab * cfg.d_model
    tokens = batch * seq
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence + attention over the cache
    attn = 4.0 * cfg.n_layers * seq * cfg.n_kv * cfg.head_dim * batch
    return 2.0 * n * batch + attn


def build_lm_cell(cfg: T.TransformerConfig, shape_name: str, opt_cfg=None) -> CellProgram:
    sh = LM_SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    pspecs = T.param_specs(cfg)
    params_sh = abstract_params(lambda k: T.init_params(k, cfg))
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    if sh["kind"] == "train":
        opt_sh = jax.eval_shape(adamw.init_state, params_sh)
        opt_specs = adamw.zero1_specs(params_sh, pspecs, data_axes=("data",), data_size=16)

        def train_step(params, opt_state, tokens, labels):
            loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, tokens, labels)
            params, opt_state, gnorm = adamw.update(opt_cfg, params, grads, opt_state)
            return params, opt_state, loss, gnorm

        # single-layer fwd+bwd for the scan correction
        def layer_step(lp, x):
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.float32), (x.shape[0], S)
            )

            def lf(lp_, x_):
                y, aux = T._layer_fwd(cfg, lp_, x_, positions)
                return jnp.sum(y.astype(jnp.float32)) + aux

            return jax.grad(lf, argnums=(0, 1))(lp, x)

        layer_sh = jax.tree.map(lambda a: sds(a.shape[1:], a.dtype), params_sh["layers"])
        layer_sp = jax.tree.map(lambda s: P(*s[1:]), pspecs["layers"])
        x_sh = sds((B, S, cfg.d_model), cfg.dtype)
        x_sp = P(DP, None, None)

        return CellProgram(
            name=f"{cfg.name}:{shape_name}", kind="train",
            fn=functools.partial(train_step),
            in_shapes=(params_sh, opt_sh, sds((B, S), jnp.int32), sds((B, S), jnp.int32)),
            in_specs=(pspecs, opt_specs, P(DP, None), P(DP, None)),
            out_specs=(pspecs, opt_specs, P(), P()),
            donate=(0, 1),
            dtype=str(jnp.dtype(cfg.dtype)),
            model_flops=lm_model_flops(cfg, "train", B, S),
            scan_correction=(
                layer_step, (layer_sh, x_sh), (layer_sp, x_sp), cfg.n_layers - 1,
            ),
        )

    if sh["kind"] == "prefill":
        cache_spec = (P(None, "data", "model", None, None),) * 2

        def prefill_step(params, tokens):
            return T.prefill(params, cfg, tokens)

        def layer_prefill(lp, x):
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.float32), (x.shape[0], S))
            return T._layer_fwd(cfg, lp, x, positions)[0]

        params_lsh = jax.tree.map(lambda a: sds(a.shape[1:], a.dtype), params_sh["layers"])
        layer_sp = jax.tree.map(lambda s: P(*s[1:]), pspecs["layers"])
        return CellProgram(
            name=f"{cfg.name}:{shape_name}", kind="serve",
            fn=prefill_step,
            in_shapes=(params_sh, sds((B, S), jnp.int32)),
            in_specs=(pspecs, P(DP, None)),
            out_specs=(P(DP, "model"), cache_spec),
            dtype=str(jnp.dtype(cfg.dtype)),
            model_flops=lm_model_flops(cfg, "prefill", B, S),
            scan_correction=(
                layer_prefill,
                (params_lsh, sds((B, S, cfg.d_model), cfg.dtype)),
                (layer_sp, P(DP, None, None)),
                cfg.n_layers - 1,
            ),
        )

    # decode
    C = S
    cache_sh = tuple(
        sds((cfg.n_layers, B, C, cfg.n_kv, cfg.head_dim), cfg.dtype) for _ in range(2)
    )
    if B == 1:
        cache_sp = (P(None, None, DP + ("model",), None, None),) * 2
        tok_sp = P(None)
    else:
        cache_sp = (P(None, DP, "model", None, None),) * 2
        tok_sp = P(DP)

    def decode(params, ck, cv, token, pos):
        logits, (ck2, cv2) = T.decode_step(params, cfg, token, (ck, cv), pos)
        return logits, ck2, cv2

    def layer_decode(lp, ck, cv, x, pos):
        from ..layers.attention import attention_decode
        from ..layers.common import rms_norm, swiglu
        from ..layers.moe import moe_apply_dense

        h, (ck2, cv2) = attention_decode(
            lp["attn"], cfg.attn_cfg(), rms_norm(x, lp["ln1"]), (ck, cv), pos, None
        )
        x = x + h
        z = rms_norm(x, lp["ln2"])
        if cfg.moe is not None:
            y, _ = moe_apply_dense(lp["moe"], cfg.moe, z)
        else:
            y = swiglu(z, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
        return x + y, ck2, cv2

    params_lsh = jax.tree.map(lambda a: sds(a.shape[1:], a.dtype), params_sh["layers"])
    layer_sp = jax.tree.map(lambda s: P(*s[1:]), pspecs["layers"])
    lcache_sh = sds((B, C, cfg.n_kv, cfg.head_dim), cfg.dtype)
    lcache_sp = P(*cache_sp[0][1:])
    return CellProgram(
        name=f"{cfg.name}:{shape_name}", kind="serve",
        fn=decode,
        in_shapes=(params_sh, *cache_sh, sds((B,), jnp.int32), sds((B,), jnp.int32)),
        in_specs=(pspecs, *cache_sp, tok_sp, tok_sp),
        out_specs=(P(tok_sp[0] if B > 1 else None, "model"), *cache_sp),
        donate=(1, 2),
        dtype=str(jnp.dtype(cfg.dtype)),
        model_flops=lm_model_flops(cfg, "decode", B, S),
        scan_correction=(
            layer_decode,
            (params_lsh, lcache_sh, lcache_sh,
             sds((B, 1, cfg.d_model), cfg.dtype), sds((B,), jnp.int32)),
            (layer_sp, lcache_sp, lcache_sp, P(tok_sp[0], None, None), tok_sp),
            cfg.n_layers - 1,
        ),
        notes="long-context decode is O(seq) per token (sub-quadratic); "
        "prefill at this length is out of scope for full-attention archs"
        if shape_name == "long_500k" else "",
    )


# ---------------------------------------------------------------------------
# GNN (SchNet) cells
# ---------------------------------------------------------------------------

# sizes are the assigned shapes padded up to multiples of 512 (device count)
# so every axis shards cleanly; the data pipeline pads identically.
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=3072, n_edges=10752, d_feat=1433, task="node", n_graphs=1,
                          true=(2708, 10556)),
    "minibatch_lg": dict(n_nodes=176128, n_edges=169984, d_feat=602, task="node", n_graphs=1,
                         true=(176128, 169984)),
    "ogb_products": dict(n_nodes=2449408, n_edges=61865984, d_feat=100, task="node", n_graphs=1,
                         true=(2449029, 61859140)),
    "molecule": dict(n_nodes=4096, n_edges=8192, d_feat=None, task="graph", n_graphs=128,
                     true=(3840, 8192)),
}


def gnn_model_flops(cfg: G.SchNetConfig, sh) -> float:
    d, r = cfg.d_hidden, cfg.n_rbf
    E, N = sh["n_edges"], sh["n_nodes"]
    per_iter = 2.0 * E * r * d + 2.0 * E * d * d + 2.0 * E * d + 2.0 * N * d * d * 2
    inp = 2.0 * N * (sh["d_feat"] or 1) * d
    fwd = inp + cfg.n_interactions * per_iter + 2.0 * N * d * (d // 2)
    return 3.0 * fwd  # train: fwd + ~2x bwd


def build_gnn_cell(cfg: G.SchNetConfig, shape_name: str, opt_cfg=None) -> CellProgram:
    sh = GNN_SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, d_node_feat=sh["d_feat"])
    params_sh = abstract_params(lambda k: G.init_params(k, cfg))
    pspecs = G.param_specs(cfg)
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    opt_sh = jax.eval_shape(adamw.init_state, params_sh)
    opt_specs = adamw.zero1_specs(params_sh, pspecs, data_size=1)
    N, E = sh["n_nodes"], sh["n_edges"]
    n_graphs = sh["n_graphs"]

    node_in = sds((N, sh["d_feat"]), jnp.float32) if sh["d_feat"] else sds((N,), jnp.int32)
    batch_sh = dict(
        node_input=node_in,
        edge_src=sds((E,), jnp.int32),
        edge_dst=sds((E,), jnp.int32),
        edge_dist=sds((E,), jnp.float32),
        graph_ids=sds((N,), jnp.int32),
        targets=sds((n_graphs if sh["task"] == "graph" else N,), jnp.float32),
    )
    edge_sp = P(DP + ("model",)) if cfg.edge_shard_model else P(DP)
    batch_sp = dict(
        node_input=P(DP, None) if sh["d_feat"] else P(DP),
        edge_src=edge_sp, edge_dst=edge_sp, edge_dist=edge_sp,
        graph_ids=P(DP),
        targets=P() if sh["task"] == "graph" else P(DP),
    )

    def loss_fn(params, batch):
        n_out = n_graphs if sh["task"] == "graph" else None
        pred = G.forward(params, cfg, batch, n_out)
        return jnp.mean((pred.astype(jnp.float32) - batch["targets"].astype(jnp.float32)) ** 2)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss, gnorm

    return CellProgram(
        name=f"schnet:{shape_name}", kind="train",
        fn=train_step,
        in_shapes=(params_sh, opt_sh, batch_sh),
        in_specs=(pspecs, opt_specs, batch_sp),
        out_specs=(pspecs, opt_specs, P(), P()),
        donate=(0, 1),
        model_flops=gnn_model_flops(cfg, sh),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    # 1M candidates padded to 2^20 so the candidate axis shards over 512
    # devices (the data pipeline pads with repeated ids; scores of pads are
    # discarded host-side)
    "retrieval_cand": dict(kind="retrieve", batch=1, n_candidates=1_048_576),
}


def _recsys_batch(model_cfg, B, with_label=True):
    S = model_cfg.seq_len
    b = {
        "hist": sds((B, S), jnp.int32),
        "target": sds((B,), jnp.int32),
        "user_id": sds((B,), jnp.int32),
    }
    sp = {"hist": P(DP, None), "target": P(DP), "user_id": P(DP)}
    if with_label:
        b["label"] = sds((B,), jnp.float32)
        sp["label"] = P(DP)
    return b, sp


def build_recsys_cell(arch: str, model_cfg, shape_name: str, opt_cfg=None) -> CellProgram:
    sh = RECSYS_SHAPES[shape_name]
    B = sh["batch"]
    init, specs, loss, serve = {
        "din": (R.din_init, R.din_specs, R.din_loss, R.din_forward),
        "bst": (R.bst_init, R.bst_specs, R.bst_loss, R.bst_forward),
        "mind": (R.mind_init, R.mind_specs, R.mind_loss, None),
        "two-tower-retrieval": (R.twotower_init, R.twotower_specs, R.twotower_loss, R.twotower_serve),
    }[arch]
    params_sh = abstract_params(lambda k: init(k, model_cfg))
    pspecs = specs(model_cfg)
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    d = model_cfg.embed_dim
    S = model_cfg.seq_len
    mlp_flops = {
        "din": 2.0 * (S * 4 * d * 80 + S * 80 * 40 + 3 * d * 200 + 200 * 80),
        "bst": 2.0 * (S * 4 * d * d + 2 * S * S * d + 2 * S * d * 128 + S * d * 1024 + 1024 * 512 + 512 * 256),
        "mind": 2.0 * (S * d * d + 3 * (S * 4 * d + 4 * d)) ,
        "two-tower-retrieval": 2.0 * (2 * d * 1024 + 1024 * 512 + 512 * 256 + d * 1024),
    }[arch]

    if sh["kind"] == "train":
        opt_sh = jax.eval_shape(adamw.init_state, params_sh)
        opt_specs = adamw.zero1_specs(params_sh, pspecs, data_size=1)
        batch_sh, batch_sp = _recsys_batch(model_cfg, B)

        def train_step(params, opt_state, batch):
            lv, grads = jax.value_and_grad(loss)(params, model_cfg, batch)
            params, opt_state, gnorm = adamw.update(opt_cfg, params, grads, opt_state)
            return params, opt_state, lv, gnorm

        extra = 2.0 * B * B * 256 if arch in ("two-tower-retrieval", "mind") else 0.0
        return CellProgram(
            name=f"{arch}:{shape_name}", kind="train",
            fn=train_step,
            in_shapes=(params_sh, opt_sh, batch_sh),
            in_specs=(pspecs, opt_specs, batch_sp),
            out_specs=(pspecs, opt_specs, P(), P()),
            donate=(0, 1),
            model_flops=3.0 * B * mlp_flops + 3.0 * extra,
        )

    if sh["kind"] == "serve":
        batch_sh, batch_sp = _recsys_batch(model_cfg, B, with_label=False)
        serve_fn = serve if serve is not None else R.mind_point_serve

        def serve_step(params, batch):
            return serve_fn(params, model_cfg, batch)

        out_sp = P(DP)
        return CellProgram(
            name=f"{arch}:{shape_name}", kind="serve",
            fn=serve_step,
            in_shapes=(params_sh, batch_sh),
            in_specs=(pspecs, batch_sp),
            out_specs=out_sp,
            model_flops=B * mlp_flops,
        )

    # retrieval: one query against n_candidates
    NC = sh["n_candidates"]
    batch_sh = {
        "hist": sds((1, S), jnp.int32),
        "user_id": sds((1,), jnp.int32),
        "candidates": sds((NC,), jnp.int32),
    }
    batch_sp = {"hist": P(None, None), "user_id": P(None), "candidates": P(DP + ("model",))}

    if arch == "two-tower-retrieval":
        def retrieve(params, batch):
            return R.twotower_retrieve(params, model_cfg, batch)
        flops = NC * (2.0 * d * 1024 + 1024 * 512 + 512 * 256) + 2.0 * NC * 256
        out_sp = (P(None), P(None))
    elif arch == "mind":
        def retrieve(params, batch):
            return R.mind_serve(params, model_cfg, batch)
        flops = 2.0 * NC * model_cfg.n_interests * d
        out_sp = P(None, DP + ("model",))
    else:
        # DIN/BST score each candidate with the full interaction tower
        def retrieve(params, batch):
            bb = {
                "hist": jnp.broadcast_to(batch["hist"], (NC, S)),
                "target": batch["candidates"],
                "user_id": jnp.broadcast_to(batch["user_id"], (NC,)),
            }
            return serve(params, model_cfg, bb)
        flops = NC * mlp_flops
        out_sp = P(DP + ("model",))

    return CellProgram(
        name=f"{arch}:{shape_name}", kind="serve",
        fn=retrieve,
        in_shapes=(params_sh, batch_sh),
        in_specs=(pspecs, batch_sp),
        out_specs=out_sp,
        model_flops=flops,
    )
