import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective schedules.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun

The XLA_FLAGS line above MUST stay the first statement: jax fixes the device
count at first init.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from collections import Counter  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import registry  # noqa: E402
from ..parallel.sharding import named_sharding_tree  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# byte widths for HLO shape parsing
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string like 'bf16[16,128]{1,0}'
    or a tuple '(f32[4], f32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, *, while_trip_counts: bool = True) -> dict:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Collectives inside while bodies are counted once by text structure; the
    caller scales scan-region collectives via the roofline correction.
    Returns {op_name: {"count": n, "bytes": b}}.
    """
    out: dict = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.rstrip("-start").rstrip("-done") if op.endswith(("-start", "-done")) else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base]["count"] += 1
            out[base]["bytes"] += _shape_bytes(shape_str)
    return out


def compile_cell(cell, mesh):
    """Lower + compile one cell on a mesh; return (record, compiled)."""
    in_sh = named_sharding_tree(cell.in_specs, mesh)
    out_sh = named_sharding_tree(cell.out_specs, mesh)
    t0 = time.time()
    jitted = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=cell.donate)
    # ambient mesh so the models' internal with_sharding_constraint hints
    # (shard_hint) resolve — without it they silently no-op
    with jax.set_mesh(mesh):
        lowered = jitted.lower(*cell.in_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    record = {
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "bytes_per_device": {
            "arguments": ma.argument_size_in_bytes,
            "outputs": ma.output_size_in_bytes,
            "temps": ma.temp_size_in_bytes,
            "aliased": ma.alias_size_in_bytes,
            "peak_estimate": ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        },
        "cost_per_device": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": colls,
        "model_flops": cell.model_flops,
        "dtype": cell.dtype,
        "notes": cell.notes,
    }
    return record, compiled, lowered


def run_cell(arch, shape, mesh, *, verbose=True, overrides=None):
    cell = registry.build_cell(arch, shape, overrides=overrides)
    rec, compiled, _ = compile_cell(cell, mesh)
    # scan correction: compile the single-layer program, scale by multiplier
    if cell.scan_correction is not None:
        layer_fn, lsh, lsp, mult = cell.scan_correction
        in_sh = named_sharding_tree(lsp, mesh)
        with jax.set_mesh(mesh):
            lcomp = jax.jit(layer_fn, in_shardings=in_sh).lower(*lsh).compile()
        lca = lcomp.cost_analysis()
        lcolls = parse_collectives(lcomp.as_text())
        rec["layer_cost_per_device"] = {
            "flops": lca.get("flops", 0.0),
            "bytes_accessed": lca.get("bytes accessed", 0.0),
            "collectives": lcolls,
            "multiplier": mult,
        }
    if verbose:
        b = rec["bytes_per_device"]
        print(
            f"  {rec['cell']:42s} compile {rec['t_compile_s']:6.1f}s  "
            f"peak/dev {b['peak_estimate']/2**30:7.2f} GiB  "
            f"flops/dev {rec['cost_per_device']['flops']:.3e}  "
            f"colls "
            + ",".join(f"{k.split('-')[-1][:4]}:{v['count']}" for k, v in rec["collectives"].items() if v["count"])
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all for arch)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="key=json_value config override (hillclimb variants)")
    ap.add_argument("--tag", default=None, help="suffix for output json names")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = json.loads(v)

    assert len(jax.devices()) == 512, "dry-run needs 512 host devices"
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = (
        registry.all_cells()
        if args.all or args.arch is None
        else [
            (args.arch, s)
            for s in ([args.shape] if args.shape else registry.shapes_for(args.arch))
        ]
    )
    meshes = {
        "single": [False],
        "multi": [True],
        "both": [False, True],
    }[args.mesh]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = "pod2" if multi else "pod1"
        print(f"== mesh {tag}: {dict(zip(mesh.axis_names, mesh.devices.shape))} ==")
        for arch, shape in cells:
            key = f"{arch}__{shape}__{tag}".replace("/", "_")
            if args.tag:
                key += f"__{args.tag}"
            fp = outdir / f"{key}.json"
            if fp.exists():
                print(f"  [cached] {key}")
                continue
            try:
                rec = run_cell(arch, shape, mesh, overrides=overrides or None)
                fp.write_text(json.dumps(rec, indent=1))
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((key, str(e)))
                print(f"  FAIL {key}: {e}")
                (outdir / f"{key}.FAILED").write_text(traceback.format_exc())
    print(f"\n{len(failures)} failures")
    for k, e in failures:
        print(" ", k, e.splitlines()[0][:160] if e else "")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
