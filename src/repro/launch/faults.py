"""Deterministic fault injection for the sharded ingestion tier.

Every failure path of ``stats.shardtier`` is exercised by *replayable*
schedules, not by ambient randomness: a :class:`FaultSchedule` is a frozen
list of ``(site, call_no, kind, param)`` events derived from a seed through
the same counter-based splittable hashing that drives the samplers
(``core.hashing`` — no PRNG state, so a schedule is a pure function of its
seed and the site registry).  The tier wraps every failure-prone operation
in a context-managed hook::

    with injector.site("shard2.ingest"):
        worker.apply(seq, keys, weights)

and the injector fires an event when that site's invocation counter matches
an event's ``call_no``.  The same schedules drive two backends: the
in-process tier (stats.shardtier) receives faults as exceptions from
``site()``, and the out-of-process tier (stats.procshard) consumes events
through ``poll()`` and realizes them against REAL worker subprocesses —
``crash`` becomes an actual ``SIGKILL``, ``partition`` severs the actual
socket.  Four fault kinds model the distributed-systems failure menagerie
(process mode adds a fifth, ``partition``, via ``PROC_KINDS``):

* ``crash``      — the callee dies before doing any work (the worker drops
  its in-memory state; recovery = checkpoint restore + WAL replay);
* ``stall``      — the call times out (clock advances past the deadline,
  the operation never ran; the caller's bounded retry fires);
* ``slow``       — the call succeeds but late (clock advances; retry
  budgets and heartbeat miss-counting see the latency);
* ``lost_reply`` — the operation RAN but the reply is dropped (the caller
  sees a failure for a call that succeeded; retries must be idempotent —
  the tier dedups by WAL sequence number).

Schedules serialize to/from plain dicts (``to_json``/``from_json``) so a
failing CI seed can be committed verbatim as a regression schedule.

Time is virtual by default (:class:`VirtualClock`): backoff sleeps and
stall/slow latencies advance a counter instead of the wall clock, keeping
the chaos suite fast and bit-deterministic.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time

import numpy as np

from ..core import hashing

# The injection-site registry (DESIGN.md §13): format strings over the shard
# id.  Keep this list in sync with stats/shardtier.py — the chaos tests
# generate schedules over exactly these sites.
SITES = (
    "shard{i}.ingest",      # ShardWorker.apply (WAL already durable)
    "shard{i}.heartbeat",   # ShardWorker.heartbeat (failure detection)
    "shard{i}.query",       # ShardWorker.sampler_view (snapshot extraction)
    "shard{i}.checkpoint",  # ShardWorker.checkpoint (atomic commit inside)
    "shard{i}.recover",     # ShardWorker.recover (restore + WAL replay)
)

KINDS = ("crash", "stall", "slow", "lost_reply")

# Process-mode schedules (stats.procshard) additionally draw ``partition``:
# the coordinator's connection to a live worker drops — the process keeps
# running and keeps its state, but every call fails until a reconnect.
# Kept OUT of KINDS so existing seeds map to the same schedules they always
# did (generate() indexes kinds by hash % len(kinds)).
PROC_KINDS = KINDS + ("partition",)


class FaultError(RuntimeError):
    """Base of all injected faults; carries the site it fired at."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}" + (f": {detail}" if detail else ""))
        self.site = site


class InjectedCrash(FaultError):
    """The callee process died — its in-memory state is gone."""


class InjectedStall(FaultError):
    """The call exceeded its deadline; the operation did NOT run."""


class InjectedLostReply(FaultError):
    """The operation ran but the reply was dropped on the wire."""


class InjectedPartition(FaultError):
    """The network path to a LIVE callee dropped: the operation did not run
    (process-mode backends sever the real connection; callers must treat it
    like a stall — retriable — and reconnect)."""


class Unreachable(RuntimeError):
    """A real transport failure (socket timeout, refused connect) to a
    worker whose process may still be alive.  NOT an injected fault — this
    is what genuine process-mode flakiness surfaces as.  Callers retry it
    exactly like a stall; only process death maps to ShardDown."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire ``kind`` on the ``call_no``-th invocation
    (1-based) of ``site``.  ``param`` is the stall/slow latency in (virtual)
    seconds; ignored for crash/lost_reply."""

    site: str
    call_no: int
    kind: str
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in PROC_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {PROC_KINDS})")
        if self.call_no < 1:
            raise ValueError("call_no is 1-based")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A frozen, replayable set of fault events."""

    events: tuple[FaultEvent, ...] = ()
    seed: int | None = None  # provenance only; replay uses the events

    @classmethod
    def generate(cls, seed: int, *, n_shards: int, n_events: int,
                 sites: tuple[str, ...] = SITES,
                 kinds: tuple[str, ...] = KINDS,
                 max_call_no: int = 8,
                 max_latency_s: float = 2.0) -> "FaultSchedule":
        """Derive ``n_events`` events from ``seed`` with counter-based
        hashing (bit-reproducible across platforms; no PRNG state).

        Events are deduplicated on (site, call_no) — two faults cannot fire
        on the same invocation — so the realized count can be < n_events.
        """
        idx = np.arange(n_events, dtype=np.int64)
        # idx first: the array part keeps the uint32 mixing array-shaped
        # (0-d chains trip numpy's scalar-overflow warning)
        h_site = hashing.hash_combine_np(idx, np.int64(seed), np.int64(0))
        h_shard = hashing.hash_combine_np(idx, np.int64(seed), np.int64(1))
        h_call = hashing.hash_combine_np(idx, np.int64(seed), np.int64(2))
        h_kind = hashing.hash_combine_np(idx, np.int64(seed), np.int64(3))
        h_lat = hashing.hash_combine_np(idx, np.int64(seed), np.int64(4))
        events: dict[tuple[str, int], FaultEvent] = {}
        for i in range(n_events):
            site = sites[int(h_site[i]) % len(sites)].format(
                i=int(h_shard[i]) % n_shards)
            call_no = 1 + int(h_call[i]) % max_call_no
            kind = kinds[int(h_kind[i]) % len(kinds)]
            lat = float(hashing.uniform01_np(h_lat[i])) * max_latency_s
            events.setdefault((site, call_no), FaultEvent(
                site=site, call_no=call_no, kind=kind,
                param=round(lat, 6) if kind in ("stall", "slow") else 0.0))
        ordered = tuple(sorted(events.values(),
                               key=lambda e: (e.site, e.call_no)))
        return cls(events=ordered, seed=seed)

    # -- record/replay -----------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "events": [dataclasses.asdict(e) for e in self.events],
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        d = json.loads(text)
        return cls(events=tuple(FaultEvent(**e) for e in d["events"]),
                   seed=d.get("seed"))


class VirtualClock:
    """Deterministic time for the chaos suite: ``sleep``/``advance`` move a
    counter, never the wall clock — a seeded run is bit-reproducible and
    takes no real time regardless of how many backoffs it schedules."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self._t += max(float(dt), 0.0)

    advance = sleep


class WallClock:
    """Real time, for live deployments of the tier."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(max(float(dt), 0.0))

    def advance(self, dt: float) -> None:
        """Injected latency under a wall clock is simulated by sleeping."""
        self.sleep(dt)


class FaultInjector:
    """Fires a schedule's events at named call sites (context-managed).

    Per-site invocation counters make injection deterministic: the Nth
    ``with injector.site(s):`` block fires the event scheduled for
    ``(s, N)`` regardless of wall time or interleaving elsewhere.  The
    injector records every fired event in ``fired`` (a replayable trace).
    """

    def __init__(self, schedule: FaultSchedule | None = None,
                 clock: VirtualClock | WallClock | None = None):
        self.schedule = schedule or FaultSchedule()
        self.clock = clock if clock is not None else VirtualClock()
        self._by_key = {(e.site, e.call_no): e for e in self.schedule.events}
        self.counts: dict[str, int] = {}
        self.fired: list[FaultEvent] = []

    def _next(self, site: str) -> FaultEvent | None:
        n = self.counts.get(site, 0) + 1
        self.counts[site] = n
        return self._by_key.get((site, n))

    def poll(self, site: str) -> FaultEvent | None:
        """Advance ``site``'s invocation counter and return the event
        scheduled for this call (recording it in ``fired``), or None.

        This is the raw hook for backends that must ACT on an event rather
        than receive it as an exception — the process-mode backend
        (stats.procshard) turns ``crash`` into a real SIGKILL and
        ``partition`` into severing a real socket, which no in-process
        raise can express."""
        ev = self._next(site)
        if ev is not None:
            self.fired.append(ev)
        return ev

    @contextlib.contextmanager
    def site(self, name: str):
        """Wrap one failure-prone operation.  May raise InjectedCrash /
        InjectedStall / InjectedPartition *instead of* running the body,
        advance the clock and run it (slow), or run it and then raise
        InjectedLostReply."""
        ev = self.poll(name)
        if ev is not None:
            if ev.kind == "crash":
                raise InjectedCrash(name)
            if ev.kind == "stall":
                self.clock.advance(ev.param)
                raise InjectedStall(name, f"stalled {ev.param:g}s")
            if ev.kind == "partition":
                raise InjectedPartition(name)
            if ev.kind == "slow":
                self.clock.advance(ev.param)
        yield
        if ev is not None and ev.kind == "lost_reply":
            raise InjectedLostReply(name)
