"""Production mesh definitions (functions — importing never touches jax
device state)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the jax version has them
    (older jax has no AxisType and defaults to the equivalent behavior)."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
    except ImportError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / single host)."""
    n = n_devices or len(jax.devices())
    return make_mesh((1, n, 1), ("pod", "data", "model"))
