"""Training launcher: end-to-end loop with checkpoint/restart, stream
statistics, straggler-tolerant data feed, and optional gradient compression.

Scales from the CPU example (examples/train_lm.py trains a ~100M model) to
the production mesh (same step function the dry-run lowers at 512 chips).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 8 --seq 128

Randomness boundary: model-parameter init uses ``jax.random.PRNGKey``
(baselined, reprolint RPL005); the stream-statistics side draws no ambient
randomness — sampling scores derive from ``core/hashing.py`` salts.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import manager as ckpt
from ..configs import registry
from ..data.streams import ShardedStream, StreamCursor
from ..models import transformer as T
from ..optim import adamw
from ..optim.compression import compress_gradients_ef
from ..stats.service import StatsConfig, StreamStatsService


def make_train_step(cfg, opt_cfg, *, grad_compression: bool = False, error_feedback=None):
    def train_step(params, opt_state, ef_state, tokens, labels):
        loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, tokens, labels)
        if grad_compression:
            grads, ef_state = compress_gradients_ef(grads, ef_state)
        params, opt_state, gnorm = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, ef_state, loss, gnorm

    return jax.jit(train_step, donate_argnums=(0, 1, 2))


def run(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str | None, ckpt_every: int = 50, resume: bool = True,
        grad_compression: bool = False, lr: float = 3e-4, log_every: int = 10):
    cfg = registry.get_config(arch, smoke=smoke)
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=max(steps, 2), warmup=min(20, steps // 5 + 1))

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    ef_state = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) if grad_compression else 0
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

    stream = ShardedStream(
        n_total=10_000_000, alpha=1.2, n_keys=cfg.vocab,
        seed=7, cursor=StreamCursor(shard=jax.process_index(), n_shards=max(jax.process_count(), 1)),
    )
    stats = StreamStatsService(StatsConfig(k=512, ls=(1.0, 16.0, 256.0), chunk=1024))

    start = 0
    if ckpt_dir and resume and (ls := ckpt.latest_step(ckpt_dir)) is not None:
        state = ckpt.restore(ckpt_dir, ls, (params, opt_state))
        params, opt_state = state
        extra = ckpt.restore_extra(ckpt_dir, ls)
        if "cursor" in extra:
            stream.load_state_dict(extra["cursor"])
        start = ls
        print(f"[train] resumed from step {ls}")

    step_fn = make_train_step(cfg, opt_cfg, grad_compression=grad_compression)
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        toks = stream.next_batch(batch * (seq + 1)).reshape(batch, seq + 1) % cfg.vocab
        stats.observe(toks.reshape(-1))  # token-frequency sketches (the paper)
        tokens = jnp.asarray(toks[:, :-1], jnp.int32)
        labels = jnp.asarray(toks[:, 1:], jnp.int32)
        params, opt_state, ef_state, loss, gnorm = step_fn(params, opt_state, ef_state, tokens, labels)
        losses.append(float(loss))
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            print(f"[train] step {step+1:5d} loss {np.mean(losses[-log_every:]):.4f} "
                  f"gnorm {float(gnorm):.2f} {dt*1000:.0f} ms/step")
            t0 = time.time()
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, opt_state),
                      extra={"cursor": stream.state_dict()})
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, (params, opt_state), extra={"cursor": stream.state_dict()})
    print(f"[train] {arch}: {n_params/1e6:.1f}M params, "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}")
    print(f"[stats] distinct tokens ~ {stats.query_distinct():.0f}; "
          f"cap_16 mass ~ {stats.query_cap(16):.0f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    run(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, grad_compression=args.grad_compression,
        lr=args.lr)


if __name__ == "__main__":
    main()
