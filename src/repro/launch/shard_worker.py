"""Shard worker subprocess entry point (``python -m repro.launch.shard_worker``).

The out-of-process tier (stats.procshard, DESIGN.md §14) runs each shard as
one of these: a plain event loop wrapping the SAME in-process
:class:`~..stats.shardtier.ShardWorker` the tier has used since PR 9 —
idempotent seq-deduped apply, checkpoint cadence, WAL-replay recover — and
speaking the length-prefixed ``.npz`` frame protocol over an ``AF_UNIX``
socket the supervisor listens on.

Protocol (one request frame in, one response frame out, strictly serial):

====================  =====================================================
request ``op``        response (on ``ok=True``)
====================  =====================================================
``apply``             ``applied_seq``, ``last_ckpt_seq`` (idempotent ack)
``heartbeat``         ``applied_seq``, ``last_ckpt_seq``
``checkpoint``        ``applied_seq``, ``last_ckpt_seq``
``recover``           ``applied_seq``, ``last_ckpt_seq``
``state``             flat ``state_dict`` leaves under the ``s_`` prefix
``shutdown``          (ack, then the process exits 0)
====================  =====================================================

Failures reply ``ok=False`` with ``error_type``/``error``; the client maps
``ShardDown``/``ValueError`` back onto themselves and wraps everything else
in ``RemoteError``.  An EOF on the socket means the coordinator dropped the
connection (shutdown or an injected partition) — the worker RECONNECTS to
the same socket path and keeps its state: a partition must not look like a
crash.  The worker only exits on an explicit ``shutdown`` op or when the
socket path stops accepting connections (coordinator gone for good).

Durable state — checkpoints and the WAL — lives under ``--root`` on a
filesystem shared with the coordinator: the coordinator appends WAL
segments (WAL-first ingest) and runs exact pass II from them; this process
restores/replays them in ``recover`` and truncates them at checkpoints
(unless ``--retain-wal``).
"""
from __future__ import annotations

import argparse
import socket
import sys
import time


def _build_worker(args):
    # jax import happens here (inside repro.stats) — keep the cold-start
    # cost out of module import so ``--help`` stays instant
    from ..stats.service import StatsConfig
    from ..stats.shardtier import ShardWorker
    import json

    cfg_d = json.loads(args.config_json)
    cfg_d["ls"] = tuple(cfg_d["ls"])
    config = StatsConfig(**cfg_d)
    return ShardWorker(
        args.shard_id, config, args.root,
        checkpoint_every=args.checkpoint_every,
        retain_wal=bool(args.retain_wal),
        fsync=bool(args.fsync))


def _serve_conn(conn: socket.socket, worker) -> bool:
    """Serve one connection until EOF (returns True: reconnect) or a
    shutdown op (returns False: exit)."""
    import numpy as np

    from ..stats.procshard import pack_state, recv_frame, send_frame, _text

    send_frame(conn, {"op": "hello", "shard_id": np.int64(worker.shard_id)})
    while True:
        try:
            req = recv_frame(conn)
        except (ConnectionError, OSError):
            return True  # coordinator dropped us; keep state, reconnect
        op = _text(req["op"])
        try:
            if op == "shutdown":
                send_frame(conn, {"ok": True})
                return False
            if op == "apply":
                worker.apply(int(req["seq"]), req["keys"], req["weights"])
            elif op == "heartbeat":
                worker.heartbeat()
            elif op == "checkpoint":
                worker.checkpoint()
            elif op == "recover":
                worker.recover()
            elif op == "state":
                svc = worker.service_view()
                resp = {"ok": True,
                        "applied_seq": np.int64(worker.applied_seq)}
                resp.update(pack_state(svc.state_dict()))
                send_frame(conn, resp)
                continue
            else:
                raise ValueError(f"unknown op {op!r}")
            send_frame(conn, {
                "ok": True,
                "applied_seq": np.int64(worker.applied_seq),
                "last_ckpt_seq": np.int64(worker._last_ckpt_seq),
            })
        except Exception as e:  # noqa: BLE001 — every failure goes on the wire
            try:
                send_frame(conn, {"ok": False,
                                  "error_type": type(e).__name__,
                                  "error": str(e)})
            except (ConnectionError, OSError):
                return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--socket", required=True,
                    help="AF_UNIX path the supervisor listens on")
    ap.add_argument("--shard-id", type=int, required=True)
    ap.add_argument("--root", required=True,
                    help="tier root (shared fs: checkpoints + WAL)")
    ap.add_argument("--config-json", required=True,
                    help="StatsConfig fields as JSON (host_id unset)")
    ap.add_argument("--checkpoint-every", type=int, default=8)
    ap.add_argument("--retain-wal", type=int, default=0)
    ap.add_argument("--fsync", type=int, default=1)
    ap.add_argument("--reconnect-window-s", type=float, default=10.0,
                    help="keep retrying connect this long after an EOF "
                         "before concluding the coordinator is gone")
    args = ap.parse_args(argv)

    worker = _build_worker(args)
    first = True
    while True:
        deadline = time.monotonic() + args.reconnect_window_s
        conn = None
        while True:
            try:
                conn = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                conn.connect(args.socket)
                break
            except OSError:
                conn.close()
                conn = None
                if first or time.monotonic() >= deadline:
                    # never managed a first connect, or the listener is
                    # gone past the window: nothing left to serve
                    return 1
                time.sleep(0.05)
        first = False
        try:
            if not _serve_conn(conn, worker):
                return 0
        finally:
            conn.close()


if __name__ == "__main__":
    sys.exit(main())
