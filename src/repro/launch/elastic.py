"""Elastic scaling / failure-recovery simulation.

Randomness boundary: demo inputs here come from ``jax.random`` /
``np.random`` (baselined, reprolint RPL005); library-side sampling
randomness must derive from the salted ``(key, eid)`` hashes in
``core/hashing.py`` so restored/merged sketches stay coordinated.

Demonstrates (on host devices) the production story:
  1. train on an N-device mesh, checkpointing params + optimizer + data
     cursor + sampler sketches;
  2. a "node failure" kills the job;
  3. the job restarts on a *smaller* mesh (N/2), restores the checkpoint —
     arrays reshard automatically because checkpoints store logical shapes —
     and training resumes bit-continuously w.r.t. the data stream (cursor)
     and statistically-continuously w.r.t. the sketches (mergeable state).

``run_stats_handoff_demo`` is the serving-plane analogue — the
**join/leave surface** for the sharded stats tier (ROADMAP):

  * leave: a tenant departs a ``MultiTenantStats`` bank by slicing its row
    out of the bank's stacked checkpoint (``checkpoint.manager
    .restore_slice``) into a standalone ``StreamStatsService`` —
    bit-identical answers, no other tenant's state leaves disk;
  * join: a standalone service's state splices INTO a resident bank via
    ``MultiTenantStats.load_tenant_state_dict`` (rebalancing onto a
    serving replica).

A sharded tier moves tenants between replicas with exactly these two
operations; the scheduler (stats.scheduler) needs no changes because the
bank's tenant axis is position-addressed.

Run (subprocess-isolated, 8 host devices):
    PYTHONPATH=src python -m repro.launch.elastic
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..checkpoint import manager as ckpt  # noqa: E402
from .mesh import make_mesh  # noqa: E402
from ..configs import registry  # noqa: E402
from ..data.streams import ShardedStream, StreamCursor  # noqa: E402
from ..models import transformer as T  # noqa: E402
from ..optim import adamw  # noqa: E402
from ..parallel.sharding import named_sharding_tree  # noqa: E402


def _mesh(n):
    return make_mesh((n, 1), ("data", "model"))


def _step_fn(cfg, opt_cfg):
    def step(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(T.loss_fn)(params, cfg, tokens, labels)
        params, opt_state, _ = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    return step


def run_elastic_demo(steps_before=6, steps_after=6, batch=8, seq=64, verbose=True):
    cfg = registry.get_config("yi-6b", smoke=True)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=100, warmup=1)
    pspecs = T.param_specs(cfg)

    stream = ShardedStream(n_total=1_000_000, alpha=1.2, n_keys=cfg.vocab, seed=3,
                           cursor=StreamCursor(shard=0, n_shards=1))

    losses = []
    with tempfile.TemporaryDirectory() as d:
        # phase 1: 8-device mesh
        mesh = _mesh(len(jax.devices()))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree.map(jax.device_put, params, named_sharding_tree(pspecs, mesh))
        opt_state = adamw.init_state(params)
        step = jax.jit(_step_fn(cfg, opt_cfg), donate_argnums=(0, 1))
        for i in range(steps_before):
            toks = stream.next_batch(batch * (seq + 1)).reshape(batch, seq + 1) % cfg.vocab
            data_sh = NamedSharding(mesh, P("data", None))
            tokens = jax.device_put(jnp.asarray(toks[:, :-1], jnp.int32), data_sh)
            labels = jax.device_put(jnp.asarray(toks[:, 1:], jnp.int32), data_sh)
            params, opt_state, loss = step(params, opt_state, tokens, labels)
            losses.append(float(loss))
        ckpt.save(d, steps_before, (params, opt_state), extra={"cursor": stream.state_dict()})
        if verbose:
            print(f"[elastic] phase 1 on {mesh.devices.size} devices: losses {losses}")

        # phase 2: "failure" -> restart on half the devices, restore + reshard
        mesh2 = _mesh(len(jax.devices()) // 2)
        shard2 = named_sharding_tree((pspecs, {"m": pspecs, "v": pspecs, "count": P()}), mesh2)
        # optimizer-state specs mirror params here (zero disabled in the demo)
        abstract = (params, opt_state)
        params2, opt2 = ckpt.restore(d, steps_before, abstract, shardings=None)
        params2 = jax.tree.map(jax.device_put, params2, shard2[0])
        opt2_m = jax.tree.map(jax.device_put, opt2["m"], shard2[1]["m"])
        opt2_v = jax.tree.map(jax.device_put, opt2["v"], shard2[1]["v"])
        opt2 = {"m": opt2_m, "v": opt2_v, "count": jnp.asarray(opt2["count"])}
        stream2 = ShardedStream(n_total=1_000_000, alpha=1.2, n_keys=cfg.vocab, seed=3,
                                cursor=StreamCursor(**ckpt.restore_extra(d, steps_before)["cursor"]))
        step2 = jax.jit(_step_fn(cfg, opt_cfg), donate_argnums=(0, 1))
        for i in range(steps_after):
            toks = stream2.next_batch(batch * (seq + 1)).reshape(batch, seq + 1) % cfg.vocab
            data_sh = NamedSharding(mesh2, P("data", None))
            tokens = jax.device_put(jnp.asarray(toks[:, :-1], jnp.int32), data_sh)
            labels = jax.device_put(jnp.asarray(toks[:, 1:], jnp.int32), data_sh)
            params2, opt2, loss = step2(params2, opt2, tokens, labels)
            losses.append(float(loss))
        if verbose:
            print(f"[elastic] phase 2 on {mesh2.devices.size} devices: losses {losses[steps_before:]}")

    # loss must keep decreasing across the restart boundary (no reset spike)
    assert losses[steps_before] < losses[0], "training did not continue across restart"
    return losses


def run_stats_handoff_demo(n_tenants=4, n_elems=2000, verbose=True):
    """Tenant leave/join between a stacked bank and standalone services.

    Checkpoints a ``MultiTenantStats`` bank, restores ONE tenant's row into
    a fresh ``StreamStatsService`` (leave), verifies the answer is
    bit-identical, then splices a standalone service back into a second
    bank (join) and verifies again.  Returns the per-tenant estimates.
    """
    from ..core import freqfns  # noqa: F401  (query surface of the demo)
    from ..stats.service import MultiTenantStats, StatsConfig, StreamStatsService

    cfg = StatsConfig(k=128, ls=(1.0, 8.0), chunk=256)
    rng = np.random.default_rng(11)
    streams = [(rng.zipf(1.3, size=n_elems) % 500).astype(np.int64)
               for _ in range(n_tenants)]
    bank = MultiTenantStats(cfg, n_tenants=n_tenants)
    for t in range(n_tenants):
        bank.observe(t, streams[t])
    bank.drain()
    estimates = [bank.query_cap(t, 8.0) for t in range(n_tenants)]

    with tempfile.TemporaryDirectory() as d:
        bank.save_checkpoint(d, step=1)

        # leave: slice tenant 2 out of the bank checkpoint
        leaver = StreamStatsService(cfg)
        example = leaver.state_dict()
        example.pop("exact_ok")  # bank rows are 1-pass sketch state
        blob = ckpt.restore_slice(d, 1, example, index=2)
        blob["exact_ok"] = np.bool_(False)
        leaver.load_state_dict(blob)
        assert leaver.campaign_forecast(8.0) == estimates[2], \
            "leave handoff changed the tenant's answer"

        # join: splice a standalone service into a fresh bank's slot 0
        joiner = StreamStatsService(cfg)
        joiner.observe(streams[1])
        bank2 = MultiTenantStats(cfg, n_tenants=n_tenants)
        blob2 = joiner.state_dict()
        blob2.pop("exact_ok")
        bank2.load_tenant_state_dict(0, blob2)
        assert bank2.query_cap(0, 8.0) == estimates[1], \
            "join handoff changed the tenant's answer"
    if verbose:
        print(f"[elastic] stats handoff OK — leave (bank->service) and "
              f"join (service->bank) both bit-identical across "
              f"{n_tenants} tenants")
    return estimates


def run_shard_tier_elastic_demo(n_shards=3, n_batches=8, batch=400,
                                verbose=True):
    """Elastic join/leave driven by the shard-tier coordinator's membership
    view (stats.shardtier.ShardTier) — the tier-level counterpart of the
    tenant handoff above.

    A shard leaves gracefully (final checkpoint, slot marked ``left`` in
    the membership view); queries degrade with an explicit coverage stamp
    while its keys keep accumulating in the slot's WAL; ``join_shard``
    revives the slot from durable state and answers return to full
    coverage, bit-identical to a tier that never lost the shard.
    """
    from ..core import freqfns, hashing
    from ..stats.query import Query
    from ..stats.service import StatsConfig
    from ..stats.shardtier import ShardTier, TierConfig

    cfg = StatsConfig(k=128, ls=(1.0, 8.0), chunk=128)
    # demo stream from the library's own counter-based hashing (no ambient
    # PRNG): skewed int keys, unit weights
    eids = np.arange(n_batches * batch, dtype=np.int64)
    keys = (hashing.hash_combine_np(eids, np.int64(7)) % np.uint32(997)
            ).astype(np.int64) + 1
    batches = keys.reshape(n_batches, batch)
    queries = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]

    with tempfile.TemporaryDirectory() as d:
        oracle = ShardTier(cfg, TierConfig(n_shards=n_shards), d + "/oracle")
        tier = ShardTier(cfg, TierConfig(n_shards=n_shards), d + "/tier")
        for b in batches[: n_batches // 2]:
            oracle.ingest(b)
            tier.ingest(b)

        # leave: graceful decommission through the coordinator
        tier.leave_shard(1)
        assert tier.membership()[1] == "left"
        for b in batches[n_batches // 2:]:
            oracle.ingest(b)
            tier.ingest(b)  # shard 1's keys land in its WAL, unapplied
        degraded = tier.query_batch(queries)
        assert degraded.degraded and degraded.coverage < 1.0
        if verbose:
            print(f"[elastic] shard 1 left: coverage "
                  f"{degraded.coverage:.3f}, "
                  f"{degraded.staleness_elements} elements stale")

        # join: revive the slot from its durable state (checkpoint + WAL)
        assert tier.join_shard(1)
        assert tier.membership()[1] == "up"
        healthy = tier.query_batch(queries)
        want = oracle.query_batch(queries)
        assert not healthy.degraded and healthy.coverage == 1.0
        assert np.array_equal(healthy.estimates, want.estimates), \
            "post-join answers differ from the never-left tier"
        if verbose:
            print(f"[elastic] shard 1 rejoined: answers bit-identical to "
                  f"the never-left tier ({healthy.estimates})")
    return healthy


if __name__ == "__main__":
    ls = run_elastic_demo()
    print("[elastic] OK — continuous training across mesh change:",
          [round(x, 3) for x in ls])
    run_stats_handoff_demo()
    run_shard_tier_elastic_demo()
