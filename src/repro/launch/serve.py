"""LM serving launcher: continuous-batched decode with prefill admission.

A miniature production server loop: requests arrive with prompts, get
prefilled into free KV-cache slots, and all active slots decode together
every step (continuous batching).  The same prefill/decode functions lower
at 512 chips in the dry-run; here they run on CPU with a smoke config.

This is the *model* serving loop.  The *statistics* serving plane — the
paper's application tier — lives in ``launch.stats_serve`` /
``stats.scheduler``, which apply the same continuous-batching idea to
multi-tenant sketch banks (admission queues, coalesced dispatch, overlap).

Randomness boundary: ``main`` uses ``jax.random`` / ``np.random`` only to
fabricate demo weights and prompts (baselined, reprolint RPL005);
library-side sampling randomness must come from ``core/hashing.py`` salts.

    PYTHONPATH=src python -m repro.launch.serve --requests 6 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..models import transformer as T


class DecodeServer:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 160):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.cache = T.init_cache(cfg, slots, max_len, jnp.float32)
        self.pos = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)
        self.outputs: dict[int, list[int]] = {}
        self.slot_req: dict[int, int] = {}
        # The KV cache is rebound to the call result on every step and the
        # old buffers are never read again, so donate them in place.
        self._decode = jax.jit(
            lambda p, tok, cache, pos: T.decode_step(p, cfg, tok, cache, pos),
            donate_argnums=(2,),
        )

    def admit(self, req_id: int, prompt: np.ndarray) -> bool:
        free = np.nonzero(~self.active)[0]
        if len(free) == 0:
            return False
        slot = int(free[0])
        # prefill the prompt token-by-token into the slot (slot-local prefill;
        # the batched-prefill path is models.transformer.prefill)
        for t, tok in enumerate(prompt.tolist()):
            token = jnp.zeros((self.slots,), jnp.int32).at[slot].set(tok)
            pos = jnp.asarray(np.where(self.active, self.pos, 0), jnp.int32).at[slot].set(t)
            # decode writes kv at pos for every slot; inactive slots write
            # into their own scratch position 0 and are ignored
            logits, self.cache = self._decode(self.params, token, self.cache, pos)
            self.pos[slot] = t + 1
        self.active[slot] = True
        self.outputs[req_id] = []
        self.slot_req[slot] = req_id
        self._last_logits = logits
        return True

    def step(self) -> list[int]:
        """One decode step for all active slots; returns finished req ids."""
        if not self.active.any():
            return []
        last = {s: (self.outputs[r][-1] if self.outputs[r] else 1)
                for s, r in self.slot_req.items() if self.active[s]}
        token = jnp.asarray(
            [last.get(s, 0) for s in range(self.slots)], jnp.int32
        )
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, token, self.cache, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done = []
        for s in range(self.slots):
            if not self.active[s]:
                continue
            r = self.slot_req[s]
            self.outputs[r].append(int(nxt[s]))
            self.pos[s] += 1
            if self.pos[s] >= self.max_len - 1:
                self.active[s] = False
                done.append(r)
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=True)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = DecodeServer(cfg, params, slots=args.slots,
                          max_len=args.max_new + 16)

    rng = np.random.default_rng(0)
    pending = [(i, rng.integers(1, cfg.vocab, size=rng.integers(3, 9)))
               for i in range(args.requests)]
    t0 = time.time()
    finished, steps = 0, 0
    while finished < args.requests:
        while pending and server.admit(pending[0][0], pending[0][1]):
            print(f"[serve] admitted request {pending[0][0]} "
                  f"(prompt len {len(pending[0][1])})")
            pending.pop(0)
        done = server.step()
        steps += 1
        for r in done:
            finished += 1
            print(f"[serve] request {r} done: {len(server.outputs[r])} tokens")
        if steps > 10000:
            raise RuntimeError("server wedged")
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in server.outputs.values())
    print(f"[serve] {args.requests} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens/dt:.1f} tok/s, continuous batching over "
          f"{args.slots} slots)")


if __name__ == "__main__":
    main()
