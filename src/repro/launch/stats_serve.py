"""Multi-tenant stats server: stacked banks + continuous batching + overlap.

Randomness boundary: the synthetic driver in ``main`` draws its workload
from ``np.random`` (baselined, reprolint RPL005); *library-side* randomness
— sampling scores, eviction races, merge coordination — must derive from
the salted ``(key, eid)`` hashes in ``core/hashing.py``, never from an
ambient PRNG, or cross-host merges lose the coordinated-sampling guarantee.

The production serving tier for frequency-cap statistics (DESIGN.md §10).
N tenants' sketch grids live as ONE stacked pytree (``MultiTenantStats``
over ``core.incremental.TenantBank``); a continuous-batching scheduler
(``stats.scheduler.StatsScheduler``) admits ingest and query requests with
per-tenant round-robin fairness, coalesces every admitted query — across
tenants — into one jitted ``QueryEngine`` dispatch, and overlaps the next
ingest tick's device work with the in-flight query batch.

Usage
-----
Programmatic (the server is a library first)::

    from repro.core import freqfns
    from repro.stats.service import StatsConfig, MultiTenantStats
    from repro.stats.scheduler import StatsScheduler, ServeConfig

    svc = MultiTenantStats(StatsConfig(k=1024, ls=(1.0, 8.0, 64.0)),
                           n_tenants=64)
    sched = StatsScheduler(svc, ServeConfig(max_queries_per_step=256))

    sched.submit_ingest(tenant=3, keys=impression_keys)   # enqueue stream
    rid = sched.submit_query(3, freqfns.cap(8.0))         # enqueue query
    sched.step()                  # one overlapped serve iteration
    rec = sched.pop_result(rid)   # QueryRecord (evicted on read)
    print(rec.estimate, rec.stderr, rec.latency_s)

Command line (synthetic 64-tenant open-loop workload)::

    PYTHONPATH=src python -m repro.launch.stats_serve \
        --tenants 64 --steps 40 --requests 400

Checkpointing: ``svc.save_checkpoint(dir, step)`` writes the whole bank as
[T, ...]-stacked leaves; restore everything with ``restore_checkpoint`` or
a single tenant with ``checkpoint.manager.restore_slice`` (the handoff
path demonstrated in ``launch.elastic``).

``StatsServer`` below is the single-service predecessor shell (kept for
single-stream embedding in pipelines); for multi-tenant serving use the
scheduler.  Throughput numbers: benchmarks/serve_throughput.py
(BENCH_serve.json — elements/s, queries/s, p50/p99 latency vs the
per-tenant-loop oracle).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import freqfns
from ..core.segments import HashBucket
from ..stats.query import BatchResult, Query
from ..stats.scheduler import ServeConfig, StatsScheduler
from ..stats.service import MultiTenantStats, StatsConfig, StreamStatsService


class StatsServer:
    """Request-batching shell around ONE StreamStatsService.

    ``submit`` enqueues a query; ``step`` ingests the next stream batch and
    answers pending queries in FIFO ``max_batch``-sized dispatch slices.
    By default a step drains the whole backlog (a burst of B requests
    completes in ceil(B / max_batch) dispatches within one step instead of
    starving across B / max_batch steps); ``drain=False`` answers a single
    slice per step for strict latency pacing.

    Results are buffered per request id and evicted on ``pop_result`` so a
    long-lived server holds only unread answers.
    """

    def __init__(self, service: StreamStatsService, *, max_batch: int = 64):
        self.service = service
        self.max_batch = max_batch
        self.pending: list[tuple[int, Query]] = []
        self.results: dict[int, dict] = {}
        self.batch_sizes: list[int] = []

    def submit(self, req_id: int, fn, segment=None) -> None:
        self.pending.append((req_id, Query(fn, segment)))

    def pop_result(self, req_id: int) -> dict | None:
        """Take (and EVICT) a completed query's answer; None if pending."""
        return self.results.pop(req_id, None)

    def step(self, keys=None, weights=None, *, drain: bool = True) -> list[int]:
        """Ingest one stream batch (if any), then answer pending queries.

        ``drain=True`` (default) empties the backlog in FIFO max_batch
        slices; ``drain=False`` answers at most one slice.
        """
        if keys is not None and len(keys):
            self.service.observe(keys, weights)
        done: list[int] = []
        while self.pending:
            take, self.pending = (self.pending[: self.max_batch],
                                  self.pending[self.max_batch:])
            ids = [rid for rid, _ in take]
            batch: BatchResult = self.service.query_batch([q for _, q in take])
            for i, rid in enumerate(ids):
                self.results[rid] = {
                    "estimate": float(batch.estimates[i]),
                    "stderr": float(batch.stderr[i]),
                    "ci": (float(batch.ci_low[i]), float(batch.ci_high[i])),
                    "l": float(batch.lanes[i]),
                    "n_keys": int(batch.n_keys[i]),
                }
            self.batch_sizes.append(len(ids))
            done.extend(ids)
            if not drain:
                break
        return done


def main():
    ap = argparse.ArgumentParser(
        description="multi-tenant frequency-cap stats server (synthetic load)")
    ap.add_argument("--tenants", type=int, default=64)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--stream-batch", type=int, default=2048,
                    help="elements per tenant ingest request")
    ap.add_argument("--ingest-per-step", type=int, default=16,
                    help="tenants submitting an ingest request each step")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="queries coalesced into one dispatch")
    ap.add_argument("--k", type=int, default=512)
    ap.add_argument("--chunk", type=int, default=2048)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    svc = MultiTenantStats(
        StatsConfig(k=args.k, ls=(1.0, 8.0, 64.0), chunk=args.chunk),
        n_tenants=args.tenants)
    sched = StatsScheduler(svc, ServeConfig(
        max_ingest_per_step=args.ingest_per_step,
        max_queries_per_step=args.max_batch))

    # synthetic ad workload: per-tenant zipf impression streams; advertisers
    # ask for many (cap T, audience segment) cells — the paper's inherently
    # many-T many-segment query mix, multiplexed across tenants
    caps = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
    segments = [None] + [HashBucket(8, b) for b in range(8)]
    arrivals = rng.poisson(args.requests / args.steps, size=args.steps)

    next_req, finished, lat = 0, 0, []
    t0 = time.time()
    for step in range(args.steps):
        for t in rng.choice(args.tenants,
                            size=min(args.ingest_per_step, args.tenants),
                            replace=False):
            keys = (rng.zipf(1.3, size=args.stream_batch) % 100_000).astype(
                np.int64)
            sched.submit_ingest(int(t), keys)
        for _ in range(int(arrivals[step])):
            if next_req >= args.requests:
                break
            sched.submit_query(
                int(rng.integers(args.tenants)),
                freqfns.cap(float(rng.choice(caps))),
                segments[int(rng.integers(len(segments)))])
            next_req += 1
        done = sched.step()
        for rid in done:
            rec = sched.pop_result(rid)
            lat.append(rec.latency_s)
        finished += len(done)
        if done:
            print(f"[stats-serve] step {step:3d}: {len(done):3d} queries in "
                  f"one coalesced dispatch, backlog "
                  f"{int(sched.service.backlog_chunks().sum())} chunks")
    for rid in sched.drain():
        rec = sched.pop_result(rid)
        lat.append(rec.latency_s)
        finished += 1
    dt = time.time() - t0

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    p50 = float(np.percentile(lat_ms, 50)) if len(lat_ms) else 0.0
    p99 = float(np.percentile(lat_ms, 99)) if len(lat_ms) else 0.0
    print(f"[stats-serve] {finished} queries for {args.tenants} tenants over "
          f"{sched.n_elements_ingested:,} ingested elements in {dt:.1f}s "
          f"({finished/dt:.0f} q/s, {sched.n_elements_ingested/dt:,.0f} "
          f"elem/s, query latency p50 {p50:.1f} ms / p99 {p99:.1f} ms, "
          f"resident bank {svc.resident_bytes/1e6:.2f} MB)")


if __name__ == "__main__":
    main()
