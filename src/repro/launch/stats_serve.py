"""Stats serving launcher: request-batched frequency-cap queries over a live
ingestion stream.

A miniature production stats server in the style of ``launch.serve``'s
continuous-batched decode loop: impression batches and query requests
interleave; pending queries are admitted into a request batch and the whole
batch is answered by ONE jitted device dispatch of the query plane
(``StreamStatsService.query_batch``) instead of one host round-trip per
query.  Each answer ships with its variance/CI diagnostics.

    PYTHONPATH=src python -m repro.launch.stats_serve --requests 200 --max-batch 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import freqfns
from ..core.segments import HashBucket
from ..stats.query import BatchResult, Query
from ..stats.service import StatsConfig, StreamStatsService


class StatsServer:
    """Request-batching shell around a StreamStatsService.

    ``submit`` enqueues a query; ``step`` ingests the next stream batch and
    answers up to ``max_batch`` pending queries in one batched dispatch —
    the stats analogue of continuous batching over decode slots.
    """

    def __init__(self, service: StreamStatsService, *, max_batch: int = 64):
        self.service = service
        self.max_batch = max_batch
        self.pending: list[tuple[int, Query]] = []
        self.results: dict[int, dict] = {}
        self.batch_sizes: list[int] = []

    def submit(self, req_id: int, fn, segment=None) -> None:
        self.pending.append((req_id, Query(fn, segment)))

    def step(self, keys=None, weights=None) -> list[int]:
        """Ingest one stream batch (if any), then answer one request batch."""
        if keys is not None and len(keys):
            self.service.observe(keys, weights)
        if not self.pending:
            return []
        take, self.pending = (self.pending[: self.max_batch],
                              self.pending[self.max_batch:])
        ids = [rid for rid, _ in take]
        batch: BatchResult = self.service.query_batch([q for _, q in take])
        for i, rid in enumerate(ids):
            self.results[rid] = {
                "estimate": float(batch.estimates[i]),
                "stderr": float(batch.stderr[i]),
                "ci": (float(batch.ci_low[i]), float(batch.ci_high[i])),
                "l": float(batch.lanes[i]),
                "n_keys": int(batch.n_keys[i]),
            }
        self.batch_sizes.append(len(ids))
        return ids


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--stream-batch", type=int, default=8192)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--k", type=int, default=1024)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    service = StreamStatsService(
        StatsConfig(k=args.k, ls=(1.0, 4.0, 16.0, 64.0), chunk=2048))
    server = StatsServer(service, max_batch=args.max_batch)

    # synthetic ad workload: zipf impressions; advertisers ask for many
    # (cap T, audience segment) cells — the paper's inherently many-T
    # many-segment query mix
    caps = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
    segments = [None] + [HashBucket(8, b) for b in range(8)]
    arrivals = rng.poisson(args.requests / args.steps, size=args.steps)

    next_req, finished = 0, 0
    t0 = time.time()
    for step in range(args.steps):
        keys = (rng.zipf(1.3, size=args.stream_batch) % 100_000).astype(np.int64)
        for _ in range(int(arrivals[step])):
            if next_req >= args.requests:
                break
            server.submit(next_req, freqfns.cap(float(rng.choice(caps))),
                          segments[int(rng.integers(len(segments)))])
            next_req += 1
        done = server.step(keys)
        finished += len(done)
        if done:
            rid = done[-1]
            r = server.results[rid]
            print(f"[stats-serve] step {step:3d}: answered {len(done):3d} "
                  f"queries in one dispatch (e.g. req {rid}: "
                  f"{r['estimate']:.0f} ± {r['stderr']:.0f} on l={r['l']:g})")
    while server.pending:  # drain
        finished += len(server.step())
    dt = time.time() - t0
    served = len(server.results)
    mean_b = float(np.mean(server.batch_sizes)) if server.batch_sizes else 0.0
    print(f"[stats-serve] {served} queries over {service.n_observed:,} "
          f"ingested elements in {dt:.1f}s ({served/dt:.0f} q/s, mean request "
          f"batch {mean_b:.1f}, resident state {service.resident_bytes/1e6:.2f} MB)")


if __name__ == "__main__":
    main()
