"""Sharding utilities: PartitionSpec filtering + NamedSharding trees.

Cells are written against the *superset* axis vocabulary ("pod", "data",
"model"); `filter_spec` projects a spec onto whatever mesh is active (the
single-pod mesh has no "pod" axis), so the same cell lowers on both
production meshes and on the 1-device test mesh.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def filter_spec(spec: P, axis_names) -> P:
    if not isinstance(spec, P):
        return spec
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axis_names)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(entry if entry in axis_names else None)
    return P(*out)


def filter_spec_tree(tree, axis_names):
    return jax.tree.map(
        lambda s: filter_spec(s, axis_names),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def named_sharding_tree(tree, mesh):
    names = mesh.axis_names
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, names)),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def mesh_axis_names():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return ()
        return tuple(mesh.axis_names)
    except Exception:
        return ()
