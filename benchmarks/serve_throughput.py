"""Multi-tenant serving throughput: stacked bank + scheduler vs per-tenant loop.

The serving-plane benchmark (DESIGN.md §10).  A fixed open-loop workload —
T tenants each receiving one chunk of zipf impressions per round, plus a
mixed cap-query stream across tenants — is driven through two backends:

* ``stacked``: ONE ``MultiTenantStats`` bank behind the continuous-batching
  ``StatsScheduler`` — per round: one vmapped ingest dispatch advancing all
  T tenants, one coalesced query dispatch answering every tenant's queries,
  overlapped (the ingest tick is enqueued while the query batch is in
  flight);
* ``oracle``: the per-tenant Python loop a naive deployment would run — T
  standalone ``StreamStatsService`` instances, one observe dispatch per
  tenant per round, one query dispatch per tenant with pending queries.

Both see byte-identical streams and the same query mix; after the timed
rounds every tenant is probed with a fixed query set and the answers must
match BITWISE (the bank is a dispatch-count optimization, not an
approximation).  Timing is min-of-reps over the whole workload with
compile excluded by a warmup rep (same discipline as sampler_throughput:
the jitted steady state is what gets measured).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--smoke] [--json PATH]

``--json`` emits BENCH_serve.json (schema_version 4, stamped with backend +
interpret mode + the reprolint version/retrace budgets the timings were
taken under).  ``--smoke`` is the CI gate: FAILS unless stacked serving
measures >= 1.5x the oracle at 64 tenants and the probes are bit-identical.

Schema v3 adds the ``recovery`` section: time-to-recover one killed shard
of the fault-tolerant ingestion tier (stats/shardtier.py) as a function of
checkpoint cadence.  Recovery = checkpoint restore + WAL-tail replay, so
the cadence trades steady-state checkpoint cost against replay length at
recovery time; each cadence leg reports the recovery wall time, how many
WAL batches it replayed, and whether the recovered shard's answers are
bit-identical to the pre-kill state (they must be — the smoke gate
enforces it).

Schema v4 adds the ``merge_cadence`` section (DESIGN.md §14): the
background exact-merge tier's cadence policy (``merge_every_n_batches``)
folds shard WALs into a reconciled exact snapshot while approx queries
keep serving — the curve measures what the cadence trades: per-merge build
cost and cumulative merge time (pass II replays the WHOLE retained WAL, so
merges get more expensive as the stream grows) against estimate staleness
(elements routed since the snapshot watermark, mean/max over the run).
Each leg pins snapshot-at-watermark answers bit-identical to the exact
two-pass answers (the snapshot IS an exact answer, just a stale one) and
reports the end-of-run relative gap between the stale snapshot and a fresh
exact fold.

Regime note: the stacked win comes from amortizing per-dispatch overhead
(1 vmapped tick vs T observes; 1 coalesced query dispatch vs T engines), so
it grows as ticks get smaller/more frequent — the low-latency serving
regime this plane exists for.  At large chunks the per-dispatch compute
dominates and both paths converge (measured ~1.1x at chunk=2048 vs ~2x at
chunk=256 on XLA:CPU); the defaults pin the serving regime, not the
batch-analytics regime that benchmarks/sampler_throughput.py covers.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.core import freqfns
from repro.kernels.capscore.capscore import default_interpret
from repro.stats.scheduler import ServeConfig, StatsScheduler
from repro.stats.service import (
    MultiTenantStats, StatsConfig, StreamStatsService, TenantQuery)

from .sampler_throughput import reprolint_stamp

SCHEMA_VERSION = 4
# within sqrt(2) of the default (1, 8, 64) lane grid — no grid warnings
CAPS = (1.0, 8.0, 10.0, 64.0)


def make_workload(T, rounds, chunk, queries_per_round, seed=0):
    """Pre-generated so both backends replay byte-identical traffic."""
    rng = np.random.default_rng(seed)
    streams = [[(rng.zipf(1.3, size=chunk) % 50_000).astype(np.int64)
                for _ in range(rounds)] for _ in range(T)]
    queries = [[(int(rng.integers(T)), float(rng.choice(CAPS)))
                for _ in range(queries_per_round)] for _ in range(rounds)]
    return streams, queries


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    if not len(lat_ms):
        return 0.0, 0.0
    return (float(np.percentile(lat_ms, 50)), float(np.percentile(lat_ms, 99)))


def run_stacked(cfg, T, streams, queries):
    """One full workload pass through the scheduler; returns
    (elapsed_s, latencies_s, probe_answers)."""
    rounds = len(queries)
    svc = MultiTenantStats(cfg, n_tenants=T)
    sched = StatsScheduler(svc, ServeConfig(
        max_ingest_per_step=T, max_queries_per_step=max(
            len(queries[0]), 1) if rounds else 1))
    lat = []
    t0 = time.perf_counter()
    for r in range(rounds):
        for t in range(T):
            sched.submit_ingest(t, streams[t][r])
        rids = [sched.submit_query(t, freqfns.cap(cap))
                for t, cap in queries[r]]
        sched.step()
        for rid in rids:
            rec = sched.pop_result(rid)
            if rec is not None:
                lat.append(rec.latency_s)
    for rid in sched.drain():
        rec = sched.pop_result(rid)
        lat.append(rec.latency_s)
    # settle: fold everything and answer the probe set from the final state
    svc.drain()
    probes = svc.query_batch(
        [TenantQuery(t, freqfns.cap(cap)) for t in range(T) for cap in CAPS])
    answers = np.asarray(probes.estimates)
    elapsed = time.perf_counter() - t0
    return elapsed, lat, answers


def run_oracle(cfg, T, streams, queries):
    """The same workload as a per-tenant Python loop (naive deployment)."""
    rounds = len(queries)
    svcs = [StreamStatsService(cfg) for _ in range(T)]
    lat = []
    t0 = time.perf_counter()
    for r in range(rounds):
        for t in range(T):
            svcs[t].observe(streams[t][r])
        phase_start = time.perf_counter()
        by_tenant: dict[int, list[float]] = {}
        for t, cap in queries[r]:
            by_tenant.setdefault(t, []).append(cap)
        for t, caps in by_tenant.items():  # one dispatch per queried tenant
            svcs[t].query_batch([(freqfns.cap(c), None) for c in caps])
            now = time.perf_counter()
            lat.extend([now - phase_start] * len(caps))
    answers = np.concatenate([
        np.asarray(svcs[t].query_batch(
            [(freqfns.cap(c), None) for c in CAPS]).estimates)
        for t in range(T)])
    elapsed = time.perf_counter() - t0
    return elapsed, lat, answers


def run(T=64, rounds=16, chunk=512, queries_per_round=64, k=512,
        ls=(1.0, 8.0, 64.0), reps=2, verbose=True):
    cfg = StatsConfig(k=k, ls=ls, chunk=chunk)
    streams, queries = make_workload(T, rounds, chunk, queries_per_round)
    n_elements = T * rounds * chunk
    n_queries = rounds * queries_per_round

    results = {}
    for name, fn in (("stacked", run_stacked), ("oracle", run_oracle)):
        best, best_lat, answers = np.inf, [], None
        for rep in range(reps):  # rep 0 pays compile; min-of-reps drops it
            elapsed, lat, ans = fn(cfg, T, streams, queries)
            if answers is None:
                answers = ans
            else:
                assert np.array_equal(answers, ans), f"{name} reps disagree"
            if elapsed < best:
                best, best_lat = elapsed, lat
        p50, p99 = _percentiles(best_lat)
        results[name] = {
            "total_s": best,
            "elements_per_s": n_elements / best,
            "queries_per_s": n_queries / best,
            "latency_p50_ms": p50,
            "latency_p99_ms": p99,
            "answers": answers,
        }
        if verbose:
            r = results[name]
            print(f"{name:8s} {r['elements_per_s']:14,.0f} elem/s "
                  f"{r['queries_per_s']:10,.1f} q/s   "
                  f"p50 {p50:8.2f} ms   p99 {p99:8.2f} ms   "
                  f"({best:.2f}s total)")

    bit_identical = bool(np.array_equal(results["stacked"]["answers"],
                                        results["oracle"]["answers"]))
    speedup = results["oracle"]["total_s"] / results["stacked"]["total_s"]
    if verbose:
        print(f"\nstacked vs per-tenant-loop oracle: {speedup:.2f}x at "
              f"{T} tenants ({rounds} rounds x {chunk} elems/tenant, "
              f"{n_queries} queries); probe answers bit-identical: "
              f"{bit_identical}")
    for r in results.values():
        r.pop("answers")
    return {
        "config": {"tenants": T, "rounds": rounds, "chunk": chunk,
                   "queries_per_round": queries_per_round, "k": k,
                   "ls": list(ls), "reps": reps},
        "stacked": results["stacked"],
        "oracle": results["oracle"],
        "speedup_vs_oracle": speedup,
        "bit_identical": bit_identical,
    }


def run_recovery(cadences=(1, 4, 16), n_shards=2, n_batches=47, batch=2048,
                 k=4096, ls=(1.0, 8.0), chunk=1024, verbose=True):
    """Time-to-recover one killed shard vs checkpoint cadence.

    For each ``checkpoint_every`` cadence: build a tier, ingest the same
    deterministic stream, hard-kill shard 0, and time ``recover_shard``
    (checkpoint restore + WAL-tail replay — the dominant recovery cost at
    large k).  Tighter cadences replay fewer batches and recover faster at
    the price of more frequent steady-state checkpoint writes; the report
    quantifies that trade so a deployment can pick its recovery-time SLO.
    Post-recovery answers must be bit-identical to the pre-kill state.

    ``n_batches`` deliberately leaves a nonzero WAL tail past the last
    checkpoint for every cadence > 1 (default 47: tails of 3 and 15 at
    cadences 4 and 16) — killing exactly on a checkpoint boundary would
    measure restore time only and flatter the loose cadences."""
    import tempfile

    from repro.stats.query import Query
    from repro.stats.shardtier import ShardTier, TierConfig

    rng = np.random.default_rng(17)
    stream = [(rng.zipf(1.3, size=batch) % 50_000).astype(np.int64)
              for _ in range(n_batches)]
    probes = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]

    legs = {}
    for every in cadences:
        with tempfile.TemporaryDirectory() as d:
            tier = ShardTier(
                StatsConfig(k=k, ls=ls, chunk=chunk),
                TierConfig(n_shards=n_shards, checkpoint_every=every,
                           auto_recover=False),
                d)
            t0 = time.perf_counter()
            for b in stream:
                tier.ingest(b)
            ingest_s = time.perf_counter() - t0
            pre = np.asarray(tier.query_batch(probes).estimates)

            tier.kill_shard(0)
            t0 = time.perf_counter()
            tier.recover_shard(0)
            recover_s = time.perf_counter() - t0
            w = tier.workers[0]
            replayed = w.applied_seq - w._last_ckpt_seq
            post = np.asarray(tier.query_batch(probes).estimates)
        legs[str(every)] = {
            "checkpoint_every": every,
            "ingest_s": ingest_s,
            "recover_s": recover_s,
            "replayed_batches": int(replayed),
            "bit_identical": bool(np.array_equal(pre, post)),
        }
        if verbose:
            leg = legs[str(every)]
            print(f"cadence {every:3d}: recover {recover_s*1e3:9.1f} ms "
                  f"({leg['replayed_batches']} WAL batches replayed, "
                  f"ingest {ingest_s:.2f}s, bit-identical "
                  f"{leg['bit_identical']})")
    return {
        "config": {"n_shards": n_shards, "n_batches": n_batches,
                   "batch": batch, "k": k, "ls": list(ls), "chunk": chunk},
        "cadences": legs,
    }


def run_merge_cadence(cadences=(2, 4, 8, 16), n_shards=2, n_batches=35,
                      batch=1024, k=256, ls=(1.0, 8.0), chunk=512,
                      verbose=True):
    """Merge-cadence vs estimate-staleness curve (schema v4).

    For each ``merge_every_n_batches`` cadence: ingest the same
    deterministic stream through a tier with the background exact-merge
    enabled, recording per-merge build time (wall — the tier runs on a
    WallClock injector with an empty schedule) and the element staleness of
    the serving snapshot after every batch.  Tighter cadences keep
    snapshot answers fresher but pay pass II more often — and each pass II
    replays the whole retained WAL, so cumulative merge cost grows
    superlinearly as the cadence tightens.  Bit-identity at the watermark
    is pinned per leg: immediately after a refresh the snapshot answers
    must equal the exact two-pass answers exactly."""
    import tempfile

    from repro.launch.faults import FaultInjector, WallClock
    from repro.stats.query import Query
    from repro.stats.shardtier import ShardTier, TierConfig

    rng = np.random.default_rng(23)
    stream = [(rng.zipf(1.3, size=batch) % 50_000).astype(np.int64)
              for _ in range(n_batches)]
    probes = [Query(freqfns.distinct()), Query(freqfns.cap(8.0))]

    legs = {}
    for every in cadences:
        with tempfile.TemporaryDirectory() as d:
            tier = ShardTier(
                StatsConfig(k=k, ls=ls, chunk=chunk),
                TierConfig(n_shards=n_shards, checkpoint_every=8,
                           retain_wal=True, fsync=False,
                           merge_every_n_batches=every),
                d, faults=FaultInjector(clock=WallClock()))
            merge_s, staleness = [], []
            watermark_identical = None
            t0 = time.perf_counter()
            for b in stream:
                n_before = tier._n_merges
                tier.ingest(b)
                if tier._n_merges > n_before:
                    merge_s.append(float(tier._snapshot["build_s"]))
                    if watermark_identical is None:
                        snap = np.asarray(tier.query_batch(
                            probes, mode="snapshot").estimates)
                        exact = np.asarray(tier.query_batch(
                            probes, mode="exact").estimates)
                        watermark_identical = bool(
                            np.array_equal(snap, exact))
                s = tier.snapshot_staleness()
                if s is not None:
                    staleness.append(s)
            total_s = time.perf_counter() - t0
            # end-of-run estimate gap: the stale snapshot vs a fresh fold
            snap_end = np.asarray(tier.query_batch(
                probes, mode="snapshot").estimates)
            exact_end = np.asarray(tier.query_batch(
                probes, mode="exact").estimates)
            gap = float(np.max(np.abs(snap_end - exact_end)
                               / np.maximum(np.abs(exact_end), 1e-12)))
        legs[str(every)] = {
            "merge_every_n_batches": every,
            "n_merges": len(merge_s),
            # the first merge pays the reconcile-path jit compile; the
            # steady-state mean excludes it (when there is a steady state)
            "merge_s_first": merge_s[0] if merge_s else None,
            "merge_s_mean": (float(np.mean(merge_s[1:] or merge_s))
                             if merge_s else None),
            "merge_s_total": float(np.sum(merge_s)),
            "total_s": total_s,
            "merge_fraction": float(np.sum(merge_s)) / total_s,
            "staleness_elements_mean": (float(np.mean(staleness))
                                        if staleness else None),
            "staleness_elements_max": (int(np.max(staleness))
                                       if staleness else None),
            "end_estimate_rel_gap": gap,
            "bit_identical_at_watermark": watermark_identical,
        }
        if verbose:
            leg = legs[str(every)]
            print(f"cadence {every:3d}: {leg['n_merges']:2d} merges "
                  f"({leg['merge_s_total']:6.2f}s total, "
                  f"{leg['merge_fraction']:5.1%} of run)  staleness "
                  f"mean {leg['staleness_elements_mean'] or 0:8.0f} "
                  f"max {leg['staleness_elements_max'] or 0:6d} elems  "
                  f"end gap {leg['end_estimate_rel_gap']:.3%}  "
                  f"watermark bit-identical {leg['bit_identical_at_watermark']}")
    return {
        "config": {"n_shards": n_shards, "n_batches": n_batches,
                   "batch": batch, "k": k, "ls": list(ls), "chunk": chunk},
        "cadences": legs,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; enforces the >=1.5x gate at 64 "
                         "tenants and bitwise probe identity")
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--tenants", type=int, default=None)
    args = ap.parse_args()

    print(f"{'path':8s} {'elements/s':>14s} {'queries/s':>10s}")
    if args.smoke:
        res = run(T=args.tenants or 64, rounds=8, chunk=256,
                  queries_per_round=24, k=128, reps=3)
        print("\n[recovery] shard time-to-recover vs checkpoint cadence "
              "(smoke-sized)")
        recovery = run_recovery(cadences=(1, 4, 16), n_batches=19,
                                batch=512, k=512, chunk=256)
        print("\n[merge-cadence] background exact-merge cadence vs "
              "staleness (smoke-sized)")
        merge_cadence = run_merge_cadence(cadences=(2, 8), n_batches=19,
                                          batch=256, k=128, chunk=128)
    else:
        res = run(T=args.tenants or 64)
        print("\n[recovery] shard time-to-recover vs checkpoint cadence "
              "(k=4096)")
        recovery = run_recovery()
        print("\n[merge-cadence] background exact-merge cadence vs "
              "staleness")
        merge_cadence = run_merge_cadence()

    record = {
        "bench": "serve_throughput",
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "capscore_interpret": bool(default_interpret()),
        "reprolint": reprolint_stamp(),
        "recovery": recovery,
        "merge_cadence": merge_cadence,
        **res,
    }
    with open(args.json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"[serve_throughput] wrote {args.json}")

    if args.smoke:
        failed = []
        if not res["bit_identical"]:
            failed.append("stacked probe answers are NOT bit-identical to "
                          "the per-tenant oracle")
        if res["speedup_vs_oracle"] < 1.5:
            failed.append(f"stacked serving measured "
                          f"{res['speedup_vs_oracle']:.2f}x the per-tenant "
                          f"loop (gate: >= 1.5x)")
        for every, leg in recovery["cadences"].items():
            if not leg["bit_identical"]:
                failed.append(f"recovery at cadence {every} changed the "
                              "shard's answers (bit-identity violated)")
        for every, leg in merge_cadence["cadences"].items():
            if leg["bit_identical_at_watermark"] is not True:
                failed.append(f"merge cadence {every}: snapshot answers at "
                              "the watermark are not bit-identical to the "
                              "exact two-pass answers")
            if leg["staleness_elements_max"] is not None and \
                    leg["staleness_elements_max"] >= int(every) * \
                    merge_cadence["config"]["batch"]:
                failed.append(f"merge cadence {every}: staleness exceeded "
                              "one full cadence period")
        if failed:
            print("PERF GATE FAILED: " + "; ".join(failed), file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
