"""Cross-host merge cost: exact (lossless summaries + pass II) vs approximate
(1-pass merge_fixed_k), the two modes of StreamStatsService.merge.

Reports per-merge wall time, the reconcile re-scan rate that exact mode adds
(pass II over every shard), and the per-host state each mode ships:

    PYTHONPATH=src python -m benchmarks.merge_throughput
"""
from __future__ import annotations

import time

import numpy as np

from repro.stats.service import StatsConfig, StreamStatsService


def _fresh_pair(cfg_kwargs, sh0, sh1):
    a = StreamStatsService(StatsConfig(host_id=0, **cfg_kwargs))
    b = StreamStatsService(StatsConfig(host_id=1, **cfg_kwargs))
    a.observe(sh0)
    b.observe(sh1)
    return a, b


def main(n=400_000, k=2048, ls=(1.0, 16.0, 256.0, 4096.0), repeats=5):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % 200_000).astype(np.int64)
    sh0, sh1 = keys[0::2], keys[1::2]
    cfg_kwargs = dict(k=k, ls=ls, chunk=2048)

    # warm the jit caches both paths hit
    a, b = _fresh_pair(cfg_kwargs, sh0[:4096], sh1[:4096])
    a.merge(b, mode="exact")
    a.reconcile(sh0[:4096])
    a, b = _fresh_pair(cfg_kwargs, sh0[:4096], sh1[:4096])
    a.merge(b, mode="approx")

    t_approx = []
    for _ in range(repeats):
        a, b = _fresh_pair(cfg_kwargs, sh0, sh1)
        t0 = time.time()
        a.merge(b, mode="approx")
        t_approx.append(time.time() - t0)

    t_exact, t_recon = [], []
    for _ in range(repeats):
        a, b = _fresh_pair(cfg_kwargs, sh0, sh1)
        t0 = time.time()
        a.merge(b, mode="exact")
        t_exact.append(time.time() - t0)
        t0 = time.time()
        a.reconcile(sh0)
        a.reconcile(sh1)
        t_recon.append(time.time() - t0)

    # per-host shipped state: the fixed-k tables both modes move, plus the
    # bottom-(k+1) summaries only exact mode needs
    L = len(ls)
    table_bytes = L * (k + 2048) * (4 + 4 + 4 + 4)  # keys/counts/kb/seed
    summary_bytes = L * (k + 1) * (4 + 4)           # bk_keys/bk_seeds

    print(f"stream n={n:,} split across 2 hosts  k={k}  |ls|={L}")
    print(f"{'mode':28s} {'merge s':>9} {'pass-II s':>10} {'shipped bytes':>14}")
    print(f"{'approx (1-pass, ~biased)':28s} {np.median(t_approx):>9.3f} "
          f"{'-':>10} {table_bytes:>14,}")
    print(f"{'exact (summaries + pass II)':28s} {np.median(t_exact):>9.3f} "
          f"{np.median(t_recon):>10.3f} {table_bytes + summary_bytes:>14,}")
    rate = n / np.median(t_recon)
    print(f"\nexact-mode reconcile re-scan rate: {rate:,.0f} keys/s "
          f"(pass II is one searchsorted-accumulate per lane per shard)")
    print(f"summary overhead on shipped state: "
          f"{summary_bytes / table_bytes:.1%}")
    return {
        "approx_merge_s": float(np.median(t_approx)),
        "exact_merge_s": float(np.median(t_exact)),
        "exact_reconcile_s": float(np.median(t_recon)),
        "reconcile_keys_per_s": float(rate),
    }


if __name__ == "__main__":
    main()
