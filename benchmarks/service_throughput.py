"""StreamStatsService ingestion throughput and resident footprint.

Compares the incremental service (O(k*|ls|) device state, one multi-l
dispatch per observe batch) against the pre-refactor buffer-and-replay
strategy (host-buffer the raw stream, re-run every SH_l sketch from scratch
per query), which is reconstructed here for the comparison:

    PYTHONPATH=src python -m benchmarks.service_throughput
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import vectorized as V
from repro.stats.service import StatsConfig, StreamStatsService


class BufferAndReplay:
    """The old StreamStatsService ingestion strategy (pre-incremental)."""

    def __init__(self, config: StatsConfig):
        self.config = config
        self._chunks: list[np.ndarray] = []

    def observe(self, keys):
        self._chunks.append(np.asarray(keys, np.int64))

    def query_all(self):
        keys = np.concatenate(self._chunks)
        return {
            l: V.sample_fixed_k(keys, None, k=self.config.k, l=l,
                                salt=self.config.salt, chunk=self.config.chunk)
            for l in self.config.ls
        }

    @property
    def resident_bytes(self) -> int:
        return sum(c.nbytes for c in self._chunks)


def main(n=200_000, batch=8192, k=2048, ls=(1.0, 16.0, 256.0, 4096.0)):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % 100_000).astype(np.int64)
    cfg = StatsConfig(k=k, ls=ls, chunk=2048)

    # --- incremental service -------------------------------------------------
    # warm every jit cache the timed loop will hit (the module-level jits are
    # shared across service instances): the full-batch update, the truncated
    # final-batch update, and the query-time remainder flush
    svc = StreamStatsService(cfg)
    svc.observe(keys[:batch])
    svc.observe(keys[batch:batch + (n % batch or batch)])
    svc.query_cap(8)
    svc = StreamStatsService(cfg)
    t0 = time.time()
    for i in range(0, n, batch):
        svc.observe(keys[i:i + batch])
    t_ingest = time.time() - t0
    t0 = time.time()
    svc.query_cap(8)
    t_query = time.time() - t0
    inc_bytes = svc.resident_bytes

    # --- old path: buffer the stream, replay per query ----------------------
    old = BufferAndReplay(cfg)
    t0 = time.time()
    for i in range(0, n, batch):
        old.observe(keys[i:i + batch])
    t_ingest_old = time.time() - t0
    t0 = time.time()
    old.query_all()
    t_query_old = time.time() - t0
    old_bytes = old.resident_bytes

    print(f"stream n={n:,}  batch={batch}  k={k}  |ls|={len(ls)}")
    print(f"{'path':24s} {'ingest keys/s':>14} {'query s':>9} {'resident bytes':>15}")
    print(f"{'incremental (multi-l)':24s} {n / t_ingest:>14,.0f} {t_query:>9.3f} "
          f"{inc_bytes:>15,}")
    print(f"{'buffer-and-replay':24s} {n / t_ingest_old:>14,.0f} {t_query_old:>9.3f} "
          f"{old_bytes:>15,}")
    print(f"\nresident state ratio (old/new): {old_bytes / inc_bytes:.1f}x "
          f"(grows with the stream; incremental is O(k*|ls|) flat)")
    print(f"query latency ratio  (old/new): {t_query_old / max(t_query, 1e-9):.1f}x "
          f"(replay recomputes every sketch per query)")
    return {
        "incremental_keys_per_s": n / t_ingest,
        "incremental_query_s": t_query,
        "incremental_bytes": inc_bytes,
        "replay_keys_per_s": n / t_ingest_old,
        "replay_query_s": t_query_old,
        "replay_bytes": old_bytes,
    }


if __name__ == "__main__":
    main()
