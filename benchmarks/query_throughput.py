"""Query-plane throughput: batched jitted query_batch vs the per-query host
path, across batch sizes.

The scalar baseline is the pre-refactor query loop: one
``estimators.estimate`` call per (FreqFn, segment) with ad-hoc segment
re-materialization (``np.isin`` / predicate evaluation per query) — the
path every query took before the batched engine existed.  The engine
answers the same mixed cap_T x segment batches in one jitted dispatch over
the stacked lanes with compiled-once segment masks.

Acceptance target (ISSUE 3): >= 10x queries/sec over the scalar path at
batch >= 64.

    PYTHONPATH=src python -m benchmarks.query_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core.segments import HashBucket, IdSet, Predicate
from repro.stats.query import Query, QueryEngine
from repro.stats.service import StatsConfig, StreamStatsService


def _query_pool(n_keys: int, rng, audience: int) -> list[Query]:
    """The paper's ad workload: many cap_T cells x audience segments.

    Audience segments are id-lists (the advertiser's user sets — tens of
    thousands of ids each), plus cheap predicate / hash-bucket slices; the
    per-query host path re-materializes each of them per query, the engine
    compiles each (lane, segment) pair once into its device mask bank.
    """
    caps = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0]
    segments = [None,
                Predicate(lambda k: k % 2 == 0, "even"),
                Predicate(lambda k: k % 3 == 0, "mod3")]
    segments += [IdSet(rng.integers(0, n_keys, size=audience))
                 for _ in range(4)]
    segments += [IdSet(rng.integers(0, n_keys, size=audience // 10))
                 for _ in range(2)]
    segments += [HashBucket(16, b) for b in range(4)]
    pool = [Query(F.cap(T), s) for T in caps for s in segments]
    pool += [Query(F.distinct(), s) for s in segments[:3]]
    pool += [Query(F.total(), s) for s in segments[:3]]
    rng.shuffle(pool)
    return pool


def _scalar_loop(sketches, queries, pick):
    """The pre-engine per-query host path (fresh mask per query)."""
    out = []
    for q in queries:
        seg = q.segment
        raw = seg.fn if isinstance(seg, Predicate) else (
            seg.ids if isinstance(seg, IdSet) else seg)
        out.append(E.estimate(sketches[pick(q)], q.fn, raw))
    return out


def main(n=400_000, k=4096, ls=(1.0, 4.0, 16.0, 64.0, 256.0),
         batch_sizes=(1, 8, 64, 256), rounds=5, n_keys=200_000,
         audience=50_000, check_target=True):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % n_keys).astype(np.int64)
    svc = StreamStatsService(StatsConfig(k=k, ls=ls, chunk=2048))
    for i in range(0, n, 16384):
        svc.observe(keys[i:i + 16384])
    sketches = svc.sketches()

    pool = _query_pool(n_keys, rng, audience)

    def pick(q):
        if q.fn.kind in ("cap", "threshold"):
            return svc.pick_l(q.fn.param)
        if q.fn.kind == "distinct":
            return svc.pick_l(1.0)
        return max(ls)

    # warm: fill the segment-mask / coefficient-table banks over the whole
    # query pool (a long-lived service's steady state) and compile every
    # (Qp, K) dispatch shape the timed loop will hit
    svc.query_batch(pool)
    for b in batch_sizes:
        svc.query_batch([pool[j % len(pool)] for j in range(b)])

    print(f"stream n={n:,}  k={k}  |ls|={len(ls)}  query pool {len(pool)}")
    print(f"{'batch':>6} {'engine q/s':>12} {'scalar q/s':>12} {'speedup':>9}")
    results = {}
    ok_64 = None
    for b in batch_sizes:
        batches = [[pool[(i * b + j) % len(pool)] for j in range(b)]
                   for i in range(rounds)]
        for qs in batches:  # warm plans/banks for every rotation
            res = svc.query_batch(qs)
        # min over rounds: the machine-capability number on shared boxes
        t_engine, t_scalar = math.inf, math.inf
        for qs in batches:
            t0 = time.perf_counter()
            res = svc.query_batch(qs)
            t_engine = min(t_engine, time.perf_counter() - t0)
            t0 = time.perf_counter()
            ref = _scalar_loop(sketches, qs, pick)
            t_scalar = min(t_scalar, time.perf_counter() - t0)
            # answers must agree bit-for-bit (the engine's core contract)
            assert all(r == float(e) for r, e in zip(ref, res.estimates)), \
                "engine != scalar loop"
        qps_e, qps_s = b / t_engine, b / t_scalar
        speed = qps_e / qps_s
        results[b] = {"engine_qps": qps_e, "scalar_qps": qps_s, "speedup": speed}
        if b >= 64:
            ok_64 = max(ok_64 or 0.0, speed)
        print(f"{b:>6} {qps_e:>12,.0f} {qps_s:>12,.0f} {speed:>8.1f}x")
    if ok_64 is not None and check_target:
        print(f"\nbatch>=64 speedup target (>=10x): best {ok_64:.1f}x — "
              f"{'OK' if ok_64 >= 10.0 else 'MISSED'}")
    elif ok_64 is not None:
        print(f"\nbest batch>=64 speedup {ok_64:.1f}x (reduced size: "
              "bit-identity/shape check only; the >=10x target is judged at "
              "the default production sizes)")
    results["target_ok"] = (ok_64 >= 10.0) if ok_64 is not None else None
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (shape/contract check only)")
    args = ap.parse_args()
    if args.smoke:
        main(n=40_000, k=256, ls=(1.0, 8.0, 64.0), batch_sizes=(1, 64),
             rounds=2, n_keys=20_000, audience=4_000, check_target=False)
    else:
        main()
