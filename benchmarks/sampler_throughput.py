"""Sampler throughput: sequential oracle vs TPU-native chunked vs kernel path.

The paper's own evaluation skips runtime ("similar to widely applied distinct
counting algorithms"); for a framework the element-rate IS the product, so we
measure it: elements/second for the oracle (Algorithm 5), the vectorized
fixed-k sampler at several chunk sizes, the capscore elementwise stage alone,
and — the headline since the single-sort ingest restructure — the multi-lane
``update_multi`` path against its pre-restructure reference, with per-stage
timings (score / order / aggregate / merge / evict) that show where the
L+1 redundant sorts went.

    PYTHONPATH=src python -m benchmarks.sampler_throughput [--smoke] [--json PATH]

``--json`` (default ``BENCH_ingest.json`` when given no value via run.py)
emits a machine-readable record of elements/s per path so CI can track the
perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incremental as I
from repro.core import samplers as S
from repro.core import vectorized as V
from repro.core.segments import chunk_order
from repro.kernels.capscore.ops import capscore, capscore_multi


def bench(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warm/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    return (time.time() - t0) / reps


def _zipf(n, n_keys=50000, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.zipf(1.3, size=n) % n_keys).astype(np.int64)


# ---------------------------------------------------------------------------
# Multi-lane ingest: single-sort path vs pre-restructure reference
# ---------------------------------------------------------------------------


def _stage_timings(L, k, chunk, reps=5):
    """Time each pipeline stage of one chunk step, new vs legacy form.

    Demonstrates the sort-count reduction: the legacy step pays L chunk sorts
    (aggregate) + 1 chunk sort (summary) + L table sorts of k+2*chunk (merge)
    + L capacity sorts (evict) per chunk; the restructured step pays ONE
    chunk sort total, O(N) searchsorted merges and a top_k partial select.
    """
    rng = np.random.default_rng(7)
    ls = jnp.asarray(np.geomspace(1.0, 2.0 ** (L - 1), L), jnp.float32)
    ck = jnp.asarray(_zipf(chunk, seed=3)[:chunk], jnp.int32)
    cw = jnp.ones(chunk, jnp.float32)
    eids = jnp.arange(chunk, dtype=jnp.int32)
    salt = jnp.uint32(1)

    # a warmed, representative state: ingest a few chunks so tau is finite
    state, spec = I.init_multi_state(np.asarray(ls), k=k, chunk=chunk, salt=1)
    warm = _zipf(chunk * 4, seed=5).astype(np.int32)
    state = I.update_multi(state, warm, np.ones(len(warm), np.float32), spec,
                           donate=False)
    table = state.table

    score, delta, entry, kb = capscore_multi(ck, eids, cw, ls, table.tau, salt)

    j_order = jax.jit(chunk_order)
    order = j_order(ck)

    def agg_shared(sc, dl, en, kb_l):
        return jax.vmap(
            lambda s_, d_, e_, b_: V.aggregate_continuous_scored(
                ck, cw, s_, d_, e_, b_, order)
        )(sc, dl, en, kb_l)

    def agg_legacy(sc, dl, en, kb_l):
        return jax.vmap(
            lambda s_, d_, e_, b_: V.aggregate_continuous_scored(
                ck, cw, s_, d_, e_, b_)
        )(sc, dl, en, kb_l)

    j_agg_shared = jax.jit(agg_shared)
    j_agg_legacy = jax.jit(agg_legacy)
    aggs = j_agg_shared(score, delta, entry, kb)

    j_merge_sorted = jax.jit(lambda t, a: jax.vmap(V.fixed_k_merge)(t, a))
    j_merge_legacy = jax.jit(lambda t, a: jax.vmap(
        lambda tt, aa: V._merge_table(tt, aa)[:4])(t, a))
    merged = j_merge_sorted(table, aggs)

    j_evict_topk = jax.jit(lambda t: jax.vmap(
        lambda tt, l: V.evict_table(tt, k=k, l=l, salt=salt, max_evict=chunk)
    )(t, ls))
    j_evict_sort = jax.jit(lambda t: jax.vmap(
        lambda tt, l: V._evict_to_k_ref(tt.keys, tt.counts, tt.kb, tt.seed,
                                        tt.tau, k, l, salt, tt.step)
    )(t, ls))

    stages = {
        "score(capscore_multi)": lambda: capscore_multi(ck, eids, cw, ls, table.tau, salt),
        "order(1 shared chunk sort)": lambda: j_order(ck),
        "aggregate[shared order, L lanes]": lambda: j_agg_shared(score, delta, entry, kb),
        "aggregate[legacy: L chunk sorts]": lambda: j_agg_legacy(score, delta, entry, kb),
        "merge[sorted-runs, L lanes]": lambda: j_merge_sorted(table, aggs),
        "merge[legacy: L table re-sorts]": lambda: j_merge_legacy(table, aggs),
        "evict[top_k, L lanes]": lambda: j_evict_topk(merged),
        "evict[legacy: L full sorts]": lambda: j_evict_sort(merged),
    }
    return {name: bench(fn, reps=reps) * 1e3 for name, fn in stages.items()}


def multi_lane_ingest(L=8, k=4096, chunk=4096, n_chunks=4, reps=3, stage_reps=5):
    """Elements/s of update_multi: single-sort path vs pre-restructure path."""
    ls = np.geomspace(1.0, 2.0 ** (L - 1), L)
    n = n_chunks * chunk
    keys = _zipf(n, seed=11).astype(np.int32)
    w = np.ones(n, np.float32)

    def run(reference):
        state, spec = I.init_multi_state(ls, k=k, chunk=chunk, salt=2)
        # warm tau so steady-state (evicting) chunks are what gets timed
        state = I.update_multi(state, keys, w, spec, donate=False,
                               reference=reference)
        return bench(I.update_multi, state, keys, w, spec, donate=False,
                     reference=reference, reps=reps)

    t_ref = run(reference=True)
    t_new = run(reference=False)
    out = {
        "L": L, "k": k, "chunk": chunk, "n": n,
        "reference_eps": n / t_ref,
        "sorted_eps": n / t_new,
        "speedup": t_ref / t_new,
        "stages_ms": _stage_timings(L, k, chunk, reps=stage_reps),
    }
    return out


def print_ingest(res):
    print(f"\n-- multi-lane ingest (L={res['L']}, k={res['k']}, "
          f"chunk={res['chunk']}, n={res['n']}):")
    print(f"{'path':36s} {'elements/s':>14s}")
    print(f"{'update_multi[reference pre-PR]':36s} {res['reference_eps']:14.0f}")
    print(f"{'update_multi[single-sort]':36s} {res['sorted_eps']:14.0f}")
    print(f"speedup: {res['speedup']:.2f}x")
    print(f"\n{'per-stage (one chunk step)':36s} {'ms':>10s}")
    for name, ms in res["stages_ms"].items():
        print(f"{name:36s} {ms:10.3f}")


def main(n=200_000, k=256, l=20.0, ingest_kw=None, json_path=None):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % 50000).astype(np.int64)
    rows = []

    t = bench(lambda: S.alg5_fixed_k_continuous(keys[:20000], None, k, l=l, salt=1), reps=1)
    rows.append(("alg5_sequential_oracle", 20000 / t, t * 1e6 / 20000))

    for chunk in (1024, 4096, 16384):
        t = bench(V.sample_fixed_k, keys, None, k=k, l=l, salt=1, chunk=chunk)
        rows.append((f"vectorized_fixed_k_chunk{chunk}", n / t, t * 1e6 / n))

    t = bench(V.sample_two_pass, keys, None, k=k, l=l, salt=1, chunk=4096)
    rows.append(("vectorized_two_pass", n / t, t * 1e6 / n))

    m = min(131072, n)
    kk = jnp.asarray(keys[:m], jnp.int32)
    ee = jnp.arange(m, dtype=jnp.int32)
    ww = jnp.ones(m, jnp.float32)
    t = bench(lambda: capscore(kk, ee, ww, l, 0.01, 3, backend="xla"))
    rows.append(("capscore_stage_xla", m / t, t * 1e6 / m))

    print(f"{'path':36s} {'elements/s':>14s} {'us/element':>12s}")
    for name, eps, us in rows:
        print(f"{name:36s} {eps:14.0f} {us:12.4f}")

    ingest = multi_lane_ingest(**(ingest_kw or {}))
    print_ingest(ingest)

    if json_path:
        record = {
            "bench": "sampler_throughput",
            "single_lane": {name: {"elements_per_s": eps} for name, eps, _ in rows},
            "multi_lane_ingest": {
                k_: v for k_, v in ingest.items() if k_ != "stages_ms"
            },
            "multi_lane_stages_ms": ingest["stages_ms"],
        }
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\n[sampler_throughput] wrote {json_path}")
    return rows, ingest


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small L/k/chunk, still emits JSON)")
    ap.add_argument("--json", default="BENCH_ingest.json",
                    help="machine-readable output path")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        main(n=50_000, k=128,
             ingest_kw=dict(L=4, k=512, chunk=1024, n_chunks=2, reps=2,
                            stage_reps=2),
             json_path=args.json)
    else:
        main(n=2_000_000 if args.full else 200_000,
             ingest_kw=dict(L=8, k=4096, chunk=4096),
             json_path=args.json)
