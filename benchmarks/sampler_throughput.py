"""Sampler throughput: sequential oracle vs TPU-native chunked vs kernel path.

The paper's own evaluation skips runtime ("similar to widely applied distinct
counting algorithms"); for a framework the element-rate IS the product, so we
measure it: elements/second for the oracle (Algorithm 5), the vectorized
fixed-k sampler at several chunk sizes, and the capscore elementwise stage
alone (XLA vs Pallas-interpret is correctness-only on CPU; on TPU the Pallas
path replaces the XLA scoring inside the chunk step).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import samplers as S
from repro.core import vectorized as V
from repro.kernels.capscore.ops import capscore


def bench(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warm/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    return (time.time() - t0) / reps


def main(n=200_000, k=256, l=20.0):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % 50000).astype(np.int64)
    rows = []

    t = bench(lambda: S.alg5_fixed_k_continuous(keys[:20000], None, k, l=l, salt=1), reps=1)
    rows.append(("alg5_sequential_oracle", 20000 / t, t * 1e6 / 20000))

    for chunk in (1024, 4096, 16384):
        t = bench(V.sample_fixed_k, keys, None, k=k, l=l, salt=1, chunk=chunk)
        rows.append((f"vectorized_fixed_k_chunk{chunk}", n / t, t * 1e6 / n))

    t = bench(V.sample_two_pass, keys, None, k=k, l=l, salt=1, chunk=4096)
    rows.append(("vectorized_two_pass", n / t, t * 1e6 / n))

    import jax.numpy as jnp

    kk = jnp.asarray(keys[:131072], jnp.int32)
    ee = jnp.arange(131072, dtype=jnp.int32)
    ww = jnp.ones(131072, jnp.float32)
    t = bench(lambda: capscore(kk, ee, ww, l, 0.01, 3, backend="xla"))
    rows.append(("capscore_stage_xla", 131072 / t, t * 1e6 / 131072))

    print(f"{'path':36s} {'elements/s':>14s} {'us/element':>12s}")
    for name, eps, us in rows:
        print(f"{name:36s} {eps:14.0f} {us:12.4f}")
    return rows


if __name__ == "__main__":
    main()
